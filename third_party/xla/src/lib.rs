//! API stub for the `xla` (xla-rs) crate.
//!
//! Exposes exactly the type/method surface `slice_serve::runtime` uses,
//! so `cargo check --features pjrt` compiles the real-hardware path in
//! this offline environment. Every fallible operation fails fast with a
//! recognizable `xla stub:` error; constructors return inert values.
//! See README.md in this directory for how to swap in the real closure.

use std::fmt;
use std::path::Path;

/// Stub error: always means "this build links the API stub".
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Self {
        Error(format!(
            "xla stub: {what} is unavailable — replace third_party/xla with \
             the real xla-rs closure to run on hardware"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (only `F32` is exercised by slice-serve).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    Bf16,
    F16,
    F32,
    F64,
}

/// A host-side literal (tensor value).
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _opaque: (),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal::default()
    }

    /// Rank-0 literal.
    pub fn scalar<T>(_value: T) -> Literal {
        Literal::default()
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::stub("Literal::create_from_shape_and_untyped_data"))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::stub("Literal::to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    pub fn copy_raw_to<T>(&self, _dst: &mut [T]) -> Result<()> {
        Err(Error::stub("Literal::copy_raw_to"))
    }
}

/// npz/raw-bytes loading surface (trait form, as in xla-rs).
pub trait FromRawBytes: Sized {
    fn read_npz<P: AsRef<Path>>(path: P, config: &()) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    fn read_npz<P: AsRef<Path>>(_path: P, _config: &()) -> Result<Vec<(String, Self)>> {
        Err(Error::stub("Literal::read_npz"))
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {
    _opaque: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _opaque: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _opaque: () }
    }
}

/// A PJRT client (CPU plugin in the real closure).
#[derive(Debug)]
pub struct PjRtClient {
    _opaque: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu (no PJRT plugin linked)"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _opaque: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _opaque: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_operations_fail_with_recognizable_error() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().starts_with("xla stub:"));
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[1, 3]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal::read_npz("nope.npz", &()).is_err());
    }
}
