//! Offline API shim for the `anyhow` crate.
//!
//! crates.io is unreachable in this repo's build environment, so this
//! vendored crate reimplements the small `anyhow` subset slice-serve
//! uses — `Result`, `Error`, the `anyhow!`/`bail!`/`ensure!` macros and
//! the `Context` extension trait — with the same names and call-site
//! semantics, so application code reads like standard rust and can move
//! to the real crate unchanged if the environment ever gains registry
//! access.
//!
//! Differences from the real crate: the error holds its context chain as
//! rendered strings (no source-error downcasting, no backtraces).

use std::fmt;

/// A drop-in `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error with a chain of context frames.
///
/// Display prints the outermost context; `{:#}` (alternate) prints the
/// whole chain outermost-to-root separated by `": "`, matching anyhow.
pub struct Error {
    /// Context frames: `frames[0]` is the root cause, later entries are
    /// contexts added around it (outermost last).
    frames: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with one more context frame (outermost).
    fn push_context<C: fmt::Display>(mut self, context: C) -> Self {
        self.frames.push(context.to_string());
        self
    }

    /// The chain of messages, outermost context first, root cause last.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().rev().map(String::as_str)
    }

    /// The root cause message (innermost frame).
    pub fn root_cause(&self) -> &str {
        &self.frames[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, frame) in self.frames.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{frame}")?;
            }
            Ok(())
        } else {
            // outermost context only, like anyhow
            write!(f, "{}", self.frames.last().expect("error has a message"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.last().expect("error has a message"))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in self.frames.iter().rev().skip(1) {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent alongside the reflexive `From<Error> for Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        Error::msg(err)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, exactly like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with a lazily-evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
    }

    #[test]
    fn contexts_stack_outermost_first() {
        let e: Error = Err::<(), _>(io_err())
            .context("layer 1")
            .context("layer 2")
            .unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["layer 2", "layer 1", "file missing"]);
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn option_context_and_macros() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.with_context(|| "was none".to_string())?;
            ensure!(v < 10, "too big: {v}");
            if v == 7 {
                bail!("unlucky {v}");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(f(None).unwrap_err().to_string(), "was none");
        assert_eq!(f(Some(99)).unwrap_err().to_string(), "too big: 99");
        assert_eq!(f(Some(7)).unwrap_err().to_string(), "unlucky 7");
        assert_eq!(anyhow!("x = {}", 5).to_string(), "x = 5");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
