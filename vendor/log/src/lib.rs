//! Offline API shim for the `log` facade crate.
//!
//! Reimplements the subset slice-serve uses — `Level`, `LevelFilter`,
//! `Log`, `Record`, `Metadata`, `set_logger`/`set_max_level`/`max_level`
//! and the five level macros — with the real crate's names and
//! semantics, so `util::logger` and call sites stay source-compatible
//! with the published `log` crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter, with `Off` below every [`Level`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn to_level_filter(self) -> LevelFilter {
        match self {
            Level::Error => LevelFilter::Error,
            Level::Warn => LevelFilter::Warn,
            Level::Info => LevelFilter::Info,
            Level::Debug => LevelFilter::Debug,
            Level::Trace => LevelFilter::Trace,
        }
    }
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a log record (level + target module path).
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, as handed to [`Log::log`].
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        false
    }

    fn log(&self, _record: &Record) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger has already been installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger, or a no-op sink before installation.
pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

/// Set the global maximum verbosity.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// The global maximum verbosity (records above this are skipped).
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing: build a [`Record`] and hand it to the global logger.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments) {
    let record = Record { metadata: Metadata { level, target }, args };
    logger().log(&record);
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_api_log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Error);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Trace);
        assert!(LevelFilter::Off < Level::Error);
        assert_eq!(Level::Warn.to_level_filter(), LevelFilter::Warn);
    }

    // One test owns the global MAX_LEVEL to avoid cross-test races.
    #[test]
    fn max_level_round_trips_and_macros_compile() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
        info!("dropped {}", 1);
        error!("also dropped");
        log!(Level::Trace, "still fine");
    }
}
