//! Quickstart: the end-to-end validation driver.
//!
//! Loads the real AOT-compiled model through the PJRT runtime and serves
//! a batch of mixed edge requests with the SLICE scheduler in **wall
//! time**, streaming real generated tokens. Reports per-task TTFT, TPOT,
//! SLO attainment, and aggregate latency/throughput.
//!
//! Run:  make artifacts && cargo run --release --example quickstart
//!
//! The run is recorded in EXPERIMENTS.md ("End-to-end validation").

use std::path::PathBuf;

use anyhow::Result;

use slice_serve::config::ServeConfig;
use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
use slice_serve::engine::clock::WallClock;
use slice_serve::engine::latency::LatencyModel;
use slice_serve::engine::pjrt::PjrtEngine;
use slice_serve::engine::sampler::Sampler;
use slice_serve::engine::tokenizer;
use slice_serve::metrics::report::{ms2, pct, secs2, Table};
use slice_serve::metrics::Attainment;
use slice_serve::runtime::ModelRuntime;
use slice_serve::server::Server;
use slice_serve::util::{logger, secs, to_ms};
use slice_serve::workload::WorkloadSpec;

fn main() -> Result<()> {
    logger::init();
    let artifacts = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));

    println!("== SLICE quickstart: real model, real tokens, wall-clock ==\n");
    let runtime = ModelRuntime::load(&artifacts)?;
    println!(
        "platform={} model=d{}/L{} context={}\n",
        runtime.platform(),
        runtime.dims().d_model,
        runtime.dims().n_layers,
        runtime.dims().max_seq
    );

    // Calibrate the SLICE latency model from this machine: quick single
    // measurement per bucket (the `calibrate` subcommand does it more
    // carefully; for the quickstart a rough model is fine).
    let latency = LatencyModel::from_points(
        vec![(1, 4_500), (2, 5_700), (4, 10_000), (8, 13_600), (16, 38_000)],
        vec![(16, 8_000), (32, 12_000), (64, 22_000)],
        16,
    );

    // A 20-request mixed edge workload at 4 tasks/s: robot commands
    // (real-time), voice and Q&A.
    let spec = WorkloadSpec::edge_mix(4.0, 0.5, 20, 7);
    let workload = spec.generate();
    let n = workload.len();

    let _cfg = ServeConfig::default();
    let policy = SlicePolicy::new(latency, SliceConfig::default());
    let engine = PjrtEngine::new(runtime, Sampler::Greedy, 7);

    let t0 = std::time::Instant::now();
    let report = Server::new(
        workload,
        Box::new(policy),
        Box::new(engine),
        WallClock::new(),
    )
    .run(secs(600.0))?;
    let wall = t0.elapsed();

    let mut table = Table::new(&[
        "task", "class", "prompt", "out", "TTFT", "avg TPOT", "SLO",
    ]);
    let mut total_tokens = 0u64;
    for t in &report.tasks {
        total_tokens += t.tokens_generated as u64;
        table.row(vec![
            t.id.to_string(),
            t.class.label().to_string(),
            format!("{:.16}…", String::from_utf8_lossy(&t.prompt)),
            format!("{:.12}…", tokenizer::decode(&t.generated)),
            ms2(t.ttft().map_or(f64::NAN, |v| to_ms(v))),
            ms2(t.avg_tpot().map_or(f64::NAN, |v| to_ms(v))),
            if t.slo_met() { "met" } else { "MISS" }.to_string(),
        ]);
    }
    println!("{}", table.render());

    let a = Attainment::compute(&report.tasks);
    println!("tasks: {n}   finished: {}   engine steps: {}", a.n_finished, report.steps);
    println!("overall SLO attainment: {}", pct(a.slo));
    println!("real-time SLO attainment: {}", pct(a.rt_slo));
    println!("non-real-time SLO attainment: {}", pct(a.nrt_slo));
    println!("mean completion: {}", secs2(a.mean_completion_all));
    println!(
        "wall time: {:.2}s   aggregate decode throughput: {:.1} tokens/s",
        wall.as_secs_f64(),
        total_tokens as f64 / wall.as_secs_f64()
    );
    Ok(())
}
