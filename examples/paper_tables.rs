//! Regenerate every table and figure of the paper's evaluation section
//! in one run and write the machine-readable results to
//! `results/paper_results.json` (consumed by EXPERIMENTS.md).
//!
//! Run: cargo run --release --example paper_tables [n_tasks] [seed]

use anyhow::Result;

use slice_serve::config::ServeConfig;
use slice_serve::experiments;
use slice_serve::util::json::Json;
use slice_serve::util::logger;

fn main() -> Result<()> {
    logger::init();
    let n_tasks: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let seed: u64 = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(42);
    let cfg = ServeConfig { n_tasks, seed, ..ServeConfig::default() };

    println!("== Regenerating all paper tables/figures (n_tasks={n_tasks}, seed={seed}) ==\n");

    let out = Json::obj()
        .set("n_tasks", n_tasks)
        .set("seed", seed)
        .set("fig1", experiments::fig1::run()?)
        .set("table2", experiments::static_mix::run(&cfg)?)
        .set("dynamic", experiments::dynamic::run(&cfg)?)
        .set("fig10", experiments::ratio_sweep::run(&cfg)?)
        .set("fig11", experiments::rate_sweep::run(&cfg)?)
        .set("ablation", experiments::ablation::run(&cfg)?);

    std::fs::create_dir_all("results")?;
    std::fs::write("results/paper_results.json", out.to_pretty())?;
    println!("\nwrote results/paper_results.json");
    Ok(())
}
