//! Perf probe: raw `ModelRuntime::decode_into` latency at the largest
//! batch bucket, sampled over several rounds — the measurement tool used
//! for the EXPERIMENTS.md §Perf iteration log. Unlike the engine bench,
//! this isolates the runtime layer (literal creation + XLA execution +
//! result copy-out) from the engine's KV slot management.
use slice_serve::runtime::ModelRuntime;
use std::time::Instant;

fn main() {
    let rt = ModelRuntime::load(std::path::Path::new("artifacts")).unwrap();
    let dims = rt.dims();
    let slab = dims.kv_slab_elems();
    let b = 16usize;
    let tokens = vec![65i32; b];
    let lens = vec![20i32; b];
    let kv = vec![0.01f32; b * slab];
    let mut logits = vec![0.0f32; b * dims.vocab];
    let mut kv_out = vec![0.0f32; b * slab];
    for round in 0..6 {
        let mut times = vec![];
        for _ in 0..10 {
            let t0 = Instant::now();
            rt.decode_into(&tokens, &lens, &kv, &mut logits, &mut kv_out).unwrap();
            times.push(t0.elapsed().as_millis());
        }
        times.sort();
        println!("round {round}: p50={}ms min={}ms max={}ms", times[5], times[0], times[9]);
    }
}
