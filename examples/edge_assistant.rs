//! Edge-assistant scenario: a voice assistant (8 tok/s to match speech)
//! and text Q&A (10 tok/s reading speed) sharing one edge device, no
//! real-time tasks at all — the *rate-matching* side of SLICE.
//!
//! Shows the decode-mask matrix delivering per-class rates: voice tasks
//! get ~8 tokens per second-cycle, Q&A ~10, instead of the uniform rate
//! a single batch would force, and how much concurrency that buys.
//!
//! Run: cargo run --release --example edge_assistant

use anyhow::Result;

use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::coordinator::task::{Task, TaskClass};
use slice_serve::engine::clock::VirtualClock;
use slice_serve::engine::sim::SimEngine;
use slice_serve::experiments::build_policy;
use slice_serve::metrics::report::{ms2, pct, Table};
use slice_serve::metrics::{Attainment, TpotSummary};
use slice_serve::server::Server;
use slice_serve::util::{logger, secs};
use slice_serve::workload::{ClassProfile, WorkloadSpec};

fn main() -> Result<()> {
    logger::init();
    println!("== Edge assistant: voice (8 tok/s) + Q&A (10 tok/s), no RT tasks ==\n");

    // 50/50 voice and Q&A at 0.35 tasks/s (~88 tok/s demand) — right at
    // the device's saturation knee. Utility is the operator's balance
    // knob: with equal utility-rates voice (cheapest per token) loses
    // contended slots, so we weight voice up to parity.
    let mut voice_profile = ClassProfile::default_for(TaskClass::Voice);
    voice_profile.utility = 2.0; // r = 2 * 0.125s = 0.25 vs QA 2 * 0.1 = 0.2
    let spec = WorkloadSpec {
        arrival_rate: 0.35,
        n_tasks: 120,
        mix: vec![
            (voice_profile, 0.5),
            (ClassProfile::default_for(TaskClass::TextQa), 0.5),
        ],
        seed: 5,
        with_prompt_bytes: false,
    };
    let cfg = ServeConfig::default();

    let mut table = Table::new(&[
        "policy", "voice TPOT", "qa TPOT", "voice SLO", "qa SLO", "overall SLO",
    ]);
    for kind in [PolicyKind::Orca, PolicyKind::FastServe, PolicyKind::Slice] {
        let report = Server::new(
            spec.generate(),
            build_policy(kind, &cfg),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        )
        .run(secs(600.0))?;

        let voice: Vec<&Task> = report
            .tasks
            .iter()
            .filter(|t| t.class == TaskClass::Voice)
            .collect();
        let qa: Vec<&Task> = report
            .tasks
            .iter()
            .filter(|t| t.class == TaskClass::TextQa)
            .collect();
        let v_sum = TpotSummary::compute("voice", &voice);
        let q_sum = TpotSummary::compute("qa", &qa);
        let v_slo = voice.iter().filter(|t| t.slo_met()).count() as f64 / voice.len() as f64;
        let q_slo = qa.iter().filter(|t| t.slo_met()).count() as f64 / qa.len() as f64;
        let a = Attainment::compute(&report.tasks);

        table.row(vec![
            report.policy.to_string(),
            ms2(v_sum.mean_tpot_ms),
            ms2(q_sum.mean_tpot_ms),
            pct(v_slo),
            pct(q_slo),
            pct(a.slo),
        ]);
    }
    println!("{}", table.render());
    println!("SLICE's mask matrix gives each class a rate matched to its SLO");
    println!("(voice ≈125ms/token, Q&A ≈100ms/token) instead of one uniform rate.");
    Ok(())
}
