//! Robot-control scenario (the paper's motivating edge workload, §I):
//! a robot issues latency-critical control/navigation commands while
//! long-form Q&A runs on the same edge device.
//!
//! Demonstrates the deadline guarantee: under SLICE every control
//! command completes inside its 1.5 s deadline even while the device is
//! saturated with Q&A; under Orca/FastServe the uniform batch drags the
//! control commands past their deadlines.
//!
//! Run: cargo run --release --example robot_control

use anyhow::Result;

use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::coordinator::task::{Task, TaskClass};
use slice_serve::engine::clock::VirtualClock;
use slice_serve::engine::sim::SimEngine;
use slice_serve::experiments::build_policy;
use slice_serve::metrics::report::{pct, secs2, Table};
use slice_serve::server::Server;
use slice_serve::util::rng::Rng;
use slice_serve::util::{logger, secs, to_secs};
use slice_serve::workload::ClassProfile;

/// Control loop: one navigation command every 2 s for a minute, against
/// a steady background of Q&A sessions (1 every 1.5 s, ~250 tokens).
fn build_scenario(seed: u64) -> Vec<Task> {
    let mut rng = Rng::new(seed);
    let mut tasks = Vec::new();
    let qa = ClassProfile::default_for(TaskClass::TextQa);

    let mut events: Vec<(u64, TaskClass)> = Vec::new();
    for i in 0..30 {
        events.push((secs(2.0 * i as f64), TaskClass::RealTime));
    }
    for i in 0..40 {
        events.push((secs(1.5 * i as f64) + 250_000, TaskClass::TextQa));
    }
    events.sort_by_key(|&(at, _)| at);

    for (id, (at, class)) in events.into_iter().enumerate() {
        let (prompt, out, utility) = match class {
            TaskClass::RealTime => (
                rng.range_u64(8, 24) as u32,
                rng.range_u64(6, 14) as u32,
                100.0,
            ),
            _ => (
                rng.range_u64(qa.prompt_range.0 as u64, qa.prompt_range.1 as u64) as u32,
                rng.range_u64(qa.output_range.0 as u64, qa.output_range.1 as u64) as u32,
                qa.utility,
            ),
        };
        tasks.push(Task::new(id as u64, class, at, prompt, out, utility));
    }
    tasks
}

fn main() -> Result<()> {
    logger::init();
    println!("== Robot control under load: SLICE vs Orca vs FastServe ==\n");
    println!("30 navigation commands (1.5s deadline, 20 tok/s) vs 40 long Q&A sessions\n");

    let cfg = ServeConfig::default();
    let mut table = Table::new(&[
        "policy",
        "commands in deadline",
        "worst command latency",
        "mean command latency",
        "Q&A SLO",
    ]);

    for kind in [PolicyKind::Orca, PolicyKind::FastServe, PolicyKind::Slice] {
        let tasks = build_scenario(99);
        let report = Server::new(
            tasks,
            build_policy(kind, &cfg),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        )
        .run(secs(300.0))?;

        let rt: Vec<&Task> = report
            .tasks
            .iter()
            .filter(|t| t.class.is_real_time())
            .collect();
        let in_deadline = rt.iter().filter(|t| t.slo_met()).count();
        let worst = rt
            .iter()
            .filter_map(|t| t.completion_time())
            .max()
            .unwrap_or(0);
        let mean = rt
            .iter()
            .filter_map(|t| t.completion_time())
            .map(to_secs)
            .sum::<f64>()
            / rt.len().max(1) as f64;
        let qa_met = report
            .tasks
            .iter()
            .filter(|t| !t.class.is_real_time() && t.slo_met())
            .count();
        let qa_total = report.tasks.len() - rt.len();

        table.row(vec![
            report.policy.to_string(),
            format!("{in_deadline}/{}", rt.len()),
            secs2(to_secs(worst)),
            secs2(mean),
            pct(qa_met as f64 / qa_total as f64),
        ]);
    }
    println!("{}", table.render());
    println!("SLICE keeps every control command inside its deadline by pausing");
    println!("low-utility Q&A decodes; uniform batching cannot.");
    Ok(())
}
