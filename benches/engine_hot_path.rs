//! Engine hot-path benchmarks: the simulation engine (which every paper
//! sweep multiplies by millions of steps), metric computation, and — if
//! `artifacts/` is present — the real PJRT decode step per batch bucket
//! (the Fig. 1 measurement as a bench).
//!
//! Run: cargo bench --bench engine_hot_path

use std::time::Duration;

use slice_serve::coordinator::pool::TaskPool;
use slice_serve::coordinator::task::{Task, TaskClass};
use slice_serve::engine::sim::SimEngine;
use slice_serve::engine::DecodeEngine;
use slice_serve::metrics::Attainment;
use slice_serve::util::bench::{bench, report_header};

fn sim_pool(n: usize) -> TaskPool {
    let mut pool = TaskPool::new();
    for i in 0..n as u64 {
        pool.insert(Task::new(i, TaskClass::Voice, 0, 16, 1000, 1.0));
    }
    pool
}

fn main() {
    let budget = Duration::from_millis(400);
    println!("{}", report_header());

    // sim engine decode step
    let pool = sim_pool(32);
    let mut engine = SimEngine::paper_calibrated();
    for b in [1usize, 9, 32] {
        let ids: Vec<u64> = (0..b as u64).collect();
        let r = bench(&format!("sim/decode_step/b{b}"), budget, || {
            engine.decode(&pool, &ids).unwrap()
        });
        println!("{}", r.report_line());
    }

    // metrics over a large finished run
    let mut tasks: Vec<Task> = Vec::new();
    for i in 0..10_000u64 {
        let mut t = Task::new(i, TaskClass::Voice, 0, 16, 4, 1.0);
        for k in 0..4u64 {
            t.on_token(1_000 + k * 100_000);
        }
        tasks.push(t);
    }
    let r = bench("metrics/attainment/10k_tasks", budget, || {
        Attainment::compute(&tasks)
    });
    println!("{}", r.report_line());

    #[cfg(feature = "pjrt")]
    pjrt_decode_bench();
    #[cfg(not(feature = "pjrt"))]
    println!("(pjrt benches skipped: built without --features pjrt)");
}

/// Real PJRT decode per bucket (Fig. 1 as a bench) — requires artifacts.
#[cfg(feature = "pjrt")]
fn pjrt_decode_bench() {
    use slice_serve::engine::pjrt::PjrtEngine;
    use slice_serve::engine::sampler::Sampler;
    use slice_serve::runtime::ModelRuntime;

    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let runtime = ModelRuntime::load(artifacts).expect("loading artifacts");
        let buckets = runtime.decode_buckets();
        let mut engine = PjrtEngine::new(runtime, Sampler::Greedy, 0);
        let mut pool = TaskPool::new();
        let maxb = *buckets.last().unwrap() as u64;
        for i in 0..maxb {
            let mut t = Task::new(i, TaskClass::TextQa, 0, 16, 64, 1.0);
            t.prompt = format!("bench prompt {i} padding pad").into_bytes();
            t.prompt.truncate(16);
            t.prompt_len = 16;
            pool.insert(t);
        }
        for i in 0..maxb {
            engine.prefill(&pool, i).unwrap();
        }
        // Manual timing loop: re-prefills happen *outside* the timed
        // region so the numbers are pure decode-step latency (this is
        // the Fig. 1 measurement).
        let max_seq = engine.max_context();
        for &b in &buckets {
            let ids: Vec<u64> = (0..b as u64).collect();
            let mut samples: Vec<u64> = Vec::new();
            while samples.len() < 15 {
                for &id in &ids {
                    if engine.cached_len(id).unwrap_or(0) + 4 >= max_seq {
                        engine.release(id);
                        engine.prefill(&pool, id).unwrap();
                    }
                }
                let t0 = std::time::Instant::now();
                let out = engine.decode(&pool, &ids).unwrap();
                samples.push(t0.elapsed().as_nanos() as u64);
                std::hint::black_box(out);
            }
            samples.sort_unstable();
            let p50 = samples[samples.len() / 2] as f64;
            let mean = samples.iter().sum::<u64>() as f64 / samples.len() as f64;
            let p99 = samples[samples.len() - 1] as f64;
            println!(
                "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
                format!("pjrt/decode_step/b{b}"),
                slice_serve::util::bench::fmt_ns(mean),
                slice_serve::util::bench::fmt_ns(p50),
                slice_serve::util::bench::fmt_ns(p99),
                samples.len()
            );
        }
    } else {
        println!(
            "(pjrt benches skipped: artifacts/ not built — run \
             `python3 -m compile.aot --out-dir ../artifacts` from python/)"
        );
    }
}
