//! End-to-end benches: one per paper table/figure, timing the full
//! simulation harness that regenerates it (workload generation +
//! discrete-event serving + metrics). These are the "cargo bench — one
//! per paper table" deliverable; the *contents* of each table/figure are
//! printed by `slice-serve experiment <id>` / `examples/paper_tables`.
//!
//! Run: cargo bench --bench paper_experiments

use std::time::Instant;

use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::engine::latency::LatencyModel;
use slice_serve::experiments::{self, fig1};
use slice_serve::util::bench::fmt_ns;
use slice_serve::workload::{table2_static_workload, WorkloadSpec};

fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("{:<40} {:>12}", name, fmt_ns(t0.elapsed().as_nanos() as f64));
    out
}

fn main() {
    // modest sizes so the full bench suite stays fast; the real numbers
    // are produced by `slice-serve experiment all --n-tasks 400`
    let cfg = ServeConfig { n_tasks: 150, ..ServeConfig::default() };
    println!("{:<40} {:>12}", "experiment (end-to-end)", "wall");

    time_once("fig1/latency_model_sweep", || {
        fig1::from_model(&LatencyModel::paper_calibrated(), &fig1::default_batches())
    });

    time_once("table2/static_mix_3_policies", || {
        for kind in experiments::ALL_POLICIES {
            let wl = table2_static_workload();
            experiments::run_sim(kind, wl, &cfg, experiments::default_drain()).unwrap();
        }
    });

    time_once("fig7_8_9/dynamic_3_policies", || {
        for kind in experiments::ALL_POLICIES {
            let wl =
                WorkloadSpec::paper_mix(1.0, 0.7, cfg.n_tasks, cfg.seed).generate();
            experiments::run_sim(kind, wl, &cfg, experiments::default_drain()).unwrap();
        }
    });

    time_once("fig10/ratio_sweep_5x3_cells", || {
        for ratio in [0.1, 0.3, 0.5, 0.7, 0.9] {
            for kind in experiments::ALL_POLICIES {
                let wl =
                    WorkloadSpec::paper_mix(1.0, ratio, cfg.n_tasks, cfg.seed).generate();
                experiments::run_sim(kind, wl, &cfg, experiments::default_drain())
                    .unwrap();
            }
        }
    });

    time_once("fig11/rate_sweep_10x3_cells", || {
        for rate in [0.1, 0.3, 0.5, 0.8, 1.0, 1.5, 2.0, 3.0, 5.0, 7.0] {
            for kind in experiments::ALL_POLICIES {
                let wl =
                    WorkloadSpec::paper_mix(rate, 0.7, cfg.n_tasks, cfg.seed).generate();
                experiments::run_sim(kind, wl, &cfg, experiments::default_drain())
                    .unwrap();
            }
        }
    });

    time_once("ablation/slice_variants", || {
        experiments::ablation::run(&ServeConfig { n_tasks: 60, ..cfg.clone() }).unwrap()
    });

    // steady-state serving throughput of the whole stack (sim engine):
    // how many scheduling+decode iterations per second the coordinator
    // can sustain — L3 must never be the bottleneck.
    let wl = WorkloadSpec::paper_mix(1.0, 0.7, 300, 42).generate();
    let t0 = Instant::now();
    let report =
        experiments::run_sim(PolicyKind::Slice, wl, &cfg, experiments::default_drain())
            .unwrap();
    let wall = t0.elapsed();
    let steps_per_sec = report.steps as f64 / wall.as_secs_f64();
    println!(
        "\nSLICE 300-task run: {} engine steps in {} -> {:.0} steps/s simulated",
        report.steps,
        fmt_ns(wall.as_nanos() as f64),
        steps_per_sec
    );
}
