//! Scheduler hot-path micro-benchmarks.
//!
//! The decode loop invokes the scheduler at every iteration boundary, so
//! the paper's "Challenge 2: scheduling overhead" translates to: one
//! scheduling decision must cost ≪ one decode step (~18-130 ms).
//! Targets (EXPERIMENTS.md §Perf): full reschedule at 64 queued tasks
//! < 100 µs; column-scan step < 1 µs; one cluster routing decision at
//! 8 replicas ≪ the mean task inter-arrival gap.
//!
//! Run: cargo bench --bench scheduler_hot_path

use std::time::Duration;

use slice_serve::cluster::{DeviceProfile, Replica, Router, RoutingStrategy};
use slice_serve::config::ServeConfig;
use slice_serve::coordinator::mask::{period_eq7, DecodeMask};
use slice_serve::coordinator::pool::TaskPool;
use slice_serve::coordinator::scheduler::{Policy, Step};
use slice_serve::coordinator::selection::{
    select_tasks_with, Candidate, Selection, SelectionScratch, CYCLE_CAP,
};
use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
use slice_serve::coordinator::task::{Task, TaskClass};
use slice_serve::engine::clock::VirtualClock;
use slice_serve::engine::latency::LatencyModel;
use slice_serve::engine::sim::SimEngine;
use slice_serve::experiments;
use slice_serve::server::Server;
use slice_serve::util::bench::{bench, report_header};
use slice_serve::util::rng::Rng;
use slice_serve::util::secs;
use slice_serve::workload::WorkloadSpec;

fn candidates(n: usize, seed: u64) -> Vec<Candidate> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| Candidate {
            id: i as u64,
            utility: if rng.chance(0.7) { 100.0 } else { 1.0 },
            tpot: rng.range_u64(50, 250) * 1_000,
            kv_bytes: rng.range_u64(1, 24) * 512 * 1024,
        })
        .collect()
}

fn pool_with_running(n: usize) -> TaskPool {
    let mut pool = TaskPool::new();
    for i in 0..n as u64 {
        let class = if i % 3 == 0 { TaskClass::RealTime } else { TaskClass::Voice };
        let mut t = Task::new(i, class, 0, 16, 1000, 1.0);
        t.state = slice_serve::coordinator::task::TaskState::Running;
        t.prefill_end = Some(1);
        t.first_token = Some(1);
        t.tokens_generated = 1;
        pool.insert(t);
    }
    pool
}

fn main() {
    let budget = Duration::from_millis(400);
    let lat = LatencyModel::paper_calibrated();
    println!("{}", report_header());

    // the PR 5 hot path: reusable scratch + incremental Eq. 7 — this is
    // exactly how SlicePolicy::reschedule invokes selection, so the
    // cell tracks what one Alg. 4 admission pass really costs
    let mut scratch = SelectionScratch::new(lat.clone());
    let mut sel_out = Selection::default();
    for n in [8usize, 64, 256, 1024] {
        let cands = candidates(n, 1);
        let r = bench(&format!("selection/select_tasks/{n}"), budget, || {
            select_tasks_with(&mut scratch, &mut sel_out, &cands, CYCLE_CAP, None);
            sel_out.selected.len()
        });
        println!("{}", r.report_line());

        // the memory knapsack dimension rides the same greedy loop; its
        // overhead per decision must stay negligible
        let r = bench(&format!("selection/select_tasks_kv/{n}"), budget, || {
            select_tasks_with(
                &mut scratch,
                &mut sel_out,
                &cands,
                CYCLE_CAP,
                Some(96 * 1024 * 1024),
            );
            sel_out.selected.len()
        });
        println!("{}", r.report_line());
    }

    for n in [8usize, 64, 256] {
        let mut rng = Rng::new(2);
        let rows: Vec<(u64, u32)> =
            (0..n).map(|i| (i as u64, rng.range_u64(4, 20) as u32)).collect();
        let r = bench(&format!("mask/build/{n}"), budget, || {
            DecodeMask::build(rows.clone())
        });
        println!("{}", r.report_line());

        let mask = DecodeMask::build(rows.clone());
        let mut col = 0u32;
        let r = bench(&format!("mask/column_batch/{n}"), budget, || {
            let b = mask.batch_len(col);
            col = (col + 1) % mask.columns();
            b
        });
        println!("{}", r.report_line());

        let quotas: Vec<u32> = {
            let mut q: Vec<u32> = rows.iter().map(|&(_, v)| v).collect();
            q.sort_unstable_by(|a, b| b.cmp(a));
            q
        };
        let r = bench(&format!("mask/period_eq7/{n}"), budget, || {
            period_eq7(&quotas, &lat)
        });
        println!("{}", r.report_line());
    }

    // One reschedule + one scheduling step, with the decode batch
    // handed back like the serving loop does (Server::execute_step
    // recycles it), so the cell measures the production steady state.
    let step_and_recycle = |policy: &mut SlicePolicy, pool: &mut TaskPool| {
        match policy.next_step(pool, 0) {
            Step::Decode { tasks } => {
                let batch = tasks.len();
                policy.recycle_batch(tasks);
                batch
            }
            _ => 0,
        }
    };

    // Full online reschedule: the cost paid on every arrival/completion
    // boundary the incremental fast paths cannot absorb. The driver
    // re-notifies the same ids each iteration, which the cache contract
    // forbids (one on_arrival per new task), so these cells run with
    // incrementality disabled — they price the rebuild path itself.
    let full_cfg = SliceConfig { incremental: false, ..SliceConfig::default() };
    for n in [16usize, 64, 256] {
        let mut pool = pool_with_running(n);
        let mut policy = SlicePolicy::new(lat.clone(), full_cfg.clone());
        let ids: Vec<u64> = (0..n as u64).collect();
        let r = bench(&format!("slice/full_reschedule/{n}"), budget, || {
            policy.on_arrival(&mut pool, &ids, 0);
            step_and_recycle(&mut policy, &mut pool)
        });
        println!("{}", r.report_line());
    }

    // The PR 5 acceptance cells: one Alg. 4 reschedule over a deep
    // queue (scratch-owned, allocation-free — the historical reference
    // pipeline these replaced was deleted once its semantics moved into
    // the property suite; BENCH_5.json preserves the measured speedup).
    for n in [256usize, 1024] {
        let mut pool = pool_with_running(n);
        let mut policy = SlicePolicy::new(lat.clone(), full_cfg.clone());
        let ids: Vec<u64> = (0..n as u64).collect();
        let r = bench(&format!("slice/reschedule/{n}"), budget, || {
            policy.on_arrival(&mut pool, &ids, 0);
            step_and_recycle(&mut policy, &mut pool)
        });
        println!("{}", r.report_line());
    }

    // The PR 8 incremental control plane at the same depths: one
    // arrival that provably cannot change the admitted prefix (the
    // boundary skip, O(log n) cache insert, no selection), then its
    // departure (O(log n) cache removal + one cached-path reschedule —
    // no pool pass, no sort). Against slice/reschedule above, the delta
    // is the O(changes) win the scale sweep's decisions/sec reflects.
    for n in [256usize, 1024] {
        let mut pool = pool_with_running(n);
        let mut policy = SlicePolicy::with_defaults(lat.clone());
        let ids: Vec<u64> = (0..n as u64).collect();
        policy.on_arrival(&mut pool, &ids, 0);
        let _ = step_and_recycle(&mut policy, &mut pool);
        let mut next = n as u64;
        let r = bench(&format!("slice/incremental_cycle/{n}"), budget, || {
            let id = next;
            next += 1;
            // rate far below the admission boundary of the overloaded
            // pool: the arrival is skippable by construction
            pool.insert(Task::new(id, TaskClass::Voice, 0, 16, 1000, 0.001));
            policy.on_arrival(&mut pool, &[id], 0);
            pool.get_mut(id).state = slice_serve::coordinator::task::TaskState::Finished;
            policy.on_completion(&mut pool, &[id], 0);
            step_and_recycle(&mut policy, &mut pool)
        });
        println!("{}", r.report_line());
    }

    // One serving-loop step at a deep pool: policy scan + engine decode
    // + outcome application, stepped through Server::run_until in
    // decode-sized quanta (tasks are effectively endless so the batch
    // never drains mid-bench).
    {
        let n = 256usize;
        let workload: Vec<Task> = (0..n as u64)
            .map(|i| {
                let class =
                    if i % 3 == 0 { TaskClass::RealTime } else { TaskClass::Voice };
                Task::new(i, class, 0, 16, 1_000_000, 1.0)
            })
            .collect();
        let mut server = Server::new(
            workload,
            Box::new(SlicePolicy::with_defaults(lat.clone())),
            Box::new(SimEngine::paper_calibrated()),
            VirtualClock::new(),
        );
        server.run_until(secs(1.0)).unwrap(); // deliver + prefill warmup
        let mut until = server.now();
        let r = bench("server/decode_step/256", budget, || {
            until += 150_000; // ~one plateau decode step of virtual time
            server.run_until(until).unwrap();
        });
        println!("{}", r.report_line());
    }

    // Steady-state next_step (column scanning, no reschedule).
    let mut pool = pool_with_running(32);
    let mut policy = SlicePolicy::with_defaults(lat.clone());
    policy.on_arrival(&mut pool, &(0..32).collect::<Vec<_>>(), 0);
    let _ = policy.next_step(&mut pool, 0); // trigger the reschedule once
    let r = bench("slice/next_step_steady/32", budget, || {
        step_and_recycle(&mut policy, &mut pool)
    });
    println!("{}", r.report_line());

    // cluster_scale: the routing layer's hot paths. A routing decision
    // runs once per arrival, so its cost must be far below the
    // inter-arrival gap even at 8 replicas; the full-run bench tracks
    // end-to-end co-simulation cost as the fleet widens.
    let cfg = ServeConfig::default();
    let mut event_cfg = cfg.clone();
    event_cfg.cluster_engine = slice_serve::config::ClusterEngine::Event;
    let make_fleet = |n: usize, loaded: bool| -> Vec<Replica> {
        (0..n)
            .map(|i| {
                let mut r = Replica::new(
                    i,
                    Box::new(SlicePolicy::with_defaults(lat.clone())),
                    Box::new(SimEngine::paper_calibrated()),
                    DeviceProfile::standard(),
                );
                if loaded {
                    for k in 0..16u64 {
                        let class =
                            if k % 3 == 0 { TaskClass::RealTime } else { TaskClass::Voice };
                        r.assign(Task::new(k, class, 0, 16, 200, 1.0));
                    }
                }
                r
            })
            .collect()
    };
    for n in [2usize, 4, 8] {
        for strategy in [RoutingStrategy::LeastLoaded, RoutingStrategy::SloAware] {
            let mut router = Router::new(strategy, make_fleet(n, true));
            let probe = Task::new(0, TaskClass::Voice, 0, 16, 100, 1.0);
            let r = bench(
                &format!("cluster/decide/{}/{n}", strategy.label()),
                budget,
                || router.decide(&probe),
            );
            println!("{}", r.report_line());
        }

        // workload generated once outside the loop; each iteration still
        // pays one Vec clone (run_cluster consumes it), which is
        // negligible against the thousands of simulated engine steps
        let wl = WorkloadSpec::paper_mix(n as f64, 0.7, 40, 7).generate();
        let r = bench(&format!("cluster/run/slo-aware/{n}x40"), budget, || {
            experiments::run_cluster(
                RoutingStrategy::SloAware,
                n,
                wl.clone(),
                &cfg,
                secs(60.0),
            )
            .unwrap()
        });
        println!("{}", r.report_line());

        // the same cell through the event engine — bit-exact results
        // (rust/tests/equivalence.rs), so any delta is pure engine
        // overhead/savings
        let r = bench(&format!("cluster/run_event/slo-aware/{n}x40"), budget, || {
            experiments::run_cluster(
                RoutingStrategy::SloAware,
                n,
                wl.clone(),
                &event_cfg,
                secs(60.0),
            )
            .unwrap()
        });
        println!("{}", r.report_line());
    }

    // Fleet-width scaling: a fixed 200-task burst over progressively
    // wider round-robin fleets. Lockstep pays O(arrivals × replicas)
    // advancement calls, the event engine only wakes busy nodes — the
    // widest pair is the PR 6 acceptance signal (BENCH_6.json carries
    // the full 16/64/256 × 10k-100k sweep).
    for width in [16usize, 64] {
        let wl = WorkloadSpec::paper_mix(8.0, 0.7, 200, 7).generate();
        let r = bench(&format!("cluster/run/round-robin/{width}x200"), budget, || {
            experiments::run_cluster(
                RoutingStrategy::RoundRobin,
                width,
                wl.clone(),
                &cfg,
                secs(60.0),
            )
            .unwrap()
        });
        println!("{}", r.report_line());
        let r = bench(
            &format!("cluster/run_event/round-robin/{width}x200"),
            budget,
            || {
                experiments::run_cluster(
                    RoutingStrategy::RoundRobin,
                    width,
                    wl.clone(),
                    &event_cfg,
                    secs(60.0),
                )
                .unwrap()
            },
        );
        println!("{}", r.report_line());
    }

    // Epoch-parallel advancement (PR 9): the widest event cell again at
    // 1 vs 4 workers. Reports are bit-exact across thread counts
    // (rust/tests/equivalence.rs), so the delta between the two cells
    // is pure advancement parallelism; BENCH_9.json carries the full
    // threads × width sweep at experiment scale.
    {
        let wl = WorkloadSpec::paper_mix(16.0, 0.7, 400, 7).generate();
        for threads in [1usize, 4] {
            let mut par_cfg = event_cfg.clone();
            par_cfg.cluster_threads = threads;
            let r = bench(
                &format!("cluster/run_event/parallel/t{threads}/64x400"),
                budget,
                || {
                    experiments::run_cluster(
                        RoutingStrategy::RoundRobin,
                        64,
                        wl.clone(),
                        &par_cfg,
                        secs(60.0),
                    )
                    .unwrap()
                },
            );
            println!("{}", r.report_line());
        }
    }

    // The heterogeneous path: a guarded edge-mixed fleet pays for
    // admission checks and migration passes on top of routing; this
    // tracks that overhead end-to-end against the homogeneous run above.
    let mixed = slice_serve::cluster::FleetSpec::preset("edge-mixed").unwrap();
    let mut guarded_cfg = cfg.clone();
    guarded_cfg.cluster_admission.enabled = true;
    guarded_cfg.cluster_migration = true;
    let wl = WorkloadSpec::paper_mix(3.0, 0.7, 120, 7).generate();
    let r = bench("cluster/run/edge-mixed-guarded/3x40", budget, || {
        experiments::run_fleet(
            RoutingStrategy::SloAware,
            &mixed,
            wl.clone(),
            &guarded_cfg,
            secs(60.0),
        )
        .unwrap()
    });
    println!("{}", r.report_line());

    // The memory-constrained path: the same guarded fleet under a tight
    // KV capacity with running-task handoff — evictions, swap-ins and
    // handoff pricing all on the serving loop's hot path.
    let mut memory_cfg = guarded_cfg.clone();
    memory_cfg.memory.kv_capacity = Some(96 * 1024 * 1024);
    memory_cfg.cluster_migrate_running = true;
    let r = bench("cluster/run/edge-mixed-memory/3x40", budget, || {
        experiments::run_fleet(
            RoutingStrategy::SloAware,
            &mixed,
            wl.clone(),
            &memory_cfg,
            secs(60.0),
        )
        .unwrap()
    });
    println!("{}", r.report_line());

    // the fullest configuration through the event engine: migration
    // passes run at every arrival boundary here, so this cell bounds
    // the event engine's worst case (no advancement savings to win)
    let mut memory_event_cfg = memory_cfg.clone();
    memory_event_cfg.cluster_engine = slice_serve::config::ClusterEngine::Event;
    let r = bench("cluster/run_event/edge-mixed-memory/3x40", budget, || {
        experiments::run_fleet(
            RoutingStrategy::SloAware,
            &mixed,
            wl.clone(),
            &memory_event_cfg,
            secs(60.0),
        )
        .unwrap()
    });
    println!("{}", r.report_line());

    // Elastic fleet under seeded churn (PR 7): lifecycle events, crash
    // evacuation and re-placement on top of the guarded path — prices
    // the membership machinery itself, since the all-disabled elastic
    // path is bit-exact with the cell above.
    let mut churn_cfg = guarded_cfg.clone();
    churn_cfg.cluster_engine = slice_serve::config::ClusterEngine::Event;
    churn_cfg.lifecycle.churn_rate = 0.05;
    churn_cfg.lifecycle.seed = 7;
    churn_cfg.lifecycle.min_replicas = 2;
    churn_cfg.lifecycle.max_replicas = 8;
    let r = bench("cluster/run_event/churn/4x120", budget, || {
        experiments::run_fleet(
            RoutingStrategy::SloAware,
            &mixed,
            wl.clone(),
            &churn_cfg,
            secs(60.0),
        )
        .unwrap()
    });
    println!("{}", r.report_line());
}
