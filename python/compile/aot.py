"""AOT pipeline: lower the L2 model to HLO text artifacts for the rust runtime.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits:
  artifacts/prefill_p{P}.hlo.txt   one per prompt bucket (batch 1)
  artifacts/decode_b{B}.hlo.txt    one per batch bucket
  artifacts/weights.npz            PRNG-seeded parameters (positional order
                                   = manifest "param_names")
  artifacts/manifest.json          model dims + artifact index

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the HLO text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Python runs only here, at build time; the rust binary is self-contained
once artifacts/ exists.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    decode_flat,
    flatten_params,
    init_params,
    param_names,
    prefill_flat,
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs(cfg: ModelConfig, params):
    return [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flatten_params(cfg, params)]


def lower_prefill(cfg: ModelConfig, params, bucket: int) -> str:
    fn = functools.partial(prefill_flat, cfg)
    tokens = jax.ShapeDtypeStruct((1, bucket), jnp.int32)
    length = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(fn).lower(tokens, length, *param_specs(cfg, params))
    return to_hlo_text(lowered)


def lower_decode(cfg: ModelConfig, params, batch: int) -> str:
    fn = functools.partial(decode_flat, cfg)
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    kv = jax.ShapeDtypeStruct((batch,) + cfg.kv_slab_shape, jnp.float32)
    lowered = jax.jit(fn).lower(tokens, lens, kv, *param_specs(cfg, params))
    return to_hlo_text(lowered)


def build_artifacts(cfg: ModelConfig, out_dir: str, seed: int = 42) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg, seed=seed)

    weights_path = os.path.join(out_dir, "weights.npz")
    np.savez(
        weights_path,
        **{n: np.asarray(p) for n, p in zip(param_names(cfg), flatten_params(cfg, params))},
    )

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
        },
        "seed": seed,
        "param_names": param_names(cfg),
        "weights": "weights.npz",
        "prefill": [],
        "decode": [],
    }

    for p in cfg.prompt_buckets:
        name = f"prefill_p{p}.hlo.txt"
        text = lower_prefill(cfg, params, p)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["prefill"].append({"bucket": p, "path": name})
        print(f"wrote {name} ({len(text)} chars)")

    for b in cfg.batch_buckets:
        name = f"decode_b{b}.hlo.txt"
        text = lower_decode(cfg, params, b)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        manifest["decode"].append({"batch": b, "path": name})
        print(f"wrote {name} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json + weights.npz ({os.path.getsize(weights_path)} bytes)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()
    cfg = ModelConfig()
    build_artifacts(cfg, args.out_dir, seed=args.seed)


if __name__ == "__main__":
    main()
