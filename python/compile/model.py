"""L2: byte-level GPT-style decoder with an explicit, fixed-size KV cache.

This is the "small real model" served by the rust coordinator. It stands in
for the paper's ChatGLM2-6B-INT4 (see DESIGN.md "Substitutions"): the
scheduler only needs a real autoregressive prefill/decode loop whose step
latency grows with batch size, which this model provides at edge-realistic
step times on the CPU PJRT backend.

Architecture (defaults, see ModelConfig):
  vocab 256 (byte-level tokenizer), d_model 128, 4 layers, 4 heads,
  head_dim 32, ffn 512, max context S=128, learned positional embeddings,
  pre-LN blocks, GELU MLP, tied output head. ~0.85M parameters.

Two entry points are AOT-lowered by aot.py:
  * prefill(params, tokens[1,P], length)      -> logits[1,V], kv[1,L,2,H,S,hd]
  * decode(params, tokens[b], lens[b], kv)    -> logits[b,V], kv updated

The KV cache layout is [batch, layer, kv, head, S, head_dim] so that one
task's cache is a single contiguous slab the rust engine can stack into
dynamic batches (the decode-mask matrix regroups batches every step).

Attention uses the L1 Pallas kernels (kernels.decode_attention /
kernels.prefill_attention); a pure-jnp twin of each forward lives in
this module as *_ref for build-time verification.
"""

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import (
    decode_attention,
    decode_attention_ref,
    prefill_attention,
    prefill_attention_ref,
)

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static hyper-parameters of the served model."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    max_seq: int = 128

    # AOT compilation buckets (each becomes one HLO artifact).
    prompt_buckets: Tuple[int, ...] = (16, 32, 64)
    batch_buckets: Tuple[int, ...] = (1, 2, 4, 8, 16)

    def __post_init__(self):
        assert self.n_heads * self.head_dim == self.d_model

    @property
    def kv_slab_shape(self) -> Tuple[int, ...]:
        """Per-task KV cache slab: [layer, k/v, head, S, head_dim]."""
        return (self.n_layers, 2, self.n_heads, self.max_seq, self.head_dim)


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic flat ordering of parameters.

    This order is the executable argument order after (tokens, lens, kv);
    aot.py records it in the manifest so the rust runtime can feed
    weights.npz entries positionally.
    """
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1_g", f"l{i}.ln1_b",
            f"l{i}.wqkv", f"l{i}.bqkv",
            f"l{i}.wo", f"l{i}.bo",
            f"l{i}.ln2_g", f"l{i}.ln2_b",
            f"l{i}.w1", f"l{i}.b1",
            f"l{i}.w2", f"l{i}.b2",
        ]
    names += ["lnf_g", "lnf_b"]
    return names


def init_params(cfg: ModelConfig, seed: int = 42) -> Params:
    """PRNG-seeded weights; the same seed is baked into artifacts."""
    key = jax.random.PRNGKey(seed)
    d, f, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.max_seq

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)

    keys = iter(jax.random.split(key, 4 + 12 * cfg.n_layers))
    p: Params = {
        "tok_emb": nrm(next(keys), (v, d), 0.02),
        "pos_emb": nrm(next(keys), (s, d), 0.01),
        "lnf_g": jnp.ones((d,), jnp.float32),
        "lnf_b": jnp.zeros((d,), jnp.float32),
    }
    resid_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    for i in range(cfg.n_layers):
        p[f"l{i}.ln1_g"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.ln1_b"] = jnp.zeros((d,), jnp.float32)
        p[f"l{i}.wqkv"] = nrm(next(keys), (d, 3 * d), 0.02)
        p[f"l{i}.bqkv"] = jnp.zeros((3 * d,), jnp.float32)
        p[f"l{i}.wo"] = nrm(next(keys), (d, d), resid_scale)
        p[f"l{i}.bo"] = jnp.zeros((d,), jnp.float32)
        p[f"l{i}.ln2_g"] = jnp.ones((d,), jnp.float32)
        p[f"l{i}.ln2_b"] = jnp.zeros((d,), jnp.float32)
        p[f"l{i}.w1"] = nrm(next(keys), (d, f), 0.02)
        p[f"l{i}.b1"] = jnp.zeros((f,), jnp.float32)
        p[f"l{i}.w2"] = nrm(next(keys), (f, d), resid_scale)
        p[f"l{i}.b2"] = jnp.zeros((d,), jnp.float32)
    return p


def _ln(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _split_heads(x, n_heads, head_dim):
    # [..., d] -> [..., H, hd] -> move H before seq handled by caller
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


# ---------------------------------------------------------------------------
# Prefill: process the whole (padded) prompt for a single task.
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: Params, tokens, length, *, use_pallas=True):
    """Run the prompt through the model and materialise the KV cache.

    Args:
      tokens: i32[1, P]  byte tokens, padded to the bucket length P
      length: i32[]      actual prompt length (1 <= length <= P)

    Returns:
      logits: f32[1, V]                  next-token logits at position length-1
      kv:     f32[1, L, 2, H, S, hd]     cache padded to the context size
    """
    _, p_len = tokens.shape
    d, h, hd, s = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.max_seq
    attn = prefill_attention if use_pallas else prefill_attention_ref

    x = params["tok_emb"][tokens] + params["pos_emb"][:p_len][None]  # [1,P,d]
    kv_layers = []
    for i in range(cfg.n_layers):
        xn = _ln(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        qkv = xn @ params[f"l{i}.wqkv"] + params[f"l{i}.bqkv"]  # [1,P,3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # [1,P,d] -> [1,H,P,hd]
        q = _split_heads(q, h, hd).transpose(0, 2, 1, 3)
        k = _split_heads(k, h, hd).transpose(0, 2, 1, 3)
        v = _split_heads(v, h, hd).transpose(0, 2, 1, 3)
        o = attn(q, k, v)  # [1,H,P,hd]
        o = o.transpose(0, 2, 1, 3).reshape(1, p_len, d)
        x = x + o @ params[f"l{i}.wo"] + params[f"l{i}.bo"]
        xn = _ln(x, params[f"l{i}.ln2_g"], params[f"l{i}.ln2_b"])
        mlp = jax.nn.gelu(xn @ params[f"l{i}.w1"] + params[f"l{i}.b1"])
        x = x + mlp @ params[f"l{i}.w2"] + params[f"l{i}.b2"]
        # pad K/V from P to the full context S
        pad = [(0, 0), (0, 0), (0, s - p_len), (0, 0)]
        kv_layers.append(jnp.stack([jnp.pad(k, pad), jnp.pad(v, pad)], axis=1))

    xf = _ln(x, params["lnf_g"], params["lnf_b"])  # [1,P,d]
    logits_all = xf @ params["tok_emb"].T  # [1,P,V]
    logits = jax.lax.dynamic_slice_in_dim(logits_all, length - 1, 1, axis=1)[:, 0]
    kv = jnp.stack(kv_layers, axis=1)  # [1, L, 2, H, S, hd]
    return logits, kv


# ---------------------------------------------------------------------------
# Decode: one token for each task in a dynamic batch.
# ---------------------------------------------------------------------------


def decode(cfg: ModelConfig, params: Params, tokens, lens, kv, *, use_pallas=True):
    """One decode step over a batch of independent tasks.

    Args:
      tokens: i32[b]                    the most recently sampled token per task
      lens:   i32[b]                    current sequence length per task
                                        (token i goes to position lens[i])
      kv:     f32[b, L, 2, H, S, hd]    per-task caches

    Returns:
      logits: f32[b, V]                 next-token logits
      kv_out: f32[b, L, 2, H, S, hd]    caches updated at position lens[i]
    """
    b = tokens.shape[0]
    d, h, hd, s = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.max_seq
    attn = decode_attention if use_pallas else decode_attention_ref

    pos = lens  # position of the new token
    x = params["tok_emb"][tokens] + params["pos_emb"][pos]  # [b,d]

    # Perf (EXPERIMENTS.md §Perf iteration 3): collect per-layer updated
    # slabs and stack once at the end instead of chaining full-tensor
    # dynamic-update-slices on [b, L, 2, H, S, hd] — avoids XLA copying
    # the whole cache for the first (non-in-place) update.
    layer_slabs = []
    for i in range(cfg.n_layers):
        xn = _ln(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        qkv = xn @ params[f"l{i}.wqkv"] + params[f"l{i}.bqkv"]  # [b,3d]
        q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, h, hd)  # [b,H,hd]
        k_new = _split_heads(k_new, h, hd)  # [b,H,hd]
        v_new = _split_heads(v_new, h, hd)

        # scatter the new K/V into each task's slab at its position
        def upd(slab, knew, vnew, p):
            # slab: [2,H,S,hd]; knew/vnew: [H,hd]
            slab = jax.lax.dynamic_update_slice(
                slab, knew[None, :, None, :], (0, 0, p, 0)
            )
            slab = jax.lax.dynamic_update_slice(
                slab, vnew[None, :, None, :], (1, 0, p, 0)
            )
            return slab

        layer_slab = jax.vmap(upd)(kv[:, i], k_new, v_new, pos)  # [b,2,H,S,hd]
        layer_slabs.append(layer_slab)

        k_cache = layer_slab[:, 0]  # [b,H,S,hd]
        v_cache = layer_slab[:, 1]
        o = attn(q, k_cache, v_cache, lens + 1)  # [b,H,hd]
        o = o.reshape(b, d)
        x = x + o @ params[f"l{i}.wo"] + params[f"l{i}.bo"]
        xn = _ln(x, params[f"l{i}.ln2_g"], params[f"l{i}.ln2_b"])
        mlp = jax.nn.gelu(xn @ params[f"l{i}.w1"] + params[f"l{i}.b1"])
        x = x + mlp @ params[f"l{i}.w2"] + params[f"l{i}.b2"]

    xf = _ln(x, params["lnf_g"], params["lnf_b"])
    logits = xf @ params["tok_emb"].T  # [b,V]
    kv_out = jnp.stack(layer_slabs, axis=1)  # [b,L,2,H,S,hd]
    return logits, kv_out


# ---------------------------------------------------------------------------
# Flat-argument wrappers (what aot.py lowers): weights are positional inputs
# so the HLO artifacts stay small and the rust runtime feeds weights.npz
# entries once at startup.
# ---------------------------------------------------------------------------


def prefill_flat(cfg: ModelConfig, tokens, length, *flat_params, use_pallas=True):
    names = param_names(cfg)
    params = dict(zip(names, flat_params))
    return prefill(cfg, params, tokens, length, use_pallas=use_pallas)


def decode_flat(cfg: ModelConfig, tokens, lens, kv, *flat_params, use_pallas=True):
    names = param_names(cfg)
    params = dict(zip(names, flat_params))
    return decode(cfg, params, tokens, lens, kv, use_pallas=use_pallas)


def flatten_params(cfg: ModelConfig, params: Params) -> List[jnp.ndarray]:
    return [params[n] for n in param_names(cfg)]


# ---------------------------------------------------------------------------
# Build-time reference generation loop (used by tests to validate that
# prefill+decode over the bucketed/padded path reproduces a straightforward
# full re-forward at every step).
# ---------------------------------------------------------------------------


def generate_ref(cfg: ModelConfig, params: Params, prompt: List[int], n_tokens: int):
    """Greedy generation via full re-forward each step (oracle, slow)."""
    toks = list(prompt)
    for _ in range(n_tokens):
        p = len(toks)
        tokens = jnp.asarray([toks], dtype=jnp.int32)
        logits, _ = prefill(cfg, params, tokens, jnp.int32(p), use_pallas=False)
        toks.append(int(jnp.argmax(logits[0])))
    return toks[len(prompt):]


def generate_kv(cfg: ModelConfig, params: Params, prompt: List[int], n_tokens: int,
                *, use_pallas=True):
    """Greedy generation via prefill + per-step decode (the served path)."""
    p = len(prompt)
    bucket = next(b for b in cfg.prompt_buckets if b >= p)
    padded = prompt + [0] * (bucket - p)
    tokens = jnp.asarray([padded], dtype=jnp.int32)
    logits, kv = prefill(cfg, params, tokens, jnp.int32(p), use_pallas=use_pallas)
    out = [int(jnp.argmax(logits[0]))]
    lens = jnp.asarray([p], dtype=jnp.int32)
    for _ in range(n_tokens - 1):
        tok = jnp.asarray([out[-1]], dtype=jnp.int32)
        logits, kv = decode(cfg, params, tok, lens, kv, use_pallas=use_pallas)
        out.append(int(jnp.argmax(logits[0])))
        lens = lens + 1
    return out
