"""L1 Pallas kernels for the SLICE decode/prefill hot-spots."""

from .decode_attention import decode_attention
from .prefill_attention import prefill_attention
from .ref import decode_attention_ref, prefill_attention_ref

__all__ = [
    "decode_attention",
    "prefill_attention",
    "decode_attention_ref",
    "prefill_attention_ref",
]
