"""L1 Pallas kernel: causal (prefill) self-attention.

Prefill processes the whole prompt in one pass; each query position attends
to all earlier positions. The grid iterates over (batch, head); the [P, hd]
Q/K/V blocks for one head are staged into VMEM and the [P, P] score tile is
computed with a causal mask.

On a real TPU the [P, P] @ [P, hd] products run on the MXU; P is capped at
the prompt buckets (<=64) so a full tile fits VMEM without double
buffering. interpret=True for CPU-PJRT execution (see decode_attention.py).
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["prefill_attention"]


def _prefill_attn_kernel(q_ref, k_ref, v_ref, o_ref):
    """One batch-element program: causal softmax(Q.K^T).V, all heads.

    Block shapes:
      q_ref, k_ref, v_ref: (1, H, P, hd) f32
      o_ref:               (1, H, P, hd) f32

    Perf note: grid is (b,) with the head axis inside the program (see
    decode_attention.py — same rationale; the [H, P, P] score tile at
    the default config is 64 KiB, VMEM-comfortable).
    """
    q = q_ref[0]  # [H, P, hd]
    k = k_ref[0]
    v = v_ref[0]

    h, p, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * scale  # [H, P, P]

    rows = jax.lax.broadcasted_iota(jnp.int32, (1, p, p), 1)
    cols = jax.lax.broadcasted_iota(jnp.int32, (1, p, p), 2)
    neg_inf = jnp.finfo(scores.dtype).min
    scores = jnp.where(cols <= rows, scores, neg_inf)

    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)

    o_ref[0] = jnp.einsum("hqk,hkd->hqd", probs, v)


@functools.partial(jax.jit, static_argnames=())
def prefill_attention(q, k, v):
    """Causal self-attention over the full prompt.

    Args:
      q, k, v: f32[b, H, P, hd]

    Returns:
      f32[b, H, P, hd]
    """
    b, h, p, hd = q.shape
    return pl.pallas_call(
        _prefill_attn_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, p, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, p, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, p, hd), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, p, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, p, hd), q.dtype),
        interpret=True,
    )(q, k, v)
