"""L1 Pallas kernel: single-query (decode) attention over a KV cache.

This is the decode-phase hot-spot of the SLICE serving stack: for every
scheduled decode step, each task in the dynamic batch attends with a single
query vector against its cached keys/values, masked to the task's current
sequence length.

TPU mapping (DESIGN.md "Hardware adaptation"): the grid iterates over
(batch, head); for each program instance the K/V block [S, hd] is staged
into VMEM (S=128 rows is lane-aligned), the query vector stays resident,
and the masked softmax is computed in-register. Decode attention is a
matrix-vector product, so the roofline is HBM->VMEM bytes for K/V, not the
MXU — exactly the "per-token latency grows with batch" regime the paper
measures on its edge GPU.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO; numerics are validated
against kernels/ref.py by pytest.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["decode_attention"]


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref):
    """One batch-element program: masked softmax(q.K^T).V over the cache,
    all heads at once.

    Block shapes:
      len_ref: (1,)          int32   current sequence length of this task
      q_ref:   (1, H, hd)    f32     query vectors (one per head)
      k_ref:   (1, H, S, hd) f32     cached keys   (padded to S)
      v_ref:   (1, H, S, hd) f32     cached values
      o_ref:   (1, H, hd)    f32     attention outputs

    Perf note (EXPERIMENTS.md §Perf iteration 1): the grid is (b,) with
    all H heads fused into one program rather than (b, H). Interpret
    mode lowers each grid cell to a sequential HLO loop iteration, so
    fewer/fatter programs cut per-cell overhead 4x on the CPU PJRT
    backend; on a real TPU the [H, S, hd] block (64 KiB at the default
    config) still fits VMEM comfortably and feeds the MXU a batched
    [H*S, hd] x [hd] product instead of H separate skinny ones.
    """
    q = q_ref[0]  # [H, hd]
    k = k_ref[0]  # [H, S, hd]
    v = v_ref[0]  # [H, S, hd]
    seq_len = len_ref[0]

    hd = q.shape[-1]
    s = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # scores over the whole padded cache; positions >= seq_len are masked.
    scores = jnp.einsum("hsd,hd->hs", k, q) * scale  # [H, S]
    positions = jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)  # [1, S]
    mask = positions < seq_len
    neg_inf = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask, scores, neg_inf)

    # numerically-stable masked softmax (per head)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask, e, 0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / denom  # [H, S]

    o_ref[0] = jnp.einsum("hs,hsd->hd", probs, v)


@functools.partial(jax.jit, static_argnames=())
def decode_attention(q, k, v, lens):
    """Batched single-step attention over per-task KV caches.

    Args:
      q:    f32[b, H, hd]     query vectors for the new token of each task
      k:    f32[b, H, S, hd]  cached keys, padded to the context size S
      v:    f32[b, H, S, hd]  cached values
      lens: i32[b]            valid cache length per task (incl. new token)

    Returns:
      f32[b, H, hd] attention outputs.
    """
    b, h, s, hd = k.shape
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, h, s, hd), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, s, hd), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        interpret=True,
    )(lens, q, k, v)
