"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth that pytest (python/tests/test_kernels.py)
asserts the Pallas kernels against, including a hypothesis sweep over
shapes and lengths.
"""

import math

import jax.numpy as jnp

__all__ = ["decode_attention_ref", "prefill_attention_ref"]


def decode_attention_ref(q, k, v, lens):
    """Reference for kernels.decode_attention.

    q: f32[b, H, hd]; k, v: f32[b, H, S, hd]; lens: i32[b]
    returns f32[b, H, hd]
    """
    b, h, s, hd = k.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) * scale
    positions = jnp.arange(s)[None, None, :]
    mask = positions < lens[:, None, None]
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(mask, e, 0.0)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", probs, v)


def prefill_attention_ref(q, k, v):
    """Reference for kernels.prefill_attention.

    q, k, v: f32[b, H, P, hd]; returns f32[b, H, P, hd]
    """
    b, h, p, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    rows = jnp.arange(p)[:, None]
    cols = jnp.arange(p)[None, :]
    causal = cols <= rows
    scores = jnp.where(causal[None, None], scores, jnp.finfo(scores.dtype).min)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    e = jnp.where(causal[None, None], e, 0.0)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
