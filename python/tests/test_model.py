"""L2 model correctness: pallas path vs pure-jnp path vs full re-forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    decode,
    decode_flat,
    flatten_params,
    generate_kv,
    generate_ref,
    init_params,
    param_names,
    prefill,
    prefill_flat,
)

TOL = dict(rtol=5e-5, atol=5e-5)

# a deliberately tiny config keeps the pure-python test loop fast
TINY = ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16, d_ff=64,
    max_seq=32, prompt_buckets=(8, 16), batch_buckets=(1, 2, 4),
)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, seed=7)


def test_param_names_cover_params(tiny_params):
    assert set(param_names(TINY)) == set(tiny_params.keys())


def test_param_names_deterministic():
    assert param_names(TINY) == param_names(TINY)
    assert param_names(TINY)[0] == "tok_emb"


def test_init_params_deterministic():
    a = init_params(TINY, seed=7)
    b = init_params(TINY, seed=7)
    for n in param_names(TINY):
        np.testing.assert_array_equal(np.asarray(a[n]), np.asarray(b[n]))


def test_init_params_seed_changes_weights():
    a = init_params(TINY, seed=7)
    b = init_params(TINY, seed=8)
    assert not np.allclose(np.asarray(a["tok_emb"]), np.asarray(b["tok_emb"]))


def test_prefill_shapes(tiny_params):
    toks = jnp.zeros((1, 8), jnp.int32)
    logits, kv = prefill(TINY, tiny_params, toks, jnp.int32(5))
    assert logits.shape == (1, TINY.vocab)
    assert kv.shape == (1,) + TINY.kv_slab_shape


def test_decode_shapes(tiny_params):
    b = 4
    kv = jnp.zeros((b,) + TINY.kv_slab_shape, jnp.float32)
    toks = jnp.zeros((b,), jnp.int32)
    lens = jnp.ones((b,), jnp.int32)
    logits, kv2 = decode(TINY, tiny_params, toks, lens, kv)
    assert logits.shape == (b, TINY.vocab)
    assert kv2.shape == kv.shape


def test_prefill_pallas_matches_jnp(tiny_params):
    toks = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    lp, kvp = prefill(TINY, tiny_params, toks, jnp.int32(8), use_pallas=True)
    lj, kvj = prefill(TINY, tiny_params, toks, jnp.int32(8), use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lj), **TOL)
    np.testing.assert_allclose(np.asarray(kvp), np.asarray(kvj), **TOL)


def test_decode_pallas_matches_jnp(tiny_params):
    b = 3
    key = jax.random.PRNGKey(0)
    kv = jax.random.normal(key, (b,) + TINY.kv_slab_shape, jnp.float32) * 0.1
    toks = jnp.asarray([1, 2, 3], jnp.int32)
    lens = jnp.asarray([1, 5, 9], jnp.int32)
    lp, kvp = decode(TINY, tiny_params, toks, lens, kv, use_pallas=True)
    lj, kvj = decode(TINY, tiny_params, toks, lens, kv, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lj), **TOL)
    np.testing.assert_allclose(np.asarray(kvp), np.asarray(kvj), **TOL)


def test_decode_writes_kv_at_position(tiny_params):
    """The new K/V row lands exactly at position lens[i]; rest untouched."""
    b = 2
    kv = jnp.zeros((b,) + TINY.kv_slab_shape, jnp.float32)
    toks = jnp.asarray([5, 6], jnp.int32)
    lens = jnp.asarray([2, 7], jnp.int32)
    _, kv2 = decode(TINY, tiny_params, toks, lens, kv)
    kv2 = np.asarray(kv2)
    for i, pos in enumerate([2, 7]):
        # the written row must be non-zero for every layer
        assert np.abs(kv2[i, :, :, :, pos, :]).sum() > 0
        # all other rows remain zero
        other = np.delete(kv2[i], pos, axis=3)
        assert np.abs(other).sum() == 0


def test_generation_kv_matches_full_reforward(tiny_params):
    """Gold autoregressive invariant: bucketed prefill+decode == re-forward."""
    prompt = [3, 14, 15, 9, 26]
    ref = generate_ref(TINY, tiny_params, prompt, 5)
    kvp = generate_kv(TINY, tiny_params, prompt, 5, use_pallas=True)
    kvj = generate_kv(TINY, tiny_params, prompt, 5, use_pallas=False)
    assert ref == kvp == kvj


def test_generation_prompt_padding_is_inert(tiny_params):
    """Same prompt padded into different buckets produces the same tokens."""
    prompt = [1, 2, 3]
    out = generate_kv(TINY, tiny_params, prompt, 4)
    # force the larger bucket by monkeypatching the bucket choice
    cfg2 = ModelConfig(
        vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16, d_ff=64,
        max_seq=32, prompt_buckets=(16,), batch_buckets=(1,),
    )
    out2 = generate_kv(cfg2, tiny_params, prompt, 4)
    assert out == out2


def test_flat_wrappers_match_dict_api(tiny_params):
    toks = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    flat = flatten_params(TINY, tiny_params)
    l1, kv1 = prefill(TINY, tiny_params, toks, jnp.int32(8))
    l2, kv2 = prefill_flat(TINY, toks, jnp.int32(8), *flat)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), **TOL)
    np.testing.assert_allclose(np.asarray(kv1), np.asarray(kv2), **TOL)

    b = 2
    kv = jnp.zeros((b,) + TINY.kv_slab_shape, jnp.float32)
    toksd = jnp.asarray([1, 2], jnp.int32)
    lens = jnp.asarray([1, 3], jnp.int32)
    l3, kv3 = decode(TINY, tiny_params, toksd, lens, kv)
    l4, kv4 = decode_flat(TINY, toksd, lens, kv, *flat)
    np.testing.assert_allclose(np.asarray(l3), np.asarray(l4), **TOL)
    np.testing.assert_allclose(np.asarray(kv3), np.asarray(kv4), **TOL)


def test_batch_rows_independent(tiny_params):
    """Decoding task X alone == decoding X inside a batch (order-free)."""
    key = jax.random.PRNGKey(1)
    kv = jax.random.normal(key, (3,) + TINY.kv_slab_shape, jnp.float32) * 0.1
    toks = jnp.asarray([7, 8, 9], jnp.int32)
    lens = jnp.asarray([4, 2, 6], jnp.int32)
    l_all, kv_all = decode(TINY, tiny_params, toks, lens, kv)
    for i in range(3):
        l_one, kv_one = decode(
            TINY, tiny_params, toks[i : i + 1], lens[i : i + 1], kv[i : i + 1]
        )
        np.testing.assert_allclose(np.asarray(l_all[i]), np.asarray(l_one[0]), **TOL)
        np.testing.assert_allclose(np.asarray(kv_all[i]), np.asarray(kv_one[0]), **TOL)
