"""L1 kernel correctness: Pallas vs pure-jnp oracle.

The hypothesis sweeps are the CORE correctness signal for the kernels:
shapes, head counts, cache sizes and valid-length vectors are generated,
and the Pallas output must match ref.py to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    decode_attention,
    decode_attention_ref,
    prefill_attention,
    prefill_attention_ref,
)

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 8),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 32, 128]),
    hd=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_decode_attention_matches_ref(b, h, s, hd, seed, data):
    lens_list = data.draw(
        st.lists(st.integers(1, s), min_size=b, max_size=b), label="lens"
    )
    q = _rand(seed, (b, h, hd))
    k = _rand(seed + 1, (b, h, s, hd))
    v = _rand(seed + 2, (b, h, s, hd))
    lens = jnp.asarray(lens_list, dtype=jnp.int32)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_decode_attention_len_one():
    """A task with a single valid cache row attends only to that row."""
    b, h, s, hd = 2, 2, 16, 8
    q = _rand(0, (b, h, hd))
    k = _rand(1, (b, h, s, hd))
    v = _rand(2, (b, h, s, hd))
    lens = jnp.asarray([1, 1], dtype=jnp.int32)
    out = decode_attention(q, k, v, lens)
    # softmax over one element is 1.0 -> output equals v[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, :, 0]), **TOL)


def test_decode_attention_full_cache():
    b, h, s, hd = 3, 4, 64, 16
    q, k, v = _rand(3, (b, h, hd)), _rand(4, (b, h, s, hd)), _rand(5, (b, h, s, hd))
    lens = jnp.full((b,), s, dtype=jnp.int32)
    out = decode_attention(q, k, v, lens)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_decode_attention_ignores_padding_garbage():
    """Values past lens must not influence the output at all."""
    b, h, s, hd = 2, 2, 32, 8
    q = _rand(6, (b, h, hd))
    k = _rand(7, (b, h, s, hd))
    v = _rand(8, (b, h, s, hd))
    lens = jnp.asarray([5, 20], dtype=jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    # poison the padded region with huge values
    mask = jnp.arange(s)[None, None, :, None] >= lens[:, None, None, None]
    k2 = jnp.where(mask, 1e6, k)
    v2 = jnp.where(mask, -1e6, v)
    out2 = decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), **TOL)


def test_decode_attention_heterogeneous_lens():
    """Each batch row is independent: permuting rows permutes outputs."""
    b, h, s, hd = 4, 2, 16, 8
    q, k, v = _rand(9, (b, h, hd)), _rand(10, (b, h, s, hd)), _rand(11, (b, h, s, hd))
    lens = jnp.asarray([1, 5, 9, 16], dtype=jnp.int32)
    out = decode_attention(q, k, v, lens)
    perm = jnp.asarray([2, 0, 3, 1])
    out_p = decode_attention(q[perm], k[perm], v[perm], lens[perm])
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p), **TOL)


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    p=st.sampled_from([4, 16, 64]),
    hd=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_attention_matches_ref(b, h, p, hd, seed):
    q = _rand(seed, (b, h, p, hd))
    k = _rand(seed + 1, (b, h, p, hd))
    v = _rand(seed + 2, (b, h, p, hd))
    out = prefill_attention(q, k, v)
    ref = prefill_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_prefill_attention_is_causal():
    """Position 0 output must not depend on later K/V rows."""
    b, h, p, hd = 1, 2, 8, 8
    q = _rand(12, (b, h, p, hd))
    k = _rand(13, (b, h, p, hd))
    v = _rand(14, (b, h, p, hd))
    out1 = prefill_attention(q, k, v)
    k2 = k.at[:, :, 1:].set(999.0)
    v2 = v.at[:, :, 1:].set(-999.0)
    out2 = prefill_attention(q, k2, v2)
    np.testing.assert_allclose(
        np.asarray(out1[:, :, 0]), np.asarray(out2[:, :, 0]), **TOL
    )


def test_prefill_first_row_equals_v0():
    b, h, p, hd = 2, 2, 4, 8
    q, k, v = _rand(15, (b, h, p, hd)), _rand(16, (b, h, p, hd)), _rand(17, (b, h, p, hd))
    out = prefill_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out[:, :, 0]), np.asarray(v[:, :, 0]), **TOL)


def test_decode_consistent_with_prefill_last_row():
    """Decode of the last token == prefill's last row (same K/V)."""
    b, h, p, hd = 2, 2, 8, 8
    q, k, v = _rand(18, (b, h, p, hd)), _rand(19, (b, h, p, hd)), _rand(20, (b, h, p, hd))
    full = prefill_attention(q, k, v)  # [b,h,p,hd]
    lens = jnp.full((b,), p, dtype=jnp.int32)
    one = decode_attention(q[:, :, -1], k, v, lens)  # [b,h,hd]
    np.testing.assert_allclose(np.asarray(full[:, :, -1]), np.asarray(one), **TOL)
