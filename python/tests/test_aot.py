"""AOT pipeline: HLO text emission, manifest integrity, weight round-trip."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile.aot import build_artifacts, lower_decode, lower_prefill
from compile.model import ModelConfig, flatten_params, init_params, param_names

TINY = ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16, d_ff=64,
    max_seq=32, prompt_buckets=(8,), batch_buckets=(1, 2),
)


@pytest.fixture(scope="module")
def tiny_params():
    return init_params(TINY, seed=7)


def test_lower_prefill_emits_hlo_text(tiny_params):
    text = lower_prefill(TINY, tiny_params, 8)
    assert "ENTRY" in text and "HloModule" in text
    # weights are inputs, not giant constants: text stays small
    assert len(text) < 2_000_000


def test_lower_decode_emits_hlo_text(tiny_params):
    text = lower_decode(TINY, tiny_params, 2)
    assert "ENTRY" in text and "HloModule" in text


def test_lowered_decode_has_expected_params(tiny_params):
    """Parameter count = tokens + lens + kv + |weights|."""
    text = lower_decode(TINY, tiny_params, 1)
    n_expected = 3 + len(param_names(TINY))
    # HLO text declares each entry parameter as parameter(k)
    count = sum(1 for line in text.splitlines() if "parameter(" in line)
    assert count >= n_expected


def test_build_artifacts_manifest_and_weights():
    with tempfile.TemporaryDirectory() as d:
        manifest = build_artifacts(TINY, d, seed=7)
        with open(os.path.join(d, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest
        assert on_disk["model"]["vocab"] == TINY.vocab
        assert on_disk["model"]["max_seq"] == TINY.max_seq
        assert [e["bucket"] for e in on_disk["prefill"]] == [8]
        assert [e["batch"] for e in on_disk["decode"]] == [1, 2]
        for e in on_disk["prefill"] + on_disk["decode"]:
            assert os.path.exists(os.path.join(d, e["path"]))

        # weights round-trip positionally
        z = np.load(os.path.join(d, "weights.npz"))
        params = init_params(TINY, seed=7)
        flat = flatten_params(TINY, params)
        for name, arr in zip(on_disk["param_names"], flat):
            np.testing.assert_array_equal(z[name], np.asarray(arr))


def test_weights_depend_on_seed():
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        build_artifacts(TINY, d1, seed=1)
        build_artifacts(TINY, d2, seed=2)
        z1 = np.load(os.path.join(d1, "weights.npz"))
        z2 = np.load(os.path.join(d2, "weights.npz"))
        assert not np.allclose(z1["tok_emb"], z2["tok_emb"])
