#!/usr/bin/env python3
"""Run the mirrored slice-serve experiments and emit EXPERIMENTS.md /
BENCH_2.json inputs.

Stages:
  1. self-check — re-assert a battery of the Rust suite's own unit/
     integration expectations against the mirror (workload statistics,
     latency-model constraints, Alg. 2/3 worked examples, serving-loop
     step counts, Fig. 11 attainment shapes). A mirror drift fails here.
  2. fig1 — the calibrated-model latency/throughput table.
  3. cluster_sweep — routing strategies x replica counts (SLICE policy),
     per-replica load held constant, plus the integration-test cells the
     Rust suite asserts (threshold validation).
  4. rust cluster integration-test cells (threshold validation).
  5. hetero_sweep — fleet mix (uniform-4 vs edge-mixed) x strategy x
     admission/migration guards at the mixed fleet's capacity knee,
     plus the hetero_fleet.rs integration-test cells.
  6. (inside 5) hetero_fleet.rs threshold validation.
  7. memory_sweep — KV capacity x preemption mode x fleet shape
     (memory-aware vs oblivious SLICE, swap vs recompute, running-task
     KV handoff on the constrained mixed fleet).
  8. memory_model.rs test-cell validation (bit-exactness of the
     unconstrained path, aware > oblivious threshold, peak <= capacity,
     handoff determinism).
  9. scheduler hot path (PR 5) — select_tasks_fast == select_tasks over
     randomized cases (the equivalence.rs mirror), a Rust-faithful
     old-vs-new reschedule-pipeline timing at n in {64, 256, 1024}
     (the old path recomputes utility rates inside the comparator and
     re-runs the Eq. 7 closed form per admission, as the pre-PR 5 Rust
     did), and the scale sweep (1k/4k/10k single + guarded edge-mixed)
     measuring decisions-per-second — the BENCH_5.json inputs. Note
     stages 1-8 themselves now run through select_tasks_fast, so their
     unchanged cells are an end-to-end bit-exactness proof.
 10. event engine (PR 6) — (a) bit-exactness: every cluster / hetero /
     memory shape runs through both the lockstep Router and the
     heap-scheduled Orchestrator and must produce identical per-task
     timestamps, per-replica step counts and migration/shed counters;
     (b) the replica-width scale sweep (round-robin homogeneous fleets,
     event engine at every size, lockstep reference at the smallest) —
     the BENCH_6.json input.
 11. elastic fleets (PR 7) — (a) unit mirrors of the Rust lifecycle/
     autoscaler/health suites; (b) all-disabled elastic machinery
     bit-exact with static fleets across the stage-10 shapes; (c) task
     conservation + determinism under explicit crashes, seeded churn
     and health-based routing; (d) the failure sweep (static / crash /
     autoscale / autoscale+crash at each size) with the acceptance
     gate: autoscaling strictly reduces shed at the largest size — the
     BENCH_7.json input.
 12. O(changes) control plane (PR 8) — (a) paper_mix_stream generator
     == materialized paper_mix; (b) reschedule skipping + cached
     candidate sets are bit-exact with the always-rebuild reference
     across the stage-10 shapes on both engines, with the summed
     decision invariant (reschedules + skipped == no-skip reschedules)
     and zero full rebuilds on cache-eligible shapes; (c) the
     edge-triggered migration engine matches the lockstep per-boundary
     reference's migrated-task set across >= 4 seeds with passes
     reduced to O(overload episodes); (d) autoscaler boot delay:
     default 0 is bit-exact (covered by stage 11's unchanged pins),
     delayed boots conserve tasks, respect fleet bounds and report
     pending boots; (e) streaming runs (fold-rejects) are bit-exact
     with materialized event runs on the routed set; (f) the streaming
     scale cells (10k + 1M by default) — the BENCH_8.json input, with
     the acceptance gate: >= 30% fewer full select_tasks passes at the
     10k edge-mixed cell.
 13. parallel event engine (PR 9) — (a) epoch-batched wake handling is
     bit-exact with the sequential arm across the stage-10 shapes at
     threads 2/4/8; (b) no epoch batch names a replica twice and
     batches really get wide; (c) the thread-speedup sweep over
     (width x size x threads): one measured run per cell, wall times
     at threads > 1 from the max-over-worker-chunks epoch cost model —
     the BENCH_9.json input, with the acceptance gate: >= 1.8x modeled
     speedup at 4 threads on the widest cell.
 14. failure detection & recovery (PR 10) — (a) unit mirrors of the
     Rust detector.rs suspicion state machine; (b) the inert detector
     (`enabled` with `suspicion_timeout = 0`, the oracle spelling) is
     bit-exact with the detector-free engines across the stage-10
     shapes at threads 1/4, and reproduces oracle crash handling under
     a real crash schedule; (c) task conservation + counter coherence
     across 500 seeded fault schedules with a nonzero detection delay;
     (d) detector lag on a live overloaded fleet never confirms a
     corpse; (e) the chaos sweep (crash/churn x detection delay x
     retry budget) with the acceptance gate: retry re-dispatch sheds
     strictly less than the no-retry floor at the crash-d8 cell — the
     BENCH_10.json input.

Usage: python3 tools/pysim/run_experiments.py [--out results.json]
       [--scale-sizes 1000,4000,10000]
       [--replica-widths 16,64,256] [--replica-sizes 10000,100000]
       [--bench6-out BENCH_6.json] [--stage10]
       [--elastic-sizes 1000,10000] [--bench7-out BENCH_7.json] [--stage11]
       [--stream-sizes 10000,1000000] [--bench8-out BENCH_8.json] [--stage12]
       [--parallel-widths 64,256] [--parallel-threads 1,2,4,8]
       [--bench9-out BENCH_9.json] [--stage13]
       [--chaos-sizes 1000,10000] [--bench10-out BENCH_10.json] [--stage14]
"""

import json
import math
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))

from slice_sim import (  # noqa: E402
    CONFIRM, CRASH, CYCLE_CAP, SUSPECT, UNSUSPECT, AdmissionConfig,
    Autoscaler, AutoscalerConfig, DecodeMask, DetectorConfig, DeviceProfile,
    FailureDetector, HealthConfig, HealthTracker, IncrementalPeriod,
    LatencyModel, LifecycleConfig, LifecycleEvent, MemoryConfig, OrcaPolicy,
    Orchestrator, Replica, Rng, Router, Server, SlicePolicy, _default_policy,
    attainment, edge_mixed, latency_summary, paper_mix, paper_mix_stream,
    period_eq7, run_cluster, run_fleet, run_fleet_stream, select_tasks,
    select_tasks_fast, secs,
)

LAT = LatencyModel.paper_calibrated()


def check(cond, label):
    status = "ok" if cond else "FAIL"
    print(f"  [{status}] {label}")
    if not cond:
        raise SystemExit(f"self-check failed: {label}")


def run_single(policy_name, rate, rt_ratio, n, seed, drain_s=120.0):
    wl = paper_mix(rate, rt_ratio, n, seed)
    horizon = (wl[-1].arrival if wl else 0) + secs(drain_s)
    policy = SlicePolicy(LAT) if policy_name == "slice" else OrcaPolicy(32)
    s = Server(wl, policy, LAT)
    s.run(horizon)
    return s


def self_check():
    print("stage 1: mirror self-check against the Rust suite's expectations")

    # rng distributions (util/rng.rs tests)
    r = Rng(11)
    mean = sum(r.exponential(2.0) for _ in range(200_000)) / 200_000
    check(abs(mean - 0.5) < 0.01, f"exponential mean {mean:.4f} ~ 0.5")
    r = Rng(17)
    counts = [0, 0, 0]
    for _ in range(30_000):
        counts[r.weighted_index([1.0, 2.0, 7.0])] += 1
    check(abs(counts[2] / 30_000 - 0.7) < 0.03, "weighted_index fractions")

    # workload (workload/mod.rs tests)
    wl = paper_mix(1.0, 0.7, 200, 42)
    check(len(wl) == 200 and all(a.arrival <= b.arrival for a, b in zip(wl, wl[1:])),
          "paper_mix sorted dense")
    wl = paper_mix(1.0, 0.7, 5000, 11)
    frac_rt = sum(t.is_real_time() for t in wl) / len(wl)
    check(abs(frac_rt - 0.7) < 0.03, f"rt fraction {frac_rt:.3f} ~ 0.7 (seed 11)")
    wl = paper_mix(2.0, 0.5, 20_000, 13)
    gap = wl[-1].arrival / 1e6 / (len(wl) - 1)
    check(abs(gap - 0.5) < 0.02, f"poisson mean gap {gap:.4f} ~ 0.5 (seed 13)")
    wl = paper_mix(1.0, 0.7, 5000, 3)
    demand = sum(t.output_len for t in wl) / (wl[-1].arrival / 1e6)
    check(70.0 < demand < 140.0, f"demand {demand:.1f} tok/s at saturation knee")

    # latency model (engine/latency.rs tests)
    check(LAT.decode(8) <= 100_000 < LAT.decode(9) == 128_590, "l(8)/l(9) knots")
    check(4 * LAT.decode(9) + LAT.decode(3) + 5 * LAT.decode(7) < 1_000_000,
          "Table II period feasible")

    # Alg. 2 / Alg. 3 worked examples (selection.rs / mask.rs tests)
    cands = [(i, 1.0, t) for i, t in enumerate(
        [100_000] * 3 + [120_000] * 4 + [250_000] * 2)]
    sel, rej = select_tasks(cands, LAT, CYCLE_CAP)
    check(len(sel) == 9 and not rej, "Table II: all 9 admitted")
    m = DecodeMask([(0, 6), (1, 4), (2, 2), (3, 1)])
    check(m.batch_lens == [4, 3, 2, 2, 1, 1], "Fig. 4 mask columns")
    check(period_eq7([6, 4, 2, 1], LAT)
          == LAT.decode(4) + LAT.decode(3) + 2 * LAT.decode(2) + 2 * LAT.decode(1),
          "Eq. 7 equals column sum")

    # serving loop (server.rs tests)
    from slice_sim import Task, VOICE
    s = Server([Task(0, VOICE, 0, 16, 10, 1.0)], OrcaPolicy(32), LAT)
    s.run(secs(60.0))
    check(s.prefill_steps == 1 and s.decode_steps == 9, "orca single-task steps")
    check(s.pool[0].avg_tpot() == 18_000, "orca solo TPOT = l(1)")

    # Fig. 11 shapes (rate_sweep.rs + sim_integration.rs tests)
    t0 = time.time()
    slice_3 = run_single("slice", 3.0, 0.7, 300, 42)
    a_slice = attainment(slice_3.pool)
    check(a_slice["rt_slo"] > 0.9, f"SLICE RT {a_slice['rt_slo']:.3f} > 0.9 @ rate 3")
    orca_3 = run_single("orca", 3.0, 0.7, 300, 42)
    a_orca = attainment(orca_3.pool)
    check(a_slice["rt_slo"] - a_orca["rt_slo"] > 0.4, "SLICE-Orca RT gap @ rate 3")
    check(a_slice["slo"] / max(a_orca["slo"], 0.01) > 3.0,
          f"overall advantage {a_slice['slo'] / max(a_orca['slo'], 0.01):.1f}x > 3x")
    orca_5 = run_single("orca", 5.0, 0.7, 300, 42)
    check(attainment(orca_5.pool)["rt_slo"] < 0.3, "Orca RT collapse @ rate 5")
    print(f"  (fig11 cells in {time.time() - t0:.1f}s)")

    # cluster: N=1 == single server, determinism
    wl1 = paper_mix(1.0, 0.7, 120, 9)
    single = run_single("slice", 1.0, 0.7, 120, 9)
    for strat in ("round-robin", "least-loaded", "slo-aware"):
        tasks, per = run_cluster(strat, 1, paper_mix(1.0, 0.7, 120, 9), secs(120.0))
        same = all(
            a.first_token == b.first_token and a.completion == b.completion
            and a.tokens_generated == b.tokens_generated
            for a, b in zip(single.pool, tasks))
        check(same and per[0][2] == single.steps, f"N=1 {strat} == single server")
    del wl1
    a1, _ = run_cluster("slo-aware", 3, paper_mix(2.0, 0.7, 150, 5), secs(120.0))
    a2, _ = run_cluster("slo-aware", 3, paper_mix(2.0, 0.7, 150, 5), secs(120.0))
    check(all(x.completion == y.completion for x, y in zip(a1, a2)),
          "cluster determinism (seed 5)")
    print()


def fig1_table():
    rows = []
    for b in range(1, 17):
        lat_ms = LAT.decode(b) / 1e3
        tps = LAT.throughput(b)
        rows.append({"batch": b, "latency_ms": lat_ms,
                     "throughput_tps": tps, "per_task_tps": tps / b})
    return rows


def cluster_cell(strategy, replicas, rate, rt_ratio, n_tasks, seed):
    wl = paper_mix(rate * replicas, rt_ratio, n_tasks * replicas, seed)
    t0 = time.time()
    tasks, per = run_cluster(strategy, replicas, wl, secs(120.0))
    wall = time.time() - t0
    att = attainment(tasks)
    lat = latency_summary(tasks)
    return {
        "replicas": replicas, "strategy": strategy,
        "slo": att["slo"], "rt_slo": att["rt_slo"], "nrt_slo": att["nrt_slo"],
        "n_tasks": att["n_tasks"], "n_finished": att["n_finished"],
        "ttft_p50_ms": lat["ttft"]["p50_ms"], "ttft_p99_ms": lat["ttft"]["p99_ms"],
        "tpot_p50_ms": lat["tpot"]["p50_ms"], "tpot_p99_ms": lat["tpot"]["p99_ms"],
        "routed": [p[1] for p in per], "total_steps": sum(p[2] for p in per),
        "harness_wall_s": round(wall, 2),
    }


def hetero_cell(fleet_label, profiles, strategy, guarded,
                rate=3.0, n_tasks=600, seed=42):
    """Mirrors experiments::hetero_sweep::run_cell (LOAD_EQUIVALENTS=3)."""
    wl = paper_mix(rate, 0.7, n_tasks, seed)
    t0 = time.time()
    tasks, per, router = run_fleet(
        strategy, profiles, wl, secs(120.0),
        admission=AdmissionConfig(enabled=guarded), migration=guarded)
    wall = time.time() - t0
    att = attainment(tasks)
    lat = latency_summary(tasks)
    return {
        "fleet": fleet_label, "strategy": strategy, "guarded": guarded,
        "profiles": [p.name for p in profiles],
        "slo": att["slo"], "rt_slo": att["rt_slo"], "nrt_slo": att["nrt_slo"],
        "n_tasks": att["n_tasks"], "n_finished": att["n_finished"],
        "rejected": len(router.rejected), "migrations": router.migrations,
        "tpot_p99_ms": lat["tpot"]["p99_ms"],
        "routed": [p[1] for p in per], "harness_wall_s": round(wall, 2),
    }


def hetero_sweep():
    print("stage 5: hetero_sweep (SLICE policy, offered load 3.0 standard-"
          "equivalents, RT:NRT 7:3, 600 tasks, seed 42; guards = admission"
          " + migration)")
    shapes = [
        ("uniform-4", lambda: [DeviceProfile.standard() for _ in range(4)]),
        ("edge-mixed", edge_mixed),
    ]
    sweep = []
    for label, mk in shapes:
        for guarded in (False, True):
            for strat in ("round-robin", "least-loaded", "slo-aware"):
                cell = hetero_cell(label, mk(), strat, guarded)
                sweep.append(cell)
                print(f"  {label:<10} guards={'on' if guarded else 'off':<3} "
                      f"{strat:<13} slo={cell['slo']:.4f} rt={cell['rt_slo']:.4f} "
                      f"nrt={cell['nrt_slo']:.4f} shed={cell['rejected']} "
                      f"mig={cell['migrations']} routed={cell['routed']} "
                      f"({cell['harness_wall_s']}s)")
    print()

    print("stage 6: hetero_fleet.rs integration-test cells (threshold "
          "validation)")
    cells = {}
    # mixed_fleet_slo_aware_guarded_at_least_round_robin (seed 42)
    cells["slo_guarded"] = hetero_cell("edge-mixed", edge_mixed(), "slo-aware", True)
    cells["rr_plain"] = hetero_cell("edge-mixed", edge_mixed(), "round-robin", False)
    cells["rr_guarded"] = hetero_cell("edge-mixed", edge_mixed(), "round-robin", True)
    cells["slo_plain"] = hetero_cell("edge-mixed", edge_mixed(), "slo-aware", False)
    for k in ("slo_guarded", "rr_plain", "rr_guarded", "slo_plain"):
        c = cells[k]
        print(f"  {k:<12} slo={c['slo']:.4f} rt={c['rt_slo']:.4f} "
              f"shed={c['rejected']} mig={c['migrations']}")
    ok = (cells["slo_guarded"]["slo"] >= cells["rr_plain"]["slo"]
          and cells["slo_guarded"]["slo"] >= cells["rr_guarded"]["slo"]
          and cells["slo_guarded"]["slo"] > 0.86 and cells["rr_plain"]["slo"] < 0.89
          and cells["slo_guarded"]["migrations"] > 0)
    check(ok, "slo-aware+guards >= round-robin on edge-mixed (rust threshold)")
    check(cells["slo_guarded"]["rt_slo"] >= cells["slo_plain"]["rt_slo"],
          "guards lift slo-aware RT attainment")
    # exactly_once_under_migration_and_shedding (rate 4.0, 800 tasks)
    over = hetero_cell("edge-mixed", edge_mixed(), "slo-aware", True,
                       rate=4.0, n_tasks=800)
    cells["overload"] = over
    print(f"  overload     slo={over['slo']:.4f} shed={over['rejected']} "
          f"mig={over['migrations']}")
    check(over["rejected"] > 0 and over["migrations"] > 0,
          "overload cell sheds and migrates")
    check(sum(over["routed"]) + over["rejected"] == 800,
          "overload cell covers every task exactly once")
    print()
    return sweep, cells


HIGH_CAPACITY_MB = 48
LOW_CAPACITY_MB = 32


def memory_cell(fleet, cap_mb, mode, aware):
    """Mirrors experiments::memory_sweep::run_cell (slo-aware strategy;
    edge-mixed cells run admission + migration + running KV handoff)."""
    mem = MemoryConfig(
        kv_capacity=cap_mb * 1024 * 1024 if cap_mb else None,
        mode=mode, aware=aware)
    if fleet == "single":
        profiles = [DeviceProfile.standard()]
        wl = paper_mix(1.0, 0.7, 200, 42)
        adm, mig, runmig = None, False, False
    else:
        profiles = edge_mixed()
        wl = paper_mix(3.0, 0.7, 600, 42)
        adm, mig, runmig = AdmissionConfig(enabled=True), True, True
    t0 = time.time()
    tasks, per, router = run_fleet(
        "slo-aware", profiles, wl, secs(120.0), admission=adm, migration=mig,
        migrate_running=runmig, memory=mem)
    wall = time.time() - t0
    att = attainment(tasks)
    stats = [r.server.kv.stats() for r in router.replicas]
    tot = lambda k: sum(s[k] for s in stats)  # noqa: E731
    return {
        "fleet": fleet, "capacity_mb": cap_mb, "mode": mode, "aware": aware,
        "slo": att["slo"], "rt_slo": att["rt_slo"], "nrt_slo": att["nrt_slo"],
        "n_tasks": att["n_tasks"], "n_finished": att["n_finished"],
        "peak_kv_bytes": tot("peak_kv_bytes"), "swap_outs": tot("swap_outs"),
        "swap_ins": tot("swap_ins"), "recomputes": tot("recomputes"),
        "handoff_restores": tot("handoff_restores"),
        "swap_delay_us": tot("swap_delay_us"),
        "per_replica_peak": [s["peak_kv_bytes"] for s in stats],
        "per_replica_cap": [r.profile.kv_capacity for r in router.replicas],
        "rejected": len(router.rejected), "migrations": router.migrations,
        "migrated_running": router.migrated_running,
        "handoff_bytes": router.handoff_bytes, "handoff_us": router.handoff_us,
        "harness_wall_s": round(wall, 2),
    }


def memory_sweep():
    print("stage 7: memory_sweep (SLICE slo-aware; single @ rate 1.0/200 "
          "tasks, edge-mixed @ 3.0/600 with guards + running KV handoff; "
          "seed 42; swap 64 MB/s, handoff 125 MB/s)")
    cells = []
    for fleet in ("single", "edge-mixed"):
        plan = [(None, "swap", True)]
        for cap in (HIGH_CAPACITY_MB, LOW_CAPACITY_MB):
            plan += [(cap, "swap", True), (cap, "recompute", True),
                     (cap, "swap", False)]
        for cap, mode, aware in plan:
            c = memory_cell(fleet, cap, mode, aware)
            cells.append(c)
            print(f"  {fleet:<10} cap={str(cap):>4} {mode:<9} "
                  f"aware={'y' if aware else 'n'} slo={c['slo']:.4f} "
                  f"rt={c['rt_slo']:.4f} nrt={c['nrt_slo']:.4f} "
                  f"peak={c['peak_kv_bytes'] / 2**20:.1f}MiB "
                  f"so/si/rc={c['swap_outs']}/{c['swap_ins']}/{c['recomputes']} "
                  f"runmig={c['migrated_running']} "
                  f"handoff={c['handoff_us'] / 1e3:.0f}ms "
                  f"({c['harness_wall_s']}s)")
    print()

    print("stage 8: memory_model.rs test-cell validation")
    by = {(c["fleet"], c["capacity_mb"], c["mode"], c["aware"]): c for c in cells}
    base = by[("single", None, "swap", True)]
    check(abs(base["slo"] - 0.97) < 1e-12 and base["swap_outs"] == 0,
          "single unlimited == pre-memory width-1 cell (0.9700, no swaps)")
    aware = by[("single", LOW_CAPACITY_MB, "swap", True)]
    obliv = by[("single", LOW_CAPACITY_MB, "swap", False)]
    print(f"  aware={aware['slo']:.4f} vs oblivious={obliv['slo']:.4f} "
          f"@ {LOW_CAPACITY_MB} MiB")
    check(aware["slo"] > obliv["slo"] + 0.02,
          "swap-aware SLICE beats memory-oblivious at the tight cell")
    for c in cells:
        if c["capacity_mb"] is not None:
            caps = c["per_replica_cap"]
            ok = all(p <= cap for p, cap in zip(c["per_replica_peak"], caps))
            check(ok, f"peak <= capacity at {c['fleet']}/{c['capacity_mb']}/"
                      f"{c['mode']}/aware={c['aware']}")
    mixed = by[("edge-mixed", LOW_CAPACITY_MB, "swap", True)]
    check(mixed["migrated_running"] > 0 and mixed["handoff_us"] > 0,
          "constrained mixed cell exercises running KV handoff")
    unlim_mixed = by[("edge-mixed", None, "swap", True)]
    check(unlim_mixed["migrated_running"] == 0,
          "unconstrained fleet never evicts, so never hands off")
    a = memory_cell("single", LOW_CAPACITY_MB, "swap", True)
    check(a["slo"] == aware["slo"] and a["swap_outs"] == aware["swap_outs"],
          "constrained cell deterministic")
    print()
    return cells


def _rand_candidates(rng, n, with_kv):
    cands = []
    for i in range(n):
        c = (i, rng.range_u64(1, 1000) / 10.0, rng.range_u64(40, 400) * 1000)
        if with_kv:
            c = c + (rng.range_u64(1, 32) * 512 * 1024,)
        cands.append(c)
    return cands


def _select_ref_rustlike(cands, lat, cycle_cap):
    """The pre-PR 5 Rust cost structure: utility rates recomputed inside
    the sort comparator (the Rust sort_by closure), then an O(n) sorted
    insert + O(n) period_eq7 closed form per admission."""
    import functools
    from bisect import bisect_left

    def cmp(a, b):
        ra = a[1] * (a[2] / 1e6)
        rb = b[1] * (b[2] / 1e6)
        if ra != rb:
            return -1 if ra > rb else 1
        return -1 if a[0] < b[0] else (1 if a[0] > b[0] else 0)

    order = sorted(cands, key=functools.cmp_to_key(cmp))
    selected, quotas_desc, rejected = [], [], []
    stopped = False
    for cand in order:
        if stopped or len(selected) >= lat.max_batch:
            rejected.append(cand[0])
            continue
        q = math.ceil(1e6 / cand[2])
        pos = bisect_left([-v for v in quotas_desc], -q)
        quotas_desc.insert(pos, q)
        p = period_eq7(quotas_desc, lat)
        if p >= cycle_cap:
            quotas_desc.pop(pos)
            rejected.append(cand[0])
            stopped = True
            continue
        selected.append((cand[0], q))
    return selected, rejected


def hot_path_stage(scale_sizes):
    print("stage 9: scheduler hot path (PR 5) — equivalence, micro timing, "
          "scale sweep")

    # -- equivalence: fast == reference over randomized cases ----------
    cases = 0
    for seed in range(300):
        rng = Rng(9_000_000 + seed)
        n = rng.range_u64(0, 60)
        cands = _rand_candidates(rng, n, with_kv=True)
        cap = (rng.range_u64(4, 64) * 1024 * 1024
               if rng.range_u64(0, 1) == 1 else None)
        a = select_tasks(cands, LAT, CYCLE_CAP, cap)
        b = select_tasks_fast(cands, LAT, CYCLE_CAP, cap)
        if a != b:
            raise SystemExit(f"stage 9: selection diverged at seed {seed}")
        cases += 1
    check(cases == 300, "select_tasks_fast == select_tasks over 300 cases")

    # incremental period == closed form under insert/remove churn
    for seed in range(200):
        rng = Rng(11_000_000 + seed)
        inc = IncrementalPeriod(LAT)
        live = []
        for _ in range(rng.range_u64(1, 30)):
            if live and rng.range_u64(0, 99) < 35:
                q = live.pop(rng.range_u64(0, len(live) - 1))
                inc.remove(q)
            else:
                q = rng.range_u64(1, 25)
                live.append(q)
                inc.insert(q)
            if inc.period != period_eq7(sorted(live, reverse=True), LAT):
                raise SystemExit(f"stage 9: period diverged at seed {seed}")
    check(True, "IncrementalPeriod == period_eq7 over 200 churn sequences")

    # -- micro timing: old vs new reschedule pipeline ------------------
    micro = []
    for n in (64, 256, 1024):
        rng = Rng(7)
        cands = _rand_candidates(rng, n, with_kv=False)
        reps = max(3, 2000 // n)
        t0 = time.perf_counter()
        for _ in range(reps):
            ref = _select_ref_rustlike(cands, LAT, CYCLE_CAP)
        old_s = (time.perf_counter() - t0) / reps
        inc = IncrementalPeriod(LAT)
        t0 = time.perf_counter()
        for _ in range(reps):
            new = select_tasks_fast(cands, LAT, CYCLE_CAP, period=inc)
        new_s = (time.perf_counter() - t0) / reps
        if ref != new:
            raise SystemExit(f"stage 9: micro cell n={n} diverged")
        micro.append({
            "n": n,
            "old_us": round(old_s * 1e6, 1),
            "new_us": round(new_s * 1e6, 1),
            "old_decisions_per_sec": round(1.0 / old_s, 1),
            "new_decisions_per_sec": round(1.0 / new_s, 1),
            "speedup": round(old_s / new_s, 2),
        })
        print(f"  select n={n:>5}: old {old_s * 1e6:8.1f}us  "
              f"new {new_s * 1e6:8.1f}us  speedup x{old_s / new_s:.2f}")

    # -- scale sweep ---------------------------------------------------
    scale = []
    for n in scale_sizes:
        rate = n / 120.0
        for fleet in ("single", "edge-mixed"):
            wl = paper_mix(rate, 0.7, n, 42)
            horizon_drain = secs(60.0)
            t0 = time.perf_counter()
            if fleet == "single":
                s = Server(wl, SlicePolicy(LAT), LAT)
                s.run((wl[-1].arrival if wl else 0) + horizon_drain)
                decisions = s.policy.reschedules
                steps = s.steps
                tasks = s.pool
                rejected = 0
            else:
                admission = AdmissionConfig(enabled=True, mode="headroom")
                tasks, _per, router = run_fleet(
                    "slo-aware", edge_mixed(), wl, horizon_drain,
                    admission=admission, migration=True)
                decisions = sum(r.server.policy.reschedules
                                for r in router.replicas) + n
                steps = sum(r.server.steps for r in router.replicas)
                rejected = len(router.rejected)
            wall = time.perf_counter() - t0
            a = attainment(tasks)
            cell = {
                "fleet": fleet, "n_tasks": n, "rate": round(rate, 2),
                "harness_wall_s": round(wall, 2),
                "decisions": decisions,
                "decisions_per_sec": round(decisions / wall, 1),
                "steps": steps,
                "steps_per_sec": round(steps / wall, 1),
                "finished": a["n_finished"], "rejected": rejected,
                "slo": a["slo"],
            }
            scale.append(cell)
            print(f"  scale {fleet:<10} n={n:>5}: wall={wall:7.2f}s  "
                  f"decisions={decisions:>6} ({cell['decisions_per_sec']:>9.1f}/s) "
                  f"steps={steps:>6} finished={a['n_finished']:>5} "
                  f"shed={rejected}")
    print()
    return {"micro": micro, "scale": scale}


def _engine_pair(label, mk_profiles, strategy, rate, n, seed,
                 admission=None, migration=False, migrate_running=False,
                 memory=None, drain_s=120.0):
    """Run one cell through both engines; fail loudly on any divergence."""
    runs = []
    for engine in ("lockstep", "event"):
        wl = paper_mix(rate, 0.7, n, seed)
        runs.append(run_fleet(
            strategy, mk_profiles(), wl, secs(drain_s), admission=admission,
            migration=migration, migrate_running=migrate_running,
            memory=memory, engine=engine))
    (ta, pa, ra), (tb, pb, rb) = runs
    ok = (pa == pb and len(ta) == len(tb)
          and all(x.id == y.id and x.first_token == y.first_token
                  and x.completion == y.completion
                  and x.tokens_generated == y.tokens_generated
                  for x, y in zip(ta, tb))
          and ra.migrations == rb.migrations
          and ra.migrated_running == rb.migrated_running
          and ra.handoff_bytes == rb.handoff_bytes
          and ra.handoff_us == rb.handoff_us
          and [t.id for t in ra.rejected] == [t.id for t in rb.rejected])
    check(ok, f"event == lockstep: {label} (seed {seed})")
    return ok


def replica_scale_cell(engine, replicas, n, seed=42, threads=1):
    """Mirrors experiments::scale_sweep::run_replica_cell: round-robin
    homogeneous standard fleet, guards off, SLICE policy. threads > 1
    routes wakes through the epoch-batched path (bit-exact; PR 9)."""
    rate = n / 120.0
    wl = paper_mix(rate, 0.7, n, seed)
    t0 = time.perf_counter()
    tasks, per, router = run_fleet(
        "round-robin", [DeviceProfile.standard() for _ in range(replicas)],
        wl, secs(60.0), engine=engine, threads=threads)
    wall = time.perf_counter() - t0
    a = attainment(tasks)
    decisions = sum(r.server.policy.reschedules for r in router.replicas) + n
    steps = sum(r.server.steps for r in router.replicas)
    return {
        "engine": engine, "fleet": "replicas", "replicas": replicas,
        "n_tasks": n, "rate": round(rate, 2), "threads": threads,
        "harness_wall_s": round(wall, 2),
        "decisions": decisions,
        "decisions_per_sec": round(decisions / wall, 1),
        "steps": steps, "steps_per_sec": round(steps / wall, 1),
        "finished": a["n_finished"], "rejected": len(router.rejected),
        "slo": a["slo"],
    }


def _engine_shapes():
    """The nine cluster shapes both the stage-10 engine-equivalence and
    the stage-11 elastic-noop checks sweep."""
    uniform4 = lambda: [DeviceProfile.standard() for _ in range(4)]  # noqa: E731
    single = lambda: [DeviceProfile.standard()]  # noqa: E731
    mem48 = MemoryConfig(kv_capacity=HIGH_CAPACITY_MB * 1024 * 1024)
    return [
        ("uniform-4 round-robin", uniform4, "round-robin", 4.0, 160, 42, {}),
        ("uniform-4 least-loaded", uniform4, "least-loaded", 4.0, 160, 42, {}),
        ("uniform-4 slo-aware", uniform4, "slo-aware", 4.0, 160, 42, {}),
        ("uniform-4 slo-aware", uniform4, "slo-aware", 4.0, 160, 7, {}),
        ("single-replica slo-aware", single, "slo-aware", 1.0, 120, 7, {}),
        ("edge-mixed depth admission", edge_mixed, "slo-aware", 6.0, 200, 42,
         {"admission": AdmissionConfig(enabled=True, mode="depth")}),
        ("edge-mixed headroom admission", edge_mixed, "slo-aware", 6.0, 200, 42,
         {"admission": AdmissionConfig(enabled=True, mode="headroom")}),
        ("edge-mixed admission+migration", edge_mixed, "slo-aware", 6.0, 200, 42,
         {"admission": AdmissionConfig(enabled=True, mode="headroom"),
          "migration": True}),
        ("edge-mixed memory+handoff", edge_mixed, "slo-aware", 6.0, 200, 42,
         {"admission": AdmissionConfig(enabled=True, mode="headroom"),
          "migration": True, "migrate_running": True, "memory": mem48}),
    ]


def event_engine_stage(replica_widths, replica_sizes):
    print("stage 10: event-driven cluster engine (PR 6) — bit-exactness, "
          "replica-width scale sweep")

    for label, mk, strat, rate, n, seed, kw in _engine_shapes():
        _engine_pair(label, mk, strat, rate, n, seed, **kw)

    sweep = []
    for width in replica_widths:
        for i, n in enumerate(replica_sizes):
            for engine in (["event", "lockstep"] if i == 0 else ["event"]):
                cell = replica_scale_cell(engine, width, n)
                sweep.append(cell)
                print(f"  {engine:<8} replicas={width:>4} n={n:>6}: "
                      f"wall={cell['harness_wall_s']:8.2f}s "
                      f"decisions={cell['decisions']:>7} "
                      f"({cell['decisions_per_sec']:>9.1f}/s) "
                      f"steps={cell['steps']:>7} "
                      f"finished={cell['finished']:>6} slo={cell['slo']:.4f}")
    # event vs lockstep at the reference size must agree cell-for-cell
    by = {(c["engine"], c["replicas"], c["n_tasks"]): c for c in sweep}
    for width in replica_widths:
        n0 = replica_sizes[0]
        ev, ls = by[("event", width, n0)], by[("lockstep", width, n0)]
        same = all(ev[k] == ls[k] for k in
                   ("decisions", "steps", "finished", "rejected", "slo"))
        check(same, f"replica sweep engines agree at width {width}, n={n0}")
    print()
    return sweep


# -------------------------------------------------- stage 11: elastic --


ELASTIC_WINDOW_S = 120.0
ELASTIC_DRAIN_S = 60.0
AUTOSCALE_MAX = 64
ELASTIC_VARIANTS = ("static", "crash", "autoscale", "autoscale-headroom",
                    "autoscale+crash")
# mirrors elastic_sweep::HEADROOM_MIN_US: 50 ms of mean Eq. 7 slack
HEADROOM_MIN_US = 50_000


def _elastic_lifecycle(variant):
    """Mirrors experiments::elastic_sweep::lifecycle_for."""
    assert variant in ELASTIC_VARIANTS, f"unknown elastic variant {variant!r}"
    lc = LifecycleConfig()
    if variant in ("crash", "autoscale+crash"):
        lc.events = [LifecycleEvent(secs(40.0), CRASH, 0),
                     LifecycleEvent(secs(80.0), CRASH, 1)]
    if variant in ("autoscale", "autoscale-headroom", "autoscale+crash"):
        lc.autoscaler.enabled = True
        lc.min_replicas = 4
        lc.max_replicas = AUTOSCALE_MAX
    if variant == "autoscale-headroom":
        lc.autoscaler.grow_on_headroom = True
        lc.autoscaler.headroom_min = HEADROOM_MIN_US
    return lc


def elastic_cell(variant, n, seed=42):
    """Mirrors experiments::elastic_sweep::run_cell: the scale sweep's
    edge-mixed overload shape (slo-aware + headroom admission +
    migration, event engine) with the variant's lifecycle attached."""
    rate = n / ELASTIC_WINDOW_S
    wl = paper_mix(rate, 0.7, n, seed)
    t0 = time.perf_counter()
    tasks, _per, router = run_fleet(
        "slo-aware", edge_mixed(), wl, secs(ELASTIC_DRAIN_S),
        admission=AdmissionConfig(enabled=True, mode="headroom"),
        migration=True, engine="event", lifecycle=_elastic_lifecycle(variant))
    wall = max(time.perf_counter() - t0, 1e-9)
    a = attainment(tasks)
    shed = len(router.rejected) + sum(r.server.shed for r in router.replicas)
    cell = {
        "variant": variant, "n_tasks": n, "rate": round(rate, 4),
        "replicas_start": 4, "replicas_final": router.alive_count(),
        "finished": a["n_finished"], "shed": shed,
        "shed_rate": round(shed / n, 4), "slo": a["slo"],
        "crashes": router.crashes, "joins": router.joins,
        "leaves": router.leaves, "grows": router.autoscale_grows,
        "shrinks": router.autoscale_shrinks,
        "evac_requeued": router.evac_requeued,
        "evac_restarted": router.evac_restarted,
        "evac_recompute_us": router.evac_recompute_us,
        "wall_s": round(wall, 2),
    }
    return cell, tasks


def _run_event(mk_profiles, strategy, wl, drain, admission=None,
               migration=False, migrate_running=False, memory=None,
               elastic=False):
    """One event-engine run. elastic=True force-attaches the
    *all-disabled* elastic machinery (live alive/degraded masks, no
    events, no autoscaler, no health) — run_fleet only attaches it when
    a feature is on, but the noop check needs the elastic decision
    paths exercised with everything off."""
    import copy

    profiles = mk_profiles()
    if (memory is not None and memory.kv_capacity is not None
            and all(p.kv_capacity is None for p in profiles)):
        profiles = [copy.copy(p) for p in profiles]
        for p in profiles:
            p.kv_capacity = int(memory.kv_capacity * p.kv_fraction)
    mk = lambda p: _default_policy(p, memory)  # noqa: E731
    fleet = [Replica(i, mk, p, memory=memory) for i, p in enumerate(profiles)]
    router = Router(strategy, fleet, admission=admission,
                    migration=migration, migrate_running=migrate_running,
                    memory=memory or MemoryConfig())
    if elastic:
        factory = lambda rid: Replica(  # noqa: E731
            rid, mk, copy.copy(profiles[0]), memory=memory)
        orch = Orchestrator(router, lifecycle=LifecycleConfig(),
                            factory=factory)
    else:
        orch = Orchestrator(router)
    tasks, per = orch.run(wl, drain)
    return tasks, per, router


def _elastic_noop_pair(label, mk_profiles, strategy, rate, n, seed,
                       admission=None, migration=False,
                       migrate_running=False, memory=None, drain_s=120.0):
    """All-disabled elastic must be bit-exact with the static event
    engine (the Rust equivalence.rs elastic-noop contract)."""
    runs = []
    for elastic in (False, True):
        wl = paper_mix(rate, 0.7, n, seed)
        runs.append(_run_event(
            mk_profiles, strategy, wl, secs(drain_s), admission=admission,
            migration=migration, migrate_running=migrate_running,
            memory=memory, elastic=elastic))
    (ta, pa, ra), (tb, pb, rb) = runs
    untouched = (rb.crashes + rb.joins + rb.leaves + rb.autoscale_grows
                 + rb.autoscale_shrinks + rb.evac_requeued
                 + rb.evac_restarted) == 0
    ok = (pa == pb and len(ta) == len(tb)
          and all(x.id == y.id and x.first_token == y.first_token
                  and x.completion == y.completion
                  and x.tokens_generated == y.tokens_generated
                  for x, y in zip(ta, tb))
          and ra.migrations == rb.migrations
          and ra.handoff_bytes == rb.handoff_bytes
          and [t.id for t in ra.rejected] == [t.id for t in rb.rejected]
          and untouched)
    check(ok, f"elastic noop == static event: {label} (seed {seed})")


def _elastic_conservation(tasks, n, label):
    ids = sorted(t.id for t in tasks)
    check(ids == list(range(n)), f"task conservation: {label}")


def elastic_stage(elastic_sizes):
    print("stage 11: elastic fleets (PR 7) — lifecycle/autoscaler/health "
          "mirrors, elastic-noop equivalence, failure sweep")

    # -- unit mirrors of the Rust lifecycle/autoscaler/health suites ---
    lc = LifecycleConfig(churn_rate=0.5, seed=9)
    a = lc.schedule(secs(120.0))
    b = lc.schedule(secs(120.0))
    check(a == b and len(a) > 0
          and all(x.time <= y.time for x, y in zip(a, a[1:]))
          and all(e.time < secs(120.0) for e in a),
          "churn schedule deterministic, sorted, horizon-bounded")
    c = LifecycleConfig(churn_rate=0.5, seed=10).schedule(secs(120.0))
    check(a != c, "different churn seed, different schedule")

    scaler = Autoscaler(AutoscalerConfig(True, 2, 3, 1_000), 1, 8)
    d = [scaler.observe(0, True, None, 4), scaler.observe(10, True, None, 4),
         scaler.observe(20, True, None, 5), scaler.observe(30, True, None, 5),
         scaler.observe(1_200, True, None, 5)]
    check(d == [None, "grow", None, None, "grow"],
          "autoscaler grows on sustained deficit, holds through cooldown")
    scaler = Autoscaler(AutoscalerConfig(True, 2, 3, 1_000), 1, 8)
    s = [scaler.observe(t * 10, False, 3, 4) for t in range(3)]
    check(s == [None, None, ("shrink", 3)],
          "autoscaler shrinks the idle replica after the streak")
    scaler = Autoscaler(AutoscalerConfig(True, 2, 3, 1_000), 2, 4)
    check(scaler.observe(0, True, None, 4) is None
          and scaler.observe(10, True, None, 4) is None,
          "autoscaler respects the fleet ceiling")

    h = HealthTracker(HealthConfig(True, 0.5, 1_000, 500), 2)
    h.observe(0, 2_000)
    degraded_once = h.degraded(0)
    h.observe(0, 2_000)
    still = h.degraded(0) and not h.degraded(1)
    h.observe(0, 0)
    h.observe(0, 0)
    check(degraded_once and still and not h.degraded(0),
          "health EWMA degrades under lag and heals on recovery")
    h = HealthTracker(HealthConfig(True, 0.5, 1_000, 500), 1)
    h.observe(0, 1)
    check(abs(h.scores[0] - 250.5) < 1e-9,
          "failure penalty applies only while overrunning")

    # -- all-disabled elastic is bit-exact with static fleets ----------
    for label, mk, strat, rate, n, seed, kw in _engine_shapes():
        _elastic_noop_pair(label, mk, strat, rate, n, seed, **kw)

    # -- lifecycle semantics on small cells ----------------------------
    cell, tasks = elastic_cell("static", 60)
    check(cell["replicas_final"] == 4
          and cell["crashes"] + cell["joins"] + cell["leaves"]
          + cell["grows"] + cell["shrinks"] == 0,
          "static cell runs without elastic machinery")
    _elastic_conservation(tasks, 60, "static cell")
    cell, tasks = elastic_cell("crash", 60)
    check(cell["crashes"] == 2 and cell["replicas_final"] == 2
          and cell["grows"] == 0 and cell["shrinks"] == 0,
          "both explicit crashes fire")
    _elastic_conservation(tasks, 60, "crash cell")
    a1, t1 = elastic_cell("autoscale", 120)
    a2, _ = elastic_cell("autoscale", 120)
    same = ({k: v for k, v in a1.items() if k != "wall_s"}
            == {k: v for k, v in a2.items() if k != "wall_s"})
    check(4 <= a1["replicas_final"] <= AUTOSCALE_MAX and same,
          "autoscale cell respects bounds and is deterministic")
    _elastic_conservation(t1, 120, "autoscale cell")
    h1, th = elastic_cell("autoscale-headroom", 120)
    h2, _ = elastic_cell("autoscale-headroom", 120)
    same = ({k: v for k, v in h1.items() if k != "wall_s"}
            == {k: v for k, v in h2.items() if k != "wall_s"})
    check(4 <= h1["replicas_final"] <= AUTOSCALE_MAX and same,
          "autoscale-headroom cell respects bounds and is deterministic")
    _elastic_conservation(th, 120, "autoscale-headroom cell")

    # -- conservation + determinism under seeded churn -----------------
    for seed in (1, 2, 3):
        lc = LifecycleConfig(churn_rate=1.0, seed=seed, min_replicas=2,
                             max_replicas=8)
        wl = paper_mix(4.0, 0.7, 240, 42)
        tasks, _per, router = run_fleet(
            "slo-aware", edge_mixed(), wl, secs(60.0),
            admission=AdmissionConfig(enabled=True, mode="headroom"),
            migration=True, engine="event", lifecycle=lc)
        _elastic_conservation(tasks, 240, f"churn seed {seed}")
        check(router.crashes + router.joins + router.leaves > 0,
              f"churn seed {seed} fired lifecycle events")

    # -- health-based routing smoke: conserved and deterministic -------
    lc = LifecycleConfig()
    lc.health.enabled = True
    lc.health.lag_threshold = 100_000  # degrade readily under overload
    outs = []
    for _ in range(2):
        wl = paper_mix(8.0, 0.7, 480, 42)
        tasks, _per, router = run_fleet(
            "slo-aware", edge_mixed(), wl, secs(60.0), migration=True,
            engine="event", lifecycle=lc)
        _elastic_conservation(tasks, 480, "health-routing cell")
        outs.append((attainment(tasks)["slo"], len(router.rejected)))
    check(outs[0] == outs[1], "health-based routing is deterministic")

    # -- the failure sweep (BENCH_7 rows) ------------------------------
    rows = []
    for n in elastic_sizes:
        for variant in ELASTIC_VARIANTS:
            cell, _tasks = elastic_cell(variant, n)
            rows.append(cell)
            print(f"  {variant:<15} n={n:>6}: wall={cell['wall_s']:7.2f}s "
                  f"alive={cell['replicas_final']:>2} "
                  f"finished={cell['finished']:>6} shed={cell['shed']:>6} "
                  f"slo={cell['slo']:.4f} crash={cell['crashes']} "
                  f"grow={cell['grows']} shrink={cell['shrinks']} "
                  f"evac={cell['evac_requeued']}+{cell['evac_restarted']}")
    n = elastic_sizes[-1]
    st = next(c for c in rows
              if c["n_tasks"] == n and c["variant"] == "static")
    au = next(c for c in rows
              if c["n_tasks"] == n and c["variant"] == "autoscale")
    print(f"  shed at {n} tasks: static {st['shed']} vs "
          f"autoscaled {au['shed']}")
    check(au["shed"] < st["shed"],
          f"autoscaling strictly reduces shed at {n} tasks")
    hr = next(c for c in rows
              if c["n_tasks"] == n and c["variant"] == "autoscale-headroom")
    print(f"  grow signal at {n} tasks: deficit shed {au['shed']} "
          f"({au['grows']} grows) vs headroom shed {hr['shed']} "
          f"({hr['grows']} grows)")
    check(hr["grows"] > 0,
          f"headroom grow signal fires at {n} tasks")
    check(hr["shed"] < st["shed"],
          f"headroom autoscaling reduces shed vs static at {n} tasks")
    print()
    return rows


# --------------------------------- stage 12: O(changes) control plane --


MIGRATION_SEEDS = (7, 42, 1234, 777)
STREAM_WINDOW_S = 120.0
STREAM_DRAIN_S = 60.0


def _policy_counters(router):
    ps = [r.server.policy for r in router.replicas]
    return (sum(p.reschedules for p in ps),
            sum(p.decisions_skipped for p in ps),
            sum(p.full_rebuilds for p in ps))


def _skip_pair(label, engine, mk_profiles, strategy, rate, n, seed,
               admission=None, migration=False, migrate_running=False,
               memory=None, drain_s=120.0):
    """Skip/cache on (the default) vs the always-rebuild reference must
    be bit-exact, with `reschedules + skipped == no-skip reschedules`
    (the Rust equivalence.rs summed-decision invariant)."""
    runs = []
    for incremental in (True, False):
        wl = paper_mix(rate, 0.7, n, seed)
        mk = (None if incremental else
              (lambda p, _m=memory: _default_policy(p, _m, incremental=False)))
        runs.append(run_fleet(
            strategy, mk_profiles(), wl, secs(drain_s), make_policy=mk,
            admission=admission, migration=migration,
            migrate_running=migrate_running, memory=memory, engine=engine))
    (ta, pa, ra), (tb, pb, rb) = runs
    ok = (pa == pb and len(ta) == len(tb)
          and all(x.id == y.id and x.first_token == y.first_token
                  and x.completion == y.completion
                  and x.tokens_generated == y.tokens_generated
                  for x, y in zip(ta, tb))
          and ra.migrations == rb.migrations
          and [t.id for t in ra.rejected] == [t.id for t in rb.rejected])
    check(ok, f"skip/cache == rebuild ({engine}): {label} (seed {seed})")
    on_res, on_skip, on_fb = _policy_counters(ra)
    off_res, off_skip, off_fb = _policy_counters(rb)
    check(off_skip == 0 and on_res + on_skip == off_res,
          f"decision invariant ({engine}): {label} "
          f"{on_res}+{on_skip} == {off_res}")
    if memory is None:
        # cache-eligible (immutable) shapes serve every reschedule from
        # the maintained candidate set — no full select_tasks rebuild
        check(on_fb == 0, f"zero full rebuilds ({engine}): {label}")
    return on_skip


def _migration_witness(seed):
    """The edge-triggered engine must migrate the *same task set* as
    the lockstep per-boundary reference while running only
    O(overload episodes) passes (no admission: queues overload, so
    migrations actually fire)."""
    runs = []
    for engine in ("lockstep", "event"):
        wl = paper_mix(6.0, 0.5, 200, seed)
        runs.append(run_fleet("slo-aware", edge_mixed(), wl, secs(60.0),
                              migration=True, engine=engine))
    (tl, pl, rl), (te, pe, re_) = runs
    ok = (pl == pe
          and [(t.id, t.completion, t.tokens_generated) for t in tl]
          == [(t.id, t.completion, t.tokens_generated) for t in te]
          and rl.migrated == re_.migrated
          and rl.migrations == re_.migrations)
    check(ok and rl.migrations > 0,
          f"edge-triggered migration set == lockstep "
          f"(seed {seed}, {rl.migrations} migrations)")
    check(re_.migration_passes <= re_.migration_checks
          and re_.migration_passes < rl.migration_passes
          and rl.migration_checks == 0,
          f"O(episodes) passes (seed {seed}): event "
          f"{re_.migration_passes}/{re_.migration_checks} checks "
          f"< lockstep {rl.migration_passes}")


def _boot_delay_checks():
    """boot_delay_s > 0 defers grow-decided joins behind Boot events:
    tasks are conserved, fleet bounds hold, in-flight boots are
    reported, and the run stays deterministic. (boot_delay = 0 is the
    bit-exact default — stage 11's unchanged pins are the witness.)"""
    outs = []
    router = None
    for _ in range(2):
        lc = _elastic_lifecycle("autoscale")
        lc.autoscaler.boot_delay = secs(2.0)
        wl = paper_mix(1000 / ELASTIC_WINDOW_S, 0.7, 1000, 42)
        tasks, _per, router = run_fleet(
            "slo-aware", edge_mixed(), wl, secs(ELASTIC_DRAIN_S),
            admission=AdmissionConfig(enabled=True, mode="headroom"),
            migration=True, engine="event", lifecycle=lc)
        _elastic_conservation(tasks, 1000, "boot-delay cell")
        outs.append((attainment(tasks)["slo"], router.alive_count(),
                     len(router.replicas), router.autoscale_grows,
                     router.autoscale_shrinks,
                     router.autoscale_pending_boots, len(router.rejected)))
    check(outs[0] == outs[1], "boot-delay cell deterministic")
    _slo, alive, width, grows, _shrinks, pending, _rej = outs[0]
    # every replica beyond the starting 4 came from a counted grow;
    # grows still pending (or dropped at the bound) make up the rest
    check(grows > 0 and alive <= AUTOSCALE_MAX
          and width - 4 + pending <= grows,
          f"boot-delay accounting: width {width}, grows {grows}, "
          f"pending {pending}")
    print(f"  boot-delay 2s autoscale n=1000: alive={alive} grows={grows} "
          f"pending_boots={pending} slo={outs[0][0]:.4f}")


def stream_scale_cell(n, seed=42):
    """Mirrors experiments::scale_sweep::run_stream_cell: edge-mixed
    fleet, slo-aware routing + headroom admission + migration, event
    engine pulling paper_mix_stream lazily, shed folded into a counter
    — O(live set) memory however long the trace."""
    rate = n / STREAM_WINDOW_S
    t0 = time.perf_counter()
    tasks, _per, router = run_fleet_stream(
        "slo-aware", edge_mixed(), paper_mix_stream(rate, 0.7, n, seed),
        secs(STREAM_DRAIN_S),
        admission=AdmissionConfig(enabled=True, mode="headroom"),
        migration=True)
    wall = max(time.perf_counter() - t0, 1e-9)
    a = attainment(tasks)
    res, skip, fb = _policy_counters(router)
    decisions = res + n
    steps = sum(r.server.steps for r in router.replicas)
    # folded rejects never reach tasks: scale the routed-set attainment
    # so each folded shed counts as a miss (the materialized
    # denominator)
    denom = a["n_tasks"] + router.rejected_folded
    slo = (float("nan") if denom == 0 or a["n_tasks"] == 0
           else a["slo"] * a["n_tasks"] / denom)
    return {
        "fleet": "edge-stream", "engine": "event", "replicas": 4,
        "n_tasks": n, "rate": round(rate, 2),
        "harness_wall_s": round(wall, 2),
        "decisions": decisions, "decisions_skipped": skip,
        "full_rebuilds": fb,
        "migration_passes": router.migration_passes,
        "migration_checks": router.migration_checks,
        "decisions_per_sec": round(decisions / wall, 1),
        "steps": steps, "steps_per_sec": round(steps / wall, 1),
        "finished": a["n_finished"],
        "rejected": len(router.rejected) + router.rejected_folded,
        "slo": slo,
    }


def _edge_mixed_cell(engine, incremental, n=10_000, seed=42):
    """The acceptance cell: the PR 5 guarded edge-mixed shape at 10k
    with full O(changes) accounting."""
    rate = n / STREAM_WINDOW_S
    wl = paper_mix(rate, 0.7, n, seed)
    mk = (None if incremental else
          (lambda p: _default_policy(p, incremental=False)))
    t0 = time.perf_counter()
    tasks, _per, router = run_fleet(
        "slo-aware", edge_mixed(), wl, secs(STREAM_DRAIN_S), make_policy=mk,
        admission=AdmissionConfig(enabled=True, mode="headroom"),
        migration=True, engine=engine)
    wall = max(time.perf_counter() - t0, 1e-9)
    a = attainment(tasks)
    res, skip, fb = _policy_counters(router)
    decisions = res + n
    steps = sum(r.server.steps for r in router.replicas)
    return {
        "fleet": "edge-mixed" if incremental else "edge-mixed-noskip",
        "engine": engine, "replicas": 4, "n_tasks": n,
        "rate": round(rate, 2), "harness_wall_s": round(wall, 2),
        "decisions": decisions, "decisions_skipped": skip,
        "full_rebuilds": fb,
        "migration_passes": router.migration_passes,
        "migration_checks": router.migration_checks,
        "decisions_per_sec": round(decisions / wall, 1),
        "steps": steps, "steps_per_sec": round(steps / wall, 1),
        "finished": a["n_finished"], "rejected": len(router.rejected),
        "slo": a["slo"],
    }


def _print_cell(cell):
    print(f"  {cell['fleet']:<18} {cell['engine']:<8} "
          f"n={cell['n_tasks']:>8}: wall={cell['harness_wall_s']:8.2f}s "
          f"decisions={cell['decisions']:>8} "
          f"({cell['decisions_per_sec']:>9.1f}/s) "
          f"skipped={cell['decisions_skipped']:>7} "
          f"rebuilds={cell['full_rebuilds']:>5} "
          f"passes={cell['migration_passes']:>6} "
          f"checks={cell['migration_checks']:>6} "
          f"shed={cell['rejected']:>8} slo={cell['slo']:.4f}")


def o_changes_stage(stream_sizes):
    print("stage 12: O(changes) control plane (PR 8) — cached candidates, "
          "reschedule skipping, edge-triggered migration, streaming traces")

    # -- the stream generator is the workload generator ----------------
    wl = paper_mix(4.0, 0.7, 500, 42)
    ws = list(paper_mix_stream(4.0, 0.7, 500, 42))
    same = (len(wl) == len(ws) and all(
        a.id == b.id and a.arrival == b.arrival and a.cls == b.cls
        and a.prompt_len == b.prompt_len and a.output_len == b.output_len
        and a.utility == b.utility for a, b in zip(wl, ws)))
    check(same, "paper_mix_stream == paper_mix (500 tasks, seed 42)")

    # -- skip/cache bit-exactness across every stage-10 shape ----------
    total_skipped = 0
    for label, mk, strat, rate, n, seed, kw in _engine_shapes():
        for engine in ("lockstep", "event"):
            total_skipped += _skip_pair(label, engine, mk, strat, rate, n,
                                        seed, **kw)
    check(total_skipped > 0,
          f"skipping fires across the shape sweep ({total_skipped} skips)")

    # -- edge-triggered migration: same migrated set, fewer passes -----
    for seed in MIGRATION_SEEDS:
        _migration_witness(seed)

    # -- autoscaler boot delay -----------------------------------------
    _boot_delay_checks()

    # -- streaming == materialized event run on the routed set ---------
    n = 2000
    rate = n / STREAM_WINDOW_S
    wl = paper_mix(rate, 0.7, n, 42)
    tm, pm, rm = run_fleet(
        "slo-aware", edge_mixed(), wl, secs(STREAM_DRAIN_S),
        admission=AdmissionConfig(enabled=True, mode="headroom"),
        migration=True, engine="event")
    ts, ps, rs = run_fleet_stream(
        "slo-aware", edge_mixed(), paper_mix_stream(rate, 0.7, n, 42),
        secs(STREAM_DRAIN_S),
        admission=AdmissionConfig(enabled=True, mode="headroom"),
        migration=True)
    rejected_ids = {t.id for t in rm.rejected}
    routed_m = [t for t in tm if t.id not in rejected_ids]
    ok = (pm == ps and len(ts) == len(routed_m)
          and all(x.id == y.id and x.first_token == y.first_token
                  and x.completion == y.completion
                  and x.tokens_generated == y.tokens_generated
                  for x, y in zip(routed_m, ts))
          and rs.rejected_folded == len(rm.rejected)
          and not rs.rejected
          and rm.migrated == rs.migrated)
    check(ok, f"stream run == materialized event run (n={n})")
    am, as_ = attainment(tm), attainment(ts)
    scaled = as_["slo"] * as_["n_tasks"] / (as_["n_tasks"]
                                            + rs.rejected_folded)
    check(abs(scaled - am["slo"]) < 1e-12,
          "folded-shed slo scaling matches the materialized denominator")

    # -- the acceptance cell + BENCH_8 rows ----------------------------
    rows = []
    on = _edge_mixed_cell("event", True)
    off = _edge_mixed_cell("event", False)
    lock = _edge_mixed_cell("lockstep", True)
    rows.extend([on, off, lock])
    for c in (on, off, lock):
        _print_cell(c)
    # >= 30% fewer full select_tasks passes (cached/dirty-only + skips)
    check(off["full_rebuilds"] > 0
          and on["full_rebuilds"] <= 0.7 * off["full_rebuilds"],
          f"edge-mixed 10k: full passes {on['full_rebuilds']} <= 70% of "
          f"no-skip {off['full_rebuilds']}")
    check(on["migration_passes"] <= on["migration_checks"]
          and on["migration_passes"] < lock["migration_passes"],
          f"edge-mixed 10k: migration passes O(episodes) "
          f"(event {on['migration_passes']} < lockstep "
          f"{lock['migration_passes']})")
    check(on["decisions"] + on["decisions_skipped"] == off["decisions"],
          "edge-mixed 10k: summed decision invariant")

    for n_tasks in stream_sizes:
        cell = stream_scale_cell(n_tasks)
        rows.append(cell)
        _print_cell(cell)
    print()
    return rows


# ------------------------------- stage 13: parallel event engine --


PARALLEL_THREADS = (1, 2, 4, 8)


def _parallel_run(replicas, n, threads, seed=42, measure=False):
    """One replica-sweep cell driven through Orchestrator directly so
    the epoch log (and, with measure=True, per-advancement costs) is
    observable. Same shape as replica_scale_cell's event runs."""
    rate = n / 120.0
    wl = paper_mix(rate, 0.7, n, seed)
    fleet = [Replica(i, lambda p: _default_policy(p),
                     DeviceProfile.standard()) for i in range(replicas)]
    router = Router("round-robin", fleet)
    orch = Orchestrator(router, threads=threads)
    orch.epoch_log = []
    if measure:
        orch.epoch_costs = []
    t0 = time.perf_counter()
    tasks, per = orch.run(wl, secs(60.0))
    wall = time.perf_counter() - t0
    return tasks, per, router, orch, wall


def _modeled_wall(wall, epoch_costs, threads):
    """The PR 9 cost model: wall time at N worker threads is everything
    that stays sequential (control plane, heap, decisions — wall minus
    the advancement cost) plus, per epoch, the slowest worker chunk of
    that epoch's measured per-replica advancement costs (replica-index
    order, ceil(batch/N) per chunk — exactly how run_epoch splits). The
    Python mirror cannot run real threads (the GIL), so BENCH_9 wall
    times for threads > 1 are this model over measured costs; CI's
    native gate replays one cell against real threads."""
    if threads <= 1:
        return wall
    seq = sum(c for ep in epoch_costs for _, c in ep)
    par = 0.0
    for ep in epoch_costs:
        if not ep:
            continue
        costs = [c for _, c in sorted(ep)]
        workers = min(threads, len(costs))
        per = -(-len(costs) // workers)  # ceil division
        par += max(sum(costs[j:j + per])
                   for j in range(0, len(costs), per))
    return max(0.0, wall - seq) + par


def parallel_engine_stage(parallel_widths, replica_sizes, parallel_threads):
    print("stage 13: parallel event engine (PR 9) — epoch batching, "
          "bit-exactness across thread counts, thread-speedup sweep")

    # -- bit-exactness: every stage-10 shape, threads 2/4/8 vs 1 -------
    for label, mk, strat, rate, n, seed, kw in _engine_shapes():
        wl = paper_mix(rate, 0.7, n, seed)
        ta, pa, ra = run_fleet(strat, mk(), wl, secs(120.0),
                               engine="event", threads=1, **kw)
        for t in (2, 4, 8):
            wl = paper_mix(rate, 0.7, n, seed)
            tb, pb, rb = run_fleet(strat, mk(), wl, secs(120.0),
                                   engine="event", threads=t, **kw)
            ok = (pa == pb and len(ta) == len(tb)
                  and all(x.id == y.id and x.first_token == y.first_token
                          and x.completion == y.completion
                          and x.tokens_generated == y.tokens_generated
                          for x, y in zip(ta, tb))
                  and ra.migrations == rb.migrations
                  and ra.migration_passes == rb.migration_passes
                  and ra.migration_checks == rb.migration_checks
                  and ra.handoff_bytes == rb.handoff_bytes
                  and [x.id for x in ra.rejected]
                  == [x.id for x in rb.rejected])
            check(ok, f"threads {t} == threads 1: {label} (seed {seed})")

    # -- epoch structure: unique replicas per batch, real width --------
    for seed in (7, 42, 1234):
        _tasks, _per, _router, orch, _wall = _parallel_run(8, 60, 4,
                                                           seed=seed)
        widest = 0
        ok = len(orch.epoch_log) > 0
        for batch in orch.epoch_log:
            ok = ok and len(set(batch)) == len(batch) \
                and all(0 <= r < 8 for r in batch)
            widest = max(widest, len(batch))
        check(ok and widest >= 2,
              f"epoch batches unique, widest {widest} >= 2 (seed {seed})")

    # -- the thread-speedup sweep (BENCH_9 rows) -----------------------
    rows = []
    for width in parallel_widths:
        for i, n in enumerate(replica_sizes):
            tasks, per, router, orch, wall = _parallel_run(
                width, n, 2, measure=True)
            a = attainment(tasks)
            decisions = sum(r.server.policy.reschedules
                            for r in router.replicas) + n
            steps = sum(r.server.steps for r in router.replicas)
            for t in parallel_threads:
                w = _modeled_wall(wall, orch.epoch_costs, t)
                cell = {
                    "engine": "event", "fleet": "replicas",
                    "replicas": width, "n_tasks": n,
                    "rate": round(n / 120.0, 2), "threads": t,
                    "harness_wall_s": round(w, 2),
                    "decisions": decisions,
                    "decisions_per_sec": round(decisions / w, 1),
                    "steps": steps,
                    "steps_per_sec": round(steps / w, 1),
                    "finished": a["n_finished"],
                    "rejected": len(router.rejected), "slo": a["slo"],
                }
                rows.append(cell)
                print(f"  event    replicas={width:>4} n={n:>6} t={t}: "
                      f"wall={cell['harness_wall_s']:8.2f}s "
                      f"decisions={decisions:>7} "
                      f"({cell['decisions_per_sec']:>9.1f}/s) "
                      f"finished={cell['finished']:>6}")
            if i == 0:
                # lockstep reference at the smallest size, single-
                # threaded by construction (run_replicas does the same)
                cell = replica_scale_cell("lockstep", width, n)
                rows.append(cell)
                print(f"  lockstep replicas={width:>4} n={n:>6} t=1: "
                      f"wall={cell['harness_wall_s']:8.2f}s "
                      f"decisions={cell['decisions']:>7} "
                      f"({cell['decisions_per_sec']:>9.1f}/s)")

    # bit-exactness at sweep scale: the smallest cell re-run at t=1
    # through run_fleet must reproduce the epoch run's counters
    w0, n0 = parallel_widths[0], replica_sizes[0]
    seq = replica_scale_cell("event", w0, n0, threads=1)
    epoch = next(r for r in rows if r["engine"] == "event"
                 and r["replicas"] == w0 and r["n_tasks"] == n0
                 and r["threads"] == parallel_threads[0])
    same = all(seq[k] == epoch[k] for k in
               ("decisions", "steps", "finished", "rejected", "slo"))
    check(same, f"epoch sweep matches sequential run at {w0}x{n0}")

    # the acceptance curve: >= 1.8x at 4 threads on the widest cell
    wn, nn = parallel_widths[-1], replica_sizes[-1]
    by = {r["threads"]: r for r in rows if r["engine"] == "event"
          and r["replicas"] == wn and r["n_tasks"] == nn}
    speedup = by[1]["harness_wall_s"] / by[4]["harness_wall_s"]
    print(f"  speedup at {wn}x{nn}: t4 = {speedup:.2f}x "
          f"(t8 = {by[1]['harness_wall_s'] / by[8]['harness_wall_s']:.2f}x)")
    check(speedup >= 1.8,
          f"modeled t4 speedup {speedup:.2f}x >= 1.8x at {wn}x{nn}")
    print()
    return rows


# --------------------- stage 14: failure detection & recovery --


CHAOS_VARIANTS = ("crash-oracle", "crash-d2", "crash-d2-noretry",
                  "crash-d8", "crash-d8-noretry",
                  "churn-oracle", "churn-d2", "churn-d2-noretry",
                  "churn-d8", "churn-d8-noretry")
CHAOS_HEARTBEAT_S = 0.5
CHAOS_MAX_RETRIES = 8
CHAOS_RETRY_BACKOFF_S = 2.0
CHAOS_CHURN_RATE = 0.05
CHAOS_CHURN_MIN = 2
CHAOS_CHURN_MAX = 8
CHAOS_WINDOW_S = 120.0
CHAOS_DRAIN_S = 60.0


def _chaos_decode(variant):
    """Mirrors experiments::chaos_sweep::decode."""
    schedule, rest = variant.split("-", 1)
    delay, retries = {
        "oracle": (0.0, CHAOS_MAX_RETRIES), "d2": (2.0, CHAOS_MAX_RETRIES),
        "d2-noretry": (2.0, 0), "d8": (8.0, CHAOS_MAX_RETRIES),
        "d8-noretry": (8.0, 0)}[rest]
    return schedule == "churn", delay, retries


def _chaos_lifecycle(variant):
    """Mirrors experiments::chaos_sweep::lifecycle_for."""
    churn, delay, retries = _chaos_decode(variant)
    lc = LifecycleConfig()
    if churn:
        lc.churn_rate = CHAOS_CHURN_RATE
        lc.min_replicas = CHAOS_CHURN_MIN
        lc.max_replicas = CHAOS_CHURN_MAX
    else:
        lc.events = [LifecycleEvent(secs(40.0), CRASH, 0),
                     LifecycleEvent(secs(80.0), CRASH, 1)]
    lc.detector.enabled = True
    lc.detector.heartbeat_interval = secs(CHAOS_HEARTBEAT_S)
    lc.detector.suspicion_timeout = secs(delay)
    lc.detector.max_retries = retries
    lc.detector.retry_backoff = secs(CHAOS_RETRY_BACKOFF_S)
    return lc


def chaos_cell(variant, n, seed=42):
    """Mirrors experiments::chaos_sweep::run_cell: the scale sweep's
    edge-mixed overload shape (slo-aware routing, admission OFF,
    overload migration, event engine) with the variant's lifecycle +
    detector config attached."""
    _churn, delay, retries = _chaos_decode(variant)
    rate = n / CHAOS_WINDOW_S
    wl = paper_mix(rate, 0.7, n, seed)
    t0 = time.perf_counter()
    tasks, _per, router = run_fleet(
        "slo-aware", edge_mixed(), wl, secs(CHAOS_DRAIN_S),
        migration=True, engine="event", lifecycle=_chaos_lifecycle(variant))
    wall = max(time.perf_counter() - t0, 1e-9)
    a = attainment(tasks)
    shed = (len(router.rejected) + router.rejected_folded
            + sum(r.server.shed for r in router.replicas))
    cell = {
        "variant": variant, "n_tasks": n, "rate": round(rate, 4),
        "detect_delay_s": delay, "max_retries": retries,
        "replicas_final": router.alive_count(),
        "finished": a["n_finished"], "shed": shed,
        "shed_rate": round(shed / n, 4),
        "slo": None if math.isnan(a["slo"]) else a["slo"],
        "crashes": router.crashes, "suspicions": router.suspicions,
        "false_suspicions": router.false_suspicions,
        "detections": router.detections,
        "limbo_recovered": router.limbo_recovered,
        "retries": router.retries,
        "retry_exhausted": router.retry_exhausted,
        "limbo_lost": router.limbo_lost,
        "evac_requeued": router.evac_requeued,
        "evac_restarted": router.evac_restarted,
        "wall_s": round(wall, 2),
    }
    return cell, tasks


def _detector_unit_mirrors():
    mk = lambda: FailureDetector(DetectorConfig(  # noqa: E731
        enabled=True, heartbeat_interval=100, suspicion_timeout=300), 2)

    d = mk()
    ok = True
    for tick in range(1, 11):
        t = tick * 100
        d.emit(0, t, 0)
        ok = ok and d.tick(0, t, False) is None and not d.is_suspected(0)
    check(ok, "on-time heartbeats never suspect")

    d = mk()
    check(d.tick(0, 100, True) is None
          and d.tick(0, 200, True) == SUSPECT
          and d.tick(0, 200, True) is None
          and d.tick(0, 300, True) == CONFIRM,
          "silence suspects (edge, not level), then confirms when dead")

    d = mk()
    d.emit(0, 100, 150)  # overloaded: arrives at 250
    check(d.tick(0, 200, False) == SUSPECT and d.is_suspected(0)
          and d.tick(0, 300, False) == UNSUSPECT and not d.is_suspected(0),
          "late heartbeat is a false suspicion")

    d = mk()
    first = d.tick(0, 200, False) == SUSPECT
    held = d.tick(0, 500, False) is None and d.is_suspected(0)
    d.emit(0, 500, 0)
    check(first and held and d.tick(0, 550, False) == UNSUSPECT,
          "live replica past timeout stays suspected, never confirmed")

    d = mk()
    d.ensure(3, 1_000)
    check(d.tick(2, 1_050, False) is None
          and d.tick(2, 1_200, False) == SUSPECT,
          "joiners start with a fresh synthetic heartbeat")

    d = mk()
    d.emit(0, 100, 300)  # arrives 400
    d.emit(0, 200, 10)  # arrives 210
    check(d.tick(0, 450, True) is None and d.tick(0, 750, True) == CONFIRM,
          "pending fold takes the freshest arrival")


def _detector_counters_zero(router):
    return (router.suspicions + router.false_suspicions + router.detections
            + router.limbo_recovered + router.retries + router.retry_exhausted
            + router.limbo_lost) == 0


def _inert_detector_pairs():
    """The oracle spelling (`enabled`, `suspicion_timeout = 0`) must be
    bit-exact with the detector-free engines across the stage-10 shapes
    at threads 1 and 4 (the Rust equivalence.rs inert-detector gate)."""
    for label, mk, strat, rate, n, seed, kw in _engine_shapes():
        base = {}
        for engine in ("lockstep", "event"):
            wl = paper_mix(rate, 0.7, n, seed)
            base[engine] = run_fleet(strat, mk(), wl, secs(120.0),
                                     engine=engine, **kw)
        for threads in (1, 4):
            lc = LifecycleConfig()
            lc.detector.enabled = True
            lc.detector.suspicion_timeout = 0
            wl = paper_mix(rate, 0.7, n, seed)
            td, pd, rd = run_fleet(strat, mk(), wl, secs(120.0),
                                   engine="event", threads=threads,
                                   lifecycle=lc, **kw)
            ok = _detector_counters_zero(rd)
            for engine in ("lockstep", "event"):
                ta, pa, ra = base[engine]
                ok = (ok and pa == pd and len(ta) == len(td)
                      and all(x.id == y.id and x.first_token == y.first_token
                              and x.completion == y.completion
                              and x.tokens_generated == y.tokens_generated
                              for x, y in zip(ta, td))
                      and ra.migrations == rd.migrations
                      and ra.migrated_running == rd.migrated_running
                      and ra.handoff_bytes == rd.handoff_bytes
                      and ra.handoff_us == rd.handoff_us
                      and [t.id for t in ra.rejected]
                      == [t.id for t in rd.rejected])
            check(ok, f"inert detector == both engines: {label} "
                      f"t{threads} (seed {seed})")


def _inert_oracle_crash_pair():
    """Under a real crash schedule the inert detector must reproduce
    the PR 7 oracle crash handling bit for bit, at threads 1 and 4."""
    def crash_lc(detector):
        lc = LifecycleConfig()
        lc.events = [LifecycleEvent(secs(40.0), CRASH, 0),
                     LifecycleEvent(secs(80.0), CRASH, 1)]
        if detector:
            lc.detector.enabled = True
            lc.detector.suspicion_timeout = 0
        return lc

    adm = AdmissionConfig(enabled=True, mode="headroom")
    wl = paper_mix(6.0, 0.7, 200, 7)
    to, po, ro = run_fleet("slo-aware", edge_mixed(), wl, secs(120.0),
                           admission=adm, migration=True, engine="event",
                           lifecycle=crash_lc(False))
    check(ro.crashes == 2, "oracle crash cell: both scheduled crashes fire")
    for threads in (1, 4):
        wl = paper_mix(6.0, 0.7, 200, 7)
        td, pd, rd = run_fleet("slo-aware", edge_mixed(), wl, secs(120.0),
                               admission=adm, migration=True, engine="event",
                               threads=threads, lifecycle=crash_lc(True))
        ok = (po == pd and len(to) == len(td)
              and all(x.id == y.id and x.first_token == y.first_token
                      and x.completion == y.completion
                      and x.tokens_generated == y.tokens_generated
                      for x, y in zip(to, td))
              and ro.crashes == rd.crashes
              and ro.evac_requeued == rd.evac_requeued
              and ro.evac_restarted == rd.evac_restarted
              and ro.migrations == rd.migrations
              and [t.id for t in ro.rejected] == [t.id for t in rd.rejected]
              and _detector_counters_zero(rd))
        check(ok, f"inert detector reproduces oracle crash handling "
                  f"(t{threads})")


def _coherence_violation(router, max_retries):
    """Mirrors chaos_recovery.rs assert_detector_coherent."""
    r = router
    if r.detections > r.crashes:
        return f"{r.detections} detections but {r.crashes} crashes"
    if r.false_suspicions > r.suspicions:
        return (f"cleared {r.false_suspicions} suspicions, raised "
                f"{r.suspicions}")
    if max_retries > 0:
        if r.retries < r.limbo_recovered:
            return (f"{r.limbo_recovered} recovered but only "
                    f"{r.retries} retry dispatches")
        if r.retry_exhausted > r.retries:
            return (f"{r.retry_exhausted} exhaustions out of "
                    f"{r.retries} dispatches")
    else:
        if r.retries != 0:
            return "retry dispatches at a zero budget"
        if r.retry_exhausted != r.limbo_recovered:
            return "zero budget must shed exactly what it recovers"
    if r.detections == r.crashes and r.limbo_lost > r.limbo_recovered:
        return (f"limbo lost {r.limbo_lost} > recovered "
                f"{r.limbo_recovered} with every corpse confirmed")
    return None


def _chaos_fault_schedules():
    """500 seeded fault schedules with a nonzero detection delay
    (chaos_recovery.rs): churn + heartbeats + suspicion + confirmation
    + retry + horizon flushing, every task accounted exactly once."""
    bad = None
    for seed in range(500):
        lc = LifecycleConfig(churn_rate=1.0, seed=seed, min_replicas=1,
                             max_replicas=5)
        lc.detector.enabled = True
        lc.detector.heartbeat_interval = secs(0.5)
        lc.detector.suspicion_timeout = secs(1.5)
        lc.detector.max_retries = 2
        lc.detector.retry_backoff = secs(0.5)
        wl = paper_mix(2.0, 0.7, 8, seed)
        tasks, _per, router = run_fleet(
            "slo-aware", [DeviceProfile.standard() for _ in range(3)],
            wl, secs(15.0), engine="event", lifecycle=lc)
        if sorted(t.id for t in tasks) != list(range(8)):
            bad = f"seed {seed}: task conservation broken"
            break
        v = _coherence_violation(router, 2)
        if v is not None:
            bad = f"seed {seed}: {v}"
            break
    check(bad is None,
          bad or "500 fault schedules: conserved, counters coherent")


def _live_lag_cell():
    """Detector lag on a live overloaded fleet: suspicion edges may
    flap, but nothing is ever confirmed, limboed or shed."""
    lc = LifecycleConfig()
    lc.detector.enabled = True
    lc.detector.heartbeat_interval = secs(0.5)
    lc.detector.suspicion_timeout = secs(2.0)
    wl = paper_mix(800 / 120.0, 0.7, 800, 42)
    tasks, _per, router = run_fleet(
        "slo-aware", edge_mixed(), wl, secs(60.0), migration=True,
        engine="event", lifecycle=lc)
    _elastic_conservation(tasks, 800, "live-lag cell")
    print(f"  live-lag 800 tasks: susp={router.suspicions}"
          f"({router.false_suspicions} cleared) det={router.detections}")
    check(router.crashes == 0 and router.detections == 0
          and router.limbo_recovered + router.retries
          + router.retry_exhausted + router.limbo_lost == 0
          and router.false_suspicions <= router.suspicions
          and router.alive_count() == len(router.replicas),
          "overload lag alone never confirms a live replica")


def chaos_stage(chaos_sizes):
    print("stage 14: failure detection & recovery (PR 10) — detector "
          "mirrors, inert-detector equivalence, chaos recovery, chaos sweep")

    _detector_unit_mirrors()
    _inert_detector_pairs()
    _inert_oracle_crash_pair()
    _chaos_fault_schedules()
    _live_lag_cell()

    # crash-oracle is the detector-free crash run in disguise: same
    # cell with the detector block absent must match task for task
    cell0, t0_ = chaos_cell("crash-oracle", 1000)
    lc = LifecycleConfig()
    lc.events = [LifecycleEvent(secs(40.0), CRASH, 0),
                 LifecycleEvent(secs(80.0), CRASH, 1)]
    wl = paper_mix(1000 / CHAOS_WINDOW_S, 0.7, 1000, 42)
    tf, _pf, rf = run_fleet(
        "slo-aware", edge_mixed(), wl, secs(CHAOS_DRAIN_S),
        migration=True, engine="event", lifecycle=lc)
    same = ([(t.id, t.first_token, t.completion, t.tokens_generated)
             for t in t0_]
            == [(t.id, t.first_token, t.completion, t.tokens_generated)
                for t in tf]
            and cell0["crashes"] == rf.crashes == 2
            and cell0["evac_requeued"] == rf.evac_requeued
            and cell0["evac_restarted"] == rf.evac_restarted)
    check(same, "crash-oracle cell == detector-free crash run")

    # -- the chaos sweep (BENCH_10 rows) -------------------------------
    rows = []
    for n in chaos_sizes:
        for variant in CHAOS_VARIANTS:
            cell, tasks = chaos_cell(variant, n)
            ids = sorted(t.id for t in tasks)
            if ids != list(range(n)):
                raise SystemExit(
                    f"stage 14: conservation broken at {variant} n={n}")
            v = _coherence_violation_cell(cell)
            if v is not None:
                raise SystemExit(f"stage 14: {variant} n={n}: {v}")
            rows.append(cell)
            print(f"  {variant:<17} n={n:>6}: wall={cell['wall_s']:7.2f}s "
                  f"alive={cell['replicas_final']:>2} "
                  f"finished={cell['finished']:>6} shed={cell['shed']:>5} "
                  f"susp={cell['suspicions']}({cell['false_suspicions']}) "
                  f"det={cell['detections']} limbo={cell['limbo_recovered']} "
                  f"retry={cell['retries']} exh={cell['retry_exhausted']} "
                  f"lost={cell['limbo_lost']}")

    by = {(c["variant"], c["n_tasks"]): c for c in rows}
    for n in chaos_sizes:
        retry, bare = by[("crash-d8", n)], by[("crash-d8-noretry", n)]
        check(retry["crashes"] == 2 and retry["detections"] == 2,
              f"crash-d8 n={n}: both crashes confirmed through the detector")
        check(bare["limbo_recovered"] > 0
              and bare["retry_exhausted"] == bare["limbo_recovered"],
              f"crash-d8-noretry n={n}: detection gap lands dispatches in "
              f"limbo; zero budget sheds them all")
        check(retry["retries"] > 0 and retry["limbo_recovered"] > 0,
              f"crash-d8 n={n}: recovery runs retry dispatches")
        print(f"  retry vs no-retry shed at n={n}: {retry['shed']} vs "
              f"{bare['shed']}")
        check(retry["shed"] < bare["shed"],
              f"crash-d8 n={n}: retry shed {retry['shed']} strictly below "
              f"the no-retry floor {bare['shed']}")
        oracle = by[("crash-oracle", n)]
        check(oracle["suspicions"] == 0 and oracle["detections"] == 0,
              f"crash-oracle n={n}: detector stays inert")
    print()
    return rows


def _coherence_violation_cell(cell):
    """The coherence predicate over a sweep row (dict) instead of a
    live Router."""
    class _R:  # noqa: N801 — ad-hoc attribute bag
        pass
    r = _R()
    for k in ("crashes", "suspicions", "false_suspicions", "detections",
              "limbo_recovered", "retries", "retry_exhausted", "limbo_lost"):
        setattr(r, k, cell[k])
    return _coherence_violation(r, cell["max_retries"])


def main():
    out_path = None
    if "--out" in sys.argv:
        out_path = sys.argv[sys.argv.index("--out") + 1]
    scale_sizes = [1000, 4000, 10000]
    if "--scale-sizes" in sys.argv:
        raw = sys.argv[sys.argv.index("--scale-sizes") + 1]
        scale_sizes = [int(v) for v in raw.split(",") if v]
    replica_widths = [16, 64, 256]
    if "--replica-widths" in sys.argv:
        raw = sys.argv[sys.argv.index("--replica-widths") + 1]
        replica_widths = [int(v) for v in raw.split(",") if v]
    replica_sizes = [10_000, 100_000]
    if "--replica-sizes" in sys.argv:
        raw = sys.argv[sys.argv.index("--replica-sizes") + 1]
        replica_sizes = [int(v) for v in raw.split(",") if v]
    bench6_out = None
    if "--bench6-out" in sys.argv:
        bench6_out = sys.argv[sys.argv.index("--bench6-out") + 1]
    elastic_sizes = [1000, 10_000]
    if "--elastic-sizes" in sys.argv:
        raw = sys.argv[sys.argv.index("--elastic-sizes") + 1]
        elastic_sizes = [int(v) for v in raw.split(",") if v]
    bench7_out = None
    if "--bench7-out" in sys.argv:
        bench7_out = sys.argv[sys.argv.index("--bench7-out") + 1]
    stream_sizes = [10_000, 1_000_000]
    if "--stream-sizes" in sys.argv:
        raw = sys.argv[sys.argv.index("--stream-sizes") + 1]
        stream_sizes = [int(v) for v in raw.split(",") if v]
    bench8_out = None
    if "--bench8-out" in sys.argv:
        bench8_out = sys.argv[sys.argv.index("--bench8-out") + 1]
    parallel_widths = [64, 256]
    if "--parallel-widths" in sys.argv:
        raw = sys.argv[sys.argv.index("--parallel-widths") + 1]
        parallel_widths = [int(v) for v in raw.split(",") if v]
    parallel_threads = list(PARALLEL_THREADS)
    if "--parallel-threads" in sys.argv:
        raw = sys.argv[sys.argv.index("--parallel-threads") + 1]
        parallel_threads = [int(v) for v in raw.split(",") if v]
    bench9_out = None
    if "--bench9-out" in sys.argv:
        bench9_out = sys.argv[sys.argv.index("--bench9-out") + 1]
    chaos_sizes = [1000, 10_000]
    if "--chaos-sizes" in sys.argv:
        raw = sys.argv[sys.argv.index("--chaos-sizes") + 1]
        chaos_sizes = [int(v) for v in raw.split(",") if v]
    bench10_out = None
    if "--bench10-out" in sys.argv:
        bench10_out = sys.argv[sys.argv.index("--bench10-out") + 1]

    if "--stage14" in sys.argv:
        # iterate on the failure detector without stages 1-13
        rows = chaos_stage(chaos_sizes)
        if bench10_out:
            _write_bench10(bench10_out, rows)
        return
    if "--stage13" in sys.argv:
        # iterate on the parallel event engine without stages 1-12
        rows = parallel_engine_stage(parallel_widths, replica_sizes,
                                     parallel_threads)
        if bench9_out:
            _write_bench9(bench9_out, rows)
        return
    if "--stage12" in sys.argv:
        # iterate on the O(changes) control plane without stages 1-11
        rows = o_changes_stage(stream_sizes)
        if bench8_out:
            _write_bench8(bench8_out, rows)
        return
    if "--stage10" in sys.argv:
        # iterate on the event engine without re-running stages 1-9
        sweep = event_engine_stage(replica_widths, replica_sizes)
        if bench6_out:
            _write_bench6(bench6_out, sweep)
        return
    if "--stage11" in sys.argv:
        # iterate on the elastic machinery without re-running stages 1-10
        rows = elastic_stage(elastic_sizes)
        if bench7_out:
            _write_bench7(bench7_out, rows)
        return

    self_check()

    print("stage 2: fig1 (calibrated latency model)")
    fig1 = fig1_table()
    for r in fig1:
        print(f"  b={r['batch']:>2}  l={r['latency_ms']:7.2f}ms  "
              f"tp={r['throughput_tps']:7.2f} tok/s  per-task={r['per_task_tps']:5.2f}")
    print()

    print("stage 3: cluster_sweep (SLICE policy, per-replica rate 1.0, "
          "RT:NRT 7:3, 200 tasks/replica, seed 42)")
    sweep = []
    for n in (1, 2, 4):
        for strat in ("round-robin", "least-loaded", "slo-aware"):
            cell = cluster_cell(strat, n, 1.0, 0.7, 200, 42)
            sweep.append(cell)
            print(f"  replicas={n} {strat:<13} slo={cell['slo']:.4f} "
                  f"rt={cell['rt_slo']:.4f} nrt={cell['nrt_slo']:.4f} "
                  f"ttft_p99={cell['ttft_p99_ms']:.1f}ms "
                  f"tpot_p99={cell['tpot_p99_ms']:.1f}ms routed={cell['routed']} "
                  f"({cell['harness_wall_s']}s)")
    print()

    print("stage 4: rust integration-test cells (threshold validation)")
    cells = {}
    # slo_aware_routing_at_least_round_robin: rate 4.0, 480 tasks, seed 42, 4 reps
    for strat in ("round-robin", "slo-aware"):
        wl = paper_mix(4.0, 0.7, 480, 42)
        tasks, _ = run_cluster(strat, 4, wl, secs(120.0))
        cells[f"test_{strat}"] = attainment(tasks)
        a = cells[f"test_{strat}"]
        print(f"  test cell {strat:<13} slo={a['slo']:.4f} rt={a['rt_slo']:.4f}")
    # more_replicas_do_not_hurt: rate 3.0, 240 tasks, seed 21, slo-aware 1 vs 4
    for n in (1, 4):
        wl = paper_mix(3.0, 0.7, 240, 21)
        tasks, _ = run_cluster("slo-aware", n, wl, secs(120.0))
        cells[f"mono_{n}"] = attainment(tasks)
        a = cells[f"mono_{n}"]
        print(f"  monotonicity n={n} slo={a['slo']:.4f} finished={a['n_finished']}")
    # cluster_sweep unit test cfg: n_tasks=120, rate 1.0, seed 42, width 4
    for strat in ("round-robin", "slo-aware"):
        wl = paper_mix(1.0 * 4, 0.7, 120 * 4, 42)  # 4 replicas, 120 tasks each
        tasks, _ = run_cluster(strat, 4, wl, secs(120.0))
        cells[f"unit_{strat}"] = attainment(tasks)
        a = cells[f"unit_{strat}"]
        print(f"  unit cell {strat:<13} slo={a['slo']:.4f} rt={a['rt_slo']:.4f}")
    print()

    hetero, hetero_cells = hetero_sweep()
    memory = memory_sweep()
    hot_path = hot_path_stage(scale_sizes)
    replica_sweep = event_engine_stage(replica_widths, replica_sizes)
    elastic_rows = elastic_stage(elastic_sizes)
    stream_rows = o_changes_stage(stream_sizes)
    parallel_rows = parallel_engine_stage(parallel_widths, replica_sizes,
                                          parallel_threads)
    chaos_rows = chaos_stage(chaos_sizes)

    doc = {"fig1": fig1, "cluster_sweep": sweep, "validation_cells": cells,
           "hetero_sweep": hetero, "hetero_validation_cells": hetero_cells,
           "memory_sweep": memory, "scheduler_hot_path": hot_path,
           "replica_sweep": replica_sweep, "elastic_sweep": elastic_rows,
           "stream_sweep": stream_rows, "parallel_sweep": parallel_rows,
           "chaos_sweep": chaos_rows}
    if out_path:
        Path(out_path).write_text(json.dumps(doc, indent=2))
        print(f"wrote {out_path}")
    if bench6_out:
        _write_bench6(bench6_out, replica_sweep)
    if bench7_out:
        _write_bench7(bench7_out, elastic_rows)
    if bench8_out:
        _write_bench8(bench8_out, stream_rows)
    if bench9_out:
        _write_bench9(bench9_out, parallel_rows)
    if bench10_out:
        _write_bench10(bench10_out, chaos_rows)


def _write_bench6(path, sweep):
    doc = {
        "schema": "slice-serve-bench/v6",
        "source": ("tools/pysim/run_experiments.py stage 10 — the bit-exact "
                   "Python mirror (no Rust toolchain in the build env); "
                   "reproduce natively with `slice-serve experiment scale "
                   "--replicas 16,64,256`"),
        "workload": ("paper_mix, rate = n_tasks/120 s across the fleet, "
                     "RT:NRT 7:3, seed 42; round-robin homogeneous standard "
                     "fleet, SLICE policy, guards off, 60 s drain"),
        "note": ("event cells at every size; lockstep reference cells at the "
                 "smallest size only (the lockstep engine is the in-tree "
                 "equivalence reference, not the scale path)"),
        "replica_sweep": sweep,
    }
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"wrote {path}")


def _write_bench8(path, rows):
    doc = {
        "schema": "slice-serve-bench/v8",
        "source": ("tools/pysim/run_experiments.py stage 12 — the bit-exact "
                   "Python mirror (no Rust toolchain in the build env); "
                   "reproduce natively with `slice-serve experiment scale "
                   "--stream` (streaming cells) and `slice-serve experiment "
                   "scale` (materialized edge-mixed cells)"),
        "workload": ("paper_mix, rate = n_tasks/120 s, RT:NRT 7:3, seed 42; "
                     "edge-mixed fleet, SLICE policy, slo-aware routing + "
                     "headroom admission + overload migration, 60 s drain; "
                     "edge-stream cells pull the seeded generator lazily "
                     "with shed arrivals folded into a counter"),
        "note": ("edge-mixed = event engine with the O(changes) control "
                 "plane on (the default); edge-mixed-noskip = the "
                 "always-rebuild reference (scheduler.incremental = false); "
                 "the lockstep cell is the per-boundary migration reference. "
                 "decisions + decisions_skipped equals the noskip decision "
                 "count; full_rebuilds counts full select_tasks passes "
                 "(everything else is served from the cached candidate "
                 "set); migration_passes is O(overload episodes) on the "
                 "event engine vs O(arrivals) on lockstep"),
        "gate": ("stage 12 asserts: <= 70% of the no-skip full passes at "
                 "the 10k edge-mixed cell, identical migrated-task sets "
                 "across engines over 4 seeds, and bounded-memory streaming "
                 "cells bit-exact with materialized event runs"),
        "stream_sweep": rows,
    }
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"wrote {path}")


def _write_bench9(path, rows):
    doc = {
        "schema": "slice-serve-bench/v9",
        "source": ("tools/pysim/run_experiments.py stage 13 — the bit-exact "
                   "Python mirror (no Rust toolchain in the build env); "
                   "reproduce natively with `slice-serve experiment scale "
                   "--replicas 64,256 --threads 1,2,4,8`"),
        "workload": ("paper_mix, rate = n_tasks/120 s across the fleet, "
                     "RT:NRT 7:3, seed 42; round-robin homogeneous standard "
                     "fleet, SLICE policy, guards off, event engine, 60 s "
                     "drain"),
        "note": ("reports are bit-exact across thread counts — only wall "
                 "time moves between rows of the same (replicas, n_tasks). "
                 "Wall times at threads > 1 come from the epoch cost "
                 "model: measured per-replica advancement costs combined "
                 "as sum-over-epochs of the slowest ceil(batch/N) worker "
                 "chunk, plus the measured sequential remainder (the "
                 "Python mirror cannot run real threads under the GIL). "
                 "CI's bench-regression gate replays the 64x10k "
                 "--threads 4 cell natively every push; lockstep "
                 "reference cells run at the smallest size, threads = 1"),
        "gate": ("the acceptance curve is >= 1.8x modeled speedup at "
                 "--threads 4 on the widest cell (asserted by stage 13); "
                 "CI fails if the native 64x10k t4 cell drops below 75% "
                 "of the committed decisions_per_sec"),
        "replica_sweep": rows,
    }
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"wrote {path}")


def _write_bench7(path, rows):
    doc = {
        "schema": "slice-serve-bench/v7",
        "source": ("tools/pysim/run_experiments.py stage 11 — the bit-exact "
                   "Python mirror (no Rust toolchain in the build env); "
                   "reproduce natively with `slice-serve experiment elastic`"),
        "workload": ("paper_mix, rate = n_tasks/120 s, RT:NRT 7:3, seed 42; "
                     "edge-mixed fleet, SLICE policy, slo-aware routing + "
                     "headroom admission + overload migration, event engine, "
                     "60 s drain"),
        "variants": ("static = PR 6 baseline; crash = replicas 0/1 die at "
                     "40 s/80 s; autoscale = grow on sustained admission "
                     "deficit up to 64 replicas, shrink on sustained idle "
                     "(never below the starting 4); autoscale-headroom = "
                     "same bounds, grow when mean Eq. 7 headroom across "
                     "the placeable fleet sinks to 50 ms (proactive vs "
                     "the reactive deficit signal); autoscale+crash = "
                     "deficit autoscaler + both crashes"),
        "gate": ("at the largest size the autoscale variant must shed "
                 "strictly fewer tasks than static (asserted by stage 11)"),
        "elastic_sweep": rows,
    }
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"wrote {path}")


def _write_bench10(path, rows):
    doc = {
        "schema": "slice-serve-bench/v10",
        "source": ("tools/pysim/run_experiments.py stage 14 — the bit-exact "
                   "Python mirror (no Rust toolchain in the build env); "
                   "reproduce natively with `slice-serve experiment chaos`"),
        "workload": ("paper_mix, rate = n_tasks/120 s, RT:NRT 7:3, seed 42; "
                     "edge-mixed fleet, SLICE policy, slo-aware routing, "
                     "admission OFF (so the recovery paths are the only "
                     "shed source), overload migration, event engine, 60 s "
                     "drain; heartbeat 0.5 s, retry backoff 2 s doubling "
                     "per attempt, retry budget 8 (0 on -noretry variants)"),
        "variants": ("crash-* = the elastic sweep's deterministic schedule "
                     "(replicas 0/1 die at 40 s/80 s); churn-* = seeded "
                     "random churn at 0.05 events/s, fleet bounded 2..8; "
                     "-oracle = suspicion_timeout 0 (detector inert, "
                     "crashes oracle-visible, the PR 7 baseline); -d2/-d8 "
                     "= 2 s / 8 s detection delay — dispatches into the "
                     "gap land in limbo and come back through retry"),
        "gate": ("stage 14 asserts: both crashes confirmed on crash-d* "
                 "cells, limbo recovery fires at 8 s delay, and the "
                 "retrying variant sheds strictly less than its no-retry "
                 "twin at every size; CI replays the crash-d8 1000-task "
                 "cell natively and requires exact counter equality with "
                 "the committed row"),
        "chaos_sweep": rows,
    }
    Path(path).write_text(json.dumps(doc, indent=2))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
