"""Bit-exact Python mirror of the slice-serve deterministic simulator.

Purpose: produce *measured* experiment numbers in environments without a
Rust toolchain (EXPERIMENTS.md records which harness produced each
table). Every algorithm here mirrors the Rust source line by line:

  Rng               <- rust/src/util/rng.rs        (xoshiro256++ / SplitMix64)
  LatencyModel      <- rust/src/engine/latency.rs  (piecewise-linear l(b))
  Task / SloSpec    <- rust/src/coordinator/task.rs
  select_tasks      <- rust/src/coordinator/selection.rs (Alg. 2)
  DecodeMask        <- rust/src/coordinator/mask.rs      (Alg. 3)
  SlicePolicy       <- rust/src/coordinator/slice.rs     (Alg. 1/4)
  OrcaPolicy        <- rust/src/coordinator/orca.rs
  Server            <- rust/src/server.rs (run / run_until / withdraw / finish)
  DeviceProfile     <- rust/src/cluster/fleet.rs (tiers, admission bounds)
  Replica / Router  <- rust/src/cluster/*.rs (staging, admission, migration,
                                              running-task KV handoff)
  Orchestrator      <- rust/src/cluster/orchestrator.rs (event-driven engine:
                                              heap-scheduled replica wakes)
  MemoryConfig etc. <- rust/src/engine/memory.rs (KV model, swap/recompute)
  Attainment etc.   <- rust/src/metrics/mod.rs
  WorkloadSpec      <- rust/src/workload/mod.rs

All scheduler/clock arithmetic is integer microseconds, so results are
reproducible bit-for-bit; the only float ops (Poisson inter-arrivals,
utility rates) use IEEE-754 doubles exactly as the Rust code does (the
single `log` call may differ from Rust's `ln` by 1 ulp on exotic libms,
which can shift an arrival timestamp by at most 1 µs).
"""

from __future__ import annotations

import heapq
import math
import time as _time
from bisect import bisect_left, insort
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional, Tuple

MASK64 = (1 << 64) - 1
CYCLE_CAP = 1_000_000

# ---------------------------------------------------------------- rng ----


class Rng:
    """xoshiro256++ seeded via SplitMix64 (util/rng.rs)."""

    def __init__(self, seed: int) -> None:
        s = []
        sm = seed & MASK64
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self) -> int:
        s = self.s
        x = (s[0] + s[3]) & MASK64
        result = (((x << 23) | (x >> 41)) + s[0]) & MASK64
        t = (s[1] << 17) & MASK64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & MASK64
        return result

    def f64(self) -> float:
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def range_u64(self, lo: int, hi: int) -> int:
        assert lo <= hi
        span = hi - lo + 1
        zone = MASK64 - (MASK64 % span)
        while True:
            v = self.next_u64()
            if v < zone:
                return lo + v % span

    def chance(self, p: float) -> bool:
        return self.f64() < p

    def exponential(self, lam: float) -> float:
        assert lam > 0.0
        u = self.f64()
        if u <= 0.0:
            u = 2.2250738585072014e-308  # f64::MIN_POSITIVE
        return -math.log(1.0 - u) / lam

    def weighted_index(self, weights: List[float]) -> int:
        total = sum(weights)
        assert total > 0.0
        x = self.f64() * total
        for i, w in enumerate(weights):
            if x < w:
                return i
            x -= w
        return len(weights) - 1


def rust_round(x: float) -> int:
    """f64::round — half away from zero (positive inputs only here)."""
    return int(math.floor(x + 0.5))


def ms(v: float) -> int:
    return rust_round(v * 1_000.0)


def secs(v: float) -> int:
    return rust_round(v * 1_000_000.0)


# ------------------------------------------------------- latency model ----


class LatencyModel:
    def __init__(self, points, prefill_points, max_batch) -> None:
        self.points = points
        self.prefill_points = prefill_points
        self.max_batch = max_batch
        self._decode_cache = {}

    @staticmethod
    def paper_calibrated() -> "LatencyModel":
        return LatencyModel(
            [(1, ms(18.0)), (2, ms(28.0)), (3, ms(40.0)), (4, ms(52.0)),
             (5, ms(64.0)), (6, ms(75.0)), (7, ms(85.0)), (8, ms(95.0)),
             (9, ms(128.59)), (12, ms(131.0)), (16, ms(134.0)),
             (24, ms(139.0)), (32, ms(145.0))],
            [(16, ms(30.0)), (32, ms(45.0)), (64, ms(75.0))],
            32,
        )

    @staticmethod
    def _interp(points, x: int) -> int:
        x0, y0 = points[0]
        if x <= x0:
            return y0
        for (xa, ya), (xb, yb) in zip(points, points[1:]):
            if x <= xb:
                frac = (x - xa) / (xb - xa)
                return rust_round(ya + frac * (yb - ya))
        return points[-1][1]

    def scaled(self, factor: float) -> "LatencyModel":
        assert factor > 0.0
        return LatencyModel(
            [(b, rust_round(us * factor)) for b, us in self.points],
            [(b, rust_round(us * factor)) for b, us in self.prefill_points],
            self.max_batch,
        )

    def decode(self, b: int) -> int:
        v = self._decode_cache.get(b)
        if v is None:
            v = self._interp(self.points, b)
            self._decode_cache[b] = v
        return v

    def prefill(self, length: int) -> int:
        if not self.prefill_points:
            return 0
        return self._interp(self.prefill_points, length)

    def throughput(self, b: int) -> float:
        if b == 0:
            return 0.0
        return b / (self.decode(b) / 1e6)


# ----------------------------------------------------------- SLO model ----

RT, VOICE, TEXTQA = "real-time", "voice", "text-qa"


@dataclass
class SloSpec:
    ttft: int
    tpot: int
    deadline: Optional[int]

    @staticmethod
    def for_class(cls: str) -> "SloSpec":
        if cls == RT:
            return SloSpec(500_000, 50_000, 1_500_000)
        if cls == VOICE:
            return SloSpec(1_000_000, 125_000, None)
        return SloSpec(1_000_000, 100_000, None)

    def tokens_per_cycle(self) -> int:
        return math.ceil(1e6 / self.tpot)


WAITING, ADMITTED, RUNNING, PAUSED, FINISHED = range(5)

# Residency (task.rs Residency)
RES_NONE, RES_RESIDENT, RES_SWAPPED = range(3)


@dataclass
class Task:
    id: int
    cls: str
    arrival: int
    prompt_len: int
    output_len: int
    utility: float
    slo: SloSpec = field(default=None)  # type: ignore[assignment]
    state: int = WAITING
    prefill_end: Optional[int] = None
    first_token: Optional[int] = None
    last_token: Optional[int] = None
    completion: Optional[int] = None
    tokens_generated: int = 0
    max_token_gap: int = 0
    residency: int = RES_NONE
    pending_restore: int = 0
    swap_outs: int = 0
    swap_ins: int = 0
    migrated_away: bool = False
    # set when the server shed the task mid-run (footprint can never
    # fit the device KV capacity): terminal, unserved, SLO-violated
    shed: bool = False

    def __post_init__(self) -> None:
        if self.slo is None:
            self.slo = SloSpec.for_class(self.cls)

    def is_real_time(self) -> bool:
        return self.cls == RT

    def on_token(self, now: int) -> None:
        if self.first_token is None:
            self.first_token = now
        elif self.last_token is not None:
            gap = now - self.last_token
            if gap > self.max_token_gap:
                self.max_token_gap = gap
        self.last_token = now
        self.tokens_generated += 1
        if self.tokens_generated >= self.output_len:
            self.state = FINISHED
            self.completion = now

    def is_finished(self) -> bool:
        return self.state == FINISHED

    def ttft(self) -> Optional[int]:
        return None if self.first_token is None else self.first_token - self.arrival

    def avg_tpot(self) -> Optional[int]:
        if self.first_token is None or self.last_token is None:
            return None
        if self.tokens_generated >= 2:
            return (self.last_token - self.first_token) // (self.tokens_generated - 1)
        return 0

    def completion_time(self) -> Optional[int]:
        return None if self.completion is None else self.completion - self.arrival

    def slo_met(self) -> bool:
        if self.shed or not self.is_finished():
            return False
        if self.slo.deadline is not None:
            c = self.completion_time()
            return c is not None and c <= self.slo.deadline
        return self.ttft_met() and self.tpot_met()

    def ttft_met(self) -> bool:
        t = self.ttft()
        return t is not None and t <= self.slo.ttft

    def tpot_met(self) -> bool:
        t = self.avg_tpot()
        return t is not None and t <= self.slo.tpot

    def remaining_tokens(self) -> int:
        return max(0, self.output_len - self.tokens_generated)

    def seq_len(self) -> int:
        return self.prompt_len + self.tokens_generated


# -------------------------------------------------------- memory model ----


@dataclass
class MemoryConfig:
    """Mirrors engine/memory.rs MemoryConfig."""

    kv_capacity: Optional[int] = None  # standard-tier bytes; None = unlimited
    bytes_per_token: int = 32 * 1024
    block_tokens: int = 16
    swap_bandwidth: int = 64_000_000  # eMMC-class storage swap
    handoff_bandwidth: int = 125_000_000  # 1 Gbit/s edge link
    mode: str = "swap"  # "swap" | "recompute"
    aware: bool = True

    def bytes_for(self, tokens: int) -> int:
        block = max(1, self.block_tokens)
        blocks = -(-tokens // block)
        return blocks * block * self.bytes_per_token

    @staticmethod
    def transfer_cost(nbytes: int, bandwidth: int) -> int:
        if bandwidth == 0:
            return 0
        return -(-(nbytes * 1_000_000) // bandwidth)

    def handoff_cost(self, tokens: int) -> int:
        return self.transfer_cost(self.bytes_for(tokens), self.handoff_bandwidth)

    def constrained(self) -> bool:
        return self.kv_capacity is not None

    def footprint_bytes(self, seq_len: int) -> int:
        """Current-footprint budget term (slice.rs MemoryBudget)."""
        return self.bytes_for(seq_len + 1)


class KvCacheModel:
    """Mirrors engine/memory.rs KvCacheModel (slots keyed by local id)."""

    def __init__(self, cfg: MemoryConfig, capacity: Optional[int],
                 recompute_curve: LatencyModel) -> None:
        self.cfg = cfg
        self.capacity = capacity
        self.curve = recompute_curve
        self.slots = {}  # local id -> [tokens, resident]
        self.occupied = 0
        self.peak = 0
        self.swap_outs_n = 0
        self.swap_ins_n = 0
        self.recomputes_n = 0
        self.handoff_restores_n = 0
        self.swap_delay = 0

    def constrained(self) -> bool:
        return self.capacity is not None

    def bytes_for(self, tokens: int) -> int:
        return self.cfg.bytes_for(tokens)

    def _bump(self) -> None:
        if self.occupied > self.peak:
            self.peak = self.occupied

    def is_resident(self, tid: int) -> bool:
        s = self.slots.get(tid)
        return s is not None and s[1]

    def insert(self, tid: int, tokens: int) -> None:
        assert tid not in self.slots
        self.occupied += self.bytes_for(tokens)
        self.slots[tid] = [tokens, True]
        self._bump()

    def note_token(self, tid: int) -> None:
        s = self.slots.get(tid)
        if s is None or not s[1]:
            return
        before = s[0]
        s[0] = before + 1
        grow = self.bytes_for(before + 1) - self.bytes_for(before)
        if grow > 0:
            self.occupied += grow
            self._bump()

    def release(self, tid: int) -> None:
        s = self.slots.pop(tid, None)
        if s is not None and s[1]:
            self.occupied -= self.bytes_for(s[0])

    def swap_out(self, tid: int) -> int:
        s = self.slots.get(tid)
        if s is None or not s[1]:
            return 0
        s[1] = False
        nbytes = self.bytes_for(s[0])
        self.occupied -= nbytes
        self.swap_outs_n += 1
        cost = (MemoryConfig.transfer_cost(nbytes, self.cfg.swap_bandwidth)
                if self.cfg.mode == "swap" else 0)
        self.swap_delay += cost
        return cost

    def restore(self, tid: int, tokens: int, pending: int) -> int:
        if self.is_resident(tid):
            return 0
        nbytes = self.bytes_for(tokens)
        self.occupied += nbytes
        self.slots[tid] = [tokens, True]
        self._bump()
        if pending > 0:
            self.handoff_restores_n += 1
            cost = pending
        elif self.cfg.mode == "swap":
            self.swap_ins_n += 1
            cost = MemoryConfig.transfer_cost(nbytes, self.cfg.swap_bandwidth)
        else:
            self.recomputes_n += 1
            cost = self.curve.prefill(tokens)
        self.swap_delay += cost
        return cost

    def resident_outside(self, protected) -> int:
        prot = set(protected)
        return sum(self.bytes_for(s[0]) for tid, s in self.slots.items()
                   if s[1] and tid not in prot)

    def stats(self) -> dict:
        return {
            "peak_kv_bytes": self.peak,
            "swap_outs": self.swap_outs_n,
            "swap_ins": self.swap_ins_n,
            "recomputes": self.recomputes_n,
            "handoff_restores": self.handoff_restores_n,
            "swap_delay_us": self.swap_delay,
        }


# ------------------------------------------------------------ workload ----

PROFILES = {
    RT: (100.0, (8, 24), (6, 14)),
    VOICE: (1.0, (8, 32), (150, 350)),
    TEXTQA: (2.0, (16, 48), (150, 350)),
}


def paper_mix_stream(arrival_rate: float, rt_ratio: float, n_tasks: int,
                     seed: int):
    """Mirrors workload::ArrivalStream (PR 8): the exact draw sequence of
    paper_mix, yielded one task at a time so million-task traces never
    materialize — `list(paper_mix_stream(...)) == paper_mix(...)` is
    asserted by run_experiments.py stage 12."""
    nrt = max(1.0 - rt_ratio, 0.0)
    mix = [(RT, rt_ratio), (VOICE, nrt / 2.0), (TEXTQA, nrt / 2.0)]
    rng = Rng(seed)
    weights = [w for _, w in mix]
    t = 0.0
    for tid in range(n_tasks):
        if tid > 0:
            t += rng.exponential(arrival_rate)
        cls = mix[rng.weighted_index(weights)][0]
        utility, prange, orange = PROFILES[cls]
        prompt_len = rng.range_u64(prange[0], prange[1])
        output_len = rng.range_u64(orange[0], orange[1])
        yield Task(tid, cls, secs(t), prompt_len, output_len, utility)


def paper_mix(arrival_rate: float, rt_ratio: float, n_tasks: int, seed: int):
    return list(paper_mix_stream(arrival_rate, rt_ratio, n_tasks, seed))


# ----------------------------------------------------------- selection ----


def period_eq7(vs_sorted_desc: List[int], lat: LatencyModel) -> int:
    n = len(vs_sorted_desc)
    if n == 0:
        return 0
    t = vs_sorted_desc[-1] * lat.decode(n)
    for j in range(n - 1):
        t += (vs_sorted_desc[j] - vs_sorted_desc[j + 1]) * lat.decode(j + 1)
    return t


def quota_of(tpot: int) -> int:
    return math.ceil(1e6 / tpot)


def select_tasks(candidates, lat: LatencyModel, cycle_cap: int,
                 kv_capacity: Optional[int] = None):
    """candidates: list of (id, utility, tpot[, kv_bytes]). Mirrors
    Alg. 2 plus the optional KV knapsack dimension."""
    order = sorted(candidates, key=lambda c: (-(c[1] * (c[2] / 1e6)), c[0]))
    selected: List[Tuple[int, int]] = []
    quotas_desc: List[int] = []
    rejected: List[int] = []
    kv_used = 0
    stopped = False
    for cand in order:
        cid, _u, tpot = cand[0], cand[1], cand[2]
        kv_bytes = cand[3] if len(cand) > 3 else 0
        if stopped or len(selected) >= lat.max_batch:
            rejected.append(cid)
            continue
        if kv_capacity is not None and kv_used + kv_bytes > kv_capacity:
            rejected.append(cid)
            stopped = True
            continue
        q = quota_of(tpot)
        # partition_point(|v| v >= q) on a descending list
        pos = bisect_left([-v for v in quotas_desc], -q)
        quotas_desc.insert(pos, q)
        p = period_eq7(quotas_desc, lat)
        if p >= cycle_cap:
            quotas_desc.pop(pos)
            rejected.append(cid)
            stopped = True
            continue
        kv_used += kv_bytes
        selected.append((cid, q))
    return selected, rejected


class IncrementalPeriod:
    """Mirrors coordinator/mask.rs IncrementalPeriod (PR 5): the Eq. 7
    cycle duration maintained as a column sum against the Δl curve —
    inserting quota q touches columns 0..q instead of re-running the
    O(n) closed form."""

    def __init__(self, lat: LatencyModel) -> None:
        self.lat = lat
        self.delta: List[int] = []
        self.cols: List[int] = []
        self.n = 0
        self.period = 0

    def clear(self) -> None:
        self.cols.clear()
        self.n = 0
        self.period = 0

    def _delta(self, b: int) -> int:
        while len(self.delta) < b:
            nx = len(self.delta) + 1
            hi = self.lat.decode(nx)
            lo = self.lat.decode(nx - 1) if nx > 1 else 0
            self.delta.append(hi - lo)
        return self.delta[b - 1]

    def probe(self, q: int) -> int:
        """Period after inserting q, without mutating (mirrors
        IncrementalPeriod::probe): empty tail columns priced in closed
        form so a pathological quota never materializes q counters."""
        assert q > 0
        deepest = (self.cols[0] + 1) if self.cols else 1
        self._delta(deepest)
        moved = 0
        for col in self.cols[: min(q, len(self.cols))]:
            moved += self.delta[col]
        if q > len(self.cols):
            moved += (q - len(self.cols)) * self.delta[0]
        return self.period + moved

    def insert(self, q: int) -> int:
        assert q > 0
        if len(self.cols) < q:
            self.cols.extend([0] * (q - len(self.cols)))
        cols = self.cols
        for j in range(q):
            cols[j] += 1
            self.period += self._delta(cols[j])
        self.n += 1
        return self.period

    def remove(self, q: int) -> None:
        assert 0 < q <= len(self.cols), "removing a quota never inserted"
        cols = self.cols
        for j in range(q):
            assert cols[j] > 0, "removing a quota never inserted"
            self.period -= self._delta(cols[j])
            cols[j] -= 1
        self.n -= 1


def select_tasks_fast(candidates, lat: LatencyModel, cycle_cap: int,
                      kv_capacity: Optional[int] = None,
                      period: Optional[IncrementalPeriod] = None):
    """Mirrors the PR 5 selection hot path (selection.rs
    select_tasks_with): rates/quotas precomputed once per candidate
    before the sort, Eq. 7 evaluated incrementally. Bit-identical to
    select_tasks (asserted in run_experiments.py stage 9)."""
    keys = []
    quotas = []
    for idx, c in enumerate(candidates):
        rate = c[1] * (c[2] / 1e6)
        keys.append((-rate, c[0], idx))
        quotas.append(quota_of(c[2]))
    keys.sort()

    inc = period if period is not None else IncrementalPeriod(lat)
    inc.clear()
    selected: List[Tuple[int, int]] = []
    rejected: List[int] = []
    kv_used = 0
    stopped = False
    for _, cid, idx in keys:
        if stopped or len(selected) >= lat.max_batch:
            rejected.append(cid)
            continue
        cand = candidates[idx]
        kv_bytes = cand[3] if len(cand) > 3 else 0
        if kv_capacity is not None and kv_used + kv_bytes > kv_capacity:
            rejected.append(cid)
            stopped = True
            continue
        q = quotas[idx]
        # probe-then-commit (mirrors select_tasks_with): a rejected
        # admission never mutates the structure
        p = inc.probe(q)
        if p >= cycle_cap:
            rejected.append(cid)
            stopped = True
            continue
        committed = inc.insert(q)
        assert committed == p, "probe and insert must agree"
        kv_used += kv_bytes
        selected.append((cid, q))
    return selected, rejected


class DecodeMask:
    def __init__(self, tasks: List[Tuple[int, int]]) -> None:
        assert all(v > 0 for _, v in tasks)
        rows = sorted(tasks, key=lambda r: (-r[1], r[0]))
        self.rows = rows
        self.columns = rows[0][1] if rows else 0
        self.batch_lens = []
        for j in range(self.columns):
            n = 0
            for _, v in rows:
                if v > j:
                    n += 1
                else:
                    break
            self.batch_lens.append(n)

    def is_empty(self) -> bool:
        return not self.rows

    def column_batch(self, j: int) -> List[Tuple[int, int]]:
        return self.rows[: self.batch_lens[j]]


# -------------------------------------------------------------- policies --


class SlicePolicy:
    name = "SLICE"

    def __init__(self, lat: LatencyModel, cycle_cap: int = CYCLE_CAP,
                 memory: Optional[MemoryConfig] = None,
                 kv_capacity: Optional[int] = None,
                 incremental: bool = True) -> None:
        self.lat = lat
        self.cycle_cap = cycle_cap
        # memory-aware selection only when constrained AND aware
        self.memory = memory if (memory is not None and memory.aware
                                 and kv_capacity is not None) else None
        self.kv_capacity = kv_capacity if self.memory is not None else None
        self.mask: Optional[DecodeMask] = None
        self.col = 0
        self.to_prefill: deque = deque()
        self.needs_reschedule = False
        self.reschedules = 0
        # PR 5 mirror: the policy owns its incremental-period scratch
        # and reschedules through the fast selection (bit-identical to
        # select_tasks — asserted in run_experiments.py stage 9, and by
        # stages 1-8 reproducing every earlier PR's cells unchanged)
        self._inc = IncrementalPeriod(lat)
        # PR 8 mirror (slice.rs "Control-plane incrementality"): in the
        # immutable-key regime — no memory dimension; the mirror has no
        # utility adaptor or prefill-aware extension — the sorted
        # candidate cache lives across decisions and arrival boundaries
        # past the admission threshold skip the reschedule outright.
        # Ascending (-rate, id) reproduces the Rust packed-key order
        # exactly: rates are the same IEEE doubles on both sides, and
        # -0.0 collides with 0.0 under tuple comparison just as
        # rate_key_desc normalises it.
        self.incremental = incremental
        self.immutable = incremental and self.memory is None
        self.sorted: List[Tuple[float, int, int]] = []  # (-rate, id, quota)
        self.generation = 0
        self.cache_generation = 0
        self.threshold: Optional[Tuple[float, int]] = None
        self.decisions_skipped = 0
        self.full_rebuilds = 0

    @staticmethod
    def _entry(t: Task) -> Tuple[float, int, int]:
        """Mirrors selection.rs admission_entry (key order, id, quota)."""
        rate = t.utility * (t.slo.tpot / 1e6)
        return (-rate, t.id, quota_of(t.slo.tpot))

    def on_arrival(self, pool, ids, now) -> None:
        self.generation += 1
        if not self.immutable:
            self.needs_reschedule = True
            return
        # maintain the sorted cache (binary insert per task) and
        # evaluate the skip precondition in the same pass: skippable iff
        # a threshold from a live selection exists, no other
        # interruption is pending, and every new entry sorts strictly
        # after the admission boundary
        skip = (not self.needs_reschedule and self.threshold is not None
                and bool(ids))
        for tid in ids:
            entry = self._entry(pool[tid])
            if skip and (entry[0], entry[1]) <= self.threshold:
                skip = False
            insort(self.sorted, entry)
        self.cache_generation = self.generation
        if skip:
            # provably a no-op reschedule; the one side effect a real
            # reschedule has on the scan — resetting the column cursor —
            # is replicated so decode order stays bit-exact
            self.decisions_skipped += 1
            self.col = 0
        else:
            self.needs_reschedule = True

    def on_completion(self, pool, ids, now) -> None:
        self.generation += 1
        if self.immutable:
            # departures notify with the finished husk still pooled, so
            # the removal key is exactly the insertion key
            for tid in ids:
                key, _tid, _q = self._entry(pool[tid])
                pos = bisect_left(self.sorted, (key, tid))
                assert (pos < len(self.sorted)
                        and self.sorted[pos][1] == tid), \
                    "departing task missing from candidate cache"
                self.sorted.pop(pos)
            self.cache_generation = self.generation
        # a departure shrinks the admitted set (freed quota may admit a
        # paused task), so it always forces a reschedule
        self.needs_reschedule = True

    def _select_cached(self):
        """Mirrors selection.rs select_tasks_sorted: Alg. 2 straight over
        the maintained cache — no pool pass, no re-sort."""
        inc = self._inc
        inc.clear()
        selected: List[Tuple[int, int]] = []
        rejected: List[int] = []
        stopped = False
        for _key, cid, q in self.sorted:
            if stopped or len(selected) >= self.lat.max_batch:
                rejected.append(cid)
                continue
            p = inc.probe(q)
            if p >= self.cycle_cap:
                rejected.append(cid)
                stopped = True
                continue
            inc.insert(q)
            selected.append((cid, q))
        return selected, rejected, stopped

    def _reschedule(self, pool) -> None:
        self.reschedules += 1
        if self.immutable and self.cache_generation == self.generation:
            selected, rejected, stopped = self._select_cached()
        else:
            self.full_rebuilds += 1
            if self.memory is not None:
                candidates = [
                    (t.id, t.utility, t.slo.tpot,
                     self.memory.footprint_bytes(t.seq_len()))
                    for t in pool if not t.is_finished()
                ]
            else:
                candidates = [
                    (t.id, t.utility, t.slo.tpot)
                    for t in pool if not t.is_finished()
                ]
            selected, rejected = select_tasks_fast(
                candidates, self.lat, self.cycle_cap, self.kv_capacity,
                period=self._inc)
            # reconstruct the stop reason: once any candidate is
            # rejected, the first rejection was a resource stop iff the
            # admitted prefix never reached max_batch (the only other
            # way to reject)
            stopped = bool(rejected) and len(selected) < self.lat.max_batch
            if self.immutable:
                # (re)seed the maintained cache so the cached path takes
                # over from here
                self.sorted = sorted(self._entry(t) for t in pool
                                     if not t.is_finished())
                self.cache_generation = self.generation
        # skip-precondition threshold: the admission boundary after this
        # selection (mirrors slice.rs; `selected` is the k-long prefix
        # of the cache)
        if not self.immutable:
            self.threshold = None
        else:
            k = len(selected)
            if k == len(self.sorted):
                self.threshold = None  # everything admitted
            elif stopped:
                e = self.sorted[k]  # resource stop: first rejected
                self.threshold = (e[0], e[1])
            elif k > 0:
                e = self.sorted[k - 1]  # max_batch stop: worst admitted
                self.threshold = (e[0], e[1])
            else:
                self.threshold = None  # max_batch == 0 degenerate shape
        self.to_prefill.clear()
        for tid, _q in selected:
            t = pool[tid]
            if t.state in (WAITING, ADMITTED):
                t.state = ADMITTED
                self.to_prefill.append(tid)
            elif t.state == PAUSED:
                t.state = RUNNING
        for tid in rejected:
            t = pool[tid]
            if t.state in (RUNNING, ADMITTED):
                t.state = PAUSED if t.prefill_end is not None else WAITING
        self.mask = DecodeMask(selected) if selected else None
        self.col = 0
        self.needs_reschedule = False

    def next_step(self, pool, now):
        if self.needs_reschedule:
            self._reschedule(pool)
        while self.to_prefill:
            tid = self.to_prefill.popleft()
            if not pool[tid].is_finished():
                return ("prefill", tid)
        mask = self.mask
        if mask is None or mask.is_empty():
            return ("idle", None)
        for _ in range(mask.columns):
            j = self.col
            self.col = (self.col + 1) % mask.columns
            batch = [
                tid for tid, _q in mask.column_batch(j) if pool[tid].state == RUNNING
            ]
            if batch:
                return ("decode", batch)
        return ("idle", None)


class OrcaPolicy:
    name = "Orca"

    def __init__(self, max_batch: int) -> None:
        self.max_batch = max_batch
        self.waiting: deque = deque()
        self.running: List[int] = []

    def on_arrival(self, pool, ids, now) -> None:
        self.waiting.extend(ids)

    def on_completion(self, pool, ids, now) -> None:
        gone = set(ids)
        self.running = [i for i in self.running if i not in gone]

    def next_step(self, pool, now):
        while len(self.running) < self.max_batch and self.waiting:
            tid = self.waiting.popleft()
            if pool[tid].is_finished():
                continue
            # migrated-in tasks arrive prefilled: straight back to decode
            pool[tid].state = (RUNNING if pool[tid].prefill_end is not None
                               else ADMITTED)
            self.running.append(tid)
        for tid in self.running:
            if pool[tid].state == ADMITTED:
                return ("prefill", tid)
        batch = [tid for tid in self.running if pool[tid].state == RUNNING]
        return ("decode", batch) if batch else ("idle", None)


# ---------------------------------------------------------------- server --


class Server:
    """Mirrors server.rs over the sim engine + virtual clock."""

    def __init__(self, workload: List[Task], policy, lat: LatencyModel,
                 kv: Optional[KvCacheModel] = None) -> None:
        assert all(
            a.arrival <= b.arrival for a, b in zip(workload, workload[1:])
        ), "workload must be sorted by arrival"
        self.pool: List[Task] = []
        self.policy = policy
        self.lat = lat
        self.kv = kv if kv is not None else KvCacheModel(MemoryConfig(), None, lat)
        self.clock = 0
        self.arrivals: deque = deque(workload)
        self.steps = 0
        self.decode_steps = 0
        self.prefill_steps = 0
        self.shed = 0
        # delivered-but-unfinished count (mirrors server.rs `live`):
        # the O(1) backing for next_event_time
        self.live_count = 0

    def now(self) -> int:
        return self.clock

    def next_event_time(self) -> Optional[int]:
        """Mirrors Server::next_event_time: `now` while any delivered
        task is unfinished, else the first pending arrival's time, else
        None (fully idle)."""
        if self.live_count > 0:
            return self.clock
        return self.arrivals[0].arrival if self.arrivals else None

    def sync_clock(self, t: int) -> None:
        """Mirrors Server::sync_clock: move the clock monotonically
        without serving (only valid while fully idle)."""
        assert self.next_event_time() is None, \
            "sync_clock would skip real serving work"
        if t > self.clock:
            self.clock = t

    def push_arrival(self, task: Task) -> None:
        assert not self.arrivals or self.arrivals[-1].arrival <= task.arrival
        self.arrivals.append(task)

    def withdraw_pending(self) -> List[Task]:
        out = list(self.arrivals)
        self.arrivals.clear()
        return out

    def _deliver_arrivals(self, now: int) -> None:
        ids = []
        while self.arrivals and self.arrivals[0].arrival <= now:
            t = self.arrivals.popleft()
            assert t.id == len(self.pool), "task ids must be dense"
            ids.append(t.id)
            self.pool.append(t)
        if ids:
            self.live_count += len(ids)
            self.policy.on_arrival(self.pool, ids, now)

    def _apply_outcome(self, token_ids: List[int], now: int) -> None:
        completed = []
        for tid in token_ids:
            t = self.pool[tid]
            if t.is_finished():
                continue
            t.on_token(now)
            self.kv.note_token(tid)
            if t.is_finished():
                completed.append(tid)
        if completed:
            self.live_count -= len(completed)
            for tid in completed:
                self.kv.release(tid)
                self.pool[tid].residency = RES_NONE
            self.policy.on_completion(self.pool, completed, now)

    def _memory_constrained(self) -> bool:
        return self.kv.constrained()

    def _pick_victim(self, protected) -> Optional[int]:
        prot = set(protected)
        best = None
        for t in self.pool:
            if (t.residency == RES_RESIDENT and not t.is_finished()
                    and t.id not in prot):
                key = (0 if t.state == PAUSED else 1, t.id)
                if best is None or key < best:
                    best = key
        return None if best is None else best[1]

    def _evict_one(self, protected) -> Optional[int]:
        victim = self._pick_victim(protected)
        if victim is None:
            return None
        cost = self.kv.swap_out(victim)
        self.pool[victim].residency = RES_SWAPPED
        self.pool[victim].swap_outs += 1
        return cost

    def _shed_task(self, tid: int, now: int) -> None:
        """Mirrors server.rs shed_task: terminal, unserved, counted an
        SLO violation; the policy sees a completion so capacity frees."""
        t = self.pool[tid]
        assert not t.is_finished() and not t.migrated_away
        t.shed = True
        t.state = FINISHED
        t.residency = RES_NONE
        t.pending_restore = 0
        self.live_count -= 1
        self.kv.release(tid)
        self.shed += 1
        self.policy.on_completion(self.pool, [tid], now)

    def _prepare_prefill(self, tid: int) -> Optional[int]:
        """Returns the eviction cost, or None when the prompt alone
        exceeds the device capacity and the task was shed (mirrors
        server.rs prepare_prefill)."""
        if not self._memory_constrained():
            return 0
        cap = self.kv.capacity
        need = self.kv.bytes_for(self.pool[tid].prompt_len + 1)
        if need > cap:
            self._shed_task(tid, self.clock)
            return None
        cost = 0
        while self.kv.occupied + need > cap:
            c = self._evict_one([tid])
            if c is None:
                break
            cost += c
        return cost

    def _restore_swapped(self, tid: int, tokens: int, pending: int) -> int:
        """Mirrors server.rs restore_swapped: a migrated-in task with no
        outstanding handoff fee and no kv slot is admitted free (its
        bytes were handed off, not swapped out locally); everything else
        pays the kv restore price."""
        if pending == 0 and tid not in self.kv.slots:
            self.kv.insert(tid, tokens)
            return 0
        return self.kv.restore(tid, tokens, pending)

    def _prepare_decode(self, tids: List[int]):
        if not self._memory_constrained():
            # a migrated-in task's handoff fee is owed even here (the
            # only way residency is Swapped on an unconstrained device)
            cost = 0
            for tid in tids:
                t = self.pool[tid]
                if t.residency == RES_SWAPPED:
                    cost += self._restore_swapped(tid, t.seq_len(),
                                                  t.pending_restore)
                    t.residency = RES_RESIDENT
                    t.pending_restore = 0
                    t.swap_ins += 1
            return tids, cost
        cap = self.kv.capacity
        # prefix of the batch whose post-step footprint fits; a head
        # that fits nothing is shed and the scan restarted (mirrors
        # server.rs prepare_decode's outgrown-the-device path)
        kept = list(tids)
        while True:
            need = 0
            keep_len = 0
            for tid in kept:
                b = self.kv.bytes_for(self.pool[tid].seq_len() + 1)
                if need + b <= cap:
                    need += b
                    keep_len += 1
                else:
                    break
            if keep_len > 0:
                del kept[keep_len:]
                break
            if not kept:
                return kept, 0
            self._shed_task(kept.pop(0), self.clock)
        cost = 0
        while self.kv.resident_outside(kept) + need > cap:
            c = self._evict_one(kept)
            if c is None:
                break
            cost += c
        for tid in kept:
            t = self.pool[tid]
            if t.residency != RES_RESIDENT:
                cost += self._restore_swapped(tid, t.seq_len(),
                                              t.pending_restore)
                t.residency = RES_RESIDENT
                t.pending_restore = 0
                t.swap_ins += 1
        return kept, cost

    def extract_task(self, tid: int, now: int) -> Task:
        import copy

        t = self.pool[tid]
        assert not t.is_finished() and not t.migrated_away
        snap = copy.copy(t)
        t.migrated_away = True
        t.state = FINISHED
        t.residency = RES_NONE
        self.live_count -= 1
        self.kv.release(tid)
        self.policy.on_completion(self.pool, [tid], now)
        return snap

    def _execute(self, step) -> None:
        kind, payload = step
        if kind == "prefill":
            mem_cost = self._prepare_prefill(payload)
            if mem_cost is None:
                return  # shed: no engine pass, no step counted
            if mem_cost > 0:
                self.clock += mem_cost
            self.steps += 1
            self.prefill_steps += 1
            duration = self.lat.prefill(self.pool[payload].prompt_len)
            self.clock += duration
            end = self.clock
            t = self.pool[payload]
            t.state = RUNNING
            t.prefill_end = end
            t.residency = RES_RESIDENT
            self.kv.insert(payload, t.prompt_len)
            self._apply_outcome([payload], end)
        else:
            assert payload, "empty decode batch"
            payload, mem_cost = self._prepare_decode(payload)
            if not payload:
                return  # every member shed: nothing to run, re-decide
            if mem_cost > 0:
                self.clock += mem_cost
            self.steps += 1
            self.decode_steps += 1
            duration = self.lat.decode(len(payload))
            self.clock += duration
            self._apply_outcome(payload, self.clock)

    def run(self, horizon: int) -> None:
        while True:
            now = self.clock
            if now >= horizon:
                return
            self._deliver_arrivals(now)
            step = self.policy.next_step(self.pool, now)
            if step[0] == "idle":
                if self.arrivals:
                    nxt = min(self.arrivals[0].arrival, horizon)
                    if nxt > self.clock:
                        self.clock = nxt
                else:
                    return
            else:
                self._execute(step)

    def run_until(self, until: int) -> None:
        while True:
            now = self.clock
            if now >= until:
                return
            self._deliver_arrivals(now)
            step = self.policy.next_step(self.pool, now)
            if step[0] == "idle":
                nxt = min(self.arrivals[0].arrival, until) if self.arrivals else until
                if nxt > self.clock:
                    self.clock = nxt
            else:
                self._execute(step)


# --------------------------------------------------------------- cluster --


@dataclass
class DeviceProfile:
    """Mirrors cluster/fleet.rs DeviceProfile."""

    name: str
    latency: LatencyModel
    max_batch: int
    max_context: int
    cycle_cap: int = CYCLE_CAP
    kv_fraction: float = 1.0
    kv_capacity: Optional[int] = None

    @staticmethod
    def standard() -> "DeviceProfile":
        return DeviceProfile("standard", LatencyModel.paper_calibrated(), 32, 8192)

    @staticmethod
    def lite() -> "DeviceProfile":
        return DeviceProfile(
            "lite", LatencyModel.paper_calibrated().scaled(1.5), 16, 4096,
            kv_fraction=0.75)

    @staticmethod
    def nano() -> "DeviceProfile":
        return DeviceProfile(
            "nano", LatencyModel.paper_calibrated().scaled(2.5), 8, 2048,
            kv_fraction=0.5)

    @staticmethod
    def named(name: str) -> "DeviceProfile":
        return {"standard": DeviceProfile.standard,
                "lite": DeviceProfile.lite,
                "nano": DeviceProfile.nano}[name]()


def edge_mixed() -> List[DeviceProfile]:
    return [DeviceProfile.standard(), DeviceProfile.standard(),
            DeviceProfile.lite(), DeviceProfile.nano()]


@dataclass
class AdmissionConfig:
    """Mirrors cluster/fleet.rs AdmissionConfig (defaults included)."""

    enabled: bool = False
    mode: str = "depth"  # "depth" | "headroom"
    rt_queue_bound: int = 12
    nrt_queue_bound: int = 10

    def bound_for(self, task: Task) -> int:
        return self.rt_queue_bound if task.is_real_time() else self.nrt_queue_bound


# ------------------------------------------------------ elastic fleets --


JOIN, LEAVE, CRASH = "join", "leave", "crash"


@dataclass
class LifecycleEvent:
    """Mirrors cluster/lifecycle.rs LifecycleEvent."""

    time: int
    action: str  # JOIN | LEAVE | CRASH
    target: Optional[int] = None


@dataclass
class AutoscalerConfig:
    """Mirrors cluster/lifecycle.rs AutoscalerConfig (defaults included)."""

    enabled: bool = False
    deficit_streak: int = 2
    idle_streak: int = 64
    cooldown: int = 500_000  # 0.5 s
    boot_delay: int = 0  # µs between a grow decision and the joiner booting
    # PR 9: replace the shed-deficit grow signal with the aggregate
    # Eq. 7 one — "deficit" becomes "mean cycle headroom across the
    # placeable fleet <= headroom_min (µs)"
    grow_on_headroom: bool = False
    headroom_min: int = 0

    def copy(self) -> "AutoscalerConfig":
        return AutoscalerConfig(self.enabled, self.deficit_streak,
                                self.idle_streak, self.cooldown,
                                self.boot_delay, self.grow_on_headroom,
                                self.headroom_min)


@dataclass
class HealthConfig:
    """Mirrors cluster/lifecycle.rs HealthConfig (defaults included)."""

    enabled: bool = False
    alpha: float = 0.2
    lag_threshold: int = 500_000  # 0.5 s of cycle overrun
    failure_penalty: int = 250_000  # 0.25 s per overloaded observation


@dataclass
class DetectorConfig:
    """Mirrors cluster/lifecycle.rs DetectorConfig (defaults included).
    active() is the runtime gate: enabled *and* a nonzero suspicion
    timeout — `suspicion_timeout = 0` keeps crashes oracle-visible
    (the PR 7 path, pinned bit-exact by stage 14)."""

    enabled: bool = False
    heartbeat_interval: int = 500_000  # 0.5 s between detector ticks
    suspicion_timeout: int = 2_000_000  # 2 s of silence confirms a corpse
    max_retries: int = 3
    retry_backoff: int = 500_000  # 0.5 s base, doubling per attempt

    def active(self) -> bool:
        return self.enabled and self.suspicion_timeout > 0


@dataclass
class LifecycleConfig:
    """Mirrors cluster/lifecycle.rs LifecycleConfig: explicit events
    merged with a seeded Poisson churn stream, fleet-size bounds, and
    the autoscaler/health/detector sub-configs."""

    events: List[LifecycleEvent] = field(default_factory=list)
    churn_rate: float = 0.0  # events/s (0 = off)
    seed: int = 1
    min_replicas: int = 1
    max_replicas: int = 64
    autoscaler: AutoscalerConfig = field(default_factory=AutoscalerConfig)
    health: HealthConfig = field(default_factory=HealthConfig)
    detector: DetectorConfig = field(default_factory=DetectorConfig)

    def has_events(self) -> bool:
        return bool(self.events) or self.churn_rate > 0.0

    def any_enabled(self) -> bool:
        return (self.has_events() or self.autoscaler.enabled
                or self.health.enabled or self.detector.enabled)

    def schedule(self, horizon: int) -> List[LifecycleEvent]:
        """Explicit events merged with the churn stream, sorted by time
        (stable — explicit events win ties)."""
        out = [e for e in self.events if e.time < horizon]
        out.sort(key=lambda e: e.time)
        if self.churn_rate > 0.0:
            rng = Rng(self.seed)
            t = 0
            while True:
                dt = rng.exponential(self.churn_rate)  # seconds
                # Rust `(dt * 1e6) as Micros` truncates toward zero
                t = min(t + int(dt * 1e6), MASK64)
                if t >= horizon:
                    break
                # 40% crash / 40% join / 20% graceful leave
                u = rng.f64()
                if u < 0.4:
                    action = CRASH
                elif u < 0.8:
                    action = JOIN
                else:
                    action = LEAVE
                out.append(LifecycleEvent(t, action, None))
            out.sort(key=lambda e: e.time)
        return out

    def target_rng(self) -> Rng:
        """Distinct stream for untargeted exit picks — adding an
        explicit event never shifts which replicas churn picks."""
        return Rng((self.seed * 0x9E3779B97F4A7C15 + 0x243F6A8885A308D3)
                   & MASK64)


class Autoscaler:
    """Mirrors cluster/autoscaler.rs: streak-and-cooldown scaler over
    deficit/idle observations. observe() returns None (hold), "grow",
    or ("shrink", victim)."""

    def __init__(self, cfg: AutoscalerConfig, min_replicas: int,
                 max_replicas: int) -> None:
        assert min_replicas >= 1
        assert min_replicas <= max_replicas
        self.cfg = cfg
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.deficit_run = 0
        self.idle_run = 0
        self.ready_at = 0
        self.grows = 0
        self.shrinks = 0

    def observe(self, now: int, deficit: bool,
                idle_replica: Optional[int], alive: int):
        if deficit:
            self.deficit_run += 1
            self.idle_run = 0
        elif idle_replica is not None:
            self.idle_run += 1
            self.deficit_run = 0
        else:
            self.deficit_run = 0
            self.idle_run = 0
        if now < self.ready_at:
            return None
        if (self.deficit_run >= self.cfg.deficit_streak
                and alive < self.max_replicas):
            self.deficit_run = 0
            self.idle_run = 0
            self.ready_at = now + self.cfg.cooldown
            self.grows += 1
            return "grow"
        if (self.idle_run >= self.cfg.idle_streak
                and alive > self.min_replicas
                and idle_replica is not None):
            self.deficit_run = 0
            self.idle_run = 0
            self.ready_at = now + self.cfg.cooldown
            self.shrinks += 1
            return ("shrink", idle_replica)
        return None


class HealthTracker:
    """Mirrors cluster/health.rs: EWMA of per-replica boundary lag with
    a flat failure penalty while the replica is overrunning.

        sample = lag + penalty * 1[lag > 0]
        score <- (1 - alpha) * score + alpha * sample
        degraded <=> score > lag_threshold
    """

    def __init__(self, cfg: HealthConfig, n: int) -> None:
        assert 0.0 < cfg.alpha <= 1.0
        self.cfg = cfg
        self.scores = [0.0] * n

    def ensure(self, n: int) -> None:
        if len(self.scores) < n:
            self.scores.extend([0.0] * (n - len(self.scores)))

    def observe(self, i: int, lag: int) -> None:
        sample = float(lag + self.cfg.failure_penalty) if lag > 0 else 0.0
        a = self.cfg.alpha
        self.scores[i] = (1.0 - a) * self.scores[i] + a * sample

    def degraded(self, i: int) -> bool:
        return self.scores[i] > float(self.cfg.lag_threshold)

    def fill_mask(self, mask: List[bool]) -> None:
        for i in range(len(mask)):
            mask[i] = self.degraded(i)


SUSPECT, UNSUSPECT, CONFIRM = "suspect", "unsuspect", "confirm"


class FailureDetector:
    """Mirrors cluster/detector.rs: the heartbeat bookkeeping behind the
    per-replica suspicion state machine. Pure clock-in/verdict-out —
    the Orchestrator applies each verdict to the Router's suspected
    mask and counters.

    tick(i, now, dead) folds arrived heartbeats and runs one suspicion
    step: healthy -> suspected when heartbeat age crosses
    heartbeat_interval, suspected -> healthy on a fresh heartbeat (a
    counted false suspicion), suspected -> confirmed when age reaches
    suspicion_timeout *and* the replica is actually silenced (ground
    truth — a live laggard caps at suspected, never a false kill)."""

    def __init__(self, cfg: DetectorConfig, n: int) -> None:
        self.cfg = cfg
        self.last_hb = [0] * n
        self.pending: List[List[int]] = [[] for _ in range(n)]
        self.suspected = [False] * n

    def ensure(self, n: int, now: int) -> None:
        """Joiners start with a synthetic heartbeat at `now` — a replica
        admitted mid-run is healthy until it actually misses a tick."""
        while len(self.last_hb) < n:
            self.last_hb.append(now)
            self.pending.append([])
            self.suspected.append(False)

    def emit(self, i: int, tick: int, lag: int) -> None:
        """A heartbeat emitted at `tick` arrives `lag` later (the
        replica's current Eq. 7 cycle overrun)."""
        self.pending[i].append(min(tick + lag, MASK64))

    def tick(self, i: int, now: int, dead: bool):
        pend = self.pending[i]
        k = 0
        while k < len(pend):
            if pend[k] <= now:
                # Rust swap_remove: overwrite with the tail, pop it
                arrived = pend[k]
                pend[k] = pend[-1]
                pend.pop()
                if arrived > self.last_hb[i]:
                    self.last_hb[i] = arrived
            else:
                k += 1
        age = max(0, now - self.last_hb[i])
        if dead and age >= self.cfg.suspicion_timeout:
            self.suspected[i] = True
            return CONFIRM
        if age > self.cfg.heartbeat_interval:
            if not self.suspected[i]:
                self.suspected[i] = True
                return SUSPECT
        elif self.suspected[i]:
            self.suspected[i] = False
            return UNSUSPECT
        return None

    def is_suspected(self, i: int) -> bool:
        return self.suspected[i] if i < len(self.suspected) else False


class Replica:
    """Mirrors cluster/replica.rs: staged tasks keep global ids; local
    ids are assigned at push time (delivery order), so migration keeps
    the pool's dense-id contract."""

    def __init__(self, rid: int, make_policy, profile: DeviceProfile,
                 memory: Optional[MemoryConfig] = None) -> None:
        self.id = rid
        kv = None
        if memory is not None:
            kv = KvCacheModel(memory, profile.kv_capacity, profile.latency)
        self.server = Server([], make_policy(profile), profile.latency, kv=kv)
        self.global_ids: List[int] = []
        self.staged: List[Task] = []
        self.profile = profile
        self.routed = 0
        self.migrated_in = 0
        self.migrated_out = 0

    def pending(self) -> int:
        return len(self.staged) + len(self.server.arrivals)

    def pending_gids(self) -> set:
        """Mirrors Replica::pending_gids: global ids of every
        queued-but-unstarted task — exactly what withdraw_all at this
        instant would return. Snapshotted at crash time so confirmation
        can tell pre-crash work (free requeue) from tasks dispatched
        into the not-yet-detected corpse (limbo, recovered via retry)."""
        gids = {t.id for t in self.staged}
        gids.update(self.global_ids[t.id] for t in self.server.arrivals)
        return gids

    def queued_in_class(self, cls: str) -> int:
        waiting = sum(
            1 for t in self.server.pool if t.cls == cls and t.state == WAITING)
        return (waiting
                + sum(1 for t in self.staged if t.cls == cls)
                + sum(1 for t in self.server.arrivals if t.cls == cls))

    def assign(self, task: Task) -> None:
        at = _partition_point(self.staged, lambda t: t.arrival <= task.arrival)
        self.staged.insert(at, task)
        self.routed += 1

    def receive_migrated(self, task: Task) -> None:
        self.recall_pending()
        self.assign(task)
        self.migrated_in += 1

    def recall_pending(self) -> None:
        withdrawn = self.server.withdraw_pending()
        if not withdrawn:
            return
        keep = len(self.global_ids) - len(withdrawn)
        for t in withdrawn:
            t.id = self.global_ids[t.id]
        del self.global_ids[keep:]
        self.staged = withdrawn + self.staged

    def withdraw_unmigrated(self, migrated_before) -> List[Task]:
        self.recall_pending()
        out = [t for t in self.staged if t.id not in migrated_before]
        self.staged = [t for t in self.staged if t.id in migrated_before]
        self.routed -= len(out)
        self.migrated_out += len(out)
        return out

    def withdraw_all(self) -> List[Task]:
        """Mirrors Replica::withdraw_all: every queued (staged or
        delivered-but-waiting) task leaves, migration history ignored —
        evacuation of a dead replica must not strand anything."""
        self.recall_pending()
        out = self.staged
        self.staged = []
        self.routed -= len(out)
        self.migrated_out += len(out)
        return out

    def evacuees(self):
        """Manifest of every in-service task as (global id, quota,
        cached tokens, prefilled) in delivery order (= pool order)."""
        out = []
        for t in self.server.pool:
            if t.is_finished() or t.migrated_away:
                continue
            out.append((self.global_ids[t.id], t.slo.tokens_per_cycle(),
                        t.seq_len(), t.prefill_end is not None))
        return out

    def extract_evacuee(self, gid: int) -> Task:
        """Extract one in-service task for evacuation; the caller prices
        the restore (recompute vs. handoff) once the destination is
        known. Unprefilled evacuees revert to fresh waiting arrivals."""
        local = self.global_ids.index(gid)
        task = self.server.extract_task(local, self.server.now())
        task.id = gid
        if task.prefill_end is not None:
            task.state = PAUSED
            task.residency = RES_SWAPPED
        else:
            task.state = WAITING
            task.residency = RES_NONE
        task.pending_restore = 0
        self.routed -= 1
        self.migrated_out += 1
        return task

    def cycle_lag(self) -> int:
        """How far the Eq. 7 period overruns the cycle cap (0 if it
        fits) — the health tracker's boundary-lag sample."""
        vs = self.demand_quotas()
        vs.sort(reverse=True)
        return max(0, period_eq7(vs, self.profile.latency)
                   - self.profile.cycle_cap)

    def running_candidates(self, migrated_before):
        out = []
        for t in self.server.pool:
            if t.is_finished() or t.migrated_away or t.prefill_end is None:
                continue
            if t.state != PAUSED or t.residency != RES_SWAPPED:
                continue
            gid = self.global_ids[t.id]
            if gid in migrated_before:
                continue
            out.append((t.utility, gid, t.slo.tokens_per_cycle(), t.seq_len()))
        out.sort(key=lambda c: (c[0], c[1]))
        return out

    def extract_running(self, gid: int, handoff_fee: int) -> Task:
        local = self.global_ids.index(gid)
        task = self.server.extract_task(local, self.server.now())
        task.id = gid
        task.state = PAUSED
        task.residency = RES_SWAPPED
        task.pending_restore = handoff_fee
        self.routed -= 1
        self.migrated_out += 1
        return task

    def run_until(self, t: int) -> None:
        due = _partition_point(self.staged, lambda task: task.arrival <= t)
        for task in self.staged[:due]:
            local = len(self.global_ids)
            self.global_ids.append(task.id)
            task.id = local
            self.server.push_arrival(task)
        del self.staged[:due]
        self.server.run_until(t)

    def next_event_time(self) -> Optional[int]:
        """Mirrors Replica::next_event_time: min of the server's next
        interesting time and the first staged (undelivered) arrival."""
        s = self.server.next_event_time()
        st = self.staged[0].arrival if self.staged else None
        if s is None:
            return st
        if st is None:
            return s
        return min(s, st)

    def sync_clock(self, t: int) -> None:
        assert not self.staged, "sync_clock with staged arrivals"
        self.server.sync_clock(t)

    def load_tokens(self) -> int:
        in_service = sum(
            t.remaining_tokens() for t in self.server.pool if not t.is_finished()
        )
        queued = sum(t.output_len for t in self.server.arrivals)
        queued += sum(t.output_len for t in self.staged)
        return in_service + queued

    def demand_quotas(self) -> List[int]:
        qs = [
            t.slo.tokens_per_cycle()
            for t in self.server.pool
            if not t.is_finished()
        ]
        qs.extend(t.slo.tokens_per_cycle() for t in self.server.arrivals)
        qs.extend(t.slo.tokens_per_cycle() for t in self.staged)
        return qs

    def headroom(self, cand_quota: int) -> int:
        vs = self.demand_quotas()
        vs.append(cand_quota)
        vs.sort(reverse=True)
        return max(0, self.profile.cycle_cap - period_eq7(vs, self.profile.latency))

    def overloaded(self) -> bool:
        vs = self.demand_quotas()
        vs.sort(reverse=True)
        return period_eq7(vs, self.profile.latency) > self.profile.cycle_cap

    def finish(self) -> List[Task]:
        assert not self.staged, "finish() with staged arrivals"
        kept = [t for t in self.server.pool if not t.migrated_away]
        for t in kept:
            t.id = self.global_ids[t.id]
        return kept


def _partition_point(xs, pred) -> int:
    lo, hi = 0, len(xs)
    while lo < hi:
        mid = (lo + hi) // 2
        if pred(xs[mid]):
            lo = mid + 1
        else:
            hi = mid
    return lo


class Router:
    def __init__(self, strategy: str, replicas: List[Replica],
                 admission: Optional[AdmissionConfig] = None,
                 migration: bool = False,
                 migrate_running: bool = False,
                 memory: Optional[MemoryConfig] = None) -> None:
        assert replicas
        assert all(r.id == i for i, r in enumerate(replicas))
        self.strategy = strategy
        self.replicas = replicas
        self.admission = admission or AdmissionConfig()
        self.migration = migration
        self.migrate_running = migrate_running
        self.memory = memory or MemoryConfig()
        self.rr_next = 0
        self.migrated = set()
        self.migrations = 0
        self.migrated_running = 0
        # PR 8 counters (mirror cluster/controller.rs): passes are
        # migration-pass pairs executed past the enablement gate — one
        # per arrival boundary under lockstep, one per productive
        # MigrationCheck under the event engine; checks count the
        # edge-triggered events themselves (0 for lockstep)
        self.migration_passes = 0
        self.migration_checks = 0
        self.handoff_bytes = 0
        self.handoff_us = 0
        self.rejected: List[Task] = []
        # streaming mode (million-task traces): fold shed arrivals into
        # a counter instead of retaining the Task
        self.fold_rejects = False
        self.rejected_folded = 0
        # elastic state (mirrors cluster/controller.rs): an *empty*
        # alive mask is the static fleet — every index alive, the fast
        # path. The event engine fills it when any elastic feature is on.
        self.alive: List[bool] = []
        self.degraded: List[bool] = []
        # PR 10 failure-detector masks (same empty-for-static contract).
        # suspected is *believed* state — excluded from placement, un-
        # suspected on a fresh heartbeat; unresponsive is ground truth
        # the placement paths must never read: a silenced corpse cannot
        # answer migration withdrawals or shrink shutdowns.
        self.suspected: List[bool] = []
        self.unresponsive: List[bool] = []
        self.crashes = 0
        self.joins = 0
        self.leaves = 0
        self.evac_requeued = 0
        self.evac_restarted = 0
        self.evac_recompute_us = 0
        self.autoscale_grows = 0
        self.autoscale_shrinks = 0
        self.autoscale_pending_boots = 0
        self.suspicions = 0
        self.false_suspicions = 0
        self.detections = 0
        self.limbo_recovered = 0
        self.retries = 0
        self.retry_exhausted = 0
        self.limbo_lost = 0

    def reject(self, task: Task) -> None:
        """Shed an arrival. Streaming runs fold the task into a counter
        so a million-task trace never accumulates shed Task objects."""
        if self.fold_rejects:
            self.rejected_folded += 1
        else:
            self.rejected.append(task)

    def is_alive(self, i: int) -> bool:
        return self.alive[i] if i < len(self.alive) else True

    def is_degraded(self, i: int) -> bool:
        return self.degraded[i] if i < len(self.degraded) else False

    def is_suspected(self, i: int) -> bool:
        return self.suspected[i] if i < len(self.suspected) else False

    def is_unresponsive(self, i: int) -> bool:
        return self.unresponsive[i] if i < len(self.unresponsive) else False

    def placeable(self, i: int) -> bool:
        return (self.is_alive(i) and not self.is_degraded(i)
                and not self.is_suspected(i))

    def alive_count(self) -> int:
        return sum(self.alive) if self.alive else len(self.replicas)

    def decide(self, task: Task) -> Optional[int]:
        n = len(self.replicas)
        # eligibility (alive ∧ ¬degraded) only exists under lifecycle
        # events — static fleets skip this block entirely
        elig = None
        if self.alive:
            elig = [self.placeable(i) for i in range(n)]
            if not any(elig):
                # every alive replica is degraded: relax to alive-only
                # rather than shedding the whole arrival stream
                elig = [self.is_alive(i) for i in range(n)]
        headrooms = None
        if self.admission.enabled:
            if self.admission.mode == "headroom":
                # keep the computed headrooms: the slo-aware pick reuses
                # them (mirrors router.rs decide), one Eq. 7 evaluation
                # per replica per decision
                quota = task.slo.tokens_per_cycle()
                headrooms = [r.headroom(quota) for r in self.replicas]
                admissible = [h > 0 for h in headrooms]
            else:
                bound = self.admission.bound_for(task)
                admissible = [r.queued_in_class(task.cls) < bound
                              for r in self.replicas]
        else:
            admissible = [True] * n
        if elig is not None:
            # open(i) = elig(i) ∧ admissible(i) — the admission mask is
            # still computed over *all* replicas (headrooms included)
            admissible = [a and e for a, e in zip(admissible, elig)]
        if not any(admissible):
            return None
        if self.strategy == "round-robin":
            start = self.rr_next
            k = next(k for k in range(n) if admissible[(start + k) % n])
            self.rr_next = start + k + 1
            return (start + k) % n
        if self.strategy == "least-loaded":
            return min((r.load_tokens(), r.id)
                       for r in self.replicas if admissible[r.id])[1]
        if headrooms is not None:
            return min((-headrooms[r.id], r.load_tokens(), r.id)
                       for r in self.replicas if admissible[r.id])[2]
        quota = task.slo.tokens_per_cycle()
        return self.best_by_headroom(quota, lambda r: admissible[r.id])

    def best_by_headroom(self, quota: int, eligible) -> Optional[int]:
        cands = [(-r.headroom(quota), r.load_tokens(), r.id)
                 for r in self.replicas if eligible(r)]
        return min(cands)[2] if cands else None

    def run_migrations(self) -> None:
        if not self.migration or len(self.replicas) < 2:
            return
        self.migration_passes += 1
        for src in range(len(self.replicas)):
            # an unresponsive source cannot answer the withdraw request
            # (dead but not yet detected) — skipping it keeps a
            # not-yet-confirmed corpse from handing its queue back
            # before the detector fires
            if (not self.is_alive(src) or self.is_unresponsive(src)
                    or not self.replicas[src].overloaded()):
                continue
            # eligible-peer check *before* withdrawing: with a churning
            # fleet the only peers may be dead or degraded, and an offer
            # with nowhere to go must never leave the queue
            if not any(r.id != src and self.placeable(r.id)
                       and not r.overloaded() for r in self.replicas):
                continue
            for task in self.replicas[src].withdraw_unmigrated(self.migrated):
                quota = task.slo.tokens_per_cycle()
                dst = self.best_by_headroom(
                    quota, lambda r: (r.id != src and self.placeable(r.id)
                                      and not r.overloaded()))
                if dst is None:
                    dst = self.best_by_headroom(
                        quota, lambda r: r.id != src and self.placeable(r.id))
                self.migrated.add(task.id)
                self.migrations += 1
                self.replicas[dst].receive_migrated(task)

    def run_running_migrations(self) -> None:
        if not self.migration or not self.migrate_running or len(self.replicas) < 2:
            return
        for src in range(len(self.replicas)):
            # same unresponsive-source gate as the queued pass above
            if (not self.is_alive(src) or self.is_unresponsive(src)
                    or not self.replicas[src].overloaded()):
                continue
            for _u, gid, quota, tokens in \
                    self.replicas[src].running_candidates(self.migrated):
                if not self.replicas[src].overloaded():
                    break
                dst = self.best_by_headroom(
                    quota, lambda r: (r.id != src and self.placeable(r.id)
                                      and not r.overloaded()))
                if dst is None:
                    break
                fee = self.memory.handoff_cost(tokens)
                if self.replicas[dst].headroom(quota) <= fee:
                    continue
                task = self.replicas[src].extract_running(gid, fee)
                self.migrated.add(gid)
                self.migrations += 1
                self.migrated_running += 1
                self.handoff_bytes += self.memory.bytes_for(tokens)
                self.handoff_us += fee
                self.replicas[dst].receive_migrated(task)

    def evacuate(self, src: int, crash: bool) -> None:
        """Mirrors Controller::evacuate. The caller has already marked
        `src` dead, so every placement below naturally excludes it.
        Queued tasks are re-placed for free; in-service tasks carry a
        restore fee (full prefill *recompute* on the destination's own
        latency curve after a crash, PR 4 KV handoff after a leave).
        Bypasses the exactly-once overload-migration set."""
        self.requeue_evacuated(src, self.replicas[src].withdraw_all())
        self.evacuate_in_service(src, crash)

    def requeue_evacuated(self, src: int, queued: List[Task]) -> None:
        """Mirrors Controller::requeue_evacuated: free re-placement of
        queued-but-unstarted tasks withdrawn from `src`. Split out so
        detector confirmation can requeue the *pre-crash* partition of
        a corpse's queue through the byte-identical oracle path while
        routing the post-crash limbo partition into retry instead."""
        for task in queued:
            quota = task.slo.tokens_per_cycle()
            dst = self.best_by_headroom(
                quota, lambda r: (r.id != src and self.placeable(r.id)
                                  and not r.overloaded()))
            if dst is None:
                # note the relaxed fallback: any *alive* peer, degraded
                # or overloaded — losing work would be worse
                dst = self.best_by_headroom(
                    quota, lambda r: r.id != src and self.is_alive(r.id))
            if dst is None:
                self.reject(task)  # no alive peer: shed
                continue
            self.evac_requeued += 1
            self.replicas[dst].receive_migrated(task)

    def evacuate_in_service(self, src: int, crash: bool) -> None:
        """The in-service half of evacuate (mirrors
        Controller::evacuate_in_service)."""
        for gid, quota, tokens, prefilled in self.replicas[src].evacuees():
            dst = self.best_by_headroom(
                quota, lambda r: (r.id != src and self.placeable(r.id)
                                  and not r.overloaded()))
            if dst is None:
                dst = self.best_by_headroom(
                    quota, lambda r: r.id != src and self.is_alive(r.id))
            if dst is None:
                continue  # stays on the dead replica; reported as a miss
            task = self.replicas[src].extract_evacuee(gid)
            if prefilled:
                if crash:
                    fee = self.replicas[dst].profile.latency.prefill(tokens)
                    self.evac_recompute_us += fee
                else:
                    fee = self.memory.handoff_cost(tokens)
                    self.handoff_bytes += self.memory.bytes_for(tokens)
                    self.handoff_us += fee
                task.pending_restore = fee
                self.evac_restarted += 1
            else:
                self.evac_requeued += 1
            self.replicas[dst].receive_migrated(task)

    def run(self, workload: List[Task], drain: int):
        assert all(a.arrival <= b.arrival for a, b in zip(workload, workload[1:]))
        last = workload[-1].arrival if workload else 0
        for task in workload:
            for r in self.replicas:
                r.run_until(task.arrival)
            self.run_migrations()
            self.run_running_migrations()
            pick = self.decide(task)
            if pick is None:
                self.reject(task)
            else:
                self.replicas[pick].assign(task)
        horizon = last + drain
        for r in self.replicas:
            r.run_until(horizon)
            assert r.pending() == 0, "drain window too small"
        per_replica = [(r.id, r.routed, r.server.steps) for r in self.replicas]
        tasks = [t for r in self.replicas for t in r.finish()]
        tasks.extend(self.rejected)
        tasks.sort(key=lambda t: t.id)
        return tasks, per_replica


class Orchestrator:
    """Mirrors cluster/orchestrator.rs: the event-driven cluster engine.

    Decisions (routing, admission, migration) are delegated to an
    embedded Router over the same replicas — only the advancement
    machinery differs. Events are heapq tuples ordered exactly like the
    Rust Event struct: (time, kind, replica, task) with kind ranks
    WAKE < LIFECYCLE < BOOT < HEARTBEAT < BOUNDARY < MIGRATION_CHECK <
    RETRY < ARRIVAL — nodes reach a boundary before anything decides
    there, a crash at t is visible to every same-time decision, a
    heartbeat tick judges the settled fleet, at the exact horizon the
    drain outranks a same-time confirmation's retries (they flush as
    limbo_lost), an overload check runs its migration pass before the
    same-instant arrival routes, recovered tasks re-dispatch just ahead
    of the same-time arrival, and arrivals route against the
    already-changed fleet. Bit-exact with Router.run by construction
    for everything except migration-pass *timing* (edge-triggered
    MigrationCheck events vs one pass per boundary — same migrated
    tasks, fewer passes); stage 10 asserts it (and stage 11 asserts the
    all-disabled elastic run changes nothing; stage 14 the inert
    detector).

    Passing a LifecycleConfig (with a factory building the replica for
    each joining fleet index) attaches the elastic machinery, mirroring
    Orchestrator::with_lifecycle: the liveness/health masks are
    initialized even when every sub-feature is disabled.
    """

    (WAKE, LIFECYCLE, BOOT, HEARTBEAT, BOUNDARY, MIGRATION_CHECK, RETRY,
     ARRIVAL) = 0, 1, 2, 3, 4, 5, 6, 7

    def __init__(self, ctl: Router,
                 lifecycle: Optional[LifecycleConfig] = None,
                 factory: Optional[Callable] = None,
                 threads: int = 1) -> None:
        self.ctl = ctl
        self.replicas = ctl.replicas
        # PR 9 epoch workers (Orchestrator::with_threads). threads <= 1
        # keeps the literal sequential WAKE arm; > 1 routes wakes
        # through _run_epoch. Python advancement stays single-threaded
        # either way (the GIL) — the mirror's job is the bit-exactness
        # contract plus the epoch structure the cost model reads.
        self.threads = max(1, int(threads))
        # set to [] to record each epoch's batch (run_counted_logged)
        self.epoch_log: Optional[List[List[int]]] = None
        # set to [] to record per-epoch (replica, seconds) advance
        # costs — the BENCH_9 thread-speedup cost-model input
        self.epoch_costs: Optional[List[List[Tuple[int, float]]]] = None
        n = len(self.replicas)
        self.wake: List[Optional[int]] = [None] * n
        self.advanced_to: List[Optional[int]] = [None] * n
        self.advancements = [0] * n
        # overload shadow (mirrors orchestrator.rs): refreshed wherever
        # load can grow, it arms MIGRATION_CHECK events edge-triggered.
        # Stale-true entries cost one cheap re-check; stale-false is
        # impossible by construction.
        self.overload: List[bool] = [False] * n
        self.overload_count = 0
        self._migration_check_at: Optional[int] = None
        self.lifecycle = lifecycle or LifecycleConfig()
        self.factory = factory
        self.autoscaler: Optional[Autoscaler] = None
        self.health: Optional[HealthTracker] = None
        # delayed failure detection (mirrors orchestrator.rs): ground
        # truth the controller must not read — silenced replicas are
        # physically dead but not yet confirmed by the detector
        self.detector: Optional[FailureDetector] = None
        self.silenced: List[bool] = [False] * n
        self.limbo_base: List[set] = [set() for _ in range(n)]
        self.limbo: dict = {}  # gid -> Task awaiting its Retry event
        self.attempts: dict = {}  # gid -> retry attempts burned (global)
        if lifecycle is not None:
            assert factory is not None, "elastic runs carry a replica factory"
            ctl.alive = [True] * n
            ctl.degraded = [False] * n
            ctl.suspected = [False] * n
            ctl.unresponsive = [False] * n
            if lifecycle.detector.active():
                self.detector = FailureDetector(lifecycle.detector, n)
            if lifecycle.autoscaler.enabled:
                self.autoscaler = Autoscaler(
                    lifecycle.autoscaler, lifecycle.min_replicas,
                    lifecycle.max_replicas)
            if lifecycle.health.enabled:
                self.health = HealthTracker(lifecycle.health, n)

    def _admit_replica(self, now: int) -> int:
        """Factory-built replica at the next fleet index, clock synced
        to now, alive and healthy (Orchestrator::admit_replica)."""
        rid = len(self.replicas)
        replica = self.factory(rid)
        assert replica.id == rid, "factory must mint the next fleet index"
        replica.sync_clock(now)
        self.replicas.append(replica)
        self.ctl.alive.append(True)
        self.ctl.degraded.append(False)
        self.ctl.suspected.append(False)
        self.ctl.unresponsive.append(False)
        self.silenced.append(False)
        self.limbo_base.append(set())
        self.wake.append(None)
        self.advanced_to.append(None)
        self.advancements.append(0)
        self.overload.append(False)
        if self.health is not None:
            self.health.ensure(rid + 1)
        if self.detector is not None:
            self.detector.ensure(rid + 1, now)
        return rid

    def _retire_replica(self, target: int, crash: bool) -> None:
        # dead first: every placement inside the evacuation excludes it
        self.ctl.alive[target] = False
        self.ctl.evacuate(target, crash)
        if self.overload[target]:
            # dead nodes never source a migration pass
            self.overload[target] = False
            self.overload_count -= 1

    def _silence_replica(self, target: int) -> None:
        """Mirrors Orchestrator::silence_replica — a crash under
        delayed detection: freeze the node (its wake dies on the
        mismatch filter and _refresh_wake never re-arms it), mark it
        unresponsive, and snapshot its queued global ids so
        confirmation can tell pre-crash work from limbo. The controller
        keeps believing it alive — that belief is the detection gap."""
        self.silenced[target] = True
        self.ctl.unresponsive[target] = True
        self.limbo_base[target] = self.replicas[target].pending_gids()
        self.wake[target] = None
        if self.overload[target]:
            # a corpse raises no overload signal
            self.overload[target] = False
            self.overload_count -= 1

    def _confirm_dead(self, target: int, now: int, heap: List) -> None:
        """Mirrors Orchestrator::confirm_dead: the delayed half of the
        crash. Pre-crash queued work re-places free (the oracle requeue
        path), in-service work re-admits at the crash recompute price,
        and limbo tasks re-dispatch under bounded retry (or shed
        outright at max_retries = 0)."""
        ctl = self.ctl
        ctl.detections += 1
        ctl.alive[target] = False
        ctl.suspected[target] = False  # dead outranks suspected
        base = self.limbo_base[target]
        self.limbo_base[target] = set()
        withdrawn = self.replicas[target].withdraw_all()
        pre_crash = [t for t in withdrawn if t.id in base]
        limbo = [t for t in withdrawn if t.id not in base]
        ctl.requeue_evacuated(target, pre_crash)
        ctl.evacuate_in_service(target, True)
        max_retries = self.lifecycle.detector.max_retries
        for task in limbo:
            ctl.limbo_recovered += 1
            if max_retries == 0:
                ctl.retry_exhausted += 1
                ctl.reject(task)
                continue
            # the budget is global: a task re-limboed from an earlier
            # corpse keeps the attempts it already burned
            self.attempts.setdefault(task.id, 0)
            heapq.heappush(heap, (now, self.RETRY, 0, task.id))
            self.limbo[task.id] = task

    def _refresh_overload(self, i: int) -> None:
        # a silenced node never reads overloaded — a corpse sends no
        # signals, so its frozen pre-crash load must not arm checks
        over = (self.ctl.is_alive(i) and not self.silenced[i]
                and self.replicas[i].overloaded())
        if self.overload[i] != over:
            self.overload[i] = over
            self.overload_count += 1 if over else -1

    def _refresh_overload_all(self) -> None:
        for i in range(len(self.replicas)):
            self._refresh_overload(i)

    def _arm_migration_check(self, heap: List, boundary: int,
                             has_arrival: bool) -> None:
        """Arm a MIGRATION_CHECK at the in-flight arrival's boundary
        when migration is on and the shadow reports overload — at most
        one per boundary, never at the drain horizon (lockstep runs no
        pass there either)."""
        if (not self.ctl.migration or self.overload_count == 0
                or not has_arrival
                or self._migration_check_at == boundary):
            return
        self._migration_check_at = boundary
        heapq.heappush(heap, (boundary, self.MIGRATION_CHECK, 0, 0))

    def _apply_lifecycle(self, e: LifecycleEvent, now: int,
                         target_rng: Rng) -> None:
        """Events that would push the alive count outside the fleet
        bounds — or that target a dead replica — are skipped (not
        clamped), consuming no randomness."""
        ctl = self.ctl
        alive = ctl.alive_count()
        if e.action == JOIN:
            if alive >= self.lifecycle.max_replicas:
                return
            self._admit_replica(now)
            ctl.joins += 1
            return
        # exits are bounded (and victims picked) on the *functioning*
        # fleet — alive and not silenced. With the detector off nothing
        # is ever silenced, so this is exactly the old alive-count
        # bound; with it on, an undetected corpse can neither die twice
        # nor keep the bound from protecting the last live replica.
        functioning = [i for i in range(len(self.replicas))
                       if ctl.is_alive(i) and not self.silenced[i]]
        if len(functioning) <= self.lifecycle.min_replicas:
            return
        if e.target is not None:
            if (e.target >= len(self.replicas)
                    or not ctl.is_alive(e.target)
                    or self.silenced[e.target]):
                return
            target = e.target
        else:
            target = functioning[
                target_rng.range_u64(0, len(functioning) - 1)]
        crash = e.action == CRASH
        if crash:
            ctl.crashes += 1
        else:
            ctl.leaves += 1
        if crash and self.detector is not None:
            # delayed detection: the fleet does not know yet
            self._silence_replica(target)
        else:
            self._retire_replica(target, crash)

    def _advance(self, i: int, t: int) -> None:
        self.advancements[i] += 1
        self.advanced_to[i] = t
        self.replicas[i].run_until(t)

    def _refresh_wake(self, i: int, heap: List) -> None:
        # silenced nodes are frozen: dispatches may still stage work on
        # them (that is the limbo), but nothing must ever advance them
        if self.silenced[i]:
            return
        nxt = self.replicas[i].next_event_time()
        if self.wake[i] == nxt:
            return
        self.wake[i] = nxt
        if nxt is not None:
            heapq.heappush(heap, (nxt, self.WAKE, i, 0))

    def _run_epoch(self, first: Tuple, heap: List, parked: List[int],
                   next_boundary: int) -> None:
        """Mirrors Orchestrator::run_epoch: pop the maximal run of WAKE
        events leading the heap (the *epoch* — everything scheduled
        before the next control-plane event), stale-filtering and
        parking exactly like the sequential arm, advance the batch, and
        apply every merge effect (wake re-arming, parking) in
        replica-index order. The stale filter guarantees each replica
        appears at most once per epoch; a node busy exactly at the
        boundary after advancing parks directly (the sequential loop
        re-pushes a same-time wake and immediately pops + parks it —
        same end state)."""
        batch: List[int] = []
        ev: Optional[Tuple] = first
        while ev is not None:
            t, _, ridx, _ = ev
            if self.wake[ridx] == t:
                self.wake[ridx] = None
                if self.advanced_to[ridx] == next_boundary:
                    parked.append(ridx)
                else:
                    batch.append(ridx)
            ev = (heapq.heappop(heap)
                  if heap and heap[0][1] == self.WAKE else None)
        if self.epoch_log is not None:
            self.epoch_log.append(list(batch))
        assert all(not self.silenced[i] for i in batch), \
            "silenced replicas are frozen and must not wake inside an epoch"
        costs: Optional[List[Tuple[int, float]]] = None
        if self.epoch_costs is not None:
            costs = []
            self.epoch_costs.append(costs)
        for i in batch:
            if costs is None:
                self._advance(i, next_boundary)
            else:
                t0 = _time.perf_counter()
                self._advance(i, next_boundary)
                costs.append((i, _time.perf_counter() - t0))
        batch.sort()
        for i in batch:
            nxt = self.replicas[i].next_event_time()
            if nxt is None:
                continue
            if nxt > next_boundary:
                self.wake[i] = nxt
                heapq.heappush(heap, (nxt, self.WAKE, i, 0))
            else:
                parked.append(i)

    def run(self, workload: List[Task], drain: int):
        assert all(a.arrival <= b.arrival for a, b in zip(workload, workload[1:]))
        last = workload[-1].arrival if workload else 0
        return self._run_events(iter(workload), last + drain, drain)

    def run_stream(self, arrivals: Iterable[Task], drain: int):
        """Mirrors Orchestrator::run_stream: drive a lazily generated
        arrival stream without materializing it — O(live set) memory.
        Lifecycle schedules need the horizon upfront, which a stream
        cannot provide, so streaming runs are static fleets (the
        autoscaler, which is schedule-free, is the exception in Rust
        too — but the pinned streaming cells keep it off)."""
        assert self.factory is None, \
            "streaming runs use static fleets (no lifecycle schedule)"
        return self._run_events(iter(arrivals), None, drain)

    def _run_events(self, arrivals, lifecycle_horizon: Optional[int],
                    drain: int):
        ctl = self.ctl
        # refined to `last pulled arrival + drain` when the stream
        # ends; until then only boundary bookkeeping reads it
        horizon = drain
        last_seen = 0
        boot_delay = self.lifecycle.autoscaler.boot_delay
        pending_boots: deque = deque()
        self._migration_check_at = None
        heap: List = []
        parked: List[int] = []
        # the lifecycle stream mirrors the arrival stream: one event in
        # the heap at a time, the next pushed when it pops
        lifecycle_events = iter(
            self.lifecycle.schedule(lifecycle_horizon)
            if lifecycle_horizon is not None else ())
        target_rng = self.lifecycle.target_rng()
        next_lifecycle = next(lifecycle_events, None)
        if next_lifecycle is not None:
            heapq.heappush(heap, (next_lifecycle.time, self.LIFECYCLE, 0, 0))
        # the heartbeat stream mirrors the lifecycle stream: one tick in
        # the heap at a time, the next pushed when it pops, ticks
        # strictly before the horizon (only with an active detector — an
        # inert one schedules nothing, the bit-exactness gate)
        hb_interval = (self.lifecycle.detector.heartbeat_interval
                       if self.detector is not None else None)
        next_heartbeat: Optional[int] = None
        if (hb_interval is not None and lifecycle_horizon is not None
                and hb_interval < lifecycle_horizon):
            next_heartbeat = hb_interval
            heapq.heappush(heap, (hb_interval, self.HEARTBEAT, 0, 0))
        nxt = next(arrivals, None)
        next_arrival = nxt
        if nxt is not None:
            last_seen = nxt.arrival
            arrival_boundary = nxt.arrival
            heapq.heappush(heap, (nxt.arrival, self.ARRIVAL, 0, nxt.id))
        else:
            horizon = last_seen + drain
            arrival_boundary = horizon
            heapq.heappush(heap, (horizon, self.BOUNDARY, 0, 0))

        def eff(arrival: int) -> int:
            # the effective boundary every wake advances its node to:
            # the next arrival, the next fleet change, or the next
            # heartbeat tick, whichever is first — a node must never
            # run past a crash instant, and a confirmation's evacuation
            # must not land on nodes already advanced past the tick
            # (with the detector off the heartbeat term is always None:
            # the boundary is byte-identical to the pre-detector engine)
            b = arrival
            if next_lifecycle is not None:
                b = min(b, next_lifecycle.time)
            if next_heartbeat is not None:
                b = min(b, next_heartbeat)
            return b

        next_boundary = eff(arrival_boundary)
        while True:
            time, kind, ridx, tid = heapq.heappop(heap)
            if kind == self.WAKE:
                if self.threads > 1:
                    self._run_epoch((time, kind, ridx, tid), heap, parked,
                                    next_boundary)
                    continue
                if self.wake[ridx] != time:
                    continue  # stale: the replica's horizon moved
                self.wake[ridx] = None
                if self.advanced_to[ridx] == next_boundary:
                    parked.append(ridx)
                    continue
                self._advance(ridx, next_boundary)
                t = self.replicas[ridx].next_event_time()
                if t is not None:
                    self.wake[ridx] = t
                    heapq.heappush(heap, (t, self.WAKE, ridx, 0))
            elif kind == self.ARRIVAL:
                task = next_arrival
                next_arrival = None
                assert task is not None and task.id == tid
                if ctl.migration or self.autoscaler is not None:
                    # migration (and shrink evacuation) reads every
                    # replica's clock: idle ones never woke, so sync
                    # them to the boundary first
                    for i, r in enumerate(self.replicas):
                        if (self.advanced_to[i] != time
                                and r.next_event_time() is None):
                            r.sync_clock(time)
                if self.health is not None:
                    # fold in this boundary's lag *before* anything
                    # decides, so migration targets and the routing
                    # pick see the same verdicts
                    for r in self.replicas:
                        if ctl.is_alive(r.id):
                            self.health.observe(r.id, r.cycle_lag())
                    self.health.fill_mask(ctl.degraded)
                # migration passes no longer run inline here: a
                # same-time MIGRATION_CHECK (armed only while some
                # replica is overloaded) already popped and ran them —
                # at every boundary where the lockstep pass would have
                # acted, and only those
                #
                # the arriving task's per-cycle quota, read before the
                # decision (the headroom-mode autoscaler aggregates the
                # fleet's Eq. 7 headroom for exactly this quota)
                quota = (task.slo.tokens_per_cycle()
                         if self.lifecycle.autoscaler.grow_on_headroom
                         else 0)
                pick = ctl.decide(task)
                if pick is None:
                    ctl.reject(task)
                else:
                    self.replicas[pick].assign(task)
                # the autoscaler observes the decision's outcome (after
                # the assign: the picked replica no longer reads as
                # idle, so it cannot be the shrink victim)
                scaled = False
                if self.autoscaler is not None:
                    deficit = pick is None
                    if not deficit and not ctl.admission.enabled:
                        # without admission nothing is ever shed; the
                        # signal falls back to "every placeable replica
                        # is overrunning"
                        deficit = all(r.overloaded() for r in self.replicas
                                      if ctl.placeable(r.id))
                    if self.lifecycle.autoscaler.grow_on_headroom:
                        # headroom mode replaces the shed/overload
                        # deficit with the aggregate Eq. 7 signal: mean
                        # cycle headroom across the placeable fleet for
                        # this arrival's quota, measured after the
                        # assignment. A shed still registers — it means
                        # zero placeable headroom, so the mean is zero
                        # too. Compared multiplied out so integer
                        # division cannot round the signal.
                        sum_h, n_h = 0, 0
                        for r in self.replicas:
                            if ctl.placeable(r.id):
                                sum_h += r.headroom(quota)
                                n_h += 1
                        floor = self.lifecycle.autoscaler.headroom_min
                        deficit = n_h == 0 or sum_h <= floor * n_h
                    # shrink victim: an alive replica with no work at
                    # all — prefer degraded, then highest index. An
                    # unresponsive (silenced, undetected) corpse cannot
                    # acknowledge a shrink: skipped
                    idle = None
                    for i, r in enumerate(self.replicas):
                        if (ctl.is_alive(i) and not ctl.is_unresponsive(i)
                                and r.next_event_time() is None):
                            key = (ctl.is_degraded(i), i)
                            if idle is None or key > idle:
                                idle = key
                    # booting replicas count toward the observed fleet
                    # size so the autoscaler cannot overshoot
                    # max_replicas while grows are in flight (empty
                    # when boot_delay is 0 — the bit-exact default)
                    decision = self.autoscaler.observe(
                        time, deficit,
                        idle[1] if idle is not None else None,
                        ctl.alive_count() + len(pending_boots))
                    if decision == "grow":
                        ctl.autoscale_grows += 1
                        if boot_delay == 0:
                            self._admit_replica(time)
                            scaled = True
                        else:
                            # deferred: the replica joins when its
                            # Boot event fires
                            at = time + boot_delay
                            pending_boots.append(at)
                            heapq.heappush(heap, (at, self.BOOT, 0, 0))
                    elif decision is not None:  # ("shrink", victim)
                        ctl.autoscale_shrinks += 1
                        self._retire_replica(decision[1], False)
                        scaled = True
                # advance the boundary and queue its event BEFORE
                # re-arming wakes, so fresh wakes park against the new
                # boundary rather than the one just consumed
                nxt = next(arrivals, None)
                next_arrival = nxt
                if nxt is not None:
                    assert nxt.arrival >= last_seen, \
                        "arrivals must be time-ordered"
                    last_seen = nxt.arrival
                    arrival_boundary = nxt.arrival
                    heapq.heappush(heap, (nxt.arrival, self.ARRIVAL, 0, nxt.id))
                else:
                    horizon = last_seen + drain
                    arrival_boundary = horizon
                    heapq.heappush(heap, (horizon, self.BOUNDARY, 0, 0))
                next_boundary = eff(arrival_boundary)
                if scaled:
                    for i in range(len(self.replicas)):
                        self._refresh_wake(i, heap)
                    parked.clear()
                else:
                    for i in parked:
                        self._refresh_wake(i, heap)
                    del parked[:]
                    if pick is not None:
                        self._refresh_wake(pick, heap)
                if ctl.migration:
                    # only this arrival's destination (or, after a
                    # scale event, anything) can have gained load
                    if scaled:
                        self._refresh_overload_all()
                    elif pick is not None:
                        self._refresh_overload(pick)
                    self._arm_migration_check(heap, arrival_boundary,
                                              next_arrival is not None)
            elif kind == self.LIFECYCLE:
                e = next_lifecycle
                assert e is not None and e.time == time
                # same contract as the arrival boundary: evacuated
                # tasks may land on idle peers, whose clocks must be at
                # the event time first (uncounted moves)
                for i, r in enumerate(self.replicas):
                    if (self.advanced_to[i] != time
                            and r.next_event_time() is None):
                        r.sync_clock(time)
                self._apply_lifecycle(e, time, target_rng)
                next_lifecycle = next(lifecycle_events, None)
                if next_lifecycle is not None:
                    heapq.heappush(
                        heap, (next_lifecycle.time, self.LIFECYCLE, 0, 0))
                next_boundary = eff(arrival_boundary)
                # the fleet changed shape: re-arm everything (clears a
                # dead replica's stale wake, arms a joiner and every
                # evacuation destination)
                for i in range(len(self.replicas)):
                    self._refresh_wake(i, heap)
                parked.clear()
                if ctl.migration:
                    # evacuations may have overloaded destinations
                    self._refresh_overload_all()
                    self._arm_migration_check(heap, arrival_boundary,
                                              next_arrival is not None)
            elif kind == self.BOOT:
                due = pending_boots.popleft()
                assert due == time, "boot event without its pending boot"
                # bounds re-check at boot time: explicit joins may have
                # filled the fleet since the grow was decided (the grow
                # stays counted; the boot is dropped)
                if ctl.alive_count() < self.lifecycle.max_replicas:
                    self._admit_replica(time)
                # the joiner is idle: no wake to arm, no load moved
            elif kind == self.MIGRATION_CHECK:
                self._migration_check_at = None
                ctl.migration_checks += 1
                # idle-clock sync first — the same contract as the
                # arrival boundary (a migrated-in task may carry an
                # arrival time earlier than this boundary, so an idle
                # destination's clock must be here before the task
                # lands), and the exact order the old inline pass saw
                for i, r in enumerate(self.replicas):
                    if (self.advanced_to[i] != time
                            and r.next_event_time() is None):
                        r.sync_clock(time)
                # the shadow may be stale-true (service progress since
                # arming drained the overload): re-check against live
                # state before paying for a pass
                self._refresh_overload_all()
                if self.overload_count > 0:
                    ctl.run_migrations()
                    ctl.run_running_migrations()
                    # migration may have moved work between any pair:
                    # refresh the shadow and re-arm the fleet
                    self._refresh_overload_all()
                    for i in range(len(self.replicas)):
                        self._refresh_wake(i, heap)
                    parked.clear()
                # no re-arm here even if overload persists: the
                # same-time arrival's handler arms the *next* boundary —
                # the lockstep one-pass-per-boundary cadence, and no
                # same-time check storm
            elif kind == self.HEARTBEAT:
                assert next_heartbeat == time
                det = self.detector
                assert det is not None, \
                    "heartbeat events only fire with a detector"
                # functioning replicas emit this tick's heartbeats,
                # delayed by their current Eq. 7 cycle lag — an
                # overloaded replica heartbeats late (the organic
                # false-suspicion source), a corpse not at all
                for i, r in enumerate(self.replicas):
                    if ctl.is_alive(i) and not self.silenced[i]:
                        det.emit(i, time, r.cycle_lag())
                # one suspicion step per believed-alive replica;
                # confirmation (ground-truth gated) is deferred so every
                # verdict this tick judges the same fleet
                confirmed: List[int] = []
                for i in range(len(self.replicas)):
                    if not ctl.is_alive(i):
                        continue
                    verdict = det.tick(i, time, self.silenced[i])
                    if verdict == SUSPECT:
                        ctl.suspicions += 1
                        ctl.suspected[i] = True
                    elif verdict == UNSUSPECT:
                        ctl.false_suspicions += 1
                        ctl.suspected[i] = False
                    elif verdict == CONFIRM:
                        confirmed.append(i)
                if confirmed:
                    # same contract as the lifecycle boundary: recovered
                    # tasks may land on idle peers, whose clocks must be
                    # at the tick first
                    for i, r in enumerate(self.replicas):
                        if (self.advanced_to[i] != time
                                and r.next_event_time() is None):
                            r.sync_clock(time)
                    for i in confirmed:
                        if ctl.alive_count() <= 1:
                            # never confirm the last believed-alive
                            # replica (unreachable while min_replicas
                            # >= 1; defer to next tick)
                            continue
                        self._confirm_dead(i, time, heap)
                    # confirmation moved work (requeue, evacuation,
                    # retries): re-arm the fleet, like a lifecycle
                    for i in range(len(self.replicas)):
                        self._refresh_wake(i, heap)
                    parked.clear()
                    if ctl.migration:
                        self._refresh_overload_all()
                        self._arm_migration_check(heap, arrival_boundary,
                                                  next_arrival is not None)
                next_heartbeat = None
                if hb_interval is not None and lifecycle_horizon is not None:
                    nt = time + hb_interval
                    if nt < lifecycle_horizon:
                        next_heartbeat = nt
                        heapq.heappush(heap, (nt, self.HEARTBEAT, 0, 0))
                next_boundary = eff(arrival_boundary)
            elif kind == self.RETRY:
                task = self.limbo.pop(tid)
                # idle-clock sync first — the retried task carries its
                # original arrival time (same contract as the migration
                # check)
                for i, r in enumerate(self.replicas):
                    if (self.advanced_to[i] != time
                            and r.next_event_time() is None):
                        r.sync_clock(time)
                attempt = self.attempts.get(tid, 0) + 1
                self.attempts[tid] = attempt
                ctl.retries += 1
                # full admission: a retry competes like any fresh
                # arrival — and may land on another not-yet-detected
                # corpse, re-entering limbo there with its attempt count
                # intact (the budget is global, not per-host)
                pick = ctl.decide(task)
                if pick is not None:
                    self.replicas[pick].receive_migrated(task)
                    self._refresh_wake(pick, heap)
                    if ctl.migration:
                        self._refresh_overload(pick)
                        self._arm_migration_check(heap, arrival_boundary,
                                                  next_arrival is not None)
                else:
                    dcfg = self.lifecycle.detector
                    # exponential backoff: attempt k + 1 fires
                    # retry_backoff << (k - 1) after attempt k fails
                    # (saturating — never wraps)
                    factor = 1 << min(attempt - 1, 63)
                    nxt_t = min(time + min(dcfg.retry_backoff * factor,
                                           MASK64), MASK64)
                    runway = (lifecycle_horizon is not None
                              and nxt_t < lifecycle_horizon)
                    if attempt < dcfg.max_retries and runway:
                        heapq.heappush(heap, (nxt_t, self.RETRY, 0, tid))
                        self.limbo[tid] = task
                    else:
                        # budget or runway exhausted: shed, reported as
                        # a retry_exhausted loss
                        ctl.retry_exhausted += 1
                        ctl.reject(task)
            else:  # BOUNDARY — the final drain at the horizon
                assert time == horizon
                # limbo tasks whose next retry fell past the horizon
                # drain as shed losses (sorted by id: dict order must
                # not leak into reports)
                if self.limbo:
                    for gid in sorted(self.limbo):
                        ctl.limbo_lost += 1
                        ctl.reject(self.limbo[gid])
                    self.limbo.clear()
                for i, r in enumerate(self.replicas):
                    if self.silenced[i]:
                        # an unconfirmed corpse: frozen at its crash
                        # clock, its queue (pre-crash work and limbo
                        # dispatches alike) dies with it, and its
                        # in-service tasks stay in its report as
                        # unfinished — the drained assert below does not
                        # apply
                        for task in r.withdraw_all():
                            ctl.limbo_lost += 1
                            ctl.reject(task)
                        continue
                    if self.advanced_to[i] == horizon:
                        pass
                    elif self.advancements[i] > 0 or self.wake[i] is not None:
                        self._advance(i, horizon)
                    else:
                        r.sync_clock(horizon)
                    assert r.pending() == 0, "drain window too small"
                break
        ctl.autoscale_pending_boots = len(pending_boots)
        per_replica = [(r.id, r.routed, r.server.steps) for r in self.replicas]
        tasks = [t for r in self.replicas for t in r.finish()]
        tasks.extend(ctl.rejected)
        tasks.sort(key=lambda t: t.id)
        return tasks, per_replica


def _default_policy(profile: DeviceProfile, memory: Optional[MemoryConfig] = None,
                    incremental: bool = True):
    lat = LatencyModel(profile.latency.points, profile.latency.prefill_points,
                       min(32, profile.max_batch))
    return SlicePolicy(lat, cycle_cap=profile.cycle_cap, memory=memory,
                       kv_capacity=profile.kv_capacity, incremental=incremental)


def run_cluster(strategy: str, replicas: int, workload: List[Task],
                drain: int, make_policy: Optional[Callable] = None):
    """Homogeneous fleet of standard devices (the PR 2 shape)."""
    profiles = [DeviceProfile.standard() for _ in range(replicas)]
    tasks, per, _router = run_fleet(strategy, profiles, workload, drain, make_policy)
    return tasks, per


def run_fleet(strategy: str, profiles: List[DeviceProfile], workload: List[Task],
              drain: int, make_policy: Optional[Callable] = None,
              admission: Optional[AdmissionConfig] = None,
              migration: bool = False,
              migrate_running: bool = False,
              memory: Optional[MemoryConfig] = None,
              engine: str = "lockstep",
              lifecycle: Optional[LifecycleConfig] = None,
              threads: int = 1):
    """Mirrors experiments::run_fleet. Returns (tasks, per_replica) plus
    shed/migration/elastic counters via the returned router's
    attributes. engine="event" drives the same Router decisions through
    the heap-scheduled Orchestrator (bit-exact with "lockstep"). When
    any elastic feature is enabled (`lifecycle.any_enabled()`) the
    event engine attaches the lifecycle machinery; replicas that join
    mid-run clone the fleet's first profile (the standard tier)."""
    # thread the base capacity into a *copy* of the spec (the Rust
    # run_fleet clones; mutating the caller's profiles would leak stale
    # capacities across calls) unless it already carries explicit ones
    if (memory is not None and memory.kv_capacity is not None
            and all(p.kv_capacity is None for p in profiles)):
        import copy

        profiles = [copy.copy(p) for p in profiles]
        for p in profiles:
            p.kv_capacity = int(memory.kv_capacity * p.kv_fraction)
    if make_policy is None:
        def mk(profile):
            return _default_policy(profile, memory)
    else:
        mk = make_policy
    fleet = [Replica(i, mk, p, memory=memory) for i, p in enumerate(profiles)]
    router = Router("round-robin" if strategy == "rr" else strategy, fleet,
                    admission=admission, migration=migration,
                    migrate_running=migrate_running, memory=memory or MemoryConfig())
    if engine == "event":
        orch_lc = None
        factory = None
        if lifecycle is not None and lifecycle.any_enabled():
            import copy

            template = profiles[0]

            def factory(rid, _mk=mk, _p=template, _mem=memory):
                return Replica(rid, _mk, copy.copy(_p), memory=_mem)

            orch_lc = lifecycle
        tasks, per = Orchestrator(router, lifecycle=orch_lc,
                                  factory=factory,
                                  threads=threads).run(workload, drain)
    else:
        assert engine == "lockstep", f"unknown cluster engine {engine!r}"
        assert lifecycle is None or not lifecycle.any_enabled(), \
            "elastic fleets need the event engine"
        assert threads <= 1, "epoch workers only exist in the event engine"
        tasks, per = router.run(workload, drain)
    return tasks, per, router


def run_fleet_stream(strategy: str, profiles: List[DeviceProfile],
                     arrivals: Iterable[Task], drain: int,
                     admission: Optional[AdmissionConfig] = None,
                     migration: bool = False,
                     fold_rejects: bool = True):
    """Mirrors experiments::scale_sweep run_stream_cell's engine setup:
    a static fleet driven by Orchestrator.run_stream over a pull-based
    arrival stream, shedding folded into a counter so a million-task
    trace never materializes (O(live set) memory)."""
    fleet = [Replica(i, lambda p: _default_policy(p), p)
             for i, p in enumerate(profiles)]
    router = Router("round-robin" if strategy == "rr" else strategy, fleet,
                    admission=admission, migration=migration,
                    migrate_running=False, memory=MemoryConfig())
    router.fold_rejects = fold_rejects
    tasks, per = Orchestrator(router).run_stream(arrivals, drain)
    return tasks, per, router


# --------------------------------------------------------------- metrics --


def quantile(sorted_xs: List[float], q: float) -> float:
    if not sorted_xs:
        return float("nan")
    pos = max(0.0, min(1.0, q)) * (len(sorted_xs) - 1)
    lo, hi = int(math.floor(pos)), int(math.ceil(pos))
    if lo == hi:
        return sorted_xs[lo]
    frac = pos - lo
    return sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac


def attainment(tasks: Iterable[Task]) -> dict:
    ts = list(tasks)
    rt = [t for t in ts if t.is_real_time()]
    nrt = [t for t in ts if not t.is_real_time()]

    def frac(num, den):
        return float("nan") if den == 0 else num / den

    return {
        "n_tasks": len(ts),
        "n_finished": sum(t.is_finished() and not t.shed for t in ts),
        "slo": frac(sum(t.slo_met() for t in ts), len(ts)),
        "rt_slo": frac(sum(t.slo_met() for t in rt), len(rt)),
        "rt_count": len(rt),
        "nrt_slo": frac(sum(t.slo_met() for t in nrt), len(nrt)),
        "nrt_count": len(nrt),
        "nrt_ttft": frac(
            sum(t.is_finished() and not t.shed and t.ttft_met() for t in nrt),
            len(nrt)
        ),
        "nrt_tpot": frac(
            sum(t.is_finished() and not t.shed and t.tpot_met() for t in nrt),
            len(nrt)
        ),
    }


def latency_summary(tasks: Iterable[Task]) -> dict:
    ts = [t for t in tasks if t.is_finished() and not t.shed]
    ttft = sorted(t.ttft() / 1e3 for t in ts if t.ttft() is not None)
    tpot = sorted(t.avg_tpot() / 1e3 for t in ts if t.avg_tpot() is not None)

    def pcts(xs):
        return {
            "n": len(xs),
            "mean_ms": sum(xs) / len(xs) if xs else float("nan"),
            "p50_ms": quantile(xs, 0.50),
            "p95_ms": quantile(xs, 0.95),
            "p99_ms": quantile(xs, 0.99),
        }

    return {"ttft": pcts(ttft), "tpot": pcts(tpot)}
