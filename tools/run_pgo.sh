#!/usr/bin/env bash
# Profile-guided-optimization build for slice-serve (see perf.md).
#
# Three phases:
#   1. build an instrumented binary (-Cprofile-generate),
#   2. train it on the streaming scale sweep — the control-plane hot
#      path the bench-regression gate measures (10k tasks through the
#      event engine with folded rejects),
#   3. merge the raw profiles and rebuild with -Cprofile-use.
#
# Requirements: a stable Rust toolchain with the llvm-tools component
# (for llvm-profdata). No external dependencies; everything runs
# offline. The optimized binary lands in the default release path
# (target/release/slice-serve) so `cargo run --release` and the bench
# harness pick it up unchanged.
#
# Usage:
#   tools/run_pgo.sh            # train on the default 10k streaming cell
#   TRAIN_TASKS=100000 tools/run_pgo.sh
#
# Combine with the parallel event engine at run time:
#   target/release/slice-serve experiment scale --tasks 100000 \
#     --replicas 256 --threads 4

set -euo pipefail
cd "$(dirname "$0")/.."

TRAIN_TASKS="${TRAIN_TASKS:-10000}"
PGO_DIR="${PGO_DIR:-target/pgo-profiles}"

# llvm-profdata ships with the llvm-tools rustup component; fall back
# to a system binary if the component is not installed.
SYSROOT="$(rustc --print sysroot)"
PROFDATA="$(find "$SYSROOT" -name llvm-profdata -type f 2>/dev/null | head -n1 || true)"
if [ -z "$PROFDATA" ]; then
    if command -v llvm-profdata >/dev/null 2>&1; then
        PROFDATA="llvm-profdata"
    else
        echo "error: llvm-profdata not found." >&2
        echo "  rustup component add llvm-tools   # or install LLVM" >&2
        exit 1
    fi
fi

rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"
ABS_PGO_DIR="$(cd "$PGO_DIR" && pwd)"

echo "== phase 1: instrumented build =="
RUSTFLAGS="-Cprofile-generate=$ABS_PGO_DIR" \
    cargo build --release

echo "== phase 2: training run (streaming scale, $TRAIN_TASKS tasks) =="
# The training workload is the streaming control-plane cell: pull-based
# arrivals, headroom admission, migration, folded rejects — the same
# shape BENCH_8.json and the CI regression gate measure.
target/release/slice-serve experiment scale \
    --tasks "$TRAIN_TASKS" --stream --out /dev/null

echo "== phase 3: merge profiles + optimized rebuild =="
"$PROFDATA" merge -o "$ABS_PGO_DIR/merged.profdata" "$ABS_PGO_DIR"
RUSTFLAGS="-Cprofile-use=$ABS_PGO_DIR/merged.profdata" \
    cargo build --release

echo "== done: PGO-optimized binary at target/release/slice-serve =="
echo "verify with e.g.:"
echo "  target/release/slice-serve experiment scale --tasks 10000 --stream"
