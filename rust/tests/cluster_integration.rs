//! Cluster-layer integration tests: determinism, single-replica
//! equivalence with the single-device path, routing-quality ordering,
//! and coverage invariants (the ISSUE-2 acceptance contract).

use slice_serve::cluster::RoutingStrategy;
use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::coordinator::task::Task;
use slice_serve::experiments::{default_drain, run_cluster, run_sim};
use slice_serve::metrics::Attainment;
use slice_serve::workload::WorkloadSpec;

fn workload(rate: f64, n: usize, seed: u64) -> Vec<Task> {
    WorkloadSpec::paper_mix(rate, 0.7, n, seed).generate()
}

fn cfg() -> ServeConfig {
    ServeConfig::default()
}

/// (a) Cluster runs are deterministic for a fixed seed: two identical
/// runs produce identical per-task records and identical routing.
#[test]
fn cluster_runs_are_deterministic() {
    for strategy in RoutingStrategy::ALL {
        let a = run_cluster(strategy, 3, workload(2.0, 150, 5), &cfg(), default_drain())
            .unwrap();
        let b = run_cluster(strategy, 3, workload(2.0, 150, 5), &cfg(), default_drain())
            .unwrap();
        let (ta, tb) = (a.tasks(), b.tasks());
        assert_eq!(ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.id, y.id, "{strategy:?} routed differently");
            assert_eq!(x.first_token, y.first_token);
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.tokens_generated, y.tokens_generated);
        }
        for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(ra.routed, rb.routed);
            assert_eq!(ra.report.steps, rb.report.steps);
        }
    }
}

/// (b) A 1-replica cluster reproduces the single-server result exactly:
/// same per-task timing records, token counts and engine step totals.
#[test]
fn single_replica_matches_single_server() {
    for kind in [PolicyKind::Slice, PolicyKind::Orca, PolicyKind::FastServe] {
        let cfg = ServeConfig { policy: kind, ..ServeConfig::default() };
        let wl = workload(1.0, 120, 9);
        let single = run_sim(kind, wl.clone(), &cfg, default_drain()).unwrap();
        for strategy in RoutingStrategy::ALL {
            let cluster = run_cluster(strategy, 1, wl.clone(), &cfg, default_drain())
                .unwrap();
            let tasks = cluster.tasks();
            assert_eq!(tasks.len(), single.tasks.len());
            for (s, c) in single.tasks.iter().zip(&tasks) {
                assert_eq!(s.id, c.id);
                assert_eq!(s.first_token, c.first_token, "{kind:?}/{strategy:?}");
                assert_eq!(s.last_token, c.last_token);
                assert_eq!(s.completion, c.completion);
                assert_eq!(s.tokens_generated, c.tokens_generated);
                assert_eq!(s.max_token_gap, c.max_token_gap);
            }
            assert_eq!(cluster.total_steps(), single.steps, "{kind:?}/{strategy:?}");
        }
    }
}

/// (c) On a heterogeneous SLO mix at equal load, SLO-aware routing
/// attains at least round-robin's fleet attainment.
#[test]
fn slo_aware_routing_at_least_round_robin() {
    // Equal per-replica pressure: 4 replicas at 4x the single-device
    // saturation rate, heterogeneous paper mix (RT deadlines + voice +
    // text Q&A SLOs).
    let cfg = cfg();
    let wl = || workload(4.0, 480, 42);
    let rr = run_cluster(RoutingStrategy::RoundRobin, 4, wl(), &cfg, default_drain())
        .unwrap();
    let slo = run_cluster(RoutingStrategy::SloAware, 4, wl(), &cfg, default_drain())
        .unwrap();
    let (a_rr, a_slo) = (rr.fleet_attainment(), slo.fleet_attainment());
    assert!(
        a_slo.slo >= a_rr.slo,
        "slo-aware fleet attainment {} < round-robin {}",
        a_slo.slo,
        a_rr.slo
    );
    assert!(
        a_slo.rt_slo >= a_rr.rt_slo,
        "slo-aware RT attainment {} < round-robin {}",
        a_slo.rt_slo,
        a_rr.rt_slo
    );
}

/// Every task is routed exactly once, to exactly one replica, for every
/// strategy and fleet width.
#[test]
fn routing_covers_workload_exactly_once() {
    for strategy in RoutingStrategy::ALL {
        for n in [1usize, 2, 4, 7] {
            let report =
                run_cluster(strategy, n, workload(2.0, 90, 13), &cfg(), default_drain())
                    .unwrap();
            assert_eq!(report.replicas.len(), n);
            assert_eq!(
                report.routed_ids(),
                (0..90).collect::<Vec<u64>>(),
                "{strategy:?}/{n} lost or duplicated tasks"
            );
            let routed_sum: usize = report.replicas.iter().map(|r| r.routed).sum();
            assert_eq!(routed_sum, 90);
        }
    }
}

/// Adding replicas at fixed total load never hurts fleet attainment
/// (capacity monotonicity sanity check for the SLO-aware strategy).
#[test]
fn more_replicas_do_not_hurt_attainment() {
    let cfg = cfg();
    let wl = || workload(3.0, 240, 21);
    let one = run_cluster(RoutingStrategy::SloAware, 1, wl(), &cfg, default_drain())
        .unwrap()
        .fleet_attainment();
    let four = run_cluster(RoutingStrategy::SloAware, 4, wl(), &cfg, default_drain())
        .unwrap()
        .fleet_attainment();
    assert!(
        four.slo >= one.slo,
        "4 replicas {} < 1 replica {}",
        four.slo,
        one.slo
    );
    assert!(four.n_finished >= one.n_finished);
}

/// Fleet attainment equals attainment computed over the union of
/// per-replica task sets (no double counting in aggregation).
#[test]
fn fleet_attainment_consistent_with_replica_reports() {
    let report = run_cluster(
        RoutingStrategy::LeastLoaded,
        3,
        workload(2.0, 120, 33),
        &cfg(),
        default_drain(),
    )
    .unwrap();
    let fleet = report.fleet_attainment();
    let mut all: Vec<Task> = report
        .replicas
        .iter()
        .flat_map(|r| r.report.tasks.iter().cloned())
        .collect();
    all.sort_by_key(|t| t.id);
    let manual = Attainment::compute(&all);
    assert_eq!(fleet.n_tasks, manual.n_tasks);
    assert_eq!(fleet.n_finished, manual.n_finished);
    assert_eq!(fleet.slo, manual.slo);
}
