//! Property-based tests over the coordinator's core invariants
//! (the proptest crate is unavailable offline; properties are driven by
//! the in-repo deterministic RNG with many random cases per property,
//! and every failure prints the case's seed for replay).

use slice_serve::cluster::{Event, EventHeap, EventKind, Orchestrator, RoutingStrategy};
use slice_serve::coordinator::mask::{period_eq7, DecodeMask, IncrementalPeriod};
use slice_serve::coordinator::selection::{select_tasks, Candidate, CYCLE_CAP};
use slice_serve::coordinator::task::{SloSpec, Task, TaskClass};
use slice_serve::engine::latency::LatencyModel;
use slice_serve::util::json::Json;
use slice_serve::util::rng::Rng;
use slice_serve::util::secs;
use slice_serve::workload::trace;

const CASES: u64 = 300;

fn random_candidates(rng: &mut Rng, n: usize) -> Vec<Candidate> {
    (0..n)
        .map(|i| Candidate {
            id: i as u64,
            utility: rng.range_u64(1, 1000) as f64 / 10.0,
            tpot: rng.range_u64(40, 400) * 1_000,
            kv_bytes: rng.range_u64(1, 32) * 512 * 1024,
        })
        .collect()
}

/// Selection admits a feasible set: the Eq. 7 period of the admitted
/// quotas is always under the cycle cap, and one more admission from the
/// rejected pool would break it (greedy maximality at the stop point).
#[test]
fn prop_selection_feasible_and_maximal_at_stop() {
    let lat = LatencyModel::paper_calibrated();
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let n = rng.range_usize(1, 40);
        let cands = random_candidates(&mut rng, n);
        let sel = select_tasks(&cands, &lat, CYCLE_CAP, None);

        let mut quotas: Vec<u32> = sel.selected.iter().map(|&(_, q)| q).collect();
        quotas.sort_unstable_by(|a, b| b.cmp(a));
        let period = period_eq7(&quotas, &lat);
        assert!(period < CYCLE_CAP, "seed {seed}: period {period} >= cap");

        // admitted + rejected partition the candidates
        assert_eq!(sel.selected.len() + sel.rejected.len(), n, "seed {seed}");
    }
}

/// The mask matrix conserves tokens: column batch sizes sum to the sum
/// of quotas, and Eq. 7 equals the exact column sum.
#[test]
fn prop_mask_token_conservation_and_eq7() {
    let lat = LatencyModel::paper_calibrated();
    for seed in 0..CASES {
        let mut rng = Rng::new(1_000_000 + seed);
        let n = rng.range_usize(1, 24);
        let rows: Vec<(u64, u32)> =
            (0..n).map(|i| (i as u64, rng.range_u64(1, 25) as u32)).collect();
        let quota_sum: u64 = rows.iter().map(|&(_, v)| v as u64).sum();

        let mask = DecodeMask::build(rows.clone());
        let col_sum: u64 = (0..mask.columns()).map(|j| mask.batch_len(j) as u64).sum();
        assert_eq!(col_sum, quota_sum, "seed {seed}");
        assert_eq!(mask.tokens_per_cycle(), quota_sum, "seed {seed}");

        let mut quotas: Vec<u32> = rows.iter().map(|&(_, v)| v).collect();
        quotas.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(
            mask.period_exact(&lat),
            period_eq7(&quotas, &lat),
            "seed {seed}: Eq.7 mismatch"
        );
    }
}

/// Every task appears in exactly its quota's worth of columns, and
/// column membership is monotone (if in column j, also in all j' < j).
#[test]
fn prop_mask_row_membership() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2_000_000 + seed);
        let n = rng.range_usize(1, 16);
        let rows: Vec<(u64, u32)> =
            (0..n).map(|i| (i as u64, rng.range_u64(1, 20) as u32)).collect();
        let mask = DecodeMask::build(rows.clone());
        for &(id, v) in &rows {
            let mut appearances = 0;
            let mut last_in = true;
            for j in 0..mask.columns() {
                let in_col = mask.column_batch(j).iter().any(|&(x, _)| x == id);
                if in_col {
                    assert!(last_in, "seed {seed}: non-prefix membership for {id}");
                    appearances += 1;
                } else {
                    last_in = false;
                }
            }
            assert_eq!(appearances, v, "seed {seed}: task {id} quota");
        }
    }
}

/// Selection prefers higher utility rates: any rejected candidate that
/// was skipped *before* the stop point must have a utility rate no
/// higher than every admitted candidate (greedy order property).
#[test]
fn prop_selection_respects_utility_rate_order() {
    let lat = LatencyModel::paper_calibrated();
    for seed in 0..CASES {
        let mut rng = Rng::new(3_000_000 + seed);
        let n = rng.range_usize(2, 30);
        let cands = random_candidates(&mut rng, n);
        let sel = select_tasks(&cands, &lat, CYCLE_CAP, None);
        if sel.selected.is_empty() || sel.rejected.is_empty() {
            continue;
        }
        let rate_of = |id: u64| {
            cands.iter().find(|c| c.id == id).unwrap().utility_rate()
        };
        let min_admitted = sel
            .selected
            .iter()
            .map(|&(id, _)| rate_of(id))
            .fold(f64::INFINITY, f64::min);
        // every admitted candidate has rate >= every post-stop rejected
        // candidate except possibly the single stop-triggering one
        let mut violations = 0;
        for &id in &sel.rejected {
            if rate_of(id) > min_admitted + 1e-12 {
                violations += 1;
            }
        }
        assert!(
            violations <= 1,
            "seed {seed}: {violations} rejected candidates outrank admitted ones"
        );
    }
}

/// The KV knapsack dimension never over-commits the budget, and a
/// constrained selection is always a prefix of the unconstrained one
/// (same greedy order, possibly earlier stop).
#[test]
fn prop_selection_kv_budget_respected() {
    let lat = LatencyModel::paper_calibrated();
    for seed in 0..CASES {
        let mut rng = Rng::new(8_000_000 + seed);
        let n = rng.range_usize(1, 40);
        let cands = random_candidates(&mut rng, n);
        let cap = rng.range_u64(4, 64) * 1024 * 1024;
        let constrained = select_tasks(&cands, &lat, CYCLE_CAP, Some(cap));
        let used: u64 = constrained
            .selected
            .iter()
            .map(|&(id, _)| cands[id as usize].kv_bytes)
            .sum();
        assert!(used <= cap, "seed {seed}: {used} B over the {cap} B budget");
        let unconstrained = select_tasks(&cands, &lat, CYCLE_CAP, None);
        assert_eq!(
            constrained.selected[..],
            unconstrained.selected[..constrained.selected.len()],
            "seed {seed}: constrained selection is not a prefix"
        );
    }
}

/// The incremental Eq. 7 structure stays bit-identical to both the
/// closed form and the mask's exact column sum over 500 randomized
/// insert/remove sequences, on the paper curve and on random measured
/// curves (PR 5 tentpole invariant; DESIGN.md "Scheduler hot path").
#[test]
fn prop_incremental_period_matches_eq7_and_mask() {
    for seed in 0..500u64 {
        let mut rng = Rng::new(11_000_000 + seed);
        // half the cases run on a random monotone measured-style curve
        let lat = if seed % 2 == 0 {
            LatencyModel::paper_calibrated()
        } else {
            let mut points = Vec::new();
            let mut b = 0u32;
            let mut us = rng.range_u64(1_000, 20_000);
            for _ in 0..rng.range_usize(2, 8) {
                b += rng.range_u64(1, 6) as u32;
                us += rng.range_u64(0, 30_000);
                points.push((b, us));
            }
            let max_b = points.last().unwrap().0;
            LatencyModel::from_points(points, vec![], max_b)
        };
        let mut inc = IncrementalPeriod::new(lat.clone());
        let mut live: Vec<u32> = Vec::new();
        for _ in 0..rng.range_usize(1, 30) {
            if !live.is_empty() && rng.chance(0.35) {
                let at = rng.range_usize(0, live.len() - 1);
                let q = live.swap_remove(at);
                inc.remove(q);
            } else {
                let q = rng.range_u64(1, 25) as u32;
                live.push(q);
                let probed = inc.probe(q);
                let p = inc.insert(q);
                assert_eq!(probed, p, "seed {seed}: probe != insert");
                assert_eq!(p, inc.period(), "seed {seed}");
            }
            let mut sorted = live.clone();
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            assert_eq!(
                inc.period(),
                period_eq7(&sorted, &lat),
                "seed {seed}: incremental != closed form, live={live:?}"
            );
            if !live.is_empty() {
                let rows: Vec<(u64, u32)> =
                    live.iter().enumerate().map(|(i, &v)| (i as u64, v)).collect();
                let mask = DecodeMask::build(rows);
                assert_eq!(
                    inc.period(),
                    mask.period_exact(&lat),
                    "seed {seed}: incremental != exact column sum, live={live:?}"
                );
            }
            assert_eq!(inc.len(), live.len(), "seed {seed}");
        }
    }
}

/// The maintained candidate cache stays bit-identical to a freshly
/// adapted-and-sorted rebuild from the pool after arbitrary mutation
/// sequences — random arrival batches, random departures, interleaved
/// scheduling steps (which may reschedule *or* skip) — checked after
/// every event over 500 sequences (PR 8 tentpole invariant; DESIGN.md
/// "Control-plane incrementality").
#[test]
fn prop_cached_candidates_match_fresh_rebuild() {
    use slice_serve::coordinator::pool::TaskPool;
    use slice_serve::coordinator::scheduler::Policy;
    use slice_serve::coordinator::selection::admission_entry;
    use slice_serve::coordinator::slice::SlicePolicy;
    use slice_serve::coordinator::task::TaskState;

    let lat = LatencyModel::paper_calibrated();
    for seed in 0..500u64 {
        let mut rng = Rng::new(12_000_000 + seed);
        let mut pool = TaskPool::new();
        let mut p = SlicePolicy::with_defaults(lat.clone());
        let mut live: Vec<u64> = Vec::new();
        let mut next_id: u64 = 0;
        let mut now: u64 = 0;
        for _ in 0..rng.range_usize(1, 40) {
            now += rng.range_u64(1, 50_000);
            if !live.is_empty() && rng.chance(0.3) {
                // departure: finish a random live task by hand (as the
                // serving loop would) and notify with the husk pooled
                let at = rng.range_usize(0, live.len() - 1);
                let id = live.swap_remove(at);
                let t = pool.get_mut(id);
                t.tokens_generated = t.output_len;
                t.state = TaskState::Finished;
                p.on_completion(&mut pool, &[id], now);
            } else {
                let n = rng.range_usize(1, 3);
                let ids: Vec<u64> = (0..n)
                    .map(|_| {
                        let id = next_id;
                        next_id += 1;
                        let class = match rng.range_u64(0, 2) {
                            0 => TaskClass::RealTime,
                            1 => TaskClass::Voice,
                            _ => TaskClass::TextQa,
                        };
                        let utility = rng.range_u64(1, 1000) as f64 / 10.0;
                        let out = rng.range_u64(1, 60) as u32;
                        pool.insert(Task::new(id, class, now, 16, out, utility));
                        live.push(id);
                        id
                    })
                    .collect();
                p.on_arrival(&mut pool, &ids, now);
            }
            if rng.chance(0.5) {
                let _ = p.next_step(&mut pool, now);
            }
            // the invariant: cache == fresh pool rebuild, after *every*
            // mutation (the cached path may consume it at any boundary)
            let mut expect: Vec<(u64, u64, u32)> = pool
                .iter()
                .filter(|t| !t.is_finished())
                .map(|t| admission_entry(t.utility, t.slo.tpot, t.id))
                .collect();
            expect.sort_unstable();
            assert_eq!(
                p.cached_candidates(),
                &expect[..],
                "seed {seed}: cache diverged from fresh rebuild"
            );
        }
        assert_eq!(p.full_rebuilds, 0, "seed {seed}: immutable regime rebuilt");
    }
}

/// Task SLO accounting is consistent: slo_met implies is_finished, and
/// for real-time tasks equals the deadline check.
#[test]
fn prop_task_slo_consistency() {
    for seed in 0..CASES {
        let mut rng = Rng::new(4_000_000 + seed);
        let class = match rng.range_u64(0, 2) {
            0 => TaskClass::RealTime,
            1 => TaskClass::Voice,
            _ => TaskClass::TextQa,
        };
        let out = rng.range_u64(1, 30) as u32;
        let mut t = Task::new(0, class, 0, 8, out, 1.0);
        let mut now = rng.range_u64(1_000, 500_000);
        let n_tokens = rng.range_u64(0, out as u64);
        for _ in 0..n_tokens {
            t.on_token(now);
            now += rng.range_u64(10_000, 300_000);
        }
        if t.slo_met() {
            assert!(t.is_finished(), "seed {seed}: slo_met but unfinished");
        }
        if let Some(dm) = t.deadline_met() {
            assert_eq!(dm, t.slo_met(), "seed {seed}: RT slo != deadline check");
        }
    }
}

/// JSON parser round-trips arbitrary generated documents.
#[test]
fn prop_json_round_trip_fuzz() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range_u64(0, 3) } else { rng.range_u64(0, 5) } {
            0 => Json::Num((rng.range_u64(0, 1_000_000) as f64) / 8.0),
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Str(
                (0..rng.range_usize(0, 12))
                    .map(|_| {
                        let c = rng.range_u64(32, 126) as u8 as char;
                        c
                    })
                    .collect(),
            ),
            3 => Json::Null,
            4 => Json::Arr(
                (0..rng.range_usize(0, 4))
                    .map(|_| gen_value(rng, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut obj = Json::obj();
                for i in 0..rng.range_usize(0, 4) {
                    obj = obj.set(&format!("k{i}"), gen_value(rng, depth - 1));
                }
                obj
            }
        }
    }
    for seed in 0..CASES {
        let mut rng = Rng::new(5_000_000 + seed);
        let v = gen_value(&mut rng, 3);
        for text in [v.to_string(), v.to_pretty()] {
            let back = Json::parse(&text).unwrap_or_else(|e| {
                panic!("seed {seed}: parse failed: {e}\n{text}")
            });
            assert_eq!(back, v, "seed {seed}");
        }
    }
}

/// Workload traces round-trip arbitrary SLO combinations.
#[test]
fn prop_trace_round_trip_fuzz() {
    for seed in 0..100 {
        let mut rng = Rng::new(6_000_000 + seed);
        let n = rng.range_usize(1, 30);
        let mut tasks = Vec::new();
        let mut arrival = 0u64;
        for i in 0..n {
            arrival += rng.range_u64(0, 2_000_000);
            let class = match rng.range_u64(0, 2) {
                0 => TaskClass::RealTime,
                1 => TaskClass::Voice,
                _ => TaskClass::TextQa,
            };
            let mut t = Task::new(
                i as u64,
                class,
                arrival,
                rng.range_u64(1, 64) as u32,
                rng.range_u64(1, 300) as u32,
                rng.range_u64(1, 100) as f64,
            );
            t.slo = SloSpec {
                ttft: rng.range_u64(100_000, 5_000_000),
                tpot: rng.range_u64(20_000, 500_000),
                deadline: if rng.chance(0.5) {
                    Some(rng.range_u64(500_000, 5_000_000))
                } else {
                    None
                },
            };
            tasks.push(t);
        }
        let j = trace::to_json(&tasks);
        let back = trace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.len(), tasks.len(), "seed {seed}");
        for (a, b) in tasks.iter().zip(&back) {
            assert_eq!(a.arrival, b.arrival, "seed {seed}");
            assert_eq!(a.slo.tpot, b.slo.tpot, "seed {seed}");
            assert_eq!(a.slo.deadline, b.slo.deadline, "seed {seed}");
        }
    }
}

/// The event heap is a strict priority queue under the documented
/// `(time, kind, replica, task)` order: over random interleavings of
/// pushes and pops, every pop returns exactly the minimum of the
/// elements currently in the heap — never out of order, never a
/// dropped or duplicated element (DESIGN.md "Event-driven cluster
/// engine").
#[test]
fn prop_event_heap_never_pops_out_of_order() {
    let kinds = [
        EventKind::Wake,
        EventKind::Lifecycle,
        EventKind::RescheduleBoundary,
        EventKind::Arrival,
    ];
    for seed in 0..CASES {
        let mut rng = Rng::new(12_000_000 + seed);
        let mut heap = EventHeap::new();
        let mut mirror: Vec<Event> = Vec::new();
        let mut last_popped: Option<Event> = None;
        for _ in 0..rng.range_usize(1, 60) {
            if !mirror.is_empty() && rng.chance(0.4) {
                let got = heap.pop().expect("mirror says non-empty");
                let min = *mirror.iter().min().unwrap();
                assert_eq!(got, min, "seed {seed}: pop is not the minimum");
                let at = mirror.iter().position(|e| *e == min).unwrap();
                mirror.swap_remove(at);
                if let Some(prev) = last_popped {
                    // pops between pushes are monotone in heap order
                    if prev.time == got.time {
                        assert!(prev <= got, "seed {seed}: same-time order");
                    }
                }
                last_popped = Some(got);
            } else {
                // duplicates on purpose: ties must be handled, not lost
                let e = Event {
                    time: rng.range_u64(0, 20),
                    kind: kinds[rng.range_usize(0, 3)],
                    replica: rng.range_usize(0, 4),
                    task: rng.range_u64(0, 6),
                };
                heap.push(e);
                mirror.push(e);
                last_popped = None;
            }
        }
        // drain: the remainder comes out fully sorted
        let mut drained: Vec<Event> = Vec::new();
        while let Some(e) = heap.pop() {
            drained.push(e);
        }
        assert_eq!(drained.len(), mirror.len(), "seed {seed}: element count");
        assert!(drained.windows(2).all(|w| w[0] <= w[1]), "seed {seed}: drain order");
        assert!(heap.is_empty() && heap.pop().is_none(), "seed {seed}");
    }
}

/// An idle replica receives zero advancement calls over a full run
/// (the event engine's core economy, which lockstep cannot offer):
/// with a 5-task trickle round-robined over a 12-wide fleet, the seven
/// replicas that route nothing and receive no migrations must report
/// zero `run_until` calls and zero engine steps — while every busy
/// replica is advanced at least once.
#[test]
fn prop_idle_replicas_receive_zero_advancements() {
    use slice_serve::cluster::{DeviceProfile, Replica};
    use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
    use slice_serve::engine::sim::SimEngine;

    for seed in [7u64, 42, 1234, 777] {
        // a light trickle across a wide round-robin fleet: replicas
        // beyond the task count never see work
        let n_tasks = 5;
        let width = 12;
        let workload =
            slice_serve::workload::WorkloadSpec::paper_mix(0.5, 0.7, n_tasks, seed)
                .generate();
        let replicas: Vec<Replica> = (0..width)
            .map(|i| {
                Replica::new(
                    i,
                    Box::new(SlicePolicy::new(
                        LatencyModel::paper_calibrated(),
                        SliceConfig::default(),
                    )),
                    Box::new(SimEngine::paper_calibrated()),
                    DeviceProfile::standard(),
                )
            })
            .collect();
        let (report, advancements) =
            Orchestrator::new(RoutingStrategy::RoundRobin, replicas)
                .run_counted(workload, secs(60.0))
                .unwrap();
        assert_eq!(advancements.len(), width);
        for (i, slot) in report.replicas.iter().enumerate() {
            if slot.routed == 0 && slot.migrated_in == 0 {
                assert_eq!(
                    advancements[i], 0,
                    "seed {seed}: idle replica {i} was advanced"
                );
                assert_eq!(slot.report.steps, 0, "seed {seed}: idle replica stepped");
            } else {
                assert!(advancements[i] > 0, "seed {seed}: busy replica {i} never ran");
            }
        }
        // round-robin over 12 replicas with 5 tasks: exactly 7 idle
        let idle = report.replicas.iter().filter(|s| s.routed == 0).count();
        assert_eq!(idle, width - n_tasks, "seed {seed}");
    }
}

/// Epoch batches are disjoint by replica (DESIGN.md "Parallel event
/// engine"): after the stale-wake filter, no epoch may hold two wakes
/// for the same replica — that disjointness is what lets the engine
/// hand workers non-overlapping `&mut Node` sets without locks. The
/// test also requires at least one multi-replica batch per run, so it
/// has teeth: a logging bug that produced only singleton batches (i.e.
/// a dead parallel path) would fail, not trivially pass.
#[test]
fn prop_epoch_batches_have_unique_replicas() {
    use slice_serve::cluster::{DeviceProfile, Replica};
    use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
    use slice_serve::engine::sim::SimEngine;

    for seed in [7u64, 42, 1234] {
        let width = 8usize;
        // a rate that keeps several replicas decoding at once, so
        // epochs genuinely batch
        let workload =
            slice_serve::workload::WorkloadSpec::paper_mix(6.0, 0.7, 60, seed).generate();
        let replicas: Vec<Replica> = (0..width)
            .map(|i| {
                Replica::new(
                    i,
                    Box::new(SlicePolicy::new(
                        LatencyModel::paper_calibrated(),
                        SliceConfig::default(),
                    )),
                    Box::new(SimEngine::paper_calibrated()),
                    DeviceProfile::standard(),
                )
            })
            .collect();
        let (report, _, epochs) = Orchestrator::new(RoutingStrategy::RoundRobin, replicas)
            .with_threads(4)
            .run_counted_logged(workload, secs(60.0))
            .unwrap();
        assert_eq!(report.replicas.len(), width, "seed {seed}");
        assert!(!epochs.is_empty(), "seed {seed}: parallel path logged no epochs");
        let mut widest = 0usize;
        for (i, batch) in epochs.iter().enumerate() {
            let mut seen = [false; 8];
            for &r in batch {
                assert!(r < width, "seed {seed}: epoch {i} wakes unknown replica {r}");
                assert!(
                    !seen[r],
                    "seed {seed}: epoch {i} advances replica {r} twice"
                );
                seen[r] = true;
            }
            widest = widest.max(batch.len());
        }
        assert!(
            widest >= 2,
            "seed {seed}: no epoch ever batched two replicas — parallelism is dead"
        );
    }
}

/// The documented same-time ordering contract (DESIGN.md "Elastic
/// fleets"): `Wake < Lifecycle < RescheduleBoundary < Arrival`. Nodes
/// reach a boundary before anything decides there; a fleet change at
/// `t` is visible to every same-time decision; arrivals route against
/// the already-changed fleet. Pinned both on the enum rank and on the
/// heap's actual pop order over every push permutation.
#[test]
fn prop_lifecycle_tie_break_order_contract() {
    assert!(EventKind::Wake < EventKind::Lifecycle);
    assert!(EventKind::Lifecycle < EventKind::RescheduleBoundary);
    assert!(EventKind::RescheduleBoundary < EventKind::Arrival);

    let expected = [
        EventKind::Wake,
        EventKind::Lifecycle,
        EventKind::RescheduleBoundary,
        EventKind::Arrival,
    ];
    // all 24 push orders of the four same-time kinds pop identically
    for seed in 0..CASES {
        let mut rng = Rng::new(13_000_000 + seed);
        let mut kinds = expected;
        for i in (1..kinds.len()).rev() {
            kinds.swap(i, rng.range_usize(0, i));
        }
        let mut heap = EventHeap::new();
        for kind in kinds {
            heap.push(Event { time: 5, kind, replica: 1, task: 2 });
        }
        let mut popped = Vec::new();
        while let Some(e) = heap.pop() {
            popped.push(e.kind);
        }
        assert_eq!(popped, expected, "seed {seed}: same-time kind order");
    }
}

/// Task conservation across arbitrary crash/join/leave sequences: every
/// workload task ends the run in exactly one report — finished, shed,
/// or still in flight on some replica (or the admission-rejected list)
/// — never duplicated by an evacuation, never lost with a crashed
/// replica. The fleet also never ends outside its configured bounds,
/// and the counter identity `alive = start + joins + grows − crashes −
/// leaves − shrinks` holds.
#[test]
fn prop_task_conservation_under_churn() {
    use slice_serve::cluster::{DeviceProfile, LifecycleConfig, Replica};
    use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
    use slice_serve::engine::sim::SimEngine;

    let std_replica = |i: usize| {
        Replica::new(
            i,
            Box::new(SlicePolicy::new(
                LatencyModel::paper_calibrated(),
                SliceConfig::default(),
            )),
            Box::new(SimEngine::paper_calibrated()),
            DeviceProfile::standard(),
        )
    };
    for seed in 0..40u64 {
        let mut rng = Rng::new(14_000_000 + seed);
        let n_tasks = rng.range_usize(10, 40);
        let rate = 1.0 + rng.range_u64(0, 30) as f64 / 10.0;
        let mut lc = LifecycleConfig {
            churn_rate: 0.05 + rng.range_u64(0, 20) as f64 / 100.0,
            seed,
            min_replicas: 1,
            max_replicas: 8,
            ..LifecycleConfig::default()
        };
        lc.autoscaler.enabled = rng.chance(0.3);
        let width = 4;
        let workload = slice_serve::workload::WorkloadSpec::paper_mix(
            rate, 0.7, n_tasks, seed,
        )
        .generate();
        let report = Orchestrator::new(
            RoutingStrategy::SloAware,
            (0..width).map(std_replica).collect(),
        )
        .with_lifecycle(lc.clone(), Box::new(std_replica))
        .run(workload, secs(60.0))
        .unwrap();

        // conservation: every task exactly once, ids 0..n
        let tasks = report.tasks();
        assert_eq!(tasks.len(), n_tasks, "seed {seed}: task count");
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(t.id, i as u64, "seed {seed}: duplicated or lost task");
        }
        // dead replicas hold no unfinished work (everything evacuated)
        for r in &report.replicas {
            if !r.alive {
                assert!(
                    r.report.tasks.iter().all(|t| t.is_finished()),
                    "seed {seed}: replica {} died holding live tasks",
                    r.replica
                );
            }
        }
        // fleet bounds + counter identity
        let e = &report.elastic;
        let alive = report.alive_replicas() as i64;
        assert!(
            (lc.min_replicas as i64..=lc.max_replicas as i64).contains(&alive),
            "seed {seed}: alive {alive} outside bounds"
        );
        assert_eq!(
            alive,
            width as i64 + (e.joins + e.autoscale_grows) as i64
                - (e.crashes + e.leaves + e.autoscale_shrinks) as i64,
            "seed {seed}: alive-count identity"
        );
    }
}

/// Latency-model interpolation is monotone for monotone knot sets.
#[test]
fn prop_latency_interpolation_monotone() {
    for seed in 0..CASES {
        let mut rng = Rng::new(7_000_000 + seed);
        let n = rng.range_usize(2, 8);
        let mut points = Vec::new();
        let mut b = 0u32;
        let mut lat = 1_000u64;
        for _ in 0..n {
            b += rng.range_u64(1, 6) as u32;
            lat += rng.range_u64(0, 30_000);
            points.push((b, lat));
        }
        let max_b = points.last().unwrap().0;
        let model = LatencyModel::from_points(points, vec![], max_b);
        let mut prev = 0;
        for q in 1..=max_b + 4 {
            let v = model.decode(q);
            assert!(v >= prev, "seed {seed}: non-monotone at b={q}");
            prev = v;
        }
    }
}
