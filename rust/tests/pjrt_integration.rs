//! Integration tests against the real PJRT runtime and AOT artifacts.
//!
//! These tests require `artifacts/` (built by `make artifacts`); they
//! skip gracefully when it is absent so `cargo test` works pre-build.
//! The golden token sequences below were produced by the python L2
//! reference (`compile.model.generate_kv`, seed 42) — matching them
//! end-to-end proves the whole AOT chain (Pallas kernel → jax model →
//! HLO text → PJRT execution → rust sampling) preserves numerics.
//!
//! The whole file is additionally gated on the `pjrt` cargo feature:
//! the default (sim-only) build compiles this target to an empty test
//! binary. Run with `cargo test --features pjrt` (real closure in
//! third_party/xla) to exercise it.

#![cfg(feature = "pjrt")]

use std::path::Path;

use slice_serve::coordinator::pool::TaskPool;
use slice_serve::coordinator::task::{Task, TaskClass};
use slice_serve::engine::pjrt::PjrtEngine;
use slice_serve::engine::sampler::Sampler;
use slice_serve::engine::DecodeEngine;
use slice_serve::runtime::ModelRuntime;

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping pjrt integration test: artifacts/ not built");
        None
    }
}

fn engine() -> Option<PjrtEngine> {
    let runtime = ModelRuntime::load(artifacts()?).expect("artifacts load");
    Some(PjrtEngine::new(runtime, Sampler::Greedy, 0))
}

fn task_with_prompt(id: u64, prompt: &str, out: u32) -> Task {
    let mut t = Task::new(id, TaskClass::TextQa, 0, prompt.len() as u32, out, 1.0);
    t.prompt = prompt.as_bytes().to_vec();
    t
}

/// Greedily generate `n` tokens for one task through prefill + decode.
fn generate(engine: &mut PjrtEngine, pool: &TaskPool, id: u64, n: usize) -> Vec<u8> {
    let mut out = Vec::new();
    let o = engine.prefill(pool, id).unwrap();
    out.push(o.tokens[0].token);
    while out.len() < n {
        let o = engine.decode(pool, &[id]).unwrap();
        out.push(o.tokens[0].token);
    }
    out
}

/// Golden sequences from the python reference (seed 42):
///   generate_kv(cfg, params, prompt, 6) for each prompt.
const GOLDEN: &[(&str, [u8; 6])] = &[
    ("hello edge world", [100, 100, 100, 100, 100, 100]),
    ("cmd: rotate arm to 45deg", [103, 103, 103, 103, 103, 103]),
    ("Q: what is the status of dock", [107, 107, 107, 107, 107, 107]),
    ("a", [97, 97, 97, 97, 97, 97]),
];

#[test]
fn golden_generation_matches_python_reference() {
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    for (i, (prompt, _)) in GOLDEN.iter().enumerate() {
        pool.insert(task_with_prompt(i as u64, prompt, 6));
    }
    for (i, (prompt, expect)) in GOLDEN.iter().enumerate() {
        let got = generate(&mut eng, &pool, i as u64, 6);
        assert_eq!(&got[..], &expect[..], "prompt {prompt:?}");
    }
}

#[test]
fn batched_decode_matches_solo_decode() {
    // Decoding two tasks in one batch must produce exactly the same
    // tokens as decoding each alone (batch regrouping correctness —
    // the property SLICE's mask matrix relies on).
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    pool.insert(task_with_prompt(0, "hello edge world", 8));
    pool.insert(task_with_prompt(1, "cmd: rotate arm to 45deg", 8));
    pool.insert(task_with_prompt(2, "hello edge world", 8));
    pool.insert(task_with_prompt(3, "cmd: rotate arm to 45deg", 8));

    // solo path
    let solo0 = generate(&mut eng, &pool, 0, 5);
    let solo1 = generate(&mut eng, &pool, 1, 5);

    // batched path for the twin tasks 2,3
    let mut out2 = vec![eng.prefill(&pool, 2).unwrap().tokens[0].token];
    let mut out3 = vec![eng.prefill(&pool, 3).unwrap().tokens[0].token];
    for _ in 0..4 {
        let o = eng.decode(&pool, &[2, 3]).unwrap();
        out2.push(o.tokens[0].token);
        out3.push(o.tokens[1].token);
    }
    assert_eq!(solo0, out2, "task decoded in batch differs from solo");
    assert_eq!(solo1, out3, "task decoded in batch differs from solo");
}

#[test]
fn bucket_padding_is_inert() {
    // A batch of 3 runs in the 4-bucket with one padding row; results
    // must match the same tasks run in exact-fit buckets.
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    for i in 0..6u64 {
        pool.insert(task_with_prompt(i, "bucket padding test prompt", 8));
    }
    // exact-fit: decode tasks {0,1} in the 2-bucket
    let mut exact = Vec::new();
    let _ = eng.prefill(&pool, 0).unwrap();
    let _ = eng.prefill(&pool, 1).unwrap();
    for _ in 0..3 {
        let o = eng.decode(&pool, &[0, 1]).unwrap();
        exact.push((o.tokens[0].token, o.tokens[1].token));
    }
    // padded: decode tasks {2,3,4} in the 4-bucket; compare twins 2,3
    let _ = eng.prefill(&pool, 2).unwrap();
    let _ = eng.prefill(&pool, 3).unwrap();
    let _ = eng.prefill(&pool, 4).unwrap();
    let mut padded = Vec::new();
    for _ in 0..3 {
        let o = eng.decode(&pool, &[2, 3, 4]).unwrap();
        padded.push((o.tokens[0].token, o.tokens[1].token));
    }
    assert_eq!(exact, padded, "padding row affected real outputs");
}

#[test]
fn kv_cache_length_advances() {
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    pool.insert(task_with_prompt(0, "cache length probe", 8));
    assert_eq!(eng.cached_len(0), None);
    let _ = eng.prefill(&pool, 0).unwrap();
    assert_eq!(eng.cached_len(0), Some(18)); // prompt length
    let _ = eng.decode(&pool, &[0]).unwrap();
    assert_eq!(eng.cached_len(0), Some(19));
    eng.release(0);
    assert_eq!(eng.cached_len(0), None);
}

#[test]
fn decode_before_prefill_is_an_error() {
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    pool.insert(task_with_prompt(0, "never prefilled", 8));
    assert!(eng.decode(&pool, &[0]).is_err());
}

#[test]
fn context_overflow_is_detected() {
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    // 60-token prompt in the 64 bucket; max_seq 128 -> ~66 decode steps
    let prompt = "x".repeat(60);
    pool.insert(task_with_prompt(0, &prompt, 200));
    let _ = eng.prefill(&pool, 0).unwrap();
    let mut saw_eos = false;
    for _ in 0..80 {
        match eng.decode(&pool, &[0]) {
            Ok(o) => {
                if o.tokens[0].eos {
                    saw_eos = true;
                    break;
                }
            }
            Err(_) => {
                saw_eos = true; // explicit overflow error also acceptable
                break;
            }
        }
    }
    assert!(saw_eos, "context overflow neither signalled eos nor errored");
}

#[test]
fn kv_memory_accounting_tracks_peak() {
    let Some(mut eng) = engine() else { return };
    let mut pool = TaskPool::new();
    for i in 0..3u64 {
        pool.insert(task_with_prompt(i, "memory accounting probe", 4));
    }
    assert_eq!(eng.peak_kv_bytes(), 0);
    let _ = eng.prefill(&pool, 0).unwrap();
    let _ = eng.prefill(&pool, 1).unwrap();
    let slab_bytes = 4 * 4 * 2 * 4 * 128 * 32 / 4; // dims: L=4,2,H=4,S=128,hd=32 f32
    let _ = slab_bytes;
    let two = eng.peak_kv_bytes();
    assert!(two > 0);
    eng.release(0);
    eng.release(1);
    // peak is a high-water mark: releasing does not lower it
    assert_eq!(eng.peak_kv_bytes(), two);
    let _ = eng.prefill(&pool, 2).unwrap();
    assert_eq!(eng.peak_kv_bytes(), two, "peak stays at 2 slots");
}
