//! Integration tests: full serving runs (workload → policy → sim engine
//! → metrics) and cross-policy invariants.

use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::coordinator::preemption::UtilityAdaptor;
use slice_serve::coordinator::task::{Task, TaskClass};
use slice_serve::engine::clock::VirtualClock;
use slice_serve::engine::latency::LatencyModel;
use slice_serve::engine::sim::SimEngine;
use slice_serve::experiments::{build_policy, default_drain, run_sim, ALL_POLICIES};
use slice_serve::metrics::Attainment;
use slice_serve::server::Server;
use slice_serve::util::secs;
use slice_serve::workload::{table2_static_workload, WorkloadSpec};

fn run(kind: PolicyKind, rate: f64, rt_ratio: f64, n: usize, seed: u64) -> Vec<Task> {
    let cfg = ServeConfig::default();
    let wl = WorkloadSpec::paper_mix(rate, rt_ratio, n, seed).generate();
    run_sim(kind, wl, &cfg, default_drain()).unwrap().tasks
}

/// Timestamps recorded for every finished task are internally coherent.
#[test]
fn timing_records_are_coherent() {
    for kind in ALL_POLICIES {
        for t in run(kind, 1.0, 0.7, 100, 11) {
            if let (Some(first), Some(last)) = (t.first_token, t.last_token) {
                assert!(first >= t.arrival, "{kind:?}: token before arrival");
                assert!(last >= first);
                if let Some(c) = t.completion {
                    assert_eq!(c, last, "{kind:?}: completion != last token");
                }
            }
            if t.is_finished() {
                assert_eq!(
                    t.tokens_generated, t.output_len,
                    "{kind:?}: finished task token count"
                );
            } else {
                assert!(t.tokens_generated < t.output_len);
            }
        }
    }
}

/// Token conservation: engine decode steps == total decoded tokens.
#[test]
fn token_conservation() {
    let cfg = ServeConfig::default();
    let wl = WorkloadSpec::paper_mix(0.5, 0.7, 60, 3).generate();
    let report = run_sim(PolicyKind::Slice, wl, &cfg, default_drain()).unwrap();
    let generated: u64 = report.tasks.iter().map(|t| t.tokens_generated as u64).sum();
    // each prefill produces 1 token; each decode produces batch-size tokens
    assert!(generated >= report.prefill_steps);
    assert!(report.decode_steps <= generated);
}

/// Full pipeline determinism: same seed → identical metrics.
#[test]
fn end_to_end_determinism() {
    for kind in ALL_POLICIES {
        let a = run(kind, 1.0, 0.7, 80, 17);
        let b = run(kind, 1.0, 0.7, 80, 17);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.completion, y.completion, "{kind:?} nondeterministic");
            assert_eq!(x.first_token, y.first_token);
            assert_eq!(x.tokens_generated, y.tokens_generated);
        }
    }
}

/// SLICE's rate guarantee: in the static Table II workload every task's
/// measured average TPOT is at or below its SLO.
#[test]
fn slice_static_rate_guarantee() {
    let cfg = ServeConfig::default();
    let wl = table2_static_workload();
    let report = run_sim(PolicyKind::Slice, wl, &cfg, default_drain()).unwrap();
    for t in &report.tasks {
        assert!(t.is_finished(), "task {} unfinished", t.id);
        let tpot = t.avg_tpot().unwrap();
        assert!(
            tpot <= t.slo.tpot,
            "task {}: measured TPOT {}us > SLO {}us",
            t.id,
            tpot,
            t.slo.tpot
        );
    }
}

/// Orca is strictly FCFS: first tokens appear in arrival order.
#[test]
fn orca_first_tokens_in_fcfs_order() {
    let tasks = run(PolicyKind::Orca, 2.0, 0.5, 50, 23);
    let mut by_arrival: Vec<&Task> = tasks.iter().collect();
    by_arrival.sort_by_key(|t| t.arrival);
    let firsts: Vec<u64> = by_arrival
        .iter()
        .filter_map(|t| t.first_token)
        .collect();
    for w in firsts.windows(2) {
        assert!(w[0] <= w[1], "Orca served out of FCFS order");
    }
}

/// Under heavy overload, SLICE still finishes (nearly) all real-time
/// tasks inside their deadline while baselines do not.
#[test]
fn overload_rt_guarantee_gap() {
    let slice = run(PolicyKind::Slice, 4.0, 0.7, 250, 31);
    let orca = run(PolicyKind::Orca, 4.0, 0.7, 250, 31);
    let a_slice = Attainment::compute(&slice);
    let a_orca = Attainment::compute(&orca);
    assert!(a_slice.rt_slo > 0.9, "SLICE RT {}", a_slice.rt_slo);
    assert!(
        a_slice.rt_slo > a_orca.rt_slo + 0.3,
        "gap too small: {} vs {}",
        a_slice.rt_slo,
        a_orca.rt_slo
    );
}

/// The SJF utility adaptor (preemption controller) changes scheduling
/// without sacrificing the real-time guarantee or overall service:
/// aggregate completions stay within 15% of the no-adaptor baseline and
/// RT attainment stays high (§IV-E describes the adaptor as a policy
/// knob, not a throughput optimization).
#[test]
fn sjf_adaptor_preserves_service() {
    let cfg_none = ServeConfig { n_tasks: 150, ..ServeConfig::default() };
    let cfg_sjf = ServeConfig {
        adaptor: UtilityAdaptor::SjfDecay { factor: 0.5, tau: 32 },
        ..cfg_none.clone()
    };
    let wl = || WorkloadSpec::paper_mix(1.0, 0.5, 150, 41).generate();
    let none = run_sim(PolicyKind::Slice, wl(), &cfg_none, default_drain()).unwrap();
    let sjf = run_sim(PolicyKind::Slice, wl(), &cfg_sjf, default_drain()).unwrap();

    let finished = |tasks: &[Task]| tasks.iter().filter(|t| t.is_finished()).count();
    let (f_none, f_sjf) = (finished(&none.tasks), finished(&sjf.tasks));
    assert!(
        f_sjf as f64 >= f_none as f64 * 0.85,
        "SJF collapsed service: {f_sjf} vs {f_none}"
    );

    let a_sjf = Attainment::compute(&sjf.tasks);
    assert!(a_sjf.rt_slo > 0.9, "SJF broke RT guarantee: {}", a_sjf.rt_slo);
}

/// A server with no tasks terminates immediately.
#[test]
fn empty_workload_terminates() {
    let cfg = ServeConfig::default();
    let report = Server::new(
        Vec::new(),
        build_policy(PolicyKind::Slice, &cfg),
        Box::new(SimEngine::paper_calibrated()),
        VirtualClock::new(),
    )
    .run(secs(10.0))
    .unwrap();
    assert_eq!(report.tasks.len(), 0);
    assert_eq!(report.steps, 0);
}

/// Tasks arriving simultaneously (burst) are all eventually served.
#[test]
fn burst_arrivals_all_served() {
    let mut wl = WorkloadSpec::paper_mix(1.0, 0.5, 40, 53).generate();
    for t in &mut wl {
        t.arrival = 0; // collapse to a burst
    }
    let cfg = ServeConfig::default();
    for kind in ALL_POLICIES {
        let report = run_sim(kind, wl.clone(), &cfg, secs(600.0)).unwrap();
        let finished = report.tasks.iter().filter(|t| t.is_finished()).count();
        assert_eq!(finished, 40, "{kind:?} left tasks unserved after a burst");
    }
}

/// The latency model cap prevents SLICE from ever batching beyond
/// max_batch in a single decode step.
#[test]
fn slice_never_exceeds_max_batch() {
    let mut lat = LatencyModel::paper_calibrated();
    lat.max_batch = 6;
    let cfg = ServeConfig { max_batch: 6, ..ServeConfig::default() };
    let wl = WorkloadSpec::paper_mix(3.0, 0.7, 100, 61).generate();
    // run manually to observe steps
    use slice_serve::coordinator::scheduler::{Policy, Step};
    use slice_serve::coordinator::pool::TaskPool;
    let mut pool = TaskPool::new();
    let mut policy = build_policy(PolicyKind::Slice, &cfg);
    let ids: Vec<u64> = wl.iter().map(|t| t.id).collect();
    for t in wl {
        pool.insert(t);
    }
    policy.on_arrival(&mut pool, &ids, 0);
    let mut decodes = 0;
    for _ in 0..500 {
        match policy.next_step(&mut pool, 0) {
            Step::Decode { tasks } => {
                assert!(tasks.len() <= 6, "batch {} > cap", tasks.len());
                decodes += 1;
                for id in tasks {
                    pool.get_mut(id).on_token(1);
                }
            }
            Step::Prefill { task } => {
                let t = pool.get_mut(task);
                t.state = slice_serve::coordinator::task::TaskState::Running;
                t.prefill_end = Some(1);
                t.on_token(1);
            }
            Step::Idle => break,
        }
    }
    assert!(decodes > 0);
}

/// Failure injection: an engine error mid-run propagates out of the
/// serving loop instead of being swallowed.
#[test]
fn engine_failure_propagates() {
    use anyhow::anyhow;
    use slice_serve::coordinator::pool::TaskPool;
    use slice_serve::coordinator::task::TaskId;
    use slice_serve::engine::{DecodeEngine, StepOutcome, TokenOut};

    struct FlakyEngine {
        inner: SimEngine,
        steps_until_failure: u32,
    }
    impl DecodeEngine for FlakyEngine {
        fn prefill(&mut self, pool: &TaskPool, task: TaskId) -> anyhow::Result<StepOutcome> {
            self.inner.prefill(pool, task)
        }
        fn decode(&mut self, pool: &TaskPool, tasks: &[TaskId]) -> anyhow::Result<StepOutcome> {
            if self.steps_until_failure == 0 {
                return Err(anyhow!("injected device failure"));
            }
            self.steps_until_failure -= 1;
            self.inner.decode(pool, tasks)
        }
        fn release(&mut self, task: TaskId) {
            self.inner.release(task);
            let _ = TokenOut { task, token: 0, eos: false };
        }
        fn max_context(&self) -> u32 {
            self.inner.max_context()
        }
        fn backend(&self) -> &'static str {
            "flaky-sim"
        }
    }

    let cfg = ServeConfig::default();
    let wl = WorkloadSpec::paper_mix(1.0, 0.5, 20, 71).generate();
    let engine = FlakyEngine {
        inner: SimEngine::paper_calibrated(),
        steps_until_failure: 5,
    };
    let result = Server::new(
        wl,
        build_policy(PolicyKind::Slice, &cfg),
        Box::new(engine),
        VirtualClock::new(),
    )
    .run(secs(60.0));
    let err = result.expect_err("injected failure must propagate");
    assert!(err.to_string().contains("injected device failure"));
}

/// Streaming delivery (the paper's tokenBuf): the token sink observes
/// every token exactly once, in per-task generation order, with
/// monotone timestamps, matching the final task records.
#[test]
fn token_sink_streams_all_tokens_in_order() {
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    // Arc<Mutex<..>> rather than Rc<RefCell<..>>: `TokenSink` is `Send`
    // (replicas — sinks included — cross threads in the parallel event
    // engine's epochs), so the capture must be too.
    let streamed: Arc<Mutex<HashMap<u64, Vec<(u8, u64)>>>> =
        Arc::new(Mutex::new(HashMap::new()));
    let sink_ref = streamed.clone();

    let cfg = ServeConfig::default();
    let wl = WorkloadSpec::paper_mix(1.0, 0.5, 30, 91).generate();
    let report = Server::new(
        wl,
        build_policy(PolicyKind::Slice, &cfg),
        Box::new(SimEngine::paper_calibrated()),
        VirtualClock::new(),
    )
    .with_token_sink(Box::new(move |task, token, now| {
        sink_ref.lock().unwrap().entry(task).or_default().push((token, now));
    }))
    .run(secs(600.0))
    .unwrap();

    let streamed = streamed.lock().unwrap();
    for t in &report.tasks {
        let stream = streamed.get(&t.id).map(|v| v.as_slice()).unwrap_or(&[]);
        assert_eq!(
            stream.len(),
            t.tokens_generated as usize,
            "task {}: stream length != record",
            t.id
        );
        // monotone timestamps
        for w in stream.windows(2) {
            assert!(w[0].1 <= w[1].1, "task {}: stream out of order", t.id);
        }
        // stream bytes equal the recorded generation
        let bytes: Vec<u8> = stream.iter().map(|&(b, _)| b).collect();
        assert_eq!(bytes, t.generated, "task {}: stream bytes differ", t.id);
    }
}
