//! KV-cache memory subsystem integration tests: the ISSUE-4 acceptance
//! contract. Bit-exactness of the unconstrained path (the refactor is
//! opt-in by construction), the occupancy-never-exceeds-capacity
//! invariant under arbitrary seeds, swap round-trip determinism,
//! memory-aware vs memory-oblivious SLICE at the tight capacity cell,
//! and exactly-once running-task migration with the KV-handoff fee
//! reflected in task timings. Thresholds validated by the pysim mirror
//! (EXPERIMENTS.md "Memory sweep"): aware 0.9350 vs oblivious 0.8850 at
//! single/32 MiB/swap, seed 42.

use slice_serve::cluster::{AdmissionConfig, FleetSpec, RoutingStrategy};
use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::coordinator::task::Task;
use slice_serve::engine::memory::{MemoryConfig, PreemptionMode};
use slice_serve::experiments::memory_sweep::{run_cell, LOW_CAPACITY_MB};
use slice_serve::experiments::{default_drain, run_fleet, run_sim};
use slice_serve::workload::WorkloadSpec;

const MIB: u64 = 1024 * 1024;

fn workload(rate: f64, n: usize, seed: u64) -> Vec<Task> {
    WorkloadSpec::paper_mix(rate, 0.7, n, seed).generate()
}

fn constrained_cfg(capacity_mb: u64) -> ServeConfig {
    ServeConfig {
        memory: MemoryConfig {
            kv_capacity: Some(capacity_mb * MIB),
            ..MemoryConfig::default()
        },
        ..ServeConfig::default()
    }
}

fn guarded(mut cfg: ServeConfig) -> ServeConfig {
    cfg.cluster_admission = AdmissionConfig { enabled: true, ..AdmissionConfig::default() };
    cfg.cluster_migration = true;
    cfg.cluster_migrate_running = true;
    cfg
}

/// A colossal capacity never evicts, so every task record is
/// bit-identical to the default (unconstrained) run even though the
/// constrained code paths execute — the refactor is opt-in by
/// construction, not by luck.
#[test]
fn huge_capacity_is_bit_identical_to_unlimited() {
    for kind in [PolicyKind::Slice, PolicyKind::Orca] {
        let cfg = ServeConfig { policy: kind, ..ServeConfig::default() };
        let unlimited =
            run_sim(kind, workload(1.0, 150, 9), &cfg, default_drain()).unwrap();
        let huge = {
            let mut cfg = cfg.clone();
            cfg.memory.kv_capacity = Some(64 * 1024 * MIB); // 64 GiB
            run_sim(kind, workload(1.0, 150, 9), &cfg, default_drain()).unwrap()
        };
        assert_eq!(unlimited.steps, huge.steps, "{kind:?}");
        for (a, b) in unlimited.tasks.iter().zip(&huge.tasks) {
            assert_eq!(a.first_token, b.first_token, "{kind:?}");
            assert_eq!(a.completion, b.completion);
            assert_eq!(a.tokens_generated, b.tokens_generated);
            assert_eq!(a.max_token_gap, b.max_token_gap);
        }
        assert_eq!(huge.memory.swap_outs, 0);
        assert_eq!(huge.memory.swap_delay, 0);
        // peak accounting works in both (parity with PjrtEngine)
        assert!(unlimited.memory.peak_kv_bytes > 0);
        assert_eq!(unlimited.memory.peak_kv_bytes, huge.memory.peak_kv_bytes);
    }
}

/// A width-1 unlimited-memory cluster remains bit-exact vs the
/// single-device path (the satellite's parity requirement), with the
/// running-handoff flag enabled — an unconstrained device never evicts,
/// so the flag is inert.
#[test]
fn width1_unlimited_cluster_matches_single_device() {
    let cfg = ServeConfig {
        cluster_migration: true,
        cluster_migrate_running: true,
        ..ServeConfig::default()
    };
    let single = run_sim(PolicyKind::Slice, workload(1.0, 120, 9), &cfg, default_drain())
        .unwrap();
    let fleet = FleetSpec::homogeneous(1, cfg.cycle_cap);
    let report = run_fleet(
        RoutingStrategy::SloAware,
        &fleet,
        workload(1.0, 120, 9),
        &cfg,
        default_drain(),
    )
    .unwrap();
    assert_eq!(report.migrated_running, 0);
    assert_eq!(report.total_steps(), single.steps);
    let tasks = report.tasks();
    for (a, b) in single.tasks.iter().zip(&tasks) {
        assert_eq!(a.first_token, b.first_token);
        assert_eq!(a.completion, b.completion);
        assert_eq!(a.tokens_generated, b.tokens_generated);
    }
    assert_eq!(
        report.fleet_memory().peak_kv_bytes,
        single.memory.peak_kv_bytes,
        "sim peak KV parity across paths"
    );
}

/// The occupancy invariant: under any workload seed, a constrained
/// run's resident high-water mark never exceeds the configured
/// capacity, for the memory-aware policy and the oblivious baseline
/// alike (the serving loop is the enforcement point).
#[test]
fn occupancy_never_exceeds_capacity_under_any_seed() {
    for seed in [1u64, 7, 42, 99] {
        for aware in [true, false] {
            let mut cfg = constrained_cfg(32);
            cfg.memory.aware = aware;
            let report =
                run_sim(PolicyKind::Slice, workload(1.0, 120, seed), &cfg, default_drain())
                    .unwrap();
            assert!(
                report.memory.peak_kv_bytes <= 32 * MIB,
                "seed {seed} aware={aware}: peak {} exceeds capacity",
                report.memory.peak_kv_bytes
            );
            // seed 7's burst pattern happens to peak just under 32 MiB
            // (measured 31.5 MiB); every other seed must actually evict
            if seed != 7 {
                assert!(
                    report.memory.swap_outs > 0,
                    "seed {seed} aware={aware}: the 32 MiB cell must evict"
                );
            }
        }
    }
    // tier-scaled capacities hold per replica on the mixed fleet
    let cfg = guarded(constrained_cfg(32));
    let report = run_fleet(
        RoutingStrategy::SloAware,
        &FleetSpec::preset("edge-mixed").unwrap(),
        workload(3.0, 300, 42),
        &cfg,
        default_drain(),
    )
    .unwrap();
    let fractions = [1.0, 1.0, 0.75, 0.5];
    for (r, f) in report.replicas.iter().zip(fractions) {
        let cap = (32.0 * f) as u64 * MIB;
        assert!(
            r.report.memory.peak_kv_bytes <= cap,
            "replica {} ({}) peak {} exceeds tier capacity {}",
            r.replica,
            r.profile,
            r.report.memory.peak_kv_bytes,
            cap
        );
    }
}

/// Swap-out/swap-in round trips preserve determinism: two identical
/// constrained runs produce identical per-task timing records and
/// identical transition counters.
#[test]
fn swap_roundtrips_are_deterministic() {
    for mode in [PreemptionMode::Swap, PreemptionMode::Recompute] {
        let run = || {
            let mut cfg = constrained_cfg(32);
            cfg.memory.mode = mode;
            run_sim(PolicyKind::Slice, workload(1.0, 150, 42), &cfg, default_drain())
                .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.memory, b.memory, "{mode:?}");
        assert_eq!(a.steps, b.steps);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.first_token, y.first_token, "{mode:?}");
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.swap_outs, y.swap_outs);
            assert_eq!(x.swap_ins, y.swap_ins);
        }
        // the mode determines which restore counter moves
        match mode {
            PreemptionMode::Swap => {
                assert!(a.memory.swap_ins > 0);
                assert_eq!(a.memory.recomputes, 0);
            }
            PreemptionMode::Recompute => {
                assert!(a.memory.recomputes > 0);
                assert_eq!(a.memory.swap_ins, 0);
            }
        }
    }
}

/// The acceptance threshold: at the tight capacity cell, memory-aware
/// SLICE (projected KV as a second Alg. 2 knapsack dimension) beats the
/// memory-oblivious baseline on SLO attainment. Measured (pysim mirror,
/// seed 42, 32 MiB, swap @ 64 MB/s): aware 0.9350 vs oblivious 0.8850.
#[test]
fn memory_aware_slice_beats_oblivious_at_tight_cell() {
    let cfg = ServeConfig::default();
    let aware = run_cell(
        "single",
        Some(LOW_CAPACITY_MB),
        PreemptionMode::Swap,
        true,
        &cfg,
    )
    .unwrap();
    let oblivious = run_cell(
        "single",
        Some(LOW_CAPACITY_MB),
        PreemptionMode::Swap,
        false,
        &cfg,
    )
    .unwrap();
    assert!(
        aware.attainment.slo > oblivious.attainment.slo + 0.02,
        "aware {} must beat oblivious {}",
        aware.attainment.slo,
        oblivious.attainment.slo
    );
    // absolute bands around the measured cells (generous to the 1-ulp
    // arrival-timestamp caveat recorded in EXPERIMENTS.md)
    assert!(aware.attainment.slo > 0.92, "aware collapsed: {}", aware.attainment.slo);
    assert!(
        oblivious.attainment.slo < 0.91,
        "oblivious unexpectedly strong: {}",
        oblivious.attainment.slo
    );
    assert!(
        oblivious.memory.swap_outs > aware.memory.swap_outs,
        "obliviousness must thrash more ({} vs {})",
        oblivious.memory.swap_outs,
        aware.memory.swap_outs
    );
}

/// Running-task migration at the constrained mixed cell: handoffs fire,
/// each task migrates at most once, the modelled transfer time is
/// accounted, and every task still lands in the report exactly once.
/// Measured (pysim, seed 42, 32 MiB base): 7 handoffs, ~398 ms total.
#[test]
fn running_handoff_fires_exactly_once_with_latency_accounted() {
    let cfg = guarded(constrained_cfg(32));
    let n = 600usize;
    let run = || {
        run_fleet(
            RoutingStrategy::SloAware,
            &FleetSpec::preset("edge-mixed").unwrap(),
            workload(3.0, n, 42),
            &cfg,
            default_drain(),
        )
        .unwrap()
    };
    let report = run();
    assert!(report.migrated_running > 0, "constrained knee cell must hand off");
    assert!(report.migrated_running <= report.migrations);
    assert!(report.migrations <= n as u64, "a task migrated more than once");
    assert!(report.handoff_us > 0, "handoff latency must be modelled");
    assert!(report.handoff_bytes > 0);
    assert_eq!(
        report.routed_ids(),
        (0..n as u64).collect::<Vec<_>>(),
        "lost or duplicated tasks under running migration"
    );
    assert_eq!(
        report.fleet_memory().handoff_restores,
        report.migrated_running,
        "every handoff fee was charged on resume"
    );
    // deterministic across identical runs
    let again = run();
    assert_eq!(report.migrated_running, again.migrated_running);
    assert_eq!(report.handoff_us, again.handoff_us);
    assert_eq!(
        report.fleet_attainment().slo,
        again.fleet_attainment().slo
    );
}

/// With memory unconstrained, the mixed guarded fleet with the running
/// flag on reproduces the PR 3 hetero numbers exactly: nothing is ever
/// evicted, so nothing can be handed off.
#[test]
fn unconstrained_mixed_fleet_reproduces_hetero_cell() {
    let mut base = guarded(ServeConfig::default());
    base.cluster_migrate_running = false;
    let with_flag = guarded(ServeConfig::default());
    let mixed = FleetSpec::preset("edge-mixed").unwrap();
    let a = run_fleet(
        RoutingStrategy::SloAware,
        &mixed,
        workload(3.0, 600, 42),
        &base,
        default_drain(),
    )
    .unwrap();
    let b = run_fleet(
        RoutingStrategy::SloAware,
        &mixed,
        workload(3.0, 600, 42),
        &with_flag,
        default_drain(),
    )
    .unwrap();
    assert_eq!(b.migrated_running, 0);
    assert_eq!(a.migrations, b.migrations);
    let (ta, tb) = (a.tasks(), b.tasks());
    for (x, y) in ta.iter().zip(&tb) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token, y.first_token);
        assert_eq!(x.completion, y.completion);
    }
    // the measured PR 3 band still holds (0.8783 at seed 42)
    let slo = a.fleet_attainment().slo;
    assert!(slo > 0.86, "hetero knee cell drifted: {slo}");
}

/// Recompute preemption prices resumes through the prefill curve and
/// never touches the swap-in counter; at the tight single-device cell
/// it matches the swap mode's attainment (measured: both 0.9350 —
/// restores are cheap relative to the decode work between them).
#[test]
fn recompute_mode_restores_via_prefill_and_holds_attainment() {
    let cfg = ServeConfig::default();
    let cell = run_cell(
        "single",
        Some(LOW_CAPACITY_MB),
        PreemptionMode::Recompute,
        true,
        &cfg,
    )
    .unwrap();
    assert!(cell.memory.recomputes > 0);
    assert_eq!(cell.memory.swap_ins, 0);
    assert!(cell.attainment.slo > 0.92, "recompute cell: {}", cell.attainment.slo);
}
