//! Heterogeneous-fleet integration tests: the ISSUE-3 acceptance
//! contract. Width-1 equivalence with PR 2's cluster (guards off),
//! migration invariants (exactly-once, determinism), admission-shed
//! accounting, and the measured routing-quality threshold on the
//! edge-mixed fleet (thresholds validated by the pysim mirror; see
//! EXPERIMENTS.md "Hetero sweep").

use slice_serve::cluster::{FleetSpec, RoutingStrategy};
use slice_serve::config::{PolicyKind, ServeConfig};
use slice_serve::coordinator::task::Task;
use slice_serve::experiments::hetero_sweep::LOAD_EQUIVALENTS;
use slice_serve::experiments::{default_drain, run_cluster, run_fleet, run_sim};
use slice_serve::workload::WorkloadSpec;

fn workload(rate: f64, n: usize, seed: u64) -> Vec<Task> {
    WorkloadSpec::paper_mix(rate, 0.7, n, seed).generate()
}

fn guarded(cfg: &ServeConfig) -> ServeConfig {
    let mut cfg = cfg.clone();
    cfg.cluster_admission.enabled = true;
    cfg.cluster_migration = true;
    cfg
}

fn mixed() -> FleetSpec {
    FleetSpec::preset("edge-mixed").unwrap()
}

/// A width-1 homogeneous fleet with admission and migration disabled
/// reproduces PR 2's single-replica cluster — and therefore the
/// single-device `Server::run` — exactly: per-task timing records and
/// engine step totals (the acceptance bit-exactness criterion).
#[test]
fn width1_guards_disabled_matches_single_device_exactly() {
    for kind in [PolicyKind::Slice, PolicyKind::Orca, PolicyKind::FastServe] {
        let cfg = ServeConfig { policy: kind, ..ServeConfig::default() };
        assert!(!cfg.cluster_admission.enabled && !cfg.cluster_migration);
        let wl = workload(1.0, 120, 9);
        let single = run_sim(kind, wl.clone(), &cfg, default_drain()).unwrap();
        let via_cluster =
            run_cluster(RoutingStrategy::SloAware, 1, wl.clone(), &cfg, default_drain())
                .unwrap();
        let via_fleet = run_fleet(
            RoutingStrategy::SloAware,
            &cfg.fleet(),
            wl,
            &cfg,
            default_drain(),
        )
        .unwrap();
        for report in [via_cluster, via_fleet] {
            assert_eq!(report.rejected_count(), 0);
            assert_eq!(report.migrations, 0);
            assert_eq!(report.total_steps(), single.steps, "{kind:?}");
            let tasks = report.tasks();
            assert_eq!(tasks.len(), single.tasks.len());
            for (s, c) in single.tasks.iter().zip(&tasks) {
                assert_eq!(s.id, c.id);
                assert_eq!(s.first_token, c.first_token, "{kind:?}");
                assert_eq!(s.last_token, c.last_token);
                assert_eq!(s.completion, c.completion);
                assert_eq!(s.tokens_generated, c.tokens_generated);
                assert_eq!(s.max_token_gap, c.max_token_gap);
            }
        }
    }
}

/// The acceptance threshold: on the edge-mixed fleet at its capacity
/// knee, slo-aware routing with admission + migration attains at least
/// round-robin (guarded or not). Measured (pysim mirror, seed 42):
/// slo-aware guarded 0.8783 vs round-robin 0.8683 (plain and guarded);
/// the inequality also holds at seeds 1/7/21/99 with 1.0–7.8 pp
/// margins.
#[test]
fn mixed_fleet_slo_aware_guarded_at_least_round_robin() {
    let cfg = ServeConfig::default();
    let n = cfg.n_tasks * LOAD_EQUIVALENTS as usize; // 600
    let wl = || workload(cfg.arrival_rate * LOAD_EQUIVALENTS, n, cfg.seed);
    let slo_g = run_fleet(
        RoutingStrategy::SloAware,
        &mixed(),
        wl(),
        &guarded(&cfg),
        default_drain(),
    )
    .unwrap();
    let rr_p =
        run_fleet(RoutingStrategy::RoundRobin, &mixed(), wl(), &cfg, default_drain())
            .unwrap();
    let rr_g = run_fleet(
        RoutingStrategy::RoundRobin,
        &mixed(),
        wl(),
        &guarded(&cfg),
        default_drain(),
    )
    .unwrap();
    let (a_slo, a_rr, a_rrg) =
        (slo_g.fleet_attainment(), rr_p.fleet_attainment(), rr_g.fleet_attainment());
    assert!(
        a_slo.slo >= a_rr.slo,
        "slo-aware+guards {} < round-robin {}",
        a_slo.slo,
        a_rr.slo
    );
    assert!(
        a_slo.slo >= a_rrg.slo,
        "slo-aware+guards {} < guarded round-robin {}",
        a_slo.slo,
        a_rrg.slo
    );
    // absolute bands around the measured cells (generous to the 1-ulp
    // arrival-timestamp caveat recorded in EXPERIMENTS.md)
    assert!(a_slo.slo > 0.86, "slo-aware+guards collapsed: {}", a_slo.slo);
    assert!(a_rr.slo < 0.89, "round-robin unexpectedly strong: {}", a_rr.slo);
    assert!(slo_g.migrations > 0, "knee cell must exercise migration");
}

/// Migration lifts real-time attainment on the mixed fleet (the Eq. 7
/// overload signal fires on the slow replicas before RT deadlines are
/// lost). Measured at seed 42: RT 0.9877 plain vs 0.9975 guarded,
/// fleet 0.8750 vs 0.8783. Fleet attainment gets a small tolerance:
/// across seeds the guards trade a task or two of non-RT for the RT
/// lift (e.g. seed 99 in the pysim sweep), and the contract is "never
/// meaningfully worse", not strict dominance.
#[test]
fn guards_do_not_hurt_slo_aware_on_mixed_fleet() {
    let cfg = ServeConfig::default();
    let n = cfg.n_tasks * LOAD_EQUIVALENTS as usize;
    let wl = || workload(cfg.arrival_rate * LOAD_EQUIVALENTS, n, cfg.seed);
    let plain =
        run_fleet(RoutingStrategy::SloAware, &mixed(), wl(), &cfg, default_drain())
            .unwrap()
            .fleet_attainment();
    let with_guards = run_fleet(
        RoutingStrategy::SloAware,
        &mixed(),
        wl(),
        &guarded(&cfg),
        default_drain(),
    )
    .unwrap()
    .fleet_attainment();
    assert!(
        with_guards.slo + 0.005 >= plain.slo,
        "guards regressed fleet attainment: {} << {}",
        with_guards.slo,
        plain.slo
    );
    assert!(
        with_guards.rt_slo >= plain.rt_slo,
        "guards regressed RT attainment: {} < {}",
        with_guards.rt_slo,
        plain.rt_slo
    );
}

/// Exactly-once delivery under migration and admission: at an overload
/// cell (4.0 tasks/s, 800 tasks) every global id lands in the report
/// exactly once — on a replica or the shed list — migrations stay
/// within the one-hop cap, and shedding actually fires.
#[test]
fn exactly_once_under_migration_and_shedding() {
    let cfg = guarded(&ServeConfig::default());
    let report = run_fleet(
        RoutingStrategy::SloAware,
        &mixed(),
        workload(4.0, 800, 42),
        &cfg,
        default_drain(),
    )
    .unwrap();
    assert_eq!(
        report.routed_ids(),
        (0..800).collect::<Vec<u64>>(),
        "lost or duplicated tasks"
    );
    let held: usize = report.replicas.iter().map(|r| r.routed).sum();
    assert_eq!(held + report.rejected_count(), 800);
    assert!(report.migrations > 0, "overload cell must migrate");
    assert!(report.migrations <= 800, "a task migrated more than once");
    let migrated_in: u64 = report.replicas.iter().map(|r| r.migrated_in).sum();
    let migrated_out: u64 = report.replicas.iter().map(|r| r.migrated_out).sum();
    assert_eq!(migrated_in, report.migrations);
    assert_eq!(migrated_out, report.migrations);
    assert!(report.rejected_count() > 0, "overload cell must shed");
    // shed tasks count as violations: attainment denominators include them
    let a = report.fleet_attainment();
    assert_eq!(a.n_tasks, 800);
    assert!(a.n_finished <= 800 - report.rejected_count());
}

/// Guarded heterogeneous runs are deterministic: identical workload
/// seeds give identical per-task records, routing, shed lists and
/// migration counts — across several seeds.
#[test]
fn guarded_runs_deterministic_across_seeds() {
    let cfg = guarded(&ServeConfig::default());
    for seed in [1u64, 7, 42] {
        let run = || {
            run_fleet(
                RoutingStrategy::SloAware,
                &mixed(),
                workload(3.0, 300, seed),
                &cfg,
                default_drain(),
            )
            .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.migrations, b.migrations, "seed {seed}");
        assert_eq!(a.rejected_count(), b.rejected_count());
        let (ta, tb) = (a.tasks(), b.tasks());
        assert_eq!(ta.len(), tb.len());
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(x.id, y.id, "seed {seed} routed differently");
            assert_eq!(x.first_token, y.first_token);
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.tokens_generated, y.tokens_generated);
        }
        for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
            assert_eq!(ra.routed, rb.routed);
            assert_eq!(ra.migrated_in, rb.migrated_in);
            assert_eq!(ra.migrated_out, rb.migrated_out);
            assert_eq!(ra.report.steps, rb.report.steps);
        }
    }
}

/// Profile plumbing: the mixed fleet reports its tier names in replica
/// order, and load-aware strategies shift share away from slow tiers.
#[test]
fn mixed_fleet_profiles_and_load_shape() {
    let cfg = ServeConfig::default();
    let report = run_fleet(
        RoutingStrategy::SloAware,
        &mixed(),
        workload(3.0, 600, 42),
        &cfg,
        default_drain(),
    )
    .unwrap();
    let profiles: Vec<&str> = report.replicas.iter().map(|r| r.profile).collect();
    assert_eq!(profiles, vec!["standard", "standard", "lite", "nano"]);
    let routed: Vec<usize> = report.replicas.iter().map(|r| r.routed).collect();
    assert!(
        routed[3] < routed[0] && routed[3] < routed[1],
        "nano should receive the smallest share, got {routed:?}"
    );
}
