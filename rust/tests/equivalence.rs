//! Bit-exactness guarantees across the engine stack.
//!
//! PR 5's optimized scheduler core (incremental Eq. 7 admission,
//! scratch-owned selection, in-place mask rebuild, serving-loop
//! indexes) was originally pinned against a verbatim pre-optimization
//! reference implementation kept in-tree. PR 10 deleted that reference
//! path — the property suite now pins the selection semantics directly
//! (`rust/tests/property_invariants.rs`) — leaving the in-place mask
//! rebuild check here and the engine-level halves below, which compare
//! production configurations against each other rather than against
//! historical code.
//!
//! PR 6 adds the cluster-engine half (DESIGN.md "Event-driven cluster
//! engine"): the event-driven `Orchestrator` must reproduce the
//! lockstep `Router` — identical `ClusterReport`s down to per-task
//! timings, per-replica routing/step counts, migration and memory
//! counters — across strategies, fleet shapes, admission modes,
//! migration and KV-handoff configurations.
//!
//! PR 9 (DESIGN.md "Parallel event engine") adds the thread-count
//! half: the epoch-batched multi-threaded advancement path must
//! reproduce the sequential event engine's `ClusterReport` exactly —
//! including the migration pass/check counters, which are deterministic
//! within one engine — at every worker count, across the same nine
//! shapes (`parallel_event_engine_is_bit_exact_across_thread_counts`).
//!
//! PR 8 (DESIGN.md "Control-plane incrementality") refines both
//! halves. Reschedule skipping makes `decisions` an implementation
//! detail: the pinned quantity is `decisions + decisions_skipped`,
//! which must equal the no-skip reference's `decisions` exactly
//! (`reschedule_skipping_is_bit_exact_and_accounted`). Edge-triggered
//! migration makes `migration_passes`/`migration_checks` legitimately
//! differ across engines — the lockstep `Router` pays one pass per
//! arrival boundary, the event engine one per overload episode — so
//! those two counters are *excluded* from the engine-pair comparison
//! and asserted `event <= lockstep` instead. Everything else,
//! including the migrated-task set, stays bit-exact.
//!
//! PR 10 (DESIGN.md "Failure detection & recovery") adds the
//! inert-detector half: a fleet with the failure detector *configured*
//! but inert (`suspicion_timeout = 0`, the oracle setting) must
//! reproduce the PR 7 reports bit for bit — no heartbeat events on the
//! heap, no detector counters, identical per-task timings — across the
//! nine shapes, both engines, and thread counts, with and without a
//! crash schedule underneath.

use slice_serve::coordinator::mask::DecodeMask;
use slice_serve::coordinator::task::TaskId;
use slice_serve::engine::latency::LatencyModel;
use slice_serve::server::RunReport;
use slice_serve::util::rng::Rng;
use slice_serve::util::secs;
use slice_serve::workload::WorkloadSpec;

const SEEDS: [u64; 4] = [7, 42, 1234, 777];

fn lat() -> LatencyModel {
    LatencyModel::paper_calibrated()
}

/// In-place mask rebuild == fresh build over random admitted sets,
/// reusing one mask across all cases.
#[test]
fn mask_rebuild_matches_build_across_random_cases() {
    let l = lat();
    let mut reused = DecodeMask::empty();
    for seed in 0..300u64 {
        let mut rng = Rng::new(10_000_000 + seed);
        let n = rng.range_usize(1, 40);
        let rows: Vec<(TaskId, u32)> = (0..n)
            .map(|i| (i as u64, rng.range_u64(1, 25) as u32))
            .collect();
        reused.rebuild(&rows);
        let fresh = DecodeMask::build(rows);
        assert_eq!(reused.rows(), fresh.rows(), "seed {seed}");
        assert_eq!(reused.columns(), fresh.columns(), "seed {seed}");
        for j in 0..fresh.columns() {
            assert_eq!(reused.batch_len(j), fresh.batch_len(j), "seed {seed} col {j}");
        }
        assert_eq!(reused.period_exact(&l), fresh.period_exact(&l), "seed {seed}");
    }
}

fn assert_reports_eq(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.steps, b.steps, "{ctx}: steps");
    assert_eq!(a.decode_steps, b.decode_steps, "{ctx}: decode_steps");
    assert_eq!(a.prefill_steps, b.prefill_steps, "{ctx}: prefill_steps");
    // Reschedule skipping (PR 8) may convert full reschedules into
    // skips, so the invariant is the *sum*: every boundary is either a
    // decision or a proven-unnecessary skip. The reference policy never
    // skips (trait default 0), so against it this pins the accounting
    // identity `decisions + decisions_skipped == decisions_ref`.
    assert_eq!(
        a.decisions + a.decisions_skipped,
        b.decisions + b.decisions_skipped,
        "{ctx}: decisions + decisions_skipped"
    );
    assert_eq!(a.end_time, b.end_time, "{ctx}: end_time");
    assert_eq!(a.memory, b.memory, "{ctx}: memory stats");
    assert_eq!(a.tasks.len(), b.tasks.len(), "{ctx}: task count");
    for (x, y) in a.tasks.iter().zip(&b.tasks) {
        assert_eq!(x.id, y.id, "{ctx}: id");
        assert_eq!(x.first_token, y.first_token, "{ctx}: task {} first_token", x.id);
        assert_eq!(x.completion, y.completion, "{ctx}: task {} completion", x.id);
        assert_eq!(
            x.tokens_generated, y.tokens_generated,
            "{ctx}: task {} tokens",
            x.id
        );
        assert_eq!(x.prefill_end, y.prefill_end, "{ctx}: task {} prefill_end", x.id);
        assert_eq!(x.swap_outs, y.swap_outs, "{ctx}: task {} swap_outs", x.id);
        assert_eq!(x.swap_ins, y.swap_ins, "{ctx}: task {} swap_ins", x.id);
    }
}

// ---- Event engine vs lockstep reference (PR 6) -------------------------

use slice_serve::cluster::{AdmissionMode, ClusterReport, FleetSpec, RoutingStrategy};
use slice_serve::config::{ClusterEngine, ServeConfig};
use slice_serve::experiments;

/// Full `ClusterReport` equality: fleet counters, the shed list, and
/// every replica's routing counts plus its entire `RunReport` (per-task
/// timings, steps, memory stats). `migration_passes` and
/// `migration_checks` are deliberately *not* compared here: the event
/// engine runs passes per overload episode, the lockstep reference per
/// arrival boundary, so they differ by design (asserted `event <=
/// lockstep` in `run_engine_pair` instead).
fn assert_cluster_reports_eq(a: &ClusterReport, b: &ClusterReport, ctx: &str) {
    assert_eq!(a.strategy, b.strategy, "{ctx}: strategy");
    assert_eq!(a.migrations, b.migrations, "{ctx}: migrations");
    assert_eq!(a.migrated_running, b.migrated_running, "{ctx}: migrated_running");
    assert_eq!(a.handoff_bytes, b.handoff_bytes, "{ctx}: handoff_bytes");
    assert_eq!(a.handoff_us, b.handoff_us, "{ctx}: handoff_us");
    assert_eq!(a.rejected_folded, b.rejected_folded, "{ctx}: rejected_folded");
    let shed_a: Vec<u64> = a.rejected.iter().map(|t| t.id).collect();
    let shed_b: Vec<u64> = b.rejected.iter().map(|t| t.id).collect();
    assert_eq!(shed_a, shed_b, "{ctx}: shed list");
    assert_eq!(a.replicas.len(), b.replicas.len(), "{ctx}: fleet width");
    for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
        let c = format!("{ctx}: replica {}", ra.replica);
        assert_eq!(ra.replica, rb.replica, "{c}: id");
        assert_eq!(ra.profile, rb.profile, "{c}: profile");
        assert_eq!(ra.routed, rb.routed, "{c}: routed");
        assert_eq!(ra.migrated_in, rb.migrated_in, "{c}: migrated_in");
        assert_eq!(ra.migrated_out, rb.migrated_out, "{c}: migrated_out");
        // Both sides run the same `SlicePolicy` over the same call
        // sequence here, so the skip split is exact, not just summed.
        assert_eq!(ra.report.decisions, rb.report.decisions, "{c}: decisions");
        assert_eq!(
            ra.report.decisions_skipped, rb.report.decisions_skipped,
            "{c}: decisions_skipped"
        );
        assert_reports_eq(&ra.report, &rb.report, &c);
    }
}

/// Run one cluster cell through both engines and assert bit-exactness.
fn run_engine_pair(
    cfg: &ServeConfig,
    strategy: RoutingStrategy,
    spec: &FleetSpec,
    rate: f64,
    n_tasks: usize,
    seed: u64,
    ctx: &str,
) {
    let workload = WorkloadSpec::paper_mix(rate, 0.7, n_tasks, seed).generate();
    let mut lockstep = cfg.clone();
    lockstep.cluster_engine = ClusterEngine::Lockstep;
    let mut event = cfg.clone();
    event.cluster_engine = ClusterEngine::Event;
    let a = experiments::run_fleet(strategy, spec, workload.clone(), &lockstep, secs(120.0))
        .unwrap();
    let b = experiments::run_fleet(strategy, spec, workload, &event, secs(120.0)).unwrap();
    assert_cluster_reports_eq(&a, &b, ctx);
    // The relaxed half of the PR 8 migration contract: the event
    // engine's edge-triggered checks may only ever *reduce* pass work
    // relative to the per-arrival lockstep cadence, never add to it,
    // and each executed pass is attributable to one handled check.
    assert_eq!(a.migration_checks, 0, "{ctx}: lockstep runs no MigrationCheck events");
    assert!(
        b.migration_passes <= a.migration_passes,
        "{ctx}: event passes ({}) exceed lockstep passes ({})",
        b.migration_passes,
        a.migration_passes
    );
    assert!(
        b.migration_passes <= b.migration_checks,
        "{ctx}: event passes ({}) exceed handled checks ({})",
        b.migration_passes,
        b.migration_checks
    );
}

/// Homogeneous 4-replica fleets: every routing strategy, across seeds.
#[test]
fn event_engine_matches_lockstep_across_strategies() {
    let cfg = ServeConfig::default();
    let spec = FleetSpec::homogeneous(4, cfg.cycle_cap);
    for strategy in RoutingStrategy::ALL {
        for seed in [7u64, 42, 1234] {
            run_engine_pair(
                &cfg,
                strategy,
                &spec,
                4.0,
                160,
                seed,
                &format!("{strategy:?}/seed{seed}"),
            );
        }
    }
}

/// A 1-replica fleet — the degenerate cell where both engines must also
/// reproduce the single-device serving path.
#[test]
fn event_engine_matches_lockstep_single_replica() {
    let cfg = ServeConfig::default();
    let spec = FleetSpec::homogeneous(1, cfg.cycle_cap);
    for seed in SEEDS {
        run_engine_pair(
            &cfg,
            RoutingStrategy::SloAware,
            &spec,
            1.0,
            120,
            seed,
            &format!("single/seed{seed}"),
        );
    }
}

/// Heterogeneous edge-mixed fleets under both admission modes (shed
/// lists must match element for element).
#[test]
fn event_engine_matches_lockstep_hetero_admission() {
    let base = ServeConfig::default();
    let spec = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(base.cycle_cap);
    for (mode, label) in
        [(AdmissionMode::QueueDepth, "depth"), (AdmissionMode::Headroom, "headroom")]
    {
        let mut cfg = base.clone();
        cfg.cluster_admission.enabled = true;
        cfg.cluster_admission.mode = mode;
        for seed in [7u64, 42, 1234] {
            run_engine_pair(
                &cfg,
                RoutingStrategy::SloAware,
                &spec,
                6.0,
                200,
                seed,
                &format!("hetero-{label}/seed{seed}"),
            );
        }
    }
}

/// Overload migration on a heterogeneous fleet: migration counts,
/// per-replica in/out tallies and post-migration timings must agree.
/// PR 8 makes the event engine's half edge-triggered (a pass runs only
/// when a `MigrationCheck` fires on an overload episode), so this test
/// is also the relaxed-equivalence witness: the migrated-task *set* —
/// per-replica `migrated_in`/`migrated_out`, every task's post-handoff
/// timings — stays bit-exact across all four seeds while the pass
/// counters are only ordered, not equal.
#[test]
fn event_engine_matches_lockstep_migration() {
    let mut cfg = ServeConfig::default();
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    cfg.cluster_migration = true;
    let spec = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(cfg.cycle_cap);
    for seed in SEEDS {
        run_engine_pair(
            &cfg,
            RoutingStrategy::SloAware,
            &spec,
            6.0,
            200,
            seed,
            &format!("migration/seed{seed}"),
        );
    }
}

/// Constrained KV memory with running-task handoff migration — the
/// fullest configuration: swap/restore counters, handoff bytes and
/// delays, and per-task swap tallies must all be bit-identical.
#[test]
fn event_engine_matches_lockstep_memory_and_handoff() {
    let mut cfg = ServeConfig::default();
    cfg.memory.kv_capacity = Some(48 * 1024 * 1024);
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    cfg.cluster_migration = true;
    cfg.cluster_migrate_running = true;
    let spec = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(cfg.cycle_cap);
    for seed in [7u64, 42, 1234] {
        run_engine_pair(
            &cfg,
            RoutingStrategy::SloAware,
            &spec,
            6.0,
            200,
            seed,
            &format!("memory-handoff/seed{seed}"),
        );
    }
    // constrained memory without migration as well: the serving loop's
    // eviction/restore clocking must agree without the handoff path
    let mut cfg = ServeConfig::default();
    cfg.memory.kv_capacity = Some(32 * 1024 * 1024);
    let spec = FleetSpec::homogeneous(4, cfg.cycle_cap);
    for seed in [7u64, 42] {
        run_engine_pair(
            &cfg,
            RoutingStrategy::LeastLoaded,
            &spec,
            4.0,
            160,
            seed,
            &format!("memory-only/seed{seed}"),
        );
    }
}

// ---- All-disabled elastic runs vs static fleets (PR 7) -----------------

use slice_serve::cluster::{LifecycleConfig, Orchestrator, Replica};
use slice_serve::coordinator::task::Task;

/// The same fleet `experiments::run_fleet` builds for `cfg`/`spec`:
/// per-profile policy + engine, `max_batch` capped, the configured KV
/// capacity threaded in when the config constrains memory.
fn build_fleet(cfg: &ServeConfig, spec: &FleetSpec) -> Vec<Replica> {
    let spec = if cfg.memory.constrained()
        && spec.profiles.iter().all(|p| p.kv_capacity.is_none())
    {
        spec.clone().with_kv_capacity(cfg.memory.kv_capacity)
    } else {
        spec.clone()
    };
    spec.profiles
        .iter()
        .enumerate()
        .map(|(i, profile)| {
            let mut profile = profile.clone();
            profile.latency.max_batch = cfg.max_batch.min(profile.max_batch);
            Replica::new(
                i,
                experiments::build_policy_for(cfg.policy, cfg, &profile),
                Box::new(experiments::build_engine_for(cfg, &profile)),
                profile,
            )
        })
        .collect()
}

/// An event-engine run with the elastic machinery *attached* but every
/// feature disabled: the liveness/health masks are initialized and the
/// elastic decision paths run for real.
fn run_elastic_noop(
    cfg: &ServeConfig,
    strategy: RoutingStrategy,
    spec: &FleetSpec,
    workload: Vec<Task>,
) -> ClusterReport {
    let factory_cfg = cfg.clone();
    Orchestrator::new(strategy, build_fleet(cfg, spec))
        .with_admission(cfg.cluster_admission)
        .with_migration(cfg.cluster_migration)
        .with_running_migration(cfg.cluster_migrate_running, cfg.memory.clone())
        .with_lifecycle(
            LifecycleConfig::default(),
            Box::new(move |id| {
                let profile = experiments::standard_profile(&factory_cfg);
                Replica::new(
                    id,
                    experiments::build_policy_for(factory_cfg.policy, &factory_cfg, &profile),
                    Box::new(experiments::build_engine_for(&factory_cfg, &profile)),
                    profile,
                )
            }),
        )
        .run(workload, secs(120.0))
        .unwrap()
}

/// The nine canonical equivalence shapes (PR 6/7): strategy spread over
/// homogeneous fleets, the single-replica degenerate, heterogeneous
/// admission in both modes, overload migration, constrained memory with
/// and without running handoff.
fn nine_shapes() -> Vec<(&'static str, ServeConfig, RoutingStrategy, FleetSpec, f64, usize)> {
    let base = ServeConfig::default();
    let homog = FleetSpec::homogeneous(4, base.cycle_cap);
    let single = FleetSpec::homogeneous(1, base.cycle_cap);
    let hetero = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(base.cycle_cap);

    let admission = |mode: AdmissionMode| {
        let mut c = base.clone();
        c.cluster_admission.enabled = true;
        c.cluster_admission.mode = mode;
        c
    };
    let migration = {
        let mut c = admission(AdmissionMode::Headroom);
        c.cluster_migration = true;
        c
    };
    let memory_handoff = {
        let mut c = migration.clone();
        c.memory.kv_capacity = Some(48 * 1024 * 1024);
        c.cluster_migrate_running = true;
        c
    };
    let memory_only = {
        let mut c = base.clone();
        c.memory.kv_capacity = Some(32 * 1024 * 1024);
        c
    };

    vec![
        ("round-robin", base.clone(), RoutingStrategy::RoundRobin, homog.clone(), 4.0, 160),
        ("least-loaded", base.clone(), RoutingStrategy::LeastLoaded, homog.clone(), 4.0, 160),
        ("slo-aware", base.clone(), RoutingStrategy::SloAware, homog.clone(), 4.0, 160),
        ("single", base.clone(), RoutingStrategy::SloAware, single, 1.0, 120),
        (
            "hetero-depth",
            admission(AdmissionMode::QueueDepth),
            RoutingStrategy::SloAware,
            hetero.clone(),
            6.0,
            200,
        ),
        (
            "hetero-headroom",
            admission(AdmissionMode::Headroom),
            RoutingStrategy::SloAware,
            hetero.clone(),
            6.0,
            200,
        ),
        ("migration", migration, RoutingStrategy::SloAware, hetero.clone(), 6.0, 200),
        ("memory-handoff", memory_handoff, RoutingStrategy::SloAware, hetero, 6.0, 200),
        ("memory-only", memory_only, RoutingStrategy::LeastLoaded, homog, 4.0, 160),
    ]
}

/// An all-disabled elastic run must be bit-exact with the PR 6 static
/// fleets on *both* engines, across the existing nine equivalence
/// shapes: the masks exist, the lifecycle stream is empty, and nothing
/// else may change — no stray joins, no elastic counters, every replica
/// alive.
#[test]
fn all_disabled_elastic_is_bit_exact_with_static_fleets() {
    for (label, cfg, strategy, spec, rate, n_tasks) in nine_shapes() {
        let spec = &spec;
        let workload = WorkloadSpec::paper_mix(rate, 0.7, n_tasks, 7).generate();
        let mut lockstep = cfg.clone();
        lockstep.cluster_engine = ClusterEngine::Lockstep;
        let mut event = cfg.clone();
        event.cluster_engine = ClusterEngine::Event;
        let ls = experiments::run_fleet(strategy, spec, workload.clone(), &lockstep, secs(120.0))
            .unwrap();
        let ev = experiments::run_fleet(strategy, spec, workload.clone(), &event, secs(120.0))
            .unwrap();
        let noop = run_elastic_noop(&cfg, strategy, spec, workload);
        assert_cluster_reports_eq(&noop, &ls, &format!("{label}: noop vs lockstep"));
        assert_cluster_reports_eq(&noop, &ev, &format!("{label}: noop vs event"));
        // nothing elastic may have happened
        let e = &noop.elastic;
        assert_eq!(
            (e.crashes, e.joins, e.leaves, e.autoscale_grows, e.autoscale_shrinks),
            (0, 0, 0, 0, 0),
            "{label}: elastic counters on an all-disabled run"
        );
        assert_eq!(e.evac_requeued + e.evac_restarted, 0, "{label}: evacuations");
        assert!(noop.replicas.iter().all(|r| r.alive), "{label}: every replica alive");
        assert_eq!(noop.alive_replicas(), spec.len(), "{label}: fleet width");
    }
}

// ---- Epoch-parallel advancement vs the sequential engine (PR 9) --------

/// The epoch-batched parallel advancement path must reproduce the
/// sequential event engine bit for bit at every thread count, across
/// all nine canonical shapes: identical `ClusterReport`s down to
/// per-task timings, shed lists, migration sets, memory counters — and,
/// unlike the cross-engine comparison, identical
/// `migration_passes`/`migration_checks` too, since both runs are the
/// same engine and the pass cadence is deterministic.
#[test]
fn parallel_event_engine_is_bit_exact_across_thread_counts() {
    for (label, cfg, strategy, spec, rate, n_tasks) in nine_shapes() {
        let workload = WorkloadSpec::paper_mix(rate, 0.7, n_tasks, 7).generate();
        let mut seq = cfg.clone();
        seq.cluster_engine = ClusterEngine::Event;
        seq.cluster_threads = 1;
        let baseline =
            experiments::run_fleet(strategy, &spec, workload.clone(), &seq, secs(120.0))
                .unwrap();
        for threads in [2usize, 4, 8] {
            let mut par = cfg.clone();
            par.cluster_engine = ClusterEngine::Event;
            par.cluster_threads = threads;
            let report = experiments::run_fleet(
                strategy,
                &spec,
                workload.clone(),
                &par,
                secs(120.0),
            )
            .unwrap();
            let ctx = format!("parallel/{label}/t{threads}");
            assert_cluster_reports_eq(&report, &baseline, &ctx);
            assert_eq!(
                report.migration_passes, baseline.migration_passes,
                "{ctx}: migration_passes"
            );
            assert_eq!(
                report.migration_checks, baseline.migration_checks,
                "{ctx}: migration_checks"
            );
        }
    }
}

// ---- Reschedule skipping vs full reschedules (PR 8) --------------------

/// Skipping enabled vs disabled must be observably identical across all
/// nine shapes on both engines: same steps, same per-task timings, same
/// shed lists, same migrations — only the `decisions`/`decisions_skipped`
/// split moves, and it must satisfy the accounting identity
/// `decisions + decisions_skipped == decisions(disabled)` exactly, per
/// replica. Shapes outside the immutable regime (memory-constrained,
/// prefill-aware, adaptor-driven) must never skip; the regime-eligible
/// shapes must skip at least once somewhere, or the optimization is
/// dead code.
#[test]
fn reschedule_skipping_is_bit_exact_and_accounted() {
    let mut total_skipped = 0u64;
    for (label, cfg, strategy, spec, rate, n_tasks) in nine_shapes() {
        let workload = WorkloadSpec::paper_mix(rate, 0.7, n_tasks, 7).generate();
        for engine in [ClusterEngine::Lockstep, ClusterEngine::Event] {
            let mut on = cfg.clone();
            on.incremental = true;
            on.cluster_engine = engine;
            let mut off = cfg.clone();
            off.incremental = false;
            off.cluster_engine = engine;
            let a = experiments::run_fleet(strategy, &spec, workload.clone(), &on, secs(120.0))
                .unwrap();
            let b = experiments::run_fleet(strategy, &spec, workload.clone(), &off, secs(120.0))
                .unwrap();
            let ctx = format!("skip/{label}/{engine:?}");
            // Everything except the decision split is bit-exact; the
            // summed comparison inside `assert_reports_eq` enforces the
            // accounting identity per replica.
            assert_cluster_counters_eq(&a, &b, &ctx);
            assert_eq!(
                b.total_decisions_skipped(),
                0,
                "{ctx}: skipping disabled yet skips counted"
            );
            if cfg.memory.constrained() {
                // outside the immutable regime the precondition can't
                // be proven, so the gate must hold the skip path shut
                assert_eq!(
                    a.total_decisions_skipped(),
                    0,
                    "{ctx}: memory-constrained shape skipped a reschedule"
                );
            }
            total_skipped += a.total_decisions_skipped();
        }
    }
    assert!(total_skipped > 0, "no shape ever skipped a reschedule — skip path is dead");
}

/// `assert_cluster_reports_eq` minus the exact per-replica decision
/// split (which legitimately moves between `decisions` and
/// `decisions_skipped` when comparing skip-on against skip-off).
fn assert_cluster_counters_eq(a: &ClusterReport, b: &ClusterReport, ctx: &str) {
    assert_eq!(a.strategy, b.strategy, "{ctx}: strategy");
    assert_eq!(a.migrations, b.migrations, "{ctx}: migrations");
    assert_eq!(a.migrated_running, b.migrated_running, "{ctx}: migrated_running");
    assert_eq!(a.handoff_bytes, b.handoff_bytes, "{ctx}: handoff_bytes");
    assert_eq!(a.handoff_us, b.handoff_us, "{ctx}: handoff_us");
    assert_eq!(a.rejected_folded, b.rejected_folded, "{ctx}: rejected_folded");
    let shed_a: Vec<u64> = a.rejected.iter().map(|t| t.id).collect();
    let shed_b: Vec<u64> = b.rejected.iter().map(|t| t.id).collect();
    assert_eq!(shed_a, shed_b, "{ctx}: shed list");
    assert_eq!(a.replicas.len(), b.replicas.len(), "{ctx}: fleet width");
    for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
        let c = format!("{ctx}: replica {}", ra.replica);
        assert_eq!(ra.routed, rb.routed, "{c}: routed");
        assert_eq!(ra.migrated_in, rb.migrated_in, "{c}: migrated_in");
        assert_eq!(ra.migrated_out, rb.migrated_out, "{c}: migrated_out");
        assert_reports_eq(&ra.report, &rb.report, &c);
    }
}

// ---- Inert detector vs the PR 7 oracle (PR 10) -------------------------

use slice_serve::cluster::{LifecycleAction, LifecycleEvent};

/// The failure detector *configured* but inert (`suspicion_timeout =
/// 0`, the oracle setting) must change nothing: no heartbeat events
/// reach the heap, the boundary math never sees a heartbeat term, and
/// the reports stay bit-exact with the detector-free engines — across
/// all nine shapes, against both the lockstep and event baselines, at
/// 1 and 4 worker threads. This is the gate that keeps
/// `--detect-delay 0` an honest oracle spelling rather than a subtly
/// different engine.
#[test]
fn inert_detector_is_bit_exact_across_shapes_and_threads() {
    for (label, cfg, strategy, spec, rate, n_tasks) in nine_shapes() {
        let workload = WorkloadSpec::paper_mix(rate, 0.7, n_tasks, 7).generate();
        let mut lockstep = cfg.clone();
        lockstep.cluster_engine = ClusterEngine::Lockstep;
        let ls = experiments::run_fleet(strategy, &spec, workload.clone(), &lockstep, secs(120.0))
            .unwrap();
        let mut event = cfg.clone();
        event.cluster_engine = ClusterEngine::Event;
        let ev = experiments::run_fleet(strategy, &spec, workload.clone(), &event, secs(120.0))
            .unwrap();
        for threads in [1usize, 4] {
            let mut det = cfg.clone();
            det.cluster_engine = ClusterEngine::Event;
            det.cluster_threads = threads;
            det.lifecycle.detector.enabled = true;
            det.lifecycle.detector.suspicion_timeout = 0;
            let report =
                experiments::run_fleet(strategy, &spec, workload.clone(), &det, secs(120.0))
                    .unwrap();
            let ctx = format!("inert-detector/{label}/t{threads}");
            assert_cluster_reports_eq(&report, &ls, &format!("{ctx} vs lockstep"));
            assert_cluster_reports_eq(&report, &ev, &format!("{ctx} vs event"));
            let e = &report.elastic;
            assert_eq!(
                (e.suspicions, e.false_suspicions, e.detections),
                (0, 0, 0),
                "{ctx}: detector counters on an inert run"
            );
            assert_eq!(
                (e.limbo_recovered, e.retries, e.retry_exhausted, e.limbo_lost),
                (0, 0, 0, 0),
                "{ctx}: recovery counters on an inert run"
            );
        }
    }
}

/// The oracle spelling under real crashes: a two-crash schedule run
/// with the detector configured at `suspicion_timeout = 0` must
/// reproduce the detector-free PR 7 crash handling bit for bit —
/// instant oracle visibility, free re-queues, recompute-priced
/// evacuation — at both thread counts, with every detector counter
/// still zero.
#[test]
fn inert_detector_reproduces_oracle_crash_handling() {
    let mut cfg = ServeConfig::default();
    cfg.cluster_engine = ClusterEngine::Event;
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    cfg.cluster_migration = true;
    cfg.lifecycle.events = vec![
        LifecycleEvent { time: secs(40.0), action: LifecycleAction::Crash, target: Some(0) },
        LifecycleEvent { time: secs(80.0), action: LifecycleAction::Crash, target: Some(1) },
    ];
    let spec = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(cfg.cycle_cap);
    let workload = WorkloadSpec::paper_mix(6.0, 0.7, 200, 7).generate();
    let oracle = experiments::run_fleet(
        RoutingStrategy::SloAware,
        &spec,
        workload.clone(),
        &cfg,
        secs(120.0),
    )
    .unwrap();
    assert_eq!(oracle.elastic.crashes, 2, "both scheduled crashes fire");
    for threads in [1usize, 4] {
        let mut det = cfg.clone();
        det.cluster_threads = threads;
        det.lifecycle.detector.enabled = true;
        det.lifecycle.detector.suspicion_timeout = 0;
        let report = experiments::run_fleet(
            RoutingStrategy::SloAware,
            &spec,
            workload.clone(),
            &det,
            secs(120.0),
        )
        .unwrap();
        let ctx = format!("oracle-crash/t{threads}");
        assert_cluster_reports_eq(&report, &oracle, &ctx);
        assert_eq!(report.elastic, oracle.elastic, "{ctx}: elastic counters");
        assert_eq!(report.elastic.detections, 0, "{ctx}: oracle path never detects");
    }
}
