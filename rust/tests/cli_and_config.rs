//! CLI and config-file integration: exercise the installed binary the
//! way a user would (config parsing, experiment subcommands, JSON
//! output), using the sim engine only so no artifacts are required.

use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_slice-serve")
}

#[test]
fn usage_prints_without_args() {
    let out = Command::new(bin()).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("experiment"));
}

#[test]
fn help_exits_zero_bad_args_exit_two() {
    // --help (anywhere) prints usage and exits 0
    let out = Command::new(bin()).arg("--help").output().unwrap();
    assert_eq!(out.status.code(), Some(0));
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
    let out = Command::new(bin()).args(["serve", "--help"]).output().unwrap();
    assert_eq!(out.status.code(), Some(0));

    // a flag missing its value is an argument error -> exit 2
    let out = Command::new(bin()).args(["serve", "--rate"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("needs a value"));

    // unknown subcommand -> exit 2
    let out = Command::new(bin()).arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn serve_sim_runs_and_reports() {
    let out = Command::new(bin())
        .args([
            "serve", "--policy", "slice", "--engine", "sim", "--rate", "0.5",
            "--n-tasks", "30", "--seed", "9",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("policy=SLICE"));
    assert!(text.contains("SLO attainment"));
}

#[test]
fn experiment_table2_emits_paper_rows() {
    let out = Command::new(bin())
        .args(["experiment", "table2"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in ["Task A", "Task B", "Task C", "Orca", "FastServe", "SLICE"] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
}

#[test]
fn experiment_writes_json_output() {
    let dir = std::env::temp_dir().join("slice_serve_test_out");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fig1.json");
    let out = Command::new(bin())
        .args(["experiment", "fig1", "--out", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = std::fs::read_to_string(&path).unwrap();
    let j = slice_serve::util::json::Json::parse(&text).unwrap();
    let rows = j.get("fig1").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 16);
    std::fs::remove_file(&path).ok();
}

#[test]
fn config_file_drives_serve() {
    let dir = std::env::temp_dir().join("slice_serve_test_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.toml");
    std::fs::write(
        &path,
        r#"
[scheduler]
policy = "orca"
max_batch = 8

[workload]
arrival_rate = 0.4
n_tasks = 20
seed = 3
"#,
    )
    .unwrap();
    let out = Command::new(bin())
        .args(["serve", "--config", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("policy=Orca"), "{text}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn cluster_subcommand_reports_fleet_and_replicas() {
    let out = Command::new(bin())
        .args([
            "cluster", "--replicas", "4", "--strategy", "slo-aware", "--rate", "2.0",
            "--n-tasks", "40", "--seed", "9",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("strategy=slo-aware replicas=4"), "{text}");
    assert!(text.contains("overall SLO attainment"), "{text}");
    assert!(text.contains("per-replica:"), "{text}");
    assert!(text.contains("TTFT p50 / p95 / p99"), "{text}");

    // bad strategy is an argument-level error
    let out = Command::new(bin())
        .args(["cluster", "--replicas", "2", "--strategy", "hash"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown routing strategy"));
}

#[test]
fn cluster_fleet_and_guard_flags() {
    let out = Command::new(bin())
        .args([
            "cluster", "--fleet", "edge-mixed", "--strategy", "slo-aware",
            "--admission", "on", "--migration", "on", "--rate", "2.0", "--n-tasks",
            "40", "--seed", "9",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("replicas=4"), "{text}");
    assert!(text.contains("shed="), "{text}");
    assert!(text.contains("migrations="), "{text}");
    assert!(text.contains("nano"), "per-replica table lists tiers: {text}");

    // unknown tier and malformed switch are argument-level errors
    let out = Command::new(bin())
        .args(["cluster", "--fleet", "warp"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown device profile"));
    let out = Command::new(bin())
        .args(["cluster", "--admission", "maybe"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("expected on|off"));
}

#[test]
fn cluster_memory_flags_and_table_rows() {
    let out = Command::new(bin())
        .args([
            "cluster", "--fleet", "edge-mixed", "--admission", "headroom",
            "--migrate-running", "on", "--kv-capacity", "32", "--rate", "2.0",
            "--n-tasks", "40", "--seed", "9",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("peak KV (fleet sum)"), "{text}");
    assert!(text.contains("swaps out / in / recompute"), "{text}");
    assert!(text.contains("KV handoffs (bytes / time)"), "{text}");
    assert!(text.contains("(running "), "running-migration count printed: {text}");

    // bad memory flags are argument-level errors
    let out = Command::new(bin())
        .args(["serve", "--kv-capacity", "-3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--kv-capacity"));
    let out = Command::new(bin())
        .args(["serve", "--preemption", "drop"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown preemption mode"));
}

#[test]
fn unknown_experiment_fails_cleanly() {
    let out = Command::new(bin())
        .args(["experiment", "fig99"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown experiment"));
}

#[test]
fn bad_flag_fails_cleanly() {
    let out = Command::new(bin())
        .args(["serve", "--rate", "not-a-number"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn trace_save_and_replay_round_trip() {
    let dir = std::env::temp_dir().join("slice_serve_trace_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("wl.json");
    // record
    let out = Command::new(bin())
        .args([
            "serve", "--engine", "sim", "--rate", "0.5", "--n-tasks", "15",
            "--seed", "77", "--save-trace", path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let first = String::from_utf8_lossy(&out.stdout).to_string();
    // replay must reproduce the identical run
    let out2 = Command::new(bin())
        .args(["serve", "--engine", "sim", "--trace", path.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out2.status.success());
    let second = String::from_utf8_lossy(&out2.stdout);
    let tail = |s: &str| {
        s.lines()
            .filter(|l| l.contains("attainment") || l.contains("completion"))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let save_line = format!("saved workload trace to {}\n", path.display());
    assert_eq!(tail(&first.replace(&save_line, "")), tail(&second));
    std::fs::remove_file(&path).ok();
}
