//! Chaos-recovery invariants (DESIGN.md "Failure detection &
//! recovery"): with crashes hidden behind the heartbeat detector, every
//! workload task is still accounted for exactly once — finished on some
//! replica, stranded unfinished on an unconfirmed corpse, or shed
//! through one of the recovery paths (`retry_exhausted`, `limbo_lost`,
//! admission) — across hundreds of seeded fault schedules; retry
//! re-dispatch strictly beats the no-retry floor at the
//! crash-at-overload acceptance cell; and detector lag alone (an
//! overloaded but live fleet) never escalates past suspicion.

use slice_serve::cluster::{
    ClusterReport, DeviceProfile, LifecycleConfig, Orchestrator, Replica,
    RoutingStrategy,
};
use slice_serve::config::ServeConfig;
use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
use slice_serve::engine::latency::LatencyModel;
use slice_serve::engine::sim::SimEngine;
use slice_serve::experiments::chaos_sweep;
use slice_serve::util::secs;
use slice_serve::workload::WorkloadSpec;

fn std_replica(i: usize) -> Replica {
    Replica::new(
        i,
        Box::new(SlicePolicy::new(
            LatencyModel::paper_calibrated(),
            SliceConfig::default(),
        )),
        Box::new(SimEngine::paper_calibrated()),
        DeviceProfile::standard(),
    )
}

/// Every workload task lands in the report exactly once — on one
/// replica (finished or stranded on a corpse) or the shed list —
/// whatever the detector and the fault schedule did meanwhile.
fn assert_conserved(report: &ClusterReport, n_tasks: usize, ctx: &str) {
    let mut seen = vec![0u32; n_tasks];
    for r in &report.replicas {
        for t in &r.report.tasks {
            seen[t.id as usize] += 1;
        }
    }
    for t in &report.rejected {
        seen[t.id as usize] += 1;
    }
    for (id, &c) in seen.iter().enumerate() {
        assert_eq!(c, 1, "{ctx}: task {id} appears {c} times");
    }
}

/// Counter coherence that must hold on any detector-active run: a
/// confirmation needs a physical crash behind it, a cleared suspicion
/// needs a raised one, every recovered limbo task fires at least one
/// retry dispatch when the budget is nonzero, and the budget bounds
/// how often one dispatch can end in exhaustion.
fn assert_detector_coherent(report: &ClusterReport, max_retries: u32, ctx: &str) {
    let e = &report.elastic;
    assert!(
        e.detections <= e.crashes,
        "{ctx}: {} detections but only {} crashes — a live replica was confirmed dead",
        e.detections,
        e.crashes
    );
    assert!(
        e.false_suspicions <= e.suspicions,
        "{ctx}: cleared {} suspicions but only {} were raised",
        e.false_suspicions,
        e.suspicions
    );
    if max_retries > 0 {
        assert!(
            e.retries >= e.limbo_recovered,
            "{ctx}: {} limbo tasks recovered but only {} retry dispatches",
            e.limbo_recovered,
            e.retries
        );
        assert!(
            e.retry_exhausted <= e.retries,
            "{ctx}: {} exhaustions out of {} dispatches",
            e.retry_exhausted,
            e.retries
        );
    } else {
        assert_eq!(e.retries, 0, "{ctx}: retry dispatches at a zero budget");
        assert_eq!(
            e.retry_exhausted, e.limbo_recovered,
            "{ctx}: zero budget sheds exactly what it recovers"
        );
    }
    if e.detections == e.crashes {
        // with every corpse confirmed, nothing strands on an
        // unconfirmed node at the horizon: the only limbo losses are
        // flushed retry-pending tasks, each recovered earlier
        assert!(
            e.limbo_lost <= e.limbo_recovered,
            "{ctx}: more limbo lost ({}) than ever recovered ({})",
            e.limbo_lost,
            e.limbo_recovered
        );
    }
}

/// 500 seeded fault schedules with a nonzero detection delay: random
/// churn (crashes, joins, graceful leaves) against a live workload,
/// with heartbeats, suspicion, confirmation, retry and horizon
/// flushing all in play — and every task still accounted for exactly
/// once, every counter coherent.
#[test]
fn every_task_is_accounted_exactly_once_across_500_fault_schedules() {
    for seed in 0..500u64 {
        let n_tasks = 8;
        let width = 3usize;
        let mut lc = LifecycleConfig {
            churn_rate: 1.0,
            seed,
            min_replicas: 1,
            max_replicas: 5,
            ..LifecycleConfig::default()
        };
        lc.detector.enabled = true;
        lc.detector.heartbeat_interval = secs(0.5);
        lc.detector.suspicion_timeout = secs(1.5);
        lc.detector.max_retries = 2;
        lc.detector.retry_backoff = secs(0.5);
        let workload = WorkloadSpec::paper_mix(2.0, 0.7, n_tasks, seed).generate();
        let report = Orchestrator::new(
            RoutingStrategy::SloAware,
            (0..width).map(std_replica).collect(),
        )
        .with_lifecycle(lc.clone(), Box::new(std_replica))
        .run(workload, secs(15.0))
        .unwrap();

        let ctx = format!("chaos seed {seed}");
        assert_conserved(&report, n_tasks, &ctx);
        assert_detector_coherent(&report, lc.detector.max_retries, &ctx);
    }
}

/// The acceptance cell: a crash-at-overload run with detection enabled
/// recovers in-limbo tasks via retry — nonzero retry dispatches, and a
/// shed count strictly below the no-retry twin at the same detection
/// delay (whose shed *is* the limbo floor, since admission is off and
/// the recovery paths are the only shed source).
#[test]
fn retry_redispatch_beats_the_no_retry_floor_at_the_crash_cell() {
    let cfg = ServeConfig::default();
    let n = 1_000;
    let retry = chaos_sweep::run_cell("crash-d8", n, &cfg).unwrap();
    let bare = chaos_sweep::run_cell("crash-d8-noretry", n, &cfg).unwrap();

    assert_eq!(retry.crashes, 2, "both scheduled crashes fire");
    assert_eq!(retry.detections, 2, "both corpses confirmed");
    assert!(
        bare.limbo_recovered > 0,
        "the 8 s detection gap must land dispatches in limbo"
    );
    assert_eq!(
        bare.retry_exhausted, bare.limbo_recovered,
        "the no-retry twin sheds its whole limbo at confirmation"
    );
    assert!(retry.retries > 0, "recovery must run retry dispatches");
    assert!(retry.limbo_recovered > 0);
    assert!(
        retry.shed < bare.shed,
        "retry shed {} must be strictly below the no-retry floor {}",
        retry.shed,
        bare.shed
    );
}

/// Detector lag on a *live* fleet: a heavy burst with no fault schedule
/// at all. Overloaded replicas heartbeat late (cycle-lag delivery), so
/// suspicion edges may rise and clear — but nothing may ever be
/// confirmed dead, nothing limboes, nothing sheds, and the fleet ends
/// fully alive.
#[test]
fn overload_lag_never_confirms_a_live_replica() {
    use slice_serve::cluster::FleetSpec;
    use slice_serve::config::{ClusterEngine, PolicyKind};
    use slice_serve::experiments::run_fleet;

    let mut cfg = ServeConfig::default();
    cfg.n_tasks = 800;
    cfg.arrival_rate = cfg.n_tasks as f64 / 120.0;
    cfg.policy = PolicyKind::Slice;
    cfg.cluster_engine = ClusterEngine::Event;
    cfg.cluster_admission.enabled = false;
    cfg.cluster_migration = true;
    cfg.lifecycle.detector.enabled = true;
    cfg.lifecycle.detector.heartbeat_interval = secs(0.5);
    cfg.lifecycle.detector.suspicion_timeout = secs(2.0);
    let spec = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(cfg.cycle_cap);
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let report =
        run_fleet(RoutingStrategy::SloAware, &spec, workload, &cfg, secs(60.0)).unwrap();

    let e = &report.elastic;
    assert_eq!(e.crashes, 0, "no faults were scheduled");
    assert_eq!(e.detections, 0, "a live replica was confirmed dead");
    assert_eq!(
        e.limbo_recovered + e.retries + e.retry_exhausted + e.limbo_lost,
        0,
        "nothing limboes without a confirmed corpse"
    );
    assert!(
        e.false_suspicions <= e.suspicions,
        "cleared {} suspicions but only {} were raised",
        e.false_suspicions,
        e.suspicions
    );
    assert!(report.replicas.iter().all(|r| r.alive), "the fleet ends fully alive");
    assert_conserved(&report, cfg.n_tasks, "live-lag");
}
