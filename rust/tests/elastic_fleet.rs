//! Elastic-fleet invariants (DESIGN.md "Elastic fleets"): placement
//! never targets dead replicas, fleet size respects its configured
//! bounds under seeded churn, crashed work is re-admitted exactly once
//! at the recompute price, the autoscaler actually buys capacity under
//! overload, and every elastic run is deterministic for a fixed seed.

use slice_serve::cluster::{
    AdmissionMode, ClusterReport, DeviceProfile, FleetSpec, LifecycleAction,
    LifecycleConfig, LifecycleEvent, Orchestrator, Replica, RoutingStrategy,
};
use slice_serve::config::{ClusterEngine, ServeConfig};
use slice_serve::coordinator::slice::{SliceConfig, SlicePolicy};
use slice_serve::engine::latency::LatencyModel;
use slice_serve::engine::sim::SimEngine;
use slice_serve::experiments::run_fleet;
use slice_serve::util::{secs, Micros};
use slice_serve::workload::WorkloadSpec;

fn std_replica(i: usize) -> Replica {
    Replica::new(
        i,
        Box::new(SlicePolicy::new(
            LatencyModel::paper_calibrated(),
            SliceConfig::default(),
        )),
        Box::new(SimEngine::paper_calibrated()),
        DeviceProfile::standard(),
    )
}

fn crash(at: Micros, target: usize) -> LifecycleEvent {
    LifecycleEvent { time: at, action: LifecycleAction::Crash, target: Some(target) }
}

/// Every workload task lands in the report exactly once — on one
/// replica or the shed list — whatever the fleet did meanwhile.
fn assert_conserved(report: &ClusterReport, n_tasks: usize, ctx: &str) {
    let mut seen = vec![0u32; n_tasks];
    for r in &report.replicas {
        for t in &r.report.tasks {
            seen[t.id as usize] += 1;
        }
    }
    for t in &report.rejected {
        seen[t.id as usize] += 1;
    }
    for (id, &c) in seen.iter().enumerate() {
        assert_eq!(c, 1, "{ctx}: task {id} appears {c} times");
    }
}

/// Replicas crashed before the first arrival route nothing, step
/// nothing, and hold nothing — placement never targets a dead replica.
#[test]
fn placement_never_targets_dead_replicas() {
    let n_tasks = 30;
    let workload = WorkloadSpec::paper_mix(2.0, 0.7, n_tasks, 7).generate();
    let lc = LifecycleConfig {
        events: vec![crash(0, 0), crash(0, 1), crash(0, 2)],
        ..LifecycleConfig::default()
    };
    let report = Orchestrator::new(
        RoutingStrategy::SloAware,
        (0..4).map(std_replica).collect(),
    )
    .with_lifecycle(lc, Box::new(std_replica))
    .run(workload, secs(120.0))
    .unwrap();

    assert_eq!(report.elastic.crashes, 3);
    assert_eq!(report.alive_replicas(), 1);
    for r in &report.replicas[..3] {
        assert!(!r.alive, "replica {} crashed at t=0", r.replica);
        assert_eq!(r.routed, 0, "replica {} was dead before any arrival", r.replica);
        assert_eq!(r.report.steps, 0, "replica {} stepped while dead", r.replica);
        assert!(r.report.tasks.is_empty(), "replica {} holds tasks", r.replica);
    }
    let survivor = &report.replicas[3];
    assert!(survivor.alive);
    assert_eq!(survivor.routed, n_tasks, "everything routes to the survivor");
    assert_conserved(&report, n_tasks, "dead-placement");
}

/// A crash mid-run evacuates every unfinished task to the survivors
/// exactly once, and started tasks pay a recompute fee on the clock:
/// whatever finishes after evacuation finishes strictly after the
/// crash instant.
#[test]
fn crashed_tasks_are_readmitted_exactly_once_with_recompute_fees() {
    let n_tasks = 80;
    let crash_t = secs(8.0);
    // round-robin pins task id % 4 to its replica, so evacuees are
    // identifiable in the final report
    let workload = WorkloadSpec::paper_mix(8.0, 0.7, n_tasks, 42).generate();
    let lc = LifecycleConfig {
        events: vec![crash(crash_t, 0)],
        ..LifecycleConfig::default()
    };
    let report = Orchestrator::new(
        RoutingStrategy::RoundRobin,
        (0..4).map(std_replica).collect(),
    )
    .with_lifecycle(lc, Box::new(std_replica))
    .run(workload, secs(120.0))
    .unwrap();

    let e = &report.elastic;
    assert_eq!(e.crashes, 1);
    assert!(e.evac_requeued + e.evac_restarted > 0, "the crash evacuated work");
    assert!(e.evac_restarted > 0, "8s of overload leaves started tasks to restart");
    assert!(e.evac_recompute_us > 0, "restarts are priced, not free");
    assert_conserved(&report, n_tasks, "crash-evac");

    // the dead replica keeps only work it finished before dying
    let dead = &report.replicas[0];
    assert!(!dead.alive);
    assert!(
        dead.report.tasks.iter().all(|t| t.is_finished()),
        "replica 0 died holding live tasks"
    );
    // every pre-crash replica-0 task found elsewhere is an evacuee;
    // their count matches the counters and none completes before the
    // crash it survived
    let mut evacuated = 0u64;
    for r in report.replicas.iter().skip(1) {
        for t in &r.report.tasks {
            if t.id % 4 == 0 && t.arrival < crash_t {
                evacuated += 1;
                if let Some(c) = t.completion {
                    assert!(c > crash_t, "task {} finished before its crash", t.id);
                }
            }
        }
    }
    assert_eq!(evacuated, e.evac_requeued + e.evac_restarted, "evacuee census");
}

/// 500 seeded churn sequences: the alive count never ends outside
/// [min_replicas, max_replicas], the counter identity `alive = start +
/// joins + grows − crashes − leaves − shrinks` holds, and every task is
/// conserved through arbitrary crash/join/leave interleavings.
#[test]
fn fleet_bounds_hold_across_500_seeded_churn_sequences() {
    for seed in 0..500u64 {
        let n_tasks = 8;
        let width = 3usize;
        let lc = LifecycleConfig {
            churn_rate: 1.0,
            seed,
            min_replicas: 1,
            max_replicas: 5,
            ..LifecycleConfig::default()
        };
        let workload = WorkloadSpec::paper_mix(2.0, 0.7, n_tasks, seed).generate();
        let report = Orchestrator::new(
            RoutingStrategy::SloAware,
            (0..width).map(std_replica).collect(),
        )
        .with_lifecycle(lc.clone(), Box::new(std_replica))
        .run(workload, secs(15.0))
        .unwrap();

        let alive = report.alive_replicas();
        assert!(
            (lc.min_replicas..=lc.max_replicas).contains(&alive),
            "seed {seed}: alive {alive} outside [{}, {}]",
            lc.min_replicas,
            lc.max_replicas
        );
        let e = &report.elastic;
        assert_eq!(
            alive as i64,
            width as i64 + (e.joins + e.autoscale_grows) as i64
                - (e.crashes + e.leaves + e.autoscale_shrinks) as i64,
            "seed {seed}: alive-count identity"
        );
        assert_conserved(&report, n_tasks, &format!("churn seed {seed}"));
    }
}

/// Under a sustained admission deficit the autoscaler grows the fleet
/// (bounded), and the grown fleet sheds strictly less than the static
/// one — the headline the elastic sweep measures at 10k tasks.
#[test]
fn autoscaler_grows_under_deficit_and_reduces_shed() {
    let mut cfg = ServeConfig::default();
    cfg.arrival_rate = 20.0;
    cfg.n_tasks = 200;
    cfg.cluster_engine = ClusterEngine::Event;
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    let spec = FleetSpec::homogeneous(2, cfg.cycle_cap);
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, 7).generate();

    let static_report = run_fleet(
        RoutingStrategy::SloAware,
        &spec,
        workload.clone(),
        &cfg,
        secs(60.0),
    )
    .unwrap();
    assert!(static_report.shed_total() > 0, "the cell must be an overload");

    let mut auto_cfg = cfg.clone();
    auto_cfg.lifecycle.autoscaler.enabled = true;
    auto_cfg.lifecycle.min_replicas = 2;
    auto_cfg.lifecycle.max_replicas = 16;
    let auto_report =
        run_fleet(RoutingStrategy::SloAware, &spec, workload, &auto_cfg, secs(60.0))
            .unwrap();

    let e = &auto_report.elastic;
    assert!(e.autoscale_grows > 0, "sustained deficit must grow the fleet");
    assert!(auto_report.alive_replicas() <= 16, "growth is bounded");
    assert!(
        auto_report.shed_total() < static_report.shed_total(),
        "autoscaled shed {} must beat static shed {}",
        auto_report.shed_total(),
        static_report.shed_total()
    );
    assert_conserved(&auto_report, cfg.n_tasks, "autoscale");
}

/// The full elastic stack — churn, autoscaler, health, admission,
/// migration, heterogeneous fleet — replays bit-identically for a
/// fixed seed: same fleet trajectory, same per-task timings.
#[test]
fn elastic_runs_are_deterministic_for_a_fixed_seed() {
    let mut cfg = ServeConfig::default();
    cfg.arrival_rate = 6.0;
    cfg.n_tasks = 120;
    cfg.cluster_engine = ClusterEngine::Event;
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    cfg.cluster_migration = true;
    cfg.lifecycle.churn_rate = 0.5;
    cfg.lifecycle.seed = 11;
    cfg.lifecycle.min_replicas = 2;
    cfg.lifecycle.max_replicas = 8;
    cfg.lifecycle.autoscaler.enabled = true;
    cfg.lifecycle.health.enabled = true;
    let spec = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(cfg.cycle_cap);

    let run = || {
        let workload =
            WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, 7)
                .generate();
        run_fleet(RoutingStrategy::SloAware, &spec, workload, &cfg, secs(60.0)).unwrap()
    };
    let a = run();
    let b = run();

    let ea = &a.elastic;
    let eb = &b.elastic;
    assert_eq!(
        (ea.crashes, ea.joins, ea.leaves, ea.autoscale_grows, ea.autoscale_shrinks),
        (eb.crashes, eb.joins, eb.leaves, eb.autoscale_grows, eb.autoscale_shrinks),
        "fleet trajectory diverged"
    );
    assert_eq!(ea.evac_requeued, eb.evac_requeued);
    assert_eq!(ea.evac_restarted, eb.evac_restarted);
    assert_eq!(ea.evac_recompute_us, eb.evac_recompute_us);
    assert_eq!(a.replicas.len(), b.replicas.len());
    assert_eq!(a.alive_replicas(), b.alive_replicas());
    let ta = a.tasks();
    let tb = b.tasks();
    assert_eq!(ta.len(), tb.len());
    for (x, y) in ta.iter().zip(&tb) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token, y.first_token, "task {}", x.id);
        assert_eq!(x.completion, y.completion, "task {}", x.id);
        assert_eq!(x.tokens_generated, y.tokens_generated, "task {}", x.id);
    }
    assert_conserved(&a, cfg.n_tasks, "deterministic rerun");
}

/// At light load no replica ever overruns, so enabling health scoring
/// changes nothing: the run is bit-exact with the static event-engine
/// run — degradation is a response to lag, never noise.
#[test]
fn health_scoring_is_inert_without_lag() {
    let mut cfg = ServeConfig::default();
    cfg.arrival_rate = 0.5;
    cfg.n_tasks = 30;
    cfg.cluster_engine = ClusterEngine::Event;
    let spec = FleetSpec::homogeneous(4, cfg.cycle_cap);
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, 7).generate();

    let plain =
        run_fleet(RoutingStrategy::SloAware, &spec, workload.clone(), &cfg, secs(120.0))
            .unwrap();
    let mut health_cfg = cfg.clone();
    health_cfg.lifecycle.health.enabled = true;
    let health =
        run_fleet(RoutingStrategy::SloAware, &spec, workload, &health_cfg, secs(120.0))
            .unwrap();

    let ta = plain.tasks();
    let tb = health.tasks();
    assert_eq!(ta.len(), tb.len());
    for (x, y) in ta.iter().zip(&tb) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.first_token, y.first_token, "task {}", x.id);
        assert_eq!(x.completion, y.completion, "task {}", x.id);
    }
    for (ra, rb) in plain.replicas.iter().zip(&health.replicas) {
        assert_eq!(ra.routed, rb.routed, "replica {} routing diverged", ra.replica);
    }
    assert!(health.replicas.iter().all(|r| r.alive));
}
