//! Byte-level tokenizer: every UTF-8 byte is one token (vocab 256).
//!
//! The served model is byte-level by construction (DESIGN.md), which
//! removes any external tokenizer dependency while keeping prompts and
//! completions real text.

/// Token used as end-of-sequence (NUL never occurs in text prompts).
pub const EOS_TOKEN: u8 = 0;

/// Encode text to tokens.
pub fn encode(text: &str) -> Vec<u8> {
    text.bytes().collect()
}

/// Decode tokens to text (lossy for non-UTF-8 sequences, which a sampled
/// byte stream can legitimately produce).
pub fn decode(tokens: &[u8]) -> String {
    String::from_utf8_lossy(tokens).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_round_trip() {
        let text = "navigate to dock 7";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn utf8_round_trip() {
        let text = "héllo ⚙ 机器人";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn one_token_per_byte() {
        assert_eq!(encode("abc").len(), 3);
        assert_eq!(encode("é").len(), 2); // two UTF-8 bytes
    }

    #[test]
    fn eos_is_nul() {
        assert_eq!(EOS_TOKEN, 0);
        assert!(!encode("plain text").contains(&EOS_TOKEN));
    }
}
