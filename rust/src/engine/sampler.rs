//! Token sampling over the model's logits.
//!
//! The serving examples use greedy decoding (deterministic, easiest to
//! validate against the python reference); temperature sampling is
//! provided for realistic workloads.

use crate::util::rng::Rng;

/// Sampling strategy.
#[derive(Debug, Clone, Copy)]
pub enum Sampler {
    /// argmax over the logits.
    Greedy,
    /// Softmax sampling with a temperature (> 0).
    Temperature(f64),
}

impl Sampler {
    /// Pick a token id from `logits` (length = vocab).
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u8 {
        match *self {
            Sampler::Greedy => argmax(logits) as u8,
            Sampler::Temperature(t) => {
                assert!(t > 0.0, "temperature must be positive");
                // numerically-stable softmax
                let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let weights: Vec<f64> = logits
                    .iter()
                    .map(|&x| (((x - max) as f64) / t).exp())
                    .collect();
                rng.weighted_index(&weights) as u8
            }
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut logits = vec![0.0f32; 256];
        logits[42] = 5.0;
        let mut rng = Rng::new(0);
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 42);
    }

    #[test]
    fn greedy_first_max_wins_ties() {
        let logits = vec![1.0f32; 8];
        let mut rng = Rng::new(0);
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 0);
    }

    #[test]
    fn temperature_sampling_prefers_high_logits() {
        let mut logits = vec![0.0f32; 4];
        logits[3] = 4.0;
        let mut rng = Rng::new(1);
        let mut hits = 0;
        for _ in 0..1000 {
            if Sampler::Temperature(1.0).sample(&logits, &mut rng) == 3 {
                hits += 1;
            }
        }
        assert!(hits > 900, "hits={hits}");
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut logits = vec![0.0f32; 4];
        logits[2] = 1.0;
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            assert_eq!(Sampler::Temperature(0.05).sample(&logits, &mut rng), 2);
        }
    }
}
