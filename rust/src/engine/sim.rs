//! Virtual-time decode engine backed by the calibrated latency model.
//!
//! Token *values* are synthetic (the simulator studies scheduling, not
//! language); token *timing* follows `l(b)` exactly. Completion is
//! governed by each task's target `output_len`, mirroring how the paper's
//! workloads fix per-task output lengths.

use anyhow::Result;

use crate::coordinator::pool::TaskPool;
use crate::coordinator::task::TaskId;

use super::latency::LatencyModel;
use super::memory::KvCacheModel;
use super::{DecodeEngine, StepOutcome, TokenOut};

/// Simulation engine: durations from [`LatencyModel`], synthetic tokens.
#[derive(Debug, Clone)]
pub struct SimEngine {
    latency: LatencyModel,
    max_context: u32,
    /// Deterministic KV-cache memory model. Unconstrained and free by
    /// default (pure peak accounting — parity with
    /// `PjrtEngine::peak_kv_bytes`); [`SimEngine::with_memory`] swaps in
    /// a capacity-constrained model.
    kv: KvCacheModel,
    /// Prefill passes executed (reports).
    pub prefill_steps: u64,
    /// Decode iterations executed (reports).
    pub decode_steps: u64,
    /// Total tokens produced by decode iterations (reports).
    pub decoded_tokens: u64,
}

impl SimEngine {
    /// Build a sim engine over a latency model and context limit.
    pub fn new(latency: LatencyModel, max_context: u32) -> Self {
        let kv = KvCacheModel::unlimited(latency.clone());
        SimEngine {
            latency,
            max_context,
            kv,
            prefill_steps: 0,
            decode_steps: 0,
            decoded_tokens: 0,
        }
    }

    /// The paper-testbed simulator: ChatGLM2-6B-class device, so the
    /// context window is effectively unbounded for edge workloads.
    pub fn paper_calibrated() -> Self {
        Self::new(LatencyModel::paper_calibrated(), 8192)
    }

    /// Replace the engine's KV-cache model (capacity-constrained runs).
    pub fn with_memory(mut self, kv: KvCacheModel) -> Self {
        self.kv = kv;
        self
    }

    /// The latency model timing this engine.
    pub fn latency(&self) -> &LatencyModel {
        &self.latency
    }

    /// High-water mark of block-rounded resident KV bytes (parity with
    /// `PjrtEngine::peak_kv_bytes`).
    pub fn peak_kv_bytes(&self) -> u64 {
        self.kv.stats().peak_kv_bytes
    }
}

impl DecodeEngine for SimEngine {
    fn prefill(&mut self, pool: &TaskPool, task: TaskId) -> Result<StepOutcome> {
        self.prefill_steps += 1;
        let t = pool.get(task);
        Ok(StepOutcome {
            duration: self.latency.prefill(t.prompt_len),
            tokens: vec![TokenOut { task, token: 0, eos: false }],
        })
    }

    fn decode(&mut self, _pool: &TaskPool, tasks: &[TaskId]) -> Result<StepOutcome> {
        self.decode_steps += 1;
        self.decoded_tokens += tasks.len() as u64;
        Ok(StepOutcome {
            duration: self.latency.decode(tasks.len() as u32),
            tokens: tasks
                .iter()
                .map(|&task| TokenOut { task, token: 0, eos: false })
                .collect(),
        })
    }

    fn release(&mut self, task: TaskId) {
        self.kv.release(task);
    }

    fn max_context(&self) -> u32 {
        self.max_context
    }

    fn backend(&self) -> &'static str {
        "sim"
    }

    fn kv_model_mut(&mut self) -> Option<&mut KvCacheModel> {
        Some(&mut self.kv)
    }

    fn kv_model(&self) -> Option<&KvCacheModel> {
        Some(&self.kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};
    use crate::util::ms;

    fn pool_one() -> TaskPool {
        let mut p = TaskPool::new();
        p.insert(Task::new(0, TaskClass::Voice, 0, 16, 4, 1.0));
        p.insert(Task::new(1, TaskClass::Voice, 0, 32, 4, 1.0));
        p
    }

    #[test]
    fn decode_duration_follows_latency_model() {
        let mut e = SimEngine::paper_calibrated();
        let pool = pool_one();
        let o1 = e.decode(&pool, &[0]).unwrap();
        assert_eq!(o1.duration, ms(18.0));
        let o9 = e.decode(&pool, &(0..9).map(|_| 0).collect::<Vec<_>>()).unwrap();
        assert_eq!(o9.duration, ms(128.59));
    }

    #[test]
    fn prefill_duration_scales_with_prompt() {
        let mut e = SimEngine::paper_calibrated();
        let pool = pool_one();
        let a = e.prefill(&pool, 0).unwrap();
        let b = e.prefill(&pool, 1).unwrap();
        assert!(b.duration > a.duration);
        assert_eq!(a.tokens.len(), 1);
        assert!(!a.tokens[0].eos);
    }

    #[test]
    fn kv_model_is_exposed_and_tracks_peak() {
        let mut e = SimEngine::paper_calibrated();
        assert_eq!(e.peak_kv_bytes(), 0);
        let kv = e.kv_model_mut().expect("sim engine always models KV");
        assert!(!kv.constrained(), "default model is unconstrained");
        kv.insert(0, 16);
        kv.insert(1, 16);
        assert!(e.peak_kv_bytes() > 0);
        let peak = e.peak_kv_bytes();
        // release keeps the high-water mark (parity with
        // PjrtEngine::peak_kv_bytes)
        e.release(0);
        e.release(1);
        assert_eq!(e.peak_kv_bytes(), peak);
        assert_eq!(e.kv_model().unwrap().occupied_bytes(), 0);
    }

    #[test]
    fn counters_track_steps() {
        let mut e = SimEngine::paper_calibrated();
        let pool = pool_one();
        let _ = e.prefill(&pool, 0);
        let _ = e.decode(&pool, &[0, 1]);
        let _ = e.decode(&pool, &[0]);
        assert_eq!(e.prefill_steps, 1);
        assert_eq!(e.decode_steps, 2);
        assert_eq!(e.decoded_tokens, 3);
    }
}
