//! Real decode engine: serves actual tokens from the AOT-compiled
//! transformer via the PJRT runtime, with a per-task host-side KV cache.
//!
//! Batch regrouping is first-class: SLICE's decode-mask matrix composes a
//! different batch every column, so each task's KV slab is kept as an
//! independent contiguous buffer and stacked into the bucketed decode
//! executable's input on demand. Unused bucket rows are padded with
//! `len = 1` zero slabs (a softmax over one zero row is well-defined;
//! padded outputs are discarded).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::pool::TaskPool;
use crate::coordinator::task::TaskId;
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

use super::sampler::Sampler;
use super::tokenizer::EOS_TOKEN;
use super::{DecodeEngine, StepOutcome, TokenOut};

/// Per-task generation state.
struct Slot {
    /// KV slab, length = dims.kv_slab_elems().
    kv: Vec<f32>,
    /// Current sequence length (prompt + generated tokens in cache).
    len: u32,
    /// Most recent sampled token (input to the next decode step).
    last_token: u8,
}

/// PJRT-backed engine.
pub struct PjrtEngine {
    runtime: ModelRuntime,
    slots: HashMap<TaskId, Slot>,
    sampler: Sampler,
    rng: Rng,
    /// Scratch buffers reused across decode calls (hot-path allocation
    /// avoidance; see EXPERIMENTS.md §Perf iteration 2).
    kv_scratch: Vec<f32>,
    kv_out_scratch: Vec<f32>,
    logits_scratch: Vec<f32>,
    /// Prefill passes executed (reports).
    pub prefill_steps: u64,
    /// Decode iterations executed (reports).
    pub decode_steps: u64,
    /// High-water mark of concurrently resident KV slots (edge memory
    /// accounting: each slot is one task's cache, dims.kv_slab_elems()
    /// * 4 bytes).
    pub peak_slots: usize,
}

impl PjrtEngine {
    /// Build an engine over a loaded runtime with a sampling strategy.
    pub fn new(runtime: ModelRuntime, sampler: Sampler, seed: u64) -> Self {
        PjrtEngine {
            runtime,
            slots: HashMap::new(),
            sampler,
            rng: Rng::new(seed),
            kv_scratch: Vec::new(),
            kv_out_scratch: Vec::new(),
            logits_scratch: Vec::new(),
            prefill_steps: 0,
            decode_steps: 0,
            peak_slots: 0,
        }
    }

    /// Peak KV memory held for in-flight tasks, in bytes.
    pub fn peak_kv_bytes(&self) -> usize {
        self.peak_slots * self.runtime.dims().kv_slab_elems() * 4
    }

    /// The underlying model runtime.
    pub fn runtime(&self) -> &ModelRuntime {
        &self.runtime
    }

    /// Sequence length currently cached for a task (tests/diagnostics).
    pub fn cached_len(&self, task: TaskId) -> Option<u32> {
        self.slots.get(&task).map(|s| s.len)
    }
}

impl DecodeEngine for PjrtEngine {
    fn prefill(&mut self, pool: &TaskPool, task: TaskId) -> Result<StepOutcome> {
        let start = Instant::now();
        self.prefill_steps += 1;
        let t = pool.get(task);
        if t.prompt.is_empty() {
            bail!("task {task} has no prompt bytes (pjrt engine needs real prompts)");
        }
        let dims = self.runtime.dims();
        if t.prompt.len() >= dims.max_seq {
            bail!("prompt of {} exceeds context {}", t.prompt.len(), dims.max_seq);
        }
        let bucket = self.runtime.manifest.prefill_bucket(t.prompt.len())?;
        let mut tokens: Vec<i32> = t.prompt.iter().map(|&b| b as i32).collect();
        tokens.resize(bucket, 0);

        let out = self
            .runtime
            .prefill(&tokens, t.prompt.len() as i32)
            .context("prefill execution")?;
        let token = self.sampler.sample(&out.logits, &mut self.rng);
        self.slots.insert(
            task,
            Slot { kv: out.kv, len: t.prompt.len() as u32, last_token: token },
        );
        self.peak_slots = self.peak_slots.max(self.slots.len());
        Ok(StepOutcome {
            duration: start.elapsed().as_micros() as u64,
            tokens: vec![TokenOut { task, token, eos: token == EOS_TOKEN }],
        })
    }

    fn decode(&mut self, _pool: &TaskPool, tasks: &[TaskId]) -> Result<StepOutcome> {
        let start = Instant::now();
        self.decode_steps += 1;
        let dims = self.runtime.dims();
        let slab = dims.kv_slab_elems();
        let bucket = self.runtime.manifest.decode_bucket(tasks.len())?;

        // Stack inputs; pad unused rows with len=1 zero slabs.
        let mut tokens = vec![0i32; bucket];
        let mut lens = vec![1i32; bucket];
        self.kv_scratch.clear();
        self.kv_scratch.resize(bucket * slab, 0.0);
        for (i, &id) in tasks.iter().enumerate() {
            let s = self
                .slots
                .get(&id)
                .with_context(|| format!("task {id} decoded before prefill"))?;
            if s.len as usize + 1 >= dims.max_seq {
                bail!("task {id} exceeded context window {}", dims.max_seq);
            }
            tokens[i] = s.last_token as i32;
            lens[i] = s.len as i32;
            self.kv_scratch[i * slab..(i + 1) * slab].copy_from_slice(&s.kv);
        }

        self.kv_out_scratch.resize(bucket * slab, 0.0);
        self.logits_scratch.resize(bucket * dims.vocab, 0.0);
        self.runtime
            .decode_into(
                &tokens,
                &lens,
                &self.kv_scratch,
                &mut self.logits_scratch,
                &mut self.kv_out_scratch,
            )
            .context("decode execution")?;

        // Unpack real rows: sample next tokens, write back updated slabs.
        let mut outs = Vec::with_capacity(tasks.len());
        for (i, &id) in tasks.iter().enumerate() {
            let logits = &self.logits_scratch[i * dims.vocab..(i + 1) * dims.vocab];
            let token = self.sampler.sample(logits, &mut self.rng);
            let s = self.slots.get_mut(&id).unwrap();
            s.kv.copy_from_slice(&self.kv_out_scratch[i * slab..(i + 1) * slab]);
            s.len += 1;
            s.last_token = token;
            let at_limit = s.len as usize + 1 >= dims.max_seq;
            outs.push(TokenOut { task: id, token, eos: token == EOS_TOKEN || at_limit });
        }
        Ok(StepOutcome { duration: start.elapsed().as_micros() as u64, tokens: outs })
    }

    fn release(&mut self, task: TaskId) {
        self.slots.remove(&task);
    }

    fn max_context(&self) -> u32 {
        self.runtime.dims().max_seq as u32
    }

    fn backend(&self) -> &'static str {
        "pjrt-cpu"
    }
}
