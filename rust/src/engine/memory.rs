//! KV-cache memory model: deterministic accounting of per-task resident
//! KV bytes, device capacity, and the cost of residency transitions
//! (DESIGN.md "Memory model").
//!
//! Edge devices are memory-bound before they are compute-bound: pausing
//! a task (Alg. 4) is only free if its KV cache stays resident, and the
//! paper's FastServe baseline explicitly prices proactive KV swapping.
//! This module makes that cost first-class for the deterministic
//! simulator, mirroring what the `pjrt` engine already measures
//! (`PjrtEngine::peak_kv_bytes`):
//!
//!   * a task's cache occupies `bytes_per_token` per resident token,
//!     rounded up to `block_tokens` paged blocks (vLLM-style paging,
//!     so fragmentation is modelled, not wished away);
//!   * evicting a task either **swaps** its blocks to host storage at
//!     `swap_bandwidth` (restored at the same rate on resume) or
//!     **recomputes** them on resume through the device's prefill
//!     latency curve (eviction itself is then free);
//!   * migrating a *running* task to another replica transfers its
//!     blocks over the inter-replica link at `handoff_bandwidth`; the
//!     pre-priced fee is charged when the destination first resumes it.
//!
//! The default [`MemoryConfig`] is unconstrained and free: no capacity,
//! no swaps, no costed transitions — every pre-memory run reproduces
//! bit-for-bit, and the subsystem is opt-in by construction.

use crate::coordinator::task::TaskId;
use crate::util::{Micros, MICROS_PER_SEC};

use super::latency::LatencyModel;

/// How an evicted task's KV cache is brought back on resume.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptionMode {
    /// Blocks are written to host storage on eviction and read back on
    /// resume, both at [`MemoryConfig::swap_bandwidth`] (FastServe-style
    /// proactive swapping).
    Swap,
    /// Blocks are dropped on eviction and re-derived on resume by a
    /// prefill pass over the task's cached tokens (priced through the
    /// device's prefill latency curve).
    Recompute,
}

impl PreemptionMode {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "swap" => PreemptionMode::Swap,
            "recompute" => PreemptionMode::Recompute,
            other => anyhow::bail!("unknown preemption mode '{other}' (swap|recompute)"),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            PreemptionMode::Swap => "swap",
            PreemptionMode::Recompute => "recompute",
        }
    }
}

/// KV-cache memory parameters (the `[memory]` config section).
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    /// Device KV capacity in bytes for a standard-tier device; `None`
    /// models an unconstrained device (the default — every pre-memory
    /// run is reproduced bit-exactly). Slower tiers scale this down via
    /// [`crate::cluster::DeviceProfile::kv_fraction`].
    pub kv_capacity: Option<u64>,
    /// Bytes of KV cache per resident token (default 32 KiB: a
    /// ChatGLM2-6B-class MQA stack, the paper's testbed model family).
    pub bytes_per_token: u64,
    /// Block granularity in tokens: occupancy is rounded up to whole
    /// blocks (paged KV allocation).
    pub block_tokens: u32,
    /// Swap bandwidth in bytes/s (swap-out and swap-in). Edge boards
    /// have *unified* memory, so evicted caches go to storage, not
    /// across PCIe: the default models eMMC-class flash (64 MB/s) — the
    /// regime where thrashing is expensive enough to schedule around.
    pub swap_bandwidth: u64,
    /// Inter-replica link bandwidth in bytes/s for running-task KV
    /// handoff.
    pub handoff_bandwidth: u64,
    /// How evicted caches are restored.
    pub mode: PreemptionMode,
    /// When true (default), the SLICE policy treats projected KV bytes
    /// as a second knapsack dimension during selection (Alg. 2); when
    /// false the policy is memory-*oblivious* and only the serving
    /// loop's capacity enforcement protects the device (the baseline
    /// the memory sweep compares against).
    pub aware: bool,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            kv_capacity: None,
            bytes_per_token: 32 * 1024,
            block_tokens: 16,
            swap_bandwidth: 64_000_000,     // eMMC-class storage swap
            handoff_bandwidth: 125_000_000, // 1 Gbit/s edge link
            mode: PreemptionMode::Swap,
            aware: true,
        }
    }
}

impl MemoryConfig {
    /// Block-rounded bytes occupied by `tokens` resident tokens.
    pub fn bytes_for(&self, tokens: u32) -> u64 {
        let block = self.block_tokens.max(1) as u64;
        let blocks = (tokens as u64).div_ceil(block);
        blocks * block * self.bytes_per_token
    }

    /// Time to move `bytes` over a link of `bandwidth` bytes/s, rounded
    /// up to integer micros (deterministic).
    pub fn transfer_cost(bytes: u64, bandwidth: u64) -> Micros {
        if bandwidth == 0 {
            return 0; // "free" link sentinel
        }
        bytes.saturating_mul(MICROS_PER_SEC).div_ceil(bandwidth)
    }

    /// KV-handoff transfer time for a task with `tokens` cached tokens.
    pub fn handoff_cost(&self, tokens: u32) -> Micros {
        Self::transfer_cost(self.bytes_for(tokens), self.handoff_bandwidth)
    }

    /// True when a finite capacity is configured.
    pub fn constrained(&self) -> bool {
        self.kv_capacity.is_some()
    }
}

/// Counters a memory-aware run reports (all zero when unconstrained
/// except the peak, which is tracked for every sim run — parity with
/// `PjrtEngine::peak_kv_bytes`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryStats {
    /// High-water mark of block-rounded resident KV bytes.
    pub peak_kv_bytes: u64,
    /// Evictions (capacity-driven swap-outs / drops).
    pub swap_outs: u64,
    /// Priced swap-ins (mode `swap`).
    pub swap_ins: u64,
    /// Priced recompute restores (mode `recompute`).
    pub recomputes: u64,
    /// Restores of migrated-in tasks priced by the handoff link.
    pub handoff_restores: u64,
    /// Total virtual time spent on swap/recompute/handoff transitions.
    pub swap_delay: Micros,
}

impl MemoryStats {
    /// Accumulate another run's counters (fleet aggregation; peaks are
    /// summed — each replica's device holds its own high-water mark).
    pub fn merge(&mut self, other: &MemoryStats) {
        self.peak_kv_bytes += other.peak_kv_bytes;
        self.swap_outs += other.swap_outs;
        self.swap_ins += other.swap_ins;
        self.recomputes += other.recomputes;
        self.handoff_restores += other.handoff_restores;
        self.swap_delay += other.swap_delay;
    }
}

/// Per-task residency record inside a [`KvCacheModel`].
#[derive(Debug, Clone, Copy)]
struct KvSlot {
    /// Cached sequence length in tokens.
    tokens: u32,
    /// True while the blocks occupy device memory.
    resident: bool,
}

/// Deterministic KV-cache state for one device: per-task resident
/// tokens, block-rounded occupancy against a capacity, and costed
/// swap/recompute/handoff transitions. Owned by the sim engine and
/// driven by the serving loop (`server::Server`), which enforces the
/// occupancy-never-exceeds-capacity invariant for *every* policy.
#[derive(Debug, Clone)]
pub struct KvCacheModel {
    cfg: MemoryConfig,
    /// This device's capacity in bytes (already tier-scaled); `None` =
    /// unconstrained.
    capacity: Option<u64>,
    /// Prefill curve used to price `recompute` restores.
    recompute_curve: LatencyModel,
    /// Slot per dense local task id.
    slots: Vec<Option<KvSlot>>,
    occupied: u64,
    stats: MemoryStats,
}

impl KvCacheModel {
    /// Build a model from the memory config, this device's (tier-scaled)
    /// capacity, and its prefill curve for recompute pricing.
    pub fn new(cfg: MemoryConfig, capacity: Option<u64>, recompute_curve: LatencyModel) -> Self {
        KvCacheModel {
            cfg,
            capacity,
            recompute_curve,
            slots: Vec::new(),
            occupied: 0,
            stats: MemoryStats::default(),
        }
    }

    /// An unconstrained, free model (pure peak accounting).
    pub fn unlimited(recompute_curve: LatencyModel) -> Self {
        Self::new(MemoryConfig::default(), None, recompute_curve)
    }

    /// The memory parameters this model prices with.
    pub fn config(&self) -> &MemoryConfig {
        &self.cfg
    }

    /// This device's capacity in bytes (`None` = unconstrained).
    pub fn capacity(&self) -> Option<u64> {
        self.capacity
    }

    /// True when a finite capacity is enforced.
    pub fn constrained(&self) -> bool {
        self.capacity.is_some()
    }

    /// Current block-rounded resident bytes.
    pub fn occupied_bytes(&self) -> u64 {
        self.occupied
    }

    /// Transition counters and the resident high-water mark.
    pub fn stats(&self) -> MemoryStats {
        self.stats
    }

    /// Block-rounded bytes for `tokens` cached tokens.
    pub fn bytes_for(&self, tokens: u32) -> u64 {
        self.cfg.bytes_for(tokens)
    }

    fn slot(&self, task: TaskId) -> Option<&KvSlot> {
        self.slots.get(task as usize).and_then(|s| s.as_ref())
    }

    fn slot_mut(&mut self, task: TaskId) -> Option<&mut KvSlot> {
        self.slots.get_mut(task as usize).and_then(|s| s.as_mut())
    }

    fn set_slot(&mut self, task: TaskId, slot: KvSlot) {
        let idx = task as usize;
        if self.slots.len() <= idx {
            self.slots.resize(idx + 1, None);
        }
        self.slots[idx] = Some(slot);
    }

    fn bump_peak(&mut self) {
        if self.occupied > self.stats.peak_kv_bytes {
            self.stats.peak_kv_bytes = self.occupied;
        }
    }

    /// True while `task`'s cache occupies device memory.
    pub fn is_resident(&self, task: TaskId) -> bool {
        self.slot(task).is_some_and(|s| s.resident)
    }

    /// Cached tokens recorded for `task` (resident or swapped).
    pub fn tokens_of(&self, task: TaskId) -> Option<u32> {
        self.slot(task).map(|s| s.tokens)
    }

    /// A task's prompt was prefilled: its cache becomes resident with
    /// `tokens` cached tokens.
    pub fn insert(&mut self, task: TaskId, tokens: u32) {
        debug_assert!(self.slot(task).is_none(), "task {task} already has a KV slot");
        self.occupied += self.cfg.bytes_for(tokens);
        self.set_slot(task, KvSlot { tokens, resident: true });
        self.bump_peak();
    }

    /// One more token was decoded into a resident cache.
    pub fn note_token(&mut self, task: TaskId) {
        let Some(slot) = self.slot_mut(task) else { return };
        if !slot.resident {
            return;
        }
        let before = slot.tokens;
        slot.tokens = before + 1;
        let grow = self.cfg.bytes_for(before + 1) - self.cfg.bytes_for(before);
        if grow > 0 {
            self.occupied += grow;
            self.bump_peak();
        }
    }

    /// Free a finished (or extracted) task's cache entirely.
    pub fn release(&mut self, task: TaskId) {
        let idx = task as usize;
        if let Some(Some(slot)) = self.slots.get(idx) {
            if slot.resident {
                self.occupied -= self.cfg.bytes_for(slot.tokens);
            }
            self.slots[idx] = None;
        }
    }

    /// Evict a resident task: frees its blocks and returns the virtual
    /// time the transition costs (a swap-out write in `swap` mode; free
    /// in `recompute` mode, where the cost moves to the resume side).
    pub fn swap_out(&mut self, task: TaskId) -> Micros {
        let mode = self.cfg.mode;
        let swap_bw = self.cfg.swap_bandwidth;
        let Some(slot) = self.slot_mut(task) else { return 0 };
        if !slot.resident {
            return 0;
        }
        slot.resident = false;
        let tokens = slot.tokens;
        let bytes = self.cfg.bytes_for(tokens);
        self.occupied -= bytes;
        self.stats.swap_outs += 1;
        let cost = match mode {
            PreemptionMode::Swap => MemoryConfig::transfer_cost(bytes, swap_bw),
            PreemptionMode::Recompute => 0,
        };
        self.stats.swap_delay += cost;
        cost
    }

    /// Make a task's cache resident again (before it can decode) and
    /// return the transition cost. `tokens` is the task's current
    /// sequence length — authoritative for migrated-in tasks the model
    /// has never seen. `pending_restore` is a pre-priced fee (the KV
    /// handoff time stamped by the router); when non-zero it replaces
    /// the mode cost.
    pub fn restore(&mut self, task: TaskId, tokens: u32, pending_restore: Micros) -> Micros {
        if self.is_resident(task) {
            return 0;
        }
        let bytes = self.cfg.bytes_for(tokens);
        self.occupied += bytes;
        self.set_slot(task, KvSlot { tokens, resident: true });
        self.bump_peak();
        let cost = if pending_restore > 0 {
            self.stats.handoff_restores += 1;
            pending_restore
        } else {
            match self.cfg.mode {
                PreemptionMode::Swap => {
                    self.stats.swap_ins += 1;
                    MemoryConfig::transfer_cost(bytes, self.cfg.swap_bandwidth)
                }
                PreemptionMode::Recompute => {
                    self.stats.recomputes += 1;
                    self.recompute_curve.prefill(tokens)
                }
            }
        };
        self.stats.swap_delay += cost;
        cost
    }

    /// Resident bytes held by tasks *outside* `protected` (the batch
    /// about to decode) — what eviction can reclaim.
    pub fn resident_outside(&self, protected: &[TaskId]) -> u64 {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(id, s)| s.as_ref().map(|s| (id as TaskId, s)))
            .filter(|(id, s)| s.resident && !protected.contains(id))
            .map(|(_, s)| self.cfg.bytes_for(s.tokens))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ms;

    fn constrained(capacity: u64, mode: PreemptionMode) -> KvCacheModel {
        let cfg = MemoryConfig {
            kv_capacity: Some(capacity),
            mode,
            ..MemoryConfig::default()
        };
        KvCacheModel::new(cfg, Some(capacity), LatencyModel::paper_calibrated())
    }

    #[test]
    fn block_rounding_and_growth() {
        let cfg = MemoryConfig::default();
        // 16-token blocks of 32 KiB/token = 512 KiB per block
        assert_eq!(cfg.bytes_for(0), 0);
        assert_eq!(cfg.bytes_for(1), 512 * 1024);
        assert_eq!(cfg.bytes_for(16), 512 * 1024);
        assert_eq!(cfg.bytes_for(17), 1024 * 1024);

        let mut m = KvCacheModel::unlimited(LatencyModel::paper_calibrated());
        m.insert(0, 16);
        assert_eq!(m.occupied_bytes(), 512 * 1024);
        m.note_token(0); // crosses into the second block
        assert_eq!(m.occupied_bytes(), 1024 * 1024);
        m.note_token(0); // stays inside it
        assert_eq!(m.occupied_bytes(), 1024 * 1024);
        assert_eq!(m.tokens_of(0), Some(18));
        m.release(0);
        assert_eq!(m.occupied_bytes(), 0);
        assert_eq!(m.stats().peak_kv_bytes, 1024 * 1024);
    }

    #[test]
    fn transfer_cost_rounds_up() {
        // 1 MiB at 64 MB/s = 16384.0 us exactly
        let bytes = 1024 * 1024;
        let bw = 64_000_000u64;
        assert_eq!(MemoryConfig::transfer_cost(bytes, bw), 16_384);
        // one byte more rounds up
        assert_eq!(MemoryConfig::transfer_cost(bytes + 1, bw), 16_385);
        assert_eq!(MemoryConfig::transfer_cost(0, bw), 0);
        assert_eq!(MemoryConfig::transfer_cost(bytes, 0), 0, "free-link sentinel");
    }

    #[test]
    fn swap_roundtrip_prices_both_directions() {
        let mut m = constrained(64 * 1024 * 1024, PreemptionMode::Swap);
        m.insert(3, 100); // 7 blocks = 3.5 MiB
        let bytes = m.bytes_for(100);
        let out = m.swap_out(3);
        assert_eq!(out, MemoryConfig::transfer_cost(bytes, m.config().swap_bandwidth));
        assert!(!m.is_resident(3));
        assert_eq!(m.occupied_bytes(), 0);
        let back = m.restore(3, 100, 0);
        assert_eq!(back, out, "swap-in mirrors swap-out");
        assert!(m.is_resident(3));
        let s = m.stats();
        assert_eq!((s.swap_outs, s.swap_ins, s.recomputes), (1, 1, 0));
        assert_eq!(s.swap_delay, out + back);
    }

    #[test]
    fn recompute_mode_prices_resume_via_prefill_curve() {
        let mut m = constrained(64 * 1024 * 1024, PreemptionMode::Recompute);
        m.insert(0, 64);
        assert_eq!(m.swap_out(0), 0, "recompute eviction is free");
        let cost = m.restore(0, 64, 0);
        assert_eq!(cost, LatencyModel::paper_calibrated().prefill(64));
        assert_eq!(cost, ms(75.0));
        let s = m.stats();
        assert_eq!((s.swap_outs, s.swap_ins, s.recomputes), (1, 0, 1));
    }

    #[test]
    fn pending_restore_fee_overrides_mode_cost() {
        let mut m = constrained(64 * 1024 * 1024, PreemptionMode::Swap);
        // a migrated-in task the model has never seen: adopted at its
        // current length, charged the router's pre-priced handoff fee
        let cost = m.restore(9, 200, 5_000);
        assert_eq!(cost, 5_000);
        assert!(m.is_resident(9));
        assert_eq!(m.tokens_of(9), Some(200));
        assert_eq!(m.stats().handoff_restores, 1);
        assert_eq!(m.stats().swap_ins, 0);
    }

    #[test]
    fn handoff_cost_uses_link_bandwidth() {
        let cfg = MemoryConfig::default();
        let bytes = cfg.bytes_for(160); // 10 blocks = 5 MiB
        assert_eq!(cfg.handoff_cost(160), MemoryConfig::transfer_cost(bytes, 125_000_000));
        // 5 MiB over 1 Gbit/s ~ 42 ms
        assert!(cfg.handoff_cost(160) > ms(40.0) && cfg.handoff_cost(160) < ms(45.0));
    }

    #[test]
    fn resident_outside_excludes_protected_and_swapped() {
        let mut m = constrained(64 * 1024 * 1024, PreemptionMode::Swap);
        m.insert(0, 16);
        m.insert(1, 16);
        m.insert(2, 16);
        m.swap_out(2);
        assert_eq!(m.resident_outside(&[0]), m.bytes_for(16));
        assert_eq!(m.resident_outside(&[]), 2 * m.bytes_for(16));
        assert_eq!(m.resident_outside(&[0, 1]), 0);
    }

    #[test]
    fn merge_accumulates_fleet_stats() {
        let mut a = MemoryStats {
            peak_kv_bytes: 10,
            swap_outs: 1,
            swap_ins: 1,
            recomputes: 0,
            handoff_restores: 2,
            swap_delay: 100,
        };
        let b = MemoryStats {
            peak_kv_bytes: 5,
            swap_outs: 2,
            swap_ins: 0,
            recomputes: 3,
            handoff_restores: 0,
            swap_delay: 50,
        };
        a.merge(&b);
        assert_eq!(a.peak_kv_bytes, 15);
        assert_eq!(a.swap_outs, 3);
        assert_eq!(a.recomputes, 3);
        assert_eq!(a.swap_delay, 150);
    }

    #[test]
    fn unlimited_model_never_charges() {
        let mut m = KvCacheModel::unlimited(LatencyModel::paper_calibrated());
        assert!(!m.constrained());
        m.insert(0, 500);
        // the serving loop never evicts on an unconstrained model; peak
        // accounting still works
        assert!(m.stats().peak_kv_bytes > 0);
        assert_eq!(m.stats().swap_delay, 0);
    }

    #[test]
    fn preemption_mode_parses() {
        assert_eq!(PreemptionMode::parse("swap").unwrap(), PreemptionMode::Swap);
        assert_eq!(
            PreemptionMode::parse("Recompute").unwrap(),
            PreemptionMode::Recompute
        );
        assert!(PreemptionMode::parse("drop").is_err());
        assert_eq!(PreemptionMode::Swap.label(), "swap");
    }
}
