//! Decode-engine substrate: the "GPU" the schedulers drive (DESIGN.md
//! "Layers" — the engine row; the latency model is DESIGN.md's l(b)).
//!
//! Contract: a [`DecodeEngine`] turns prefill/decode requests into
//! [`StepOutcome`]s (modelled or measured durations plus one token per
//! batched task); it never touches scheduling state.
//!
//! Two interchangeable backends implement [`DecodeEngine`]:
//!   * [`sim::SimEngine`] — virtual-time execution against a calibrated
//!     latency model (`latency::LatencyModel`); used for every paper
//!     sweep (thousands of tasks, deterministic, fast).
//!   * [`pjrt::PjrtEngine`] — real token generation: executes the
//!     AOT-compiled transformer artifacts on the PJRT CPU client with a
//!     per-task KV cache; used by the end-to-end examples and the Fig. 1
//!     measurement.

pub mod clock;
pub mod latency;
pub mod memory;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sampler;
pub mod sim;
pub mod tokenizer;

use anyhow::Result;

use crate::coordinator::pool::TaskPool;
use crate::coordinator::task::TaskId;
use crate::util::Micros;

/// One generated token for one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenOut {
    /// The task this token belongs to.
    pub task: TaskId,
    /// The generated token value (a byte; vocab 256).
    pub token: u8,
    /// True if the model emitted its end-of-sequence token.
    pub eos: bool,
}

/// Result of one engine step (prefill or decode iteration).
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// How long the step took (modelled or measured).
    pub duration: Micros,
    /// One entry per task that produced a token this step.
    pub tokens: Vec<TokenOut>,
}

/// An execution backend for prompt prefill and batched decode.
///
/// `Send` is part of the contract: the cluster layer's parallel event
/// engine advances whole replicas — and therefore their engines — on
/// worker threads inside an epoch (DESIGN.md "Parallel event engine").
pub trait DecodeEngine: Send {
    /// Process one task's prompt; produces its first output token.
    fn prefill(&mut self, pool: &TaskPool, task: TaskId) -> Result<StepOutcome>;

    /// One decode iteration over `tasks`; produces one token per task.
    fn decode(&mut self, pool: &TaskPool, tasks: &[TaskId]) -> Result<StepOutcome>;

    /// Free any per-task state (KV cache) after completion/eviction.
    fn release(&mut self, task: TaskId);

    /// Largest sequence length (prompt + output) the engine can serve.
    fn max_context(&self) -> u32;

    /// Human-readable backend name for reports.
    fn backend(&self) -> &'static str;

    /// The engine's KV-cache memory model, if it keeps one. The serving
    /// loop drives residency transitions (insert/evict/restore) through
    /// this hook; engines without a deterministic memory model (the
    /// real PJRT engine measures instead of modelling) return `None`
    /// and the loop skips all memory accounting.
    fn kv_model_mut(&mut self) -> Option<&mut memory::KvCacheModel> {
        None
    }

    /// Read-only view of [`DecodeEngine::kv_model_mut`].
    fn kv_model(&self) -> Option<&memory::KvCacheModel> {
        None
    }
}
