//! Virtual and wall clocks behind one interface, so the same serving loop
//! drives both discrete-event simulation and the real PJRT engine.

use std::time::Instant;

use crate::util::Micros;

/// Time source for the serving loop.
pub trait Clock {
    /// Current time in micros since the run started.
    fn now(&self) -> Micros;
    /// Account `d` micros of engine work. Virtual clocks jump; the wall
    /// clock ignores this (real time already elapsed inside the engine).
    fn advance(&mut self, d: Micros);
    /// Wait until `t` (virtual: jump; wall: sleep).
    fn advance_to(&mut self, t: Micros);
}

/// Discrete-event simulation clock.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: Micros,
}

impl VirtualClock {
    /// A virtual clock at t = 0.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Micros {
        self.now
    }

    fn advance(&mut self, d: Micros) {
        self.now += d;
    }

    fn advance_to(&mut self, t: Micros) {
        if t > self.now {
            self.now = t;
        }
    }
}

/// Real-time clock anchored at construction.
#[derive(Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    /// A wall clock anchored at the moment of construction.
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Micros {
        self.start.elapsed().as_micros() as Micros
    }

    fn advance(&mut self, _d: Micros) {
        // real time already passed while the engine executed
    }

    fn advance_to(&mut self, t: Micros) {
        let now = self.now();
        if t > now {
            std::thread::sleep(std::time::Duration::from_micros(t - now));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_jumps() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance(500);
        assert_eq!(c.now(), 500);
        c.advance_to(1000);
        assert_eq!(c.now(), 1000);
        c.advance_to(400); // never goes backwards
        assert_eq!(c.now(), 1000);
    }

    #[test]
    fn wall_clock_monotone_and_ignores_advance() {
        let mut c = WallClock::new();
        let a = c.now();
        c.advance(1_000_000_000); // must NOT jump forward an hour
        let b = c.now();
        assert!(b < 1_000_000_000);
        assert!(b >= a);
    }

    #[test]
    fn wall_clock_advance_to_sleeps() {
        let mut c = WallClock::new();
        let t0 = c.now();
        c.advance_to(t0 + 2_000); // 2ms
        assert!(c.now() >= t0 + 2_000);
    }
}
