//! Decode/prefill latency model `l(b)`.
//!
//! `l(b)` — the latency of one decode forward pass at batch size `b` — is
//! the central physical quantity in the paper: task selection (Alg. 2)
//! estimates the scheduling-cycle duration with it (Eq. 7), and its
//! nonlinearity is what makes the selection problem NP-hard (§IV-A).
//!
//! Two sources:
//!   * [`LatencyModel::paper_calibrated`] — piecewise-linear curve fitted
//!     to the paper's published measurements of ChatGLM2-6B-INT4 on an
//!     RTX 4060 Ti (Fig. 1 and Table II): near-linear growth up to b=8,
//!     l(9) = 128.59 ms (Table II's uniform-batch TPOT with 9 tasks, i.e.
//!     latency > 120 ms once b > 9 per Fig. 1), then a plateau where
//!     throughput scales with b again.
//!   * [`LatencyModel::from_points`] — fitted from measurements of the
//!     real PJRT engine (`slice-serve calibrate`), so the simulator can
//!     mirror this machine instead of the paper's GPU.

use crate::util::{ms, Micros};

/// Piecewise-linear interpolation over measured (batch, latency) points.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// (batch, decode latency) knots, strictly increasing in batch.
    points: Vec<(u32, Micros)>,
    /// Prefill latency per prompt-length bucket: (bucket, latency).
    prefill_points: Vec<(u32, Micros)>,
    /// Hard cap on concurrently decodable tasks (device memory limit).
    pub max_batch: u32,
}

impl LatencyModel {
    /// Curve calibrated to the paper's testbed (see module docs).
    ///
    /// Constraints encoded:
    ///   l(8) <= 100 ms < l(9)  ("batch > 8 exceeds the 100 ms threshold")
    ///   l(9) = 128.59 ms       (Table II: 9-task uniform batch TPOT)
    ///   plateau >= 120 ms for b > 9 with near-constant latency (Fig. 1)
    ///   Table II feasibility: 4*l(9) + l(3) + 5*l(7) < 1000 ms, so the
    ///   paper's 9-task static mix is admissible for SLICE.
    pub fn paper_calibrated() -> Self {
        LatencyModel {
            points: vec![
                (1, ms(18.0)),
                (2, ms(28.0)),
                (3, ms(40.0)),
                (4, ms(52.0)),
                (5, ms(64.0)),
                (6, ms(75.0)),
                (7, ms(85.0)),
                (8, ms(95.0)),
                (9, ms(128.59)),
                (12, ms(131.0)),
                (16, ms(134.0)),
                (24, ms(139.0)),
                (32, ms(145.0)),
            ],
            prefill_points: vec![
                (16, ms(30.0)),
                (32, ms(45.0)),
                (64, ms(75.0)),
            ],
            max_batch: 32,
        }
    }

    /// Build from measured decode points (e.g. the PJRT engine).
    pub fn from_points(
        points: Vec<(u32, Micros)>,
        prefill_points: Vec<(u32, Micros)>,
        max_batch: u32,
    ) -> Self {
        assert!(!points.is_empty());
        assert!(points.windows(2).all(|w| w[0].0 < w[1].0), "points not sorted");
        LatencyModel { points, prefill_points, max_batch }
    }

    /// A uniformly slower (or faster) device: every decode/prefill knot
    /// multiplied by `factor` and rounded to integer micros. This is how
    /// heterogeneous fleet profiles (`cluster::fleet::DeviceProfile`)
    /// derive lite/nano device curves from the paper-calibrated one.
    pub fn scaled(&self, factor: f64) -> Self {
        assert!(factor > 0.0, "latency scale factor must be positive");
        let scale = |pts: &[(u32, Micros)]| -> Vec<(u32, Micros)> {
            pts.iter()
                .map(|&(b, us)| (b, (us as f64 * factor).round() as Micros))
                .collect()
        };
        LatencyModel {
            points: scale(&self.points),
            prefill_points: scale(&self.prefill_points),
            max_batch: self.max_batch,
        }
    }

    /// Decode latency for batch size `b` (clamped to the model range).
    pub fn decode(&self, b: u32) -> Micros {
        interp(&self.points, b)
    }

    /// Prefill latency for a prompt of `len` tokens (bucket-interpolated).
    pub fn prefill(&self, len: u32) -> Micros {
        if self.prefill_points.is_empty() {
            return 0;
        }
        interp(&self.prefill_points, len)
    }

    /// Max sustainable aggregate throughput at batch size b: b / l(b),
    /// in tokens per second.
    pub fn throughput(&self, b: u32) -> f64 {
        if b == 0 {
            return 0.0;
        }
        b as f64 / (self.decode(b) as f64 / 1e6)
    }

    /// The batch size maximizing b / l(b) within the cap.
    pub fn best_throughput_batch(&self) -> u32 {
        (1..=self.max_batch)
            .max_by(|&a, &b| {
                self.throughput(a)
                    .partial_cmp(&self.throughput(b))
                    .unwrap()
            })
            .unwrap_or(1)
    }
}

fn interp(points: &[(u32, Micros)], x: u32) -> Micros {
    let (x0, y0) = points[0];
    if x <= x0 {
        return y0;
    }
    for w in points.windows(2) {
        let (xa, ya) = w[0];
        let (xb, yb) = w[1];
        if x <= xb {
            let frac = (x - xa) as f64 / (xb - xa) as f64;
            return (ya as f64 + frac * (yb as f64 - ya as f64)).round() as Micros;
        }
    }
    // extrapolate flat beyond the last knot (plateau regime)
    points.last().unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constraints_hold() {
        let m = LatencyModel::paper_calibrated();
        assert!(m.decode(8) <= ms(100.0));
        assert!(m.decode(9) > ms(100.0));
        assert_eq!(m.decode(9), ms(128.59));
        for b in 10..=32 {
            assert!(m.decode(b) >= ms(120.0), "plateau at b={b}");
        }
    }

    #[test]
    fn near_linear_up_to_eight() {
        let m = LatencyModel::paper_calibrated();
        let slopes: Vec<f64> = (1..8)
            .map(|b| (m.decode(b + 1) as f64 - m.decode(b) as f64) / 1000.0)
            .collect();
        for s in &slopes {
            assert!((9.0..=13.0).contains(s), "slope {s} outside near-linear band");
        }
    }

    #[test]
    fn table2_static_mix_is_feasible() {
        // 4*l(9) + l(3) + 5*l(7) < 1000ms (see selection tests for the
        // full Eq. 7 derivation of the paper's 9-task static workload).
        let m = LatencyModel::paper_calibrated();
        let period = 4 * m.decode(9) + m.decode(3) + 5 * m.decode(7);
        assert!(period < ms(1000.0), "period = {period}");
    }

    #[test]
    fn interpolation_between_knots() {
        let m = LatencyModel::from_points(
            vec![(1, 10_000), (5, 50_000)],
            vec![],
            8,
        );
        assert_eq!(m.decode(3), 30_000);
        assert_eq!(m.decode(1), 10_000);
        assert_eq!(m.decode(0), 10_000); // clamped low
        assert_eq!(m.decode(100), 50_000); // plateau extrapolation
    }

    #[test]
    fn throughput_per_task_below_10_at_paper_plateau() {
        // Fig. 1: at b >= 9, per-task rate drops below 10 tokens/s.
        let m = LatencyModel::paper_calibrated();
        for b in 9..=16 {
            let per_task = m.throughput(b) / b as f64;
            assert!(per_task < 10.0, "b={b} per-task={per_task}");
        }
    }

    #[test]
    fn throughput_grows_in_plateau() {
        // Fig. 1b: beyond the knee, aggregate throughput scales ~linearly.
        let m = LatencyModel::paper_calibrated();
        assert!(m.throughput(16) > m.throughput(9));
        assert!(m.throughput(32) > m.throughput(16));
    }

    #[test]
    fn prefill_interpolates_buckets() {
        let m = LatencyModel::paper_calibrated();
        assert_eq!(m.prefill(16), ms(30.0));
        assert!(m.prefill(24) > ms(30.0) && m.prefill(24) < ms(45.0));
        assert_eq!(m.prefill(64), ms(75.0));
    }

    #[test]
    #[should_panic]
    fn unsorted_points_rejected() {
        let _ = LatencyModel::from_points(vec![(3, 1), (2, 1)], vec![], 4);
    }

    #[test]
    fn scaled_multiplies_every_knot() {
        let m = LatencyModel::paper_calibrated();
        let slow = m.scaled(2.5);
        for b in [1u32, 8, 9, 32] {
            assert_eq!(slow.decode(b), (m.decode(b) as f64 * 2.5).round() as Micros);
        }
        assert_eq!(slow.prefill(16), (m.prefill(16) as f64 * 2.5).round() as Micros);
        assert_eq!(slow.max_batch, m.max_batch);
        // identity scale is exact
        let same = m.scaled(1.0);
        assert_eq!(same.decode(9), m.decode(9));
    }

    #[test]
    #[should_panic]
    fn non_positive_scale_rejected() {
        let _ = LatencyModel::paper_calibrated().scaled(0.0);
    }
}
