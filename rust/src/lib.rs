//! slice-serve: a reproduction of *SLICE: SLO-Driven Scheduling for LLM
//! Inference on Edge Computing Devices* (Zhou et al., CS.DC 2025) as a
//! three-layer rust + JAX + Pallas serving stack.
//!
//! Layers:
//!   * `cluster` — multi-replica scale-out (an extension beyond the
//!     paper): a router dispatching tasks across N single-device stacks
//!     — homogeneous or a heterogeneous mix of device tiers — under
//!     round-robin / least-loaded / SLO-aware strategies, with opt-in
//!     admission control and overload migration.
//!   * L3 (`coordinator`, `server`) — the paper's contribution: the
//!     SLICE scheduler (utility-maximizing selection + decode-mask-matrix
//!     rate allocation + online event loop) and its baselines.
//!   * L2/L1 (`python/compile/`) — the served model: a byte-level
//!     transformer whose decode/prefill attention is a Pallas kernel,
//!     AOT-lowered to HLO text at build time.
//!   * `runtime`/`engine` — the PJRT bridge executing those artifacts,
//!     plus a calibrated simulation engine for the paper's sweeps.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured record.

#![warn(missing_docs)]

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;
