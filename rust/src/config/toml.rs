//! Minimal TOML-subset parser (see `config::mod` docs for the subset).
//!
//! Supports `[section]` tables, `[[section]]` arrays of tables (each
//! header appends a fresh table; following keys land in it), `key =
//! value` scalars/flat arrays, and `#` comments.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// One table of an array-of-tables (`[[name]]`): key -> value.
pub type TomlTable = BTreeMap<String, TomlValue>;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// A quoted string.
    Str(String),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of values.
    Array(Vec<TomlValue>),
}

/// A parsed document: section -> key -> value. Top-level keys live in
/// the "" section.
#[derive(Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, TomlTable>,
    /// `[[name]]` arrays of tables, in document order.
    tables: BTreeMap<String, Vec<TomlTable>>,
}

/// Where the keys following the most recent header land.
enum Target {
    /// A `[section]` header (or the implicit "" top level).
    Section(String),
    /// The latest table of a `[[name]]` array.
    Table(String),
}

impl TomlDoc {
    /// Parse a document in the supported TOML subset.
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut target = Target::Section(String::new());
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[") {
                let name = name
                    .strip_suffix("]]")
                    .with_context(|| format!("line {}: unterminated table array", lineno + 1))?;
                let name = name.trim().to_string();
                doc.tables.entry(name.clone()).or_default().push(TomlTable::new());
                target = Target::Table(name);
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
                let name = name.trim().to_string();
                doc.sections.entry(name.clone()).or_default();
                target = Target::Section(name);
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            let slot = match &target {
                Target::Section(name) => doc.sections.entry(name.clone()).or_default(),
                Target::Table(name) => doc
                    .tables
                    .get_mut(name)
                    .and_then(|v| v.last_mut())
                    .expect("table array entry pushed at its header"),
            };
            slot.insert(key.trim().to_string(), v);
        }
        Ok(doc)
    }

    /// Raw value lookup (top-level keys live in the "" section).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// The tables of a `[[name]]` array, in document order (empty slice
    /// when the document has none).
    pub fn get_tables(&self, name: &str) -> &[TomlTable] {
        self.tables.get(name).map_or(&[], Vec::as_slice)
    }

    /// Typed lookup: string.
    pub fn get_str(&self, section: &str, key: &str) -> Result<Option<String>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Str(s)) => Ok(Some(s.clone())),
            Some(v) => bail!("[{section}].{key}: expected string, got {v:?}"),
        }
    }

    /// Typed lookup: integer.
    pub fn get_i64(&self, section: &str, key: &str) -> Result<Option<i64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) => Ok(Some(*i)),
            Some(v) => bail!("[{section}].{key}: expected integer, got {v:?}"),
        }
    }

    /// Typed lookup: float (integers widen).
    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Float(f)) => Ok(Some(*f)),
            Some(TomlValue::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => bail!("[{section}].{key}: expected float, got {v:?}"),
        }
    }

    /// Typed lookup: bool.
    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Bool(b)) => Ok(Some(*b)),
            Some(v) => bail!("[{section}].{key}: expected bool, got {v:?}"),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string is respected
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value: {s}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
top = 1
[a]
s = "hello"   # comment
i = 42
f = 2.5
b = true
arr = [1, 2, 3]
[b]
x = -7
"#,
        )
        .unwrap();
        assert_eq!(doc.get_i64("", "top").unwrap(), Some(1));
        assert_eq!(doc.get_str("a", "s").unwrap(), Some("hello".into()));
        assert_eq!(doc.get_i64("a", "i").unwrap(), Some(42));
        assert_eq!(doc.get_f64("a", "f").unwrap(), Some(2.5));
        assert_eq!(doc.get_bool("a", "b").unwrap(), Some(true));
        assert_eq!(
            doc.get("a", "arr"),
            Some(&TomlValue::Array(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ]))
        );
        assert_eq!(doc.get_i64("b", "x").unwrap(), Some(-7));
        assert_eq!(doc.get_i64("b", "missing").unwrap(), None);
    }

    #[test]
    fn int_coerces_to_float_not_vice_versa() {
        let doc = TomlDoc::parse("x = 3\ny = 3.5\n").unwrap();
        assert_eq!(doc.get_f64("", "x").unwrap(), Some(3.0));
        assert!(doc.get_i64("", "y").is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = TomlDoc::parse("s = \"a#b\" # real comment\n").unwrap();
        assert_eq!(doc.get_str("", "s").unwrap(), Some("a#b".into()));
    }

    #[test]
    fn errors_are_informative() {
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("x = \"unterminated\n").is_err());
        assert!(TomlDoc::parse("[[unterminated\n").is_err());
    }

    #[test]
    fn array_of_tables_in_document_order() {
        let doc = TomlDoc::parse(
            r#"
[cluster]
strategy = "slo-aware"

[[cluster.replica]]
device = "standard"

[[cluster.replica]]
device = "nano"
scale = 2.5

[cluster2]
after = 1
"#,
        )
        .unwrap();
        let tables = doc.get_tables("cluster.replica");
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].get("device"), Some(&TomlValue::Str("standard".into())));
        assert_eq!(tables[1].get("device"), Some(&TomlValue::Str("nano".into())));
        assert_eq!(tables[1].get("scale"), Some(&TomlValue::Float(2.5)));
        // keys after a later [section] header do not leak into the table
        assert_eq!(doc.get_i64("cluster2", "after").unwrap(), Some(1));
        assert_eq!(doc.get_str("cluster", "strategy").unwrap(), Some("slo-aware".into()));
        assert!(doc.get_tables("missing").is_empty());
    }
}
