//! Configuration system: a small TOML-subset parser plus typed configs
//! for the server, scheduler, engine, workload and cluster (the
//! `toml`/`serde` crates are unavailable offline, so the parser lives
//! here — DESIGN.md "Dependency policy").
//!
//! Contract: [`ServeConfig`] is the single knob surface every launcher
//! (CLI subcommands, experiments, benches) builds policies and
//! workloads from; file keys and CLI flags set the same fields.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with
//! strings, integers, floats, booleans and flat arrays, comments with
//! `#`. This covers everything the launcher needs.

pub mod toml;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cluster::RoutingStrategy;
use crate::coordinator::fastserve::FastServeConfig;
use crate::coordinator::preemption::UtilityAdaptor;
use crate::coordinator::selection::CYCLE_CAP;
use crate::util::{secs, Micros};

use self::toml::TomlDoc;

/// Which scheduling policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's SLICE scheduler.
    Slice,
    /// Orca-style FCFS continuous batching.
    Orca,
    /// FastServe skip-join MLFQ.
    FastServe,
}

impl PolicyKind {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "slice" => PolicyKind::Slice,
            "orca" => PolicyKind::Orca,
            "fastserve" | "fast-serve" => PolicyKind::FastServe,
            other => bail!("unknown policy '{other}' (slice|orca|fastserve)"),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Slice => "SLICE",
            PolicyKind::Orca => "Orca",
            PolicyKind::FastServe => "FastServe",
        }
    }
}

/// Engine backend selection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineKind {
    /// Virtual-time simulation with the paper-calibrated latency model.
    Sim,
    /// Real AOT-compiled model via PJRT; holds the artifacts directory.
    Pjrt(PathBuf),
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Scheduling policy to run.
    pub policy: PolicyKind,
    /// Engine backend (sim or pjrt).
    pub engine: EngineKind,
    /// SLICE: scheduling-cycle cap.
    pub cycle_cap: Micros,
    /// SLICE: utility adaptor.
    pub adaptor: UtilityAdaptor,
    /// SLICE extension: charge pending prefill work to the cycle budget.
    pub prefill_aware: bool,
    /// Orca / FastServe: max concurrent batch.
    pub max_batch: u32,
    /// FastServe MLFQ shape.
    pub fastserve: FastServeConfig,
    /// Workload parameters.
    pub arrival_rate: f64,
    /// Real-time share of the workload mix.
    pub rt_ratio: f64,
    /// Number of tasks to generate.
    pub n_tasks: usize,
    /// Workload RNG seed.
    pub seed: u64,
    /// Run horizon.
    pub horizon: Micros,
    /// Cluster mode: number of replicas.
    pub cluster_replicas: usize,
    /// Cluster mode: routing strategy.
    pub cluster_strategy: RoutingStrategy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            policy: PolicyKind::Slice,
            engine: EngineKind::Sim,
            cycle_cap: CYCLE_CAP,
            adaptor: UtilityAdaptor::None,
            prefill_aware: false,
            max_batch: 32,
            fastserve: FastServeConfig::default(),
            arrival_rate: 1.0,
            rt_ratio: 0.7,
            n_tasks: 200,
            seed: 42,
            horizon: secs(600.0),
            cluster_replicas: 1,
            cluster_strategy: RoutingStrategy::SloAware,
        }
    }
}

impl ServeConfig {
    /// Load from a TOML file (all keys optional; defaults otherwise).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml(&text)
    }

    /// Parse a TOML document (all keys optional; defaults otherwise).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ServeConfig::default();

        if let Some(v) = doc.get_str("scheduler", "policy")? {
            cfg.policy = PolicyKind::parse(&v)?;
        }
        if let Some(v) = doc.get_f64("scheduler", "cycle_cap_ms")? {
            cfg.cycle_cap = (v * 1000.0) as Micros;
        }
        if let Some(v) = doc.get_i64("scheduler", "max_batch")? {
            cfg.max_batch = v as u32;
        }
        if let Some(v) = doc.get_bool("scheduler", "prefill_aware")? {
            cfg.prefill_aware = v;
        }
        if let Some(v) = doc.get_str("scheduler", "adaptor")? {
            cfg.adaptor = match v.as_str() {
                "none" => UtilityAdaptor::None,
                "sjf" => UtilityAdaptor::SjfDecay { factor: 0.5, tau: 32 },
                "sticky" => UtilityAdaptor::StickyBoost { multiplier: 2.0 },
                other => bail!("unknown adaptor '{other}' (none|sjf|sticky)"),
            };
        }
        if let Some(v) = doc.get_i64("fastserve", "levels")? {
            cfg.fastserve.levels = v as usize;
        }
        if let Some(v) = doc.get_i64("fastserve", "base_quantum")? {
            cfg.fastserve.base_quantum = v as u32;
        }
        if let Some(v) = doc.get_i64("fastserve", "base_join_len")? {
            cfg.fastserve.base_join_len = v as u32;
        }
        if let Some(v) = doc.get_str("engine", "backend")? {
            cfg.engine = match v.as_str() {
                "sim" => EngineKind::Sim,
                "pjrt" => {
                    let dir = doc
                        .get_str("engine", "artifacts")?
                        .unwrap_or_else(|| "artifacts".to_string());
                    EngineKind::Pjrt(PathBuf::from(dir))
                }
                other => bail!("unknown engine backend '{other}' (sim|pjrt)"),
            };
        }
        if let Some(v) = doc.get_f64("workload", "arrival_rate")? {
            cfg.arrival_rate = v;
        }
        if let Some(v) = doc.get_f64("workload", "rt_ratio")? {
            cfg.rt_ratio = v;
        }
        if let Some(v) = doc.get_i64("workload", "n_tasks")? {
            cfg.n_tasks = v as usize;
        }
        if let Some(v) = doc.get_i64("workload", "seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_f64("workload", "horizon_s")? {
            cfg.horizon = secs(v);
        }
        if let Some(v) = doc.get_i64("cluster", "replicas")? {
            if v < 1 {
                bail!("[cluster] replicas must be >= 1, got {v}");
            }
            cfg.cluster_replicas = v as usize;
        }
        if let Some(v) = doc.get_str("cluster", "strategy")? {
            cfg.cluster_strategy = RoutingStrategy::parse(&v)?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.policy, PolicyKind::Slice);
        assert_eq!(c.cycle_cap, 1_000_000);
        assert_eq!(c.max_batch, 32);
        assert_eq!(c.cluster_replicas, 1);
        assert_eq!(c.cluster_strategy, RoutingStrategy::SloAware);
    }

    #[test]
    fn parses_cluster_section() {
        let text = "[cluster]\nreplicas = 4\nstrategy = \"least-loaded\"\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert_eq!(c.cluster_replicas, 4);
        assert_eq!(c.cluster_strategy, RoutingStrategy::LeastLoaded);
        assert!(ServeConfig::from_toml("[cluster]\nreplicas = 0\n").is_err());
        assert!(ServeConfig::from_toml("[cluster]\nstrategy = \"hash\"\n").is_err());
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# SLICE serving config
[scheduler]
policy = "orca"
cycle_cap_ms = 800.0
max_batch = 16
adaptor = "sjf"

[fastserve]
levels = 4
base_quantum = 4

[engine]
backend = "pjrt"
artifacts = "artifacts"

[workload]
arrival_rate = 2.5
rt_ratio = 0.5
n_tasks = 1000
seed = 7
horizon_s = 120.0
"#;
        let c = ServeConfig::from_toml(text).unwrap();
        assert_eq!(c.policy, PolicyKind::Orca);
        assert_eq!(c.cycle_cap, 800_000);
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.adaptor, UtilityAdaptor::SjfDecay { factor: 0.5, tau: 32 });
        assert_eq!(c.fastserve.levels, 4);
        assert_eq!(c.fastserve.base_quantum, 4);
        assert_eq!(c.engine, EngineKind::Pjrt(PathBuf::from("artifacts")));
        assert_eq!(c.arrival_rate, 2.5);
        assert_eq!(c.n_tasks, 1000);
        assert_eq!(c.seed, 7);
        assert_eq!(c.horizon, 120_000_000);
    }

    #[test]
    fn rejects_unknown_policy() {
        assert!(ServeConfig::from_toml("[scheduler]\npolicy = \"lifo\"\n").is_err());
    }

    #[test]
    fn policy_kind_parse() {
        assert_eq!(PolicyKind::parse("SLICE").unwrap(), PolicyKind::Slice);
        assert_eq!(PolicyKind::parse("fastserve").unwrap(), PolicyKind::FastServe);
        assert!(PolicyKind::parse("bogus").is_err());
    }
}
