//! Configuration system: a small TOML-subset parser plus typed configs
//! for the server, scheduler, engine, workload and cluster (the
//! `toml`/`serde` crates are unavailable offline, so the parser lives
//! here — DESIGN.md "Dependency policy").
//!
//! Contract: [`ServeConfig`] is the single knob surface every launcher
//! (CLI subcommands, experiments, benches) builds policies and
//! workloads from; file keys and CLI flags set the same fields.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with
//! strings, integers, floats, booleans and flat arrays, comments with
//! `#`. This covers everything the launcher needs.

pub mod toml;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cluster::{
    AdmissionConfig, AdmissionMode, DeviceProfile, FleetSpec, LifecycleAction,
    LifecycleConfig, LifecycleEvent, RoutingStrategy,
};
use crate::coordinator::fastserve::FastServeConfig;
use crate::coordinator::preemption::UtilityAdaptor;
use crate::coordinator::selection::CYCLE_CAP;
use crate::engine::memory::{MemoryConfig, PreemptionMode};
use crate::util::{secs, Micros};

use self::toml::{TomlDoc, TomlTable, TomlValue};

/// Which scheduling policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's SLICE scheduler.
    Slice,
    /// Orca-style FCFS continuous batching.
    Orca,
    /// FastServe skip-join MLFQ.
    FastServe,
}

impl PolicyKind {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "slice" => PolicyKind::Slice,
            "orca" => PolicyKind::Orca,
            "fastserve" | "fast-serve" => PolicyKind::FastServe,
            other => bail!("unknown policy '{other}' (slice|orca|fastserve)"),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            PolicyKind::Slice => "SLICE",
            PolicyKind::Orca => "Orca",
            PolicyKind::FastServe => "FastServe",
        }
    }
}

/// Which cluster engine advances the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClusterEngine {
    /// Lockstep reference engine ([`crate::cluster::Router`]): every
    /// replica is advanced to every arrival. The in-tree semantic
    /// reference; the default.
    #[default]
    Lockstep,
    /// Event-driven engine ([`crate::cluster::Orchestrator`]): a global
    /// event heap advances replicas only when they have work. Bit-exact
    /// with lockstep; the one to use at fleet scale.
    Event,
}

impl ClusterEngine {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "lockstep" | "router" => ClusterEngine::Lockstep,
            "event" | "orchestrator" => ClusterEngine::Event,
            other => bail!("unknown cluster engine '{other}' (lockstep|event)"),
        })
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            ClusterEngine::Lockstep => "lockstep",
            ClusterEngine::Event => "event",
        }
    }
}

/// Engine backend selection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineKind {
    /// Virtual-time simulation with the paper-calibrated latency model.
    Sim,
    /// Real AOT-compiled model via PJRT; holds the artifacts directory.
    Pjrt(PathBuf),
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Scheduling policy to run.
    pub policy: PolicyKind,
    /// Engine backend (sim or pjrt).
    pub engine: EngineKind,
    /// SLICE: scheduling-cycle cap.
    pub cycle_cap: Micros,
    /// SLICE: utility adaptor.
    pub adaptor: UtilityAdaptor,
    /// SLICE extension: charge pending prefill work to the cycle budget.
    pub prefill_aware: bool,
    /// SLICE: cached candidate sets + reschedule skipping (DESIGN.md
    /// "Control-plane incrementality"). Bit-exact with `false` by
    /// construction; the off-switch exists for A/B runs and so the
    /// equivalence suite can pin that claim.
    pub incremental: bool,
    /// Orca / FastServe: max concurrent batch.
    pub max_batch: u32,
    /// FastServe MLFQ shape.
    pub fastserve: FastServeConfig,
    /// Workload parameters.
    pub arrival_rate: f64,
    /// Real-time share of the workload mix.
    pub rt_ratio: f64,
    /// Number of tasks to generate.
    pub n_tasks: usize,
    /// Workload RNG seed.
    pub seed: u64,
    /// Run horizon.
    pub horizon: Micros,
    /// Cluster mode: number of replicas (homogeneous fleets).
    pub cluster_replicas: usize,
    /// Cluster mode: routing strategy.
    pub cluster_strategy: RoutingStrategy,
    /// Cluster mode: explicit per-replica device profiles. `None` means
    /// a homogeneous fleet of `cluster_replicas` standard devices.
    pub cluster_fleet: Option<FleetSpec>,
    /// Cluster mode: router admission bounds (disabled by default).
    pub cluster_admission: AdmissionConfig,
    /// Cluster mode: overload migration (disabled by default).
    pub cluster_migration: bool,
    /// Cluster mode: running-task KV-handoff migration (disabled by
    /// default; requires `cluster_migration`).
    pub cluster_migrate_running: bool,
    /// Cluster mode: which engine advances the fleet (lockstep
    /// reference by default; the event engine is bit-exact and faster
    /// at scale).
    pub cluster_engine: ClusterEngine,
    /// Cluster mode: worker threads for the event engine's
    /// epoch-batched wake advancement (`[cluster] threads` /
    /// `--threads`; DESIGN.md "Parallel event engine"). Any value
    /// produces bit-identical reports; 1 (the default) is the exact
    /// sequential path, larger values cut wall time on wide fleets.
    /// Ignored by the lockstep reference engine.
    pub cluster_threads: usize,
    /// Cluster mode: elastic-fleet knobs — lifecycle events (explicit
    /// schedule + seeded churn), fleet-size bounds, autoscaler, health
    /// scoring and heartbeat failure detection (`[cluster.lifecycle]` /
    /// `[cluster.autoscaler]` / `[cluster.health]` /
    /// `[cluster.detector]`; all off by default). Any enabled elastic
    /// feature requires the event engine.
    pub lifecycle: LifecycleConfig,
    /// KV-cache memory model (`[memory]`; unconstrained by default, so
    /// every pre-memory run reproduces bit-exactly).
    pub memory: MemoryConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            policy: PolicyKind::Slice,
            engine: EngineKind::Sim,
            cycle_cap: CYCLE_CAP,
            adaptor: UtilityAdaptor::None,
            prefill_aware: false,
            incremental: true,
            max_batch: 32,
            fastserve: FastServeConfig::default(),
            arrival_rate: 1.0,
            rt_ratio: 0.7,
            n_tasks: 200,
            seed: 42,
            horizon: secs(600.0),
            cluster_replicas: 1,
            cluster_strategy: RoutingStrategy::SloAware,
            cluster_fleet: None,
            cluster_admission: AdmissionConfig::default(),
            cluster_migration: false,
            cluster_migrate_running: false,
            cluster_engine: ClusterEngine::Lockstep,
            cluster_threads: 1,
            lifecycle: LifecycleConfig::default(),
            memory: MemoryConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Load from a TOML file (all keys optional; defaults otherwise).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        Self::from_toml(&text)
    }

    /// Parse a TOML document (all keys optional; defaults otherwise).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = ServeConfig::default();

        if let Some(v) = doc.get_str("scheduler", "policy")? {
            cfg.policy = PolicyKind::parse(&v)?;
        }
        if let Some(v) = doc.get_f64("scheduler", "cycle_cap_ms")? {
            cfg.cycle_cap = (v * 1000.0) as Micros;
        }
        if let Some(v) = doc.get_i64("scheduler", "max_batch")? {
            cfg.max_batch = v as u32;
        }
        if let Some(v) = doc.get_bool("scheduler", "prefill_aware")? {
            cfg.prefill_aware = v;
        }
        if let Some(v) = doc.get_bool("scheduler", "incremental")? {
            cfg.incremental = v;
        }
        if let Some(v) = doc.get_str("scheduler", "adaptor")? {
            cfg.adaptor = match v.as_str() {
                "none" => UtilityAdaptor::None,
                "sjf" => UtilityAdaptor::SjfDecay { factor: 0.5, tau: 32 },
                "sticky" => UtilityAdaptor::StickyBoost { multiplier: 2.0 },
                other => bail!("unknown adaptor '{other}' (none|sjf|sticky)"),
            };
        }
        if let Some(v) = doc.get_i64("fastserve", "levels")? {
            cfg.fastserve.levels = v as usize;
        }
        if let Some(v) = doc.get_i64("fastserve", "base_quantum")? {
            cfg.fastserve.base_quantum = v as u32;
        }
        if let Some(v) = doc.get_i64("fastserve", "base_join_len")? {
            cfg.fastserve.base_join_len = v as u32;
        }
        if let Some(v) = doc.get_str("engine", "backend")? {
            cfg.engine = match v.as_str() {
                "sim" => EngineKind::Sim,
                "pjrt" => {
                    let dir = doc
                        .get_str("engine", "artifacts")?
                        .unwrap_or_else(|| "artifacts".to_string());
                    EngineKind::Pjrt(PathBuf::from(dir))
                }
                other => bail!("unknown engine backend '{other}' (sim|pjrt)"),
            };
        }
        if let Some(v) = doc.get_f64("workload", "arrival_rate")? {
            cfg.arrival_rate = v;
        }
        if let Some(v) = doc.get_f64("workload", "rt_ratio")? {
            cfg.rt_ratio = v;
        }
        if let Some(v) = doc.get_i64("workload", "n_tasks")? {
            cfg.n_tasks = v as usize;
        }
        if let Some(v) = doc.get_i64("workload", "seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_f64("workload", "horizon_s")? {
            cfg.horizon = secs(v);
        }
        let replicas_key = doc.get_i64("cluster", "replicas")?;
        if let Some(v) = replicas_key {
            if v < 1 {
                bail!("[cluster] replicas must be >= 1, got {v}");
            }
            cfg.cluster_replicas = v as usize;
        }
        if let Some(v) = doc.get_str("cluster", "strategy")? {
            cfg.cluster_strategy = RoutingStrategy::parse(&v)?;
        }
        if let Some(v) = doc.get_str("cluster", "fleet")? {
            cfg.cluster_fleet = Some(FleetSpec::preset(&v)?.with_cycle_cap(cfg.cycle_cap));
        }
        // a bound key implies admission unless it is explicitly switched
        // off — a configured bound must never be a silent no-op
        let admission_key = doc.get_bool("cluster", "admission")?;
        if let Some(v) = admission_key {
            cfg.cluster_admission.enabled = v;
        }
        let mut bound_set = false;
        if let Some(v) = doc.get_i64("cluster", "rt_queue_bound")? {
            if v < 1 {
                bail!("[cluster] rt_queue_bound must be >= 1, got {v}");
            }
            cfg.cluster_admission.rt_queue_bound = v as usize;
            bound_set = true;
        }
        if let Some(v) = doc.get_i64("cluster", "nrt_queue_bound")? {
            if v < 1 {
                bail!("[cluster] nrt_queue_bound must be >= 1, got {v}");
            }
            cfg.cluster_admission.nrt_queue_bound = v as usize;
            bound_set = true;
        }
        if bound_set && admission_key.is_none() {
            cfg.cluster_admission.enabled = true;
        }
        if let Some(v) = doc.get_str("cluster", "admission_mode")? {
            cfg.cluster_admission.mode = match v.as_str() {
                "depth" => AdmissionMode::QueueDepth,
                "headroom" => AdmissionMode::Headroom,
                other => bail!("unknown admission_mode '{other}' (depth|headroom)"),
            };
            if admission_key.is_none() {
                // naming a mode opts in, like setting a bound does
                cfg.cluster_admission.enabled = true;
            }
        }
        if bound_set && cfg.cluster_admission.mode == AdmissionMode::Headroom {
            // headroom admission never reads the depth bounds — a
            // configured bound must never be a silent no-op
            bail!(
                "[cluster] rt_queue_bound/nrt_queue_bound apply to depth \
                 admission; remove them or set admission_mode = \"depth\""
            );
        }
        let engine_key = doc.get_str("cluster", "engine")?;
        if let Some(v) = &engine_key {
            cfg.cluster_engine = ClusterEngine::parse(v)?;
        }
        if let Some(v) = doc.get_i64("cluster", "threads")? {
            if v < 1 {
                bail!("[cluster] threads must be >= 1, got {v}");
            }
            cfg.cluster_threads = v as usize;
            if cfg.cluster_threads > 1 {
                // only the event engine has epochs to parallelize — the
                // knob implies it (never a silent no-op), and conflicts
                // with an explicitly lockstep engine
                if engine_key.is_some() && cfg.cluster_engine == ClusterEngine::Lockstep {
                    bail!(
                        "[cluster] threads > 1 applies to the event engine; \
                         use engine = \"event\" or threads = 1"
                    );
                }
                cfg.cluster_engine = ClusterEngine::Event;
            }
        }
        if let Some(v) = doc.get_bool("cluster", "migration")? {
            cfg.cluster_migration = v;
        }
        let migrate_running_key = doc.get_bool("cluster", "migrate_running")?;
        if let Some(v) = migrate_running_key {
            cfg.cluster_migrate_running = v;
            if v {
                // running handoff rides on the migration pass it
                // extends: enabling it always enables migration (even
                // over an explicit `migration = false` — the same rule
                // the CLI applies, so both surfaces agree)
                cfg.cluster_migration = true;
            }
        }
        // ---- [cluster.lifecycle] / [cluster.autoscaler] / [cluster.health]
        for (key, action) in [
            ("crash_at_s", LifecycleAction::Crash),
            ("leave_at_s", LifecycleAction::Leave),
            ("join_at_s", LifecycleAction::Join),
        ] {
            for t in parse_secs_array(&doc, "cluster.lifecycle", key)? {
                // config events are untargeted: the victim is drawn from
                // the schedule's seeded RNG at fire time
                cfg.lifecycle.events.push(LifecycleEvent {
                    time: secs(t),
                    action,
                    target: None,
                });
            }
        }
        cfg.lifecycle.events.sort_by_key(|e| e.time);
        if let Some(v) = doc.get_f64("cluster.lifecycle", "churn_rate")? {
            if v < 0.0 {
                bail!("[cluster.lifecycle] churn_rate must be >= 0 events/s, got {v}");
            }
            cfg.lifecycle.churn_rate = v;
        }
        if let Some(v) = doc.get_i64("cluster.lifecycle", "seed")? {
            cfg.lifecycle.seed = v as u64;
        }
        if let Some(v) = doc.get_i64("cluster.lifecycle", "min_replicas")? {
            if v < 1 {
                bail!("[cluster.lifecycle] min_replicas must be >= 1, got {v}");
            }
            cfg.lifecycle.min_replicas = v as usize;
        }
        if let Some(v) = doc.get_i64("cluster.lifecycle", "max_replicas")? {
            if v < 1 {
                bail!("[cluster.lifecycle] max_replicas must be >= 1, got {v}");
            }
            cfg.lifecycle.max_replicas = v as usize;
        }
        if cfg.lifecycle.min_replicas > cfg.lifecycle.max_replicas {
            bail!(
                "[cluster.lifecycle] min_replicas {} exceeds max_replicas {}",
                cfg.lifecycle.min_replicas,
                cfg.lifecycle.max_replicas
            );
        }
        // naming any autoscaler/health knob opts the feature in unless
        // `enabled = false` is explicit — a configured knob must never
        // be a silent no-op (the admission-bound rule above)
        let autoscaler_key = doc.get_bool("cluster.autoscaler", "enabled")?;
        let mut autoscaler_knob = false;
        if let Some(v) = doc.get_i64("cluster.autoscaler", "deficit_streak")? {
            if v < 1 {
                bail!("[cluster.autoscaler] deficit_streak must be >= 1, got {v}");
            }
            cfg.lifecycle.autoscaler.deficit_streak = v as u32;
            autoscaler_knob = true;
        }
        if let Some(v) = doc.get_i64("cluster.autoscaler", "idle_streak")? {
            if v < 1 {
                bail!("[cluster.autoscaler] idle_streak must be >= 1, got {v}");
            }
            cfg.lifecycle.autoscaler.idle_streak = v as u32;
            autoscaler_knob = true;
        }
        if let Some(v) = doc.get_f64("cluster.autoscaler", "cooldown_s")? {
            if v < 0.0 {
                bail!("[cluster.autoscaler] cooldown_s must be >= 0, got {v}");
            }
            cfg.lifecycle.autoscaler.cooldown = secs(v);
            autoscaler_knob = true;
        }
        if let Some(v) = doc.get_f64("cluster.autoscaler", "boot_delay_s")? {
            if v < 0.0 {
                bail!("[cluster.autoscaler] boot_delay_s must be >= 0, got {v}");
            }
            cfg.lifecycle.autoscaler.boot_delay = secs(v);
            autoscaler_knob = true;
        }
        let headroom_mode_key = doc.get_bool("cluster.autoscaler", "grow_on_headroom")?;
        if let Some(v) = headroom_mode_key {
            cfg.lifecycle.autoscaler.grow_on_headroom = v;
            if v {
                autoscaler_knob = true;
            }
        }
        if let Some(v) = doc.get_f64("cluster.autoscaler", "headroom_min_ms")? {
            if v < 0.0 {
                bail!("[cluster.autoscaler] headroom_min_ms must be >= 0, got {v}");
            }
            if headroom_mode_key == Some(false) {
                // the floor only feeds the headroom-mode trigger — a
                // configured knob must never be a silent no-op
                bail!(
                    "[cluster.autoscaler] headroom_min_ms requires \
                     grow_on_headroom = true"
                );
            }
            cfg.lifecycle.autoscaler.headroom_min = (v * 1000.0) as Micros;
            // naming the floor opts the headroom mode (and the
            // autoscaler) in, like every other named knob
            cfg.lifecycle.autoscaler.grow_on_headroom = true;
            autoscaler_knob = true;
        }
        cfg.lifecycle.autoscaler.enabled = autoscaler_key.unwrap_or(autoscaler_knob);
        let health_key = doc.get_bool("cluster.health", "enabled")?;
        let mut health_knob = false;
        if let Some(v) = doc.get_f64("cluster.health", "alpha")? {
            if !(v > 0.0 && v <= 1.0) {
                bail!("[cluster.health] alpha must be in (0, 1], got {v}");
            }
            cfg.lifecycle.health.alpha = v;
            health_knob = true;
        }
        if let Some(v) = doc.get_f64("cluster.health", "lag_threshold_ms")? {
            if v <= 0.0 {
                bail!("[cluster.health] lag_threshold_ms must be positive, got {v}");
            }
            cfg.lifecycle.health.lag_threshold = (v * 1000.0) as Micros;
            health_knob = true;
        }
        if let Some(v) = doc.get_f64("cluster.health", "failure_penalty_ms")? {
            if v < 0.0 {
                bail!("[cluster.health] failure_penalty_ms must be >= 0, got {v}");
            }
            cfg.lifecycle.health.failure_penalty = (v * 1000.0) as Micros;
            health_knob = true;
        }
        cfg.lifecycle.health.enabled = health_key.unwrap_or(health_knob);
        let detector_key = doc.get_bool("cluster.detector", "enabled")?;
        let mut detector_knob = false;
        if let Some(v) = doc.get_f64("cluster.detector", "heartbeat_interval_s")? {
            if v <= 0.0 {
                bail!("[cluster.detector] heartbeat_interval_s must be positive, got {v}");
            }
            cfg.lifecycle.detector.heartbeat_interval = secs(v);
            detector_knob = true;
        }
        if let Some(v) = doc.get_f64("cluster.detector", "suspicion_timeout_s")? {
            if v < 0.0 {
                bail!("[cluster.detector] suspicion_timeout_s must be >= 0, got {v}");
            }
            // 0 is legal and means "oracle detection": the detector
            // stays inert and crashes are visible instantly (the PR 7
            // path, pinned bit-exact by the equivalence suite)
            cfg.lifecycle.detector.suspicion_timeout = secs(v);
            detector_knob = true;
        }
        if let Some(v) = doc.get_i64("cluster.detector", "max_retries")? {
            if v < 0 || v > u32::MAX as i64 {
                bail!("[cluster.detector] max_retries must fit in [0, 2^32), got {v}");
            }
            cfg.lifecycle.detector.max_retries = v as u32;
            detector_knob = true;
        }
        if let Some(v) = doc.get_f64("cluster.detector", "retry_backoff_s")? {
            if v < 0.0 {
                bail!("[cluster.detector] retry_backoff_s must be >= 0, got {v}");
            }
            cfg.lifecycle.detector.retry_backoff = secs(v);
            detector_knob = true;
        }
        cfg.lifecycle.detector.enabled = detector_key.unwrap_or(detector_knob);
        if cfg.lifecycle.any_enabled() {
            // lifecycle events ride the event heap, which the lockstep
            // reference engine does not have
            if engine_key.is_some() && cfg.cluster_engine == ClusterEngine::Lockstep {
                bail!(
                    "[cluster] engine = \"lockstep\" cannot run elastic fleets \
                     (lifecycle/autoscaler/health/detector); use engine = \"event\""
                );
            }
            cfg.cluster_engine = ClusterEngine::Event;
        }
        // ---- [memory] --------------------------------------------------
        if let Some(v) = doc.get_f64("memory", "kv_capacity_mb")? {
            if v <= 0.0 {
                bail!("[memory] kv_capacity_mb must be positive, got {v}");
            }
            cfg.memory.kv_capacity = Some((v * 1024.0 * 1024.0) as u64);
        }
        if let Some(v) = doc.get_i64("memory", "kv_bytes_per_token")? {
            if v < 1 {
                bail!("[memory] kv_bytes_per_token must be >= 1, got {v}");
            }
            cfg.memory.bytes_per_token = v as u64;
        }
        if let Some(v) = doc.get_i64("memory", "block_tokens")? {
            if v < 1 {
                bail!("[memory] block_tokens must be >= 1, got {v}");
            }
            cfg.memory.block_tokens = v as u32;
        }
        // bandwidth keys: `*_mb_per_s` is the current spelling; the
        // original `*_mbps` (ambiguous — read megaBITS by some tools)
        // keys are still parsed for back-compat (DESIGN.md "Deviations
        // from the paper", deprecation note). Setting both is an error.
        cfg.memory.swap_bandwidth = parse_bandwidth(
            &doc,
            "swap_bandwidth_mb_per_s",
            "swap_bandwidth_mbps",
            cfg.memory.swap_bandwidth,
        )?;
        cfg.memory.handoff_bandwidth = parse_bandwidth(
            &doc,
            "handoff_bandwidth_mb_per_s",
            "handoff_bandwidth_mbps",
            cfg.memory.handoff_bandwidth,
        )?;
        if let Some(v) = doc.get_str("memory", "preemption")? {
            cfg.memory.mode = PreemptionMode::parse(&v)?;
        }
        if let Some(v) = doc.get_bool("memory", "aware")? {
            cfg.memory.aware = v;
        }
        let replica_tables = doc.get_tables("cluster.replica");
        if !replica_tables.is_empty() {
            if cfg.cluster_fleet.is_some() {
                bail!("[cluster] fleet and [[cluster.replica]] are mutually exclusive");
            }
            let profiles = replica_tables
                .iter()
                .map(|t| parse_replica_table(t, cfg.cycle_cap))
                .collect::<Result<Vec<_>>>()?;
            cfg.cluster_fleet = Some(FleetSpec { profiles });
        }
        if let Some(fleet) = &cfg.cluster_fleet {
            if replicas_key.is_some() {
                bail!(
                    "[cluster] replicas conflicts with an explicit fleet \
                     (fleet / [[cluster.replica]] fixes the width)"
                );
            }
            cfg.cluster_replicas = fleet.len();
        }
        Ok(cfg)
    }

    /// The effective fleet for cluster runs: the explicit spec when one
    /// was configured, else `cluster_replicas` standard devices carrying
    /// the configured cycle cap (exactly the pre-refactor homogeneous
    /// fleet).
    pub fn fleet(&self) -> FleetSpec {
        match &self.cluster_fleet {
            Some(f) => f.clone(),
            None => FleetSpec::homogeneous(self.cluster_replicas, self.cycle_cap),
        }
    }
}

/// Parse a `[memory]` bandwidth key in MB/s, preferring the current
/// `*_mb_per_s` spelling and still accepting the deprecated `*_mbps`
/// one. Naming both is a conflict; naming neither keeps `default`.
fn parse_bandwidth(
    doc: &TomlDoc,
    key: &str,
    deprecated: &str,
    default: u64,
) -> Result<u64> {
    let new = doc.get_f64("memory", key)?;
    let old = doc.get_f64("memory", deprecated)?;
    if new.is_some() && old.is_some() {
        bail!("[memory] {key} conflicts with deprecated {deprecated}; set only one");
    }
    match new.or(old) {
        None => Ok(default),
        Some(v) if v > 0.0 => Ok((v * 1e6) as u64),
        Some(v) => bail!("[memory] {key} must be positive, got {v}"),
    }
}

/// Parse a flat array of non-negative times in seconds
/// (`crash_at_s = [40.0, 80.0]`). Missing key => empty.
fn parse_secs_array(doc: &TomlDoc, section: &str, key: &str) -> Result<Vec<f64>> {
    let Some(v) = doc.get(section, key) else {
        return Ok(Vec::new());
    };
    let TomlValue::Array(items) = v else {
        bail!("[{section}].{key}: expected an array of seconds, got {v:?}");
    };
    items
        .iter()
        .map(|it| match it {
            TomlValue::Float(f) if *f >= 0.0 => Ok(*f),
            TomlValue::Int(i) if *i >= 0 => Ok(*i as f64),
            other => {
                bail!("[{section}].{key}: expected non-negative seconds, got {other:?}")
            }
        })
        .collect()
}

/// Parse one `[[cluster.replica]]` table: a named `device` tier
/// (default "standard"), optionally rescaled (`scale`, a latency
/// multiplier on the tier curve) or given a custom `cycle_cap_ms`.
/// Without an explicit `cycle_cap_ms` the replica inherits the
/// configured `[scheduler] cycle_cap_ms` (`default_cycle_cap`).
fn parse_replica_table(table: &TomlTable, default_cycle_cap: Micros) -> Result<DeviceProfile> {
    for key in table.keys() {
        if !matches!(key.as_str(), "device" | "scale" | "cycle_cap_ms") {
            bail!("[[cluster.replica]]: unknown key '{key}' (device|scale|cycle_cap_ms)");
        }
    }
    let device = match table.get("device") {
        None => "standard".to_string(),
        Some(TomlValue::Str(s)) => s.clone(),
        Some(v) => bail!("[[cluster.replica]].device: expected string, got {v:?}"),
    };
    let mut profile = DeviceProfile::named(&device)?;
    match table.get("scale") {
        None => {}
        Some(TomlValue::Float(f)) if *f > 0.0 => {
            profile.latency = profile.latency.scaled(*f);
        }
        Some(TomlValue::Int(i)) if *i > 0 => {
            profile.latency = profile.latency.scaled(*i as f64);
        }
        Some(v) => bail!("[[cluster.replica]].scale: expected positive number, got {v:?}"),
    }
    match table.get("cycle_cap_ms") {
        None => profile.cycle_cap = default_cycle_cap,
        Some(TomlValue::Float(f)) if *f > 0.0 => {
            profile.cycle_cap = (*f * 1000.0) as Micros;
        }
        Some(TomlValue::Int(i)) if *i > 0 => {
            profile.cycle_cap = (*i as u64) * 1000;
        }
        Some(v) => {
            bail!("[[cluster.replica]].cycle_cap_ms: expected positive number, got {v:?}")
        }
    }
    Ok(profile)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.policy, PolicyKind::Slice);
        assert_eq!(c.cycle_cap, 1_000_000);
        assert_eq!(c.max_batch, 32);
        assert_eq!(c.cluster_replicas, 1);
        assert_eq!(c.cluster_strategy, RoutingStrategy::SloAware);
    }

    #[test]
    fn parses_cluster_section() {
        let text = "[cluster]\nreplicas = 4\nstrategy = \"least-loaded\"\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert_eq!(c.cluster_replicas, 4);
        assert_eq!(c.cluster_strategy, RoutingStrategy::LeastLoaded);
        assert!(c.cluster_fleet.is_none());
        assert!(!c.cluster_admission.enabled);
        assert!(!c.cluster_migration);
        assert_eq!(c.fleet().names(), vec!["standard"; 4]);
        assert!(ServeConfig::from_toml("[cluster]\nreplicas = 0\n").is_err());
        assert!(ServeConfig::from_toml("[cluster]\nstrategy = \"hash\"\n").is_err());
    }

    #[test]
    fn parses_fleet_preset_and_guards() {
        let text = "[cluster]\nfleet = \"edge-mixed\"\nadmission = true\n\
                    rt_queue_bound = 6\nnrt_queue_bound = 9\nmigration = true\n";
        let c = ServeConfig::from_toml(text).unwrap();
        let fleet = c.fleet();
        assert_eq!(fleet.names(), vec!["standard", "standard", "lite", "nano"]);
        assert_eq!(c.cluster_replicas, 4, "replica count follows the fleet");
        assert!(c.cluster_admission.enabled);
        assert_eq!(c.cluster_admission.rt_queue_bound, 6);
        assert_eq!(c.cluster_admission.nrt_queue_bound, 9);
        assert!(c.cluster_migration);
        assert!(ServeConfig::from_toml("[cluster]\nfleet = \"warp\"\n").is_err());
        assert!(ServeConfig::from_toml("[cluster]\nrt_queue_bound = 0\n").is_err());
    }

    #[test]
    fn bound_keys_imply_admission_unless_switched_off() {
        let c = ServeConfig::from_toml("[cluster]\nrt_queue_bound = 6\n").unwrap();
        assert!(c.cluster_admission.enabled, "a bound must never be a silent no-op");
        assert_eq!(c.cluster_admission.rt_queue_bound, 6);
        let c = ServeConfig::from_toml(
            "[cluster]\nadmission = false\nnrt_queue_bound = 4\n",
        )
        .unwrap();
        assert!(!c.cluster_admission.enabled, "explicit off wins");
        assert_eq!(c.cluster_admission.nrt_queue_bound, 4);
    }

    #[test]
    fn scheduler_cycle_cap_threads_into_fleets() {
        // preset fleets inherit the configured cap...
        let text = "[scheduler]\ncycle_cap_ms = 500.0\n[cluster]\nfleet = \"edge-mixed\"\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert!(c.fleet().profiles.iter().all(|p| p.cycle_cap == 500_000));
        // ...and so do replica tables without an explicit cycle_cap_ms,
        // while explicit per-replica caps take precedence
        let text = "[scheduler]\ncycle_cap_ms = 500.0\n\
                    [[cluster.replica]]\ndevice = \"standard\"\n\
                    [[cluster.replica]]\ndevice = \"lite\"\ncycle_cap_ms = 800.0\n";
        let c = ServeConfig::from_toml(text).unwrap();
        let fleet = c.fleet();
        assert_eq!(fleet.profiles[0].cycle_cap, 500_000);
        assert_eq!(fleet.profiles[1].cycle_cap, 800_000);
    }

    #[test]
    fn replicas_key_conflicts_with_explicit_fleet() {
        let text = "[cluster]\nreplicas = 8\nfleet = \"edge-mixed\"\n";
        assert!(ServeConfig::from_toml(text).is_err());
        let text = "[cluster]\nreplicas = 8\n[[cluster.replica]]\ndevice = \"nano\"\n";
        assert!(ServeConfig::from_toml(text).is_err());
    }

    #[test]
    fn parses_replica_table_array() {
        let text = r#"
[cluster]
strategy = "slo-aware"

[[cluster.replica]]
device = "standard"

[[cluster.replica]]
device = "lite"
cycle_cap_ms = 800.0

[[cluster.replica]]
device = "nano"
scale = 1.2
"#;
        let c = ServeConfig::from_toml(text).unwrap();
        let fleet = c.cluster_fleet.expect("fleet parsed");
        assert_eq!(fleet.names(), vec!["standard", "lite", "nano"]);
        assert_eq!(c.cluster_replicas, 3);
        assert_eq!(fleet.profiles[1].cycle_cap, 800_000);
        // nano rescaled by a further 1.2x on top of the tier curve
        let nano = crate::cluster::DeviceProfile::nano();
        assert_eq!(
            fleet.profiles[2].latency.decode(1),
            (nano.latency.decode(1) as f64 * 1.2).round() as Micros
        );
    }

    #[test]
    fn replica_table_rejects_bad_keys_and_fleet_conflict() {
        assert!(ServeConfig::from_toml("[[cluster.replica]]\ndevice = \"tpu\"\n").is_err());
        assert!(ServeConfig::from_toml("[[cluster.replica]]\ngpu = 2\n").is_err());
        assert!(ServeConfig::from_toml("[[cluster.replica]]\nscale = -1.0\n").is_err());
        let conflict = "[cluster]\nfleet = \"edge-mixed\"\n[[cluster.replica]]\n";
        assert!(ServeConfig::from_toml(conflict).is_err());
    }

    #[test]
    fn parses_memory_section() {
        let text = "[memory]\nkv_capacity_mb = 96.0\nkv_bytes_per_token = 16384\n\
                    block_tokens = 8\nswap_bandwidth_mbps = 2000.0\n\
                    handoff_bandwidth_mbps = 250.0\npreemption = \"recompute\"\n\
                    aware = false\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert_eq!(c.memory.kv_capacity, Some(96 * 1024 * 1024));
        assert_eq!(c.memory.bytes_per_token, 16384);
        assert_eq!(c.memory.block_tokens, 8);
        assert_eq!(c.memory.swap_bandwidth, 2_000_000_000);
        assert_eq!(c.memory.handoff_bandwidth, 250_000_000);
        assert_eq!(c.memory.mode, PreemptionMode::Recompute);
        assert!(!c.memory.aware);
        assert!(ServeConfig::from_toml("[memory]\nkv_capacity_mb = -1.0\n").is_err());
        assert!(ServeConfig::from_toml("[memory]\npreemption = \"drop\"\n").is_err());
        assert!(ServeConfig::from_toml("[memory]\nblock_tokens = 0\n").is_err());
    }

    #[test]
    fn parses_renamed_bandwidth_keys() {
        // current `*_mb_per_s` spellings land on the same fields...
        let text = "[memory]\nswap_bandwidth_mb_per_s = 2000.0\n\
                    handoff_bandwidth_mb_per_s = 250.0\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert_eq!(c.memory.swap_bandwidth, 2_000_000_000);
        assert_eq!(c.memory.handoff_bandwidth, 250_000_000);
        // ...naming both spellings of one key is a conflict, not a
        // silent precedence rule
        assert!(ServeConfig::from_toml(
            "[memory]\nswap_bandwidth_mb_per_s = 64.0\nswap_bandwidth_mbps = 64.0\n",
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[memory]\nswap_bandwidth_mb_per_s = -5.0\n",
        )
        .is_err());
    }

    #[test]
    fn parses_cluster_engine() {
        let c = ServeConfig::default();
        assert_eq!(c.cluster_engine, ClusterEngine::Lockstep);
        let c = ServeConfig::from_toml("[cluster]\nengine = \"event\"\n").unwrap();
        assert_eq!(c.cluster_engine, ClusterEngine::Event);
        let c = ServeConfig::from_toml("[cluster]\nengine = \"lockstep\"\n").unwrap();
        assert_eq!(c.cluster_engine, ClusterEngine::Lockstep);
        assert_eq!(ClusterEngine::parse("orchestrator").unwrap(), ClusterEngine::Event);
        assert_eq!(ClusterEngine::Event.label(), "event");
        assert!(ServeConfig::from_toml("[cluster]\nengine = \"warp\"\n").is_err());
    }

    #[test]
    fn parses_cluster_threads() {
        let c = ServeConfig::default();
        assert_eq!(c.cluster_threads, 1, "sequential engine by default");
        let c = ServeConfig::from_toml("[cluster]\nengine = \"event\"\nthreads = 8\n")
            .unwrap();
        assert_eq!(c.cluster_threads, 8);
        // naming the knob implies the engine that can honor it — a
        // configured knob is never a silent no-op
        let c = ServeConfig::from_toml("[cluster]\nthreads = 4\n").unwrap();
        assert_eq!(c.cluster_threads, 4);
        assert_eq!(c.cluster_engine, ClusterEngine::Event);
        assert!(ServeConfig::from_toml(
            "[cluster]\nengine = \"lockstep\"\nthreads = 4\n",
        )
        .is_err());
        // threads = 1 is the sequential default and honors any engine
        let c = ServeConfig::from_toml(
            "[cluster]\nengine = \"lockstep\"\nthreads = 1\n",
        )
        .unwrap();
        assert_eq!(c.cluster_engine, ClusterEngine::Lockstep);
        assert!(ServeConfig::from_toml("[cluster]\nthreads = 0\n").is_err());
        assert!(ServeConfig::from_toml("[cluster]\nthreads = -2\n").is_err());
    }

    #[test]
    fn memory_defaults_are_unconstrained() {
        let c = ServeConfig::default();
        assert!(c.memory.kv_capacity.is_none());
        assert!(!c.memory.constrained());
        assert!(c.memory.aware);
        assert!(!c.cluster_migrate_running);
    }

    #[test]
    fn parses_admission_mode_and_migrate_running() {
        let c = ServeConfig::from_toml("[cluster]\nadmission_mode = \"headroom\"\n")
            .unwrap();
        assert!(c.cluster_admission.enabled, "naming a mode opts in");
        assert_eq!(c.cluster_admission.mode, AdmissionMode::Headroom);
        let c = ServeConfig::from_toml(
            "[cluster]\nadmission = false\nadmission_mode = \"headroom\"\n",
        )
        .unwrap();
        assert!(!c.cluster_admission.enabled, "explicit off wins");
        assert!(
            ServeConfig::from_toml("[cluster]\nadmission_mode = \"magic\"\n").is_err()
        );
        // depth bounds are meaningless under headroom admission: reject
        // rather than silently ignore a configured bound
        assert!(ServeConfig::from_toml(
            "[cluster]\nadmission_mode = \"headroom\"\nrt_queue_bound = 4\n",
        )
        .is_err());

        let c = ServeConfig::from_toml("[cluster]\nmigrate_running = true\n").unwrap();
        assert!(c.cluster_migrate_running);
        assert!(c.cluster_migration, "running handoff implies migration");
        // the implication is unconditional — identical to the CLI rule,
        // so the two config surfaces never disagree
        let c = ServeConfig::from_toml(
            "[cluster]\nmigration = false\nmigrate_running = true\n",
        )
        .unwrap();
        assert!(c.cluster_migration, "migrate_running always enables the pass");
    }

    #[test]
    fn parses_lifecycle_section_and_implies_event_engine() {
        let text = r#"
[cluster]
replicas = 4

[cluster.lifecycle]
crash_at_s = [40.0, 80]
join_at_s = [60.0]
churn_rate = 0.1
seed = 9
min_replicas = 2
max_replicas = 16
"#;
        let c = ServeConfig::from_toml(text).unwrap();
        let lc = &c.lifecycle;
        assert_eq!(lc.events.len(), 3);
        assert!(lc.events.windows(2).all(|w| w[0].time <= w[1].time));
        assert_eq!(lc.events[0].time, secs(40.0));
        assert_eq!(lc.events[0].action, LifecycleAction::Crash);
        assert_eq!(lc.events[1].action, LifecycleAction::Join);
        assert_eq!(lc.events[2].time, secs(80.0), "integer seconds widen");
        assert!(lc.events.iter().all(|e| e.target.is_none()));
        assert_eq!(lc.churn_rate, 0.1);
        assert_eq!(lc.seed, 9);
        assert_eq!((lc.min_replicas, lc.max_replicas), (2, 16));
        assert!(lc.has_events() && lc.any_enabled());
        assert_eq!(
            c.cluster_engine,
            ClusterEngine::Event,
            "elastic implies the event engine"
        );
        // an explicit lockstep engine conflicts with elastic features
        assert!(ServeConfig::from_toml(
            "[cluster]\nengine = \"lockstep\"\n[cluster.lifecycle]\nchurn_rate = 0.1\n",
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.lifecycle]\nchurn_rate = -0.5\n",
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.lifecycle]\nmin_replicas = 8\nmax_replicas = 2\n",
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.lifecycle]\ncrash_at_s = 40.0\n",
        )
        .is_err(), "scalar where an array is expected");
    }

    #[test]
    fn autoscaler_and_health_knobs_imply_enabled() {
        let text = "[cluster.autoscaler]\ndeficit_streak = 3\ncooldown_s = 1.0\n\
                    boot_delay_s = 2.5\n\
                    [cluster.health]\nalpha = 0.5\nlag_threshold_ms = 250.0\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert!(c.lifecycle.autoscaler.enabled, "a knob is never a silent no-op");
        assert_eq!(c.lifecycle.autoscaler.deficit_streak, 3);
        assert_eq!(c.lifecycle.autoscaler.cooldown, secs(1.0));
        assert_eq!(c.lifecycle.autoscaler.boot_delay, secs(2.5));
        assert!(ServeConfig::from_toml(
            "[cluster.autoscaler]\nboot_delay_s = -1.0\n",
        )
        .is_err());
        assert!(c.lifecycle.health.enabled);
        assert_eq!(c.lifecycle.health.alpha, 0.5);
        assert_eq!(c.lifecycle.health.lag_threshold, 250_000);
        assert_eq!(c.cluster_engine, ClusterEngine::Event);
        // explicit off wins over named knobs
        let c = ServeConfig::from_toml(
            "[cluster.autoscaler]\nenabled = false\nidle_streak = 8\n",
        )
        .unwrap();
        assert!(!c.lifecycle.autoscaler.enabled, "explicit off wins");
        assert_eq!(c.lifecycle.autoscaler.idle_streak, 8);
        assert_eq!(
            c.cluster_engine,
            ClusterEngine::Lockstep,
            "nothing enabled: engine stays the default"
        );
        assert!(ServeConfig::from_toml("[cluster.health]\nalpha = 1.5\n").is_err());
        assert!(
            ServeConfig::from_toml("[cluster.autoscaler]\nidle_streak = 0\n").is_err()
        );
    }

    #[test]
    fn parses_autoscaler_headroom_mode() {
        let c = ServeConfig::default();
        assert!(!c.lifecycle.autoscaler.grow_on_headroom, "deficit mode by default");
        assert_eq!(c.lifecycle.autoscaler.headroom_min, 0);
        let c = ServeConfig::from_toml(
            "[cluster.autoscaler]\ngrow_on_headroom = true\nheadroom_min_ms = 50.0\n",
        )
        .unwrap();
        assert!(c.lifecycle.autoscaler.enabled, "a knob is never a silent no-op");
        assert!(c.lifecycle.autoscaler.grow_on_headroom);
        assert_eq!(c.lifecycle.autoscaler.headroom_min, 50_000);
        assert_eq!(c.cluster_engine, ClusterEngine::Event);
        // naming the floor alone opts the mode (and the autoscaler) in
        let c = ServeConfig::from_toml(
            "[cluster.autoscaler]\nheadroom_min_ms = 25.0\n",
        )
        .unwrap();
        assert!(c.lifecycle.autoscaler.enabled && c.lifecycle.autoscaler.grow_on_headroom);
        assert_eq!(c.lifecycle.autoscaler.headroom_min, 25_000);
        // a floor under an explicit grow_on_headroom = false would be a
        // silent no-op: reject the contradiction
        assert!(ServeConfig::from_toml(
            "[cluster.autoscaler]\ngrow_on_headroom = false\nheadroom_min_ms = 50.0\n",
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.autoscaler]\nheadroom_min_ms = -1.0\n",
        )
        .is_err());
        // explicit off still wins over the mode knob
        let c = ServeConfig::from_toml(
            "[cluster.autoscaler]\nenabled = false\ngrow_on_headroom = true\n",
        )
        .unwrap();
        assert!(!c.lifecycle.autoscaler.enabled && c.lifecycle.autoscaler.grow_on_headroom);
    }

    #[test]
    fn lifecycle_defaults_are_static() {
        let c = ServeConfig::default();
        assert!(!c.lifecycle.any_enabled());
        assert!(c.lifecycle.events.is_empty());
        assert_eq!(c.lifecycle.churn_rate, 0.0);
        assert!(!c.lifecycle.autoscaler.enabled && !c.lifecycle.health.enabled);
        assert!(!c.lifecycle.detector.enabled);
    }

    #[test]
    fn detector_knobs_imply_enabled() {
        let text = "[cluster.detector]\nheartbeat_interval_s = 0.25\n\
                    suspicion_timeout_s = 1.5\nmax_retries = 5\n\
                    retry_backoff_s = 0.5\n";
        let c = ServeConfig::from_toml(text).unwrap();
        assert!(c.lifecycle.detector.enabled, "a knob is never a silent no-op");
        assert_eq!(c.lifecycle.detector.heartbeat_interval, secs(0.25));
        assert_eq!(c.lifecycle.detector.suspicion_timeout, secs(1.5));
        assert_eq!(c.lifecycle.detector.max_retries, 5);
        assert_eq!(c.lifecycle.detector.retry_backoff, secs(0.5));
        assert!(c.lifecycle.detector.active());
        assert_eq!(c.cluster_engine, ClusterEngine::Event);
        // explicit off wins over named knobs
        let c = ServeConfig::from_toml(
            "[cluster.detector]\nenabled = false\nmax_retries = 7\n",
        )
        .unwrap();
        assert!(!c.lifecycle.detector.enabled, "explicit off wins");
        assert_eq!(c.lifecycle.detector.max_retries, 7);
        assert_eq!(c.cluster_engine, ClusterEngine::Lockstep);
        // timeout 0 is legal — enabled-but-inert oracle detection
        let c = ServeConfig::from_toml(
            "[cluster.detector]\nsuspicion_timeout_s = 0.0\n",
        )
        .unwrap();
        assert!(c.lifecycle.detector.enabled && !c.lifecycle.detector.active());
        assert_eq!(
            c.cluster_engine,
            ClusterEngine::Event,
            "enabled (even inert) still rides the event heap config path"
        );
    }

    #[test]
    fn fuzzed_configs_never_panic() {
        use crate::util::rng::Rng;
        // seeded fuzz-lite over the TOML surface: random structural
        // mutations of a valid document — truncations, byte splices,
        // fragment shuffles, value swaps — must parse or error
        // gracefully, never panic. 500 mutants per seed keeps the test
        // under a second while covering every section the parser owns.
        let base = "[cluster]\nreplicas = 4\nengine = \"event\"\nthreads = 2\n\
                    [cluster.lifecycle]\nchurn_rate = 0.5\nseed = 7\n\
                    min_replicas = 1\nmax_replicas = 8\n\
                    [cluster.autoscaler]\ndeficit_streak = 3\ncooldown_s = 1.0\n\
                    [cluster.health]\nalpha = 0.4\nlag_threshold_ms = 250.0\n\
                    [cluster.detector]\nheartbeat_interval_s = 0.25\n\
                    suspicion_timeout_s = 1.0\nmax_retries = 3\nretry_backoff_s = 0.5\n\
                    [memory]\nkv_capacity_mb = 512.0\nblock_tokens = 16\n\
                    preemption = \"swap\"\n";
        let splices = [
            "= -1", "= 0", "= 1e309", "= \"\"", "= true", "= [1, 2",
            "[[cluster.replica]]", "enabled", "= nan", "\"unterminated",
            "suspicion_timeout_s = -3.0", "max_retries = 9999999999999",
            "[cluster.detector]", "#", "=", "\n\n[", "]\n",
        ];
        let mut rng = Rng::new(0x51CE_FA11);
        for _ in 0..500 {
            let mut doc = String::from(base);
            match rng.range_usize(0, 3) {
                0 => {
                    // truncate at a random byte (char-boundary safe:
                    // the base document is pure ASCII)
                    doc.truncate(rng.range_usize(0, doc.len()));
                }
                1 => {
                    // splice a hostile fragment at a random line break
                    let lines: Vec<&str> = base.lines().collect();
                    let at = rng.range_usize(0, lines.len() - 1);
                    let frag = splices[rng.range_usize(0, splices.len() - 1)];
                    let mut out = String::new();
                    for (i, line) in lines.iter().enumerate() {
                        out.push_str(line);
                        out.push('\n');
                        if i == at {
                            out.push_str(frag);
                            out.push('\n');
                        }
                    }
                    doc = out;
                }
                2 => {
                    // delete a random line (orphans section headers and
                    // breaks key/value pairing)
                    let lines: Vec<&str> = base.lines().collect();
                    let drop = rng.range_usize(0, lines.len() - 1);
                    doc = lines
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| i != drop)
                        .map(|(_, l)| format!("{l}\n"))
                        .collect();
                }
                _ => {
                    // swap two random lines (values land under the
                    // wrong section headers)
                    let mut lines: Vec<&str> = base.lines().collect();
                    let a = rng.range_usize(0, lines.len() - 1);
                    let b = rng.range_usize(0, lines.len() - 1);
                    lines.swap(a, b);
                    doc = lines.iter().map(|l| format!("{l}\n")).collect();
                }
            }
            // parse-or-error is the whole assertion: a panic here (or
            // an abort on overflow) fails the test
            let _ = ServeConfig::from_toml(&doc);
        }
    }

    #[test]
    fn detector_validation_bails() {
        assert!(ServeConfig::from_toml(
            "[cluster.detector]\nheartbeat_interval_s = 0.0\n"
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.detector]\nheartbeat_interval_s = -1.0\n"
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.detector]\nsuspicion_timeout_s = -0.5\n"
        )
        .is_err());
        assert!(ServeConfig::from_toml("[cluster.detector]\nmax_retries = -1\n").is_err());
        assert!(ServeConfig::from_toml(
            "[cluster.detector]\nretry_backoff_s = -2.0\n"
        )
        .is_err());
        assert!(ServeConfig::from_toml(
            "[cluster]\nengine = \"lockstep\"\n\n\
             [cluster.detector]\nsuspicion_timeout_s = 2.0\n"
        )
        .is_err(), "an active detector cannot run on the lockstep engine");
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
# SLICE serving config
[scheduler]
policy = "orca"
cycle_cap_ms = 800.0
max_batch = 16
adaptor = "sjf"

[fastserve]
levels = 4
base_quantum = 4

[engine]
backend = "pjrt"
artifacts = "artifacts"

[workload]
arrival_rate = 2.5
rt_ratio = 0.5
n_tasks = 1000
seed = 7
horizon_s = 120.0
"#;
        let c = ServeConfig::from_toml(text).unwrap();
        assert_eq!(c.policy, PolicyKind::Orca);
        assert_eq!(c.cycle_cap, 800_000);
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.adaptor, UtilityAdaptor::SjfDecay { factor: 0.5, tau: 32 });
        assert_eq!(c.fastserve.levels, 4);
        assert_eq!(c.fastserve.base_quantum, 4);
        assert_eq!(c.engine, EngineKind::Pjrt(PathBuf::from("artifacts")));
        assert_eq!(c.arrival_rate, 2.5);
        assert_eq!(c.n_tasks, 1000);
        assert_eq!(c.seed, 7);
        assert_eq!(c.horizon, 120_000_000);
    }

    #[test]
    fn rejects_unknown_policy() {
        assert!(ServeConfig::from_toml("[scheduler]\npolicy = \"lifo\"\n").is_err());
    }

    #[test]
    fn policy_kind_parse() {
        assert_eq!(PolicyKind::parse("SLICE").unwrap(), PolicyKind::Slice);
        assert_eq!(PolicyKind::parse("fastserve").unwrap(), PolicyKind::FastServe);
        assert!(PolicyKind::parse("bogus").is_err());
    }
}
