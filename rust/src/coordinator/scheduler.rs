//! The scheduling-policy interface shared by SLICE and the baselines.
//!
//! The serving loop (`server::Server`) is policy-agnostic: it delivers
//! arrival/completion events and repeatedly asks the policy for the next
//! engine step. All three strategies (SLICE, Orca, FastServe) implement
//! [`Policy`], so every experiment compares them under an identical
//! engine, workload and measurement pipeline — the comparison the paper
//! makes on top of FastLLM.

use crate::util::Micros;

use super::pool::TaskPool;
use super::task::TaskId;

/// One unit of work the policy asks the engine to perform.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Step {
    /// Run the prompt phase for one task (produces its first token).
    Prefill { task: TaskId },
    /// Run one decode iteration for a batch (one token per listed task).
    Decode { tasks: Vec<TaskId> },
    /// Nothing runnable; the server advances time to the next arrival.
    Idle,
}

impl Step {
    /// Number of tasks the engine touches in this step.
    pub fn batch_size(&self) -> usize {
        match self {
            Step::Prefill { .. } => 1,
            Step::Decode { tasks } => tasks.len(),
            Step::Idle => 0,
        }
    }
}

/// A scheduling policy: SLICE or one of the baselines.
///
/// `Send` is part of the contract: the cluster layer's parallel event
/// engine advances whole replicas — server, policy, engine — on worker
/// threads inside an epoch (DESIGN.md "Parallel event engine"), so a
/// policy may not hold thread-pinned state (`Rc`, raw pointers).
pub trait Policy: Send {
    /// Display name used in reports ("SLICE", "Orca", "FastServe").
    fn name(&self) -> &'static str;

    /// New tasks entered the pool (state Waiting).
    fn on_arrival(&mut self, pool: &mut TaskPool, ids: &[TaskId], now: Micros);

    /// Tasks finished during the last step and were removed from service.
    fn on_completion(&mut self, pool: &mut TaskPool, ids: &[TaskId], now: Micros);

    /// Decide the next step. Must not return `Decode` with an empty list.
    fn next_step(&mut self, pool: &mut TaskPool, now: Micros) -> Step;

    /// The serving loop hands the decode-batch buffer back after the
    /// engine has consumed it, so a policy can reuse the allocation for
    /// its next [`Step::Decode`] — the steady-state decode scan then
    /// performs zero heap allocation (DESIGN.md "Scheduler hot path").
    /// Default: drop the buffer (baselines that build batches their own
    /// way lose nothing).
    fn recycle_batch(&mut self, _batch: Vec<TaskId>) {}

    /// Scheduling decisions taken so far — full Alg. 4 reschedules for
    /// SLICE, zero for policies that don't count (observability for the
    /// scale sweep; lands in `server::RunReport::decisions`).
    fn decisions(&self) -> u64 {
        0
    }

    /// Reschedules the policy proved unnecessary and skipped outright
    /// (DESIGN.md "Control-plane incrementality"): for SLICE, arrival
    /// boundaries whose new tasks provably cannot alter the admitted
    /// set. Zero for policies without a skip path. The accounting
    /// invariant `decisions + decisions_skipped` equals the decision
    /// count of a skip-disabled run is pinned by the equivalence suite;
    /// lands in `server::RunReport::decisions_skipped`.
    fn decisions_skipped(&self) -> u64 {
        0
    }
}
