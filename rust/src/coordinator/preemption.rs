//! Preemption controller: dynamic utility adaptation (paper §IV-E).
//!
//! After every scheduling round the online SLICE algorithm may adjust the
//! utility of in-flight tasks (Alg. 4, line 17, `UTILITYADAPTOR`) to
//! customize preemption behaviour:
//!   * decaying the utility of tasks that have already generated many
//!     tokens mimics Shortest-Job-First and avoids head-of-line blocking;
//!   * boosting currently-running tasks makes scheduling sticky and
//!     prevents mid-stream preemption;
//!   * charging an eviction penalty to tasks whose KV cache was swapped
//!     out keeps selection honest about the restore cost a resume pays
//!     under a finite memory capacity (DESIGN.md "Memory model").

use super::task::{Residency, Task, TaskState};

/// Pluggable utility-adaptation strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum UtilityAdaptor {
    /// Use base utilities unchanged.
    None,
    /// SJF-mimicking decay: U' = U * factor^(tokens_generated / tau).
    /// factor in (0,1); tau is the token scale of the decay.
    SjfDecay { factor: f64, tau: u32 },
    /// Anti-preemption: running/paused tasks get U' = U * multiplier.
    StickyBoost { multiplier: f64 },
    /// Memory-aware: tasks whose KV cache is swapped out get
    /// U' = U * factor (factor in (0,1]) — re-admitting them costs a
    /// swap-in/recompute transition the schedule must pay for, so
    /// selection slightly prefers resident work of equal utility rate.
    EvictionPenalty { factor: f64 },
}

impl UtilityAdaptor {
    /// The adapted utility for `task` given its current progress/state.
    pub fn effective(&self, task: &Task) -> f64 {
        match *self {
            UtilityAdaptor::None => task.utility,
            UtilityAdaptor::SjfDecay { factor, tau } => {
                let exp = task.tokens_generated as f64 / tau.max(1) as f64;
                task.utility * factor.powf(exp)
            }
            UtilityAdaptor::StickyBoost { multiplier } => {
                if matches!(task.state, TaskState::Running | TaskState::Paused) {
                    task.utility * multiplier
                } else {
                    task.utility
                }
            }
            UtilityAdaptor::EvictionPenalty { factor } => {
                if task.residency == Residency::Swapped {
                    task.utility * factor
                } else {
                    task.utility
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};

    fn task_with_tokens(tokens: u32) -> Task {
        let mut t = Task::new(0, TaskClass::Voice, 0, 8, 100, 10.0);
        t.tokens_generated = tokens;
        t
    }

    #[test]
    fn none_is_identity() {
        let t = task_with_tokens(50);
        assert_eq!(UtilityAdaptor::None.effective(&t), 10.0);
    }

    #[test]
    fn sjf_decay_monotone_in_tokens() {
        let a = UtilityAdaptor::SjfDecay { factor: 0.5, tau: 16 };
        let fresh = task_with_tokens(0);
        let old = task_with_tokens(32);
        assert_eq!(a.effective(&fresh), 10.0);
        assert!((a.effective(&old) - 2.5).abs() < 1e-12); // 10 * 0.5^2
        assert!(a.effective(&old) < a.effective(&fresh));
    }

    #[test]
    fn eviction_penalty_discounts_swapped_tasks_only() {
        let a = UtilityAdaptor::EvictionPenalty { factor: 0.8 };
        let resident = {
            let mut t = task_with_tokens(10);
            t.residency = crate::coordinator::task::Residency::Resident;
            t
        };
        let swapped = {
            let mut t = task_with_tokens(10);
            t.residency = crate::coordinator::task::Residency::Swapped;
            t
        };
        assert_eq!(a.effective(&resident), 10.0);
        assert!((a.effective(&swapped) - 8.0).abs() < 1e-12);
        // tasks with no KV yet are untouched
        assert_eq!(a.effective(&task_with_tokens(0)), 10.0);
    }

    #[test]
    fn sticky_boost_only_for_in_service_tasks() {
        let a = UtilityAdaptor::StickyBoost { multiplier: 3.0 };
        let waiting = task_with_tokens(0);
        assert_eq!(a.effective(&waiting), 10.0);
        let mut running = task_with_tokens(0);
        running.state = TaskState::Running;
        assert_eq!(a.effective(&running), 30.0);
        let mut paused = task_with_tokens(0);
        paused.state = TaskState::Paused;
        assert_eq!(a.effective(&paused), 30.0);
    }
}
