//! Decode-mask matrix (paper §IV-D, Algorithm 3, Fig. 4).
//!
//! The rate allocator gives every scheduled task an individual token
//! generation rate by building a binary matrix: one row per task (sorted
//! by per-cycle token quota v_i, descending), v_0 columns (the largest
//! quota). Row i has its first v_i entries set. Execution scans columns
//! left to right; the tasks whose bit is set in the current column form
//! the decode batch for one forward pass. A full sweep of the columns is
//! one *scheduling cycle* and gives task i exactly v_i tokens.
//!
//! Because rows are sorted descending, the set of tasks in column j is
//! always a **prefix** of the task list (those with v_i > j). The hot
//! path therefore never materializes the matrix: [`DecodeMask::batch_len`]
//! is a prefix length computed once per column. The explicit bit matrix
//! is retained for tests, ablation and debugging (`as_bit_matrix`).

use crate::engine::latency::LatencyModel;
use crate::util::Micros;

use super::task::TaskId;

/// A built decode-mask matrix over a selected batch of tasks.
#[derive(Debug, Clone)]
pub struct DecodeMask {
    /// (task, per-cycle quota v_i), sorted by v_i descending.
    rows: Vec<(TaskId, u32)>,
    /// Number of columns = v_0 (quota of the most demanding task).
    columns: u32,
    /// Per-column batch length: batch_lens[j] = |{i : v_i > j}|.
    batch_lens: Vec<u32>,
}

impl DecodeMask {
    /// Build the matrix from (task, required tokens/cycle) pairs.
    /// Tasks with v = 0 are rejected (every scheduled task must make
    /// progress each cycle — Eq. 3/4).
    pub fn build(mut tasks: Vec<(TaskId, u32)>) -> Self {
        assert!(tasks.iter().all(|&(_, v)| v > 0), "zero-rate task in mask");
        // stable ordering: quota desc, id asc for determinism
        tasks.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let columns = tasks.first().map_or(0, |&(_, v)| v);
        let mut batch_lens = Vec::with_capacity(columns as usize);
        for j in 0..columns {
            // rows sorted desc -> prefix property
            let n = tasks.partition_point(|&(_, v)| v > j);
            batch_lens.push(n as u32);
        }
        DecodeMask { rows: tasks, columns, batch_lens }
    }

    /// True when no tasks are scheduled.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Number of scheduled tasks (rows).
    pub fn n_tasks(&self) -> usize {
        self.rows.len()
    }

    /// Number of columns (= the largest per-cycle quota).
    pub fn columns(&self) -> u32 {
        self.columns
    }

    /// Tasks participating in column `j` (a prefix of the sorted rows).
    pub fn column_batch(&self, j: u32) -> &[(TaskId, u32)] {
        let n = self.batch_len(j) as usize;
        &self.rows[..n]
    }

    /// Number of tasks decoding in column `j`.
    pub fn batch_len(&self, j: u32) -> u32 {
        if j >= self.columns {
            0
        } else {
            self.batch_lens[j as usize]
        }
    }

    /// All rows (task, quota), sorted by quota descending.
    pub fn rows(&self) -> &[(TaskId, u32)] {
        &self.rows
    }

    /// Total tokens generated per full cycle (= sum of quotas = sum of
    /// column batch sizes).
    pub fn tokens_per_cycle(&self) -> u64 {
        self.rows.iter().map(|&(_, v)| v as u64).sum()
    }

    /// Exact cycle duration: sum of l(batch) over all columns.
    pub fn period_exact(&self, l: &LatencyModel) -> Micros {
        (0..self.columns)
            .map(|j| l.decode(self.batch_len(j)))
            .sum()
    }

    /// Explicit 0/1 matrix (tests / visualization only).
    pub fn as_bit_matrix(&self) -> Vec<Vec<u8>> {
        self.rows
            .iter()
            .map(|&(_, v)| {
                (0..self.columns).map(|j| u8::from(j < v)).collect()
            })
            .collect()
    }
}

/// Closed-form cycle estimate, Eq. (7) of the paper:
///
///   T_period = v_b * l(b+1) + sum_{j=0}^{b-1} (v_j - v_{j+1}) * l(j+1)
///
/// where `vs` are per-cycle quotas sorted descending over b+1 tasks.
/// Equivalent to summing l(batch) over the mask's columns (tested against
/// [`DecodeMask::period_exact`]).
pub fn period_eq7(vs_sorted_desc: &[u32], l: &LatencyModel) -> Micros {
    let n = vs_sorted_desc.len();
    if n == 0 {
        return 0;
    }
    debug_assert!(vs_sorted_desc.windows(2).all(|w| w[0] >= w[1]));
    let vb = vs_sorted_desc[n - 1];
    let mut t = vb as u64 * l.decode(n as u32);
    for j in 0..n - 1 {
        let dv = (vs_sorted_desc[j] - vs_sorted_desc[j + 1]) as u64;
        t += dv * l.decode(j as u32 + 1);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ms;

    fn model() -> LatencyModel {
        LatencyModel::paper_calibrated()
    }

    /// The paper's Fig. 4 worked example: quotas 6/4/2/1.
    #[test]
    fn fig4_example_matrix() {
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(m.columns(), 6);
        assert_eq!(m.n_tasks(), 4);
        let bits = m.as_bit_matrix();
        assert_eq!(bits[0], vec![1, 1, 1, 1, 1, 1]);
        assert_eq!(bits[1], vec![1, 1, 1, 1, 0, 0]);
        assert_eq!(bits[2], vec![1, 1, 0, 0, 0, 0]);
        assert_eq!(bits[3], vec![1, 0, 0, 0, 0, 0]);
        // column batches: col0 -> 4 tasks, col1 -> 3, col2..3 -> 2, col4..5 -> 1
        assert_eq!(
            (0..6).map(|j| m.batch_len(j)).collect::<Vec<_>>(),
            vec![4, 3, 2, 2, 1, 1]
        );
        // scanning column 2 groups task0 and task1 (paper's example)
        let col2: Vec<TaskId> = m.column_batch(2).iter().map(|&(id, _)| id).collect();
        assert_eq!(col2, vec![0, 1]);
    }

    #[test]
    fn tokens_per_cycle_equals_quota_sum() {
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(m.tokens_per_cycle(), 13);
        let col_sum: u64 = (0..m.columns()).map(|j| m.batch_len(j) as u64).sum();
        assert_eq!(col_sum, 13);
    }

    #[test]
    fn eq7_matches_column_sum_fig4() {
        let l = model();
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(m.period_exact(&l), period_eq7(&[6, 4, 2, 1], &l));
        // manual expansion: l(4) + l(3) + 2*l(2) + 2*l(1)
        let manual = l.decode(4) + l.decode(3) + 2 * l.decode(2) + 2 * l.decode(1);
        assert_eq!(m.period_exact(&l), manual);
    }

    #[test]
    fn eq7_matches_column_sum_randomized() {
        let l = model();
        let mut rng = crate::util::rng::Rng::new(2024);
        for _ in 0..200 {
            let n = rng.range_usize(1, 12);
            let mut vs: Vec<u32> =
                (0..n).map(|_| rng.range_u64(1, 30) as u32).collect();
            vs.sort_unstable_by(|a, b| b.cmp(a));
            let tasks: Vec<(TaskId, u32)> =
                vs.iter().enumerate().map(|(i, &v)| (i as u64, v)).collect();
            let m = DecodeMask::build(tasks);
            assert_eq!(m.period_exact(&l), period_eq7(&vs, &l), "vs={vs:?}");
        }
    }

    #[test]
    fn equal_quotas_single_batch() {
        let l = model();
        let m = DecodeMask::build(vec![(0, 5), (1, 5), (2, 5)]);
        assert_eq!(m.columns(), 5);
        for j in 0..5 {
            assert_eq!(m.batch_len(j), 3);
        }
        assert_eq!(m.period_exact(&l), 5 * l.decode(3));
    }

    #[test]
    fn table2_period_under_cycle_cap() {
        // Table II: quotas ceil(1/TPOT) = A:10 x3, B:ceil(8.33)=9 x4, C:4 x2
        let l = model();
        let vs = [10, 10, 10, 9, 9, 9, 9, 4, 4];
        let period = period_eq7(&vs, &l);
        assert!(
            period < ms(1000.0),
            "paper's 9-task static mix must be admissible, period={period}"
        );
    }

    #[test]
    fn single_task_mask() {
        let l = model();
        let m = DecodeMask::build(vec![(7, 3)]);
        assert_eq!(m.columns(), 3);
        assert_eq!(m.batch_len(0), 1);
        assert_eq!(m.period_exact(&l), 3 * l.decode(1));
        assert_eq!(m.column_batch(0), &[(7, 3)]);
    }

    #[test]
    fn column_batches_are_prefixes_of_sorted_rows() {
        let m = DecodeMask::build(vec![(5, 2), (9, 7), (1, 7), (3, 4)]);
        // sorted: (1,7), (9,7), (3,4), (5,2) — ties broken by id
        let ids: Vec<TaskId> = m.rows().iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![1, 9, 3, 5]);
        for j in 0..m.columns() {
            let batch = m.column_batch(j);
            assert_eq!(batch, &m.rows()[..batch.len()]);
            // monotone: batch sizes never grow as j increases
            if j > 0 {
                assert!(m.batch_len(j) <= m.batch_len(j - 1));
            }
        }
    }

    #[test]
    #[should_panic]
    fn zero_quota_rejected() {
        let _ = DecodeMask::build(vec![(0, 0)]);
    }

    #[test]
    fn empty_mask() {
        let m = DecodeMask::build(vec![]);
        assert!(m.is_empty());
        assert_eq!(m.columns(), 0);
        assert_eq!(m.batch_len(0), 0);
        assert_eq!(m.period_exact(&model()), 0);
    }
}
