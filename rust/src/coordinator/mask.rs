//! Decode-mask matrix (paper §IV-D, Algorithm 3, Fig. 4).
//!
//! The rate allocator gives every scheduled task an individual token
//! generation rate by building a binary matrix: one row per task (sorted
//! by per-cycle token quota v_i, descending), v_0 columns (the largest
//! quota). Row i has its first v_i entries set. Execution scans columns
//! left to right; the tasks whose bit is set in the current column form
//! the decode batch for one forward pass. A full sweep of the columns is
//! one *scheduling cycle* and gives task i exactly v_i tokens.
//!
//! Because rows are sorted descending, the set of tasks in column j is
//! always a **prefix** of the task list (those with v_i > j). The hot
//! path therefore never materializes the matrix: [`DecodeMask::batch_len`]
//! is a prefix length computed once per column. The explicit bit matrix
//! is retained for tests, ablation and debugging (`as_bit_matrix`).

use crate::engine::latency::LatencyModel;
use crate::util::Micros;

use super::task::TaskId;

/// A built decode-mask matrix over a selected batch of tasks.
#[derive(Debug, Clone)]
pub struct DecodeMask {
    /// (task, per-cycle quota v_i), sorted by v_i descending.
    rows: Vec<(TaskId, u32)>,
    /// Number of columns = v_0 (quota of the most demanding task).
    columns: u32,
    /// Per-column batch length: batch_lens[j] = |{i : v_i > j}|.
    batch_lens: Vec<u32>,
}

impl DecodeMask {
    /// Build the matrix from (task, required tokens/cycle) pairs.
    /// Tasks with v = 0 are rejected (every scheduled task must make
    /// progress each cycle — Eq. 3/4).
    pub fn build(tasks: Vec<(TaskId, u32)>) -> Self {
        let mut mask = DecodeMask { rows: tasks, columns: 0, batch_lens: Vec::new() };
        mask.finish_build();
        mask
    }

    /// An empty mask (no scheduled tasks, zero columns). Useful as the
    /// initial state of a mask that is [`DecodeMask::rebuild`]-ed in
    /// place on every reschedule.
    pub fn empty() -> Self {
        DecodeMask { rows: Vec::new(), columns: 0, batch_lens: Vec::new() }
    }

    /// Rebuild the matrix in place from a fresh admitted set, reusing
    /// the row/column buffers (the Alg. 4 reschedule hot path performs
    /// zero steady-state heap allocation). Produces exactly the matrix
    /// [`DecodeMask::build`] would.
    pub fn rebuild(&mut self, tasks: &[(TaskId, u32)]) {
        self.rows.clear();
        self.rows.extend_from_slice(tasks);
        self.finish_build();
    }

    /// Reset to the empty mask, keeping buffers.
    pub fn clear(&mut self) {
        self.rows.clear();
        self.columns = 0;
        self.batch_lens.clear();
    }

    /// Shared tail of [`DecodeMask::build`] / [`DecodeMask::rebuild`]:
    /// sort rows and recompute the per-column prefix lengths.
    fn finish_build(&mut self) {
        assert!(self.rows.iter().all(|&(_, v)| v > 0), "zero-rate task in mask");
        // stable ordering: quota desc, id asc for determinism
        self.rows.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        self.columns = self.rows.first().map_or(0, |&(_, v)| v);
        self.batch_lens.clear();
        self.batch_lens.reserve(self.columns as usize);
        for j in 0..self.columns {
            // rows sorted desc -> prefix property
            let n = self.rows.partition_point(|&(_, v)| v > j);
            self.batch_lens.push(n as u32);
        }
    }

    /// True when no tasks are scheduled.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Number of scheduled tasks (rows).
    pub fn n_tasks(&self) -> usize {
        self.rows.len()
    }

    /// Number of columns (= the largest per-cycle quota).
    pub fn columns(&self) -> u32 {
        self.columns
    }

    /// Tasks participating in column `j` (a prefix of the sorted rows).
    pub fn column_batch(&self, j: u32) -> &[(TaskId, u32)] {
        let n = self.batch_len(j) as usize;
        &self.rows[..n]
    }

    /// Number of tasks decoding in column `j`.
    pub fn batch_len(&self, j: u32) -> u32 {
        if j >= self.columns {
            0
        } else {
            self.batch_lens[j as usize]
        }
    }

    /// All rows (task, quota), sorted by quota descending.
    pub fn rows(&self) -> &[(TaskId, u32)] {
        &self.rows
    }

    /// Total tokens generated per full cycle (= sum of quotas = sum of
    /// column batch sizes).
    pub fn tokens_per_cycle(&self) -> u64 {
        self.rows.iter().map(|&(_, v)| v as u64).sum()
    }

    /// Exact cycle duration: sum of l(batch) over all columns.
    pub fn period_exact(&self, l: &LatencyModel) -> Micros {
        (0..self.columns)
            .map(|j| l.decode(self.batch_len(j)))
            .sum()
    }

    /// Explicit 0/1 matrix (tests / visualization only).
    pub fn as_bit_matrix(&self) -> Vec<Vec<u8>> {
        self.rows
            .iter()
            .map(|&(_, v)| {
                (0..self.columns).map(|j| u8::from(j < v)).collect()
            })
            .collect()
    }
}

/// Closed-form cycle estimate, Eq. (7) of the paper:
///
///   T_period = v_b * l(b+1) + sum_{j=0}^{b-1} (v_j - v_{j+1}) * l(j+1)
///
/// where `vs` are per-cycle quotas sorted descending over b+1 tasks.
/// Equivalent to summing l(batch) over the mask's columns (tested against
/// [`DecodeMask::period_exact`]).
pub fn period_eq7(vs_sorted_desc: &[u32], l: &LatencyModel) -> Micros {
    let n = vs_sorted_desc.len();
    if n == 0 {
        return 0;
    }
    debug_assert!(vs_sorted_desc.windows(2).all(|w| w[0] >= w[1]));
    let vb = vs_sorted_desc[n - 1];
    let mut t = vb as u64 * l.decode(n as u32);
    for j in 0..n - 1 {
        let dv = (vs_sorted_desc[j] - vs_sorted_desc[j + 1]) as u64;
        t += dv * l.decode(j as u32 + 1);
    }
    t
}

/// Incrementally maintained Eq. (7) cycle duration over a quota
/// multiset — the per-admission engine behind
/// `selection::select_tasks`, costing O(v_max) counter bumps
/// independent of the queue depth (PR 5; DESIGN.md "Scheduler hot
/// path").
///
/// The closed form rewrites as a column sum against the Δl curve:
///
///   T_period = Σ_j l(c(j)) = Σ_b (l(b) − l(b−1)) · v_(b)
///
/// where `c(j) = |{i : v_i > j}|` is the batch size of mask column `j`
/// and `v_(b)` is the b-th largest quota (with l(0) = 0). Inserting a
/// quota `q` therefore only grows columns `0..q` by one member each:
/// the period moves by `Σ_{j<q} Δl(c(j)+1)`, touching `q ≤ v_max`
/// column counters instead of re-evaluating the O(n) closed form over
/// a freshly re-sorted quota list. `v_max` is bounded by the largest
/// admissible per-cycle quota (≈ cycle_cap / l(1), ~55 on the paper
/// curve), so one insert or remove is O(v_max) = O(1) in the number of
/// queued tasks, with Δl memoised per batch size.
///
/// All arithmetic is exact integer addition over the same `Micros`
/// values `period_eq7` multiplies out, so the maintained period is
/// bit-identical to the closed form (asserted over randomized
/// insert/remove sequences in `rust/tests/property_invariants.rs`).
#[derive(Debug, Clone)]
pub struct IncrementalPeriod {
    latency: LatencyModel,
    /// Memoised Δl: `delta[b-1] = l(b) − l(b−1)` (signed — a measured
    /// curve from `LatencyModel::from_points` need not be monotone),
    /// grown lazily as deeper batch sizes are touched.
    delta: Vec<i64>,
    /// `cols[j]` = number of live quotas strictly greater than `j`
    /// (= the decode batch size of mask column `j`).
    cols: Vec<u32>,
    /// Number of quotas currently in the multiset.
    n: usize,
    /// Maintained Σ_j l(cols[j]), signed only so partial sums of Δl
    /// stay exact on non-monotone curves; the total is always ≥ 0.
    period: i64,
}

impl IncrementalPeriod {
    /// An empty multiset over `latency`'s decode curve.
    pub fn new(latency: LatencyModel) -> Self {
        IncrementalPeriod { latency, delta: Vec::new(), cols: Vec::new(), n: 0, period: 0 }
    }

    /// The device curve this structure prices columns with.
    pub fn latency(&self) -> &LatencyModel {
        &self.latency
    }

    /// Remove every quota, keeping the memoised Δl table and column
    /// buffer (the per-reschedule reset).
    pub fn clear(&mut self) {
        self.cols.clear();
        self.n = 0;
        self.period = 0;
    }

    /// Number of quotas in the multiset.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when no quotas are held.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The maintained cycle duration — always equal to
    /// [`period_eq7`] over the current multiset sorted descending.
    pub fn period(&self) -> Micros {
        debug_assert!(self.period >= 0, "negative cycle duration");
        self.period as Micros
    }

    /// Grow the memoised Δl table to cover batch sizes `1..=b`
    /// (Δl(b) = l(b) − l(b−1) with l(0) = 0).
    fn ensure_delta(&mut self, b: u32) {
        while (self.delta.len() as u32) < b {
            let next = self.delta.len() as u32 + 1;
            let hi = self.latency.decode(next) as i64;
            let lo = if next == 1 { 0 } else { self.latency.decode(next - 1) as i64 };
            self.delta.push(hi - lo);
        }
    }

    /// The period this multiset would have after inserting quota `q`,
    /// without mutating anything — the selection loop's feasibility
    /// check. Costs O(min(q, deepest committed quota)): columns beyond
    /// the materialized prefix are empty, so a deeper probe prices its
    /// tail in closed form ((q − len) · Δl(1)) instead of walking it —
    /// a pathological quota (e.g. a hand-written trace with a zero
    /// TPOT) is rejected without ever materializing q counters.
    /// Exactly equals [`IncrementalPeriod::insert`]'s return for the
    /// same `q` (identical integer arithmetic).
    pub fn probe(&mut self, q: u32) -> Micros {
        assert!(q > 0, "zero-rate quota in period structure");
        let deepest = self.cols.first().map_or(1, |&c| c + 1);
        self.ensure_delta(deepest);
        let delta = &self.delta;
        let known = (q as usize).min(self.cols.len());
        let mut moved: i64 = 0;
        for &col in &self.cols[..known] {
            // Δl(col + 1) lives at delta[col]
            moved += delta[col as usize];
        }
        if q as usize > self.cols.len() {
            // untouched tail columns go 0 -> 1, each costing Δl(1)
            moved += (q as usize - self.cols.len()) as i64 * delta[0];
        }
        let p = self.period + moved;
        debug_assert!(p >= 0, "negative cycle duration");
        p as Micros
    }

    /// Insert one per-cycle quota (v > 0) and return the new period.
    pub fn insert(&mut self, q: u32) -> Micros {
        assert!(q > 0, "zero-rate quota in period structure");
        if self.cols.len() < q as usize {
            self.cols.resize(q as usize, 0);
        }
        // column 0 always holds the largest count, so one table grow
        // covers every bumped column
        let deepest = self.cols.first().map_or(1, |&c| c + 1);
        self.ensure_delta(deepest);
        let delta = &self.delta;
        let mut moved: i64 = 0;
        for col in &mut self.cols[..q as usize] {
            *col += 1;
            moved += delta[(*col - 1) as usize];
        }
        self.period += moved;
        self.n += 1;
        self.period()
    }

    /// Remove one previously inserted quota (the exact inverse of
    /// [`IncrementalPeriod::insert`] — selection's rollback path).
    pub fn remove(&mut self, q: u32) {
        assert!(
            q > 0 && self.cols.len() >= q as usize,
            "removing a quota never inserted"
        );
        let delta = &self.delta;
        let mut moved: i64 = 0;
        for col in &mut self.cols[..q as usize] {
            assert!(*col > 0, "removing a quota never inserted");
            moved += delta[(*col - 1) as usize];
            *col -= 1;
        }
        self.period -= moved;
        self.n -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ms;

    fn model() -> LatencyModel {
        LatencyModel::paper_calibrated()
    }

    /// The paper's Fig. 4 worked example: quotas 6/4/2/1.
    #[test]
    fn fig4_example_matrix() {
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(m.columns(), 6);
        assert_eq!(m.n_tasks(), 4);
        let bits = m.as_bit_matrix();
        assert_eq!(bits[0], vec![1, 1, 1, 1, 1, 1]);
        assert_eq!(bits[1], vec![1, 1, 1, 1, 0, 0]);
        assert_eq!(bits[2], vec![1, 1, 0, 0, 0, 0]);
        assert_eq!(bits[3], vec![1, 0, 0, 0, 0, 0]);
        // column batches: col0 -> 4 tasks, col1 -> 3, col2..3 -> 2, col4..5 -> 1
        assert_eq!(
            (0..6).map(|j| m.batch_len(j)).collect::<Vec<_>>(),
            vec![4, 3, 2, 2, 1, 1]
        );
        // scanning column 2 groups task0 and task1 (paper's example)
        let col2: Vec<TaskId> = m.column_batch(2).iter().map(|&(id, _)| id).collect();
        assert_eq!(col2, vec![0, 1]);
    }

    #[test]
    fn tokens_per_cycle_equals_quota_sum() {
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(m.tokens_per_cycle(), 13);
        let col_sum: u64 = (0..m.columns()).map(|j| m.batch_len(j) as u64).sum();
        assert_eq!(col_sum, 13);
    }

    #[test]
    fn eq7_matches_column_sum_fig4() {
        let l = model();
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(m.period_exact(&l), period_eq7(&[6, 4, 2, 1], &l));
        // manual expansion: l(4) + l(3) + 2*l(2) + 2*l(1)
        let manual = l.decode(4) + l.decode(3) + 2 * l.decode(2) + 2 * l.decode(1);
        assert_eq!(m.period_exact(&l), manual);
    }

    #[test]
    fn eq7_matches_column_sum_randomized() {
        let l = model();
        let mut rng = crate::util::rng::Rng::new(2024);
        for _ in 0..200 {
            let n = rng.range_usize(1, 12);
            let mut vs: Vec<u32> =
                (0..n).map(|_| rng.range_u64(1, 30) as u32).collect();
            vs.sort_unstable_by(|a, b| b.cmp(a));
            let tasks: Vec<(TaskId, u32)> =
                vs.iter().enumerate().map(|(i, &v)| (i as u64, v)).collect();
            let m = DecodeMask::build(tasks);
            assert_eq!(m.period_exact(&l), period_eq7(&vs, &l), "vs={vs:?}");
        }
    }

    #[test]
    fn equal_quotas_single_batch() {
        let l = model();
        let m = DecodeMask::build(vec![(0, 5), (1, 5), (2, 5)]);
        assert_eq!(m.columns(), 5);
        for j in 0..5 {
            assert_eq!(m.batch_len(j), 3);
        }
        assert_eq!(m.period_exact(&l), 5 * l.decode(3));
    }

    #[test]
    fn table2_period_under_cycle_cap() {
        // Table II: quotas ceil(1/TPOT) = A:10 x3, B:ceil(8.33)=9 x4, C:4 x2
        let l = model();
        let vs = [10, 10, 10, 9, 9, 9, 9, 4, 4];
        let period = period_eq7(&vs, &l);
        assert!(
            period < ms(1000.0),
            "paper's 9-task static mix must be admissible, period={period}"
        );
    }

    #[test]
    fn single_task_mask() {
        let l = model();
        let m = DecodeMask::build(vec![(7, 3)]);
        assert_eq!(m.columns(), 3);
        assert_eq!(m.batch_len(0), 1);
        assert_eq!(m.period_exact(&l), 3 * l.decode(1));
        assert_eq!(m.column_batch(0), &[(7, 3)]);
    }

    #[test]
    fn column_batches_are_prefixes_of_sorted_rows() {
        let m = DecodeMask::build(vec![(5, 2), (9, 7), (1, 7), (3, 4)]);
        // sorted: (1,7), (9,7), (3,4), (5,2) — ties broken by id
        let ids: Vec<TaskId> = m.rows().iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![1, 9, 3, 5]);
        for j in 0..m.columns() {
            let batch = m.column_batch(j);
            assert_eq!(batch, &m.rows()[..batch.len()]);
            // monotone: batch sizes never grow as j increases
            if j > 0 {
                assert!(m.batch_len(j) <= m.batch_len(j - 1));
            }
        }
    }

    #[test]
    #[should_panic]
    fn zero_quota_rejected() {
        let _ = DecodeMask::build(vec![(0, 0)]);
    }

    #[test]
    fn empty_mask() {
        let m = DecodeMask::build(vec![]);
        assert!(m.is_empty());
        assert_eq!(m.columns(), 0);
        assert_eq!(m.batch_len(0), 0);
        assert_eq!(m.period_exact(&model()), 0);
    }

    #[test]
    fn rebuild_matches_build_and_reuses_buffers() {
        let sets: [&[(TaskId, u32)]; 4] = [
            &[(0, 6), (1, 4), (2, 2), (3, 1)],
            &[(5, 2), (9, 7), (1, 7), (3, 4)],
            &[(7, 3)],
            &[(0, 5), (1, 5), (2, 5)],
        ];
        let mut reused = DecodeMask::empty();
        assert!(reused.is_empty());
        for rows in sets {
            reused.rebuild(rows);
            let fresh = DecodeMask::build(rows.to_vec());
            assert_eq!(reused.rows(), fresh.rows());
            assert_eq!(reused.columns(), fresh.columns());
            assert_eq!(reused.as_bit_matrix(), fresh.as_bit_matrix());
            for j in 0..fresh.columns() + 1 {
                assert_eq!(reused.batch_len(j), fresh.batch_len(j));
            }
            assert_eq!(reused.period_exact(&model()), fresh.period_exact(&model()));
        }
        reused.clear();
        assert!(reused.is_empty());
        assert_eq!(reused.columns(), 0);
        assert_eq!(reused.period_exact(&model()), 0);
    }

    #[test]
    #[should_panic]
    fn rebuild_rejects_zero_quota() {
        let mut m = DecodeMask::empty();
        m.rebuild(&[(0, 0)]);
    }

    #[test]
    fn incremental_period_fig4_example() {
        let l = model();
        let mut inc = IncrementalPeriod::new(l.clone());
        assert!(inc.is_empty());
        assert_eq!(inc.period(), 0);
        // insert the Fig. 4 quotas in admission (unsorted) order
        let mut sorted: Vec<u32> = Vec::new();
        for q in [4u32, 6, 1, 2] {
            let probed = inc.probe(q);
            let p = inc.insert(q);
            assert_eq!(probed, p, "probe must price the insert exactly");
            sorted.push(q);
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            assert_eq!(p, period_eq7(&sorted, &l), "after inserting {q}");
            assert_eq!(p, inc.period());
        }
        assert_eq!(inc.len(), 4);
        let m = DecodeMask::build(vec![(0, 6), (1, 4), (2, 2), (3, 1)]);
        assert_eq!(inc.period(), m.period_exact(&l));
        // rollback is the exact inverse
        inc.remove(2);
        assert_eq!(inc.period(), period_eq7(&[6, 4, 1], &l));
        inc.remove(6);
        assert_eq!(inc.period(), period_eq7(&[4, 1], &l));
        inc.clear();
        assert!(inc.is_empty());
        assert_eq!(inc.period(), 0);
        assert_eq!(inc.insert(5), period_eq7(&[5], &l), "reusable after clear");
    }

    #[test]
    fn incremental_period_matches_eq7_randomized_with_removals() {
        let l = model();
        let mut rng = crate::util::rng::Rng::new(2025);
        for case in 0..200 {
            let mut inc = IncrementalPeriod::new(l.clone());
            let mut live: Vec<u32> = Vec::new();
            for _ in 0..rng.range_usize(1, 40) {
                if !live.is_empty() && rng.chance(0.3) {
                    let at = rng.range_usize(0, live.len() - 1);
                    let q = live.swap_remove(at);
                    inc.remove(q);
                } else {
                    let q = rng.range_u64(1, 30) as u32;
                    live.push(q);
                    inc.insert(q);
                }
                let mut sorted = live.clone();
                sorted.sort_unstable_by(|a, b| b.cmp(a));
                assert_eq!(
                    inc.period(),
                    period_eq7(&sorted, &l),
                    "case {case}: live={live:?}"
                );
                assert_eq!(inc.len(), live.len());
            }
        }
    }

    #[test]
    fn probe_prices_deep_tail_without_materializing() {
        let l = model();
        let mut inc = IncrementalPeriod::new(l.clone());
        inc.insert(4);
        // probing far past the materialized columns prices the empty
        // tail in closed form: 4 bumped columns + (q - 4) fresh l(1)
        // columns — and leaves the structure untouched
        let q = 1_000_000u32;
        let expected = {
            let mut vs = vec![4u32, q];
            vs.sort_unstable_by(|a, b| b.cmp(a));
            period_eq7(&vs, &l)
        };
        assert_eq!(inc.probe(q), expected);
        assert_eq!(inc.len(), 1, "probe must not mutate");
        assert_eq!(inc.period(), period_eq7(&[4], &l));
    }

    #[test]
    #[should_panic]
    fn incremental_period_rejects_zero_quota() {
        let mut inc = IncrementalPeriod::new(model());
        inc.insert(0);
    }

    #[test]
    #[should_panic]
    fn incremental_period_rejects_unmatched_remove() {
        let mut inc = IncrementalPeriod::new(model());
        inc.insert(3);
        inc.remove(5);
    }
}
