//! The SLICE scheduling policy (paper §IV, Algorithms 1-4).
//!
//! Online operation (Alg. 4): on every task arrival or departure the
//! decode loop is interrupted and the offline algorithm re-runs —
//! utility-rate task selection (Alg. 2, `selection.rs`) followed by
//! decode-mask-matrix rate allocation (Alg. 3, `mask.rs`). Between
//! events the policy walks the mask matrix column by column, emitting one
//! dynamically-regrouped decode batch per column; a full sweep is one
//! scheduling cycle delivering every admitted task its per-second token
//! quota.

use std::collections::VecDeque;

use crate::engine::latency::LatencyModel;
use crate::engine::memory::MemoryConfig;
use crate::util::Micros;

use super::mask::DecodeMask;
use super::pool::TaskPool;
use super::preemption::UtilityAdaptor;
use super::scheduler::{Policy, Step};
use super::selection::{select_tasks_with, Candidate, Selection, SelectionScratch, CYCLE_CAP};
use super::task::{TaskId, TaskState};

/// Memory-aware selection parameters (DESIGN.md "Memory model"): the
/// device's KV capacity plus the footprint geometry (delegated to the
/// shared [`MemoryConfig`] rounding so selection's projections can
/// never diverge from the serving loop's enforcement accounting).
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryBudget {
    /// Device KV capacity in bytes (tier-scaled).
    pub capacity: u64,
    /// The paging geometry (bytes per token, block rounding).
    pub cfg: MemoryConfig,
}

impl MemoryBudget {
    /// Build from a memory config and a device capacity; `None` unless
    /// the config is both constrained and memory-*aware* (an oblivious
    /// policy under a finite capacity is the sweep's baseline).
    pub fn from_config(cfg: &MemoryConfig, capacity: Option<u64>) -> Option<Self> {
        match capacity {
            Some(capacity) if cfg.aware => {
                Some(MemoryBudget { capacity, cfg: cfg.clone() })
            }
            _ => None,
        }
    }

    /// A task's *current* KV footprint (its sequence so far plus the
    /// next token), block-rounded. Selection re-runs at every arrival
    /// and departure (Alg. 4), so budgeting against current footprints
    /// tracks occupancy as generations grow — a full-generation
    /// worst-case projection proved so conservative it left the device
    /// idle (measured in EXPERIMENTS.md "Memory sweep").
    pub fn footprint_bytes(&self, seq_len: u32) -> u64 {
        self.cfg.bytes_for(seq_len + 1)
    }
}

/// SLICE scheduler configuration.
#[derive(Debug, Clone)]
pub struct SliceConfig {
    /// Scheduling-cycle duration cap (paper: 1000 ms).
    pub cycle_cap: Micros,
    /// Utility adaptation applied at every reschedule (Alg. 4 line 17).
    pub adaptor: UtilityAdaptor,
    /// Extension (not in the paper; ablated in `experiments::ablation`):
    /// subtract the prefill cost of newly admitted tasks from the cycle
    /// budget during selection. Alg. 2 estimates the cycle from decode
    /// steps only, so a burst of admissions can overrun the 1000 ms cap
    /// by the length of the prefill queue; this accounts for it.
    pub prefill_aware: bool,
    /// Memory extension: when set, selection treats projected KV bytes
    /// as a second knapsack dimension so the emitted schedule always
    /// fits the device's cache (`None` = memory-oblivious, the
    /// pre-memory behaviour).
    pub memory: Option<MemoryBudget>,
}

impl Default for SliceConfig {
    fn default() -> Self {
        SliceConfig {
            cycle_cap: CYCLE_CAP,
            adaptor: UtilityAdaptor::None,
            prefill_aware: false,
            memory: None,
        }
    }
}

/// The online SLICE policy.
///
/// Hot-path note (DESIGN.md "Scheduler hot path"): the policy owns
/// every buffer the Alg. 4 reschedule and the column scan touch — the
/// candidate list, the selection scratch (sort keys + incremental
/// Eq. 7 structure), the selection output, the mask rows and the
/// decode-batch buffer (recycled by the serving loop via
/// [`Policy::recycle_batch`]) — so steady-state scheduling performs
/// zero heap allocation once the buffers reach the workload's
/// high-water mark.
pub struct SlicePolicy {
    latency: LatencyModel,
    cfg: SliceConfig,
    /// Current rate-allocation matrix over the admitted set (empty =
    /// nothing scheduled); rebuilt in place at each reschedule.
    mask: DecodeMask,
    /// Next column to scan.
    col: u32,
    /// Admitted tasks whose prompt has not been prefilled yet.
    to_prefill: VecDeque<TaskId>,
    /// Set when an arrival/departure event requires re-running the
    /// offline algorithm (the paper's interruption event queue).
    needs_reschedule: bool,
    /// Reschedule counter (observability / tests).
    pub reschedules: u64,
    /// Candidate buffer rebuilt from the pool at each reschedule.
    candidates: Vec<Candidate>,
    /// Selection working memory (sort keys, quotas, incremental period).
    scratch: SelectionScratch,
    /// Selection output, reused across reschedules.
    sel: Selection,
    /// Decode-batch buffer, recycled by the serving loop.
    batch: Vec<TaskId>,
}

impl SlicePolicy {
    /// Build the policy from a device latency model and config.
    pub fn new(latency: LatencyModel, cfg: SliceConfig) -> Self {
        let scratch = SelectionScratch::new(latency.clone());
        SlicePolicy {
            latency,
            cfg,
            mask: DecodeMask::empty(),
            col: 0,
            to_prefill: VecDeque::new(),
            needs_reschedule: false,
            reschedules: 0,
            candidates: Vec::new(),
            scratch,
            sel: Selection::default(),
            batch: Vec::new(),
        }
    }

    /// Build with [`SliceConfig::default`].
    pub fn with_defaults(latency: LatencyModel) -> Self {
        Self::new(latency, SliceConfig::default())
    }

    /// Re-run the offline SLICE algorithm (task selection + rate
    /// allocation) over every unfinished task.
    fn reschedule(&mut self, pool: &mut TaskPool, _now: Micros) {
        self.reschedules += 1;

        // One pass over the pool builds the candidate list (Alg. 4
        // line 17: adapt utilities before selection) and accumulates
        // the pending prefill debt the prefill-aware extension charges
        // against the cycle budget (see SliceConfig).
        self.candidates.clear();
        let mut prefill_debt: Micros = 0;
        for t in pool.iter() {
            if t.is_finished() {
                continue;
            }
            if self.cfg.prefill_aware && t.prefill_end.is_none() {
                prefill_debt += self.latency.prefill(t.prompt_len);
            }
            self.candidates.push(Candidate {
                id: t.id,
                utility: self.cfg.adaptor.effective(t),
                tpot: t.slo.tpot,
                kv_bytes: self
                    .cfg
                    .memory
                    .as_ref()
                    .map_or(0, |m| m.footprint_bytes(t.seq_len())),
            });
        }
        let cycle_cap = if self.cfg.prefill_aware {
            self.cfg.cycle_cap.saturating_sub(prefill_debt.min(self.cfg.cycle_cap / 2))
        } else {
            self.cfg.cycle_cap
        };
        let kv_capacity = self.cfg.memory.as_ref().map(|m| m.capacity);
        select_tasks_with(
            &mut self.scratch,
            &mut self.sel,
            &self.candidates,
            cycle_cap,
            kv_capacity,
        );

        // Update task states and the prefill queue.
        self.to_prefill.clear();
        for &(id, _) in &self.sel.selected {
            let t = pool.get_mut(id);
            match t.state {
                TaskState::Waiting | TaskState::Admitted => {
                    t.state = TaskState::Admitted;
                    self.to_prefill.push_back(id);
                }
                TaskState::Paused => t.state = TaskState::Running,
                TaskState::Running => {}
                TaskState::Finished => unreachable!("finished task selected"),
            }
        }
        for &id in &self.sel.rejected {
            let t = pool.get_mut(id);
            if matches!(t.state, TaskState::Running | TaskState::Admitted) {
                // deselected mid-flight: pause (KV retained; decode stops)
                t.state = if t.prefill_end.is_some() {
                    TaskState::Paused
                } else {
                    TaskState::Waiting
                };
            }
        }

        if self.sel.selected.is_empty() {
            self.mask.clear();
        } else {
            self.mask.rebuild(&self.sel.selected);
        }
        self.col = 0;
        self.needs_reschedule = false;
    }

    /// Currently admitted tasks, in mask order (tests / observability).
    pub fn admitted(&self) -> Vec<TaskId> {
        self.mask.rows().iter().map(|&(id, _)| id).collect()
    }
}

impl Policy for SlicePolicy {
    fn name(&self) -> &'static str {
        "SLICE"
    }

    fn on_arrival(&mut self, _pool: &mut TaskPool, _ids: &[TaskId], _now: Micros) {
        // interruption event: re-run the offline algorithm (Alg. 4)
        self.needs_reschedule = true;
    }

    fn on_completion(&mut self, _pool: &mut TaskPool, _ids: &[TaskId], _now: Micros) {
        self.needs_reschedule = true;
    }

    fn next_step(&mut self, pool: &mut TaskPool, now: Micros) -> Step {
        if self.needs_reschedule {
            self.reschedule(pool, now);
        }

        // Prefill newly admitted tasks before resuming the column scan.
        while let Some(id) = self.to_prefill.pop_front() {
            if !pool.get(id).is_finished() {
                return Step::Prefill { task: id };
            }
        }

        if self.mask.is_empty() {
            return Step::Idle;
        }

        // Column scan: skip columns whose batch is entirely finished
        // (can happen between a completion event and the reschedule).
        // The batch is the column's prefix of the mask rows filtered to
        // running tasks, written into the recycled buffer — the server
        // hands it back via recycle_batch, so the steady-state scan
        // allocates nothing.
        let columns = self.mask.columns();
        for _ in 0..columns {
            let j = self.col;
            self.col = (self.col + 1) % columns;
            self.batch.clear();
            self.batch.extend(
                self.mask
                    .column_batch(j)
                    .iter()
                    .map(|&(id, _)| id)
                    .filter(|&id| pool.get(id).state == TaskState::Running),
            );
            if !self.batch.is_empty() {
                return Step::Decode { tasks: std::mem::take(&mut self.batch) };
            }
        }
        Step::Idle
    }

    fn recycle_batch(&mut self, mut batch: Vec<TaskId>) {
        batch.clear();
        // keep whichever buffer holds the larger allocation (the server
        // may hand back a trimmed batch it rebuilt under memory pressure)
        if batch.capacity() > self.batch.capacity() {
            self.batch = batch;
        }
    }

    fn decisions(&self) -> u64 {
        self.reschedules
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};

    fn pool_with(tasks: Vec<Task>) -> TaskPool {
        let mut p = TaskPool::new();
        for t in tasks {
            p.insert(t);
        }
        p
    }

    fn mark_prefilled(pool: &mut TaskPool, id: TaskId, now: Micros) {
        let t = pool.get_mut(id);
        t.state = TaskState::Running;
        t.prefill_end = Some(now);
        t.on_token(now);
    }

    #[test]
    fn arrival_triggers_reschedule_and_prefill() {
        let mut pool = pool_with(vec![
            Task::new(0, TaskClass::RealTime, 0, 16, 10, 100.0),
            Task::new(1, TaskClass::Voice, 0, 16, 10, 1.0),
        ]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0, 1], 0);

        // first steps must be prefills, real-time task first (higher r_i)
        let s1 = p.next_step(&mut pool, 0);
        assert_eq!(s1, Step::Prefill { task: 0 });
        mark_prefilled(&mut pool, 0, 30_000);
        let s2 = p.next_step(&mut pool, 30_000);
        assert_eq!(s2, Step::Prefill { task: 1 });
        mark_prefilled(&mut pool, 1, 60_000);

        // then decode columns; both tasks running
        let s3 = p.next_step(&mut pool, 60_000);
        match s3 {
            Step::Decode { tasks } => {
                assert!(tasks.contains(&0));
            }
            s => panic!("expected decode, got {s:?}"),
        }
        assert_eq!(p.reschedules, 1);
    }

    #[test]
    fn mask_columns_shrink_batches_for_low_rate_tasks() {
        // RT task (20 t/s quota) + voice task (8 t/s quota): voice appears
        // in only 8 of 20 columns.
        let mut pool = pool_with(vec![
            Task::new(0, TaskClass::RealTime, 0, 16, 100, 100.0),
            Task::new(1, TaskClass::Voice, 0, 16, 100, 1.0),
        ]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0, 1], 0);
        let _ = p.next_step(&mut pool, 0);
        mark_prefilled(&mut pool, 0, 1);
        let _ = p.next_step(&mut pool, 1);
        mark_prefilled(&mut pool, 1, 2);

        let mut batch_sizes = Vec::new();
        for _ in 0..20 {
            match p.next_step(&mut pool, 10) {
                Step::Decode { tasks } => batch_sizes.push(tasks.len()),
                s => panic!("expected decode, got {s:?}"),
            }
        }
        let twos = batch_sizes.iter().filter(|&&n| n == 2).count();
        let ones = batch_sizes.iter().filter(|&&n| n == 1).count();
        assert_eq!(twos, 8, "voice quota columns");
        assert_eq!(ones, 12, "RT-only columns");
    }

    #[test]
    fn completion_triggers_reschedule() {
        let mut pool = pool_with(vec![Task::new(0, TaskClass::Voice, 0, 16, 1, 1.0)]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0], 0);
        let _ = p.next_step(&mut pool, 0);
        mark_prefilled(&mut pool, 0, 1); // output_len 1 -> finished
        assert!(pool.get(0).is_finished());
        p.on_completion(&mut pool, &[0], 1);
        assert_eq!(p.next_step(&mut pool, 2), Step::Idle);
        assert_eq!(p.reschedules, 2);
    }

    #[test]
    fn overload_pauses_low_utility_tasks() {
        // 40 RT tasks cannot all be admitted; the rest must stay waiting.
        let tasks: Vec<Task> = (0..40)
            .map(|i| Task::new(i, TaskClass::RealTime, 0, 16, 50, 100.0))
            .collect();
        let mut pool = pool_with(tasks);
        let ids: Vec<TaskId> = (0..40).collect();
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &ids, 0);
        let _ = p.next_step(&mut pool, 0);
        let admitted = p.admitted().len();
        assert!(admitted > 0 && admitted < 40, "admitted={admitted}");
        let waiting = pool.ids_in_state(TaskState::Waiting).len();
        assert_eq!(waiting, 40 - admitted);
    }

    #[test]
    fn sjf_adaptor_prefers_fresh_tasks_on_reschedule() {
        // Two identical voice tasks; one has generated many tokens. With
        // SjfDecay and capacity for only one (tiny max_batch), the fresh
        // task wins the slot.
        let mut lat = LatencyModel::paper_calibrated();
        lat.max_batch = 1;
        let mut t0 = Task::new(0, TaskClass::Voice, 0, 16, 100, 10.0);
        t0.tokens_generated = 64;
        t0.state = TaskState::Running;
        t0.prefill_end = Some(1);
        let t1 = Task::new(1, TaskClass::Voice, 0, 16, 100, 10.0);
        let mut pool = pool_with(vec![t0, t1]);
        let mut p = SlicePolicy::new(
            lat,
            SliceConfig {
                adaptor: UtilityAdaptor::SjfDecay { factor: 0.5, tau: 16 },
                ..SliceConfig::default()
            },
        );
        p.on_arrival(&mut pool, &[1], 0);
        let step = p.next_step(&mut pool, 0);
        assert_eq!(step, Step::Prefill { task: 1 });
        assert_eq!(pool.get(0).state, TaskState::Paused, "long task preempted");
    }

    #[test]
    fn memory_budget_limits_admissions() {
        // 8 mid-generation voice tasks, each holding ~11.5 MiB of cache;
        // a 32 MiB budget keeps only 2 scheduled, the rest pause
        // (memory, not the cycle cap, binds)
        let mk_tasks = || -> Vec<Task> {
            (0..8)
                .map(|i| {
                    let mut t = Task::new(i, TaskClass::Voice, 0, 32, 400, 1.0);
                    t.state = TaskState::Running;
                    t.prefill_end = Some(1);
                    t.tokens_generated = 335; // seq_len 367 -> 368-token footprint
                    t
                })
                .collect()
        };
        // default geometry: 32 KiB/token, 16-token blocks
        let budget = MemoryBudget {
            capacity: 32 * 1024 * 1024,
            cfg: MemoryConfig::default(),
        };
        assert_eq!(budget.footprint_bytes(367), 368 * 32 * 1024); // 11.5 MiB
        let mut pool = pool_with(mk_tasks());
        let ids: Vec<TaskId> = (0..8).collect();
        let mut aware = SlicePolicy::new(
            LatencyModel::paper_calibrated(),
            SliceConfig { memory: Some(budget), ..SliceConfig::default() },
        );
        aware.on_arrival(&mut pool, &ids, 0);
        let _ = aware.next_step(&mut pool, 0);
        assert_eq!(aware.admitted().len(), 2, "32 MiB / 11.5 MiB = 2 tasks");
        assert_eq!(pool.ids_in_state(TaskState::Paused).len(), 6);

        // the oblivious policy keeps all 8 (cycle cap alone allows it)
        let mut pool = pool_with(mk_tasks());
        let mut oblivious =
            SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        oblivious.on_arrival(&mut pool, &ids, 0);
        let _ = oblivious.next_step(&mut pool, 0);
        assert_eq!(oblivious.admitted().len(), 8);
    }

    #[test]
    fn idle_when_no_tasks() {
        let mut pool = TaskPool::new();
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        assert_eq!(p.next_step(&mut pool, 0), Step::Idle);
    }
}
