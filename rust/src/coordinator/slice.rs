//! The SLICE scheduling policy (paper §IV, Algorithms 1-4).
//!
//! Online operation (Alg. 4): on every task arrival or departure the
//! decode loop is interrupted and the offline algorithm re-runs —
//! utility-rate task selection (Alg. 2, `selection.rs`) followed by
//! decode-mask-matrix rate allocation (Alg. 3, `mask.rs`). Between
//! events the policy walks the mask matrix column by column, emitting one
//! dynamically-regrouped decode batch per column; a full sweep is one
//! scheduling cycle delivering every admitted task its per-second token
//! quota.
//!
//! Control-plane incrementality (DESIGN.md chapter of the same name):
//! when candidate keys cannot change between reschedules — no utility
//! adaptor, no memory dimension, no prefill-aware debt — the policy
//! keeps the sorted `(key, id, quota)` candidate list alive *across*
//! decisions, maintaining it with O(log n) binary insert/remove per
//! arrival/departure instead of an O(n log n) rebuild, and skips a
//! reschedule outright when every new arrival provably sorts past the
//! last admission boundary (the admitted prefix cannot change). Both
//! fast paths are bit-exact with the rebuild-every-time reference;
//! `SliceConfig::incremental` turns them off for the equivalence suite.

use std::collections::VecDeque;

use crate::engine::latency::LatencyModel;
use crate::engine::memory::MemoryConfig;
use crate::util::Micros;

use super::mask::DecodeMask;
use super::pool::TaskPool;
use super::preemption::UtilityAdaptor;
use super::scheduler::{Policy, Step};
use super::selection::{
    admission_entry, select_tasks_sorted, select_tasks_with, Candidate, Selection,
    SelectionScratch, CYCLE_CAP,
};
use super::task::{TaskId, TaskState};

/// Memory-aware selection parameters (DESIGN.md "Memory model"): the
/// device's KV capacity plus the footprint geometry (delegated to the
/// shared [`MemoryConfig`] rounding so selection's projections can
/// never diverge from the serving loop's enforcement accounting).
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryBudget {
    /// Device KV capacity in bytes (tier-scaled).
    pub capacity: u64,
    /// The paging geometry (bytes per token, block rounding).
    pub cfg: MemoryConfig,
}

impl MemoryBudget {
    /// Build from a memory config and a device capacity; `None` unless
    /// the config is both constrained and memory-*aware* (an oblivious
    /// policy under a finite capacity is the sweep's baseline).
    pub fn from_config(cfg: &MemoryConfig, capacity: Option<u64>) -> Option<Self> {
        match capacity {
            Some(capacity) if cfg.aware => {
                Some(MemoryBudget { capacity, cfg: cfg.clone() })
            }
            _ => None,
        }
    }

    /// A task's *current* KV footprint (its sequence so far plus the
    /// next token), block-rounded. Selection re-runs at every arrival
    /// and departure (Alg. 4), so budgeting against current footprints
    /// tracks occupancy as generations grow — a full-generation
    /// worst-case projection proved so conservative it left the device
    /// idle (measured in EXPERIMENTS.md "Memory sweep").
    pub fn footprint_bytes(&self, seq_len: u32) -> u64 {
        self.cfg.bytes_for(seq_len + 1)
    }
}

/// SLICE scheduler configuration.
#[derive(Debug, Clone)]
pub struct SliceConfig {
    /// Scheduling-cycle duration cap (paper: 1000 ms).
    pub cycle_cap: Micros,
    /// Utility adaptation applied at every reschedule (Alg. 4 line 17).
    pub adaptor: UtilityAdaptor,
    /// Extension (not in the paper; ablated in `experiments::ablation`):
    /// subtract the prefill cost of newly admitted tasks from the cycle
    /// budget during selection. Alg. 2 estimates the cycle from decode
    /// steps only, so a burst of admissions can overrun the 1000 ms cap
    /// by the length of the prefill queue; this accounts for it.
    pub prefill_aware: bool,
    /// Memory extension: when set, selection treats projected KV bytes
    /// as a second knapsack dimension so the emitted schedule always
    /// fits the device's cache (`None` = memory-oblivious, the
    /// pre-memory behaviour).
    pub memory: Option<MemoryBudget>,
    /// Enable the cross-decision fast paths (cached candidate list +
    /// reschedule skipping) where they are sound — the immutable-key
    /// regime: no adaptor, no memory dimension, not prefill-aware.
    /// Bit-exact with `false` by construction; the switch exists so the
    /// equivalence suite can pin that claim and so `decisions` keeps
    /// its pre-PR 8 meaning when disabled.
    pub incremental: bool,
}

impl Default for SliceConfig {
    fn default() -> Self {
        SliceConfig {
            cycle_cap: CYCLE_CAP,
            adaptor: UtilityAdaptor::None,
            prefill_aware: false,
            memory: None,
            incremental: true,
        }
    }
}

/// The online SLICE policy.
///
/// Hot-path note (DESIGN.md "Scheduler hot path"): the policy owns
/// every buffer the Alg. 4 reschedule and the column scan touch — the
/// candidate list, the selection scratch (sort keys + incremental
/// Eq. 7 structure), the selection output, the mask rows and the
/// decode-batch buffer (recycled by the serving loop via
/// [`Policy::recycle_batch`]) — so steady-state scheduling performs
/// zero heap allocation once the buffers reach the workload's
/// high-water mark.
pub struct SlicePolicy {
    latency: LatencyModel,
    cfg: SliceConfig,
    /// Current rate-allocation matrix over the admitted set (empty =
    /// nothing scheduled); rebuilt in place at each reschedule.
    mask: DecodeMask,
    /// Next column to scan.
    col: u32,
    /// Admitted tasks whose prompt has not been prefilled yet.
    to_prefill: VecDeque<TaskId>,
    /// Set when an arrival/departure event requires re-running the
    /// offline algorithm (the paper's interruption event queue).
    needs_reschedule: bool,
    /// Reschedule counter (observability / tests).
    pub reschedules: u64,
    /// Arrival boundaries skipped by the precondition (observability;
    /// `reschedules + decisions_skipped` equals a skip-disabled run's
    /// `reschedules` exactly — pinned in `rust/tests/equivalence.rs`).
    pub decisions_skipped: u64,
    /// Reschedules that had to rebuild + re-sort the candidate list
    /// from the pool instead of reusing the maintained cache (0 in the
    /// immutable-key regime by construction).
    pub full_rebuilds: u64,
    /// Candidate buffer rebuilt from the pool at each reschedule.
    candidates: Vec<Candidate>,
    /// Selection working memory (sort keys, quotas, incremental period).
    scratch: SelectionScratch,
    /// Selection output, reused across reschedules.
    sel: Selection,
    /// Decode-batch buffer, recycled by the serving loop.
    batch: Vec<TaskId>,
    /// True iff candidate keys are provably constant between
    /// reschedules under this config (see module doc) — the gate for
    /// both cross-decision fast paths.
    immutable: bool,
    /// The maintained candidate cache, ascending by `(key, id)` —
    /// exactly the full path's sort order (the pair is unique).
    sorted: Vec<(u64, TaskId, u32)>,
    /// Pool-mutation epoch: bumped on every arrival/completion batch.
    generation: u64,
    /// Epoch the cache was last synchronized at; the cached path runs
    /// only when equal to `generation` (staleness guard).
    cache_generation: u64,
    /// Skip-precondition threshold from the last real selection: the
    /// `(key, id)` of the admission boundary element. An arrival batch
    /// whose entries all sort strictly after it cannot change the
    /// admitted prefix. `None` = never skip (everything was admitted,
    /// or no selection has run since the last departure).
    threshold: Option<(u64, TaskId)>,
}

impl SlicePolicy {
    /// Build the policy from a device latency model and config.
    pub fn new(latency: LatencyModel, cfg: SliceConfig) -> Self {
        let scratch = SelectionScratch::new(latency.clone());
        let immutable = cfg.incremental
            && matches!(cfg.adaptor, UtilityAdaptor::None)
            && cfg.memory.is_none()
            && !cfg.prefill_aware;
        SlicePolicy {
            latency,
            cfg,
            mask: DecodeMask::empty(),
            col: 0,
            to_prefill: VecDeque::new(),
            needs_reschedule: false,
            reschedules: 0,
            decisions_skipped: 0,
            full_rebuilds: 0,
            candidates: Vec::new(),
            scratch,
            sel: Selection::default(),
            batch: Vec::new(),
            immutable,
            sorted: Vec::new(),
            generation: 0,
            cache_generation: 0,
            threshold: None,
        }
    }

    /// Build with [`SliceConfig::default`].
    pub fn with_defaults(latency: LatencyModel) -> Self {
        Self::new(latency, SliceConfig::default())
    }

    /// Re-run the offline SLICE algorithm (task selection + rate
    /// allocation) over every unfinished task.
    fn reschedule(&mut self, pool: &mut TaskPool, _now: Micros) {
        self.reschedules += 1;

        let stopped = if self.immutable && self.cache_generation == self.generation {
            // Cached path: keys are immutable and the maintained sorted
            // list is in sync with the pool, so the greedy loop runs
            // directly over it — no pool pass, no re-adapt, no sort.
            select_tasks_sorted(
                &mut self.scratch,
                &mut self.sel,
                &self.sorted,
                self.cfg.cycle_cap,
            )
        } else {
            self.full_rebuilds += 1;
            // One pass over the pool builds the candidate list (Alg. 4
            // line 17: adapt utilities before selection) and accumulates
            // the pending prefill debt the prefill-aware extension
            // charges against the cycle budget (see SliceConfig).
            self.candidates.clear();
            let mut prefill_debt: Micros = 0;
            for t in pool.iter() {
                if t.is_finished() {
                    continue;
                }
                if self.cfg.prefill_aware && t.prefill_end.is_none() {
                    prefill_debt += self.latency.prefill(t.prompt_len);
                }
                self.candidates.push(Candidate {
                    id: t.id,
                    utility: self.cfg.adaptor.effective(t),
                    tpot: t.slo.tpot,
                    kv_bytes: self
                        .cfg
                        .memory
                        .as_ref()
                        .map_or(0, |m| m.footprint_bytes(t.seq_len())),
                });
            }
            let cycle_cap = if self.cfg.prefill_aware {
                self.cfg.cycle_cap.saturating_sub(prefill_debt.min(self.cfg.cycle_cap / 2))
            } else {
                self.cfg.cycle_cap
            };
            let kv_capacity = self.cfg.memory.as_ref().map(|m| m.capacity);
            let stopped = select_tasks_with(
                &mut self.scratch,
                &mut self.sel,
                &self.candidates,
                cycle_cap,
                kv_capacity,
            );
            if self.immutable {
                // (re)seed the maintained cache from the rebuild so the
                // cached path takes over from here
                self.scratch.export_sorted(&mut self.sorted);
                self.cache_generation = self.generation;
            }
            stopped
        };

        // Skip-precondition threshold (see `threshold` field): the
        // admission boundary after this selection. Only meaningful in
        // the immutable regime, where `sorted` mirrors the selection
        // order — `selected` is exactly its k-long prefix.
        self.threshold = if !self.immutable {
            None
        } else {
            let k = self.sel.selected.len();
            if k == self.sorted.len() {
                // everything admitted: any arrival could extend the set
                None
            } else if stopped {
                // resource stop: the first rejected element triggered
                // it; an arrival sorting before it would be probed
                // earlier and might fit, so it is the boundary
                let (key, id, _) = self.sorted[k];
                Some((key, id))
            } else if k > 0 {
                // max_batch stop: the boundary is the worst admitted
                // element — anything sorting after it lands in the
                // rejected region regardless
                let (key, id, _) = self.sorted[k - 1];
                Some((key, id))
            } else {
                None // max_batch == 0 degenerate shape
            }
        };

        // Update task states and the prefill queue.
        self.to_prefill.clear();
        for &(id, _) in &self.sel.selected {
            let t = pool.get_mut(id);
            match t.state {
                TaskState::Waiting | TaskState::Admitted => {
                    t.state = TaskState::Admitted;
                    self.to_prefill.push_back(id);
                }
                TaskState::Paused => t.state = TaskState::Running,
                TaskState::Running => {}
                TaskState::Finished => unreachable!("finished task selected"),
            }
        }
        for &id in &self.sel.rejected {
            let t = pool.get_mut(id);
            if matches!(t.state, TaskState::Running | TaskState::Admitted) {
                // deselected mid-flight: pause (KV retained; decode stops)
                t.state = if t.prefill_end.is_some() {
                    TaskState::Paused
                } else {
                    TaskState::Waiting
                };
            }
        }

        if self.sel.selected.is_empty() {
            self.mask.clear();
        } else {
            self.mask.rebuild(&self.sel.selected);
        }
        self.col = 0;
        self.needs_reschedule = false;
    }

    /// Currently admitted tasks, in mask order (tests / observability).
    pub fn admitted(&self) -> Vec<TaskId> {
        self.mask.rows().iter().map(|&(id, _)| id).collect()
    }

    /// The maintained candidate cache, ascending by `(key, id)` — the
    /// property suite pins it against a fresh pool rebuild after
    /// arbitrary mutation sequences. Empty until the first reschedule
    /// seeds it; meaningless outside the immutable regime.
    pub fn cached_candidates(&self) -> &[(u64, TaskId, u32)] {
        &self.sorted
    }
}

impl Policy for SlicePolicy {
    fn name(&self) -> &'static str {
        "SLICE"
    }

    fn on_arrival(&mut self, pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
        self.generation += 1;
        if !self.immutable {
            // interruption event: re-run the offline algorithm (Alg. 4)
            self.needs_reschedule = true;
            return;
        }
        // Maintain the sorted cache (binary insert per task) and
        // evaluate the skip precondition in the same pass: the batch is
        // skippable iff a threshold from a live selection exists, no
        // other interruption is pending, and every new entry sorts
        // strictly after the admission boundary.
        let mut skip = !self.needs_reschedule && self.threshold.is_some() && !ids.is_empty();
        let (t_key, t_id) = self.threshold.unwrap_or((0, 0));
        for &id in ids {
            let t = pool.get(id);
            let entry = admission_entry(self.cfg.adaptor.effective(t), t.slo.tpot, id);
            if skip && (entry.0, entry.1) <= (t_key, t_id) {
                skip = false;
            }
            let pos = self
                .sorted
                .partition_point(|&(k, tid, _)| (k, tid) < (entry.0, entry.1));
            self.sorted.insert(pos, entry);
        }
        self.cache_generation = self.generation;
        if skip {
            // Provably a no-op reschedule: the admitted prefix, mask and
            // prefill queue are untouched; the new tasks stay Waiting,
            // exactly what the rebuild would leave. The one side effect
            // a real reschedule has on the scan — resetting the column
            // cursor — is replicated so decode order stays bit-exact.
            self.decisions_skipped += 1;
            self.col = 0;
        } else {
            self.needs_reschedule = true;
        }
    }

    fn on_completion(&mut self, pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
        self.generation += 1;
        if self.immutable {
            // Departures notify with the finished husk still pooled
            // (utility and TPOT intact), so the removal key is exactly
            // the insertion key — binary remove per task.
            for &id in ids {
                let t = pool.get(id);
                let (key, _, _) = admission_entry(self.cfg.adaptor.effective(t), t.slo.tpot, id);
                let pos = self
                    .sorted
                    .partition_point(|&(k, tid, _)| (k, tid) < (key, id));
                debug_assert!(
                    pos < self.sorted.len() && self.sorted[pos].1 == id,
                    "departing task missing from candidate cache"
                );
                self.sorted.remove(pos);
            }
            self.cache_generation = self.generation;
        }
        // A departure shrinks the admitted set (freed quota may admit a
        // paused task), so it always forces a reschedule; the stale
        // threshold is guarded by needs_reschedule until then.
        self.needs_reschedule = true;
    }

    fn next_step(&mut self, pool: &mut TaskPool, now: Micros) -> Step {
        if self.needs_reschedule {
            self.reschedule(pool, now);
        }

        // Prefill newly admitted tasks before resuming the column scan.
        while let Some(id) = self.to_prefill.pop_front() {
            if !pool.get(id).is_finished() {
                return Step::Prefill { task: id };
            }
        }

        if self.mask.is_empty() {
            return Step::Idle;
        }

        // Column scan: skip columns whose batch is entirely finished
        // (can happen between a completion event and the reschedule).
        // The batch is the column's prefix of the mask rows filtered to
        // running tasks, written into the recycled buffer — the server
        // hands it back via recycle_batch, so the steady-state scan
        // allocates nothing.
        let columns = self.mask.columns();
        for _ in 0..columns {
            let j = self.col;
            self.col = (self.col + 1) % columns;
            self.batch.clear();
            self.batch.extend(
                self.mask
                    .column_batch(j)
                    .iter()
                    .map(|&(id, _)| id)
                    .filter(|&id| pool.get(id).state == TaskState::Running),
            );
            if !self.batch.is_empty() {
                return Step::Decode { tasks: std::mem::take(&mut self.batch) };
            }
        }
        Step::Idle
    }

    fn recycle_batch(&mut self, mut batch: Vec<TaskId>) {
        batch.clear();
        // keep whichever buffer holds the larger allocation (the server
        // may hand back a trimmed batch it rebuilt under memory pressure)
        if batch.capacity() > self.batch.capacity() {
            self.batch = batch;
        }
    }

    fn decisions(&self) -> u64 {
        self.reschedules
    }

    fn decisions_skipped(&self) -> u64 {
        self.decisions_skipped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};

    fn pool_with(tasks: Vec<Task>) -> TaskPool {
        let mut p = TaskPool::new();
        for t in tasks {
            p.insert(t);
        }
        p
    }

    fn mark_prefilled(pool: &mut TaskPool, id: TaskId, now: Micros) {
        let t = pool.get_mut(id);
        t.state = TaskState::Running;
        t.prefill_end = Some(now);
        t.on_token(now);
    }

    #[test]
    fn arrival_triggers_reschedule_and_prefill() {
        let mut pool = pool_with(vec![
            Task::new(0, TaskClass::RealTime, 0, 16, 10, 100.0),
            Task::new(1, TaskClass::Voice, 0, 16, 10, 1.0),
        ]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0, 1], 0);

        // first steps must be prefills, real-time task first (higher r_i)
        let s1 = p.next_step(&mut pool, 0);
        assert_eq!(s1, Step::Prefill { task: 0 });
        mark_prefilled(&mut pool, 0, 30_000);
        let s2 = p.next_step(&mut pool, 30_000);
        assert_eq!(s2, Step::Prefill { task: 1 });
        mark_prefilled(&mut pool, 1, 60_000);

        // then decode columns; both tasks running
        let s3 = p.next_step(&mut pool, 60_000);
        match s3 {
            Step::Decode { tasks } => {
                assert!(tasks.contains(&0));
            }
            s => panic!("expected decode, got {s:?}"),
        }
        assert_eq!(p.reschedules, 1);
    }

    #[test]
    fn mask_columns_shrink_batches_for_low_rate_tasks() {
        // RT task (20 t/s quota) + voice task (8 t/s quota): voice appears
        // in only 8 of 20 columns.
        let mut pool = pool_with(vec![
            Task::new(0, TaskClass::RealTime, 0, 16, 100, 100.0),
            Task::new(1, TaskClass::Voice, 0, 16, 100, 1.0),
        ]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0, 1], 0);
        let _ = p.next_step(&mut pool, 0);
        mark_prefilled(&mut pool, 0, 1);
        let _ = p.next_step(&mut pool, 1);
        mark_prefilled(&mut pool, 1, 2);

        let mut batch_sizes = Vec::new();
        for _ in 0..20 {
            match p.next_step(&mut pool, 10) {
                Step::Decode { tasks } => batch_sizes.push(tasks.len()),
                s => panic!("expected decode, got {s:?}"),
            }
        }
        let twos = batch_sizes.iter().filter(|&&n| n == 2).count();
        let ones = batch_sizes.iter().filter(|&&n| n == 1).count();
        assert_eq!(twos, 8, "voice quota columns");
        assert_eq!(ones, 12, "RT-only columns");
    }

    #[test]
    fn completion_triggers_reschedule() {
        let mut pool = pool_with(vec![Task::new(0, TaskClass::Voice, 0, 16, 1, 1.0)]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0], 0);
        let _ = p.next_step(&mut pool, 0);
        mark_prefilled(&mut pool, 0, 1); // output_len 1 -> finished
        assert!(pool.get(0).is_finished());
        p.on_completion(&mut pool, &[0], 1);
        assert_eq!(p.next_step(&mut pool, 2), Step::Idle);
        assert_eq!(p.reschedules, 2);
    }

    #[test]
    fn overload_pauses_low_utility_tasks() {
        // 40 RT tasks cannot all be admitted; the rest must stay waiting.
        let tasks: Vec<Task> = (0..40)
            .map(|i| Task::new(i, TaskClass::RealTime, 0, 16, 50, 100.0))
            .collect();
        let mut pool = pool_with(tasks);
        let ids: Vec<TaskId> = (0..40).collect();
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &ids, 0);
        let _ = p.next_step(&mut pool, 0);
        let admitted = p.admitted().len();
        assert!(admitted > 0 && admitted < 40, "admitted={admitted}");
        let waiting = pool.ids_in_state(TaskState::Waiting).len();
        assert_eq!(waiting, 40 - admitted);
    }

    #[test]
    fn sjf_adaptor_prefers_fresh_tasks_on_reschedule() {
        // Two identical voice tasks; one has generated many tokens. With
        // SjfDecay and capacity for only one (tiny max_batch), the fresh
        // task wins the slot.
        let mut lat = LatencyModel::paper_calibrated();
        lat.max_batch = 1;
        let mut t0 = Task::new(0, TaskClass::Voice, 0, 16, 100, 10.0);
        t0.tokens_generated = 64;
        t0.state = TaskState::Running;
        t0.prefill_end = Some(1);
        let t1 = Task::new(1, TaskClass::Voice, 0, 16, 100, 10.0);
        let mut pool = pool_with(vec![t0, t1]);
        let mut p = SlicePolicy::new(
            lat,
            SliceConfig {
                adaptor: UtilityAdaptor::SjfDecay { factor: 0.5, tau: 16 },
                ..SliceConfig::default()
            },
        );
        p.on_arrival(&mut pool, &[1], 0);
        let step = p.next_step(&mut pool, 0);
        assert_eq!(step, Step::Prefill { task: 1 });
        assert_eq!(pool.get(0).state, TaskState::Paused, "long task preempted");
    }

    #[test]
    fn memory_budget_limits_admissions() {
        // 8 mid-generation voice tasks, each holding ~11.5 MiB of cache;
        // a 32 MiB budget keeps only 2 scheduled, the rest pause
        // (memory, not the cycle cap, binds)
        let mk_tasks = || -> Vec<Task> {
            (0..8)
                .map(|i| {
                    let mut t = Task::new(i, TaskClass::Voice, 0, 32, 400, 1.0);
                    t.state = TaskState::Running;
                    t.prefill_end = Some(1);
                    t.tokens_generated = 335; // seq_len 367 -> 368-token footprint
                    t
                })
                .collect()
        };
        // default geometry: 32 KiB/token, 16-token blocks
        let budget = MemoryBudget {
            capacity: 32 * 1024 * 1024,
            cfg: MemoryConfig::default(),
        };
        assert_eq!(budget.footprint_bytes(367), 368 * 32 * 1024); // 11.5 MiB
        let mut pool = pool_with(mk_tasks());
        let ids: Vec<TaskId> = (0..8).collect();
        let mut aware = SlicePolicy::new(
            LatencyModel::paper_calibrated(),
            SliceConfig { memory: Some(budget), ..SliceConfig::default() },
        );
        aware.on_arrival(&mut pool, &ids, 0);
        let _ = aware.next_step(&mut pool, 0);
        assert_eq!(aware.admitted().len(), 2, "32 MiB / 11.5 MiB = 2 tasks");
        assert_eq!(pool.ids_in_state(TaskState::Paused).len(), 6);

        // the oblivious policy keeps all 8 (cycle cap alone allows it)
        let mut pool = pool_with(mk_tasks());
        let mut oblivious =
            SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        oblivious.on_arrival(&mut pool, &ids, 0);
        let _ = oblivious.next_step(&mut pool, 0);
        assert_eq!(oblivious.admitted().len(), 8);
    }

    #[test]
    fn idle_when_no_tasks() {
        let mut pool = TaskPool::new();
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        assert_eq!(p.next_step(&mut pool, 0), Step::Idle);
    }

    /// Drive the incremental and the skip-disabled policy in lockstep,
    /// asserting identical steps (prefills replayed into both pools).
    fn lockstep_steps(
        a: &mut SlicePolicy,
        pool_a: &mut TaskPool,
        b: &mut SlicePolicy,
        pool_b: &mut TaskPool,
        now: &mut Micros,
        n: usize,
    ) {
        for _ in 0..n {
            let sa = a.next_step(pool_a, *now);
            let sb = b.next_step(pool_b, *now);
            assert_eq!(sa, sb, "incremental and disabled policies diverged");
            *now += 1;
            if let Step::Prefill { task } = sa {
                mark_prefilled(pool_a, task, *now);
                mark_prefilled(pool_b, task, *now);
            }
        }
    }

    #[test]
    fn low_rate_arrival_is_skipped_bit_exactly() {
        // overloaded pool (cycle-stop): a later arrival sorting past the
        // admission boundary is provably a no-op — the incremental
        // policy skips the reschedule, the disabled one pays for it,
        // and the emitted steps stay identical
        let mk_pool = || {
            pool_with(
                (0..30)
                    .map(|i| Task::new(i, TaskClass::RealTime, 0, 16, 50, 100.0))
                    .collect(),
            )
        };
        let mut pool_a = mk_pool();
        let mut pool_b = mk_pool();
        let mut a = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        let mut b = SlicePolicy::new(
            LatencyModel::paper_calibrated(),
            SliceConfig { incremental: false, ..SliceConfig::default() },
        );
        let ids: Vec<TaskId> = (0..30).collect();
        a.on_arrival(&mut pool_a, &ids, 0);
        b.on_arrival(&mut pool_b, &ids, 0);
        let mut now: Micros = 0;
        lockstep_steps(&mut a, &mut pool_a, &mut b, &mut pool_b, &mut now, 5);
        assert_eq!(a.reschedules, 1);

        // rate 0.001 * 0.05 — far below the boundary: skip
        pool_a.insert(Task::new(100, TaskClass::Voice, now, 16, 50, 0.001));
        pool_b.insert(Task::new(100, TaskClass::Voice, now, 16, 50, 0.001));
        a.on_arrival(&mut pool_a, &[100], now);
        b.on_arrival(&mut pool_b, &[100], now);
        assert_eq!(a.decisions_skipped, 1, "arrival past the boundary skips");
        assert_eq!(a.reschedules, 1);
        lockstep_steps(&mut a, &mut pool_a, &mut b, &mut pool_b, &mut now, 10);
        assert_eq!(b.reschedules, 2);
        assert_eq!(
            a.reschedules + a.decisions_skipped,
            b.reschedules,
            "skip accounting identity"
        );
        assert_eq!(pool_a.get(100).state, pool_b.get(100).state);

        // a high-rate arrival beats the boundary: both must reschedule
        pool_a.insert(Task::new(101, TaskClass::RealTime, now, 16, 50, 1e6));
        pool_b.insert(Task::new(101, TaskClass::RealTime, now, 16, 50, 1e6));
        a.on_arrival(&mut pool_a, &[101], now);
        b.on_arrival(&mut pool_b, &[101], now);
        lockstep_steps(&mut a, &mut pool_a, &mut b, &mut pool_b, &mut now, 10);
        assert_eq!(a.decisions_skipped, 1);
        assert_eq!(b.reschedules, 3);
        assert_eq!(a.reschedules + a.decisions_skipped, b.reschedules);
        assert_eq!(a.full_rebuilds, 0, "immutable regime never rebuilds");
    }

    #[test]
    fn no_skip_when_everything_is_admitted() {
        // 2 tasks, both admitted -> no admission boundary -> a third
        // arrival must reschedule even though its rate is the lowest
        let mut pool = pool_with(vec![
            Task::new(0, TaskClass::RealTime, 0, 16, 10, 100.0),
            Task::new(1, TaskClass::Voice, 0, 16, 10, 1.0),
        ]);
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &[0, 1], 0);
        let _ = p.next_step(&mut pool, 0);
        pool.insert(Task::new(2, TaskClass::Voice, 0, 16, 10, 0.001));
        p.on_arrival(&mut pool, &[2], 0);
        let _ = p.next_step(&mut pool, 0);
        assert_eq!(p.decisions_skipped, 0);
        assert_eq!(p.reschedules, 2);
        assert!(p.admitted().contains(&2), "third task joins the admitted set");
    }

    #[test]
    fn completion_blocks_skip_until_next_selection() {
        // overload, then a completion (stale boundary), then a low-rate
        // arrival before any next_step: the skip must not fire
        let mut pool = pool_with(
            (0..30)
                .map(|i| Task::new(i, TaskClass::RealTime, 0, 16, 50, 100.0))
                .collect(),
        );
        let ids: Vec<TaskId> = (0..30).collect();
        let mut p = SlicePolicy::with_defaults(LatencyModel::paper_calibrated());
        p.on_arrival(&mut pool, &ids, 0);
        let _ = p.next_step(&mut pool, 0);
        // finish task 0 by hand (as the serving loop would after its
        // last token) and notify
        mark_prefilled(&mut pool, 0, 1);
        let t = pool.get_mut(0);
        t.tokens_generated = 50;
        t.state = TaskState::Finished;
        p.on_completion(&mut pool, &[0], 2);
        pool.insert(Task::new(100, TaskClass::Voice, 2, 16, 50, 0.001));
        p.on_arrival(&mut pool, &[100], 2);
        assert_eq!(p.decisions_skipped, 0, "pending departure blocks the skip");
        let _ = p.next_step(&mut pool, 3);
        assert_eq!(p.reschedules, 2);
    }
}
