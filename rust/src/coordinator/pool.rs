//! Task pool: owns every task known to the serving system.
//!
//! Tasks are issued dense ids by the workload generator, so the pool is a
//! flat Vec indexed by id — O(1) lookup on the decode hot path with no
//! hashing.

use super::task::{Task, TaskId, TaskState};

/// All tasks the server has accepted, indexed by task id.
#[derive(Debug, Default)]
pub struct TaskPool {
    tasks: Vec<Task>,
}

impl TaskPool {
    /// An empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a task; its id must equal its index (dense ids).
    pub fn insert(&mut self, task: Task) {
        assert_eq!(
            task.id as usize,
            self.tasks.len(),
            "task ids must be dense and inserted in order"
        );
        self.tasks.push(task);
    }

    /// Look up a task by id (panics on unknown id).
    pub fn get(&self, id: TaskId) -> &Task {
        &self.tasks[id as usize]
    }

    /// Mutable task lookup (panics on unknown id).
    pub fn get_mut(&mut self, id: TaskId) -> &mut Task {
        &mut self.tasks[id as usize]
    }

    /// Number of tasks ever accepted.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// True when no tasks were accepted yet.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Iterate all tasks in id order.
    pub fn iter(&self) -> impl Iterator<Item = &Task> {
        self.tasks.iter()
    }

    /// Ids of tasks in a given state.
    pub fn ids_in_state(&self, state: TaskState) -> Vec<TaskId> {
        self.tasks
            .iter()
            .filter(|t| t.state == state)
            .map(|t| t.id)
            .collect()
    }

    /// Every task that still needs service (not finished).
    pub fn unfinished(&self) -> Vec<TaskId> {
        self.tasks
            .iter()
            .filter(|t| !t.is_finished())
            .map(|t| t.id)
            .collect()
    }

    /// Consume the pool, returning all tasks (end-of-run metrics).
    pub fn into_tasks(self) -> Vec<Task> {
        self.tasks
    }

    /// All tasks as a slice (id-indexed).
    pub fn tasks(&self) -> &[Task] {
        &self.tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::TaskClass;

    #[test]
    fn dense_ids_enforced() {
        let mut p = TaskPool::new();
        p.insert(Task::new(0, TaskClass::Voice, 0, 8, 4, 1.0));
        p.insert(Task::new(1, TaskClass::RealTime, 0, 8, 4, 100.0));
        assert_eq!(p.len(), 2);
        assert_eq!(p.get(1).class, TaskClass::RealTime);
    }

    #[test]
    #[should_panic]
    fn non_dense_id_panics() {
        let mut p = TaskPool::new();
        p.insert(Task::new(5, TaskClass::Voice, 0, 8, 4, 1.0));
    }

    #[test]
    fn state_queries() {
        let mut p = TaskPool::new();
        p.insert(Task::new(0, TaskClass::Voice, 0, 8, 4, 1.0));
        p.insert(Task::new(1, TaskClass::Voice, 0, 8, 4, 1.0));
        p.get_mut(0).state = TaskState::Running;
        assert_eq!(p.ids_in_state(TaskState::Running), vec![0]);
        assert_eq!(p.ids_in_state(TaskState::Waiting), vec![1]);
        assert_eq!(p.unfinished(), vec![0, 1]);
        p.get_mut(0).finish(100);
        assert_eq!(p.unfinished(), vec![1]);
    }
}
