//! L3 coordinator: the paper's contribution (DESIGN.md "Layers" and
//! "Scheduling cycle").
//!
//! Contract: a [`scheduler::Policy`] owns admission and batching over a
//! [`pool::TaskPool`]; the serving loop delivers arrival/completion
//! events and executes whatever [`scheduler::Step`]s the policy emits.
//!
//! * [`task`] — SLO model and task lifecycle.
//! * [`pool`] — task ownership.
//! * [`selection`] — utility-maximizing task selection (Alg. 2).
//! * [`mask`] — decode-mask matrix rate allocation (Alg. 3, Fig. 4).
//! * [`slice`] — the online SLICE policy (Alg. 1/4).
//! * [`preemption`] — utility adaptation / preemption controller (§IV-E).
//! * [`orca`], [`fastserve`] — the paper's baselines.
//! * [`scheduler`] — the policy interface all three implement.

pub mod fastserve;
pub mod mask;
pub mod orca;
pub mod pool;
pub mod preemption;
pub mod scheduler;
pub mod selection;
pub mod slice;
pub mod task;

pub use fastserve::{FastServeConfig, FastServePolicy};
pub use mask::{period_eq7, DecodeMask, IncrementalPeriod};
pub use orca::OrcaPolicy;
pub use pool::TaskPool;
pub use preemption::UtilityAdaptor;
pub use scheduler::{Policy, Step};
pub use selection::{
    select_tasks, select_tasks_with, Candidate, Selection, SelectionScratch, CYCLE_CAP,
};
pub use slice::{SliceConfig, SlicePolicy};
pub use task::{SloSpec, Task, TaskClass, TaskId, TaskState};
