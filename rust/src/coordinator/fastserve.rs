//! FastServe baseline: skip-join multi-level feedback queue with
//! iteration-level preemption (Wu et al., arXiv:2305.05920 — the paper's
//! second baseline).
//!
//! Behaviour reproduced:
//!   * K priority levels; level k's quantum is `base_quantum << k` output
//!     tokens. A task that exhausts its quantum at level k demotes to
//!     k+1 (classic MLFQ aging toward long jobs).
//!   * **Skip-join**: a task does not start at the top level; it joins
//!     the level whose quantum matches its prompt length (longer prompts
//!     imply longer jobs), avoiding pointless demotion churn.
//!   * **Iteration-level preemption**: the decode batch is re-formed from
//!     the highest-priority queues at every iteration boundary, so a new
//!     arrival at a higher level preempts lower-level tasks immediately
//!     after the in-flight forward pass.
//!
//! Like Orca (and per the paper's §VI-C observation), FastServe batches
//! every selected task into a single forward pass and gives them all the
//! same decoding rate — it has no notion of per-task SLO.

use std::collections::VecDeque;

use crate::util::Micros;

use super::pool::TaskPool;
use super::scheduler::{Policy, Step};
use super::task::{TaskId, TaskState};

/// FastServe configuration.
#[derive(Debug, Clone)]
pub struct FastServeConfig {
    /// Number of MLFQ levels.
    pub levels: usize,
    /// Quantum (output tokens) at level 0; doubles per level.
    pub base_quantum: u32,
    /// Prompt-length threshold for skip-join at level 0; doubles per level.
    pub base_join_len: u32,
    /// Max concurrent tasks per decode iteration.
    pub max_batch: u32,
}

impl Default for FastServeConfig {
    fn default() -> Self {
        FastServeConfig { levels: 6, base_quantum: 2, base_join_len: 16, max_batch: 32 }
    }
}

/// FastServe skip-join MLFQ policy.
pub struct FastServePolicy {
    cfg: FastServeConfig,
    /// queues[k] = FIFO of task ids at priority level k (0 = highest).
    queues: Vec<VecDeque<TaskId>>,
    /// Tokens generated since the task entered its current level.
    level_tokens: Vec<(TaskId, u32, usize)>, // (task, tokens_at_level, level)
}

impl FastServePolicy {
    /// Build the policy from an MLFQ shape.
    pub fn new(cfg: FastServeConfig) -> Self {
        let queues = (0..cfg.levels).map(|_| VecDeque::new()).collect();
        FastServePolicy { cfg, queues, level_tokens: Vec::new() }
    }

    /// Build with [`FastServeConfig::default`].
    pub fn with_defaults() -> Self {
        Self::new(FastServeConfig::default())
    }

    fn quantum(&self, level: usize) -> u32 {
        self.cfg.base_quantum << level.min(31)
    }

    /// Skip-join: initial level from the prompt length.
    fn join_level(&self, prompt_len: u32) -> usize {
        let mut level = 0usize;
        let mut threshold = self.cfg.base_join_len;
        while level + 1 < self.cfg.levels && prompt_len > threshold {
            level += 1;
            threshold <<= 1;
        }
        level
    }

    fn entry_mut(&mut self, id: TaskId) -> Option<&mut (TaskId, u32, usize)> {
        self.level_tokens.iter_mut().find(|e| e.0 == id)
    }

    fn remove_task(&mut self, id: TaskId) {
        for q in &mut self.queues {
            q.retain(|&x| x != id);
        }
        self.level_tokens.retain(|e| e.0 != id);
    }

    /// The level a task currently sits at (tests).
    pub fn level_of(&self, id: TaskId) -> Option<usize> {
        self.level_tokens.iter().find(|e| e.0 == id).map(|e| e.2)
    }

    /// Account one generated token and demote on quantum exhaustion.
    fn charge_token(&mut self, id: TaskId) {
        let levels = self.cfg.levels;
        let Some(entry) = self.entry_mut(id) else { return };
        entry.1 += 1;
        let (tokens, level) = (entry.1, entry.2);
        if tokens >= self.quantum(level) && level + 1 < levels {
            // demote: move to the back of the next queue
            let Some(entry) = self.entry_mut(id) else { return };
            entry.1 = 0;
            entry.2 = level + 1;
            self.queues[level].retain(|&x| x != id);
            self.queues[level + 1].push_back(id);
        }
    }
}

impl Policy for FastServePolicy {
    fn name(&self) -> &'static str {
        "FastServe"
    }

    fn on_arrival(&mut self, pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
        for &id in ids {
            let level = self.join_level(pool.get(id).prompt_len);
            self.queues[level].push_back(id);
            self.level_tokens.push((id, 0, level));
        }
    }

    fn on_completion(&mut self, _pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
        for &id in ids {
            self.remove_task(id);
        }
    }

    fn next_step(&mut self, pool: &mut TaskPool, _now: Micros) -> Step {
        // Form the iteration batch from the highest-priority queues.
        let mut batch: Vec<TaskId> = Vec::new();
        for q in &self.queues {
            for &id in q {
                if batch.len() as u32 >= self.cfg.max_batch {
                    break;
                }
                if !pool.get(id).is_finished() {
                    batch.push(id);
                }
            }
            if batch.len() as u32 >= self.cfg.max_batch {
                break;
            }
        }
        if batch.is_empty() {
            return Step::Idle;
        }

        // Prefill before decode, in priority order.
        for &id in &batch {
            let t = pool.get_mut(id);
            if t.state == TaskState::Waiting || t.state == TaskState::Paused {
                // migrated-in tasks arrive prefilled (Paused): straight
                // back to decode, never a second prefill
                t.state = if t.prefill_end.is_some() {
                    TaskState::Running
                } else {
                    TaskState::Admitted
                };
            }
            if pool.get(id).state == TaskState::Admitted {
                // charge the first token (produced by prefill) to the quantum
                self.charge_token(id);
                return Step::Prefill { task: id };
            }
        }

        let decode_batch: Vec<TaskId> = batch
            .into_iter()
            .filter(|&id| pool.get(id).state == TaskState::Running)
            .collect();
        if decode_batch.is_empty() {
            return Step::Idle;
        }
        for &id in &decode_batch {
            self.charge_token(id);
        }
        Step::Decode { tasks: decode_batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};

    fn pool_with_prompts(prompts: &[u32]) -> TaskPool {
        let mut p = TaskPool::new();
        for (i, &pl) in prompts.iter().enumerate() {
            p.insert(Task::new(i as u64, TaskClass::Voice, 0, pl, 100, 1.0));
        }
        p
    }

    fn mark_prefilled(pool: &mut TaskPool, id: TaskId, now: Micros) {
        let t = pool.get_mut(id);
        t.state = TaskState::Running;
        t.prefill_end = Some(now);
        t.on_token(now);
    }

    #[test]
    fn skip_join_assigns_levels_by_prompt_length() {
        let mut pool = pool_with_prompts(&[8, 20, 40, 200]);
        let mut p = FastServePolicy::with_defaults();
        p.on_arrival(&mut pool, &[0, 1, 2, 3], 0);
        assert_eq!(p.level_of(0), Some(0)); // 8 <= 16
        assert_eq!(p.level_of(1), Some(1)); // 16 < 20 <= 32
        assert_eq!(p.level_of(2), Some(2)); // 32 < 40 <= 64
        assert_eq!(p.level_of(3), Some(4)); // 128 < 200 <= 256
    }

    #[test]
    fn quantum_exhaustion_demotes() {
        let mut pool = pool_with_prompts(&[8]);
        let mut p = FastServePolicy::with_defaults();
        p.on_arrival(&mut pool, &[0], 0);
        assert_eq!(p.level_of(0), Some(0));
        // prefill consumes 1 of the level-0 quantum (2 tokens)
        assert_eq!(p.next_step(&mut pool, 0), Step::Prefill { task: 0 });
        mark_prefilled(&mut pool, 0, 1);
        // one decode exhausts the level-0 quantum -> demote to level 1
        let _ = p.next_step(&mut pool, 2);
        assert_eq!(p.level_of(0), Some(1));
        // quantum at level 1 is 4 tokens; 4 more decodes demote to level 2
        for _ in 0..4 {
            let _ = p.next_step(&mut pool, 3);
        }
        assert_eq!(p.level_of(0), Some(2));
    }

    #[test]
    fn higher_priority_arrival_preempts_next_iteration() {
        let mut pool = pool_with_prompts(&[100, 8]);
        let mut p = FastServePolicy::with_defaults();
        p.on_arrival(&mut pool, &[0], 0); // long prompt -> deep level
        assert_eq!(p.next_step(&mut pool, 0), Step::Prefill { task: 0 });
        mark_prefilled(&mut pool, 0, 1);
        // short task arrives at level 0, must be served at the next
        // iteration boundary (prefill first)
        p.on_arrival(&mut pool, &[1], 2);
        assert_eq!(p.next_step(&mut pool, 2), Step::Prefill { task: 1 });
        mark_prefilled(&mut pool, 1, 3);
        match p.next_step(&mut pool, 4) {
            Step::Decode { tasks } => assert_eq!(tasks[0], 1, "level-0 first"),
            s => panic!("expected decode, got {s:?}"),
        }
    }

    #[test]
    fn batch_respects_cap() {
        let prompts: Vec<u32> = (0..40).map(|_| 8).collect();
        let mut pool = pool_with_prompts(&prompts);
        let mut p = FastServePolicy::new(FastServeConfig {
            max_batch: 4,
            ..FastServeConfig::default()
        });
        let ids: Vec<TaskId> = (0..40).collect();
        p.on_arrival(&mut pool, &ids, 0);
        for i in 0..4u64 {
            assert_eq!(p.next_step(&mut pool, 0), Step::Prefill { task: i });
            mark_prefilled(&mut pool, i, 1);
        }
        match p.next_step(&mut pool, 2) {
            Step::Decode { tasks } => assert_eq!(tasks.len(), 4),
            s => panic!("expected decode, got {s:?}"),
        }
    }

    #[test]
    fn completion_removes_from_queues() {
        let mut pool = pool_with_prompts(&[8, 8]);
        let mut p = FastServePolicy::with_defaults();
        p.on_arrival(&mut pool, &[0, 1], 0);
        pool.get_mut(0).finish(1);
        p.on_completion(&mut pool, &[0], 1);
        assert_eq!(p.level_of(0), None);
        assert_eq!(p.next_step(&mut pool, 2), Step::Prefill { task: 1 });
    }

    #[test]
    fn idle_when_empty() {
        let mut pool = TaskPool::new();
        let mut p = FastServePolicy::with_defaults();
        assert_eq!(p.next_step(&mut pool, 0), Step::Idle);
    }
}
