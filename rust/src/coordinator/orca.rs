//! Orca baseline: iteration-level continuous batching with FCFS admission
//! (Yu et al., OSDI'22 — the paper's primary baseline, and the default
//! scheduling strategy of FastLLM/FasterTransformer/vLLM).
//!
//! Behaviour reproduced (paper §VI-A "Baselines" and §VI-C analysis):
//! every arriving task is admitted into the running batch as soon as a
//! slot is free (FCFS, iteration boundaries); every decode iteration runs
//! the **entire** running batch through one forward pass, so all tasks
//! receive the same decoding rate; finished tasks exit and waiting tasks
//! join between iterations.

use std::collections::VecDeque;

use crate::util::Micros;

use super::pool::TaskPool;
use super::scheduler::{Policy, Step};
use super::task::{TaskId, TaskState};

/// Orca-style continuous batching policy.
pub struct OrcaPolicy {
    /// Maximum concurrent tasks in the running batch (the "predefined
    /// maximum batch processing capacity" of §VI-C).
    max_batch: u32,
    /// FCFS arrival queue.
    waiting: VecDeque<TaskId>,
    /// Admitted tasks, in admission order.
    running: Vec<TaskId>,
}

impl OrcaPolicy {
    /// Build the policy with a max running-batch capacity.
    pub fn new(max_batch: u32) -> Self {
        OrcaPolicy { max_batch, waiting: VecDeque::new(), running: Vec::new() }
    }

    /// Number of currently admitted tasks (tests/observability).
    pub fn running_len(&self) -> usize {
        self.running.len()
    }
}

impl Policy for OrcaPolicy {
    fn name(&self) -> &'static str {
        "Orca"
    }

    fn on_arrival(&mut self, _pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
        self.waiting.extend(ids.iter().copied());
    }

    fn on_completion(&mut self, _pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
        self.running.retain(|id| !ids.contains(id));
    }

    fn next_step(&mut self, pool: &mut TaskPool, _now: Micros) -> Step {
        // FCFS admission at the iteration boundary.
        while (self.running.len() as u32) < self.max_batch {
            let Some(id) = self.waiting.pop_front() else { break };
            if pool.get(id).is_finished() {
                continue;
            }
            let t = pool.get_mut(id);
            // a migrated-in task arrives with its prefill (and KV record)
            // intact: it rejoins decode directly, no second prefill
            t.state = if t.prefill_end.is_some() {
                TaskState::Running
            } else {
                TaskState::Admitted
            };
            self.running.push(id);
        }

        // Prefill any admitted-but-unprefilled task first (FCFS order).
        for &id in &self.running {
            if pool.get(id).state == TaskState::Admitted {
                return Step::Prefill { task: id };
            }
        }

        // One iteration over the whole running batch.
        let batch: Vec<TaskId> = self
            .running
            .iter()
            .copied()
            .filter(|&id| pool.get(id).state == TaskState::Running)
            .collect();
        if batch.is_empty() {
            Step::Idle
        } else {
            Step::Decode { tasks: batch }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::{Task, TaskClass};

    fn pool_with(n: u64) -> TaskPool {
        let mut p = TaskPool::new();
        for i in 0..n {
            p.insert(Task::new(i, TaskClass::Voice, 0, 16, 10, 1.0));
        }
        p
    }

    fn mark_prefilled(pool: &mut TaskPool, id: TaskId, now: Micros) {
        let t = pool.get_mut(id);
        t.state = TaskState::Running;
        t.prefill_end = Some(now);
        t.on_token(now);
    }

    #[test]
    fn fcfs_admission_then_whole_batch_decode() {
        let mut pool = pool_with(3);
        let mut p = OrcaPolicy::new(32);
        p.on_arrival(&mut pool, &[0, 1, 2], 0);

        for expected in 0..3u64 {
            match p.next_step(&mut pool, 0) {
                Step::Prefill { task } => {
                    assert_eq!(task, expected, "prefill in FCFS order");
                    mark_prefilled(&mut pool, task, 1);
                }
                s => panic!("expected prefill, got {s:?}"),
            }
        }
        match p.next_step(&mut pool, 10) {
            Step::Decode { tasks } => assert_eq!(tasks, vec![0, 1, 2]),
            s => panic!("expected full-batch decode, got {s:?}"),
        }
    }

    #[test]
    fn respects_max_batch() {
        let mut pool = pool_with(5);
        let mut p = OrcaPolicy::new(2);
        p.on_arrival(&mut pool, &[0, 1, 2, 3, 4], 0);
        let _ = p.next_step(&mut pool, 0);
        assert_eq!(p.running_len(), 2);
        // completing one admits the next FCFS task
        pool.get_mut(0).finish(5);
        p.on_completion(&mut pool, &[0], 5);
        let _ = p.next_step(&mut pool, 6);
        assert_eq!(p.running_len(), 2);
        assert!(pool.get(2).state != TaskState::Waiting);
    }

    #[test]
    fn finished_tasks_leave_the_batch() {
        let mut pool = pool_with(2);
        let mut p = OrcaPolicy::new(32);
        p.on_arrival(&mut pool, &[0, 1], 0);
        let _ = p.next_step(&mut pool, 0);
        mark_prefilled(&mut pool, 0, 1);
        let _ = p.next_step(&mut pool, 1);
        mark_prefilled(&mut pool, 1, 2);
        pool.get_mut(0).finish(10);
        p.on_completion(&mut pool, &[0], 10);
        match p.next_step(&mut pool, 11) {
            Step::Decode { tasks } => assert_eq!(tasks, vec![1]),
            s => panic!("expected decode, got {s:?}"),
        }
    }

    #[test]
    fn idle_when_empty() {
        let mut pool = TaskPool::new();
        let mut p = OrcaPolicy::new(32);
        assert_eq!(p.next_step(&mut pool, 0), Step::Idle);
    }
}
