//! Utility-maximizing task selection (paper §IV-C, Algorithm 2).
//!
//! Each candidate task is scored with a *utility rate* r_i = U_i * T_TPOT
//! (Eq. 6): the utility earned per token-per-second of capacity it
//! consumes. Tasks are admitted greedily in descending r_i order; after
//! each admission the scheduling-cycle duration is re-estimated with
//! Eq. (7) over the admitted quotas, and the first admission that pushes
//! the cycle past the cap (1000 ms — one cycle must deliver every task's
//! per-second quota) is rolled back, terminating selection.
//!
//! Memory extension (DESIGN.md "Memory model"): when the device's KV
//! capacity is finite, selection carries a second knapsack dimension —
//! each candidate's KV footprint ([`Candidate::kv_bytes`]; the SLICE
//! policy projects the *current* block-rounded footprint, re-evaluated
//! at every Alg. 4 reschedule). The admission that overflows capacity
//! is rolled back and terminates selection with exactly the
//! non-replacement semantics of the cycle cap, so a schedule is only
//! emitted if its resident KV fits the device (cf. the
//! projected-occupancy admission of SLOs-Serve, arXiv:2504.08784).

use crate::engine::latency::LatencyModel;
use crate::util::Micros;

use super::mask::period_eq7;
use super::task::TaskId;

/// A candidate for selection.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// The candidate task.
    pub id: TaskId,
    /// Base or adapted utility U_i.
    pub utility: f64,
    /// TPOT requirement in micros.
    pub tpot: Micros,
    /// The candidate's KV footprint in bytes, as projected by the
    /// caller (SLICE uses the current block-rounded footprint,
    /// `MemoryBudget::footprint_bytes`). Ignored unless selection runs
    /// with a finite KV capacity; zero for memory-oblivious callers.
    pub kv_bytes: u64,
}

impl Candidate {
    /// Utility rate r_i = U_i * T_TPOT (Eq. 6). T_TPOT in seconds so the
    /// scale matches the paper's formulation.
    pub fn utility_rate(&self) -> f64 {
        self.utility * (self.tpot as f64 / 1e6)
    }

    /// Per-cycle token quota v_i = ceil(1s / T_TPOT).
    pub fn quota(&self) -> u32 {
        (1e6 / self.tpot as f64).ceil() as u32
    }
}

/// Result of one selection round.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Admitted (task, per-cycle quota), in admission order.
    pub selected: Vec<(TaskId, u32)>,
    /// Estimated cycle duration for the admitted set (Eq. 7).
    pub period: Micros,
    /// Candidates that were considered but not admitted.
    pub rejected: Vec<TaskId>,
}

/// The scheduling-cycle duration cap: every scheduled task receives its
/// full per-second quota within one cycle, so a cycle longer than 1000 ms
/// cannot honor any admitted task's TPOT SLO (paper §IV-C).
pub const CYCLE_CAP: Micros = 1_000_000;

/// Algorithm 2: greedy utility-rate admission with Eq. (7) feasibility,
/// plus an optional KV-memory knapsack dimension.
///
/// `max_batch` additionally caps concurrent tasks (device memory limit;
/// the paper's formulation leaves this implicit in l(b)'s domain).
/// `kv_capacity` (when finite) bounds the cumulative projected KV
/// footprint of the admitted set; the first admission overflowing it is
/// rolled back and terminates selection, mirroring the cycle-cap
/// semantics.
pub fn select_tasks(
    candidates: &[Candidate],
    latency: &LatencyModel,
    cycle_cap: Micros,
    kv_capacity: Option<u64>,
) -> Selection {
    let mut order: Vec<&Candidate> = candidates.iter().collect();
    // descending utility rate; deterministic tie-break by id
    order.sort_by(|a, b| {
        b.utility_rate()
            .partial_cmp(&a.utility_rate())
            .unwrap()
            .then(a.id.cmp(&b.id))
    });

    let mut selected: Vec<(TaskId, u32)> = Vec::new();
    let mut quotas_desc: Vec<u32> = Vec::new(); // maintained sorted desc
    let mut period: Micros = 0;
    let mut kv_used: u64 = 0;
    let mut rejected: Vec<TaskId> = Vec::new();
    let mut stopped = false;

    for cand in order {
        if stopped || selected.len() as u32 >= latency.max_batch {
            rejected.push(cand.id);
            continue;
        }
        if let Some(cap) = kv_capacity {
            if kv_used + cand.kv_bytes > cap {
                // memory overflow: roll back and terminate, exactly the
                // non-replacement semantics of the cycle cap below
                rejected.push(cand.id);
                stopped = true;
                continue;
            }
        }
        let q = cand.quota();
        // insert into the descending quota list
        let pos = quotas_desc.partition_point(|&v| v >= q);
        quotas_desc.insert(pos, q);
        let p = period_eq7(&quotas_desc, latency);
        if p >= cycle_cap {
            // roll back and terminate (non-replacement iteration, Alg. 2
            // line 13-17)
            quotas_desc.remove(pos);
            rejected.push(cand.id);
            stopped = true;
            continue;
        }
        period = p;
        kv_used += cand.kv_bytes;
        selected.push((cand.id, q));
    }

    Selection { selected, period, rejected }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ms;

    fn model() -> LatencyModel {
        LatencyModel::paper_calibrated()
    }

    fn cand(id: TaskId, utility: f64, tpot_ms: f64) -> Candidate {
        Candidate { id, utility, tpot: ms(tpot_ms), kv_bytes: 0 }
    }

    #[test]
    fn utility_rate_eq6() {
        let c = cand(0, 100.0, 50.0);
        assert!((c.utility_rate() - 5.0).abs() < 1e-12);
        let c2 = cand(1, 1.0, 125.0);
        assert!((c2.utility_rate() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn quota_is_ceil_of_rate() {
        assert_eq!(cand(0, 1.0, 100.0).quota(), 10);
        assert_eq!(cand(0, 1.0, 120.0).quota(), 9); // 8.33 -> 9
        assert_eq!(cand(0, 1.0, 250.0).quota(), 4);
        assert_eq!(cand(0, 1.0, 50.0).quota(), 20);
    }

    #[test]
    fn admits_all_when_feasible_table2() {
        // the paper's Table II static mix: 3xA(100ms), 4xB(120ms), 2xC(250ms)
        let mut cands = Vec::new();
        for i in 0..3 {
            cands.push(cand(i, 1.0, 100.0));
        }
        for i in 3..7 {
            cands.push(cand(i, 1.0, 120.0));
        }
        for i in 7..9 {
            cands.push(cand(i, 1.0, 250.0));
        }
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 9, "all 9 tasks admissible (Table II)");
        assert!(sel.period < CYCLE_CAP);
        assert!(sel.rejected.is_empty());
    }

    #[test]
    fn admission_stops_at_cycle_cap() {
        // many high-rate tasks cannot all fit in one cycle
        let cands: Vec<Candidate> =
            (0..30).map(|i| cand(i, 1.0, 50.0)).collect(); // 20 t/s each
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert!(!sel.selected.is_empty());
        assert!(sel.selected.len() < 30);
        assert!(sel.period < CYCLE_CAP);
        // the admitted set plus any rejected task must overflow the cap
        let mut quotas: Vec<u32> =
            sel.selected.iter().map(|&(_, q)| q).collect();
        quotas.push(20);
        quotas.sort_unstable_by(|a, b| b.cmp(a));
        assert!(period_eq7(&quotas, &model()) >= CYCLE_CAP);
    }

    #[test]
    fn higher_utility_rate_wins() {
        // one real-time task (U=100) among many cheap tasks: RT admitted first
        let mut cands: Vec<Candidate> =
            (0..30).map(|i| cand(i, 1.0, 50.0)).collect();
        cands.push(cand(99, 100.0, 50.0));
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected[0].0, 99, "highest utility rate admitted first");
    }

    #[test]
    fn low_rate_tasks_pack_deeper() {
        // 4 t/s tasks: quota 4 each; many fit in one cycle
        let cands: Vec<Candidate> =
            (0..20).map(|i| cand(i, 1.0, 250.0)).collect();
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        // 4 tokens/cycle => even at plateau l(16)=134ms, 4 columns of 16
        // tasks ≈ 536ms — well under the cap
        assert!(sel.selected.len() >= 16, "got {}", sel.selected.len());
    }

    #[test]
    fn respects_max_batch_cap() {
        let mut l = model();
        l.max_batch = 4;
        let cands: Vec<Candidate> =
            (0..10).map(|i| cand(i, 1.0, 250.0)).collect();
        let sel = select_tasks(&cands, &l, CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 4);
        assert_eq!(sel.rejected.len(), 6);
    }

    #[test]
    fn empty_candidates() {
        let sel = select_tasks(&[], &model(), CYCLE_CAP, None);
        assert!(sel.selected.is_empty());
        assert_eq!(sel.period, 0);
    }

    #[test]
    fn single_task_always_admitted() {
        // even the most demanding single task fits: quota*l(1) < 1000ms
        // for 20 t/s: 20 * 18ms = 360ms
        let sel = select_tasks(&[cand(0, 1.0, 50.0)], &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 1);
        assert_eq!(sel.period, 20 * model().decode(1));
    }

    #[test]
    fn rejected_plus_selected_covers_all() {
        let cands: Vec<Candidate> =
            (0..25).map(|i| cand(i, 1.0 + (i % 3) as f64, 50.0 + 10.0 * (i % 5) as f64)).collect();
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        let mut all: Vec<TaskId> = sel
            .selected
            .iter()
            .map(|&(id, _)| id)
            .chain(sel.rejected.iter().copied())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn kv_capacity_caps_the_admitted_footprint() {
        // 10 tasks of 4 MiB projected footprint under a 24 MiB budget:
        // exactly 6 admitted, the overflow rolled back, selection stops
        let mb = 1024 * 1024;
        let cands: Vec<Candidate> = (0..10)
            .map(|i| Candidate { id: i, utility: 1.0, tpot: ms(250.0), kv_bytes: 4 * mb })
            .collect();
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, Some(24 * mb));
        assert_eq!(sel.selected.len(), 6);
        assert_eq!(sel.rejected.len(), 4);
        // the same candidates without a capacity all fit the cycle
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 10);
    }

    #[test]
    fn kv_dimension_preserves_utility_rate_order() {
        // the high-rate task is admitted first and survives; the bulky
        // low-rate tasks hit the memory wall
        let mb = 1024 * 1024;
        let mut cands: Vec<Candidate> = (0..5)
            .map(|i| Candidate { id: i, utility: 1.0, tpot: ms(125.0), kv_bytes: 8 * mb })
            .collect();
        cands.push(Candidate { id: 9, utility: 100.0, tpot: ms(50.0), kv_bytes: 8 * mb });
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, Some(16 * mb));
        assert_eq!(sel.selected.len(), 2);
        assert_eq!(sel.selected[0].0, 9, "utility-rate order unchanged");
    }

    #[test]
    fn zero_footprint_candidates_ignore_capacity() {
        let cands: Vec<Candidate> = (0..9).map(|i| cand(i, 1.0, 120.0)).collect();
        let unconstrained = select_tasks(&cands, &model(), CYCLE_CAP, None);
        let constrained = select_tasks(&cands, &model(), CYCLE_CAP, Some(1));
        assert_eq!(unconstrained.selected, constrained.selected);
    }

    #[test]
    fn selection_is_deterministic() {
        let cands: Vec<Candidate> =
            (0..25).map(|i| cand(i, 1.0, 100.0)).collect();
        let a = select_tasks(&cands, &model(), CYCLE_CAP, None);
        let b = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.rejected, b.rejected);
    }
}
