//! Utility-maximizing task selection (paper §IV-C, Algorithm 2).
//!
//! Each candidate task is scored with a *utility rate* r_i = U_i * T_TPOT
//! (Eq. 6): the utility earned per token-per-second of capacity it
//! consumes. Tasks are admitted greedily in descending r_i order; after
//! each admission the scheduling-cycle duration is re-estimated with
//! Eq. (7) over the admitted quotas, and the first admission that pushes
//! the cycle past the cap (1000 ms — one cycle must deliver every task's
//! per-second quota) is rolled back, terminating selection.
//!
//! Memory extension (DESIGN.md "Memory model"): when the device's KV
//! capacity is finite, selection carries a second knapsack dimension —
//! each candidate's KV footprint ([`Candidate::kv_bytes`]; the SLICE
//! policy projects the *current* block-rounded footprint, re-evaluated
//! at every Alg. 4 reschedule). The admission that overflows capacity
//! is rolled back and terminates selection with exactly the
//! non-replacement semantics of the cycle cap, so a schedule is only
//! emitted if its resident KV fits the device (cf. the
//! projected-occupancy admission of SLOs-Serve, arXiv:2504.08784).
//!
//! Hot path (DESIGN.md "Scheduler hot path"): the greedy loop runs at
//! every arrival/departure, so [`select_tasks_with`] evaluates each
//! admission with the incremental Σ Δl·v structure
//! ([`super::mask::IncrementalPeriod`]) and reusable scratch buffers —
//! O(n log n) per reschedule, zero steady-state allocation. (The
//! pre-optimization O(n²) reference implementation was kept in-tree
//! through PR 9 to pin equivalence and the bench trajectory; with the
//! speedups confirmed by BENCH_ci.json history it is gone — the
//! property suite now pins the semantics directly.)
//!
//! Cached-candidate path (DESIGN.md "Control-plane incrementality"):
//! when candidate keys are immutable between reschedules (no utility
//! adaptor, no memory dimension, no prefill debt), the caller maintains
//! the sorted `(key, id, quota)` list incrementally across decisions
//! and runs [`select_tasks_sorted`] — the greedy loop without the
//! per-reschedule rebuild and sort. Because `(key, id)` pairs are
//! unique, an order-maintained list reproduces the full sort
//! bit-for-bit; [`admission_entry`] computes a single entry with
//! exactly the expressions [`select_tasks_with`] uses.

use crate::engine::latency::LatencyModel;
use crate::util::Micros;

use super::mask::IncrementalPeriod;
use super::task::TaskId;

/// A candidate for selection.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// The candidate task.
    pub id: TaskId,
    /// Base or adapted utility U_i.
    pub utility: f64,
    /// TPOT requirement in micros.
    pub tpot: Micros,
    /// The candidate's KV footprint in bytes, as projected by the
    /// caller (SLICE uses the current block-rounded footprint,
    /// `MemoryBudget::footprint_bytes`). Ignored unless selection runs
    /// with a finite KV capacity; zero for memory-oblivious callers.
    pub kv_bytes: u64,
}

impl Candidate {
    /// Utility rate r_i = U_i * T_TPOT (Eq. 6). T_TPOT in seconds so the
    /// scale matches the paper's formulation.
    pub fn utility_rate(&self) -> f64 {
        self.utility * (self.tpot as f64 / 1e6)
    }

    /// Per-cycle token quota v_i = ceil(1s / T_TPOT).
    pub fn quota(&self) -> u32 {
        (1e6 / self.tpot as f64).ceil() as u32
    }
}

/// Result of one selection round.
#[derive(Debug, Clone, Default)]
pub struct Selection {
    /// Admitted (task, per-cycle quota), in admission order.
    pub selected: Vec<(TaskId, u32)>,
    /// Estimated cycle duration for the admitted set (Eq. 7).
    pub period: Micros,
    /// Candidates that were considered but not admitted.
    pub rejected: Vec<TaskId>,
}

/// The scheduling-cycle duration cap: every scheduled task receives its
/// full per-second quota within one cycle, so a cycle longer than 1000 ms
/// cannot honor any admitted task's TPOT SLO (paper §IV-C).
pub const CYCLE_CAP: Micros = 1_000_000;

/// Reusable working memory for [`select_tasks_with`]: the sort keys,
/// precomputed quotas and the incremental Eq. 7 structure. Owned by the
/// caller (e.g. `SlicePolicy`) so a steady-state reschedule performs
/// zero heap allocation once the buffers have grown to the workload's
/// high-water mark.
#[derive(Debug)]
pub struct SelectionScratch {
    /// (descending-rate key, id, index into `candidates`): sorting this
    /// ascending yields utility rate descending, then id ascending,
    /// then input order — the reference comparator's total order with
    /// the rate computed once per candidate instead of O(n log n)
    /// times inside the comparator.
    keys: Vec<(u64, TaskId, u32)>,
    /// Per-candidate quota v_i = ceil(1s / T_TPOT), precomputed once.
    quotas: Vec<u32>,
    /// Incremental Eq. 7 evaluator over the admitted quotas.
    period: IncrementalPeriod,
}

impl SelectionScratch {
    /// Fresh scratch calibrated to one device curve. The curve both
    /// prices admissions (Eq. 7) and caps the batch (`max_batch`), so
    /// it lives with the scratch rather than being re-passed per call.
    pub fn new(latency: LatencyModel) -> Self {
        SelectionScratch {
            keys: Vec::new(),
            quotas: Vec::new(),
            period: IncrementalPeriod::new(latency),
        }
    }

    /// The device curve selections run against.
    pub fn latency(&self) -> &LatencyModel {
        self.period.latency()
    }

    /// Export the post-sort candidate order as maintained-cache entries
    /// `(key, id, quota)` — the state [`select_tasks_sorted`] consumes.
    /// Valid right after a [`select_tasks_with`] call; used to (re)seed
    /// `SlicePolicy`'s cached list from a full rebuild.
    pub fn export_sorted(&self, out: &mut Vec<(u64, TaskId, u32)>) {
        out.clear();
        out.extend(
            self.keys.iter().map(|&(k, id, idx)| (k, id, self.quotas[idx as usize])),
        );
    }
}

/// Total-order sort key for a utility rate, descending: IEEE-754
/// doubles order by their sign-adjusted bit pattern, so one integer
/// compare replaces the reference comparator's two rate recomputations
/// plus `partial_cmp`. `-0.0` is normalised onto `+0.0` (the reference
/// treats them as equal and falls through to the id tie-break); NaN
/// panics exactly like the reference comparator's `unwrap`.
#[inline]
fn rate_key_desc(rate: f64) -> u64 {
    assert!(!rate.is_nan(), "utility rate is NaN");
    let bits = (rate + 0.0).to_bits();
    let ascending = if bits >> 63 == 1 { !bits } else { bits | (1 << 63) };
    !ascending
}

/// One maintained-candidate entry `(packed descending-rate key, id,
/// quota)` for the cached-candidate fast path: the exact expressions
/// [`select_tasks_with`] evaluates per candidate, exposed so
/// `SlicePolicy` can insert/remove single entries into its sorted cache
/// without rebuilding the whole set. Sorting entries ascending by
/// `(key, id)` reproduces the full path's total order because the pair
/// is unique per pool (ids are unique and the idx tie-break is never
/// reached).
#[inline]
pub fn admission_entry(utility: f64, tpot: Micros, id: TaskId) -> (u64, TaskId, u32) {
    let rate = utility * (tpot as f64 / 1e6);
    (rate_key_desc(rate), id, (1e6 / tpot as f64).ceil() as u32)
}

/// Algorithm 2: greedy utility-rate admission with Eq. (7) feasibility,
/// plus an optional KV-memory knapsack dimension.
///
/// `max_batch` (carried by the scratch's latency model) additionally
/// caps concurrent tasks (device memory limit; the paper's formulation
/// leaves this implicit in l(b)'s domain). `kv_capacity` (when finite)
/// bounds the cumulative projected KV footprint of the admitted set;
/// the first admission overflowing it is rolled back and terminates
/// selection, mirroring the cycle-cap semantics.
///
/// This is the allocation-free hot path: results land in `out`
/// (cleared first) and all working memory lives in `scratch`. One
/// admission probes and commits O(v_max) column counters instead of a
/// naive O(n) sorted insert + O(n) closed form per admission, so the
/// greedy loop is O(n log n) overall — the candidate sort — rather
/// than O(n²) (the admission semantics are pinned against the Eq. 7
/// closed form by the property suite and the tests below).
///
/// Returns `true` iff selection terminated on a resource stop (cycle
/// cap or KV overflow) rather than admitting everything / filling
/// `max_batch` — the stop reason the reschedule-skip precondition needs
/// to pick a sound admission threshold.
pub fn select_tasks_with(
    scratch: &mut SelectionScratch,
    out: &mut Selection,
    candidates: &[Candidate],
    cycle_cap: Micros,
    kv_capacity: Option<u64>,
) -> bool {
    scratch.keys.clear();
    scratch.quotas.clear();
    scratch.period.clear();
    for (idx, c) in candidates.iter().enumerate() {
        // same expressions as Candidate::utility_rate / Candidate::quota,
        // evaluated once per candidate before the sort (not inside the
        // comparator)
        let rate = c.utility * (c.tpot as f64 / 1e6);
        scratch.keys.push((rate_key_desc(rate), c.id, idx as u32));
        scratch.quotas.push((1e6 / c.tpot as f64).ceil() as u32);
    }
    // ascending on the packed key = rate desc, id asc, input order —
    // a total order, so the unstable sort reproduces the reference
    // path's stable sort exactly
    scratch.keys.sort_unstable();

    out.selected.clear();
    out.rejected.clear();
    out.period = 0;
    let max_batch = scratch.period.latency().max_batch;
    let mut kv_used: u64 = 0;
    let mut stopped = false;

    for &(_, id, idx) in &scratch.keys {
        if stopped || out.selected.len() as u32 >= max_batch {
            out.rejected.push(id);
            continue;
        }
        let kv_bytes = candidates[idx as usize].kv_bytes;
        if let Some(cap) = kv_capacity {
            if kv_used + kv_bytes > cap {
                // memory overflow: roll back and terminate, exactly the
                // non-replacement semantics of the cycle cap below
                out.rejected.push(id);
                stopped = true;
                continue;
            }
        }
        let q = scratch.quotas[idx as usize];
        // probe-then-commit: a rejected admission never mutates the
        // structure (non-replacement iteration, Alg. 2 line 13-17),
        // and a quota too large to ever fit is priced in closed form
        // without materializing its columns
        let p = scratch.period.probe(q);
        if p >= cycle_cap {
            out.rejected.push(id);
            stopped = true;
            continue;
        }
        let committed = scratch.period.insert(q);
        debug_assert_eq!(committed, p, "probe and insert must agree");
        out.period = committed;
        kv_used += kv_bytes;
        out.selected.push((id, q));
    }
    stopped
}

/// The cached-candidate greedy loop: Algorithm 2 over an already-sorted
/// maintained `(key, id, quota)` list, skipping the per-reschedule
/// rebuild, re-adapt and sort of [`select_tasks_with`]. Only valid in
/// the immutable-key regime (no utility adaptor, no memory dimension,
/// no prefill debt — `SlicePolicy` gates on exactly that), where the
/// KV dimension is inert and `cycle_cap` is the configured constant.
/// Admission order over the same multiset of `(key, id)` pairs is
/// identical to the full path's, so the output is bit-for-bit equal —
/// pinned by `sorted_path_matches_full_path` below and the property
/// suite. Returns the same stop-reason bool as [`select_tasks_with`].
pub fn select_tasks_sorted(
    scratch: &mut SelectionScratch,
    out: &mut Selection,
    sorted: &[(u64, TaskId, u32)],
    cycle_cap: Micros,
) -> bool {
    scratch.period.clear();
    out.selected.clear();
    out.rejected.clear();
    out.period = 0;
    let max_batch = scratch.period.latency().max_batch;
    let mut stopped = false;
    for &(_, id, q) in sorted {
        if stopped || out.selected.len() as u32 >= max_batch {
            out.rejected.push(id);
            continue;
        }
        let p = scratch.period.probe(q);
        if p >= cycle_cap {
            out.rejected.push(id);
            stopped = true;
            continue;
        }
        let committed = scratch.period.insert(q);
        debug_assert_eq!(committed, p, "probe and insert must agree");
        out.period = committed;
        out.selected.push((id, q));
    }
    stopped
}

/// Convenience wrapper over [`select_tasks_with`] allocating fresh
/// scratch and output per call (tests, experiments, one-shot callers).
/// The serving loop's reschedule path uses the scratch API directly.
pub fn select_tasks(
    candidates: &[Candidate],
    latency: &LatencyModel,
    cycle_cap: Micros,
    kv_capacity: Option<u64>,
) -> Selection {
    let mut scratch = SelectionScratch::new(latency.clone());
    let mut out = Selection::default();
    select_tasks_with(&mut scratch, &mut out, candidates, cycle_cap, kv_capacity);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mask::period_eq7;
    use crate::util::ms;

    fn model() -> LatencyModel {
        LatencyModel::paper_calibrated()
    }

    fn cand(id: TaskId, utility: f64, tpot_ms: f64) -> Candidate {
        Candidate { id, utility, tpot: ms(tpot_ms), kv_bytes: 0 }
    }

    #[test]
    fn utility_rate_eq6() {
        let c = cand(0, 100.0, 50.0);
        assert!((c.utility_rate() - 5.0).abs() < 1e-12);
        let c2 = cand(1, 1.0, 125.0);
        assert!((c2.utility_rate() - 0.125).abs() < 1e-12);
    }

    #[test]
    fn quota_is_ceil_of_rate() {
        assert_eq!(cand(0, 1.0, 100.0).quota(), 10);
        assert_eq!(cand(0, 1.0, 120.0).quota(), 9); // 8.33 -> 9
        assert_eq!(cand(0, 1.0, 250.0).quota(), 4);
        assert_eq!(cand(0, 1.0, 50.0).quota(), 20);
    }

    #[test]
    fn admits_all_when_feasible_table2() {
        // the paper's Table II static mix: 3xA(100ms), 4xB(120ms), 2xC(250ms)
        let mut cands = Vec::new();
        for i in 0..3 {
            cands.push(cand(i, 1.0, 100.0));
        }
        for i in 3..7 {
            cands.push(cand(i, 1.0, 120.0));
        }
        for i in 7..9 {
            cands.push(cand(i, 1.0, 250.0));
        }
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 9, "all 9 tasks admissible (Table II)");
        assert!(sel.period < CYCLE_CAP);
        assert!(sel.rejected.is_empty());
    }

    #[test]
    fn admission_stops_at_cycle_cap() {
        // many high-rate tasks cannot all fit in one cycle
        let cands: Vec<Candidate> =
            (0..30).map(|i| cand(i, 1.0, 50.0)).collect(); // 20 t/s each
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert!(!sel.selected.is_empty());
        assert!(sel.selected.len() < 30);
        assert!(sel.period < CYCLE_CAP);
        // the admitted set plus any rejected task must overflow the cap
        let mut quotas: Vec<u32> =
            sel.selected.iter().map(|&(_, q)| q).collect();
        quotas.push(20);
        quotas.sort_unstable_by(|a, b| b.cmp(a));
        assert!(period_eq7(&quotas, &model()) >= CYCLE_CAP);
    }

    #[test]
    fn higher_utility_rate_wins() {
        // one real-time task (U=100) among many cheap tasks: RT admitted first
        let mut cands: Vec<Candidate> =
            (0..30).map(|i| cand(i, 1.0, 50.0)).collect();
        cands.push(cand(99, 100.0, 50.0));
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected[0].0, 99, "highest utility rate admitted first");
    }

    #[test]
    fn low_rate_tasks_pack_deeper() {
        // 4 t/s tasks: quota 4 each; many fit in one cycle
        let cands: Vec<Candidate> =
            (0..20).map(|i| cand(i, 1.0, 250.0)).collect();
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        // 4 tokens/cycle => even at plateau l(16)=134ms, 4 columns of 16
        // tasks ≈ 536ms — well under the cap
        assert!(sel.selected.len() >= 16, "got {}", sel.selected.len());
    }

    #[test]
    fn respects_max_batch_cap() {
        let mut l = model();
        l.max_batch = 4;
        let cands: Vec<Candidate> =
            (0..10).map(|i| cand(i, 1.0, 250.0)).collect();
        let sel = select_tasks(&cands, &l, CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 4);
        assert_eq!(sel.rejected.len(), 6);
    }

    #[test]
    fn empty_candidates() {
        let sel = select_tasks(&[], &model(), CYCLE_CAP, None);
        assert!(sel.selected.is_empty());
        assert_eq!(sel.period, 0);
    }

    #[test]
    fn single_task_always_admitted() {
        // even the most demanding single task fits: quota*l(1) < 1000ms
        // for 20 t/s: 20 * 18ms = 360ms
        let sel = select_tasks(&[cand(0, 1.0, 50.0)], &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 1);
        assert_eq!(sel.period, 20 * model().decode(1));
    }

    #[test]
    fn rejected_plus_selected_covers_all() {
        let cands: Vec<Candidate> =
            (0..25).map(|i| cand(i, 1.0 + (i % 3) as f64, 50.0 + 10.0 * (i % 5) as f64)).collect();
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        let mut all: Vec<TaskId> = sel
            .selected
            .iter()
            .map(|&(id, _)| id)
            .chain(sel.rejected.iter().copied())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn kv_capacity_caps_the_admitted_footprint() {
        // 10 tasks of 4 MiB projected footprint under a 24 MiB budget:
        // exactly 6 admitted, the overflow rolled back, selection stops
        let mb = 1024 * 1024;
        let cands: Vec<Candidate> = (0..10)
            .map(|i| Candidate { id: i, utility: 1.0, tpot: ms(250.0), kv_bytes: 4 * mb })
            .collect();
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, Some(24 * mb));
        assert_eq!(sel.selected.len(), 6);
        assert_eq!(sel.rejected.len(), 4);
        // the same candidates without a capacity all fit the cycle
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(sel.selected.len(), 10);
    }

    #[test]
    fn kv_dimension_preserves_utility_rate_order() {
        // the high-rate task is admitted first and survives; the bulky
        // low-rate tasks hit the memory wall
        let mb = 1024 * 1024;
        let mut cands: Vec<Candidate> = (0..5)
            .map(|i| Candidate { id: i, utility: 1.0, tpot: ms(125.0), kv_bytes: 8 * mb })
            .collect();
        cands.push(Candidate { id: 9, utility: 100.0, tpot: ms(50.0), kv_bytes: 8 * mb });
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, Some(16 * mb));
        assert_eq!(sel.selected.len(), 2);
        assert_eq!(sel.selected[0].0, 9, "utility-rate order unchanged");
    }

    #[test]
    fn zero_footprint_candidates_ignore_capacity() {
        let cands: Vec<Candidate> = (0..9).map(|i| cand(i, 1.0, 120.0)).collect();
        let unconstrained = select_tasks(&cands, &model(), CYCLE_CAP, None);
        let constrained = select_tasks(&cands, &model(), CYCLE_CAP, Some(1));
        assert_eq!(unconstrained.selected, constrained.selected);
    }

    #[test]
    fn selection_is_deterministic() {
        let cands: Vec<Candidate> =
            (0..25).map(|i| cand(i, 1.0, 100.0)).collect();
        let a = select_tasks(&cands, &model(), CYCLE_CAP, None);
        let b = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert_eq!(a.selected, b.selected);
        assert_eq!(a.rejected, b.rejected);
    }

    #[test]
    fn pathological_quota_rejected_without_column_state() {
        // a hand-written trace can carry a near-zero TPOT whose quota
        // (ceil(1e6/tpot)) is enormous; it must be rejected (and, by
        // non-replacement, everything sorted after it) without
        // materializing quota-sized column state. The monster sorts
        // first (utility rate 1e9 * 1e-6 dwarfs the others), so the
        // whole set drains to rejected in sorted order.
        let mut cands = vec![cand(0, 1.0, 100.0), cand(1, 1.0, 250.0)];
        cands.insert(1, Candidate { id: 9, utility: 1e9, tpot: 1, kv_bytes: 0 });
        let sel = select_tasks(&cands, &model(), CYCLE_CAP, None);
        assert!(sel.selected.is_empty(), "non-replacement stop before any admission");
        // sorted order: rate 1000 (id 9), 0.25 (id 1), 0.1 (id 0)
        assert_eq!(sel.rejected, vec![9, 1, 0], "sorted order, monster first");
        assert_eq!(sel.period, 0);
    }

    #[test]
    fn rate_key_orders_like_partial_cmp() {
        // descending key: bigger rate -> smaller key
        let rates = [-3.5, -0.0, 0.0, 1e-300, 0.125, 1.0, 5.0, 1e12, f64::INFINITY];
        for w in rates.windows(2) {
            assert!(
                rate_key_desc(w[0]) > rate_key_desc(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
        // `partial_cmp` on rates treats -0.0 == +0.0 and tie-breaks by
        // id; the packed key must collide the same way
        assert_eq!(rate_key_desc(-0.0), rate_key_desc(0.0));
    }

    #[test]
    fn scratch_reuse_matches_fresh() {
        // exercise one scratch across shapes that grow and shrink, with
        // and without the KV dimension — stale state would corrupt
        // later rounds
        let mut scratch = SelectionScratch::new(model());
        let mut out = Selection::default();
        let mb = 1024 * 1024;
        let rounds: Vec<(Vec<Candidate>, Option<u64>)> = vec![
            ((0..30).map(|i| cand(i, 1.0, 50.0)).collect(), None),
            (vec![cand(7, 100.0, 50.0)], None),
            (
                (0..10)
                    .map(|i| Candidate {
                        id: i,
                        utility: 1.0 + (i % 4) as f64,
                        tpot: ms(250.0),
                        kv_bytes: 4 * mb,
                    })
                    .collect(),
                Some(24 * mb),
            ),
            (Vec::new(), None),
            (
                (0..25)
                    .map(|i| cand(i, 1.0 + (i % 3) as f64, 50.0 + 10.0 * (i % 5) as f64))
                    .collect(),
                None,
            ),
        ];
        for (cands, cap) in rounds {
            select_tasks_with(&mut scratch, &mut out, &cands, CYCLE_CAP, cap);
            let fresh = select_tasks(&cands, &model(), CYCLE_CAP, cap);
            assert_eq!(out.selected, fresh.selected);
            assert_eq!(out.rejected, fresh.rejected);
            assert_eq!(out.period, fresh.period);
        }
    }

    #[test]
    fn admission_entry_matches_full_path_keys() {
        // the maintained-cache entry must be byte-identical to what the
        // full path computes and exports for the same candidate
        let cands: Vec<Candidate> = (0..25)
            .map(|i| cand(i, 1.0 + (i % 3) as f64, 50.0 + 10.0 * (i % 5) as f64))
            .collect();
        let mut scratch = SelectionScratch::new(model());
        let mut out = Selection::default();
        select_tasks_with(&mut scratch, &mut out, &cands, CYCLE_CAP, None);
        let mut exported = Vec::new();
        scratch.export_sorted(&mut exported);
        assert_eq!(exported.len(), cands.len());
        let mut built: Vec<(u64, TaskId, u32)> = cands
            .iter()
            .map(|c| admission_entry(c.utility, c.tpot, c.id))
            .collect();
        built.sort_unstable();
        assert_eq!(exported, built);
    }

    #[test]
    fn sorted_path_matches_full_path() {
        // immutable-regime shapes (kv_bytes 0, no capacity): running the
        // greedy loop over the exported sorted entries reproduces the
        // full rebuild path bit-for-bit, including the stop reason
        let shapes: Vec<Vec<Candidate>> = vec![
            (0..30).map(|i| cand(i, 1.0, 50.0)).collect(), // cycle-stop
            (0..9).map(|i| cand(i, 1.0, 120.0)).collect(), // all admitted
            (0..25)
                .map(|i| cand(i, 1.0 + (i % 3) as f64, 50.0 + 10.0 * (i % 5) as f64))
                .collect(),
            Vec::new(),
        ];
        for cands in shapes {
            let mut scratch = SelectionScratch::new(model());
            let mut full = Selection::default();
            let full_stop =
                select_tasks_with(&mut scratch, &mut full, &cands, CYCLE_CAP, None);
            let mut sorted = Vec::new();
            scratch.export_sorted(&mut sorted);
            let mut fast = Selection::default();
            let fast_stop =
                select_tasks_sorted(&mut scratch, &mut fast, &sorted, CYCLE_CAP);
            assert_eq!(full_stop, fast_stop);
            assert_eq!(full.selected, fast.selected);
            assert_eq!(full.rejected, fast.rejected);
            assert_eq!(full.period, fast.period);
        }
    }

    #[test]
    fn sorted_path_respects_max_batch_without_stop() {
        let mut l = model();
        l.max_batch = 4;
        let cands: Vec<Candidate> =
            (0..10).map(|i| cand(i, 1.0, 250.0)).collect();
        let mut scratch = SelectionScratch::new(l);
        let mut full = Selection::default();
        let full_stop =
            select_tasks_with(&mut scratch, &mut full, &cands, CYCLE_CAP, None);
        assert!(!full_stop, "max_batch cap is not a resource stop");
        let mut sorted = Vec::new();
        scratch.export_sorted(&mut sorted);
        let mut fast = Selection::default();
        let fast_stop = select_tasks_sorted(&mut scratch, &mut fast, &sorted, CYCLE_CAP);
        assert!(!fast_stop);
        assert_eq!(full.selected, fast.selected);
        assert_eq!(full.rejected, fast.rejected);
    }
}
