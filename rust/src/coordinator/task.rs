//! Task model: SLO specifications, runtime state and lifecycle.
//!
//! A *task* is one inference request. The paper distinguishes:
//!   * **real-time** tasks (machine control, navigation): a hard
//!     end-to-end deadline, translated (§IV-A) into a TTFT budget plus a
//!     TPOT requirement (20 tokens/s in the evaluation);
//!   * **non-real-time** tasks (voice chat at 8 tokens/s, text Q&A at
//!     10 tokens/s): a TTFT SLO and a TPOT SLO.

use crate::util::{Micros, MICROS_PER_SEC};

/// Unique task identifier.
pub type TaskId = u64;

/// The application class a task belongs to (drives default SLOs/utility).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskClass {
    /// Machine control / navigation planning: hard deadline.
    RealTime,
    /// Voice chat: generation must keep up with speech (8 tokens/s).
    Voice,
    /// Text Q&A: generation must keep up with reading (10 tokens/s).
    TextQa,
}

impl TaskClass {
    /// True for the hard-deadline (machine control) class.
    pub fn is_real_time(&self) -> bool {
        matches!(self, TaskClass::RealTime)
    }

    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            TaskClass::RealTime => "real-time",
            TaskClass::Voice => "voice",
            TaskClass::TextQa => "text-qa",
        }
    }
}

/// Service-level objectives for one task.
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// Max time from arrival to the first output token.
    pub ttft: Micros,
    /// Max average time between output tokens.
    pub tpot: Micros,
    /// Hard end-to-end deadline (real-time tasks only).
    pub deadline: Option<Micros>,
}

impl SloSpec {
    /// Paper defaults: real-time = 20 tokens/s rate + 1.5 s deadline.
    pub fn real_time() -> Self {
        SloSpec {
            ttft: 500_000,
            tpot: 50_000, // 20 tokens/s
            deadline: Some(1_500_000),
        }
    }

    /// Paper defaults: voice chat = 8 tokens/s.
    pub fn voice() -> Self {
        SloSpec { ttft: 1_000_000, tpot: 125_000, deadline: None }
    }

    /// Paper defaults: text Q&A = 10 tokens/s.
    pub fn text_qa() -> Self {
        SloSpec { ttft: 1_000_000, tpot: 100_000, deadline: None }
    }

    /// The paper-default SLOs for a task class.
    pub fn for_class(class: TaskClass) -> Self {
        match class {
            TaskClass::RealTime => Self::real_time(),
            TaskClass::Voice => Self::voice(),
            TaskClass::TextQa => Self::text_qa(),
        }
    }

    /// Required token generation rate v_i = 1 / T_TPOT, in tokens/s.
    pub fn required_rate(&self) -> f64 {
        MICROS_PER_SEC as f64 / self.tpot as f64
    }

    /// Tokens per scheduling cycle: v_i rounded **up** so the allocated
    /// rate is never below the SLO (Alg. 3 uses ceil for the matrix
    /// width; we use ceil for every row — see DESIGN.md deviations).
    pub fn tokens_per_cycle(&self) -> u32 {
        self.required_rate().ceil() as u32
    }
}

/// Where a task's KV cache lives (DESIGN.md "Memory model"). Tracked
/// on the task so schedulers and the serving loop agree on residency
/// without reaching into engine state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Residency {
    /// No KV cache exists yet (prompt not prefilled).
    #[default]
    None,
    /// The cache occupies device memory; the task can decode directly.
    Resident,
    /// The cache was evicted (swapped to host, dropped for recompute,
    /// or in flight from another replica); resuming pays a restore
    /// transition before the next decode.
    Swapped,
}

/// Lifecycle state of a task inside the serving system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// In the request buffer, not yet admitted by the scheduler.
    Waiting,
    /// Admitted; prompt not yet prefilled.
    Admitted,
    /// Prefill done; participating in decode scheduling.
    Running,
    /// Temporarily descheduled (lost selection after a reschedule event).
    Paused,
    /// All tokens generated (or EOS sampled).
    Finished,
}

/// One inference request plus its runtime bookkeeping.
#[derive(Debug, Clone)]
pub struct Task {
    /// Unique, dense id (pool index).
    pub id: TaskId,
    /// Application class (drives default SLOs/utility).
    pub class: TaskClass,
    /// This task's service-level objectives.
    pub slo: SloSpec,
    /// Scheduling weight U_i; real-time tasks get 10-100x the utility of
    /// non-real-time tasks (paper §I).
    pub utility: f64,
    /// Current (possibly adapted) utility — the preemption controller
    /// mutates this one, keeping `utility` as the base value.
    pub effective_utility: f64,

    /// Prompt length in tokens.
    pub prompt_len: u32,
    /// Target number of output tokens (simulator) / max tokens (real
    /// engine; generation may stop earlier on EOS).
    pub output_len: u32,
    /// Prompt bytes for the real engine (empty in pure simulation).
    pub prompt: Vec<u8>,

    // -- runtime state ------------------------------------------------------
    /// Lifecycle state.
    pub state: TaskState,
    /// Arrival time.
    pub arrival: Micros,
    /// When prefill finished (None until then).
    pub prefill_end: Option<Micros>,
    /// First output token timestamp.
    pub first_token: Option<Micros>,
    /// Latest output token timestamp.
    pub last_token: Option<Micros>,
    /// Completion timestamp.
    pub completion: Option<Micros>,
    /// Output tokens generated so far.
    pub tokens_generated: u32,
    /// Largest observed inter-token gap (stutter diagnostics).
    pub max_token_gap: Micros,
    /// Generated token values (real engine only).
    pub generated: Vec<u8>,

    // -- KV-cache memory state (DESIGN.md "Memory model") -------------------
    /// Where this task's KV cache currently lives.
    pub residency: Residency,
    /// Pre-priced restore fee in micros (the KV-handoff transfer time
    /// stamped by the cluster router when a running task migrates);
    /// charged once by the destination when the task next decodes.
    pub pending_restore: Micros,
    /// Times this task's cache was evicted from device memory.
    pub swap_outs: u32,
    /// Times this task's cache was restored (swap-in or recompute).
    pub swap_ins: u32,
    /// Set when a running task was handed off to another replica: the
    /// source keeps this husk out of scheduling and reports (the moved
    /// copy carries the timing record forward).
    pub migrated_away: bool,
    /// Set when the server shed the task mid-run (its footprint could
    /// not fit the device's KV capacity). Shed tasks are terminal
    /// (`Finished` state so they leave the live indexes) but *never*
    /// count as served: `slo_met` is false and the attainment metrics
    /// exclude them from the finished set.
    pub shed: bool,
}

impl Task {
    /// Build a fresh (Waiting) task with its class-default SLOs.
    pub fn new(
        id: TaskId,
        class: TaskClass,
        arrival: Micros,
        prompt_len: u32,
        output_len: u32,
        utility: f64,
    ) -> Self {
        Task {
            id,
            class,
            slo: SloSpec::for_class(class),
            utility,
            effective_utility: utility,
            prompt_len,
            output_len,
            prompt: Vec::new(),
            state: TaskState::Waiting,
            arrival,
            prefill_end: None,
            first_token: None,
            last_token: None,
            completion: None,
            tokens_generated: 0,
            max_token_gap: 0,
            generated: Vec::new(),
            residency: Residency::None,
            pending_restore: 0,
            swap_outs: 0,
            swap_ins: 0,
            migrated_away: false,
            shed: false,
        }
    }

    /// Record one generated token at time `now`.
    pub fn on_token(&mut self, now: Micros) {
        if self.first_token.is_none() {
            self.first_token = Some(now);
        } else if let Some(last) = self.last_token {
            let gap = now.saturating_sub(last);
            if gap > self.max_token_gap {
                self.max_token_gap = gap;
            }
        }
        self.last_token = Some(now);
        self.tokens_generated += 1;
        if self.tokens_generated >= self.output_len {
            self.state = TaskState::Finished;
            self.completion = Some(now);
        }
    }

    /// Force completion (EOS from the real model before output_len).
    pub fn finish(&mut self, now: Micros) {
        self.state = TaskState::Finished;
        self.completion = Some(now);
    }

    /// True once all tokens are generated (or EOS forced completion).
    pub fn is_finished(&self) -> bool {
        self.state == TaskState::Finished
    }

    /// Measured time-to-first-token.
    pub fn ttft(&self) -> Option<Micros> {
        self.first_token.map(|t| t.saturating_sub(self.arrival))
    }

    /// Measured average time-per-output-token: (last - first) / (n - 1).
    /// A single-token task trivially satisfies any TPOT.
    pub fn avg_tpot(&self) -> Option<Micros> {
        match (self.first_token, self.last_token) {
            (Some(f), Some(l)) if self.tokens_generated >= 2 => {
                Some((l - f) / (self.tokens_generated as u64 - 1))
            }
            (Some(_), Some(_)) => Some(0),
            _ => None,
        }
    }

    /// End-to-end completion latency.
    pub fn completion_time(&self) -> Option<Micros> {
        self.completion.map(|c| c.saturating_sub(self.arrival))
    }

    /// Paper §VI-A: real-time SLO = completion before deadline;
    /// non-real-time SLO = TTFT SLO **and** TPOT SLO both met.
    pub fn slo_met(&self) -> bool {
        if self.shed || !self.is_finished() {
            return false;
        }
        if let Some(deadline) = self.slo.deadline {
            return self.completion_time().map_or(false, |c| c <= deadline);
        }
        self.ttft_met() && self.tpot_met()
    }

    /// True when the measured TTFT is within its SLO.
    pub fn ttft_met(&self) -> bool {
        self.ttft().map_or(false, |t| t <= self.slo.ttft)
    }

    /// True when the measured average TPOT is within its SLO.
    pub fn tpot_met(&self) -> bool {
        self.avg_tpot().map_or(false, |t| t <= self.slo.tpot)
    }

    /// Deadline attainment for real-time tasks (None for non-real-time).
    pub fn deadline_met(&self) -> Option<bool> {
        self.slo.deadline.map(|d| {
            self.is_finished() && self.completion_time().map_or(false, |c| c <= d)
        })
    }

    /// Tokens still to generate.
    pub fn remaining_tokens(&self) -> u32 {
        self.output_len.saturating_sub(self.tokens_generated)
    }

    /// Current total sequence length (prompt + generated so far).
    pub fn seq_len(&self) -> u32 {
        self.prompt_len + self.tokens_generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ms;

    fn rt_task() -> Task {
        Task::new(1, TaskClass::RealTime, 0, 16, 10, 100.0)
    }

    #[test]
    fn slo_defaults_match_paper() {
        assert_eq!(SloSpec::real_time().tpot, 50_000);
        assert_eq!(SloSpec::real_time().deadline, Some(1_500_000));
        assert_eq!(SloSpec::voice().required_rate(), 8.0);
        assert_eq!(SloSpec::text_qa().required_rate(), 10.0);
    }

    #[test]
    fn tokens_per_cycle_rounds_up() {
        let s = SloSpec { ttft: 0, tpot: 120_000, deadline: None }; // 8.33 t/s
        assert_eq!(s.tokens_per_cycle(), 9);
        assert_eq!(SloSpec::voice().tokens_per_cycle(), 8);
    }

    #[test]
    fn token_bookkeeping_and_completion() {
        let mut t = rt_task();
        for i in 0..10u64 {
            t.on_token(ms(100.0) + i * ms(40.0));
        }
        assert!(t.is_finished());
        assert_eq!(t.ttft(), Some(ms(100.0)));
        assert_eq!(t.avg_tpot(), Some(ms(40.0)));
        assert_eq!(t.completion_time(), Some(ms(100.0) + 9 * ms(40.0)));
    }

    #[test]
    fn real_time_slo_is_deadline_only() {
        let mut t = rt_task();
        // generate all 10 tokens slowly but inside the deadline
        for i in 0..10u64 {
            t.on_token(ms(100.0) + i * ms(120.0));
        }
        // TPOT 120ms > 50ms SLO, but completion 1.18s < 1.5s deadline
        assert!(!t.tpot_met());
        assert!(t.slo_met());
    }

    #[test]
    fn real_time_misses_deadline() {
        let mut t = rt_task();
        for i in 0..10u64 {
            t.on_token(ms(200.0) + i * ms(160.0));
        }
        assert!(t.completion_time().unwrap() > 1_500_000);
        assert!(!t.slo_met());
        assert_eq!(t.deadline_met(), Some(false));
    }

    #[test]
    fn non_real_time_needs_both_ttft_and_tpot() {
        let mut t = Task::new(2, TaskClass::Voice, 0, 16, 5, 1.0);
        for i in 0..5u64 {
            t.on_token(ms(500.0) + i * ms(100.0)); // TTFT 0.5s OK, TPOT 100ms OK
        }
        assert!(t.slo_met());

        let mut t2 = Task::new(3, TaskClass::Voice, 0, 16, 5, 1.0);
        for i in 0..5u64 {
            t2.on_token(ms(500.0) + i * ms(200.0)); // TPOT 200ms > 125ms
        }
        assert!(!t2.slo_met());

        let mut t3 = Task::new(4, TaskClass::Voice, 0, 16, 5, 1.0);
        for i in 0..5u64 {
            t3.on_token(ms(1500.0) + i * ms(100.0)); // TTFT 1.5s > 1s
        }
        assert!(!t3.slo_met());
    }

    #[test]
    fn unfinished_task_fails_slo() {
        let mut t = rt_task();
        t.on_token(ms(10.0));
        assert!(!t.slo_met());
        assert_eq!(t.remaining_tokens(), 9);
        assert_eq!(t.seq_len(), 17);
    }

    #[test]
    fn max_gap_tracks_stutter() {
        let mut t = Task::new(5, TaskClass::TextQa, 0, 8, 4, 1.0);
        t.on_token(ms(100.0));
        t.on_token(ms(150.0));
        t.on_token(ms(400.0)); // 250ms stutter
        t.on_token(ms(450.0));
        assert_eq!(t.max_token_gap, ms(250.0));
    }

    #[test]
    fn fresh_task_has_no_kv_state() {
        let t = rt_task();
        assert_eq!(t.residency, Residency::None);
        assert_eq!(t.pending_restore, 0);
        assert_eq!((t.swap_outs, t.swap_ins), (0, 0));
        assert!(!t.migrated_away);
    }

    #[test]
    fn single_token_task_satisfies_tpot() {
        let mut t = Task::new(6, TaskClass::TextQa, 0, 8, 1, 1.0);
        t.on_token(ms(100.0));
        assert!(t.is_finished());
        assert_eq!(t.avg_tpot(), Some(0));
        assert!(t.tpot_met());
    }
}
