//! Fig. 10a/b/c — SLO attainment vs the real-time task ratio.
//!
//! Arrival rate fixed at 1.0; the real-time share sweeps 10%..90%.
//! Expected shape: SLICE holds >80% real-time attainment everywhere;
//! baselines sit near ~10% when the RT share is below 70%; overall
//! advantage up to ~13x.

use anyhow::Result;

use crate::config::{PolicyKind, ServeConfig};
use crate::metrics::report::{nan_null, pct, Table};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{default_drain, run_sim, ALL_POLICIES};

/// The swept real-time ratios (paper Fig. 10 x-axis).
pub fn default_ratios() -> Vec<f64> {
    vec![0.1, 0.3, 0.5, 0.7, 0.9]
}

/// One (ratio, policy) cell.
#[derive(Debug)]
pub struct RatioCell {
    /// Real-time share of the mix.
    pub ratio: f64,
    /// Policy label.
    pub policy: &'static str,
    /// Attainment at this ratio.
    pub attainment: Attainment,
}

/// Run one (policy, RT ratio) cell of the sweep.
pub fn run_cell(kind: PolicyKind, ratio: f64, cfg: &ServeConfig) -> Result<RatioCell> {
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, ratio, cfg.n_tasks, cfg.seed).generate();
    let report = run_sim(kind, workload, cfg, default_drain())?;
    Ok(RatioCell { ratio, policy: report.policy, attainment: Attainment::compute(&report.tasks) })
}

/// Full sweep; prints the three panels of Fig. 10.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let ratios = default_ratios();
    let mut cells: Vec<RatioCell> = Vec::new();
    for &ratio in &ratios {
        for kind in ALL_POLICIES {
            cells.push(run_cell(kind, ratio, cfg)?);
        }
    }

    for (title, pick) in [
        ("Fig. 10a — real-time SLO attainment", 0usize),
        ("Fig. 10b — non-real-time SLO attainment", 1),
        ("Fig. 10c — overall SLO attainment", 2),
    ] {
        let mut t = Table::new(&["RT ratio", "Orca", "FastServe", "SLICE"]);
        for &ratio in &ratios {
            let row: Vec<String> = ALL_POLICIES
                .iter()
                .map(|&k| {
                    let c = cells
                        .iter()
                        .find(|c| c.ratio == ratio && c.policy == k.label())
                        .unwrap();
                    let v = match pick {
                        0 => c.attainment.rt_slo,
                        1 => c.attainment.nrt_slo,
                        _ => c.attainment.slo,
                    };
                    pct(v)
                })
                .collect();
            t.row(
                std::iter::once(format!("{:.0}%", ratio * 100.0))
                    .chain(row)
                    .collect(),
            );
        }
        println!("{title}\n\n{}", t.render());
    }

    Ok(Json::from(
        cells
            .iter()
            .map(|c| {
                Json::obj()
                    .set("ratio", c.ratio)
                    .set("policy", c.policy)
                    .set("slo", nan_null(c.attainment.slo))
                    .set("rt_slo", nan_null(c.attainment.rt_slo))
                    .set("nrt_slo", nan_null(c.attainment.nrt_slo))
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_rt_attainment_stable_across_ratios() {
        // Fig. 10a: SLICE holds its real-time attainment above 80% at
        // both ends of the sweep.
        let cfg = ServeConfig { n_tasks: 120, ..ServeConfig::default() };
        for ratio in [0.1, 0.7] {
            let cell = run_cell(PolicyKind::Slice, ratio, &cfg).unwrap();
            assert!(
                cell.attainment.rt_slo > 0.8,
                "ratio {ratio}: SLICE RT attainment {}",
                cell.attainment.rt_slo
            );
        }
    }

    #[test]
    fn baselines_collapse_at_low_rt_ratio() {
        // Fig. 10a: with few (short) RT tasks, the long NRT tasks bloat
        // the uniform batch and baselines miss most RT deadlines.
        let cfg = ServeConfig { n_tasks: 200, ..ServeConfig::default() };
        let orca = run_cell(PolicyKind::Orca, 0.5, &cfg).unwrap();
        let slice = run_cell(PolicyKind::Slice, 0.5, &cfg).unwrap();
        assert!(
            slice.attainment.rt_slo > orca.attainment.rt_slo + 0.3,
            "SLICE {} vs Orca {}",
            slice.attainment.rt_slo,
            orca.attainment.rt_slo
        );
    }
}
