//! Fig. 11a/b/c — SLO attainment vs task arrival rate (0.1 .. 7.0).
//!
//! RT:NRT fixed at 7:3. Expected shape: baselines collapse once the rate
//! passes ~0.8-1.5 (RT attainment → ~0); SLICE holds near-100% real-time
//! attainment throughout and ~80% overall past saturation — the paper's
//! headline "up to 35x" SLO-attainment advantage.

use anyhow::Result;

use crate::config::{PolicyKind, ServeConfig};
use crate::metrics::report::{nan_null, pct, Table};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{default_drain, run_sim, ALL_POLICIES};

/// The paper sweeps ten increasing rates in [0.1, 7.0].
pub fn default_rates() -> Vec<f64> {
    vec![0.1, 0.3, 0.5, 0.8, 1.0, 1.5, 2.0, 3.0, 5.0, 7.0]
}

/// One (rate, policy) cell.
#[derive(Debug)]
pub struct RateCell {
    /// Arrival rate (tasks/s).
    pub rate: f64,
    /// Policy label.
    pub policy: &'static str,
    /// Attainment at this rate.
    pub attainment: Attainment,
}

/// Run one (policy, rate) cell of the sweep.
pub fn run_cell(kind: PolicyKind, rate: f64, cfg: &ServeConfig) -> Result<RateCell> {
    let workload =
        WorkloadSpec::paper_mix(rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed).generate();
    let report = run_sim(kind, workload, cfg, default_drain())?;
    Ok(RateCell { rate, policy: report.policy, attainment: Attainment::compute(&report.tasks) })
}

/// Full sweep; prints the three panels of Fig. 11.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let rates = default_rates();
    let mut cells: Vec<RateCell> = Vec::new();
    for &rate in &rates {
        for kind in ALL_POLICIES {
            cells.push(run_cell(kind, rate, cfg)?);
        }
    }

    for (title, pick) in [
        ("Fig. 11a — real-time SLO attainment", 0usize),
        ("Fig. 11b — non-real-time SLO attainment", 1),
        ("Fig. 11c — overall SLO attainment", 2),
    ] {
        let mut t = Table::new(&["rate", "Orca", "FastServe", "SLICE"]);
        for &rate in &rates {
            let row: Vec<String> = ALL_POLICIES
                .iter()
                .map(|&k| {
                    let c = cells
                        .iter()
                        .find(|c| c.rate == rate && c.policy == k.label())
                        .unwrap();
                    let v = match pick {
                        0 => c.attainment.rt_slo,
                        1 => c.attainment.nrt_slo,
                        _ => c.attainment.slo,
                    };
                    pct(v)
                })
                .collect();
            t.row(std::iter::once(format!("{rate}")).chain(row).collect());
        }
        println!("{title}\n\n{}", t.render());
    }

    Ok(Json::from(
        cells
            .iter()
            .map(|c| {
                Json::obj()
                    .set("rate", c.rate)
                    .set("policy", c.policy)
                    .set("slo", nan_null(c.attainment.slo))
                    .set("rt_slo", nan_null(c.attainment.rt_slo))
                    .set("nrt_slo", nan_null(c.attainment.nrt_slo))
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        // long enough to reach the post-saturation steady state
        ServeConfig { n_tasks: 300, ..ServeConfig::default() }
    }

    #[test]
    fn slice_rt_attainment_survives_overload() {
        // Fig. 11a: SLICE near-100% RT attainment even at rate 3.0.
        let cell = run_cell(PolicyKind::Slice, 3.0, &cfg()).unwrap();
        assert!(
            cell.attainment.rt_slo > 0.9,
            "SLICE RT attainment at rate 3.0 = {}",
            cell.attainment.rt_slo
        );
    }

    #[test]
    fn baselines_collapse_past_saturation() {
        // Fig. 11a: baseline RT attainment collapses past saturation
        // while SLICE holds near 100% — the gap is the paper's headline.
        for kind in [PolicyKind::Orca, PolicyKind::FastServe] {
            let base = run_cell(kind, 3.0, &cfg()).unwrap();
            let slice = run_cell(PolicyKind::Slice, 3.0, &cfg()).unwrap();
            assert!(
                slice.attainment.rt_slo - base.attainment.rt_slo > 0.4,
                "{kind:?} RT {} vs SLICE RT {} at rate 3.0",
                base.attainment.rt_slo,
                slice.attainment.rt_slo
            );
        }
        // Orca (pure FCFS) should be deeply collapsed
        let orca = run_cell(PolicyKind::Orca, 5.0, &cfg()).unwrap();
        assert!(
            orca.attainment.rt_slo < 0.3,
            "Orca RT attainment at rate 5.0 = {}",
            orca.attainment.rt_slo
        );
    }

    #[test]
    fn everyone_fine_at_idle() {
        for kind in ALL_POLICIES {
            let cell = run_cell(kind, 0.1, &cfg()).unwrap();
            assert!(
                cell.attainment.slo > 0.9,
                "{kind:?} attainment at 0.1 = {}",
                cell.attainment.slo
            );
        }
    }

    #[test]
    fn slice_overall_advantage_large_under_overload() {
        // Fig. 11c: the headline multiple. We assert a conservative >3x.
        let slice = run_cell(PolicyKind::Slice, 3.0, &cfg()).unwrap();
        let orca = run_cell(PolicyKind::Orca, 3.0, &cfg()).unwrap();
        let ratio = slice.attainment.slo / orca.attainment.slo.max(0.01);
        assert!(
            ratio > 3.0,
            "SLICE/Orca overall attainment ratio at rate 3.0 = {ratio}"
        );
    }
}
