//! Cluster sweep — routing strategies × replica counts (extension
//! beyond the paper; see DESIGN.md "Cluster layer").
//!
//! Per-replica load is held constant across fleet sizes: a cell with N
//! replicas serves N× the single-device arrival rate and N× the task
//! count, so columns compare routing quality at equal pressure. The
//! expected shape: at 1 replica all strategies are identical; as the
//! fleet grows, load-oblivious round-robin lets Poisson bursts pile
//! onto individual replicas while SLO-aware routing absorbs them, so
//! `slo-aware` fleet attainment ≥ `round-robin` at every width.

use anyhow::Result;

use crate::cluster::RoutingStrategy;
use crate::config::ServeConfig;
use crate::engine::memory::MemoryStats;
use crate::metrics::report::{
    latency_summary_json, memory_stats_json, ms2, nan_null, pct, Table,
};
use crate::metrics::{Attainment, LatencySummary};
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{default_drain, run_cluster};

/// Fleet widths the sweep compares.
pub fn default_replica_counts() -> Vec<usize> {
    vec![1, 2, 4]
}

/// One (replica count, strategy) cell.
#[derive(Debug)]
pub struct ClusterCell {
    /// Fleet width of this cell.
    pub replicas: usize,
    /// Routing strategy label.
    pub strategy: &'static str,
    /// Fleet-wide attainment.
    pub attainment: Attainment,
    /// Fleet-wide TTFT/TPOT distributions.
    pub latency: LatencySummary,
    /// Tasks routed to each replica (balance diagnostics).
    pub routed: Vec<usize>,
    /// Fleet-aggregated KV accounting (peak bytes, swap counters).
    pub memory: MemoryStats,
}

/// Run one cell: N replicas at N× the configured single-device load.
pub fn run_cell(
    strategy: RoutingStrategy,
    replicas: usize,
    cfg: &ServeConfig,
) -> Result<ClusterCell> {
    let workload = WorkloadSpec::paper_mix(
        cfg.arrival_rate * replicas as f64,
        cfg.rt_ratio,
        cfg.n_tasks * replicas,
        cfg.seed,
    )
    .generate();
    let report = run_cluster(strategy, replicas, workload, cfg, default_drain())?;
    let tasks = report.tasks();
    Ok(ClusterCell {
        replicas,
        strategy: report.strategy,
        attainment: Attainment::compute(&tasks),
        latency: LatencySummary::compute(&tasks),
        routed: report.replicas.iter().map(|r| r.routed).collect(),
        memory: report.fleet_memory(),
    })
}

/// Full sweep; prints the fleet table and returns the JSON series.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let mut cells: Vec<ClusterCell> = Vec::new();
    for &n in &default_replica_counts() {
        for strategy in RoutingStrategy::ALL {
            cells.push(run_cell(strategy, n, cfg)?);
        }
    }

    println!(
        "Cluster sweep — policy {:?}, per-replica rate {}, RT ratio {}, \
         {} tasks/replica, seed {}\n",
        cfg.policy, cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed
    );
    let mut t = Table::new(&[
        "replicas", "strategy", "fleet SLO", "RT SLO", "non-RT SLO", "TTFT p99",
        "TPOT p99", "routed per replica",
    ]);
    for c in &cells {
        t.row(vec![
            c.replicas.to_string(),
            c.strategy.to_string(),
            pct(c.attainment.slo),
            pct(c.attainment.rt_slo),
            pct(c.attainment.nrt_slo),
            ms2(c.latency.ttft.p99_ms),
            ms2(c.latency.tpot.p99_ms),
            format!("{:?}", c.routed),
        ]);
    }
    println!("{}", t.render());

    Ok(Json::from(
        cells
            .iter()
            .map(|c| {
                Json::obj()
                    .set("replicas", c.replicas)
                    .set("strategy", c.strategy)
                    .set("slo", nan_null(c.attainment.slo))
                    .set("rt_slo", nan_null(c.attainment.rt_slo))
                    .set("nrt_slo", nan_null(c.attainment.nrt_slo))
                    .set("latency", latency_summary_json(&c.latency))
                    .set("memory", memory_stats_json(&c.memory))
                    .set(
                        "routed",
                        c.routed.iter().map(|&r| Json::from(r)).collect::<Vec<_>>(),
                    )
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig { n_tasks: 120, ..ServeConfig::default() }
    }

    #[test]
    fn single_replica_strategies_identical() {
        // With one replica every strategy routes every task to it, so
        // the cells must be byte-identical.
        let rr = run_cell(RoutingStrategy::RoundRobin, 1, &cfg()).unwrap();
        let slo = run_cell(RoutingStrategy::SloAware, 1, &cfg()).unwrap();
        assert_eq!(rr.attainment.slo, slo.attainment.slo);
        assert_eq!(rr.attainment.n_finished, slo.attainment.n_finished);
    }

    #[test]
    fn slo_aware_at_least_round_robin_at_width_two() {
        // The acceptance shape of the sweep: at equal load, SLO-aware
        // routing never does worse than round-robin on the heterogeneous
        // paper mix (RT deadlines next to voice/Q&A rate SLOs). Width 2
        // here; the width-4 cell is asserted by the integration test
        // `slo_aware_routing_at_least_round_robin`.
        let rr = run_cell(RoutingStrategy::RoundRobin, 2, &cfg()).unwrap();
        let slo = run_cell(RoutingStrategy::SloAware, 2, &cfg()).unwrap();
        assert!(
            slo.attainment.slo >= rr.attainment.slo,
            "slo-aware {} < round-robin {}",
            slo.attainment.slo,
            rr.attainment.slo
        );
    }

    #[test]
    fn routed_counts_cover_workload() {
        let c = run_cell(RoutingStrategy::LeastLoaded, 2, &cfg()).unwrap();
        assert_eq!(c.routed.iter().sum::<usize>(), c.attainment.n_tasks);
        assert_eq!(c.attainment.n_tasks, 240);
    }
}
