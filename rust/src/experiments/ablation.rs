//! Ablations of SLICE's design choices (DESIGN.md "Design choices to
//! ablate"):
//!   1. utility-rate ordering (r = U * T_TPOT) vs plain-utility ordering;
//!   2. the 1000 ms cycle cap vs shorter/longer caps;
//!   3. utility adaptor off vs SJF decay (head-of-line blocking);
//!
//! Each ablation runs the saturated dynamic workload and reports the
//! attainment deltas.

use anyhow::Result;

use crate::config::{PolicyKind, ServeConfig};
use crate::coordinator::preemption::UtilityAdaptor;
use crate::metrics::report::{nan_null, pct, Table};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::util::ms;
use crate::workload::WorkloadSpec;

use super::{default_drain, run_sim};

/// One ablation row.
#[derive(Debug)]
pub struct AblationRow {
    /// Variant label.
    pub name: String,
    /// Attainment under the variant.
    pub attainment: Attainment,
}

fn run_variant(name: &str, cfg: &ServeConfig) -> Result<AblationRow> {
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let report = run_sim(PolicyKind::Slice, workload, cfg, default_drain())?;
    Ok(AblationRow {
        name: name.to_string(),
        attainment: Attainment::compute(&report.tasks),
    })
}

/// Run all ablations; returns rows and prints the table.
pub fn run(base: &ServeConfig) -> Result<Json> {
    let mut rows = Vec::new();

    rows.push(run_variant("SLICE (default, cap=1000ms)", base)?);

    for cap_ms in [250.0, 500.0, 2000.0] {
        let cfg = ServeConfig { cycle_cap: ms(cap_ms), ..base.clone() };
        rows.push(run_variant(&format!("cycle cap {cap_ms}ms"), &cfg)?);
    }

    let sjf = ServeConfig {
        adaptor: UtilityAdaptor::SjfDecay { factor: 0.5, tau: 32 },
        ..base.clone()
    };
    rows.push(run_variant("adaptor = SJF decay", &sjf)?);

    let sticky = ServeConfig {
        adaptor: UtilityAdaptor::StickyBoost { multiplier: 2.0 },
        ..base.clone()
    };
    rows.push(run_variant("adaptor = sticky boost", &sticky)?);

    // extension: charge pending prefills to the cycle budget (stresses
    // bursty arrivals; run at 3x the base rate to expose the effect)
    for (name, on) in [("bursty, prefill-naive", false), ("bursty, prefill-aware", true)] {
        let cfg = ServeConfig {
            arrival_rate: base.arrival_rate * 3.0,
            prefill_aware: on,
            ..base.clone()
        };
        rows.push(run_variant(name, &cfg)?);
    }

    let mut t = Table::new(&["variant", "overall SLO", "RT SLO", "NRT SLO"]);
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            pct(r.attainment.slo),
            pct(r.attainment.rt_slo),
            pct(r.attainment.nrt_slo),
        ]);
    }
    println!("Ablations — SLICE design choices (saturated dynamic workload)\n");
    println!("{}", t.render());

    Ok(Json::from(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("variant", r.name.clone())
                    .set("slo", nan_null(r.attainment.slo))
                    .set("rt_slo", nan_null(r.attainment.rt_slo))
                    .set("nrt_slo", nan_null(r.attainment.nrt_slo))
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_variants_all_run() {
        let base = ServeConfig { n_tasks: 60, ..ServeConfig::default() };
        let j = run(&base).unwrap();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 8);
    }

    #[test]
    fn prefill_aware_preserves_rt_guarantee() {
        // The extension only shrinks the admitted set; the real-time
        // guarantee must stay intact (small per-task noise allowed: a
        // tighter budget can reorder which burst member waits).
        let naive = ServeConfig {
            n_tasks: 120,
            arrival_rate: 3.0,
            ..ServeConfig::default()
        };
        let aware = ServeConfig { prefill_aware: true, ..naive.clone() };
        let a = run_variant("naive", &naive).unwrap();
        let b = run_variant("aware", &aware).unwrap();
        assert!(
            b.attainment.rt_slo >= a.attainment.rt_slo - 0.02,
            "prefill-aware RT {} well below naive RT {}",
            b.attainment.rt_slo,
            a.attainment.rt_slo
        );
        assert!(b.attainment.rt_slo > 0.9);
    }
}
