//! Fig. 7/8/9 — the dynamic experiment: Poisson arrivals at rate 1.0
//! (the load that saturates the paper's GPU), RT:NRT = 7:3.
//!
//! Fig. 7: SLO attainment overall / real-time / non-real-time.
//! Fig. 8: TPOT, TTFT and deadline attainment breakdown.
//! Fig. 9: average completion time by task group.

use anyhow::Result;

use crate::config::{PolicyKind, ServeConfig};
use crate::metrics::report::{attainment_json, pct, secs2, Table};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{default_drain, run_sim, ALL_POLICIES};

/// One policy's dynamic-run outcome.
#[derive(Debug)]
pub struct DynamicResult {
    /// Policy label.
    pub policy: &'static str,
    /// Attainment over the dynamic workload.
    pub attainment: Attainment,
}

/// Run the dynamic workload for one policy.
pub fn run_policy(kind: PolicyKind, cfg: &ServeConfig) -> Result<DynamicResult> {
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let report = run_sim(kind, workload, cfg, default_drain())?;
    Ok(DynamicResult {
        policy: report.policy,
        attainment: Attainment::compute(&report.tasks),
    })
}

/// Run all three policies; print Fig. 7, Fig. 8 and Fig. 9 series.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let results: Vec<DynamicResult> = ALL_POLICIES
        .iter()
        .map(|&k| run_policy(k, cfg))
        .collect::<Result<_>>()?;

    println!(
        "Dynamic experiment — arrival rate {}, RT:NRT = {:.0}:{:.0}, {} tasks, seed {}\n",
        cfg.arrival_rate,
        cfg.rt_ratio * 10.0,
        (1.0 - cfg.rt_ratio) * 10.0,
        cfg.n_tasks,
        cfg.seed
    );

    let mut t7 = Table::new(&["Strategy", "Overall SLO", "Real-time SLO", "Non-RT SLO"]);
    for r in &results {
        t7.row(vec![
            r.policy.to_string(),
            pct(r.attainment.slo),
            pct(r.attainment.rt_slo),
            pct(r.attainment.nrt_slo),
        ]);
    }
    println!("Fig. 7 — SLO attainment\n\n{}", t7.render());

    let mut t8 = Table::new(&[
        "Strategy", "NRT TTFT attain", "NRT TPOT attain", "RT deadline attain",
    ]);
    for r in &results {
        t8.row(vec![
            r.policy.to_string(),
            pct(r.attainment.nrt_ttft),
            pct(r.attainment.nrt_tpot),
            pct(r.attainment.rt_slo),
        ]);
    }
    println!("Fig. 8 — attainment breakdown\n\n{}", t8.render());

    let mut t9 = Table::new(&[
        "Strategy", "Mean completion (all)", "Mean completion (RT)", "Mean completion (NRT)",
    ]);
    for r in &results {
        t9.row(vec![
            r.policy.to_string(),
            secs2(r.attainment.mean_completion_all),
            secs2(r.attainment.mean_completion_rt),
            secs2(r.attainment.mean_completion_nrt),
        ]);
    }
    println!("Fig. 9 — completion time\n\n{}", t9.render());

    Ok(Json::from(
        results
            .iter()
            .map(|r| {
                Json::obj()
                    .set("policy", r.policy)
                    .set("attainment", attainment_json(&r.attainment))
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig { n_tasks: 150, ..ServeConfig::default() }
    }

    #[test]
    fn slice_beats_baselines_at_saturation() {
        let slice = run_policy(PolicyKind::Slice, &cfg()).unwrap();
        let orca = run_policy(PolicyKind::Orca, &cfg()).unwrap();
        let fast = run_policy(PolicyKind::FastServe, &cfg()).unwrap();

        // Fig. 7 shape: SLICE well above both baselines overall
        assert!(
            slice.attainment.slo > orca.attainment.slo,
            "SLICE {} vs Orca {}",
            slice.attainment.slo,
            orca.attainment.slo
        );
        assert!(slice.attainment.slo > fast.attainment.slo);
        // and real-time attainment is high
        assert!(
            slice.attainment.rt_slo > 0.8,
            "SLICE RT attainment {} (paper: 85%)",
            slice.attainment.rt_slo
        );
    }

    #[test]
    fn slice_faster_rt_completion() {
        // Fig. 9 shape: SLICE completes real-time tasks much faster.
        let slice = run_policy(PolicyKind::Slice, &cfg()).unwrap();
        let orca = run_policy(PolicyKind::Orca, &cfg()).unwrap();
        assert!(
            slice.attainment.mean_completion_rt < orca.attainment.mean_completion_rt,
            "SLICE RT {}s vs Orca RT {}s",
            slice.attainment.mean_completion_rt,
            orca.attainment.mean_completion_rt
        );
    }
}
