//! Chaos sweep — detection delay × churn × retry policy over the
//! crash-at-overload cell (extension beyond the paper; DESIGN.md
//! "Failure detection & recovery").
//!
//! The elastic sweep measures crashes the fleet learns about
//! *instantly* (oracle detection). This sweep measures the cost of
//! realism: with `[cluster.detector]` active, a crash is invisible
//! until `suspicion_timeout` of missed heartbeats accumulate, and
//! every task dispatched into the corpse during that gap lands in
//! limbo — recovered at confirmation via bounded retry with
//! exponential backoff, or shed. Three axes:
//!
//!   * **detection delay** — `suspicion_timeout` of 0 (the oracle
//!     baseline, detector inert, bit-exact with the elastic sweep's
//!     crash path), 2 s, and 8 s;
//!   * **churn** — the elastic sweep's deterministic crash schedule
//!     (replicas 0 and 1 at 40 s / 80 s) vs seeded random churn
//!     ([`CHURN_RATE`] events/s: joins, leaves *and* crashes);
//!   * **retry policy** — the patient default ([`MAX_RETRIES`]
//!     attempts, [`RETRY_BACKOFF_S`] base backoff, doubling — the last
//!     attempts land in the post-burst drain where placement succeeds)
//!     vs `max_retries = 0` (every limbo task shed at confirmation:
//!     the no-retry floor).
//!
//! Cells run the scale sweep's edge-mixed overload shape (SLO-aware
//! routing, migration on) with admission **off**: under Eq. 7 headroom
//! admission the overload window sheds arrivals wholesale, which would
//! drown the chaos losses this sweep isolates. With admission off the
//! only shed paths are the recovery paths themselves
//! (`retry_exhausted`, `limbo_lost`), so the retry-vs-no-retry gap in
//! the shed column *is* the recovery win.
//!
//! The acceptance gate for the detector work is the largest crash
//! cell: the retry variant must show nonzero retries and shed
//! strictly below its no-retry twin at the same delay.

use std::time::Instant;

use anyhow::Result;

use crate::cluster::{
    FleetSpec, LifecycleAction, LifecycleConfig, LifecycleEvent, RoutingStrategy,
};
use crate::config::{ClusterEngine, PolicyKind, ServeConfig};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::util::secs;
use crate::workload::WorkloadSpec;

use super::run_fleet;

/// Default task counts the sweep runs (override with `--tasks`). The
/// larger size is the scale sweep's overload cell.
pub const DEFAULT_SIZES: [usize; 2] = [1_000, 10_000];

/// Variants every size runs, in report order: schedule × delay × retry
/// policy, with one oracle baseline per schedule (retry policy is
/// irrelevant at delay 0 — the detector is inert and nothing limboes).
pub const VARIANTS: [&str; 10] = [
    "crash-oracle",
    "crash-d2",
    "crash-d2-noretry",
    "crash-d8",
    "crash-d8-noretry",
    "churn-oracle",
    "churn-d2",
    "churn-d2-noretry",
    "churn-d8",
    "churn-d8-noretry",
];

/// Heartbeat period every detecting variant uses.
pub const HEARTBEAT_S: f64 = 0.5;

/// Retry budget of the retrying variants. Patient on purpose: with
/// [`RETRY_BACKOFF_S`] doubling, the budget spans past the 120 s
/// arrival window into the drain, where the fleet has capacity again.
pub const MAX_RETRIES: u32 = 8;

/// Base backoff (seconds) before the second attempt; doubles per
/// attempt after that. The first attempt fires at confirmation.
pub const RETRY_BACKOFF_S: f64 = 2.0;

/// Seeded-churn event rate (events/s) of the `churn-*` variants.
pub const CHURN_RATE: f64 = 0.05;

/// Fleet bounds of the `churn-*` variants (the deterministic crash
/// variants keep the config defaults, like the elastic sweep).
pub const CHURN_MIN_REPLICAS: usize = 2;
pub const CHURN_MAX_REPLICAS: usize = 8;

/// Virtual seconds the whole burst arrives within (same window as the
/// scale and elastic sweeps, so the 10k cell is the same overload).
pub const ARRIVAL_WINDOW_S: f64 = 120.0;

/// Virtual drain past the last arrival.
pub const DRAIN_S: f64 = 60.0;

/// One (variant, task count) cell.
#[derive(Debug)]
pub struct ChaosCell {
    /// Variant label (see [`VARIANTS`]).
    pub variant: &'static str,
    /// Workload size.
    pub n_tasks: usize,
    /// Offered arrival rate (tasks/s).
    pub rate: f64,
    /// Detection delay (`suspicion_timeout`) in seconds; 0 = oracle.
    pub detect_delay_s: f64,
    /// Retry budget (0 = shed every limbo task at confirmation).
    pub max_retries: u32,
    /// Alive replicas at the horizon.
    pub replicas_final: usize,
    /// Tasks finished by the horizon.
    pub finished: usize,
    /// Tasks shed fleet-wide.
    pub shed: u64,
    /// `shed / n_tasks`.
    pub shed_rate: f64,
    /// SLO attainment over every routed *and* shed task.
    pub slo: f64,
    /// Physical crashes injected.
    pub crashes: u64,
    /// Suspicion edges raised / of those, cleared by a fresh heartbeat.
    pub suspicions: u64,
    pub false_suspicions: u64,
    /// Crashes confirmed by the detector (0 in oracle variants).
    pub detections: u64,
    /// Limbo tasks found on confirmed corpses / retry dispatches run /
    /// tasks shed with the budget spent / tasks still limboed at the
    /// horizon.
    pub limbo_recovered: u64,
    pub retries: u64,
    pub retry_exhausted: u64,
    pub limbo_lost: u64,
    /// Oracle-path evacuation counters (pre-crash queue + in-service).
    pub evac_requeued: u64,
    pub evac_restarted: u64,
    /// Host wall-clock seconds for the cell.
    pub wall_s: f64,
}

/// Decode a variant name into (churn?, detection delay s, max retries).
pub fn decode(variant: &str) -> Result<(bool, f64, u32)> {
    let (schedule, rest) = variant
        .split_once('-')
        .ok_or_else(|| anyhow::anyhow!("unknown chaos-sweep variant '{variant}'"))?;
    let churn = match schedule {
        "crash" => false,
        "churn" => true,
        _ => anyhow::bail!("unknown chaos-sweep variant '{variant}'"),
    };
    let (delay, retries) = match rest {
        "oracle" => (0.0, MAX_RETRIES),
        "d2" => (2.0, MAX_RETRIES),
        "d2-noretry" => (2.0, 0),
        "d8" => (8.0, MAX_RETRIES),
        "d8-noretry" => (8.0, 0),
        _ => anyhow::bail!("unknown chaos-sweep variant '{variant}'"),
    };
    Ok((churn, delay, retries))
}

/// The lifecycle config a variant name implies.
pub fn lifecycle_for(variant: &str) -> Result<LifecycleConfig> {
    let (churn, delay, retries) = decode(variant)?;
    let mut lc = LifecycleConfig::default();
    if churn {
        lc.churn_rate = CHURN_RATE;
        lc.min_replicas = CHURN_MIN_REPLICAS;
        lc.max_replicas = CHURN_MAX_REPLICAS;
    } else {
        // the elastic sweep's crash schedule: explicit targets, no RNG
        lc.events = vec![
            LifecycleEvent {
                time: secs(40.0),
                action: LifecycleAction::Crash,
                target: Some(0),
            },
            LifecycleEvent {
                time: secs(80.0),
                action: LifecycleAction::Crash,
                target: Some(1),
            },
        ];
    }
    lc.detector.enabled = true;
    lc.detector.heartbeat_interval = secs(HEARTBEAT_S);
    lc.detector.suspicion_timeout = secs(delay);
    lc.detector.max_retries = retries;
    lc.detector.retry_backoff = secs(RETRY_BACKOFF_S);
    Ok(lc)
}

/// Run one cell: the scale sweep's edge-mixed overload shape with the
/// variant's lifecycle + detector config attached (admission off — see
/// the module doc).
pub fn run_cell(
    variant: &'static str,
    n_tasks: usize,
    cfg: &ServeConfig,
) -> Result<ChaosCell> {
    let (_, delay, retries) = decode(variant)?;
    let mut cfg = cfg.clone();
    cfg.n_tasks = n_tasks;
    cfg.arrival_rate = n_tasks as f64 / ARRIVAL_WINDOW_S;
    cfg.policy = PolicyKind::Slice;
    cfg.cluster_engine = ClusterEngine::Event;
    cfg.cluster_admission.enabled = false;
    cfg.cluster_migration = true;
    cfg.lifecycle = lifecycle_for(variant)?;
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let spec = FleetSpec::preset("edge-mixed")?.with_cycle_cap(cfg.cycle_cap);

    let start = Instant::now();
    let report = run_fleet(RoutingStrategy::SloAware, &spec, workload, &cfg, secs(DRAIN_S))?;
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    let a = Attainment::compute(&report.tasks());
    let shed = report.shed_total();
    let e = &report.elastic;
    Ok(ChaosCell {
        variant,
        n_tasks,
        rate: cfg.arrival_rate,
        detect_delay_s: delay,
        max_retries: retries,
        replicas_final: report.alive_replicas(),
        finished: a.n_finished,
        shed,
        shed_rate: shed as f64 / n_tasks as f64,
        slo: a.slo,
        crashes: e.crashes,
        suspicions: e.suspicions,
        false_suspicions: e.false_suspicions,
        detections: e.detections,
        limbo_recovered: e.limbo_recovered,
        retries: e.retries,
        retry_exhausted: e.retry_exhausted,
        limbo_lost: e.limbo_lost,
        evac_requeued: e.evac_requeued,
        evac_restarted: e.evac_restarted,
        wall_s,
    })
}

fn render_rows(rows: &[ChaosCell]) {
    use crate::metrics::report::{pct, Table};
    let mut t = Table::new(&[
        "variant", "tasks", "delay s", "budget", "alive", "finished", "shed",
        "shed%", "SLO", "crash", "susp(false)", "detect", "limbo", "retry",
        "exhaust", "lost",
    ]);
    for c in rows {
        t.row(vec![
            c.variant.to_string(),
            c.n_tasks.to_string(),
            format!("{:.0}", c.detect_delay_s),
            c.max_retries.to_string(),
            c.replicas_final.to_string(),
            c.finished.to_string(),
            c.shed.to_string(),
            pct(c.shed_rate),
            pct(c.slo),
            c.crashes.to_string(),
            format!("{}({})", c.suspicions, c.false_suspicions),
            c.detections.to_string(),
            c.limbo_recovered.to_string(),
            c.retries.to_string(),
            c.retry_exhausted.to_string(),
            c.limbo_lost.to_string(),
        ]);
    }
    println!("{}", t.render());
}

fn rows_to_json(rows: &[ChaosCell]) -> Json {
    use crate::metrics::report::nan_null;
    Json::from(
        rows.iter()
            .map(|c| {
                Json::obj()
                    .set("variant", c.variant)
                    .set("n_tasks", c.n_tasks)
                    .set("rate", c.rate)
                    .set("detect_delay_s", c.detect_delay_s)
                    .set("max_retries", c.max_retries as u64)
                    .set("replicas_final", c.replicas_final)
                    .set("finished", c.finished)
                    .set("shed", c.shed)
                    .set("shed_rate", c.shed_rate)
                    .set("slo", nan_null(c.slo))
                    .set("crashes", c.crashes)
                    .set("suspicions", c.suspicions)
                    .set("false_suspicions", c.false_suspicions)
                    .set("detections", c.detections)
                    .set("limbo_recovered", c.limbo_recovered)
                    .set("retries", c.retries)
                    .set("retry_exhausted", c.retry_exhausted)
                    .set("limbo_lost", c.limbo_lost)
                    .set("evac_requeued", c.evac_requeued)
                    .set("evac_restarted", c.evac_restarted)
                    .set("wall_s", c.wall_s)
            })
            .collect::<Vec<_>>(),
    )
}

/// Full sweep over `sizes`; prints the table (plus the
/// retry-vs-no-retry shed verdict at the largest size) and returns the
/// JSON series (BENCH_10.json shape).
pub fn run(cfg: &ServeConfig, sizes: &[usize]) -> Result<Json> {
    let mut rows: Vec<ChaosCell> = Vec::new();
    for &n in sizes {
        for variant in VARIANTS {
            rows.push(run_cell(variant, n, cfg)?);
        }
    }

    println!(
        "Chaos sweep — SLICE, edge-mixed fleet, slo-aware + migration, \
         admission off, heartbeat {HEARTBEAT_S}s, \
         {ARRIVAL_WINDOW_S:.0}s arrival window, {DRAIN_S:.0}s drain, seed {}\n",
        cfg.seed
    );
    render_rows(&rows);
    if let Some(&n) = sizes.last() {
        let find = |v: &str| rows.iter().find(|c| c.n_tasks == n && c.variant == v);
        for delay in ["d2", "d8"] {
            let (retry, bare) = (
                find(&format!("crash-{delay}")),
                find(&format!("crash-{delay}-noretry")),
            );
            if let (Some(r), Some(b)) = (retry, bare) {
                println!(
                    "\ncrash {delay} at {n} tasks: retry shed {} ({} retries, {} \
                     recovered) vs no-retry shed {} — {}",
                    r.shed,
                    r.retries,
                    r.limbo_recovered,
                    b.shed,
                    if r.retries > 0 && r.shed < b.shed {
                        "retry recovers limbo tasks"
                    } else {
                        "RETRY DID NOT BEAT THE NO-RETRY FLOOR"
                    }
                );
            }
        }
    }
    Ok(rows_to_json(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_cell_keeps_the_detector_counters_at_zero() {
        let c = run_cell("crash-oracle", 60, &ServeConfig::default()).unwrap();
        assert_eq!(c.crashes, 2, "both explicit crashes fire");
        assert_eq!(c.replicas_final, 2);
        assert_eq!(
            c.suspicions + c.false_suspicions + c.detections, 0,
            "delay 0 keeps the detector inert"
        );
        assert_eq!(c.limbo_recovered + c.retries + c.retry_exhausted + c.limbo_lost, 0);
    }

    #[test]
    fn delayed_cell_detects_both_crashes() {
        let c = run_cell("crash-d2", 60, &ServeConfig::default()).unwrap();
        assert_eq!(c.crashes, 2);
        assert_eq!(c.detections, 2, "both corpses confirmed by heartbeat age");
        assert!(c.suspicions >= 2, "confirmation passes through suspicion");
        assert_eq!(c.replicas_final, 2);
    }

    #[test]
    fn noretry_sheds_everything_recovered() {
        let c = run_cell("crash-d8-noretry", 60, &ServeConfig::default()).unwrap();
        assert_eq!(c.max_retries, 0);
        assert_eq!(c.retries, 0, "no retry dispatches at a zero budget");
        assert_eq!(
            c.retry_exhausted, c.limbo_recovered,
            "every limbo task sheds at confirmation"
        );
    }

    #[test]
    fn cells_are_deterministic() {
        let cfg = ServeConfig::default();
        let a = run_cell("churn-d2", 120, &cfg).unwrap();
        let b = run_cell("churn-d2", 120, &cfg).unwrap();
        assert_eq!(a.finished, b.finished, "same seed, same run");
        assert_eq!(a.shed, b.shed);
        assert_eq!((a.detections, a.retries, a.limbo_lost), (b.detections, b.retries, b.limbo_lost));
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(lifecycle_for("crash-d4").is_err());
        assert!(lifecycle_for("mesh-oracle").is_err());
    }
}
