//! Experiment harnesses: one module per table/figure of the paper's
//! evaluation (§VI), plus the cluster-sweep extension (DESIGN.md
//! "Cluster layer"). Every harness prints the same rows/series the
//! paper reports and returns a JSON document for plotting;
//! EXPERIMENTS.md records paper-vs-measured for each.
//!
//! Contract: harnesses compose the other layers ([`run_sim`] /
//! [`run_cluster`] + `workload` + `metrics`) and never reach into
//! scheduler internals, so every policy comparison runs an identical
//! pipeline.
//!
//! | module        | reproduces                                  |
//! |---------------|---------------------------------------------|
//! | `fig1`        | Fig. 1a/1b latency & throughput vs batch    |
//! | `static_mix`  | Table II + Fig. 6 (9-task static workload)  |
//! | `dynamic`     | Fig. 7/8/9 (rate 1.0, RT:NRT = 7:3)         |
//! | `ratio_sweep` | Fig. 10a/b/c (RT ratio sweep)               |
//! | `rate_sweep`  | Fig. 11a/b/c (arrival rate sweep)           |
//! | `ablation`    | design-choice ablations (DESIGN.md)         |
//! | `cluster_sweep` | routing strategies × replica counts (ext.)|
//! | `hetero_sweep`  | fleet mix × strategy × admission (ext.)   |
//! | `scale_sweep`   | scheduler throughput at 1k-10k tasks (ext.)|
//! | `elastic_sweep` | shed/SLO under crashes + autoscaling (ext.) |
//! | `chaos_sweep`   | detection delay × churn × retry policy (ext.)|

pub mod ablation;
pub mod chaos_sweep;
pub mod cluster_sweep;
pub mod dynamic;
pub mod elastic_sweep;
pub mod fig1;
pub mod hetero_sweep;
pub mod memory_sweep;
pub mod rate_sweep;
pub mod ratio_sweep;
pub mod scale_sweep;
pub mod static_mix;

use anyhow::{bail, Result};

use crate::cluster::{
    ClusterReport, DeviceProfile, FleetSpec, Orchestrator, Replica, Router,
    RoutingStrategy,
};
use crate::config::{ClusterEngine, PolicyKind, ServeConfig};
use crate::coordinator::fastserve::FastServePolicy;
use crate::coordinator::orca::OrcaPolicy;
use crate::coordinator::scheduler::Policy;
use crate::coordinator::slice::{MemoryBudget, SliceConfig, SlicePolicy};
use crate::coordinator::task::Task;
use crate::engine::clock::VirtualClock;
use crate::engine::memory::KvCacheModel;
use crate::engine::sim::SimEngine;
use crate::server::{RunReport, Server};
use crate::util::{secs, Micros};

/// All three policies, in the order the paper reports them.
pub const ALL_POLICIES: [PolicyKind; 3] =
    [PolicyKind::Orca, PolicyKind::FastServe, PolicyKind::Slice];

/// The single-device profile a serve config implies: the paper's
/// standard device carrying the configured cycle cap and (tier-scaled)
/// KV capacity.
pub fn standard_profile(cfg: &ServeConfig) -> DeviceProfile {
    let mut profile = DeviceProfile::standard();
    profile.cycle_cap = cfg.cycle_cap;
    profile.kv_capacity = cfg
        .memory
        .kv_capacity
        .map(|b| (b as f64 * profile.kv_fraction) as u64);
    profile
}

/// Instantiate a policy from its kind and the serve config, calibrated
/// to the paper's standard device (the single-device path).
pub fn build_policy(kind: PolicyKind, cfg: &ServeConfig) -> Box<dyn Policy> {
    build_policy_for(kind, cfg, &standard_profile(cfg))
}

/// Instantiate a policy calibrated to one replica's device profile: the
/// scheduler sees the device's own latency curve, cycle cap, batch
/// limit (further capped by the configured `max_batch`) and — when a
/// finite KV capacity is configured and the policy is memory-aware —
/// its KV budget. For the standard profile this is exactly the
/// single-device construction.
pub fn build_policy_for(
    kind: PolicyKind,
    cfg: &ServeConfig,
    profile: &DeviceProfile,
) -> Box<dyn Policy> {
    let max_batch = cfg.max_batch.min(profile.max_batch);
    match kind {
        PolicyKind::Slice => {
            let mut lat = profile.latency.clone();
            lat.max_batch = max_batch;
            Box::new(SlicePolicy::new(
                lat,
                SliceConfig {
                    cycle_cap: profile.cycle_cap,
                    adaptor: cfg.adaptor,
                    prefill_aware: cfg.prefill_aware,
                    memory: MemoryBudget::from_config(&cfg.memory, profile.kv_capacity),
                    incremental: cfg.incremental,
                },
            ))
        }
        PolicyKind::Orca => Box::new(OrcaPolicy::new(max_batch)),
        PolicyKind::FastServe => {
            let mut fs_cfg = cfg.fastserve.clone();
            fs_cfg.max_batch = max_batch;
            Box::new(FastServePolicy::new(fs_cfg))
        }
    }
}

/// Build a sim engine calibrated to `profile`, carrying the configured
/// memory model (unconstrained and free by default).
pub fn build_engine_for(cfg: &ServeConfig, profile: &DeviceProfile) -> SimEngine {
    let kv = KvCacheModel::new(
        cfg.memory.clone(),
        profile.kv_capacity,
        profile.latency.clone(),
    );
    SimEngine::new(profile.latency.clone(), profile.max_context).with_memory(kv)
}

/// Run one (policy, workload) pair on the simulation engine in virtual
/// time. `drain` extends the horizon past the last arrival.
pub fn run_sim(
    kind: PolicyKind,
    workload: Vec<Task>,
    cfg: &ServeConfig,
    drain: Micros,
) -> Result<RunReport> {
    let last_arrival = workload.last().map_or(0, |t| t.arrival);
    let horizon = last_arrival + drain;
    let policy = build_policy(kind, cfg);
    let engine = Box::new(build_engine_for(cfg, &standard_profile(cfg)));
    Server::new(workload, policy, engine, VirtualClock::new()).run(horizon)
}

/// Run one (strategy, homogeneous replica count, workload) cluster
/// configuration on the simulation engine — the PR 2 shape, now a thin
/// wrapper over [`run_fleet`] with `replicas` standard devices.
pub fn run_cluster(
    strategy: RoutingStrategy,
    replicas: usize,
    workload: Vec<Task>,
    cfg: &ServeConfig,
    drain: Micros,
) -> Result<ClusterReport> {
    run_fleet(
        strategy,
        &FleetSpec::homogeneous(replicas, cfg.cycle_cap),
        workload,
        cfg,
        drain,
    )
}

/// Run one (strategy, fleet spec, workload) cluster configuration on
/// the simulation engine. Every replica gets a fresh policy (from
/// `cfg.policy`) and a sim engine, both calibrated to its own device
/// profile — including its tier-scaled KV capacity when the config
/// constrains memory; admission control and migration follow the
/// config (`cluster_admission` / `cluster_migration` /
/// `cluster_migrate_running`, all off by default). When any elastic
/// feature is enabled (`cfg.lifecycle`) the event engine attaches the
/// lifecycle machinery; replicas that join mid-run are built from the
/// spec's first profile (the fleet's standard tier).
pub fn run_fleet(
    strategy: RoutingStrategy,
    spec: &FleetSpec,
    workload: Vec<Task>,
    cfg: &ServeConfig,
    drain: Micros,
) -> Result<ClusterReport> {
    let (spec, fleet) = build_fleet_for(spec, cfg);
    // the two engines are bit-exact (rust/tests/equivalence.rs); the
    // config picks which one advances the fleet
    match cfg.cluster_engine {
        ClusterEngine::Lockstep => {
            if cfg.lifecycle.any_enabled() {
                bail!(
                    "elastic fleets (lifecycle/autoscaler/health/detector) need the \
                     event engine; the lockstep reference cannot inject lifecycle events"
                );
            }
            Router::new(strategy, fleet)
                .with_admission(cfg.cluster_admission)
                .with_migration(cfg.cluster_migration)
                .with_running_migration(cfg.cluster_migrate_running, cfg.memory.clone())
                .run(workload, drain)
        }
        ClusterEngine::Event => {
            let mut orch = Orchestrator::new(strategy, fleet)
                .with_admission(cfg.cluster_admission)
                .with_migration(cfg.cluster_migration)
                .with_running_migration(cfg.cluster_migrate_running, cfg.memory.clone())
                .with_threads(cfg.cluster_threads);
            if cfg.lifecycle.any_enabled() {
                // joins clone the fleet's first profile — the spec's
                // standard tier — calibrated exactly like the initial
                // replicas
                let template = spec.profiles[0].clone();
                let factory_cfg = cfg.clone();
                orch = orch.with_lifecycle(
                    cfg.lifecycle.clone(),
                    Box::new(move |id| {
                        let mut profile = template.clone();
                        profile.latency.max_batch =
                            factory_cfg.max_batch.min(profile.max_batch);
                        Replica::new(
                            id,
                            build_policy_for(factory_cfg.policy, &factory_cfg, &profile),
                            Box::new(build_engine_for(&factory_cfg, &profile)),
                            profile,
                        )
                    }),
                );
            }
            orch.run(workload, drain)
        }
    }
}

/// Materialize a fleet from a spec: thread the configured base KV
/// capacity into the spec unless it already carries explicit
/// per-replica capacities, then build each replica with a fresh policy
/// and engine calibrated to its own profile.
fn build_fleet_for(spec: &FleetSpec, cfg: &ServeConfig) -> (FleetSpec, Vec<Replica>) {
    let spec = if cfg.memory.constrained()
        && spec.profiles.iter().all(|p| p.kv_capacity.is_none())
    {
        spec.clone().with_kv_capacity(cfg.memory.kv_capacity)
    } else {
        spec.clone()
    };
    let fleet: Vec<Replica> = spec
        .profiles
        .iter()
        .enumerate()
        .map(|(i, profile)| {
            let mut profile = profile.clone();
            profile.latency.max_batch = cfg.max_batch.min(profile.max_batch);
            Replica::new(
                i,
                build_policy_for(cfg.policy, cfg, &profile),
                Box::new(build_engine_for(cfg, &profile)),
                profile,
            )
        })
        .collect();
    (spec, fleet)
}

/// [`run_fleet`] over a pull-based arrival stream: the event engine
/// consumes tasks one at a time (constant memory in the trace length)
/// and folds rejected tasks into a counter
/// (`ClusterReport::rejected_folded`) instead of retaining them — the
/// million-task scale-sweep path. Static fleets only (streaming has no
/// horizon up front, which the lifecycle schedule needs).
pub fn run_fleet_stream<I>(
    strategy: RoutingStrategy,
    spec: &FleetSpec,
    arrivals: I,
    cfg: &ServeConfig,
    drain: Micros,
) -> Result<ClusterReport>
where
    I: IntoIterator<Item = Task>,
{
    if cfg.lifecycle.any_enabled() {
        bail!("streaming runs use static fleets (no lifecycle/autoscaler/health)");
    }
    let (_, fleet) = build_fleet_for(spec, cfg);
    Orchestrator::new(strategy, fleet)
        .with_admission(cfg.cluster_admission)
        .with_migration(cfg.cluster_migration)
        .with_running_migration(cfg.cluster_migrate_running, cfg.memory.clone())
        .with_threads(cfg.cluster_threads)
        .with_fold_rejects(true)
        .run_stream(arrivals, drain)
        .map(|(report, _)| report)
}

/// Default drain window after the last arrival (virtual seconds).
pub fn default_drain() -> Micros {
    secs(120.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Attainment;
    use crate::workload::WorkloadSpec;

    #[test]
    fn run_sim_executes_all_policies() {
        let cfg = ServeConfig::default();
        for kind in ALL_POLICIES {
            let workload = WorkloadSpec::paper_mix(0.5, 0.7, 20, 1).generate();
            let report = run_sim(kind, workload, &cfg, default_drain()).unwrap();
            assert_eq!(report.tasks.len(), 20);
            let a = Attainment::compute(&report.tasks);
            assert_eq!(a.n_finished, 20, "{kind:?} must finish a light load");
        }
    }

    #[test]
    fn light_load_all_policies_high_attainment() {
        // At 0.3 tasks/s the device is nearly idle: every policy should
        // meet nearly every SLO.
        let cfg = ServeConfig::default();
        for kind in ALL_POLICIES {
            let workload = WorkloadSpec::paper_mix(0.3, 0.7, 30, 2).generate();
            let report = run_sim(kind, workload, &cfg, default_drain()).unwrap();
            let a = Attainment::compute(&report.tasks);
            assert!(a.slo > 0.9, "{kind:?} attainment {} too low at idle", a.slo);
        }
    }
}
