//! Table II + Fig. 6 — the static 9-task experiment.
//!
//! Three task types with differentiated TPOT SLOs (A: 100 ms x3,
//! B: 120 ms x4, C: 250 ms x2) all arrive at t = 0. Orca and FastServe
//! batch all nine uniformly, so every task measures the same TPOT
//! (l(9) = 128.59 ms on the paper's GPU) and only type C meets its SLO
//! (2/9 ≈ 22%). SLICE allocates per-type rates via the mask matrix and
//! meets all nine (100%).

use anyhow::Result;

use crate::config::{PolicyKind, ServeConfig};
use crate::coordinator::task::Task;
use crate::metrics::report::{pct, Table};
use crate::metrics::{Attainment, TpotSummary};
use crate::util::json::Json;
use crate::workload::table2_static_workload;

use super::{run_sim, ALL_POLICIES};

/// Result rows for one strategy.
#[derive(Debug)]
pub struct StaticResult {
    /// Policy label.
    pub policy: &'static str,
    /// Per-type TPOT summaries (Task A / B / C).
    pub groups: Vec<TpotSummary>,
    /// Overall SLO attainment on the 9-task mix.
    pub slo_attainment: f64,
}

fn group_tasks(tasks: &[Task]) -> Vec<(&'static str, Vec<&Task>)> {
    let by_tpot = |ms: u64| -> Vec<&Task> {
        tasks.iter().filter(|t| t.slo.tpot == ms * 1000).collect()
    };
    vec![
        ("Task A", by_tpot(100)),
        ("Task B", by_tpot(120)),
        ("Task C", by_tpot(250)),
    ]
}

/// Run the static experiment for one policy.
pub fn run_policy(kind: PolicyKind, cfg: &ServeConfig) -> Result<StaticResult> {
    let workload = table2_static_workload();
    let report = run_sim(kind, workload, cfg, super::default_drain())?;
    let groups = group_tasks(&report.tasks)
        .into_iter()
        .map(|(label, ts)| TpotSummary::compute(label, &ts))
        .collect();
    let att = Attainment::compute(&report.tasks);
    Ok(StaticResult { policy: report.policy, groups, slo_attainment: att.slo })
}

/// Run all three strategies and print the Table II layout.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let mut out = Vec::new();
    let mut table = Table::new(&[
        "Strategy", "Task Type", "Tasks", "TPOT SLO", "Actual TPOT",
        "Decoding rate", "TPOT ok", "SLO attainment",
    ]);
    for kind in ALL_POLICIES {
        let res = run_policy(kind, cfg)?;
        for (i, g) in res.groups.iter().enumerate() {
            table.row(vec![
                if i == 0 { res.policy.to_string() } else { String::new() },
                g.label.clone(),
                g.n_tasks.to_string(),
                format!("{:.0}ms", g.tpot_slo_ms),
                format!("{:.2}ms", g.mean_tpot_ms),
                format!("{:.2} tok/s", g.mean_rate),
                if g.all_tpot_met { "Yes" } else { "No" }.to_string(),
                if i == 0 { pct(res.slo_attainment) } else { String::new() },
            ]);
        }
        out.push(res);
    }
    println!("Table II / Fig. 6 — static 9-task mix, three strategies\n");
    println!("{}", table.render());

    Ok(Json::from(
        out.iter()
            .map(|r| {
                Json::obj()
                    .set("policy", r.policy)
                    .set("slo_attainment", r.slo_attainment)
                    .set(
                        "groups",
                        r.groups
                            .iter()
                            .map(|g| {
                                Json::obj()
                                    .set("label", g.label.clone())
                                    .set("n", g.n_tasks)
                                    .set("tpot_slo_ms", g.tpot_slo_ms)
                                    .set("actual_tpot_ms", g.mean_tpot_ms)
                                    .set("rate_tps", g.mean_rate)
                                    .set("tpot_met", g.all_tpot_met)
                            })
                            .collect::<Vec<_>>(),
                    )
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_meets_all_baselines_meet_only_type_c() {
        let cfg = ServeConfig::default();

        let slice = run_policy(PolicyKind::Slice, &cfg).unwrap();
        assert!(
            slice.slo_attainment > 0.99,
            "SLICE static attainment {} (paper: 100%)",
            slice.slo_attainment
        );
        for g in &slice.groups {
            assert!(g.all_tpot_met, "SLICE must meet {} SLO", g.label);
            // allocated rate must be at least the SLO rate
            assert!(
                g.mean_rate + 0.2 >= 1000.0 / g.tpot_slo_ms,
                "{}: rate {} below SLO rate",
                g.label,
                g.mean_rate
            );
        }

        for kind in [PolicyKind::Orca, PolicyKind::FastServe] {
            let res = run_policy(kind, &cfg).unwrap();
            assert!(
                (res.slo_attainment - 2.0 / 9.0).abs() < 1e-6,
                "{:?} attainment {} (paper: 22%)",
                kind,
                res.slo_attainment
            );
            // uniform batching: A and B fail, C passes
            assert!(!res.groups[0].all_tpot_met);
            assert!(!res.groups[1].all_tpot_met);
            assert!(res.groups[2].all_tpot_met);
        }
    }

    #[test]
    fn baselines_have_uniform_tpot_across_types() {
        // Fig. 6's key observation: Orca/FastServe give every type the
        // same decoding rate.
        let cfg = ServeConfig::default();
        let res = run_policy(PolicyKind::Orca, &cfg).unwrap();
        let t0 = res.groups[0].mean_tpot_ms;
        for g in &res.groups[1..] {
            assert!(
                (g.mean_tpot_ms - t0).abs() < 0.15 * t0,
                "uniform TPOT expected, got {} vs {t0}",
                g.mean_tpot_ms
            );
        }
    }

    #[test]
    fn slice_tpot_tracks_slo_ordering() {
        // SLICE gives type A the highest rate, C the lowest (Fig. 6).
        let cfg = ServeConfig::default();
        let res = run_policy(PolicyKind::Slice, &cfg).unwrap();
        assert!(res.groups[0].mean_rate > res.groups[1].mean_rate);
        assert!(res.groups[1].mean_rate > res.groups[2].mean_rate);
    }
}
