//! Heterogeneous-fleet sweep — fleet mix × routing strategy ×
//! admission/migration guards (extension beyond the paper; see
//! DESIGN.md "Heterogeneous fleets").
//!
//! Both fleet shapes serve the *same* workload at the same offered
//! load, sized to the mixed fleet's aggregate capacity (~3 standard
//! device-equivalents for `edge-mixed`): the homogeneous 4×standard
//! fleet has slack, while the mixed fleet only meets its SLOs if
//! routing respects device speed. The expected shape: round-robin
//! sends a quarter of the traffic to the nano-class board and non-RT
//! attainment collapses there; SLO-aware routing sizes each replica's
//! share to its Eq. 7 headroom; admission + migration then shed or
//! re-place the residual overload instead of letting queues grow
//! without bound. The acceptance invariant — mixed fleet, slo-aware +
//! guards ≥ round-robin — is asserted with measured margins in
//! `rust/tests/hetero_fleet.rs`.

use anyhow::Result;

use crate::cluster::{FleetSpec, RoutingStrategy};
use crate::config::ServeConfig;
use crate::engine::memory::MemoryStats;
use crate::metrics::report::{
    latency_summary_json, memory_stats_json, ms2, nan_null, pct, Table,
};
use crate::metrics::{Attainment, LatencySummary};
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::{default_drain, run_fleet};

/// Offered load in standard-device equivalents: the `edge-mixed`
/// capacity (1 + 1 + 1/1.5 + 1/2.5 ≈ 3.07), rounded down so the mixed
/// fleet runs at its knee rather than past it.
pub const LOAD_EQUIVALENTS: f64 = 3.0;

/// The two fleet shapes the sweep compares, as (label, spec) pairs.
pub fn fleet_shapes() -> Vec<(&'static str, FleetSpec)> {
    vec![
        ("uniform-4", FleetSpec::preset("standard,standard,standard,standard").unwrap()),
        ("edge-mixed", FleetSpec::preset("edge-mixed").unwrap()),
    ]
}

/// One (fleet, strategy, guards) cell.
#[derive(Debug)]
pub struct HeteroCell {
    /// Fleet-shape label.
    pub fleet: &'static str,
    /// Per-replica tier names.
    pub profiles: Vec<&'static str>,
    /// Routing strategy label.
    pub strategy: &'static str,
    /// True when admission control + migration were enabled.
    pub guarded: bool,
    /// Fleet-wide attainment (shed tasks count as violations).
    pub attainment: Attainment,
    /// Fleet-wide TTFT/TPOT distributions.
    pub latency: LatencySummary,
    /// Tasks each replica ended the run holding.
    pub routed: Vec<usize>,
    /// Tasks shed by admission control.
    pub rejected: usize,
    /// Tasks re-placed by overload migration.
    pub migrations: u64,
    /// Fleet-aggregated KV accounting (peak bytes, swap counters).
    pub memory: MemoryStats,
}

/// Run one cell. `guarded` switches admission control and overload
/// migration on together (bounds from `cfg.cluster_admission`).
pub fn run_cell(
    label: &'static str,
    spec: &FleetSpec,
    strategy: RoutingStrategy,
    guarded: bool,
    cfg: &ServeConfig,
) -> Result<HeteroCell> {
    let workload = WorkloadSpec::paper_mix(
        cfg.arrival_rate * LOAD_EQUIVALENTS,
        cfg.rt_ratio,
        cfg.n_tasks * LOAD_EQUIVALENTS as usize,
        cfg.seed,
    )
    .generate();
    let mut cfg = cfg.clone();
    cfg.cluster_admission.enabled = guarded;
    cfg.cluster_migration = guarded;
    let report = run_fleet(strategy, spec, workload, &cfg, default_drain())?;
    let tasks = report.tasks();
    Ok(HeteroCell {
        fleet: label,
        profiles: spec.names(),
        strategy: report.strategy,
        guarded,
        attainment: Attainment::compute(&tasks),
        latency: LatencySummary::compute(&tasks),
        routed: report.replicas.iter().map(|r| r.routed).collect(),
        rejected: report.rejected_count(),
        migrations: report.migrations,
        memory: report.fleet_memory(),
    })
}

/// Full sweep; prints the fleet table and returns the JSON series.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let shapes = fleet_shapes();
    let mut cells: Vec<HeteroCell> = Vec::new();
    for (label, spec) in &shapes {
        for guarded in [false, true] {
            for strategy in RoutingStrategy::ALL {
                cells.push(run_cell(*label, spec, strategy, guarded, cfg)?);
            }
        }
    }

    println!(
        "Hetero sweep — policy {:?}, offered load {}x rate {}, RT ratio {}, \
         {} tasks, seed {} (guards = admission + migration)\n",
        cfg.policy,
        LOAD_EQUIVALENTS,
        cfg.arrival_rate,
        cfg.rt_ratio,
        cfg.n_tasks * LOAD_EQUIVALENTS as usize,
        cfg.seed
    );
    let mut t = Table::new(&[
        "fleet", "guards", "strategy", "fleet SLO", "RT SLO", "non-RT SLO", "shed",
        "migrations", "TPOT p99", "routed per replica",
    ]);
    for c in &cells {
        t.row(vec![
            c.fleet.to_string(),
            if c.guarded { "on" } else { "off" }.to_string(),
            c.strategy.to_string(),
            pct(c.attainment.slo),
            pct(c.attainment.rt_slo),
            pct(c.attainment.nrt_slo),
            c.rejected.to_string(),
            c.migrations.to_string(),
            ms2(c.latency.tpot.p99_ms),
            format!("{:?}", c.routed),
        ]);
    }
    println!("{}", t.render());

    Ok(Json::from(
        cells
            .iter()
            .map(|c| {
                Json::obj()
                    .set("fleet", c.fleet)
                    .set(
                        "profiles",
                        c.profiles.iter().map(|&p| Json::from(p)).collect::<Vec<_>>(),
                    )
                    .set("strategy", c.strategy)
                    .set("guarded", c.guarded)
                    .set("slo", nan_null(c.attainment.slo))
                    .set("rt_slo", nan_null(c.attainment.rt_slo))
                    .set("nrt_slo", nan_null(c.attainment.nrt_slo))
                    .set("n_tasks", c.attainment.n_tasks)
                    .set("n_finished", c.attainment.n_finished)
                    .set("rejected", c.rejected)
                    .set("migrations", c.migrations)
                    .set("latency", latency_summary_json(&c.latency))
                    .set("memory", memory_stats_json(&c.memory))
                    .set(
                        "routed",
                        c.routed.iter().map(|&r| Json::from(r)).collect::<Vec<_>>(),
                    )
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig { n_tasks: 30, ..ServeConfig::default() }
    }

    #[test]
    fn cells_cover_the_workload_exactly_once() {
        let shapes = fleet_shapes();
        let (label, spec) = &shapes[1];
        for guarded in [false, true] {
            let c = run_cell(*label, spec, RoutingStrategy::SloAware, guarded, &cfg())
                .unwrap();
            assert_eq!(c.attainment.n_tasks, 90);
            assert_eq!(c.routed.iter().sum::<usize>() + c.rejected, 90);
            assert_eq!(c.profiles, vec!["standard", "standard", "lite", "nano"]);
        }
    }

    #[test]
    fn guarded_cells_are_deterministic() {
        let shapes = fleet_shapes();
        let (label, spec) = &shapes[1];
        let a = run_cell(*label, spec, RoutingStrategy::SloAware, true, &cfg()).unwrap();
        let b = run_cell(*label, spec, RoutingStrategy::SloAware, true, &cfg()).unwrap();
        assert_eq!(a.attainment.slo, b.attainment.slo);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.rejected, b.rejected);
        assert_eq!(a.migrations, b.migrations);
    }

    #[test]
    fn plain_cells_never_shed_or_migrate() {
        let shapes = fleet_shapes();
        let (label, spec) = &shapes[0];
        let c = run_cell(*label, spec, RoutingStrategy::RoundRobin, false, &cfg())
            .unwrap();
        assert_eq!(c.rejected, 0);
        assert_eq!(c.migrations, 0);
    }
}
