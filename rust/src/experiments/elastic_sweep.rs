//! Elastic-fleet sweep — SLO attainment and shed rate under failures
//! and autoscaling (extension beyond the paper; DESIGN.md "Elastic
//! fleets").
//!
//! The scale sweep's 10k-task edge-mixed overload cell sheds nearly the
//! whole burst: four replicas cannot absorb an 83 tasks/s window no
//! matter how the scheduler orders work. This sweep measures what the
//! elastic machinery buys back. Each task count runs four variants of
//! the same edge-mixed overload cell (SLO-aware routing, Eq. 7 headroom
//! admission, overload migration, event engine):
//!
//!   * `static`      — the PR 6 baseline, no elastic features.
//!   * `crash`       — two deterministic crashes (replicas 0 and 1 at
//!                     40 s and 80 s) with no autoscaler: the failure
//!                     floor.
//!   * `autoscale`   — the autoscaler grows the fleet (up to
//!                     [`AUTOSCALE_MAX`]) on sustained admission
//!                     deficit and shrinks it on sustained idleness.
//!   * `autoscale-headroom` — same bounds, but the grow signal is the
//!                     aggregate Eq. 7 headroom floor
//!                     (`grow_on_headroom`, [`HEADROOM_MIN_US`]): the
//!                     fleet grows as slack drains, *before* arrivals
//!                     shed — the proactive-vs-reactive comparison cell.
//!   * `autoscale+crash` — both: recovery under failures.
//!
//! The acceptance gate for the elastic work is the 10k cell:
//! `autoscale` must shed strictly fewer tasks than `static`.

use std::time::Instant;

use anyhow::Result;

use crate::cluster::{
    AdmissionMode, FleetSpec, LifecycleAction, LifecycleConfig, LifecycleEvent,
    RoutingStrategy,
};
use crate::config::{ClusterEngine, PolicyKind, ServeConfig};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::util::{secs, Micros};
use crate::workload::WorkloadSpec;

use super::run_fleet;

/// Default task counts the sweep runs (override with `--tasks`). The
/// larger size is the scale sweep's overload cell.
pub const DEFAULT_SIZES: [usize; 2] = [1_000, 10_000];

/// Variants every size runs, in report order.
pub const VARIANTS: [&str; 5] =
    ["static", "crash", "autoscale", "autoscale-headroom", "autoscale+crash"];

/// Mean-headroom floor (µs of Eq. 7 cycle slack) the
/// `autoscale-headroom` variant grows at: 50 ms of mean slack across
/// the placeable fleet — comfortably above zero, so the grow fires
/// while the fleet still admits, not after it starts shedding.
pub const HEADROOM_MIN_US: Micros = 50_000;

/// Virtual seconds the whole burst arrives within (same window as the
/// scale sweep, so the 10k cell is the same overload).
pub const ARRIVAL_WINDOW_S: f64 = 120.0;

/// Virtual drain past the last arrival.
pub const DRAIN_S: f64 = 60.0;

/// Fleet ceiling for the autoscaled variants.
pub const AUTOSCALE_MAX: usize = 64;

/// One (variant, task count) cell.
#[derive(Debug)]
pub struct ElasticCell {
    /// Variant label (see [`VARIANTS`]).
    pub variant: &'static str,
    /// Workload size.
    pub n_tasks: usize,
    /// Offered arrival rate (tasks/s).
    pub rate: f64,
    /// Fleet width at t=0 (the edge-mixed preset: 4).
    pub replicas_start: usize,
    /// Alive replicas at the horizon.
    pub replicas_final: usize,
    /// Tasks finished by the horizon.
    pub finished: usize,
    /// Tasks shed fleet-wide: admission rejections plus per-replica
    /// memory sheds.
    pub shed: u64,
    /// `shed / n_tasks`.
    pub shed_rate: f64,
    /// SLO attainment over every routed *and* shed task.
    pub slo: f64,
    /// Lifecycle counters.
    pub crashes: u64,
    pub joins: u64,
    pub leaves: u64,
    /// Autoscaler actions.
    pub grows: u64,
    pub shrinks: u64,
    /// Evacuation counters: queued tasks re-placed for free, started
    /// tasks re-admitted with a restore fee, total recompute charged.
    pub evac_requeued: u64,
    pub evac_restarted: u64,
    pub evac_recompute_us: Micros,
    /// Host wall-clock seconds for the cell.
    pub wall_s: f64,
}

/// The lifecycle config a variant name implies. Crash variants kill
/// replicas 0 and 1 (by explicit target — no RNG involved) at 40 s and
/// 80 s; autoscale variants hold the fleet at [4, [`AUTOSCALE_MAX`]] so
/// the autoscaler never shrinks below the starting width.
pub fn lifecycle_for(variant: &str) -> Result<LifecycleConfig> {
    let mut lc = LifecycleConfig::default();
    let (crash, autoscale) = match variant {
        "static" => (false, false),
        "crash" => (true, false),
        "autoscale" | "autoscale-headroom" => (false, true),
        "autoscale+crash" => (true, true),
        other => anyhow::bail!("unknown elastic-sweep variant '{other}'"),
    };
    if crash {
        lc.events = vec![
            LifecycleEvent {
                time: secs(40.0),
                action: LifecycleAction::Crash,
                target: Some(0),
            },
            LifecycleEvent {
                time: secs(80.0),
                action: LifecycleAction::Crash,
                target: Some(1),
            },
        ];
    }
    if autoscale {
        lc.autoscaler.enabled = true;
        lc.min_replicas = 4;
        lc.max_replicas = AUTOSCALE_MAX;
    }
    if variant == "autoscale-headroom" {
        lc.autoscaler.grow_on_headroom = true;
        lc.autoscaler.headroom_min = HEADROOM_MIN_US;
    }
    Ok(lc)
}

/// Run one cell: the scale sweep's edge-mixed overload shape with the
/// variant's lifecycle config attached.
pub fn run_cell(
    variant: &'static str,
    n_tasks: usize,
    cfg: &ServeConfig,
) -> Result<ElasticCell> {
    let mut cfg = cfg.clone();
    cfg.n_tasks = n_tasks;
    cfg.arrival_rate = n_tasks as f64 / ARRIVAL_WINDOW_S;
    cfg.policy = PolicyKind::Slice;
    cfg.cluster_engine = ClusterEngine::Event;
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    cfg.cluster_migration = true;
    cfg.lifecycle = lifecycle_for(variant)?;
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let spec = FleetSpec::preset("edge-mixed")?.with_cycle_cap(cfg.cycle_cap);
    let replicas_start = spec.profiles.len();

    let start = Instant::now();
    let report = run_fleet(RoutingStrategy::SloAware, &spec, workload, &cfg, secs(DRAIN_S))?;
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    let a = Attainment::compute(&report.tasks());
    let shed = report.shed_total();
    let e = &report.elastic;
    Ok(ElasticCell {
        variant,
        n_tasks,
        rate: cfg.arrival_rate,
        replicas_start,
        replicas_final: report.alive_replicas(),
        finished: a.n_finished,
        shed,
        shed_rate: shed as f64 / n_tasks as f64,
        slo: a.slo,
        crashes: e.crashes,
        joins: e.joins,
        leaves: e.leaves,
        grows: e.autoscale_grows,
        shrinks: e.autoscale_shrinks,
        evac_requeued: e.evac_requeued,
        evac_restarted: e.evac_restarted,
        evac_recompute_us: e.evac_recompute_us,
        wall_s,
    })
}

fn render_rows(rows: &[ElasticCell]) {
    use crate::metrics::report::{pct, Table};
    let mut t = Table::new(&[
        "variant", "tasks", "rate/s", "repl", "alive", "finished", "shed",
        "shed%", "SLO", "crash", "join", "grow", "shrink", "evac", "restart",
        "recompute s",
    ]);
    for c in rows {
        t.row(vec![
            c.variant.to_string(),
            c.n_tasks.to_string(),
            format!("{:.1}", c.rate),
            c.replicas_start.to_string(),
            c.replicas_final.to_string(),
            c.finished.to_string(),
            c.shed.to_string(),
            pct(c.shed_rate),
            pct(c.slo),
            c.crashes.to_string(),
            c.joins.to_string(),
            c.grows.to_string(),
            c.shrinks.to_string(),
            c.evac_requeued.to_string(),
            c.evac_restarted.to_string(),
            format!("{:.1}", c.evac_recompute_us as f64 / 1e6),
        ]);
    }
    println!("{}", t.render());
}

fn rows_to_json(rows: &[ElasticCell]) -> Json {
    use crate::metrics::report::nan_null;
    Json::from(
        rows.iter()
            .map(|c| {
                Json::obj()
                    .set("variant", c.variant)
                    .set("n_tasks", c.n_tasks)
                    .set("rate", c.rate)
                    .set("replicas_start", c.replicas_start)
                    .set("replicas_final", c.replicas_final)
                    .set("finished", c.finished)
                    .set("shed", c.shed)
                    .set("shed_rate", c.shed_rate)
                    .set("slo", nan_null(c.slo))
                    .set("crashes", c.crashes)
                    .set("joins", c.joins)
                    .set("leaves", c.leaves)
                    .set("grows", c.grows)
                    .set("shrinks", c.shrinks)
                    .set("evac_requeued", c.evac_requeued)
                    .set("evac_restarted", c.evac_restarted)
                    .set("evac_recompute_us", c.evac_recompute_us)
                    .set("wall_s", c.wall_s)
            })
            .collect::<Vec<_>>(),
    )
}

/// Full sweep over `sizes`; prints the table (plus the
/// autoscaled-vs-static shed verdict at the largest size) and returns
/// the JSON series (BENCH_7.json shape).
pub fn run(cfg: &ServeConfig, sizes: &[usize]) -> Result<Json> {
    let mut rows: Vec<ElasticCell> = Vec::new();
    for &n in sizes {
        for variant in VARIANTS {
            rows.push(run_cell(variant, n, cfg)?);
        }
    }

    println!(
        "Elastic sweep — SLICE, edge-mixed fleet, slo-aware + headroom \
         admission + migration, {ARRIVAL_WINDOW_S:.0}s arrival window, \
         {DRAIN_S:.0}s drain, seed {}\n",
        cfg.seed
    );
    render_rows(&rows);
    if let Some(&n) = sizes.last() {
        let find = |v: &str| rows.iter().find(|c| c.n_tasks == n && c.variant == v);
        if let (Some(st), Some(au)) = (find("static"), find("autoscale")) {
            println!(
                "\nshed at {n} tasks: static {} vs autoscaled {} — {}",
                st.shed,
                au.shed,
                if au.shed < st.shed {
                    "autoscaling reduces shed"
                } else {
                    "AUTOSCALING DID NOT REDUCE SHED"
                }
            );
        }
        if let (Some(de), Some(hr)) = (find("autoscale"), find("autoscale-headroom")) {
            println!(
                "grow signal at {n} tasks: deficit shed {} ({} grows) vs \
                 headroom shed {} ({} grows)",
                de.shed, de.grows, hr.shed, hr.grows
            );
        }
    }
    Ok(rows_to_json(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_cell_runs_without_elastic_machinery() {
        let c = run_cell("static", 60, &ServeConfig::default()).unwrap();
        assert_eq!(c.replicas_start, 4);
        assert_eq!(c.replicas_final, 4);
        assert_eq!(c.crashes + c.joins + c.leaves + c.grows + c.shrinks, 0);
    }

    #[test]
    fn crash_cell_kills_both_targets() {
        let c = run_cell("crash", 60, &ServeConfig::default()).unwrap();
        assert_eq!(c.crashes, 2, "both explicit crashes fire");
        assert_eq!(c.replicas_final, 2);
        assert!(c.grows == 0 && c.shrinks == 0);
    }

    #[test]
    fn autoscale_cell_respects_bounds_and_is_deterministic() {
        let cfg = ServeConfig::default();
        let a = run_cell("autoscale", 120, &cfg).unwrap();
        let b = run_cell("autoscale", 120, &cfg).unwrap();
        assert!(a.replicas_final >= 4 && a.replicas_final <= AUTOSCALE_MAX);
        assert_eq!(a.finished, b.finished, "same seed, same run");
        assert_eq!(a.shed, b.shed);
        assert_eq!((a.grows, a.shrinks), (b.grows, b.shrinks));
    }

    #[test]
    fn headroom_variant_sets_grow_signal() {
        let lc = lifecycle_for("autoscale-headroom").unwrap();
        assert!(lc.autoscaler.enabled);
        assert!(lc.autoscaler.grow_on_headroom);
        assert_eq!(lc.autoscaler.headroom_min, HEADROOM_MIN_US);
        // the deficit variant keeps the PR 7 signal
        assert!(!lifecycle_for("autoscale").unwrap().autoscaler.grow_on_headroom);
    }

    #[test]
    fn headroom_cell_is_deterministic() {
        let cfg = ServeConfig::default();
        let a = run_cell("autoscale-headroom", 120, &cfg).unwrap();
        let b = run_cell("autoscale-headroom", 120, &cfg).unwrap();
        assert!(a.replicas_final >= 4 && a.replicas_final <= AUTOSCALE_MAX);
        assert_eq!(a.finished, b.finished, "same seed, same run");
        assert_eq!(a.shed, b.shed);
        assert_eq!((a.grows, a.shrinks), (b.grows, b.shrinks));
    }

    #[test]
    fn unknown_variant_rejected() {
        assert!(lifecycle_for("mesh").is_err());
    }
}
