//! Fig. 1 — decode latency (a) and token throughput (b) vs batch size.
//!
//! Two sources are reported side by side:
//!   * the paper-calibrated latency model (what every simulation uses);
//!   * optionally, measured step latencies of the real PJRT engine
//!     (`slice-serve experiment fig1 --artifacts <dir>`), which is also
//!     how `calibrate` fits a machine-local model.

use anyhow::Result;

use crate::engine::latency::LatencyModel;
use crate::metrics::report::Table;
use crate::util::json::Json;

/// One measured/modelled row.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    /// Decode batch size b.
    pub batch: u32,
    /// l(b) in milliseconds.
    pub latency_ms: f64,
    /// Aggregate throughput b / l(b) in tokens/s.
    pub throughput_tps: f64,
    /// Per-task rate 1 / l(b) in tokens/s.
    pub per_task_tps: f64,
}

/// Produce the Fig. 1 series from a latency model.
pub fn from_model(model: &LatencyModel, batches: &[u32]) -> Vec<Fig1Row> {
    batches
        .iter()
        .map(|&b| {
            let lat = model.decode(b) as f64 / 1e3;
            let tps = model.throughput(b);
            Fig1Row {
                batch: b,
                latency_ms: lat,
                throughput_tps: tps,
                per_task_tps: tps / b as f64,
            }
        })
        .collect()
}

/// Standard batch sweep (the paper sweeps 1..16).
pub fn default_batches() -> Vec<u32> {
    (1..=16).collect()
}

/// JSON export of the Fig. 1 series.
pub fn rows_to_json(rows: &[Fig1Row]) -> Json {
    Json::from(
        rows.iter()
            .map(|r| {
                Json::obj()
                    .set("batch", r.batch as u64)
                    .set("latency_ms", r.latency_ms)
                    .set("throughput_tps", r.throughput_tps)
                    .set("per_task_tps", r.per_task_tps)
            })
            .collect::<Vec<_>>(),
    )
}

/// Text-table rendering of the Fig. 1 series.
pub fn render(rows: &[Fig1Row]) -> String {
    let mut t = Table::new(&[
        "batch", "decode latency (ms)", "throughput (tok/s)", "per-task (tok/s)",
    ]);
    for r in rows {
        t.row(vec![
            r.batch.to_string(),
            format!("{:.2}", r.latency_ms),
            format!("{:.2}", r.throughput_tps),
            format!("{:.2}", r.per_task_tps),
        ]);
    }
    t.render()
}

/// Run the figure against the calibrated model and print it.
pub fn run() -> Result<Json> {
    let model = LatencyModel::paper_calibrated();
    let rows = from_model(&model, &default_batches());
    println!("Fig. 1 — decode latency & throughput vs batch size (calibrated model)\n");
    println!("{}", render(&rows));
    Ok(rows_to_json(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shape_reproduced() {
        let rows = from_model(&LatencyModel::paper_calibrated(), &default_batches());
        // (1) near-linear latency growth to b=9
        assert!(rows[8].latency_ms > 120.0, "l(9) spikes above 120ms");
        // (2) per-task rate below 10 tok/s past the knee
        for r in rows.iter().filter(|r| r.batch >= 9) {
            assert!(r.per_task_tps < 10.0);
        }
        // (3) throughput keeps scaling in the plateau
        assert!(rows[15].throughput_tps > rows[8].throughput_tps);
    }

    #[test]
    fn json_round_trips() {
        let rows = from_model(&LatencyModel::paper_calibrated(), &[1, 2, 4]);
        let j = rows_to_json(&rows);
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 3);
    }
}
