//! Memory sweep — KV capacity × preemption mode × fleet shape
//! (extension beyond the paper; see DESIGN.md "Memory model").
//!
//! Each fleet shape serves its usual offered load (the single standard
//! device at the saturation-knee rate, the edge-mixed fleet at its ~3
//! standard-equivalents) under three memory regimes: unconstrained
//! (the pre-memory baseline — bit-identical to every earlier PR), a
//! generous capacity that occasionally evicts, and a tight capacity
//! where the scheduler lives or dies by how it spends cache. At each
//! constrained point the sweep compares swap vs recompute preemption
//! and memory-*aware* SLICE (projected KV as a second Alg. 2 knapsack
//! dimension) against the memory-*oblivious* baseline (same policy,
//! selection blind to memory, the serving loop's capacity enforcement
//! thrashing on its behalf). Mixed-fleet cells run with admission +
//! migration + running-task KV handoff enabled, so `migrated_running`
//! and handoff totals appear in the JSON. The acceptance thresholds
//! are asserted in `rust/tests/memory_model.rs` with pysim-validated
//! margins (EXPERIMENTS.md "Memory sweep").

use anyhow::Result;

use crate::cluster::{FleetSpec, RoutingStrategy};
use crate::config::ServeConfig;
use crate::engine::memory::PreemptionMode;
use crate::metrics::report::{ms2, nan_null, pct, Table};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

use super::hetero_sweep::LOAD_EQUIVALENTS;
use super::{default_drain, run_fleet};

/// Generous capacity (MiB, standard tier): ~85% of the single-device
/// knee cell's unconstrained peak (56 MiB measured), evicting only at
/// bursts.
pub const HIGH_CAPACITY_MB: u64 = 48;
/// Tight capacity (MiB, standard tier): ~57% of the unconstrained
/// peak, forcing sustained eviction — the cell where memory-aware
/// selection has to earn its keep.
pub const LOW_CAPACITY_MB: u64 = 32;

/// One (fleet, capacity, preemption mode, awareness) cell.
#[derive(Debug)]
pub struct MemoryCell {
    /// Fleet-shape label ("single" / "edge-mixed").
    pub fleet: &'static str,
    /// Standard-tier capacity in MiB (`None` = unconstrained).
    pub capacity_mb: Option<u64>,
    /// Preemption mode label.
    pub mode: &'static str,
    /// True when SLICE selection carried the KV knapsack dimension.
    pub aware: bool,
    /// Fleet-wide attainment (shed tasks count as violations).
    pub attainment: Attainment,
    /// Aggregated KV accounting across the fleet.
    pub memory: crate::engine::memory::MemoryStats,
    /// Tasks shed by admission control.
    pub rejected: usize,
    /// Total migrations (queued + running).
    pub migrations: u64,
    /// Running-task KV handoffs.
    pub migrated_running: u64,
    /// KV bytes moved by those handoffs.
    pub handoff_bytes: u64,
    /// Modelled handoff transfer time total (us).
    pub handoff_us: u64,
}

/// Run one cell of the sweep.
pub fn run_cell(
    fleet: &'static str,
    capacity_mb: Option<u64>,
    mode: PreemptionMode,
    aware: bool,
    cfg: &ServeConfig,
) -> Result<MemoryCell> {
    let mut cfg = cfg.clone();
    cfg.memory.kv_capacity = capacity_mb.map(|mb| mb * 1024 * 1024);
    cfg.memory.mode = mode;
    cfg.memory.aware = aware;
    let (spec, workload) = match fleet {
        "single" => (
            FleetSpec::homogeneous(1, cfg.cycle_cap),
            WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
                .generate(),
        ),
        "edge-mixed" => {
            // guards + running handoff on: the regime the tentpole studies
            cfg.cluster_admission.enabled = true;
            cfg.cluster_migration = true;
            cfg.cluster_migrate_running = true;
            (
                FleetSpec::preset("edge-mixed")?.with_cycle_cap(cfg.cycle_cap),
                WorkloadSpec::paper_mix(
                    cfg.arrival_rate * LOAD_EQUIVALENTS,
                    cfg.rt_ratio,
                    cfg.n_tasks * LOAD_EQUIVALENTS as usize,
                    cfg.seed,
                )
                .generate(),
            )
        }
        other => anyhow::bail!("unknown memory-sweep fleet '{other}'"),
    };
    let report = run_fleet(RoutingStrategy::SloAware, &spec, workload, &cfg, default_drain())?;
    let tasks = report.tasks();
    Ok(MemoryCell {
        fleet,
        capacity_mb,
        mode: mode.label(),
        aware,
        attainment: Attainment::compute(&tasks),
        memory: report.fleet_memory(),
        rejected: report.rejected_count(),
        migrations: report.migrations,
        migrated_running: report.migrated_running,
        handoff_bytes: report.handoff_bytes,
        handoff_us: report.handoff_us,
    })
}

/// The pruned cell list: one unconstrained baseline per fleet, then
/// (swap, aware) / (recompute, aware) / (swap, oblivious) at each
/// constrained capacity.
pub fn cells() -> Vec<(&'static str, Option<u64>, PreemptionMode, bool)> {
    let mut out = Vec::new();
    for fleet in ["single", "edge-mixed"] {
        out.push((fleet, None, PreemptionMode::Swap, true));
        for cap in [HIGH_CAPACITY_MB, LOW_CAPACITY_MB] {
            out.push((fleet, Some(cap), PreemptionMode::Swap, true));
            out.push((fleet, Some(cap), PreemptionMode::Recompute, true));
            out.push((fleet, Some(cap), PreemptionMode::Swap, false));
        }
    }
    out
}

/// Full sweep; prints the memory table and returns the JSON series.
pub fn run(cfg: &ServeConfig) -> Result<Json> {
    let mut rows: Vec<MemoryCell> = Vec::new();
    for (fleet, cap, mode, aware) in cells() {
        rows.push(run_cell(fleet, cap, mode, aware, cfg)?);
    }

    println!(
        "Memory sweep — policy {:?}, rate {} (x{} on edge-mixed), RT ratio {}, seed {} \
         (edge-mixed cells: admission + migration + running KV handoff on)\n",
        cfg.policy, cfg.arrival_rate, LOAD_EQUIVALENTS, cfg.rt_ratio, cfg.seed
    );
    let mut t = Table::new(&[
        "fleet", "capacity", "preempt", "aware", "fleet SLO", "RT SLO", "peak KV",
        "swaps out/in", "recomp", "run-mig", "handoff",
    ]);
    for c in &rows {
        t.row(vec![
            c.fleet.to_string(),
            c.capacity_mb
                .map_or_else(|| "unlimited".to_string(), |m| format!("{m} MiB")),
            c.mode.to_string(),
            if c.aware { "yes" } else { "no" }.to_string(),
            pct(c.attainment.slo),
            pct(c.attainment.rt_slo),
            format!("{:.1} MiB", c.memory.peak_kv_bytes as f64 / (1024.0 * 1024.0)),
            format!("{}/{}", c.memory.swap_outs, c.memory.swap_ins),
            c.memory.recomputes.to_string(),
            c.migrated_running.to_string(),
            ms2(c.handoff_us as f64 / 1e3),
        ]);
    }
    println!("{}", t.render());

    Ok(Json::from(
        rows.iter()
            .map(|c| {
                Json::obj()
                    .set("fleet", c.fleet)
                    .set(
                        "capacity_mb",
                        c.capacity_mb.map_or(Json::Null, Json::from),
                    )
                    .set("mode", c.mode)
                    .set("aware", c.aware)
                    .set("slo", nan_null(c.attainment.slo))
                    .set("rt_slo", nan_null(c.attainment.rt_slo))
                    .set("nrt_slo", nan_null(c.attainment.nrt_slo))
                    .set("n_tasks", c.attainment.n_tasks)
                    .set("n_finished", c.attainment.n_finished)
                    .set("peak_kv_bytes", c.memory.peak_kv_bytes)
                    .set("swap_outs", c.memory.swap_outs)
                    .set("swap_ins", c.memory.swap_ins)
                    .set("recomputes", c.memory.recomputes)
                    .set("handoff_restores", c.memory.handoff_restores)
                    .set("swap_delay_us", c.memory.swap_delay)
                    .set("rejected", c.rejected)
                    .set("migrations", c.migrations)
                    .set("migrated_running", c.migrated_running)
                    .set("handoff_bytes", c.handoff_bytes)
                    .set("handoff_us", c.handoff_us)
            })
            .collect::<Vec<_>>(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig { n_tasks: 20, ..ServeConfig::default() }
    }

    #[test]
    fn unconstrained_cell_never_swaps() {
        let c = run_cell("single", None, PreemptionMode::Swap, true, &cfg()).unwrap();
        assert_eq!(c.memory.swap_outs, 0);
        assert_eq!(c.memory.swap_delay, 0);
        assert!(c.memory.peak_kv_bytes > 0, "peak tracked even unconstrained");
        assert_eq!(c.migrated_running, 0);
    }

    #[test]
    fn cell_list_covers_capacity_by_mode_by_fleet() {
        let all = cells();
        assert_eq!(all.len(), 14);
        assert!(all.iter().any(|&(f, c, m, a)| {
            f == "edge-mixed"
                && c == Some(LOW_CAPACITY_MB)
                && m == PreemptionMode::Recompute
                && a
        }));
        // exactly one unconstrained baseline per fleet
        assert_eq!(all.iter().filter(|&&(_, c, _, _)| c.is_none()).count(), 2);
    }

    #[test]
    fn constrained_cell_is_deterministic() {
        let a = run_cell("single", Some(64), PreemptionMode::Swap, true, &cfg()).unwrap();
        let b = run_cell("single", Some(64), PreemptionMode::Swap, true, &cfg()).unwrap();
        assert_eq!(a.attainment.slo, b.attainment.slo);
        assert_eq!(a.memory, b.memory);
    }
}
