//! Scale sweep — scheduler throughput at 1k/4k/10k queued tasks
//! (extension beyond the paper; DESIGN.md "Scheduler hot path").
//!
//! The paper's "Challenge 2" is scheduling overhead: Alg. 4 re-runs
//! selection + rate allocation on *every* arrival and departure, so one
//! decision must cost far less than one decode step even when thousands
//! of tasks are queued (cf. the iteration-level schedulers of Orca,
//! OSDI '22, and Sarathi-Serve, OSDI '24). Each cell floods a fleet
//! with an n-task burst (the whole workload arrives inside a fixed
//! window, so the live set grows to ~n), serves it to a drain horizon,
//! and reports *host* wall time plus decisions-per-second — scheduler
//! reschedules (and, for fleets, routing decisions) divided by the wall
//! seconds the whole co-simulation took. Unfinished tasks at the
//! horizon are expected (the burst is deliberately far past capacity);
//! the sweep measures scheduling throughput, not attainment.
//!
//! Cells: `single` (one standard device, SLICE) and `edge-mixed` (the
//! 4-replica heterogeneous fleet, SLO-aware routing with Eq. 7
//! headroom admission + overload migration — the guard configuration
//! whose per-decision cost scales with the live set).
//!
//! The `--replicas` axis (BENCH_6.json) instead sweeps fleet *width*:
//! homogeneous round-robin fleets of 16/64/256 standard devices at
//! 10k–100k tasks, run through both cluster engines. The lockstep
//! reference advances every replica to every arrival (O(arrivals ×
//! replicas) advancement calls), so its wall time grows linearly in
//! width even when most replicas are idle; the event engine only
//! advances replicas with work, so its wall time is sublinear in
//! width. Lockstep reference cells run at the smallest task count only
//! — the reference engine exists for equivalence, not scale.
//!
//! `--threads <n[,n,...]>` (BENCH_9.json) adds the epoch-parallel
//! worker axis on top of the replica sweep: every event-engine width
//! runs at every thread count. The engine is bit-exact across counts
//! (rust/tests/equivalence.rs), so only `wall_s` and the derived
//! throughput columns move between rows of one (width, size) pair.

use std::time::Instant;

use anyhow::Result;

use crate::cluster::{AdmissionMode, FleetSpec, RoutingStrategy};
use crate::config::{ClusterEngine, PolicyKind, ServeConfig};
use crate::metrics::Attainment;
use crate::util::json::Json;
use crate::util::{secs, Micros};
use crate::workload::WorkloadSpec;

use super::{run_fleet, run_sim};

/// Default task counts the sweep runs (override with `--tasks`).
pub const DEFAULT_SIZES: [usize; 3] = [1_000, 4_000, 10_000];

/// Default fleet widths for the replica axis (override the axis with
/// `--replicas`).
pub const DEFAULT_REPLICA_COUNTS: [usize; 3] = [16, 64, 256];

/// Default task counts for the replica axis — wider fleets need larger
/// bursts to keep every replica busy (override with `--tasks`).
pub const DEFAULT_REPLICA_SIZES: [usize; 2] = [10_000, 100_000];

/// Default task counts for the streaming axis (`--stream`, BENCH_8.json):
/// one comparison point shared with the eager sweep plus the million-task
/// cell that only fits in memory because arrivals are pulled lazily.
pub const DEFAULT_STREAM_SIZES: [usize; 2] = [10_000, 1_000_000];

/// Virtual seconds the whole burst arrives within — the arrival rate is
/// `n / ARRIVAL_WINDOW_S`, so the standing queue reaches ~n tasks for
/// every sweep size.
pub const ARRIVAL_WINDOW_S: f64 = 120.0;

/// Virtual drain past the last arrival. Short on purpose: the burst is
/// far past capacity, so the cell measures scheduling throughput under
/// a maximal live set rather than waiting hours of virtual time for
/// the backlog to clear.
pub const DRAIN_S: f64 = 60.0;

/// One (fleet shape, task count) cell.
#[derive(Debug)]
pub struct ScaleCell {
    /// Fleet-shape label ("single" / "edge-mixed" / "replicas-N").
    pub fleet: &'static str,
    /// Cluster engine that drove the cell.
    pub engine: ClusterEngine,
    /// Fleet width (1 for "single", 4 for "edge-mixed").
    pub replicas: usize,
    /// Epoch-parallel worker threads the event engine advanced replicas
    /// with (1 = the sequential reference path; lockstep cells are
    /// always 1).
    pub threads: usize,
    /// Workload size.
    pub n_tasks: usize,
    /// Offered arrival rate (tasks/s).
    pub rate: f64,
    /// Host wall-clock seconds for the whole co-simulation.
    pub wall_s: f64,
    /// Virtual span of the run (seconds).
    pub virtual_s: f64,
    /// Scheduling decisions: policy reschedules plus (for fleets) one
    /// routing decision per arrival.
    pub decisions: u64,
    /// Reschedules the O(changes) control plane proved unnecessary and
    /// skipped (DESIGN.md "Control-plane incrementality");
    /// `decisions + decisions_skipped` equals the decision count with
    /// skipping disabled.
    pub decisions_skipped: u64,
    /// Full migration passes the controller ran (edge-mixed cells; the
    /// event engine runs O(overload episodes), lockstep O(arrivals)).
    pub migration_passes: u64,
    /// Overload-triggered migration checks (event engine only).
    pub migration_checks: u64,
    /// `decisions / wall_s`.
    pub decisions_per_sec: f64,
    /// Engine steps executed.
    pub steps: u64,
    /// `steps / wall_s`.
    pub steps_per_sec: f64,
    /// Tasks finished by the horizon (the rest count as violations).
    pub finished: usize,
    /// Tasks shed by admission control (edge-mixed cells).
    pub rejected: usize,
    /// SLO attainment at the horizon (expected low: the burst is
    /// deliberately past capacity).
    pub slo: f64,
}

/// Run one cell of the sweep.
pub fn run_cell(fleet: &'static str, n_tasks: usize, cfg: &ServeConfig) -> Result<ScaleCell> {
    let mut cfg = cfg.clone();
    cfg.n_tasks = n_tasks;
    cfg.arrival_rate = n_tasks as f64 / ARRIVAL_WINDOW_S;
    cfg.policy = PolicyKind::Slice;
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let drain: Micros = secs(DRAIN_S);

    let start = Instant::now();
    let (decisions, skipped, mig, steps, end_time, finished, rejected, slo) = match fleet {
        "single" => {
            let report = run_sim(PolicyKind::Slice, workload, &cfg, drain)?;
            let a = Attainment::compute(&report.tasks);
            (
                report.decisions,
                report.decisions_skipped,
                (0, 0),
                report.steps,
                report.end_time,
                a.n_finished,
                0,
                a.slo,
            )
        }
        "edge-mixed" => {
            // headroom admission + overload migration: the guard
            // configuration whose routing cost scales with live work
            cfg.cluster_admission.enabled = true;
            cfg.cluster_admission.mode = AdmissionMode::Headroom;
            cfg.cluster_migration = true;
            let spec = FleetSpec::preset("edge-mixed")?.with_cycle_cap(cfg.cycle_cap);
            let report =
                run_fleet(RoutingStrategy::SloAware, &spec, workload, &cfg, drain)?;
            let tasks = report.tasks();
            let a = Attainment::compute(&tasks);
            let end = report
                .replicas
                .iter()
                .map(|r| r.report.end_time)
                .max()
                .unwrap_or(0);
            (
                // one routing decision per arrival plus every replica's
                // reschedules
                report.total_decisions() + a.n_tasks as u64,
                report.total_decisions_skipped(),
                (report.migration_passes, report.migration_checks),
                report.total_steps(),
                end,
                a.n_finished,
                report.rejected_count(),
                a.slo,
            )
        }
        other => anyhow::bail!("unknown scale-sweep fleet '{other}'"),
    };
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    Ok(ScaleCell {
        fleet,
        engine: cfg.cluster_engine,
        replicas: if fleet == "single" { 1 } else { 4 },
        threads: cfg.cluster_threads,
        n_tasks,
        rate: cfg.arrival_rate,
        wall_s,
        virtual_s: end_time as f64 / 1e6,
        decisions,
        decisions_skipped: skipped,
        migration_passes: mig.0,
        migration_checks: mig.1,
        decisions_per_sec: decisions as f64 / wall_s,
        steps,
        steps_per_sec: steps as f64 / wall_s,
        finished,
        rejected,
        slo,
    })
}

/// Run one streaming cell: the edge-mixed guard fleet fed by a seeded
/// [`crate::workload::ArrivalStream`] through the event engine with
/// folded rejects — constant memory in the trace length, which is what
/// makes the million-task cell feasible. Attainment counts folded shed
/// tasks as misses, matching the materialized cells' semantics.
pub fn run_stream_cell(n_tasks: usize, cfg: &ServeConfig) -> Result<ScaleCell> {
    let mut cfg = cfg.clone();
    cfg.n_tasks = n_tasks;
    cfg.arrival_rate = n_tasks as f64 / ARRIVAL_WINDOW_S;
    cfg.policy = PolicyKind::Slice;
    cfg.cluster_admission.enabled = true;
    cfg.cluster_admission.mode = AdmissionMode::Headroom;
    cfg.cluster_migration = true;
    cfg.cluster_engine = ClusterEngine::Event;
    let stream =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .stream();
    let spec = FleetSpec::preset("edge-mixed")?.with_cycle_cap(cfg.cycle_cap);
    let drain: Micros = secs(DRAIN_S);

    let start = Instant::now();
    let report =
        super::run_fleet_stream(RoutingStrategy::SloAware, &spec, stream, &cfg, drain)?;
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    let tasks = report.tasks();
    let a = Attainment::compute(&tasks);
    let end = report.replicas.iter().map(|r| r.report.end_time).max().unwrap_or(0);
    let decisions = report.total_decisions() + n_tasks as u64;
    let steps = report.total_steps();
    // folded rejects never reach `tasks()`: scale the routed-set
    // attainment so each folded shed counts as a miss, the same
    // denominator the materialized cells use
    let denom = a.n_tasks as u64 + report.rejected_folded;
    let slo = if denom == 0 || a.n_tasks == 0 {
        f64::NAN
    } else {
        a.slo * a.n_tasks as f64 / denom as f64
    };
    Ok(ScaleCell {
        fleet: "edge-stream",
        engine: ClusterEngine::Event,
        replicas: 4,
        threads: cfg.cluster_threads,
        n_tasks,
        rate: cfg.arrival_rate,
        wall_s,
        virtual_s: end as f64 / 1e6,
        decisions,
        decisions_skipped: report.total_decisions_skipped(),
        migration_passes: report.migration_passes,
        migration_checks: report.migration_checks,
        decisions_per_sec: decisions as f64 / wall_s,
        steps,
        steps_per_sec: steps as f64 / wall_s,
        finished: a.n_finished,
        rejected: report.rejected_count(),
        slo,
    })
}

/// Run one replica-axis cell: a homogeneous round-robin fleet of
/// `replicas` standard devices under an `n_tasks` burst, driven by
/// `engine`. Round-robin with admission and migration off keeps the
/// routing decision O(1), so the cell isolates *engine advancement*
/// cost: lockstep pays O(arrivals × replicas) `run_until` calls, the
/// event engine only wakes replicas that have work.
pub fn run_replica_cell(
    engine: ClusterEngine,
    replicas: usize,
    n_tasks: usize,
    threads: usize,
    cfg: &ServeConfig,
) -> Result<ScaleCell> {
    assert!(
        threads == 1 || engine == ClusterEngine::Event,
        "epoch workers only exist in the event engine"
    );
    let mut cfg = cfg.clone();
    cfg.n_tasks = n_tasks;
    cfg.arrival_rate = n_tasks as f64 / ARRIVAL_WINDOW_S;
    cfg.policy = PolicyKind::Slice;
    cfg.cluster_engine = engine;
    cfg.cluster_threads = threads;
    cfg.cluster_admission.enabled = false;
    cfg.cluster_migration = false;
    cfg.cluster_migrate_running = false;
    let workload =
        WorkloadSpec::paper_mix(cfg.arrival_rate, cfg.rt_ratio, cfg.n_tasks, cfg.seed)
            .generate();
    let spec = FleetSpec::homogeneous(replicas, cfg.cycle_cap);

    let start = Instant::now();
    let report =
        super::run_fleet(RoutingStrategy::RoundRobin, &spec, workload, &cfg, secs(DRAIN_S))?;
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    let tasks = report.tasks();
    let a = Attainment::compute(&tasks);
    let end = report.replicas.iter().map(|r| r.report.end_time).max().unwrap_or(0);
    let decisions = report.total_decisions() + a.n_tasks as u64;
    let steps = report.total_steps();
    Ok(ScaleCell {
        fleet: "replicas",
        engine,
        replicas,
        threads,
        n_tasks,
        rate: cfg.arrival_rate,
        wall_s,
        virtual_s: end as f64 / 1e6,
        decisions,
        decisions_skipped: report.total_decisions_skipped(),
        migration_passes: report.migration_passes,
        migration_checks: report.migration_checks,
        decisions_per_sec: decisions as f64 / wall_s,
        steps,
        steps_per_sec: steps as f64 / wall_s,
        finished: a.n_finished,
        rejected: report.rejected_count(),
        slo: a.slo,
    })
}

fn render_rows(rows: &[ScaleCell]) {
    use crate::metrics::report::{pct, Table};
    let mut t = Table::new(&[
        "fleet", "engine", "repl", "thr", "tasks", "rate/s", "wall s",
        "decisions", "skipped", "mig pass", "decisions/s", "steps", "steps/s",
        "finished", "shed", "SLO",
    ]);
    for c in rows {
        t.row(vec![
            c.fleet.to_string(),
            c.engine.label().to_string(),
            c.replicas.to_string(),
            c.threads.to_string(),
            c.n_tasks.to_string(),
            format!("{:.1}", c.rate),
            format!("{:.3}", c.wall_s),
            c.decisions.to_string(),
            c.decisions_skipped.to_string(),
            c.migration_passes.to_string(),
            format!("{:.0}", c.decisions_per_sec),
            c.steps.to_string(),
            format!("{:.0}", c.steps_per_sec),
            c.finished.to_string(),
            c.rejected.to_string(),
            pct(c.slo),
        ]);
    }
    println!("{}", t.render());
}

fn rows_to_json(rows: &[ScaleCell]) -> Json {
    use crate::metrics::report::nan_null;
    Json::from(
        rows.iter()
            .map(|c| {
                Json::obj()
                    .set("fleet", c.fleet)
                    .set("engine", c.engine.label())
                    .set("replicas", c.replicas)
                    .set("threads", c.threads)
                    .set("n_tasks", c.n_tasks)
                    .set("rate", c.rate)
                    .set("wall_s", c.wall_s)
                    .set("virtual_s", c.virtual_s)
                    .set("decisions", c.decisions)
                    .set("decisions_skipped", c.decisions_skipped)
                    .set("migration_passes", c.migration_passes)
                    .set("migration_checks", c.migration_checks)
                    .set("decisions_per_sec", c.decisions_per_sec)
                    .set("steps", c.steps)
                    .set("steps_per_sec", c.steps_per_sec)
                    .set("finished", c.finished)
                    .set("rejected", c.rejected)
                    .set("slo", nan_null(c.slo))
            })
            .collect::<Vec<_>>(),
    )
}

/// Full sweep over `sizes`; prints the throughput table and returns
/// the JSON series (BENCH_5.json shape plus engine/replicas columns).
pub fn run(cfg: &ServeConfig, sizes: &[usize]) -> Result<Json> {
    let mut rows: Vec<ScaleCell> = Vec::new();
    for &n in sizes {
        for fleet in ["single", "edge-mixed"] {
            rows.push(run_cell(fleet, n, cfg)?);
        }
    }

    println!(
        "Scale sweep — SLICE, {ARRIVAL_WINDOW_S:.0}s arrival window, \
         {DRAIN_S:.0}s drain, seed {} (edge-mixed: slo-aware + headroom \
         admission + migration)\n",
        cfg.seed
    );
    render_rows(&rows);
    Ok(rows_to_json(&rows))
}

/// Streaming sweep (`experiment scale --stream`, BENCH_8.json): one
/// edge-mixed cell per size, fed by the constant-memory
/// [`crate::workload::ArrivalStream`] with folded rejects — the only
/// way the million-task cell fits in memory. Prints the table and
/// returns the JSON series (same keys as [`run`]).
pub fn run_streaming(cfg: &ServeConfig, sizes: &[usize]) -> Result<Json> {
    let mut rows: Vec<ScaleCell> = Vec::new();
    for &n in sizes {
        rows.push(run_stream_cell(n, cfg)?);
    }

    println!(
        "Streaming scale sweep — SLICE edge-mixed, pull-based arrivals + \
         folded rejects, {ARRIVAL_WINDOW_S:.0}s arrival window, {DRAIN_S:.0}s \
         drain, seed {}\n",
        cfg.seed
    );
    render_rows(&rows);
    Ok(rows_to_json(&rows))
}

/// Replica-axis sweep (BENCH_6.json; BENCH_9.json with a thread axis):
/// event-engine cells at every (width, size, thread-count) triple,
/// lockstep reference cells at the smallest size only — wide lockstep
/// cells cost O(arrivals × replicas) wall time by construction, and the
/// reference engine exists for equivalence, not scale. The lockstep
/// reference always runs single-threaded (it has no epoch workers).
/// Prints the table and returns the JSON series.
pub fn run_replicas(
    cfg: &ServeConfig,
    replica_counts: &[usize],
    sizes: &[usize],
    threads: &[usize],
) -> Result<Json> {
    let mut rows: Vec<ScaleCell> = Vec::new();
    for &width in replica_counts {
        for (i, &n) in sizes.iter().enumerate() {
            for &t in threads {
                rows.push(run_replica_cell(ClusterEngine::Event, width, n, t, cfg)?);
            }
            if i == 0 {
                rows.push(run_replica_cell(ClusterEngine::Lockstep, width, n, 1, cfg)?);
            }
        }
    }

    println!(
        "Replica-scale sweep — SLICE, round-robin homogeneous fleets, \
         {ARRIVAL_WINDOW_S:.0}s arrival window, {DRAIN_S:.0}s drain, seed {} \
         (lockstep reference at the smallest size, single-threaded)\n",
        cfg.seed
    );
    render_rows(&rows);
    Ok(rows_to_json(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_cells_complete_and_count_decisions() {
        let cfg = ServeConfig::default();
        let c = run_cell("single", 40, &cfg).unwrap();
        assert_eq!(c.n_tasks, 40);
        assert!(c.decisions > 0, "SLICE reschedules must be counted");
        assert!(c.decisions_per_sec > 0.0);
        assert!(c.steps > 0);
        let c = run_cell("edge-mixed", 40, &cfg).unwrap();
        // at least one routing decision per arrival rides on top of
        // the per-replica reschedules
        assert!(c.decisions >= 40);
    }

    #[test]
    fn unknown_fleet_rejected() {
        assert!(run_cell("mesh", 10, &ServeConfig::default()).is_err());
    }

    #[test]
    fn stream_cell_matches_materialized_run() {
        // the streaming path (pull-based arrivals + folded rejects)
        // must reproduce the materialized edge-mixed cell's simulation
        // observables; only wall time may differ
        let mut cfg = ServeConfig::default();
        cfg.cluster_engine = ClusterEngine::Event;
        let eager = run_cell("edge-mixed", 300, &cfg).unwrap();
        let streamed = run_stream_cell(300, &cfg).unwrap();
        assert_eq!(streamed.decisions, eager.decisions);
        assert_eq!(streamed.decisions_skipped, eager.decisions_skipped);
        assert_eq!(streamed.steps, eager.steps);
        assert_eq!(streamed.finished, eager.finished);
        assert_eq!(streamed.rejected, eager.rejected, "folded count = list count");
        assert_eq!(streamed.virtual_s, eager.virtual_s);
        assert_eq!(streamed.migration_passes, eager.migration_passes);
        if !eager.slo.is_nan() {
            assert!(
                (streamed.slo - eager.slo).abs() < 1e-12,
                "shed-as-miss attainment must match: {} vs {}",
                streamed.slo,
                eager.slo
            );
        }
    }

    #[test]
    fn replica_cells_agree_across_engines() {
        let cfg = ServeConfig::default();
        let ev = run_replica_cell(ClusterEngine::Event, 4, 60, 1, &cfg).unwrap();
        let ls = run_replica_cell(ClusterEngine::Lockstep, 4, 60, 1, &cfg).unwrap();
        // wall time differs; every simulation observable must not
        assert_eq!(ev.decisions, ls.decisions);
        assert_eq!(ev.steps, ls.steps);
        assert_eq!(ev.finished, ls.finished);
        assert_eq!(ev.virtual_s, ls.virtual_s);
        assert_eq!(ev.replicas, 4);
        assert_eq!(ev.engine.label(), "event");
    }

    #[test]
    fn replica_cells_agree_across_thread_counts() {
        // the epoch-parallel engine is bit-exact: only wall time (and
        // the throughput columns derived from it) may differ between
        // thread counts of one (width, size) cell
        let cfg = ServeConfig::default();
        let seq = run_replica_cell(ClusterEngine::Event, 8, 120, 1, &cfg).unwrap();
        let par = run_replica_cell(ClusterEngine::Event, 8, 120, 4, &cfg).unwrap();
        assert_eq!(par.threads, 4);
        assert_eq!(par.decisions, seq.decisions);
        assert_eq!(par.decisions_skipped, seq.decisions_skipped);
        assert_eq!(par.steps, seq.steps);
        assert_eq!(par.finished, seq.finished);
        assert_eq!(par.rejected, seq.rejected);
        assert_eq!(par.virtual_s, seq.virtual_s);
        assert_eq!(par.migration_passes, seq.migration_passes);
    }
}
