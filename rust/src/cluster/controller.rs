//! The cluster controller: every routing/admission/migration *decision*
//! the cluster layer makes, factored out of the engines that drive
//! replica time forward (DESIGN.md "Event-driven cluster engine").
//!
//! Two engines share this code verbatim:
//!   * [`crate::cluster::Router`] — the lockstep reference engine,
//!     which advances every replica to every arrival;
//!   * [`crate::cluster::Orchestrator`] — the event-driven engine,
//!     which advances a replica only when it has work.
//!
//! The controller is generic over `AsRef<Replica>`/`AsMut<Replica>` so
//! both a bare [`Replica`] slice (lockstep) and a
//! [`crate::cluster::Node`] slice (event engine) run the *identical*
//! decision code — the bit-exactness contract between the engines rests
//! on there being exactly one copy of it.
//!
//! Everything here reads replica load signals that are
//! clock-independent (`queued_in_class`, `load_tokens`, `headroom`,
//! `overloaded` — all counting staged + pending + live work, never the
//! clock), which is what lets the event engine skip advancing idle
//! replicas without perturbing a single decision.

use std::collections::HashSet;

use crate::coordinator::task::{Task, TaskId};
use crate::engine::memory::MemoryConfig;
use crate::util::Micros;

use super::fleet::{AdmissionConfig, AdmissionMode};
use super::replica::Replica;
use super::router::{ClusterReport, ElasticStats, RoutingStrategy};

/// Routing/admission/migration decision state shared by both cluster
/// engines. Owns every counter the final [`ClusterReport`] aggregates.
pub(crate) struct Controller {
    pub(crate) strategy: RoutingStrategy,
    pub(crate) admission: AdmissionConfig,
    pub(crate) migration: bool,
    /// Running-task KV handoff (requires `migration`).
    pub(crate) migrate_running: bool,
    /// Prices KV handoffs (bytes per token, link bandwidth).
    pub(crate) memory: MemoryConfig,
    rr_next: usize,
    /// Admissibility-mask buffer reused across routing decisions (one
    /// decision runs per arrival — the cluster hot path allocates
    /// nothing whether or not admission control is on).
    admission_scratch: Vec<bool>,
    /// Per-replica headrooms computed by a headroom-admission pass,
    /// reused by the SLO-aware pick in the same decision so each
    /// replica's Eq. 7 demand is evaluated once per arrival, not twice.
    headroom_scratch: Vec<Micros>,
    /// Global ids that have migrated once already (exactly-once cap).
    pub(crate) migrated: HashSet<TaskId>,
    pub(crate) migrations: u64,
    pub(crate) migrated_running: u64,
    /// Migration pass pairs actually executed past the enablement gate
    /// (one count per [`Controller::run_migrations`] invocation). The
    /// lockstep engine pays one per arrival boundary; the event engine
    /// pays one per `MigrationCheck` that found an overloaded replica.
    pub(crate) migration_passes: u64,
    /// Edge-triggered `MigrationCheck` events handled by the event
    /// engine (lockstep runs keep this 0).
    pub(crate) migration_checks: u64,
    pub(crate) handoff_bytes: u64,
    pub(crate) handoff_us: Micros,
    pub(crate) rejected: Vec<Task>,
    /// Streaming mode (million-task traces): fold shed arrivals into a
    /// counter instead of retaining the `Task` — a shed task is an SLO
    /// miss by definition, so per-task records add nothing the cell
    /// metrics need, and retaining them is what unbounds memory.
    pub(crate) fold_rejects: bool,
    /// Shed arrivals folded under `fold_rejects`.
    pub(crate) rejected_folded: u64,
    /// Per-replica liveness under lifecycle events. **Empty for static
    /// fleets** — the empty-mask fast path is what keeps elastic
    /// support out of the static hot path entirely (`is_alive` treats
    /// a missing entry as alive). The event engine fills it when any
    /// elastic feature is on.
    pub(crate) alive: Vec<bool>,
    /// Per-replica health verdicts (same empty-for-static contract).
    /// Degraded replicas are skipped by placement and migration; if
    /// *every* alive replica is degraded, placement relaxes to
    /// alive-only — total shed would be worse than slow service.
    pub(crate) degraded: Vec<bool>,
    /// Per-replica failure-detector suspicion (same empty-for-static
    /// contract). Suspected replicas are excluded from new placement
    /// and migration destinations — gently drained — and un-suspected
    /// on a fresh heartbeat. Unlike `alive`, this is *believed* state:
    /// a suspected replica may be dead (not yet confirmed) or merely
    /// lagging.
    pub(crate) suspected: Vec<bool>,
    /// Per-replica physical reachability (same empty-for-static
    /// contract). Set by the orchestrator the instant a replica dies
    /// under delayed detection: the *controller* still believes it
    /// alive (dispatches go there and sit in limbo — sends are
    /// fire-and-forget), but operations that need a *response* from
    /// the replica — migration withdrawals, shrink-victim shutdowns —
    /// silently fail, so those paths check this mask. Not a detection
    /// signal: placement must never read it.
    pub(crate) unresponsive: Vec<bool>,
    /// Eligibility-mask buffer (alive ∧ ¬degraded per decision),
    /// reused like the admission scratch.
    eligible_scratch: Vec<bool>,
    pub(crate) crashes: u64,
    pub(crate) joins: u64,
    pub(crate) leaves: u64,
    pub(crate) evac_requeued: u64,
    pub(crate) evac_restarted: u64,
    pub(crate) evac_recompute_us: Micros,
    pub(crate) autoscale_grows: u64,
    pub(crate) autoscale_shrinks: u64,
    /// Grow decisions still booting at run end (boot-delayed joins).
    pub(crate) autoscale_pending_boots: u64,
    pub(crate) suspicions: u64,
    pub(crate) false_suspicions: u64,
    pub(crate) detections: u64,
    pub(crate) limbo_recovered: u64,
    pub(crate) retries: u64,
    pub(crate) retry_exhausted: u64,
    pub(crate) limbo_lost: u64,
}

impl Controller {
    pub(crate) fn new(strategy: RoutingStrategy) -> Self {
        Controller {
            strategy,
            admission: AdmissionConfig::default(),
            migration: false,
            migrate_running: false,
            memory: MemoryConfig::default(),
            rr_next: 0,
            admission_scratch: Vec::new(),
            headroom_scratch: Vec::new(),
            migrated: HashSet::new(),
            migrations: 0,
            migrated_running: 0,
            migration_passes: 0,
            migration_checks: 0,
            handoff_bytes: 0,
            handoff_us: 0,
            rejected: Vec::new(),
            fold_rejects: false,
            rejected_folded: 0,
            alive: Vec::new(),
            degraded: Vec::new(),
            suspected: Vec::new(),
            unresponsive: Vec::new(),
            eligible_scratch: Vec::new(),
            crashes: 0,
            joins: 0,
            leaves: 0,
            evac_requeued: 0,
            evac_restarted: 0,
            evac_recompute_us: 0,
            autoscale_grows: 0,
            autoscale_shrinks: 0,
            autoscale_pending_boots: 0,
            suspicions: 0,
            false_suspicions: 0,
            detections: 0,
            limbo_recovered: 0,
            retries: 0,
            retry_exhausted: 0,
            limbo_lost: 0,
        }
    }

    /// Record a shed arrival: retained on `rejected` (the default,
    /// every report/test observes the full `Task`) or folded to a
    /// counter in streaming mode (`fold_rejects`).
    pub(crate) fn reject(&mut self, task: Task) {
        if self.fold_rejects {
            self.rejected_folded += 1;
        } else {
            self.rejected.push(task);
        }
    }

    /// Liveness under lifecycle events; a missing entry (static fleet)
    /// is alive.
    pub(crate) fn is_alive(&self, i: usize) -> bool {
        self.alive.get(i).copied().unwrap_or(true)
    }

    /// Health verdict; a missing entry (static fleet) is healthy.
    pub(crate) fn is_degraded(&self, i: usize) -> bool {
        self.degraded.get(i).copied().unwrap_or(false)
    }

    /// Failure-detector suspicion; a missing entry (static fleet, or
    /// detector off) is not suspected.
    pub(crate) fn is_suspected(&self, i: usize) -> bool {
        self.suspected.get(i).copied().unwrap_or(false)
    }

    /// Physical reachability under delayed detection; a missing entry
    /// is responsive. See the field doc: response-requiring paths only.
    pub(crate) fn is_unresponsive(&self, i: usize) -> bool {
        self.unresponsive.get(i).copied().unwrap_or(false)
    }

    /// Replicas placement may target: alive, not degraded, and not
    /// suspected by the failure detector.
    pub(crate) fn placeable(&self, i: usize) -> bool {
        self.is_alive(i) && !self.is_degraded(i) && !self.is_suspected(i)
    }

    /// Alive replicas right now (fleet-bound checks).
    pub(crate) fn alive_count(&self, fleet_len: usize) -> usize {
        if self.alive.is_empty() {
            fleet_len
        } else {
            self.alive.iter().filter(|&&a| a).count()
        }
    }

    /// A read-only snapshot of the liveness/degradation masks — the
    /// *only* controller state the parallel event engine's epoch
    /// workers may observe (DESIGN.md "Parallel event engine"). Workers
    /// receive disjoint `&mut Node`s plus this snapshot, never `&mut
    /// Controller`: advancement reads no decision state, so the Send
    /// audit for the worker closure reduces to `Node: Send`, and every
    /// decision that *writes* controller state stays on the
    /// orchestrator thread between epochs.
    pub(crate) fn mask_snapshot(&self) -> MaskSnapshot<'_> {
        MaskSnapshot { alive: &self.alive, degraded: &self.degraded }
    }

    /// Pick the replica for `task` under the configured strategy, or
    /// `None` when admission control sheds it (every replica is at its
    /// class bound). Tie-breaks are deterministic: least-loaded breaks
    /// ties by lowest replica index, and SLO-aware breaks headroom ties
    /// by least load, then lowest replica index — so cluster runs are
    /// reproducible for a fixed seed.
    pub(crate) fn decide<R: AsRef<Replica>>(
        &mut self,
        replicas: &[R],
        task: &Task,
    ) -> Option<usize> {
        // the admissibility mask lives in a scratch buffer reused
        // across decisions (temporarily moved out so the strategy arms
        // below can borrow the controller), and is only filled when
        // admission is on — the bench-tracked cluster/decide hot path
        // never allocates in steady state
        let mut mask = std::mem::take(&mut self.admission_scratch);
        let mut headrooms = std::mem::take(&mut self.headroom_scratch);
        let mut elig = std::mem::take(&mut self.eligible_scratch);
        mask.clear();
        headrooms.clear();
        elig.clear();
        // eligibility (alive ∧ ¬degraded) only exists under lifecycle
        // events — static fleets take the empty-mask fast path and this
        // whole block is a no-op
        let use_elig = !self.alive.is_empty();
        if use_elig {
            elig.extend((0..replicas.len()).map(|i| self.placeable(i)));
            if !elig.iter().any(|&e| e) {
                // every alive replica is degraded: relax to alive-only
                // rather than shedding the whole arrival stream
                for (i, e) in elig.iter_mut().enumerate() {
                    *e = self.is_alive(i);
                }
            }
        }
        let use_mask = self.admission.enabled;
        if use_mask {
            match self.admission.mode {
                AdmissionMode::QueueDepth => {
                    let bound = self.admission.bound_for(task.class);
                    mask.extend(
                        replicas
                            .iter()
                            .map(|r| r.as_ref().queued_in_class(task.class) < bound),
                    );
                }
                AdmissionMode::Headroom => {
                    // keep the computed headrooms: the SLO-aware pick
                    // below reuses them, so headroom admission costs
                    // one Eq. 7 evaluation per replica, not two
                    let quota = task.slo.tokens_per_cycle();
                    for r in replicas {
                        let h = r.as_ref().headroom(quota);
                        headrooms.push(h);
                        mask.push(h > 0);
                    }
                }
            }
        }
        let open = |i: usize| (!use_elig || elig[i]) && (!use_mask || mask[i]);
        let pick = if !(0..replicas.len()).any(open) {
            None
        } else {
            Some(match self.strategy {
                RoutingStrategy::RoundRobin => {
                    // first admissible replica at or after the cursor
                    let start = self.rr_next;
                    let n = replicas.len();
                    let k = (0..n)
                        .find(|&k| open((start + k) % n))
                        .expect("some replica is admissible");
                    self.rr_next = start + k + 1;
                    (start + k) % n
                }
                RoutingStrategy::LeastLoaded => replicas
                    .iter()
                    .map(AsRef::as_ref)
                    .filter(|r| open(r.id()))
                    .map(|r| (r.load_tokens(), r.id()))
                    .min()
                    .map(|(_, id)| id)
                    .unwrap(),
                RoutingStrategy::SloAware if !headrooms.is_empty() => replicas
                    .iter()
                    .map(AsRef::as_ref)
                    .filter(|r| open(r.id()))
                    .map(|r| {
                        // same key as best_by_headroom, headroom cached
                        (std::cmp::Reverse(headrooms[r.id()]), r.load_tokens(), r.id())
                    })
                    .min()
                    .map(|(_, _, id)| id)
                    .expect("some replica is admissible"),
                RoutingStrategy::SloAware => {
                    let quota = task.slo.tokens_per_cycle();
                    best_by_headroom(replicas, quota, |r| open(r.id()))
                        .expect("some replica is admissible")
                }
            })
        };
        self.admission_scratch = mask;
        self.headroom_scratch = headrooms;
        self.eligible_scratch = elig;
        pick
    }

    /// The migration pass run at each routing boundary: every
    /// overloaded replica offers its not-yet-migrated queued tasks
    /// back, and each is re-placed on the best *non-overloaded* peer by
    /// (headroom, load, index) — a task never burns its single allowed
    /// migration moving onto a replica that is itself overloaded. If
    /// every peer fills up mid-pass, the remaining offers fall back to
    /// the least-bad peer. Skipped entirely unless some peer has
    /// positive headroom. Migrated tasks were admitted when first
    /// routed, so re-placement deliberately ignores admission queue
    /// bounds (bounds govern new arrivals, not work already accepted).
    pub(crate) fn run_migrations<R: AsRef<Replica> + AsMut<Replica>>(
        &mut self,
        replicas: &mut [R],
    ) {
        if !self.migration || replicas.len() < 2 {
            return;
        }
        self.migration_passes += 1;
        for src in 0..replicas.len() {
            // an unresponsive source cannot answer the withdraw request
            // (it is dead but not yet detected) — skipping it is what
            // keeps a not-yet-confirmed corpse from magically handing
            // its queue back before the detector fires
            if !self.is_alive(src)
                || self.is_unresponsive(src)
                || !replicas[src].as_ref().overloaded()
            {
                continue;
            }
            // the eligible-peer check runs *before* withdrawing: with a
            // churning fleet the only peers may be dead or degraded, and
            // an offer with nowhere to go must never leave the queue
            let peer_has_headroom = replicas
                .iter()
                .map(AsRef::as_ref)
                .any(|r| r.id() != src && self.placeable(r.id()) && !r.overloaded());
            if !peer_has_headroom {
                continue;
            }
            let offered = replicas[src].as_mut().withdraw_unmigrated(&self.migrated);
            for task in offered {
                let quota = task.slo.tokens_per_cycle();
                let dst = best_by_headroom(replicas, quota, |r| {
                    r.id() != src && self.placeable(r.id()) && !r.overloaded()
                })
                .or_else(|| {
                    best_by_headroom(replicas, quota, |r| {
                        r.id() != src && self.placeable(r.id())
                    })
                })
                .expect("an eligible peer exists (checked before withdrawing)");
                self.migrated.insert(task.id);
                self.migrations += 1;
                replicas[dst].as_mut().receive_migrated(task);
            }
        }
    }

    /// The running-task KV-handoff pass: after the queued pass, a
    /// replica the queue withdrawal could not decongest hands off
    /// mid-generation tasks it has paused *and* evicted (see
    /// [`Replica::running_candidates`] — work receiving zero service
    /// whose cache is off-device anyway), cheapest utility first, to
    /// the peer with the most Eq. 7 headroom — but only when that
    /// headroom gain strictly exceeds the modelled KV transfer time
    /// over the inter-replica link, so a handoff never costs more
    /// cycle time than it buys. The fee rides on the task
    /// (`pending_restore`) and is charged by the destination's serving
    /// loop at the task's next decode.
    pub(crate) fn run_running_migrations<R: AsRef<Replica> + AsMut<Replica>>(
        &mut self,
        replicas: &mut [R],
    ) {
        if !self.migration || !self.migrate_running || replicas.len() < 2 {
            return;
        }
        for src in 0..replicas.len() {
            // same unresponsive-source gate as the queued pass above
            if !self.is_alive(src)
                || self.is_unresponsive(src)
                || !replicas[src].as_ref().overloaded()
            {
                continue;
            }
            let candidates = replicas[src].as_ref().running_candidates(&self.migrated);
            for (_, gid, quota, tokens) in candidates {
                if !replicas[src].as_ref().overloaded() {
                    break;
                }
                let Some((dst, dst_headroom)) =
                    best_by_headroom_with(replicas, quota, |r| {
                        r.id() != src && self.placeable(r.id()) && !r.overloaded()
                    })
                else {
                    break;
                };
                let fee = self.memory.handoff_cost(tokens);
                if dst_headroom <= fee {
                    // Eq. 7 gain does not cover this cache's transfer; a
                    // later candidate may be smaller, so keep scanning
                    continue;
                }
                let task = replicas[src].as_mut().extract_running(gid, fee);
                self.migrated.insert(gid);
                self.migrations += 1;
                self.migrated_running += 1;
                self.handoff_bytes += self.memory.bytes_for(tokens);
                self.handoff_us += fee;
                replicas[dst].as_mut().receive_migrated(task);
            }
        }
    }

    /// Evacuate a replica that is leaving the fleet (`crash`: it died
    /// losing its resident KV; otherwise a graceful leave). The caller
    /// has already marked it dead in `alive`, so every placement below
    /// naturally excludes it.
    ///
    /// Queued-but-unstarted tasks are withdrawn and re-placed for free
    /// (their state never left this replica). In-service tasks are
    /// extracted and re-admitted on the best eligible peer with a
    /// restore fee stamped on the task and charged by the destination
    /// at the task's next decode: after a crash the fee is a full
    /// prefill *recompute* of the cached sequence **on the
    /// destination's own latency curve** (the cache is gone); after a
    /// leave it is the PR 4 KV *handoff* transfer time over the
    /// inter-replica link. Evacuation bypasses the exactly-once
    /// overload-migration set — losing a replica is not an overload
    /// decision, and a previously-migrated task must still move off a
    /// dead one.
    pub(crate) fn evacuate<R: AsRef<Replica> + AsMut<Replica>>(
        &mut self,
        replicas: &mut [R],
        src: usize,
        crash: bool,
    ) {
        // queued tasks first: free re-placement, arrival order
        let queued = replicas[src].as_mut().withdraw_all();
        self.requeue_evacuated(replicas, src, queued);
        self.evacuate_in_service(replicas, src, crash);
    }

    /// Free re-placement of queued-but-unstarted tasks withdrawn from
    /// `src` (their state never left that replica). Split out of
    /// [`Controller::evacuate`] so detector confirmation can requeue
    /// the *pre-crash* partition of a dead replica's queue through the
    /// byte-identical oracle path while routing the post-crash limbo
    /// partition into retry instead.
    pub(crate) fn requeue_evacuated<R: AsRef<Replica> + AsMut<Replica>>(
        &mut self,
        replicas: &mut [R],
        src: usize,
        queued: Vec<Task>,
    ) {
        for task in queued {
            let quota = task.slo.tokens_per_cycle();
            let dst = best_by_headroom(replicas, quota, |r| {
                r.id() != src && self.placeable(r.id()) && !r.overloaded()
            })
            .or_else(|| {
                best_by_headroom(replicas, quota, |r| {
                    r.id() != src && self.is_alive(r.id())
                })
            });
            match dst {
                Some(d) => {
                    self.evac_requeued += 1;
                    replicas[d].as_mut().receive_migrated(task);
                }
                // unreachable while min_replicas >= 1 (the lifecycle
                // bound keeps an alive peer); shed defensively
                None => self.reject(task),
            }
        }
    }

    /// The in-service half of [`Controller::evacuate`]: extract and
    /// re-admit everything `src` was actively serving, with the
    /// crash/leave restore fee priced on each destination.
    pub(crate) fn evacuate_in_service<R: AsRef<Replica> + AsMut<Replica>>(
        &mut self,
        replicas: &mut [R],
        src: usize,
        crash: bool,
    ) {
        // everything in service, delivery order
        let manifest = replicas[src].as_ref().evacuees();
        for (gid, quota, tokens, prefilled) in manifest {
            let dst = best_by_headroom(replicas, quota, |r| {
                r.id() != src && self.placeable(r.id()) && !r.overloaded()
            })
            .or_else(|| {
                best_by_headroom(replicas, quota, |r| {
                    r.id() != src && self.is_alive(r.id())
                })
            });
            let Some(d) = dst else {
                // no alive peer (unreachable under the lifecycle
                // bounds): the task stays on the dead replica and its
                // report counts it as an SLO violation
                continue;
            };
            let mut task = replicas[src].as_mut().extract_evacuee(gid);
            if prefilled {
                let fee = if crash {
                    replicas[d].as_ref().profile().latency.prefill(tokens)
                } else {
                    self.memory.handoff_cost(tokens)
                };
                task.pending_restore = fee;
                if crash {
                    self.evac_recompute_us += fee;
                } else {
                    self.handoff_bytes += self.memory.bytes_for(tokens);
                    self.handoff_us += fee;
                }
                self.evac_restarted += 1;
            } else {
                self.evac_requeued += 1;
            }
            replicas[d].as_mut().receive_migrated(task);
        }
    }

    /// Consume the controller and the drained fleet into the final
    /// [`ClusterReport`] — the single construction point both engines
    /// share, so the report shape cannot drift between them.
    pub(crate) fn into_report(self, replicas: Vec<Replica>) -> ClusterReport {
        let elastic = ElasticStats {
            crashes: self.crashes,
            joins: self.joins,
            leaves: self.leaves,
            evac_requeued: self.evac_requeued,
            evac_restarted: self.evac_restarted,
            evac_recompute_us: self.evac_recompute_us,
            autoscale_grows: self.autoscale_grows,
            autoscale_shrinks: self.autoscale_shrinks,
            autoscale_pending_boots: self.autoscale_pending_boots,
            suspicions: self.suspicions,
            false_suspicions: self.false_suspicions,
            detections: self.detections,
            limbo_recovered: self.limbo_recovered,
            retries: self.retries,
            retry_exhausted: self.retry_exhausted,
            limbo_lost: self.limbo_lost,
        };
        let mut reports: Vec<_> = replicas.into_iter().map(Replica::finish).collect();
        if !self.alive.is_empty() {
            for r in &mut reports {
                r.alive = self.alive[r.replica];
            }
        }
        ClusterReport {
            strategy: self.strategy.label(),
            migrations: self.migrations,
            migrated_running: self.migrated_running,
            migration_passes: self.migration_passes,
            migration_checks: self.migration_checks,
            handoff_bytes: self.handoff_bytes,
            handoff_us: self.handoff_us,
            rejected: self.rejected,
            rejected_folded: self.rejected_folded,
            replicas: reports,
            elastic,
        }
    }
}

/// Immutable view of the controller's liveness/degradation masks,
/// shareable with epoch worker threads (same empty-for-static contract
/// as [`Controller::is_alive`]/[`Controller::is_degraded`]).
#[derive(Clone, Copy)]
pub(crate) struct MaskSnapshot<'a> {
    alive: &'a [bool],
    degraded: &'a [bool],
}

impl MaskSnapshot<'_> {
    /// Liveness; a missing entry (static fleet) is alive.
    pub(crate) fn is_alive(&self, i: usize) -> bool {
        self.alive.get(i).copied().unwrap_or(true)
    }

    /// Health verdict; a missing entry (static fleet) is healthy.
    #[allow(dead_code)] // symmetry with is_alive; kept for worker use
    pub(crate) fn is_degraded(&self, i: usize) -> bool {
        self.degraded.get(i).copied().unwrap_or(false)
    }
}

/// The replica with the most Eq. 7 headroom for `quota` among those
/// `eligible` — ties broken by least load, then lowest index (the
/// deterministic placement key shared by SLO-aware routing and
/// migration re-placement). `None` when nothing is eligible.
fn best_by_headroom<R: AsRef<Replica>, F: Fn(&Replica) -> bool>(
    replicas: &[R],
    quota: u32,
    eligible: F,
) -> Option<usize> {
    best_by_headroom_with(replicas, quota, eligible).map(|(id, _)| id)
}

/// [`best_by_headroom`] returning the winner's headroom as well, so
/// callers comparing it against a fee don't re-evaluate the replica's
/// whole Eq. 7 demand.
fn best_by_headroom_with<R: AsRef<Replica>, F: Fn(&Replica) -> bool>(
    replicas: &[R],
    quota: u32,
    eligible: F,
) -> Option<(usize, Micros)> {
    replicas
        .iter()
        .map(AsRef::as_ref)
        .filter(|r| eligible(r))
        .map(|r| (std::cmp::Reverse(r.headroom(quota)), r.load_tokens(), r.id()))
        .min()
        .map(|(std::cmp::Reverse(headroom), _, id)| (id, headroom))
}
