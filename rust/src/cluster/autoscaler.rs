//! Fleet autoscaling on SLICE admission signals (DESIGN.md "Elastic
//! fleets").
//!
//! The autoscaler observes every routing boundary and votes
//! [`ScaleDecision`]s; the [`Orchestrator`](super::Orchestrator)
//! applies them (a grow admits a factory-built replica, a shrink
//! retires one leave-style — its work is evacuated, not dropped).
//!
//! Signals are the free by-products of the decisions the cluster
//! already makes, so the scaler adds no per-boundary Eq. 7 work:
//!
//!   * **deficit** — the router shed this arrival. Under headroom
//!     admission a shed means *no* alive, healthy replica had positive
//!     Eq. 7 cycle headroom for the task, i.e. the fleet is at zero
//!     headroom — exactly the paper's overload signal. Without
//!     admission the fallback is every placeable replica overrunning
//!     its cycle. Under `grow_on_headroom` the deficit observation is
//!     instead the fleet's mean Eq. 7 headroom dropping to the
//!     configured floor, so the fleet grows *before* it sheds — see
//!     [`AutoscalerConfig::grow_on_headroom`].
//!   * **idle** — some alive replica has no scheduled work at all
//!     (no queue, no live tasks, no pending event) and nothing was
//!     shed: the fleet is over-provisioned.
//!
//! Hysteresis: a signal must persist for `deficit_streak` /
//! `idle_streak` consecutive boundaries (opposite observations reset
//! the run), and after any action the scaler sleeps for `cooldown`
//! virtual time. Size is bounded by the lifecycle `min_replicas` /
//! `max_replicas`. Everything is a pure function of the observation
//! stream — reruns of one seed scale identically.

use super::lifecycle::AutoscalerConfig;
use crate::util::Micros;

/// What the autoscaler wants done to the fleet at this boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// No change.
    Hold,
    /// Admit one fresh replica.
    Grow,
    /// Retire the replica with this id (idle at decision time).
    Shrink(usize),
}

/// Streak-and-cooldown scaler over shed/idle observations.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    min_replicas: usize,
    max_replicas: usize,
    deficit_run: u32,
    idle_run: u32,
    ready_at: Micros,
    grows: u64,
    shrinks: u64,
}

impl Autoscaler {
    /// New scaler with the given signal shape and fleet bounds.
    pub fn new(cfg: AutoscalerConfig, min_replicas: usize, max_replicas: usize) -> Self {
        assert!(min_replicas >= 1, "fleet lower bound must be at least 1");
        assert!(
            min_replicas <= max_replicas,
            "fleet bounds inverted: min {} > max {}",
            min_replicas,
            max_replicas
        );
        Autoscaler {
            cfg,
            min_replicas,
            max_replicas,
            deficit_run: 0,
            idle_run: 0,
            ready_at: 0,
            grows: 0,
            shrinks: 0,
        }
    }

    /// Actions taken so far, `(grows, shrinks)`.
    pub fn actions(&self) -> (u64, u64) {
        (self.grows, self.shrinks)
    }

    /// Feed one routing-boundary observation and get the decision.
    ///
    /// * `now` — boundary time.
    /// * `deficit` — the fleet had no capacity for this arrival (shed,
    ///   or all-placeable-overloaded fallback).
    /// * `idle_replica` — an alive replica with no work at all, if any
    ///   (the shrink victim; caller picks deterministically).
    /// * `alive` — current alive count (bounds check).
    ///
    /// The caller must apply the returned action for the counters and
    /// cooldown to stay truthful.
    pub fn observe(
        &mut self,
        now: Micros,
        deficit: bool,
        idle_replica: Option<usize>,
        alive: usize,
    ) -> ScaleDecision {
        // A boundary is deficit, idle, or neither; a deficit boundary
        // always breaks an idle streak and vice versa.
        if deficit {
            self.deficit_run += 1;
            self.idle_run = 0;
        } else if idle_replica.is_some() {
            self.idle_run += 1;
            self.deficit_run = 0;
        } else {
            self.deficit_run = 0;
            self.idle_run = 0;
        }
        if now < self.ready_at {
            return ScaleDecision::Hold;
        }
        if self.deficit_run >= self.cfg.deficit_streak && alive < self.max_replicas {
            self.deficit_run = 0;
            self.idle_run = 0;
            self.ready_at = now.saturating_add(self.cfg.cooldown);
            self.grows += 1;
            return ScaleDecision::Grow;
        }
        if self.idle_run >= self.cfg.idle_streak && alive > self.min_replicas {
            if let Some(victim) = idle_replica {
                self.deficit_run = 0;
                self.idle_run = 0;
                self.ready_at = now.saturating_add(self.cfg.cooldown);
                self.shrinks += 1;
                return ScaleDecision::Shrink(victim);
            }
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscalerConfig {
        AutoscalerConfig {
            enabled: true,
            deficit_streak: 2,
            idle_streak: 3,
            cooldown: 1_000,
            ..AutoscalerConfig::default()
        }
    }

    #[test]
    fn grows_after_sustained_deficit_only() {
        let mut a = Autoscaler::new(cfg(), 1, 8);
        assert_eq!(a.observe(0, true, None, 4), ScaleDecision::Hold);
        assert_eq!(a.observe(10, true, None, 4), ScaleDecision::Grow);
        assert_eq!(a.actions(), (1, 0));
    }

    #[test]
    fn opposite_signal_resets_streak() {
        let mut a = Autoscaler::new(cfg(), 1, 8);
        assert_eq!(a.observe(0, true, None, 4), ScaleDecision::Hold);
        // an idle boundary wipes the deficit run
        assert_eq!(a.observe(10, false, Some(2), 4), ScaleDecision::Hold);
        assert_eq!(a.observe(20, true, None, 4), ScaleDecision::Hold);
        assert_eq!(a.observe(30, true, None, 4), ScaleDecision::Grow);
    }

    #[test]
    fn cooldown_blocks_back_to_back_actions() {
        let mut a = Autoscaler::new(cfg(), 1, 8);
        a.observe(0, true, None, 4);
        assert_eq!(a.observe(10, true, None, 4), ScaleDecision::Grow);
        // streak re-satisfied inside the cooldown window: held
        a.observe(20, true, None, 5);
        assert_eq!(a.observe(30, true, None, 5), ScaleDecision::Hold);
        // past the cooldown the pent-up streak fires
        assert_eq!(a.observe(1_200, true, None, 5), ScaleDecision::Grow);
    }

    #[test]
    fn respects_fleet_bounds() {
        let mut a = Autoscaler::new(cfg(), 2, 4);
        a.observe(0, true, None, 4);
        assert_eq!(a.observe(10, true, None, 4), ScaleDecision::Hold, "at max");
        let mut b = Autoscaler::new(cfg(), 2, 4);
        for t in 0..3 {
            let d = b.observe(t * 10, false, Some(1), 2);
            assert_eq!(d, ScaleDecision::Hold, "at min");
        }
    }

    #[test]
    fn shrinks_idle_replica_after_streak() {
        let mut a = Autoscaler::new(cfg(), 1, 8);
        assert_eq!(a.observe(0, false, Some(3), 4), ScaleDecision::Hold);
        assert_eq!(a.observe(10, false, Some(3), 4), ScaleDecision::Hold);
        assert_eq!(a.observe(20, false, Some(3), 4), ScaleDecision::Shrink(3));
        assert_eq!(a.actions(), (0, 1));
    }
}
