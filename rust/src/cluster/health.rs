//! Router health scoring: an EWMA of per-replica boundary lag with a
//! recent-failure penalty (DESIGN.md "Elastic fleets").
//!
//! At every routing boundary the tracker samples each alive replica's
//! *cycle lag* — how far its Eq. 7 period currently overruns the cycle
//! cap (`period_eq7(demand) − cycle_cap`, clamped at zero; the same
//! quantity whose sign drives `Replica::overloaded`). The health score
//! is an exponentially-weighted moving average of that lag plus a flat
//! `failure_penalty` whenever the replica is overrunning at all, so a
//! replica that keeps brushing overload degrades faster than its raw
//! lag suggests:
//!
//! ```text
//! sample_i = lag_i + penalty · 1[lag_i > 0]
//! score_i ← (1 − alpha) · score_i + alpha · sample_i
//! degraded_i ⇔ score_i > lag_threshold
//! ```
//!
//! Degraded replicas are excluded from placement and migration targets
//! (the controller falls back to alive-only if *every* alive replica
//! is degraded — shedding everything because the whole fleet is slow
//! would be worse than placing on the least-bad replica). Scores decay
//! back under the threshold once the replica catches up, so degradation
//! is a temporary routing state, not a lifecycle transition.

use super::lifecycle::HealthConfig;
use crate::util::Micros;

/// Per-replica EWMA lag scores and the degraded verdicts they imply.
#[derive(Debug, Clone)]
pub struct HealthTracker {
    cfg: HealthConfig,
    scores: Vec<f64>,
}

impl HealthTracker {
    /// New tracker for `n` replicas, all starting healthy (score 0).
    pub fn new(cfg: HealthConfig, n: usize) -> Self {
        assert!(
            cfg.alpha > 0.0 && cfg.alpha <= 1.0,
            "health alpha must be in (0, 1], got {}",
            cfg.alpha
        );
        HealthTracker { cfg, scores: vec![0.0; n] }
    }

    /// Grow the score table when replicas join (new entries healthy).
    pub fn ensure(&mut self, n: usize) {
        if self.scores.len() < n {
            self.scores.resize(n, 0.0);
        }
    }

    /// Fold one boundary's lag sample for replica `i` into its score.
    /// Dead replicas are simply not observed — their score freezes.
    pub fn observe(&mut self, i: usize, lag: Micros) {
        let sample = if lag > 0 {
            (lag + self.cfg.failure_penalty) as f64
        } else {
            0.0
        };
        let a = self.cfg.alpha;
        self.scores[i] = (1.0 - a) * self.scores[i] + a * sample;
    }

    /// Current score for replica `i` (µs of smoothed cycle overrun).
    pub fn score(&self, i: usize) -> f64 {
        self.scores[i]
    }

    /// True when replica `i`'s smoothed lag exceeds the threshold.
    pub fn degraded(&self, i: usize) -> bool {
        self.scores[i] > self.cfg.lag_threshold as f64
    }

    /// Write the degraded verdicts into the controller's mask.
    pub fn fill_mask(&self, mask: &mut [bool]) {
        for (i, d) in mask.iter_mut().enumerate() {
            *d = self.degraded(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> HealthConfig {
        HealthConfig {
            enabled: true,
            alpha: 0.5,
            lag_threshold: 1_000,
            failure_penalty: 500,
        }
    }

    #[test]
    fn sustained_lag_degrades_and_recovery_heals() {
        let mut h = HealthTracker::new(cfg(), 2);
        assert!(!h.degraded(0));
        // sample = 2_000 + 500; EWMA alpha 0.5: 1250, 1875 > 1000
        h.observe(0, 2_000);
        assert!(h.degraded(0), "one big overrun already crosses at alpha 0.5");
        h.observe(0, 2_000);
        assert!(h.degraded(0));
        assert!(!h.degraded(1), "scores are per-replica");
        // lag gone: score halves each boundary, back under threshold
        h.observe(0, 0);
        h.observe(0, 0);
        assert!(!h.degraded(0), "healthy boundaries decay the score");
    }

    #[test]
    fn penalty_applies_only_while_overrunning() {
        let mut h = HealthTracker::new(cfg(), 1);
        h.observe(0, 1);
        // sample = 1 + 500 penalty
        assert!((h.score(0) - 250.5).abs() < 1e-9);
        h.observe(0, 0);
        // zero-lag sample carries no penalty
        assert!((h.score(0) - 125.25).abs() < 1e-9);
    }

    #[test]
    fn ensure_adds_healthy_entries() {
        let mut h = HealthTracker::new(cfg(), 1);
        h.observe(0, 5_000);
        h.ensure(3);
        assert!(h.degraded(0));
        assert!(!h.degraded(1) && !h.degraded(2));
        let mut mask = vec![false; 3];
        h.fill_mask(&mut mask);
        assert_eq!(mask, vec![true, false, false]);
    }
}
