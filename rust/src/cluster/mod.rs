//! Cluster layer: multi-replica edge serving above L3 (DESIGN.md
//! "Cluster layer" / "Heterogeneous fleets" / "Event-driven cluster
//! engine").
//!
//! The paper schedules one edge device. This layer scales SLICE out
//! across N [`Replica`]s — each a complete single-device stack
//! (`server::Server` + a `Policy` + a sim engine on its own virtual
//! clock) built from a per-replica [`DeviceProfile`] — under a
//! pluggable [`RoutingStrategy`] (round-robin, least-loaded, or
//! SLO-aware Eq. 7 headroom). Fleets may be heterogeneous
//! ([`FleetSpec`]: mixed device tiers), the fleet can apply per-class
//! admission bounds ([`AdmissionConfig`]), and overloaded replicas can
//! offer queued tasks back for re-placement (migration) — both opt-in.
//!
//! Two engines drive the fleet, sharing every decision through the
//! internal `controller` module:
//!   * [`Router`] — the **lockstep reference engine**: advances every
//!     replica's clock to every arrival before routing it, so load
//!     signals are read exactly when a real front-end would read them;
//!   * [`Orchestrator`] — the **event-driven engine**: a global
//!     [`EventHeap`] of next-arrival / per-node wake / drain-boundary
//!     events; a replica ([`Node`]) is advanced only when it has work.
//!     Bit-exact with the router (pinned by
//!     `rust/tests/equivalence.rs`), and the one to use at fleet scale.
//!
//! Contracts:
//!   * the scheduler code each replica runs is byte-identical to the
//!     single-device path — a 1-replica cluster (admission and
//!     migration disabled) reproduces `Server::run` exactly (asserted
//!     in `rust/tests/cluster_integration.rs` and
//!     `rust/tests/hetero_fleet.rs`);
//!   * both engines produce identical [`ClusterReport`]s for the same
//!     inputs — the event engine's heap order `(time, kind, replica,
//!     task)` reproduces lockstep's decision order;
//!   * cluster runs are deterministic for a fixed workload seed: every
//!     routing, admission and migration tie-break is deterministic
//!     (lowest replica index last);
//!   * every task lands in the report exactly once — on one replica or
//!     on the shed list — and a task migrates at most once;
//!   * fleet metrics ([`ClusterReport`]) aggregate per-replica reports
//!     with global task ids restored, counting shed tasks as SLO
//!     violations.
//!
//! Fleets can be **elastic** (DESIGN.md "Elastic fleets", all opt-in):
//! a deterministic [`LifecycleEvent`] stream (join/leave/crash,
//! explicit times or seeded churn) injected through the event heap, an
//! [`Autoscaler`] growing/shrinking on shed/idle signals with
//! hysteresis, and [`HealthTracker`] EWMA lag scoring that keeps
//! placement off degraded replicas. A crash loses resident KV — its
//! queue is re-placed free and its running tasks re-admitted at the
//! PR 4 recompute price; a graceful leave hands KV off at the modelled
//! link cost. With the [`FailureDetector`] enabled crashes stop being
//! oracle-visible: the fleet learns about them from missed heartbeats
//! (DESIGN.md "Failure detection & recovery"), dispatches into the
//! not-yet-detected corpse sit in limbo until confirmation, and are
//! then re-dispatched with bounded retry/backoff. With everything
//! disabled the masks stay empty and both engines reproduce the
//! static-fleet reports bit-for-bit.
//!
//! Multi-replica serving is an **extension**, not part of the paper —
//! see DESIGN.md "Deviations from the paper".

pub(crate) mod controller;
pub mod autoscaler;
pub mod detector;
pub mod fleet;
pub mod health;
pub mod lifecycle;
pub mod node;
pub mod orchestrator;
pub mod replica;
pub mod router;

pub use autoscaler::{Autoscaler, ScaleDecision};
pub use detector::{FailureDetector, Verdict};
pub use fleet::{AdmissionConfig, AdmissionMode, DeviceProfile, FleetSpec};
pub use health::HealthTracker;
pub use lifecycle::{
    AutoscalerConfig, DetectorConfig, HealthConfig, LifecycleAction, LifecycleConfig,
    LifecycleEvent,
};
pub use node::Node;
pub use orchestrator::{Event, EventHeap, EventKind, Orchestrator};
pub use replica::{Replica, ReplicaReport};
pub use router::{ClusterReport, ElasticStats, Router, RoutingStrategy};
