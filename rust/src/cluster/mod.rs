//! Cluster layer: multi-replica edge serving above L3 (DESIGN.md
//! "Cluster layer" / "Heterogeneous fleets").
//!
//! The paper schedules one edge device. This layer scales SLICE out: a
//! [`Router`] dispatches the arrival stream across N [`Replica`]s —
//! each a complete single-device stack (`server::Server` + a `Policy` +
//! a sim engine on its own virtual clock) built from a per-replica
//! [`DeviceProfile`] — under a pluggable [`RoutingStrategy`]
//! (round-robin, least-loaded, or SLO-aware Eq. 7 headroom). Replica
//! clocks are advanced in lockstep to each arrival, so routing sees
//! device load exactly when a real front-end would. Fleets may be
//! heterogeneous ([`FleetSpec`]: mixed device tiers), the router can
//! apply per-class admission bounds ([`AdmissionConfig`]), and
//! overloaded replicas can offer queued tasks back for re-placement
//! (migration) — both opt-in.
//!
//! Contracts:
//!   * the scheduler code each replica runs is byte-identical to the
//!     single-device path — a 1-replica cluster (admission and
//!     migration disabled) reproduces `Server::run` exactly (asserted
//!     in `rust/tests/cluster_integration.rs` and
//!     `rust/tests/hetero_fleet.rs`);
//!   * cluster runs are deterministic for a fixed workload seed: every
//!     routing, admission and migration tie-break is deterministic
//!     (lowest replica index last);
//!   * every task lands in the report exactly once — on one replica or
//!     on the shed list — and a task migrates at most once;
//!   * fleet metrics ([`ClusterReport`]) aggregate per-replica reports
//!     with global task ids restored, counting shed tasks as SLO
//!     violations.
//!
//! Multi-replica serving is an **extension**, not part of the paper —
//! see DESIGN.md "Deviations from the paper".

pub mod fleet;
pub mod replica;
pub mod router;

pub use fleet::{AdmissionConfig, AdmissionMode, DeviceProfile, FleetSpec};
pub use replica::{Replica, ReplicaReport};
pub use router::{ClusterReport, Router, RoutingStrategy};
