//! Cluster layer: multi-replica edge serving above L3 (DESIGN.md
//! "Cluster layer").
//!
//! The paper schedules one edge device. This layer scales SLICE out: a
//! [`Router`] dispatches the arrival stream across N [`Replica`]s —
//! each a complete single-device stack (`server::Server` + a `Policy` +
//! a sim engine on its own virtual clock) — under a pluggable
//! [`RoutingStrategy`] (round-robin, least-loaded, or SLO-aware Eq. 7
//! headroom). Replica clocks are advanced in lockstep to each arrival,
//! so routing sees device load exactly when a real front-end would.
//!
//! Contracts:
//!   * the scheduler code each replica runs is byte-identical to the
//!     single-device path — a 1-replica cluster reproduces `Server::run`
//!     exactly (asserted in `rust/tests/cluster_integration.rs`);
//!   * cluster runs are deterministic for a fixed workload seed: every
//!     routing tie-break is by lowest replica index;
//!   * fleet metrics ([`ClusterReport`]) aggregate per-replica reports
//!     with global task ids restored.
//!
//! Multi-replica serving is an **extension**, not part of the paper —
//! see DESIGN.md "Deviations from the paper".

pub mod replica;
pub mod router;

pub use replica::{Replica, ReplicaReport};
pub use router::{ClusterReport, Router, RoutingStrategy};
