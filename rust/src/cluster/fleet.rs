//! Heterogeneous fleet description: per-replica device profiles plus
//! router admission bounds (DESIGN.md "Heterogeneous fleets").
//!
//! The paper calibrates one edge device; an edge *fleet* mixes device
//! tiers (a workstation GPU next to Orin- and Nano-class boards). A
//! [`DeviceProfile`] captures what the router and scheduler must know
//! about one device — its latency curve `l(b)`, batch/context limits
//! and Eq. 7 scheduling-cycle cap — and a [`FleetSpec`] is the ordered
//! list of profiles a cluster run builds its replicas from. Specs come
//! from three equivalent sources (all producing the same struct):
//!
//!   * CLI presets: `slice-serve cluster --fleet edge-mixed` (or a
//!     comma list like `standard,standard,lite,nano`);
//!   * config files: a `[[cluster.replica]]` TOML array of tables;
//!   * code: [`FleetSpec::homogeneous`] / [`FleetSpec::preset`].
//!
//! [`AdmissionConfig`] holds the router's per-class queue bounds (see
//! `cluster::Router` for the shed/deferral semantics). Admission and
//! migration are opt-in: the defaults reproduce the PR 2 homogeneous
//! cluster behaviour bit-for-bit.

use anyhow::{bail, Result};

use crate::coordinator::selection::CYCLE_CAP;
use crate::coordinator::task::TaskClass;
use crate::engine::latency::LatencyModel;
use crate::util::Micros;

/// Everything the cluster layer knows about one device tier.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    /// Tier name used in reports ("standard", "lite", "nano", ...).
    pub name: &'static str,
    /// The device's calibrated decode/prefill latency curve.
    pub latency: LatencyModel,
    /// Hard cap on concurrently decodable tasks (device memory limit).
    pub max_batch: u32,
    /// Context-window limit of the device's engine.
    pub max_context: u32,
    /// Eq. 7 scheduling-cycle cap used for selection and headroom.
    pub cycle_cap: Micros,
    /// This tier's share of the configured base KV capacity (standard
    /// 1.0, lite 0.75, nano 0.5 — DRAM shrinks less steeply across
    /// edge boards than compute does, and every tier must still hold
    /// the longest single task's cache). Applied by
    /// [`FleetSpec::with_kv_capacity`].
    pub kv_fraction: f64,
    /// Tier-scaled KV capacity in bytes; `None` (the default) models an
    /// unconstrained device, reproducing every pre-memory run
    /// bit-exactly.
    pub kv_capacity: Option<u64>,
}

impl DeviceProfile {
    /// The paper's testbed device (RTX 4060 Ti class): the curve every
    /// PR 2 replica ran, so a fleet of `standard` profiles reproduces
    /// the homogeneous cluster exactly.
    pub fn standard() -> Self {
        DeviceProfile {
            name: "standard",
            latency: LatencyModel::paper_calibrated(),
            max_batch: 32,
            max_context: 8192,
            cycle_cap: CYCLE_CAP,
            kv_fraction: 1.0,
            kv_capacity: None,
        }
    }

    /// A mid-tier edge board (Orin class): 1.5x the standard latency at
    /// every batch size, half the batch and context headroom.
    pub fn lite() -> Self {
        DeviceProfile {
            name: "lite",
            latency: LatencyModel::paper_calibrated().scaled(1.5),
            max_batch: 16,
            max_context: 4096,
            cycle_cap: CYCLE_CAP,
            kv_fraction: 0.75,
            kv_capacity: None,
        }
    }

    /// A constrained edge board (Nano class): 2.5x the standard latency,
    /// batch capped at 8.
    pub fn nano() -> Self {
        DeviceProfile {
            name: "nano",
            latency: LatencyModel::paper_calibrated().scaled(2.5),
            max_batch: 8,
            max_context: 2048,
            cycle_cap: CYCLE_CAP,
            kv_fraction: 0.5,
            kv_capacity: None,
        }
    }

    /// Look up a tier by its CLI/config spelling.
    pub fn named(name: &str) -> Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "standard" => Self::standard(),
            "lite" => Self::lite(),
            "nano" => Self::nano(),
            other => bail!("unknown device profile '{other}' (standard|lite|nano)"),
        })
    }
}

/// Ordered per-replica device profiles for one cluster run.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// One profile per replica, in replica-index order.
    pub profiles: Vec<DeviceProfile>,
}

impl FleetSpec {
    /// `n` standard devices — the PR 2 homogeneous fleet. `cycle_cap`
    /// is threaded from the serve config so a configured cap applies to
    /// selection and routing exactly as it did pre-refactor.
    pub fn homogeneous(n: usize, cycle_cap: Micros) -> Self {
        assert!(n >= 1, "a fleet needs at least one replica");
        let mut profile = DeviceProfile::standard();
        profile.cycle_cap = cycle_cap;
        FleetSpec { profiles: vec![profile; n] }
    }

    /// Parse a `--fleet` spelling: a named preset (`edge-mixed`) or a
    /// comma-separated list of device tiers (`standard,lite,nano`).
    pub fn preset(spec: &str) -> Result<Self> {
        let profiles = match spec.to_ascii_lowercase().as_str() {
            // two workstation-class devices next to one mid-tier and one
            // constrained board — the heterogeneity the hetero sweep and
            // EXPERIMENTS.md study
            "edge-mixed" => vec![
                DeviceProfile::standard(),
                DeviceProfile::standard(),
                DeviceProfile::lite(),
                DeviceProfile::nano(),
            ],
            list => list
                .split(',')
                .map(|name| DeviceProfile::named(name.trim()))
                .collect::<Result<Vec<_>>>()?,
        };
        if profiles.is_empty() {
            bail!("fleet spec '{spec}' names no replicas");
        }
        Ok(FleetSpec { profiles })
    }

    /// Overwrite every profile's scheduling-cycle cap — how a
    /// configured `[scheduler] cycle_cap_ms` is threaded into preset
    /// fleets (per-replica `cycle_cap_ms` table keys take precedence at
    /// the config layer).
    pub fn with_cycle_cap(mut self, cycle_cap: Micros) -> Self {
        for p in &mut self.profiles {
            p.cycle_cap = cycle_cap;
        }
        self
    }

    /// Apply a base KV capacity (a standard device's bytes) to every
    /// profile, scaled by its tier fraction — how `[memory]
    /// kv_capacity_mb` / `--kv-capacity` is threaded into a fleet.
    /// `None` clears every capacity (unconstrained).
    pub fn with_kv_capacity(mut self, base: Option<u64>) -> Self {
        for p in &mut self.profiles {
            p.kv_capacity = base.map(|b| (b as f64 * p.kv_fraction) as u64);
        }
        self
    }

    /// Number of replicas the spec describes.
    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    /// True when the spec is empty (never for constructed specs).
    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// Tier names in replica order (reports/diagnostics).
    pub fn names(&self) -> Vec<&'static str> {
        self.profiles.iter().map(|p| p.name).collect()
    }
}

/// What signal decides whether a replica may accept one more task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionMode {
    /// Per-class queued-but-unstarted depth bounds (the PR 3 signal).
    #[default]
    QueueDepth,
    /// Eq. 7 cycle headroom: a replica is admissible while adding the
    /// task's per-cycle quota leaves its scheduling cycle strictly
    /// under the cap. A deep queue of fast tasks stays admissible;
    /// a shallow queue of expensive ones does not (the ROADMAP
    /// follow-on replacing depth with demand).
    Headroom,
}

impl AdmissionMode {
    /// Display name used in reports.
    pub fn label(&self) -> &'static str {
        match self {
            AdmissionMode::QueueDepth => "depth",
            AdmissionMode::Headroom => "headroom",
        }
    }
}

/// Router admission control: a per-replica admissibility signal —
/// per-SLO-class queue-depth bounds ([`AdmissionMode::QueueDepth`]) or
/// Eq. 7 cycle headroom ([`AdmissionMode::Headroom`]). A task is
/// *deferred* to the strategy's next-best admissible replica while one
/// exists, and *shed* (rejected, counted SLO-violated) once none does.
/// Disabled (the default) admits everything — the PR 2 behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Master switch; when false the bounds are ignored.
    pub enabled: bool,
    /// Which admissibility signal the router reads.
    pub mode: AdmissionMode,
    /// Max queued-but-unstarted real-time tasks per replica
    /// (`QueueDepth` mode).
    pub rt_queue_bound: usize,
    /// Max queued-but-unstarted non-real-time tasks per replica
    /// (`QueueDepth` mode).
    pub nrt_queue_bound: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            enabled: false,
            mode: AdmissionMode::QueueDepth,
            rt_queue_bound: 12,
            nrt_queue_bound: 10,
        }
    }
}

impl AdmissionConfig {
    /// The queue bound applying to `class`.
    pub fn bound_for(&self, class: TaskClass) -> usize {
        if class.is_real_time() {
            self.rt_queue_bound
        } else {
            self.nrt_queue_bound
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ms;

    #[test]
    fn named_profiles_resolve() {
        assert_eq!(DeviceProfile::named("standard").unwrap().name, "standard");
        assert_eq!(DeviceProfile::named("LITE").unwrap().name, "lite");
        assert_eq!(DeviceProfile::named("nano").unwrap().name, "nano");
        let err = DeviceProfile::named("tpu").unwrap_err().to_string();
        assert!(err.contains("standard|lite|nano"), "unhelpful error: {err}");
    }

    #[test]
    fn tiers_are_ordered_by_speed() {
        let (s, l, n) =
            (DeviceProfile::standard(), DeviceProfile::lite(), DeviceProfile::nano());
        for b in [1u32, 4, 8] {
            assert!(s.latency.decode(b) < l.latency.decode(b));
            assert!(l.latency.decode(b) < n.latency.decode(b));
        }
        assert!(s.max_batch > l.max_batch && l.max_batch > n.max_batch);
    }

    #[test]
    fn homogeneous_is_all_standard() {
        let f = FleetSpec::homogeneous(3, CYCLE_CAP);
        assert_eq!(f.len(), 3);
        assert_eq!(f.names(), vec!["standard"; 3]);
        assert_eq!(f.profiles[0].latency.decode(9), ms(128.59));
    }

    #[test]
    fn edge_mixed_preset_shape() {
        let f = FleetSpec::preset("edge-mixed").unwrap();
        assert_eq!(f.names(), vec!["standard", "standard", "lite", "nano"]);
    }

    #[test]
    fn with_cycle_cap_overwrites_every_profile() {
        let f = FleetSpec::preset("edge-mixed").unwrap().with_cycle_cap(750_000);
        assert!(f.profiles.iter().all(|p| p.cycle_cap == 750_000));
    }

    #[test]
    fn comma_list_parses() {
        let f = FleetSpec::preset("standard, lite,nano").unwrap();
        assert_eq!(f.names(), vec!["standard", "lite", "nano"]);
        assert!(FleetSpec::preset("standard,warp").is_err());
        assert!(FleetSpec::preset("").is_err());
    }

    #[test]
    fn kv_capacity_scales_by_tier_fraction() {
        let base = 256 * 1024 * 1024u64;
        let f = FleetSpec::preset("edge-mixed").unwrap().with_kv_capacity(Some(base));
        let caps: Vec<Option<u64>> =
            f.profiles.iter().map(|p| p.kv_capacity).collect();
        assert_eq!(
            caps,
            vec![Some(base), Some(base), Some(base * 3 / 4), Some(base / 2)]
        );
        // None clears it again (unconstrained default)
        let f = f.with_kv_capacity(None);
        assert!(f.profiles.iter().all(|p| p.kv_capacity.is_none()));
        // and the default profiles are unconstrained
        assert!(DeviceProfile::standard().kv_capacity.is_none());
    }

    #[test]
    fn admission_mode_defaults_to_depth() {
        let a = AdmissionConfig::default();
        assert_eq!(a.mode, AdmissionMode::QueueDepth);
        assert_eq!(AdmissionMode::QueueDepth.label(), "depth");
        assert_eq!(AdmissionMode::Headroom.label(), "headroom");
    }

    #[test]
    fn admission_bounds_by_class() {
        let a = AdmissionConfig { enabled: true, rt_queue_bound: 3, nrt_queue_bound: 7, ..AdmissionConfig::default() };
        assert_eq!(a.bound_for(TaskClass::RealTime), 3);
        assert_eq!(a.bound_for(TaskClass::Voice), 7);
        assert_eq!(a.bound_for(TaskClass::TextQa), 7);
        assert!(!AdmissionConfig::default().enabled);
    }
}
