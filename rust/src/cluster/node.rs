//! One event-engine node: a [`Replica`] plus the bookkeeping the
//! [`crate::cluster::Orchestrator`] needs to advance it lazily —
//! its scheduled wake time, the last boundary it was advanced to, and
//! an advancement counter (the observable that proves an idle replica
//! is never stepped, which the lockstep engine cannot do).
//!
//! A node exposes the *time of its next interesting event*
//! ([`Node::next_event_time`], delegating to
//! [`Replica::next_event_time`]): the earliest instant at which
//! advancing the replica would do real work (deliver an arrival or run
//! an engine step) rather than just move its clock. The orchestrator
//! only schedules wake events at these times; everything else about
//! routing-visible replica state (`queued_in_class`, `load_tokens`,
//! `headroom`, `overloaded`) is clock-independent, so a lagging clock
//! on an idle node is unobservable to the shared
//! [`Controller`](super::controller::Controller) decision code.

use anyhow::Result;

use crate::util::Micros;

use super::replica::Replica;

/// A replica wrapped with event-engine advancement bookkeeping.
pub struct Node {
    replica: Replica,
    /// The wake time currently scheduled in the orchestrator's event
    /// heap, if any. An entry popping with a different time is stale
    /// (the wake was refreshed after assignment/migration) and dropped.
    wake: Option<Micros>,
    /// The last routing boundary this node was advanced to.
    advanced_to: Option<Micros>,
    /// Number of `run_until` advancements issued to the replica — the
    /// event engine's cost model, and the proof obligation of the
    /// idle-replica property test (an unused replica stays at zero).
    advancements: u64,
}

impl Node {
    /// Wrap a replica for event-driven advancement.
    pub fn new(replica: Replica) -> Self {
        Node { replica, wake: None, advanced_to: None, advancements: 0 }
    }

    /// The wrapped replica (read-only).
    pub fn replica(&self) -> &Replica {
        &self.replica
    }

    /// Unwrap into the replica (for [`Replica::finish`]).
    pub fn into_replica(self) -> Replica {
        self.replica
    }

    /// The wake time currently scheduled in the event heap, if any.
    pub fn wake(&self) -> Option<Micros> {
        self.wake
    }

    /// Record that a wake event for time `t` is now in the heap.
    pub fn set_wake(&mut self, t: Micros) {
        self.wake = Some(t);
    }

    /// Record that this node's scheduled wake was consumed (or that any
    /// remaining heap entries for it are stale).
    pub fn clear_wake(&mut self) {
        self.wake = None;
    }

    /// The last routing boundary this node was advanced to.
    pub fn advanced_to(&self) -> Option<Micros> {
        self.advanced_to
    }

    /// How many advancement calls this node has received.
    pub fn advancements(&self) -> u64 {
        self.advancements
    }

    /// Advance the replica's simulation to boundary `t` (counted — this
    /// is real work: delivering arrivals and running engine steps).
    pub fn advance_to(&mut self, t: Micros) -> Result<()> {
        self.advancements += 1;
        self.advanced_to = Some(t);
        self.replica.run_until(t)
    }

    /// Move the replica's clock to `t` without running the serving loop
    /// (uncounted — used at the drain boundary for replicas that never
    /// had work, so their reports end at the common horizon exactly as
    /// under lockstep while the zero-advancement property still holds).
    pub fn sync_clock(&mut self, t: Micros) {
        self.replica.sync_clock(t);
    }

    /// Earliest time at which advancing this replica would do real
    /// work, or `None` when it is fully idle (no live, staged, or
    /// pending-arrival tasks).
    pub fn next_event_time(&self) -> Option<Micros> {
        self.replica.next_event_time()
    }
}

// Compile-time Send audit (DESIGN.md "Parallel event engine"): epoch
// workers receive `&mut Node`, so everything a node owns — replica,
// server, policy, engine, token sink — must be able to cross threads.
// This fails to compile if any layer regresses to a thread-pinned type.
const fn assert_send<T: Send>() {}
const _: () = assert_send::<Node>();

impl AsRef<Replica> for Node {
    fn as_ref(&self) -> &Replica {
        &self.replica
    }
}

impl AsMut<Replica> for Node {
    fn as_mut(&mut self) -> &mut Replica {
        &mut self.replica
    }
}
