//! One cluster replica: a full single-device serving stack (policy +
//! engine + virtual clock) behind a thin id-translation shim.
//!
//! The router hands a replica globally-identified tasks; the replica
//! re-ids them densely (the [`TaskPool`] contract) and translates back
//! when the run finishes, so fleet-level metrics see the original ids
//! while the scheduler code runs byte-identical to the single-device
//! path (DESIGN.md "Cluster layer").

use anyhow::Result;

use crate::coordinator::mask::period_eq7;
use crate::coordinator::scheduler::Policy;
use crate::coordinator::task::{Task, TaskId};
use crate::engine::clock::VirtualClock;
use crate::engine::latency::LatencyModel;
use crate::engine::DecodeEngine;
use crate::server::{RunReport, Server};
use crate::util::Micros;

/// A single serving replica inside a [`crate::cluster::Router`] fleet.
pub struct Replica {
    id: usize,
    server: Server<VirtualClock>,
    /// Maps this replica's dense local ids back to global task ids.
    global_ids: Vec<TaskId>,
    latency: LatencyModel,
}

impl Replica {
    /// Build a replica over a fresh policy/engine pair. `latency` is the
    /// device curve the router scores SLO-aware decisions with; it must
    /// match the engine's (as `experiments::run_cluster` guarantees).
    pub fn new(
        id: usize,
        policy: Box<dyn Policy>,
        engine: Box<dyn DecodeEngine>,
        latency: LatencyModel,
    ) -> Self {
        Replica {
            id,
            server: Server::new(Vec::new(), policy, engine, VirtualClock::new()),
            global_ids: Vec::new(),
            latency,
        }
    }

    /// This replica's index within the fleet.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Number of tasks routed to this replica so far.
    pub fn routed(&self) -> usize {
        self.global_ids.len()
    }

    /// Current virtual time on this replica.
    pub fn now(&self) -> Micros {
        self.server.now()
    }

    /// Routed arrivals not yet delivered to this replica's scheduler.
    pub fn pending(&self) -> usize {
        self.server.pending_arrivals().count()
    }

    /// Accept a routed task: record its global id, re-id it into this
    /// replica's dense local id space and enqueue the arrival.
    pub fn assign(&mut self, mut task: Task) {
        let local = self.global_ids.len() as TaskId;
        self.global_ids.push(task.id);
        task.id = local;
        self.server.push_arrival(task);
    }

    /// Advance this replica's simulation to time `t`.
    pub fn run_until(&mut self, t: Micros) -> Result<()> {
        self.server.run_until(t)
    }

    /// Outstanding work in tokens: remaining output of every unfinished
    /// task in service plus the full output of still-queued arrivals.
    /// This is the least-loaded routing signal.
    pub fn load_tokens(&self) -> u64 {
        let in_service: u64 = self
            .server
            .pool()
            .iter()
            .filter(|t| !t.is_finished())
            .map(|t| t.remaining_tokens() as u64)
            .sum();
        let queued: u64 = self
            .server
            .pending_arrivals()
            .map(|t| t.output_len as u64)
            .sum();
        in_service + queued
    }

    /// Per-cycle token quotas (v_i = ceil(1s / T_TPOT)) of every live
    /// task on this replica — the Eq. 7 demand the device must serve
    /// each scheduling cycle.
    pub fn demand_quotas(&self) -> Vec<u32> {
        self.server
            .pool()
            .iter()
            .filter(|t| !t.is_finished())
            .map(|t| t.slo.tokens_per_cycle())
            .chain(self.server.pending_arrivals().map(|t| t.slo.tokens_per_cycle()))
            .collect()
    }

    /// Scheduling-cycle headroom (Eq. 7) if a task with per-cycle quota
    /// `cand_quota` joined this replica: `cycle_cap − T_period(demand ∪
    /// {candidate})`, saturating at zero. The SLO-aware router sends a
    /// task where this is largest, which is where its Eq. 6 utility
    /// rate is most likely to survive selection.
    pub fn headroom(&self, cand_quota: u32, cycle_cap: Micros) -> Micros {
        let mut vs = self.demand_quotas();
        vs.push(cand_quota);
        vs.sort_unstable_by(|a, b| b.cmp(a));
        cycle_cap.saturating_sub(period_eq7(&vs, &self.latency))
    }

    /// Finish the replica's run and translate local ids back to global.
    pub fn finish(self) -> ReplicaReport {
        let mut report = self.server.finish();
        for t in &mut report.tasks {
            t.id = self.global_ids[t.id as usize];
        }
        ReplicaReport { replica: self.id, routed: self.global_ids.len(), report }
    }
}

/// One replica's contribution to a cluster run, with global task ids.
pub struct ReplicaReport {
    /// Fleet index of the replica.
    pub replica: usize,
    /// Tasks routed to it.
    pub routed: usize,
    /// Its full single-device run report.
    pub report: RunReport,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::orca::OrcaPolicy;
    use crate::coordinator::task::TaskClass;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn replica() -> Replica {
        Replica::new(
            0,
            Box::new(OrcaPolicy::new(32)),
            Box::new(SimEngine::paper_calibrated()),
            LatencyModel::paper_calibrated(),
        )
    }

    #[test]
    fn assign_re_ids_and_finish_restores() {
        let mut r = replica();
        r.assign(Task::new(17, TaskClass::Voice, 0, 16, 5, 1.0));
        r.assign(Task::new(99, TaskClass::RealTime, secs(0.1), 16, 5, 100.0));
        assert_eq!(r.routed(), 2);
        r.run_until(secs(30.0)).unwrap();
        let rep = r.finish();
        let mut ids: Vec<TaskId> = rep.report.tasks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![17, 99]);
        assert!(rep.report.tasks.iter().all(|t| t.is_finished()));
    }

    #[test]
    fn load_counts_queued_and_in_service_tokens() {
        let mut r = replica();
        assert_eq!(r.load_tokens(), 0);
        r.assign(Task::new(0, TaskClass::Voice, 0, 16, 40, 1.0));
        r.assign(Task::new(1, TaskClass::Voice, secs(5.0), 16, 7, 1.0));
        // nothing delivered yet: both still queued
        assert_eq!(r.load_tokens(), 47);
        // run past the first arrival; its remaining tokens shrink
        r.run_until(secs(1.0)).unwrap();
        assert!(r.load_tokens() < 47);
        assert!(r.load_tokens() >= 7, "queued task still counted");
    }

    #[test]
    fn headroom_shrinks_with_demand() {
        let cap = 1_000_000;
        let mut r = replica();
        let empty = r.headroom(8, cap);
        for i in 0..6 {
            r.assign(Task::new(i, TaskClass::RealTime, 0, 16, 200, 100.0));
        }
        let loaded = r.headroom(8, cap);
        assert!(loaded < empty, "headroom {loaded} !< {empty}");
    }
}
