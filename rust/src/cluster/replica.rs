//! One cluster replica: a full single-device serving stack (policy +
//! engine + virtual clock) built from a [`DeviceProfile`], behind a
//! thin id-translation shim.
//!
//! The router hands a replica globally-identified tasks. The replica
//! *stages* them (sorted by arrival, still carrying global ids) and
//! only re-ids them into its dense local id space when its clock is
//! about to cross their arrival — the moment they are pushed into the
//! inner [`Server`]. Staged and pushed-but-undelivered tasks are
//! "queued-but-unstarted": the scheduler has never seen them, so the
//! router may withdraw them for migration without perturbing policy
//! state ([`Replica::withdraw_unmigrated`]). Local ids are therefore
//! assigned in delivery order, which keeps the `TaskPool` dense-id
//! contract intact even when migration reorders queues. Without
//! migration the staging layer is behaviourally invisible: tasks are
//! pushed in exactly the order and at exactly the boundaries PR 2
//! pushed them, so homogeneous runs reproduce bit-for-bit (asserted in
//! `rust/tests/hetero_fleet.rs`).

use std::cell::RefCell;
use std::collections::HashSet;

use anyhow::Result;

use crate::coordinator::mask::period_eq7;
use crate::coordinator::scheduler::Policy;
use crate::coordinator::task::{Residency, Task, TaskClass, TaskId, TaskState};
use crate::engine::clock::VirtualClock;
use crate::engine::DecodeEngine;
use crate::server::{RunReport, Server};
use crate::util::Micros;

use super::fleet::DeviceProfile;

/// A single serving replica inside a [`crate::cluster::Router`] fleet.
pub struct Replica {
    id: usize,
    server: Server<VirtualClock>,
    /// Maps this replica's dense local ids back to global task ids.
    global_ids: Vec<TaskId>,
    /// Routed tasks (global ids) not yet handed to the server, sorted
    /// by arrival; ties keep routing order.
    staged: Vec<Task>,
    profile: DeviceProfile,
    routed: usize,
    migrated_in: u64,
    migrated_out: u64,
    /// Quota buffer reused by every Eq. 7 headroom/overload evaluation
    /// (a routing decision evaluates one per replica, so the decision
    /// loop must not allocate). Interior mutability keeps the
    /// load-signal methods `&self` for the router's read-only scans.
    quota_scratch: RefCell<Vec<u32>>,
}

impl Replica {
    /// Build a replica over a fresh policy/engine pair calibrated to
    /// `profile` (as `experiments::run_fleet` guarantees): the policy
    /// and engine must share the profile's latency curve, and the
    /// router scores SLO-aware decisions with the same curve and the
    /// profile's cycle cap.
    pub fn new(
        id: usize,
        policy: Box<dyn Policy>,
        engine: Box<dyn DecodeEngine>,
        profile: DeviceProfile,
    ) -> Self {
        Replica {
            id,
            server: Server::new(Vec::new(), policy, engine, VirtualClock::new()),
            global_ids: Vec::new(),
            staged: Vec::new(),
            profile,
            routed: 0,
            migrated_in: 0,
            migrated_out: 0,
            quota_scratch: RefCell::new(Vec::new()),
        }
    }

    /// This replica's index within the fleet.
    pub fn id(&self) -> usize {
        self.id
    }

    /// The device profile this replica models.
    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// Number of tasks currently placed on this replica (assigned minus
    /// migrated away).
    pub fn routed(&self) -> usize {
        self.routed
    }

    /// Tasks migrated into / out of this replica (reports).
    pub fn migration_counts(&self) -> (u64, u64) {
        (self.migrated_in, self.migrated_out)
    }

    /// Current virtual time on this replica.
    pub fn now(&self) -> Micros {
        self.server.now()
    }

    /// Routed arrivals not yet delivered to this replica's scheduler
    /// (staged here plus queued inside the server).
    pub fn pending(&self) -> usize {
        self.staged.len() + self.server.pending_arrivals().count()
    }

    /// Global ids of every queued-but-unstarted task — exactly the set
    /// a [`Replica::withdraw_all`] at this instant would return. The
    /// failure detector snapshots this at crash time so that, at
    /// confirmation, the pre-crash queue (re-placed free, like oracle
    /// evacuation) can be told apart from tasks dispatched into the
    /// not-yet-detected corpse (in limbo, recovered via retry).
    pub fn pending_gids(&self) -> HashSet<TaskId> {
        self.staged
            .iter()
            .map(|t| t.id)
            .chain(
                self.server
                    .pending_arrivals()
                    .map(|t| self.global_ids[t.id as usize]),
            )
            .collect()
    }

    /// Tasks this replica's server has delivered and not yet finished
    /// (ascending id). Every load signal below walks this live set
    /// instead of the full historic pool, so a routing decision stays
    /// O(outstanding work) as completed tasks accumulate.
    fn live_tasks(&self) -> impl Iterator<Item = &Task> {
        let pool = self.server.pool();
        self.server.live_ids().iter().map(move |&id| pool.get(id))
    }

    /// Queued-but-unstarted tasks of one SLO class: staged, undelivered,
    /// or delivered but still waiting for the policy to admit them. This
    /// is the router's admission-control backpressure signal.
    pub fn queued_in_class(&self, class: TaskClass) -> usize {
        let waiting = self
            .live_tasks()
            .filter(|t| t.class == class && t.state == TaskState::Waiting)
            .count();
        waiting
            + self.staged.iter().filter(|t| t.class == class).count()
            + self
                .server
                .pending_arrivals()
                .filter(|t| t.class == class)
                .count()
    }

    /// Accept a routed task (global id): stage it for delivery. Tasks
    /// routed at later boundaries always arrive later, so this is an
    /// append; migrated-in tasks may sort earlier.
    pub fn assign(&mut self, task: Task) {
        let at = self.staged.partition_point(|t| t.arrival <= task.arrival);
        self.staged.insert(at, task);
        self.routed += 1;
    }

    /// Accept a task migrated from another replica. The inner server's
    /// undelivered queue is recalled first so the merged queue can be
    /// re-pushed in global arrival order (local ids are assigned at
    /// push time, so delivery order stays dense).
    pub fn receive_migrated(&mut self, task: Task) {
        self.recall_pending();
        self.assign(task);
        self.migrated_in += 1;
    }

    /// Pull every pushed-but-undelivered task back out of the server
    /// into the staging queue, restoring global ids. Undelivered tasks
    /// are always the most recently pushed, so the translation table
    /// truncates cleanly.
    fn recall_pending(&mut self) {
        let mut withdrawn = self.server.withdraw_pending();
        if withdrawn.is_empty() {
            return;
        }
        let keep = self.global_ids.len() - withdrawn.len();
        for t in &mut withdrawn {
            t.id = self.global_ids[t.id as usize];
        }
        self.global_ids.truncate(keep);
        // withdrawn tasks were queued before anything still staged, so
        // they precede it (equal arrivals keep queue order)
        debug_assert!(
            self.staged.first().map_or(true, |s| {
                withdrawn.last().map_or(true, |w| w.arrival <= s.arrival)
            }),
            "recall would reorder the staged queue"
        );
        withdrawn.append(&mut self.staged);
        self.staged = withdrawn;
    }

    /// Mid-generation tasks eligible for a KV-handoff migration:
    /// delivered, prefilled, unfinished tasks the scheduler has
    /// *paused* and the serving loop has already *evicted* — work that
    /// is receiving zero service here and whose cache is off-device
    /// anyway, so handing it to a peer costs this replica nothing.
    /// (On an unconstrained device nothing is ever evicted, so the
    /// running pass cannot fire — legacy runs stay bit-identical even
    /// with the flag on.) Returned as `(utility, global id, per-cycle
    /// quota, cached tokens)` sorted by ascending utility then id — the
    /// order the router offers them in. Excludes tasks that already
    /// migrated once (`migrated_before`) and earlier handoff husks.
    pub fn running_candidates(
        &self,
        migrated_before: &HashSet<TaskId>,
    ) -> Vec<(f64, TaskId, u32, u32)> {
        let mut out: Vec<(f64, TaskId, u32, u32)> = self
            .live_tasks()
            .filter(|t| {
                !t.is_finished()
                    && !t.migrated_away
                    && t.prefill_end.is_some()
                    && t.state == TaskState::Paused
                    && t.residency == Residency::Swapped
            })
            .map(|t| {
                (
                    t.utility,
                    self.global_ids[t.id as usize],
                    t.slo.tokens_per_cycle(),
                    t.seq_len(),
                )
            })
            .filter(|&(_, gid, _, _)| !migrated_before.contains(&gid))
            .collect();
        out.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).expect("utilities are finite").then(a.1.cmp(&b.1))
        });
        out
    }

    /// Extract one running task for a KV handoff: the inner server
    /// keeps a husk (excluded from scheduling and this replica's
    /// report) and the returned task — global id restored, paused, its
    /// cache marked in-flight with the pre-priced `handoff_fee` — is
    /// ready for [`Replica::receive_migrated`] on the destination.
    pub fn extract_running(&mut self, global_id: TaskId, handoff_fee: Micros) -> Task {
        let local = self
            .global_ids
            .iter()
            .position(|&g| g == global_id)
            .expect("extracting a task this replica never served") as TaskId;
        let now = self.server.now();
        let mut task = self.server.extract_task(local, now);
        task.id = global_id;
        task.state = TaskState::Paused;
        task.residency = Residency::Swapped;
        task.pending_restore = handoff_fee;
        self.routed -= 1;
        self.migrated_out += 1;
        task
    }

    /// Withdraw every queued-but-unstarted task that has not migrated
    /// before (exactly-once: `migrated_before` filters repeat offers),
    /// in arrival order, for the router to re-place. Tasks that already
    /// migrated once stay staged here.
    pub fn withdraw_unmigrated(&mut self, migrated_before: &HashSet<TaskId>) -> Vec<Task> {
        self.recall_pending();
        let mut out = Vec::new();
        let mut keep = Vec::with_capacity(self.staged.len());
        for task in self.staged.drain(..) {
            if migrated_before.contains(&task.id) {
                keep.push(task);
            } else {
                out.push(task);
            }
        }
        self.staged = keep;
        self.routed -= out.len();
        self.migrated_out += out.len() as u64;
        out
    }

    /// Withdraw *every* queued-but-unstarted task — migration history
    /// notwithstanding — in arrival order. This is the evacuation path:
    /// when this replica leaves the fleet its queue must move, even
    /// tasks that already migrated once (the exactly-once contract is
    /// per overload pass, not per lifecycle event).
    pub fn withdraw_all(&mut self) -> Vec<Task> {
        self.recall_pending();
        let out = std::mem::take(&mut self.staged);
        self.routed -= out.len();
        self.migrated_out += out.len() as u64;
        out
    }

    /// Manifest of every task in service on this replica — delivered,
    /// unfinished, not handed off — as `(global id, per-cycle quota,
    /// cached tokens, prefilled)` in delivery order. The evacuation
    /// pass prices each entry (recompute after a crash, KV handoff
    /// after a graceful leave) before extracting it.
    pub fn evacuees(&self) -> Vec<(TaskId, u32, u32, bool)> {
        self.live_tasks()
            .filter(|t| !t.is_finished() && !t.migrated_away)
            .map(|t| {
                (
                    self.global_ids[t.id as usize],
                    t.slo.tokens_per_cycle(),
                    t.seq_len(),
                    t.prefill_end.is_some(),
                )
            })
            .collect()
    }

    /// Extract one in-service task for evacuation. The inner server
    /// keeps a husk (dropped from this replica's report); the returned
    /// task carries its global id and timing record. A prefilled task
    /// leaves paused with its cache "in flight" — the caller stamps
    /// `pending_restore` once the destination (and hence the price:
    /// recompute vs. handoff) is known; an unprefilled task reverts to
    /// a fresh waiting arrival.
    pub fn extract_evacuee(&mut self, global_id: TaskId) -> Task {
        let local = self
            .global_ids
            .iter()
            .position(|&g| g == global_id)
            .expect("evacuating a task this replica never served") as TaskId;
        let now = self.server.now();
        let mut task = self.server.extract_task(local, now);
        task.id = global_id;
        if task.prefill_end.is_some() {
            task.state = TaskState::Paused;
            task.residency = Residency::Swapped;
        } else {
            task.state = TaskState::Waiting;
            task.residency = Residency::None;
        }
        task.pending_restore = 0;
        self.routed -= 1;
        self.migrated_out += 1;
        task
    }

    /// How far this replica's Eq. 7 period currently overruns its cycle
    /// cap, zero while it fits — the health tracker's boundary-lag
    /// sample ([`crate::cluster::HealthTracker`]): the signed
    /// complement of [`Replica::headroom`], sharing its scratch and
    /// cost model.
    pub fn cycle_lag(&self) -> Micros {
        let mut vs = self.quota_scratch.borrow_mut();
        vs.clear();
        self.collect_demand(&mut vs);
        vs.sort_unstable_by(|a, b| b.cmp(a));
        period_eq7(&vs, &self.profile.latency).saturating_sub(self.profile.cycle_cap)
    }

    /// Earliest time at which advancing this replica would do real work
    /// — run an engine step or deliver an arrival — or `None` when it
    /// is fully idle. This is the event engine's wake signal
    /// (DESIGN.md "Event-driven cluster engine"): the orchestrator
    /// never advances a replica before this time, and a `None` replica
    /// is never advanced at all.
    pub fn next_event_time(&self) -> Option<Micros> {
        let staged = self.staged.first().map(|t| t.arrival);
        match (self.server.next_event_time(), staged) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// Move this replica's clock to `t` without running the serving
    /// loop. Only meaningful while the replica is fully idle
    /// ([`Replica::next_event_time`] is `None`): for an idle replica,
    /// [`Replica::run_until`] would deliver nothing and step nothing —
    /// the clock move is all it does — so the event engine uses this to
    /// keep idle clocks at routing boundaries without charging an
    /// advancement.
    pub fn sync_clock(&mut self, t: Micros) {
        debug_assert!(
            self.next_event_time().is_none(),
            "sync_clock would skip real work on replica {}",
            self.id
        );
        self.server.sync_clock(t);
    }

    /// Advance this replica's simulation to time `t`, handing staged
    /// arrivals due by then to the server (assigning their dense local
    /// ids in delivery order).
    pub fn run_until(&mut self, t: Micros) -> Result<()> {
        let due = self.staged.partition_point(|task| task.arrival <= t);
        for mut task in self.staged.drain(..due) {
            let local = self.global_ids.len() as TaskId;
            self.global_ids.push(task.id);
            task.id = local;
            self.server.push_arrival(task);
        }
        self.server.run_until(t)
    }

    /// Outstanding work in tokens: remaining output of every unfinished
    /// task in service plus the full output of still-queued arrivals
    /// (staged or undelivered). This is the least-loaded routing signal.
    pub fn load_tokens(&self) -> u64 {
        let in_service: u64 = self
            .live_tasks()
            .map(|t| t.remaining_tokens() as u64)
            .sum();
        let queued: u64 = self
            .server
            .pending_arrivals()
            .chain(self.staged.iter())
            .map(|t| t.output_len as u64)
            .sum();
        in_service + queued
    }

    /// Fill `out` with the per-cycle token quotas (v_i = ceil(1s /
    /// T_TPOT)) of every live task on this replica — the Eq. 7 demand
    /// the device must serve each scheduling cycle.
    fn collect_demand(&self, out: &mut Vec<u32>) {
        out.extend(self.live_tasks().map(|t| t.slo.tokens_per_cycle()));
        out.extend(
            self.server
                .pending_arrivals()
                .chain(self.staged.iter())
                .map(|t| t.slo.tokens_per_cycle()),
        );
    }

    /// Per-cycle token quotas of every live task on this replica
    /// (observability; the decision loops use the internal scratch).
    pub fn demand_quotas(&self) -> Vec<u32> {
        let mut out = Vec::new();
        self.collect_demand(&mut out);
        out
    }

    /// Scheduling-cycle headroom (Eq. 7) if a task with per-cycle quota
    /// `cand_quota` joined this replica: `cycle_cap − T_period(demand ∪
    /// {candidate})` under this device's own latency curve and cycle
    /// cap, saturating at zero. The SLO-aware router sends a task where
    /// this is largest, which is where its Eq. 6 utility rate is most
    /// likely to survive selection. Runs against the shared quota
    /// scratch — the routing decision loop evaluates one of these per
    /// replica and must not allocate.
    pub fn headroom(&self, cand_quota: u32) -> Micros {
        let mut vs = self.quota_scratch.borrow_mut();
        vs.clear();
        self.collect_demand(&mut vs);
        vs.push(cand_quota);
        vs.sort_unstable_by(|a, b| b.cmp(a));
        self.profile
            .cycle_cap
            .saturating_sub(period_eq7(&vs, &self.profile.latency))
    }

    /// True when this replica's Eq. 7 headroom has gone negative: the
    /// cycle its queued demand implies already exceeds the device's
    /// cycle cap. The router's migration pass fires on this.
    pub fn overloaded(&self) -> bool {
        let mut vs = self.quota_scratch.borrow_mut();
        vs.clear();
        self.collect_demand(&mut vs);
        vs.sort_unstable_by(|a, b| b.cmp(a));
        period_eq7(&vs, &self.profile.latency) > self.profile.cycle_cap
    }

    /// Finish the replica's run and translate local ids back to global.
    /// Husks of tasks handed off to another replica are dropped — the
    /// destination's report carries their timing record.
    pub fn finish(self) -> ReplicaReport {
        assert!(self.staged.is_empty(), "finish() with staged arrivals");
        let mut report = self.server.finish();
        report.tasks.retain(|t| !t.migrated_away);
        for t in &mut report.tasks {
            t.id = self.global_ids[t.id as usize];
        }
        ReplicaReport {
            replica: self.id,
            routed: self.routed,
            profile: self.profile.name,
            migrated_in: self.migrated_in,
            migrated_out: self.migrated_out,
            alive: true,
            report,
        }
    }
}

/// Identity impls so the shared [`Controller`](super::controller)
/// decision code runs verbatim over bare replica slices (the lockstep
/// router) and over [`Node`](super::node::Node) slices (the event
/// engine).
impl AsRef<Replica> for Replica {
    fn as_ref(&self) -> &Replica {
        self
    }
}

impl AsMut<Replica> for Replica {
    fn as_mut(&mut self) -> &mut Replica {
        self
    }
}

/// One replica's contribution to a cluster run, with global task ids.
pub struct ReplicaReport {
    /// Fleet index of the replica.
    pub replica: usize,
    /// Tasks it ended the run holding (routed + migrated in − out).
    pub routed: usize,
    /// Device-profile tier name the replica ran.
    pub profile: &'static str,
    /// Tasks migrated onto this replica.
    pub migrated_in: u64,
    /// Tasks this replica offered back under overload.
    pub migrated_out: u64,
    /// False when the replica crashed or left before the run ended
    /// (the controller stamps the final mask; static fleets are all
    /// alive). A dead replica's report still carries every task it
    /// finished before dying.
    pub alive: bool,
    /// Its full single-device run report.
    pub report: RunReport,
}

/// Test scaffolding shared by the replica and router suites: a policy
/// and replica builder that deterministically manufacture the
/// paused+evicted states the KV-handoff migration pass operates on.
#[cfg(test)]
pub(crate) mod testutil {
    use crate::coordinator::pool::TaskPool;
    use crate::coordinator::scheduler::{Policy, Step};
    use crate::coordinator::task::{Task, TaskClass, TaskId, TaskState};
    use crate::engine::memory::{KvCacheModel, MemoryConfig};
    use crate::engine::sim::SimEngine;
    use crate::util::Micros;

    use super::super::fleet::DeviceProfile;
    use super::Replica;

    /// Prefills each delivered task once, pausing every previously
    /// prefilled task first — under a tiny KV capacity the serving loop
    /// then evicts the paused ones (the handoff candidate state).
    pub(crate) struct PrefillThenPause {
        seen: Vec<TaskId>,
    }

    impl PrefillThenPause {
        pub(crate) fn new() -> Self {
            PrefillThenPause { seen: Vec::new() }
        }
    }

    impl Policy for PrefillThenPause {
        fn name(&self) -> &'static str {
            "prefill-then-pause"
        }

        fn on_arrival(&mut self, _pool: &mut TaskPool, ids: &[TaskId], _now: Micros) {
            self.seen.extend(ids.iter().copied());
        }

        fn on_completion(&mut self, _pool: &mut TaskPool, _ids: &[TaskId], _now: Micros) {}

        fn next_step(&mut self, pool: &mut TaskPool, _now: Micros) -> Step {
            for &id in &self.seen {
                let t = pool.get_mut(id);
                if t.state == TaskState::Running && !t.is_finished() {
                    t.state = TaskState::Paused;
                }
            }
            for &id in &self.seen {
                if pool.get(id).state == TaskState::Waiting {
                    return Step::Prefill { task: id };
                }
            }
            Step::Idle
        }
    }

    /// A replica whose serving loop holds a tiny KV capacity (exactly
    /// one 81-token cache's 6 blocks), driven by [`PrefillThenPause`]:
    /// each new prefill evicts the previous paused task, leaving a
    /// deterministic trail of paused+evicted handoff candidates. The
    /// assigned real-time quotas overload the replica (4 x 20
    /// tokens/cycle exceeds the 1 s cap on the standard curve).
    pub(crate) fn evicting_replica(id: usize, n_tasks: u64) -> Replica {
        let profile = DeviceProfile::standard();
        let cap = 3 * 1024 * 1024u64; // bytes_for(81) exactly
        let kv = KvCacheModel::new(
            MemoryConfig { kv_capacity: Some(cap), ..MemoryConfig::default() },
            Some(cap),
            profile.latency.clone(),
        );
        let engine =
            SimEngine::new(profile.latency.clone(), profile.max_context).with_memory(kv);
        let mut r = Replica::new(
            id,
            Box::new(PrefillThenPause::new()),
            Box::new(engine),
            profile,
        );
        for i in 0..n_tasks {
            r.assign(Task::new(
                100 + i,
                TaskClass::RealTime,
                0,
                80,
                100,
                100.0 + i as f64,
            ));
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    use super::testutil::evicting_replica;
    use super::*;
    use crate::coordinator::orca::OrcaPolicy;
    use crate::engine::sim::SimEngine;
    use crate::util::secs;

    fn replica() -> Replica {
        replica_with(DeviceProfile::standard())
    }

    fn replica_with(profile: DeviceProfile) -> Replica {
        Replica::new(
            0,
            Box::new(OrcaPolicy::new(profile.max_batch)),
            Box::new(SimEngine::new(profile.latency.clone(), profile.max_context)),
            profile,
        )
    }

    #[test]
    fn assign_re_ids_and_finish_restores() {
        let mut r = replica();
        r.assign(Task::new(17, TaskClass::Voice, 0, 16, 5, 1.0));
        r.assign(Task::new(99, TaskClass::RealTime, secs(0.1), 16, 5, 100.0));
        assert_eq!(r.routed(), 2);
        r.run_until(secs(30.0)).unwrap();
        let rep = r.finish();
        let mut ids: Vec<TaskId> = rep.report.tasks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![17, 99]);
        assert!(rep.report.tasks.iter().all(|t| t.is_finished()));
        assert_eq!(rep.profile, "standard");
    }

    #[test]
    fn load_counts_queued_and_in_service_tokens() {
        let mut r = replica();
        assert_eq!(r.load_tokens(), 0);
        r.assign(Task::new(0, TaskClass::Voice, 0, 16, 40, 1.0));
        r.assign(Task::new(1, TaskClass::Voice, secs(5.0), 16, 7, 1.0));
        // nothing delivered yet: both still queued
        assert_eq!(r.load_tokens(), 47);
        // run past the first arrival; its remaining tokens shrink
        r.run_until(secs(1.0)).unwrap();
        assert!(r.load_tokens() < 47);
        assert!(r.load_tokens() >= 7, "queued task still counted");
    }

    #[test]
    fn headroom_shrinks_with_demand() {
        let mut r = replica();
        let empty = r.headroom(8);
        for i in 0..6 {
            r.assign(Task::new(i, TaskClass::RealTime, 0, 16, 200, 100.0));
        }
        let loaded = r.headroom(8);
        assert!(loaded < empty, "headroom {loaded} !< {empty}");
    }

    #[test]
    fn slower_profile_has_less_headroom_and_overloads_sooner() {
        // 3 real-time quotas (20 tok/cycle each): 20*l(3) = 800 ms on
        // the standard curve, 2000 ms on nano's 2.5x curve.
        let mut fast = replica_with(DeviceProfile::standard());
        let mut slow = replica_with(DeviceProfile::nano());
        for i in 0..3 {
            let t = Task::new(i, TaskClass::RealTime, 0, 16, 100, 100.0);
            fast.assign(t.clone());
            slow.assign(t);
        }
        assert!(slow.headroom(8) < fast.headroom(8));
        assert!(slow.overloaded(), "3 RT quotas exceed nano's 1s cycle");
        assert!(!fast.overloaded(), "standard absorbs 3 RT quotas");
    }

    #[test]
    fn withdraw_returns_unstarted_tasks_with_global_ids() {
        let mut r = replica();
        r.assign(Task::new(40, TaskClass::Voice, 0, 16, 30, 1.0));
        r.run_until(secs(0.5)).unwrap(); // task 40 delivered and running
        r.assign(Task::new(41, TaskClass::Voice, secs(1.0), 16, 5, 1.0));
        r.assign(Task::new(42, TaskClass::RealTime, secs(1.0), 16, 5, 100.0));
        let out = r.withdraw_unmigrated(&HashSet::new());
        assert_eq!(out.iter().map(|t| t.id).collect::<Vec<_>>(), vec![41, 42]);
        assert_eq!(r.routed(), 1);
        assert_eq!(r.migration_counts().1, 2);
        // the running task is untouched and the replica still finishes
        r.run_until(secs(30.0)).unwrap();
        let rep = r.finish();
        assert_eq!(rep.report.tasks.len(), 1);
        assert_eq!(rep.report.tasks[0].id, 40);
    }

    #[test]
    fn withdraw_skips_tasks_already_migrated_once() {
        let mut r = replica();
        r.assign(Task::new(7, TaskClass::Voice, 0, 16, 5, 1.0));
        r.assign(Task::new(8, TaskClass::Voice, 0, 16, 5, 1.0));
        let migrated: HashSet<TaskId> = [7].into_iter().collect();
        let out = r.withdraw_unmigrated(&migrated);
        assert_eq!(out.iter().map(|t| t.id).collect::<Vec<_>>(), vec![8]);
        assert_eq!(r.routed(), 1, "task 7 stays put");
        r.run_until(secs(30.0)).unwrap();
        let rep = r.finish();
        assert_eq!(rep.report.tasks[0].id, 7);
    }

    #[test]
    fn migrated_in_task_sorts_before_later_arrivals() {
        let mut r = replica();
        r.assign(Task::new(0, TaskClass::Voice, 0, 16, 200, 1.0));
        r.run_until(secs(10.0)).unwrap();
        r.assign(Task::new(5, TaskClass::Voice, secs(10.0), 16, 5, 1.0));
        // a task that arrived earlier elsewhere migrates in now
        r.receive_migrated(Task::new(3, TaskClass::Voice, secs(4.0), 16, 5, 1.0));
        assert_eq!(r.migration_counts().0, 1);
        assert_eq!(r.routed(), 3);
        r.run_until(secs(60.0)).unwrap();
        let rep = r.finish();
        let mut ids: Vec<TaskId> = rep.report.tasks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 3, 5]);
        assert!(rep.report.tasks.iter().all(|t| t.is_finished()));
    }

    #[test]
    fn running_candidates_are_paused_and_evicted_only() {
        let mut r = evicting_replica(0, 4);
        r.run_until(secs(5.0)).unwrap();
        assert!(r.overloaded(), "4 RT quotas exceed the cycle cap");
        // tasks 100..102 were paused then evicted by later prefills;
        // 103 is paused but still resident — not a candidate
        let cands = r.running_candidates(&HashSet::new());
        let ids: Vec<TaskId> = cands.iter().map(|&(_, gid, _, _)| gid).collect();
        assert_eq!(ids, vec![100, 101, 102], "cheapest utility first");
        assert_eq!(cands[0].2, 20, "real-time quota");
        assert_eq!(cands[0].3, 81, "cached tokens = prompt + prefill token");
        // exactly-once filter
        let migrated: HashSet<TaskId> = [100].into_iter().collect();
        assert_eq!(r.running_candidates(&migrated).len(), 2);

        let moved = r.extract_running(100, 7_500);
        assert_eq!(moved.id, 100);
        assert_eq!(moved.state, TaskState::Paused);
        assert_eq!(moved.residency, Residency::Swapped);
        assert_eq!(moved.pending_restore, 7_500);
        assert!(moved.tokens_generated > 0, "timing record travels with the task");
        assert_eq!(r.routed(), 3);
        assert_eq!(r.migration_counts().1, 1);
        assert!(r
            .running_candidates(&HashSet::new())
            .iter()
            .all(|&(_, gid, _, _)| gid != 100));

        // the husk never reaches the report
        r.run_until(secs(6.0)).unwrap();
        let rep = r.finish();
        let mut ids: Vec<TaskId> = rep.report.tasks.iter().map(|t| t.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![101, 102, 103]);
    }

    #[test]
    fn unconstrained_replica_has_no_handoff_candidates() {
        // same Orca replica as the other tests: tasks run resident, so
        // nothing is ever paused+evicted and the running pass cannot fire
        let mut r = replica();
        r.assign(Task::new(0, TaskClass::RealTime, 0, 16, 200, 100.0));
        r.assign(Task::new(1, TaskClass::RealTime, 0, 16, 200, 100.0));
        r.run_until(secs(1.0)).unwrap();
        assert!(r.running_candidates(&HashSet::new()).is_empty());
    }

    #[test]
    fn next_event_time_covers_staged_pending_and_live_work() {
        let mut r = replica();
        assert_eq!(r.next_event_time(), None, "fresh replica is idle");
        r.sync_clock(secs(3.0));
        assert_eq!(r.now(), secs(3.0), "idle clock syncs without advancement");
        r.assign(Task::new(0, TaskClass::Voice, secs(5.0), 16, 400, 1.0));
        assert_eq!(
            r.next_event_time(),
            Some(secs(5.0)),
            "staged arrival is the next event"
        );
        r.run_until(secs(5.5)).unwrap();
        assert_eq!(
            r.next_event_time(),
            Some(r.now()),
            "live unfinished work wakes immediately"
        );
        r.run_until(secs(60.0)).unwrap();
        assert_eq!(r.next_event_time(), None, "drained replica is idle again");
        let _ = r.finish();
    }

    #[test]
    fn withdraw_all_ignores_migration_history() {
        let mut r = replica();
        r.assign(Task::new(7, TaskClass::Voice, 0, 16, 5, 1.0));
        r.assign(Task::new(8, TaskClass::Voice, secs(1.0), 16, 5, 1.0));
        // withdraw_unmigrated would leave 7 behind; evacuation must not
        let out = r.withdraw_all();
        assert_eq!(out.iter().map(|t| t.id).collect::<Vec<_>>(), vec![7, 8]);
        assert_eq!(r.routed(), 0);
        assert_eq!(r.migration_counts().1, 2);
        assert!(r.evacuees().is_empty(), "nothing was in service");
        let _ = r.finish();
    }

    #[test]
    fn evacuees_price_as_restarts_and_extract_keeps_record() {
        let mut r = evicting_replica(0, 3);
        r.run_until(secs(5.0)).unwrap();
        let manifest = r.evacuees();
        assert_eq!(manifest.len(), 3, "all delivered tasks are in service");
        assert!(
            manifest.iter().all(|&(_, q, tok, pre)| q == 20 && tok == 81 && pre),
            "real-time quotas, 81 cached tokens, all prefilled"
        );
        let t = r.extract_evacuee(100);
        assert_eq!(t.id, 100);
        assert_eq!(t.state, TaskState::Paused);
        assert_eq!(t.residency, Residency::Swapped);
        assert_eq!(t.pending_restore, 0, "caller prices the restore");
        assert!(t.tokens_generated > 0, "timing record travels with the task");
        assert_eq!(r.evacuees().len(), 2, "husk left the manifest");
        assert_eq!(r.routed(), 2);
        // the husk never reaches the report
        r.run_until(secs(6.0)).unwrap();
        let rep = r.finish();
        assert!(rep.report.tasks.iter().all(|t| t.id != 100));
        assert!(rep.alive, "finish() defaults to alive; the controller stamps");
    }

    #[test]
    fn unprefilled_evacuee_reverts_to_fresh_arrival() {
        // a policy that never schedules: delivered tasks stay Waiting
        struct NeverRun;
        impl crate::coordinator::scheduler::Policy for NeverRun {
            fn name(&self) -> &'static str {
                "never-run"
            }
            fn on_arrival(
                &mut self,
                _pool: &mut crate::coordinator::pool::TaskPool,
                _ids: &[TaskId],
                _now: Micros,
            ) {
            }
            fn on_completion(
                &mut self,
                _pool: &mut crate::coordinator::pool::TaskPool,
                _ids: &[TaskId],
                _now: Micros,
            ) {
            }
            fn next_step(
                &mut self,
                _pool: &mut crate::coordinator::pool::TaskPool,
                _now: Micros,
            ) -> crate::coordinator::scheduler::Step {
                crate::coordinator::scheduler::Step::Idle
            }
        }
        let profile = DeviceProfile::standard();
        let mut r = Replica::new(
            0,
            Box::new(NeverRun),
            Box::new(SimEngine::new(profile.latency.clone(), profile.max_context)),
            profile,
        );
        r.assign(Task::new(42, TaskClass::Voice, 0, 16, 5, 1.0));
        r.run_until(secs(1.0)).unwrap();
        let manifest = r.evacuees();
        assert_eq!(manifest.len(), 1);
        assert!(!manifest[0].3, "never prefilled");
        let t = r.extract_evacuee(42);
        assert_eq!(t.state, TaskState::Waiting);
        assert_eq!(t.residency, Residency::None);
        assert_eq!(t.pending_restore, 0);
    }

    #[test]
    fn cycle_lag_is_headrooms_signed_complement() {
        let mut r = replica();
        assert_eq!(r.cycle_lag(), 0, "idle replica has no lag");
        for i in 0..3 {
            r.assign(Task::new(i, TaskClass::RealTime, 0, 16, 100, 100.0));
        }
        assert_eq!(r.cycle_lag(), 0, "3 RT quotas fit the standard cycle");
        assert!(!r.overloaded());
        for i in 3..6 {
            r.assign(Task::new(i, TaskClass::RealTime, 0, 16, 100, 100.0));
        }
        assert!(r.overloaded());
        assert!(r.cycle_lag() > 0, "overload implies positive lag");
    }

    #[test]
    fn queued_in_class_counts_staged_and_waiting() {
        let mut r = replica();
        r.assign(Task::new(0, TaskClass::RealTime, 0, 16, 5, 100.0));
        r.assign(Task::new(1, TaskClass::Voice, 0, 16, 5, 1.0));
        r.assign(Task::new(2, TaskClass::Voice, secs(9.0), 16, 5, 1.0));
        assert_eq!(r.queued_in_class(TaskClass::RealTime), 1);
        assert_eq!(r.queued_in_class(TaskClass::Voice), 2);
        r.run_until(secs(30.0)).unwrap();
        assert_eq!(r.queued_in_class(TaskClass::Voice), 0);
    }
}
