//! The event-driven cluster engine: a global binary-heap event queue
//! over per-replica [`Node`]s, advancing a replica only when it has
//! work (DESIGN.md "Event-driven cluster engine").
//!
//! The lockstep reference engine ([`crate::cluster::Router`]) advances
//! every replica to every arrival — O(arrivals × replicas) `run_until`
//! calls, almost all of them no-ops on wide fleets. This engine keeps
//! one [`EventHeap`] ordered by the deterministic key
//! `(time, kind, replica, task)` and pops three event kinds:
//!
//!   * [`EventKind::Wake`] — a node's next-interesting-event time was
//!     reached: advance *that node* to the current routing boundary
//!     (one `run_until`, the same call lockstep would have made);
//!   * [`EventKind::RescheduleBoundary`] — the final drain boundary at
//!     the common horizon;
//!   * [`EventKind::Arrival`] — route one task: run the shared
//!     [`Controller`] migration passes, decide, assign.
//!
//! Exactly one `Arrival` event is in the heap at a time (the next one
//! is pushed after the current one is handled), so the heap holds at
//! most one wake per node plus two boundary events — O(events log
//! replicas) total work.
//!
//! ## Why this reproduces lockstep bit-for-bit
//!
//! The engine only ever calls `run_until` with *boundary times* — the
//! same arrival-time/horizon targets the lockstep loop uses — and it
//! skips exactly the calls that would have been no-ops: a replica with
//! no live, staged, or pending work neither delivers arrivals nor runs
//! engine steps under `run_until`, it only moves its clock, and every
//! routing-visible load signal is clock-independent. Wake events sort
//! *before* same-time `Arrival`/`RescheduleBoundary` events (the kind
//! rank), so every node with work due by a boundary is advanced to it
//! before the boundary's decision runs — the lockstep order. Migration
//! passes run *inline* in the `Arrival` handler (not as separate heap
//! events): lockstep interleaves (migrate, decide) per task even for
//! same-time arrivals, and the kind-major tie-break would otherwise
//! batch all same-time reschedules ahead of all same-time arrivals,
//! changing decision order. The equivalence suite
//! (`rust/tests/equivalence.rs`) pins all of this: every cluster /
//! hetero-fleet / memory cell must produce an identical
//! [`ClusterReport`] under both engines.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::Result;

use crate::coordinator::task::{Task, TaskId};
use crate::engine::memory::MemoryConfig;
use crate::util::Micros;

use super::controller::Controller;
use super::fleet::AdmissionConfig;
use super::node::Node;
use super::replica::Replica;
use super::router::{ClusterReport, RoutingStrategy};

/// What a popped event asks the orchestrator to do. The discriminant
/// order is the heap tie-break rank at equal times: wakes first (nodes
/// reach the boundary before any decision runs there), then the drain
/// boundary, then arrivals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// A node's next-interesting-event time arrived: advance it.
    Wake,
    /// The common drain horizon: advance everything with work, finish.
    RescheduleBoundary,
    /// Route the next workload task.
    Arrival,
}

/// One scheduled event. Ordering is the documented deterministic
/// contract: time, then kind rank, then replica id, then task id —
/// derived lexicographically from the field order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Event {
    /// Virtual time the event fires at.
    pub time: Micros,
    /// What to do (and the same-time rank; see [`EventKind`]).
    pub kind: EventKind,
    /// Node the event concerns (wake events; 0 otherwise).
    pub replica: usize,
    /// Task the event concerns (arrival events; 0 otherwise).
    pub task: TaskId,
}

/// A min-heap of [`Event`]s popping in `(time, kind, replica, task)`
/// order. Public so the property suite can drive it directly (the
/// never-pops-out-of-order invariant).
#[derive(Default)]
pub struct EventHeap {
    heap: BinaryHeap<Reverse<Event>>,
}

impl EventHeap {
    /// An empty heap.
    pub fn new() -> Self {
        EventHeap { heap: BinaryHeap::new() }
    }

    /// Schedule an event.
    pub fn push(&mut self, event: Event) {
        self.heap.push(Reverse(event));
    }

    /// Pop the least event under the deterministic key.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// The least event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek().map(|Reverse(e)| e)
    }

    /// Number of scheduled events (stale wake entries included).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// The event-driven cluster engine: same construction surface and same
/// [`ClusterReport`] as [`crate::cluster::Router`], different time
/// advancement.
pub struct Orchestrator {
    nodes: Vec<Node>,
    ctl: Controller,
}

impl Orchestrator {
    /// Build an orchestrator over pre-constructed replicas (at least
    /// one), mirroring [`crate::cluster::Router::new`].
    pub fn new(strategy: RoutingStrategy, replicas: Vec<Replica>) -> Self {
        assert!(!replicas.is_empty(), "a cluster needs at least one replica");
        assert!(
            replicas.iter().enumerate().all(|(i, r)| r.id() == i),
            "replica ids must equal their fleet position"
        );
        Orchestrator {
            nodes: replicas.into_iter().map(Node::new).collect(),
            ctl: Controller::new(strategy),
        }
    }

    /// Enable/configure per-class admission bounds.
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.ctl.admission = admission;
        self
    }

    /// Enable or disable overload migration.
    pub fn with_migration(mut self, migration: bool) -> Self {
        self.ctl.migration = migration;
        self
    }

    /// Enable running-task KV-handoff migration, priced by `memory`.
    pub fn with_running_migration(mut self, enabled: bool, memory: MemoryConfig) -> Self {
        self.ctl.migrate_running = enabled;
        self.ctl.memory = memory;
        self
    }

    /// Number of replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.nodes.len()
    }

    /// Recompute a node's wake time after its workload changed
    /// (assignment or migration) and reschedule it in the heap. Stale
    /// heap entries are invalidated by the wake-time mismatch on pop.
    fn refresh_wake(&mut self, idx: usize, heap: &mut EventHeap) {
        let node = &mut self.nodes[idx];
        let next = node.next_event_time();
        if node.wake() == next {
            return; // already scheduled at the right time
        }
        match next {
            Some(t) => {
                node.set_wake(t);
                heap.push(Event { time: t, kind: EventKind::Wake, replica: idx, task: 0 });
            }
            None => node.clear_wake(),
        }
    }

    /// Route and serve an entire workload, then drain to `last_arrival
    /// + drain` — the same contract as [`crate::cluster::Router::run`],
    /// with identical output.
    pub fn run(self, workload: Vec<Task>, drain: Micros) -> Result<ClusterReport> {
        self.run_counted(workload, drain).map(|(report, _)| report)
    }

    /// [`Orchestrator::run`], additionally returning the per-node
    /// advancement counts (how many `run_until` calls each replica
    /// received) — the observability hook the idle-replica property
    /// test and the scale sweep's activity accounting use.
    pub fn run_counted(
        mut self,
        workload: Vec<Task>,
        drain: Micros,
    ) -> Result<(ClusterReport, Vec<u64>)> {
        assert!(
            workload.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "workload must be sorted by arrival"
        );
        let last_arrival = workload.last().map_or(0, |t| t.arrival);
        let horizon = last_arrival + drain;
        let mut arrivals = workload.into_iter();
        let mut heap = EventHeap::new();
        // nodes that reached the current boundary and whose recomputed
        // wake is *at* the boundary (still busy there): re-armed after
        // the boundary advances, so a busy node cannot wake-loop
        let mut parked: Vec<usize> = Vec::new();
        // the single in-flight arrival (its heap event carries the id)
        let mut next_arrival: Option<Task> = None;
        // time of the next Arrival event, or the horizon once the
        // workload is exhausted — every wake advances its node here
        let mut next_boundary = match arrivals.next() {
            Some(t) => {
                let at = t.arrival;
                heap.push(Event { time: at, kind: EventKind::Arrival, replica: 0, task: t.id });
                next_arrival = Some(t);
                at
            }
            None => {
                heap.push(Event {
                    time: horizon,
                    kind: EventKind::RescheduleBoundary,
                    replica: 0,
                    task: 0,
                });
                horizon
            }
        };

        loop {
            let ev = heap
                .pop()
                .expect("the boundary-event chain keeps the heap non-empty");
            match ev.kind {
                EventKind::Wake => {
                    let node = &mut self.nodes[ev.replica];
                    if node.wake() != Some(ev.time) {
                        continue; // stale entry: the wake was refreshed
                    }
                    node.clear_wake();
                    if node.advanced_to() == Some(next_boundary) {
                        // already at the boundary and busy there —
                        // re-arm only after the boundary moves on
                        parked.push(ev.replica);
                        continue;
                    }
                    node.advance_to(next_boundary)?;
                    if let Some(t) = node.next_event_time() {
                        node.set_wake(t);
                        heap.push(Event {
                            time: t,
                            kind: EventKind::Wake,
                            replica: ev.replica,
                            task: 0,
                        });
                    }
                }
                EventKind::Arrival => {
                    let task = next_arrival.take().expect("arrival event without its task");
                    debug_assert_eq!(task.id, ev.task);
                    if self.ctl.migration {
                        // a migrated-in task may carry an arrival time
                        // earlier than this boundary, so an *idle*
                        // destination must have its clock at the
                        // boundary — where lockstep left it — before
                        // the task lands, or it would be delivered (and
                        // prefilled) in the destination's past. Busy
                        // nodes are already here via their wakes; idle
                        // ones only need the clock moved (uncounted —
                        // no arrivals to deliver, no steps to run).
                        for node in &mut self.nodes {
                            if node.advanced_to() != Some(ev.time)
                                && node.next_event_time().is_none()
                            {
                                node.sync_clock(ev.time);
                            }
                        }
                    }
                    // inline migration passes, then decide — the exact
                    // per-task interleaving the lockstep loop runs
                    self.ctl.run_migrations(&mut self.nodes);
                    self.ctl.run_running_migrations(&mut self.nodes);
                    let pick = self.ctl.decide(&self.nodes, &task);
                    match pick {
                        Some(p) => self.nodes[p].as_mut().assign(task),
                        None => self.ctl.rejected.push(task),
                    }
                    // move the boundary forward *before* re-arming
                    // wakes, so a wake at this same time advances
                    // instead of parking forever
                    next_boundary = match arrivals.next() {
                        Some(t) => {
                            let at = t.arrival;
                            heap.push(Event {
                                time: at,
                                kind: EventKind::Arrival,
                                replica: 0,
                                task: t.id,
                            });
                            next_arrival = Some(t);
                            at
                        }
                        None => {
                            heap.push(Event {
                                time: horizon,
                                kind: EventKind::RescheduleBoundary,
                                replica: 0,
                                task: 0,
                            });
                            horizon
                        }
                    };
                    if self.ctl.migration {
                        // migration may have moved work between any
                        // pair of nodes: re-arm the whole fleet (the
                        // pass itself is already O(replicas))
                        for i in 0..self.nodes.len() {
                            self.refresh_wake(i, &mut heap);
                        }
                        parked.clear();
                    } else {
                        // only the assigned node's workload changed
                        for i in std::mem::take(&mut parked) {
                            self.refresh_wake(i, &mut heap);
                        }
                        if let Some(p) = pick {
                            self.refresh_wake(p, &mut heap);
                        }
                    }
                }
                EventKind::RescheduleBoundary => {
                    debug_assert_eq!(ev.time, horizon);
                    // the drain boundary: same-time wakes already
                    // popped (kind rank), so every node with live work
                    // has been advanced to the horizon. Nodes that had
                    // work earlier but idled drain with a (counted)
                    // advancement, exactly like lockstep; nodes that
                    // never had work only sync their clock so reports
                    // end at the common horizon with zero advancements.
                    for node in &mut self.nodes {
                        if node.advanced_to() == Some(horizon) {
                            // drained by its own wake
                        } else if node.advancements() > 0 || node.wake().is_some() {
                            node.advance_to(horizon)?;
                        } else {
                            node.sync_clock(horizon);
                        }
                        let r = node.as_ref();
                        assert!(
                            r.pending() == 0,
                            "drain window too small: replica {} has {} undelivered arrivals",
                            r.id(),
                            r.pending()
                        );
                    }
                    break;
                }
            }
        }

        let counts: Vec<u64> = self.nodes.iter().map(Node::advancements).collect();
        let replicas: Vec<Replica> =
            self.nodes.into_iter().map(Node::into_replica).collect();
        Ok((self.ctl.into_report(replicas), counts))
    }
}
